//! The overhead manager at work: decisions, Gantt schedules, and the
//! Amdahl gap.
//!
//! ```bash
//! cargo run --release --example adaptive_scheduler
//! ```
//!
//! 1. Shows the manager's serial/parallel verdicts across work sizes and
//!    the computed serial cutoff.
//! 2. Renders Gantt timelines for a managed quicksort and matmul on the
//!    simulated machine — the α/β overhead segments are visible inline.
//! 3. Prints the ideal-vs-adjusted speedup sweep (the paper's Amdahl
//!    criticism).

use ohm::dla::matmul;
use ohm::exec::ExecCtx;
use ohm::overhead::{amdahl, Manager, OverheadParams, WorkEstimate};
use ohm::report::gantt;
use ohm::sort::{parallel_quicksort, PivotStrategy};
use ohm::workload::{arrays, matrices};

fn main() {
    let params = OverheadParams::paper_2022();
    let mgr = Manager::new(params, 4);

    println!("== manager decisions (4 cores, paper-2022 overheads)");
    for work_us in [10.0, 100.0, 500.0, 2_000.0, 50_000.0] {
        let est = WorkEstimate::fully_parallel(work_us * 1e3, 64 << 10);
        let d = mgr.decide(&est);
        println!("  work {work_us:>8.0} µs → {d:?}");
    }
    let cutoff = mgr.serial_cutoff_ns(1.0, 1e12);
    println!("  serial cutoff: {:.1} µs of work\n", cutoff / 1e3);

    println!("== Gantt: managed quicksort, n=2000, 4 virtual cores");
    let ctx = ExecCtx::simulated(4, params).with_trace(true);
    let mut xs = arrays::uniform_i64(2000, 7);
    let rep = parallel_quicksort(&mut xs, PivotStrategy::Mean, &ctx);
    print!("{}", gantt::render(&rep.timeline, 4, 100));

    println!("\n== Gantt: managed matmul, order 256");
    let a = matrices::uniform(256, 256, 1);
    let b = matrices::uniform(256, 256, 2);
    let (_, rep) = matmul::run(&a, &b, &ctx);
    print!("{}", gantt::render(&rep.timeline, 4, 100));

    println!("\n== Amdahl vs overhead-adjusted speedup (matmul order 512)");
    let est = WorkEstimate::fully_parallel(512f64.powi(3), (2 * 512 * 512 * 4) as u64);
    println!("  {:>6} {:>8} {:>10} {:>8}", "cores", "ideal", "adjusted", "gap");
    for (p, ideal, adj) in amdahl::sweep(&params, &est, &[1, 2, 4, 8, 16, 32]) {
        println!("  {p:>6} {ideal:>8.2} {adj:>10.2} {:>8.2}", ideal - adj);
    }
    if let Some(sat) = amdahl::saturation_point(&params, &est, 64) {
        println!("  speedup saturates at {sat} cores");
    }
}
