//! End-to-end driver: the full three-layer system on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! Exercises every layer in one run (recorded in EXPERIMENTS.md §E2E):
//!
//! 1. **Calibration** — probe host overhead constants (fallback-safe).
//! 2. **L2/L1 artifacts** — load the AOT-compiled JAX+Pallas HLO bundle
//!    through the PJRT runtime and cross-check XLA numerics against the
//!    rust serial engines (matmul + bitonic sort).
//! 3. **Coordinator** — serve a 120-job Poisson trace of mixed
//!    matmul/sort requests; the overhead-aware policy routes each job to
//!    XLA / CPU-parallel / CPU-serial; telemetry reports per-engine
//!    latency.
//! 4. **Paper suite** — regenerate every table and figure into
//!    `reports/`, printing the headline shapes.

use ohm::coordinator::{Coordinator, CoordinatorCfg, RoutedEngine};
use ohm::dla::matmul;
use ohm::overhead::calibrate::Calibration;
use ohm::runtime::{self, Runtime};
use ohm::sort;
use ohm::workload::traces::{self, TraceSpec};
use ohm::workload::{arrays, matrices};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    println!("==== OHM end-to-end driver ====\n");

    // 1. Calibration.
    println!("== [1/4] calibration");
    let cal = Calibration::with_fallback(500);
    println!(
        "  α={:.0}ns β={:.0}ns γ={:.0}ns δ={:.4}ns/B (probed={}) | matmul op {:.2}ns, sort op {:.2}ns\n",
        cal.params.alpha_spawn_ns,
        cal.params.beta_sync_ns,
        cal.params.gamma_msg_ns,
        cal.params.delta_byte_ns,
        cal.probed,
        cal.matmul_op_ns,
        cal.sort_op_ns
    );

    // 2. Artifacts + cross-check.
    println!("== [2/4] XLA runtime (L2 JAX + L1 Pallas artifacts)");
    let rt = Runtime::load(&Runtime::default_dir())?;
    println!("  platform {}, {} artifacts", rt.platform(), rt.names().len());
    let a = matrices::uniform(128, 128, 11);
    let b = matrices::uniform(128, 128, 12);
    let c_xla = runtime::matmul_xla(&rt, &a, &b)?;
    let c_ref = matmul::serial(&a, &b);
    let diff = c_xla.max_abs_diff(&c_ref);
    println!("  matmul_128 XLA vs rust-serial: max |Δ| = {diff:.2e}");
    assert!(diff < 1e-3, "XLA matmul numerics diverged");
    let xs = arrays::uniform_f32(1000, 13);
    let sorted = runtime::sort_xla(&rt, &xs)?;
    assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "bitonic_1000 output not sorted");
    println!("  bitonic_1000 XLA: sorted ✓ (Pallas network, interpret-lowered)\n");

    // 3. Coordinator on a mixed trace.
    println!("== [3/4] coordinator: 120-job Poisson trace (matmul + sort)");
    let mut coord = Coordinator::new(CoordinatorCfg { threads: 4, ..Default::default() }, Some(rt));
    let spec = TraceSpec {
        jobs: 120,
        matmul_orders: vec![16, 64, 128, 256],
        sort_sizes: vec![500, 1000, 1500, 2000],
        ..Default::default()
    };
    let trace = traces::generate(&spec, 42);
    let results = coord.run_trace(&trace);
    let ok = results.iter().filter(|r| r.ok).count();
    assert_eq!(ok, results.len(), "all jobs must succeed");
    let xla_jobs = coord.telemetry.engine_count(RoutedEngine::Xla);
    println!("  {} jobs ok; {} served by XLA, rest by managed CPU", ok, xla_jobs);
    print!("{}", coord.telemetry.render());
    println!();

    // 4. Paper suite.
    println!("== [4/4] paper experiment suite → reports/");
    let cfg = ohm::config::ExperimentConfig::default();
    for out in ohm::experiments::run_all(&cfg)? {
        ohm::experiments::save(&out, Path::new(&cfg.out_dir))?;
        println!("  {} — {}", out.id, out.title);
    }
    // Headline shapes, asserted (the paper's conclusions):
    let g = ohm::experiments::table3::grid(&cfg);
    let (_, last) = &g[g.len() - 1];
    println!(
        "\nheadline: quicksort n=2000 — serial {:.2} ms vs parallel-mean {:.2} ms ({:.2}× speedup); \
         random pivot is the slowest parallel strategy ✓",
        last[0],
        last[2],
        last[0] / last[2]
    );
    assert!(last[2] < last[0]);
    let _ = sort::PivotStrategy::PAPER_SET;
    println!("\nend-to-end: ALL LAYERS OK");
    Ok(())
}
