//! Figure 2 reproduction: the serial/parallel crossover for matmul.
//!
//! ```bash
//! cargo run --release --example matmul_crossover
//! ```
//!
//! Sweeps matrix orders and prints three curves — serial, the paper's
//! naive per-row-thread platform (crossover ≈ order 1000, matching the
//! paper's "minimum 1000 and above"), and OHM's managed execution
//! (crossover an order of magnitude earlier). Also writes
//! `reports/fig2_matmul.csv`.

use ohm::config::ExperimentConfig;
use ohm::experiments;

fn main() {
    let cfg = ExperimentConfig {
        matmul_orders: vec![16, 32, 64, 128, 256, 512, 750, 1000, 1500, 2048],
        ..Default::default()
    };
    let out = experiments::run("fig2", &cfg).expect("fig2");
    print!("{}", out.text);
    let paths = experiments::save(&out, std::path::Path::new(&cfg.out_dir)).expect("save");
    for p in paths {
        println!("wrote {}", p.display());
    }
}
