//! Quickstart: overhead-managed execution in a dozen lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Runs one matmul and one quicksort under the overhead manager on the
//! simulated 4-core machine, printing virtual time, speedup, and the
//! overhead ledger — the paper's methodology end to end.

use ohm::dla::matmul;
use ohm::exec::ExecCtx;
use ohm::overhead::OverheadParams;
use ohm::sort::{parallel_quicksort, PivotStrategy};
use ohm::workload::{arrays, matrices};

fn main() {
    // A 4-core machine with the paper-calibrated overhead constants.
    let ctx = ExecCtx::simulated(4, OverheadParams::paper_2022());

    // --- Dense linear algebra: C = A·B, order 512 --------------------
    let a = matrices::uniform(512, 512, 1);
    let b = matrices::uniform(512, 512, 2);
    let (c, rep) = matmul::run(&a, &b, &ctx);
    println!(
        "matmul 512³: {:.3} ms virtual, speedup {:.2}×, ledger: {}",
        rep.time_us() / 1e3,
        rep.speedup().unwrap(),
        rep.ledger.summary()
    );
    assert!(c.frobenius() > 0.0);

    // --- Sorting: 100k elements, mean pivot --------------------------
    let mut data = arrays::uniform_i64(100_000, 42);
    let rep = parallel_quicksort(&mut data, PivotStrategy::Mean, &ctx);
    assert!(data.windows(2).all(|w| w[0] <= w[1]));
    println!(
        "quicksort 100k: {:.3} ms virtual, speedup {:.2}×, spawns {}",
        rep.time_us() / 1e3,
        rep.speedup().unwrap(),
        rep.ledger.spawns
    );

    // --- The management decision itself -------------------------------
    // Small problems are kept serial (the fork-join switch):
    let tiny = matrices::uniform(8, 8, 3);
    let (_, rep) = matmul::run(&tiny, &tiny, &ctx);
    println!(
        "matmul 8³: spawns = {} (manager kept it serial — overhead would dominate)",
        rep.ledger.spawns
    );
}
