//! Table 3 / Figure 5 reproduction: quicksort pivot strategies,
//! serial vs parallel.
//!
//! ```bash
//! cargo run --release --example sort_pivots
//! ```
//!
//! Prints our simulated grid next to the paper's published values and the
//! Fig 5 chart; writes `reports/table3_quicksort.csv` and
//! `reports/fig5_quicksort_series.csv`.

use ohm::config::ExperimentConfig;
use ohm::experiments;

fn main() {
    let cfg = ExperimentConfig::default(); // paper sizes: 1000..2000, 4 cores
    for id in ["table3", "fig5"] {
        let out = experiments::run(id, &cfg).expect(id);
        print!("{}", out.text);
        let paths = experiments::save(&out, std::path::Path::new(&cfg.out_dir)).expect("save");
        for p in paths {
            println!("wrote {}", p.display());
        }
        println!();
    }
}
