"""AOT pipeline: lower every L2 model variant to HLO text + manifest.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under ``--out-dir`` (default ``../artifacts`` relative to the
``python/`` package root):

* ``<name>.hlo.txt``  — one per registry variant
* ``manifest.tsv``    — one line per artifact, tab-separated:
      name  file  n_inputs  input_specs  output_spec
  where a spec is ``dtype:d0xd1x...`` and input_specs are
  ``;``-joined.  The rust loader (`runtime::artifact`) parses exactly
  this format; keep the two in sync.

Run via ``make artifacts`` (no-op when inputs are unchanged — make
compares mtimes).  Python never runs at request time.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Sequence

import jax
from jax._src.lib import xla_client as xc

from . import model

MANIFEST_NAME = "manifest.tsv"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _fmt_spec(spec) -> str:
    dims = "x".join(str(d) for d in spec.shape)
    return f"{spec.dtype}:{dims}" if dims else f"{spec.dtype}:scalar"


def lower_variant(name: str, fn, specs: Sequence[jax.ShapeDtypeStruct]):
    """Lower one variant; returns (hlo_text, output_spec)."""
    lowered = jax.jit(fn).lower(*specs)
    out_aval = jax.eval_shape(fn, *specs)[0]
    return to_hlo_text(lowered), out_aval


def export_all(out_dir: str, only: List[str] | None = None) -> List[str]:
    """Lower every registry variant into out_dir; returns manifest lines."""
    os.makedirs(out_dir, exist_ok=True)
    reg = model.registry()
    names = only if only else sorted(reg)
    lines: List[str] = []
    for name in names:
        if name not in reg:
            raise SystemExit(f"unknown variant {name!r}; have {sorted(reg)}")
        fn, specs = reg[name]
        text, out_spec = lower_variant(name, fn, specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        in_specs = ";".join(_fmt_spec(s) for s in specs)
        lines.append(
            "\t".join([name, fname, str(len(specs)), in_specs, _fmt_spec(out_spec)])
        )
        print(f"  lowered {name}: {len(text)} chars", file=sys.stderr)
    with open(os.path.join(out_dir, MANIFEST_NAME), "w") as f:
        f.write("\n".join(lines) + "\n")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out-dir",
        default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
        help="artifact output directory",
    )
    ap.add_argument(
        "--only",
        nargs="*",
        default=None,
        help="lower only these variant names (default: all)",
    )
    args = ap.parse_args()
    lines = export_all(os.path.abspath(args.out_dir), args.only)
    print(f"wrote {len(lines)} artifacts + {MANIFEST_NAME} to {args.out_dir}")


if __name__ == "__main__":
    main()
