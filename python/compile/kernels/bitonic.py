"""L1 Pallas kernel: bitonic sorting network.

Hardware adaptation of the paper's parallel quicksort (DESIGN.md
§Hardware-Adaptation).  Quicksort's recursion is control-flow- and
data-dependent, which does not map onto a fixed-shape dataflow device;
the canonical TPU equivalent of "divide the array among cores and sort
sub-ranges in parallel" is the bitonic network: O(log^2 n) stages of
data-independent compare-exchanges, every stage perfectly parallel with
zero synchronization inside a stage — the same overhead structure the
paper engineers for (sync only at stage joins, disjoint writes).

The whole network runs inside one Pallas kernel (array resident in
VMEM), with ``interpret=True`` for CPU-PJRT executability.  Oracle:
``jnp.sort`` via ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(x: jax.Array, idx: jax.Array, k: jax.Array, j: jax.Array) -> jax.Array:
    """One bitonic substage over the whole array (vectorized).

    Element i is paired with i^j; the pair is ordered ascending when
    (i & k) == 0, descending otherwise.  Both halves of every pair
    compute the same min/max, so writes are disjoint and branch-free —
    the kernel-level analogue of the paper's "no multiple copies of the
    same index" output rule (Table 2).
    """
    partner = idx ^ j
    px = jnp.take(x, partner, axis=0)
    ascending = (idx & k) == 0
    is_low = idx < partner
    take_min = jnp.where(ascending, is_low, ~is_low)
    lo = jnp.minimum(x, px)
    hi = jnp.maximum(x, px)
    return jnp.where(take_min, lo, hi)


def _bitonic_kernel(x_ref, o_ref, *, log_n: int):
    """Full bitonic sort network: log_n stages, stage kk has kk+1 substages."""
    x = x_ref[...]
    n = x.shape[0]
    idx = jax.lax.iota(jnp.int32, n)

    def stage_body(kk, x):
        k = jnp.int32(2) << kk  # k = 2^(kk+1)

        def substage_body(jj, x):
            j = k >> (jj + 1)  # j = k/2, k/4, ..., 1
            return _compare_exchange(x, idx, k, j)

        return jax.lax.fori_loop(0, kk + 1, substage_body, x)

    o_ref[...] = jax.lax.fori_loop(0, log_n, stage_body, x)


def _stage_kernel(x_ref, o_ref, *, k: int, j: int):
    """A single (k, j) substage as its own kernel (test granularity)."""
    x = x_ref[...]
    idx = jax.lax.iota(jnp.int32, x.shape[0])
    o_ref[...] = _compare_exchange(x, idx, jnp.int32(k), jnp.int32(j))


def _check_pow2(n: int) -> int:
    log_n = n.bit_length() - 1
    assert 1 << log_n == n, f"bitonic network needs power-of-two length, got {n}"
    return log_n


def sort(x: jax.Array) -> jax.Array:
    """Bitonic-sort a power-of-two-length 1-D array ascending."""
    (n,) = x.shape
    log_n = _check_pow2(n)
    if n == 1:
        return x
    kernel = functools.partial(_bitonic_kernel, log_n=log_n)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)


def sort_stage(x: jax.Array, k: int, j: int) -> jax.Array:
    """Run one compare-exchange substage (used by stage-level tests)."""
    (n,) = x.shape
    _check_pow2(n)
    kernel = functools.partial(_stage_kernel, k=k, j=j)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)


def _max_sentinel(dtype) -> jax.Array:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype=dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype=dtype)


def sort_padded(x: jax.Array) -> jax.Array:
    """Sort any-length 1-D array: pad with +max to the next power of two.

    The sentinels sort to the tail and are sliced off, so the visible
    result is exact for any input that does not itself contain the
    sentinel value at the clipped positions.
    """
    (n,) = x.shape
    if n == 0:
        return x
    np2 = 1 << max(0, (n - 1).bit_length())
    if np2 == n:
        return sort(x)
    pad = jnp.full((np2 - n,), _max_sentinel(x.dtype), dtype=x.dtype)
    return sort(jnp.concatenate([x, pad]))[:n]


def comparator_count(n: int) -> int:
    """Total compare-exchange ops (perf model: work = n/2 per substage)."""
    log_n = _check_pow2(n)
    substages = log_n * (log_n + 1) // 2
    return substages * (n // 2)
