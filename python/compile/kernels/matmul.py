"""L1 Pallas kernel: tiled matrix multiplication.

The paper's matmul parallelization distributes row/column work among
cores (master-slave) and keeps the inter-product additions core-local so
no synchronization happens inside a row-column product.  The TPU mapping
of that insight (DESIGN.md §Hardware-Adaptation):

* the Pallas grid plays the role of the master-slave distribution —
  each (i, j) grid step owns one disjoint output tile, so there is no
  output synchronization (the paper's "replication of output matrix"
  overhead is structurally absent);
* the K-loop accumulates into the output tile held in VMEM — the
  paper's "inter-product addition" stays core-local;
* tiles are 128x128 by default, matching the MXU systolic array shape,
  staged HBM->VMEM by BlockSpec.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
any backend (including the rust-side PJRT CPU client) runs.  Correctness
is pinned against the pure-jnp oracle in ``ref.py`` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tile.  On a real TPU this is the systolic array
# native shape; under interpret=True it only affects the loop structure.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k_steps: int):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ y[k,j].

    The grid iterates k innermost; the output tile is revisited across
    the K steps and accumulated in place (VMEM-resident on TPU), so the
    only synchronization in the whole matmul is the implicit join at
    grid completion — exactly the paper's overhead-managed schedule.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Tiled Pallas matmul, f32 accumulation, f32 result.

    Requires dimensions to be multiples of the block shape; callers with
    ragged shapes go through :func:`matmul_padded`.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shape ({m},{k})x({k},{n}) not a multiple of blocks "
        f"({block_m},{block_n},{block_k}); use matmul_padded"
    )
    n_k_steps = k // block_k
    kernel = functools.partial(_matmul_kernel, n_k_steps=n_k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, n_k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def _pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two block <= preferred that keeps padding < 2x."""
    b = preferred
    while b > 8 and _round_up(dim, b) >= 2 * dim and b > dim:
        b //= 2
    return b


def matmul_padded(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Matmul for arbitrary shapes: zero-pad to tile multiples, slice back.

    Zero padding is exact for matmul (padded rows/cols contribute 0), so
    no tolerance is lost; this is how the L2 model exposes the paper's
    order-1000 matrices (padded to 1024) to the 128-tile kernel.
    """
    m, k = x.shape
    _, n = y.shape
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = matmul(xp, yp, block_m=bm, block_n=bn, block_k=bk)
    return out[:m, :n]


def vmem_bytes(block_m: int, block_n: int, block_k: int, in_dtype_bits: int = 32) -> int:
    """Estimated VMEM working set of one grid step (perf model for §Perf).

    x tile + y tile (input dtype) + f32 output/accumulator tile; the
    double-buffered pipeline doubles the input tiles.
    """
    in_bytes = in_dtype_bits // 8
    x_tile = block_m * block_k * in_bytes
    y_tile = block_k * block_n * in_bytes
    o_tile = block_m * block_n * 4
    return 2 * (x_tile + y_tile) + o_tile


def mxu_utilization(m: int, n: int, k: int, block_m: int, block_n: int, block_k: int) -> float:
    """Fraction of MXU-issue slots doing useful work (padding waste only)."""
    mp, np_, kp = _round_up(m, block_m), _round_up(n, block_n), _round_up(k, block_k)
    return (m * n * k) / float(mp * np_ * kp)
