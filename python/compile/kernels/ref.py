"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has exactly one oracle here; pytest pins
kernel-vs-oracle agreement across shape/dtype sweeps (hypothesis).  The
oracles are deliberately the most boring possible jnp expressions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """f32-accumulated matmul — oracle for kernels.matmul.matmul*."""
    return jnp.matmul(
        x.astype(jnp.float32),
        y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def sort(x: jax.Array) -> jax.Array:
    """Ascending sort — oracle for kernels.bitonic.sort*."""
    return jnp.sort(x)


def matmul_chain(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """(A @ B) @ C in f32 — oracle for the L2 matrix-chain model."""
    return matmul(matmul(a, b), c)
