"""L2: JAX compute graphs for the paper's two DLA domains.

Each public ``build_*`` function returns ``(fn, example_specs)`` where
``fn`` is the jit-able computation (calling the L1 Pallas kernels) and
``example_specs`` are the ``jax.ShapeDtypeStruct`` arguments used to
lower it.  ``aot.py`` lowers every registered variant to HLO text for
the rust runtime; nothing in this module runs at request time.

All functions return 1-tuples: the AOT recipe lowers with
``return_tuple=True`` and the rust side unwraps with ``to_tuple1()``.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import bitonic as bitonic_kernel
from .kernels import matmul as matmul_kernel

Spec = jax.ShapeDtypeStruct
ModelFn = Callable[..., tuple]
Variant = Tuple[ModelFn, List[Spec]]


def build_matmul(n: int, dtype=jnp.float32) -> Variant:
    """Square order-n matmul C = A @ B through the tiled Pallas kernel.

    Orders that are not tile multiples (the paper's order-1000 case) go
    through the zero-padding wrapper — exact for matmul.
    """

    def fn(x, y):
        return (matmul_kernel.matmul_padded(x, y),)

    spec = Spec((n, n), dtype)
    return fn, [spec, spec]


def build_matmul_rect(m: int, k: int, n: int, dtype=jnp.float32) -> Variant:
    """Rectangular matmul (m,k) @ (k,n) — exercises ragged tiling."""

    def fn(x, y):
        return (matmul_kernel.matmul_padded(x, y),)

    return fn, [Spec((m, k), dtype), Spec((k, n), dtype)]


def build_matmul_chain(n: int, dtype=jnp.float32) -> Variant:
    """(A @ B) @ C — the paper's 'matrix chain multiplication' mention.

    Two kernel invocations fused into one artifact; XLA sees both
    pallas-lowered loops in a single module and can pipeline them.
    """

    def fn(a, b, c):
        ab = matmul_kernel.matmul_padded(a, b)
        return (matmul_kernel.matmul_padded(ab, c),)

    spec = Spec((n, n), dtype)
    return fn, [spec, spec, spec]


def build_matmul_native(n: int, dtype=jnp.float32) -> Variant:
    """Square matmul through XLA's native dot (no Pallas).

    §Perf (L2): under ``interpret=True`` the Pallas kernel lowers to a
    while-loop of dynamic-slice/dot/dynamic-update-slice, which the CPU
    backend executes tile by tile; the native ``jnp.matmul`` lowers to a
    single fused ``dot`` the backend dispatches to its optimized kernel.
    On a real TPU the Pallas/Mosaic path is the optimized one; on the CPU
    PJRT plugin the native variant is the roofline reference. The runtime
    bench (`runtime_xla`) measures both; the coordinator prefers
    ``matmul_native_<n>`` when present.
    """

    def fn(x, y):
        return (jnp.matmul(x, y, preferred_element_type=jnp.float32),)

    spec = Spec((n, n), dtype)
    return fn, [spec, spec]


def build_bitonic(n: int, dtype=jnp.float32) -> Variant:
    """Sort n values ascending via the bitonic-network kernel.

    n may be any positive size; non-powers-of-two pad with +max
    sentinels inside the graph (see kernels.bitonic.sort_padded).
    """

    def fn(x):
        return (bitonic_kernel.sort_padded(x),)

    return fn, [Spec((n,), dtype)]


def build_topk_of_sorted(n: int, k: int, dtype=jnp.float32) -> Variant:
    """Smallest-k via full bitonic sort + slice (coordinator demo op)."""

    def fn(x):
        return (bitonic_kernel.sort_padded(x)[:k],)

    return fn, [Spec((n,), dtype)]


# ---------------------------------------------------------------------------
# Variant registry: everything aot.py exports, keyed by artifact name.
# Sizes mirror the paper's evaluation sweep (Fig 2 orders around the
# crossover at 1000; Table 3 element counts 1000..2000) plus tile-exact
# sizes for the runtime integration tests.
# ---------------------------------------------------------------------------

def registry() -> Dict[str, Variant]:
    reg: Dict[str, Variant] = {}
    for n in (64, 128, 256, 512, 1000, 1024):
        reg[f"matmul_{n}"] = build_matmul(n)
    for n in (256, 1000):
        reg[f"matmul_native_{n}"] = build_matmul_native(n)
    reg["matmul_rect_96x160x224"] = build_matmul_rect(96, 160, 224)
    reg["matmul_chain_256"] = build_matmul_chain(256)
    for n in (1000, 1100, 1500, 2000, 1024, 4096):
        reg[f"bitonic_{n}"] = build_bitonic(n)
    reg["topk_2048_16"] = build_topk_of_sorted(2048, 16)
    return reg
