"""AOT pipeline: HLO-text emission + manifest format (rust-side contract)."""

import os

import jax.numpy as jnp
import pytest

from compile import aot, model


def test_to_hlo_text_contains_entry():
    fn, specs = model.build_matmul(64)
    text, out_spec = aot.lower_variant("matmul_64", fn, specs)
    assert "ENTRY" in text and "HloModule" in text
    assert out_spec.shape == (64, 64)
    # No Mosaic custom-calls may leak into CPU-executable artifacts.
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_export_subset_and_manifest(tmp_path):
    out = str(tmp_path)
    lines = aot.export_all(out, only=["matmul_64", "bitonic_1024"])
    assert len(lines) == 2
    assert os.path.exists(os.path.join(out, "matmul_64.hlo.txt"))
    assert os.path.exists(os.path.join(out, "bitonic_1024.hlo.txt"))
    manifest = open(os.path.join(out, aot.MANIFEST_NAME)).read().strip().splitlines()
    assert len(manifest) == 2
    name, fname, n_in, in_specs, out_spec = manifest[0].split("\t")
    assert name == "bitonic_1024" or name == "matmul_64"
    # spec grammar: dtype:dims
    for s in in_specs.split(";"):
        dtype, dims = s.split(":")
        assert dtype == "float32"
        assert all(d.isdigit() for d in dims.split("x"))


def test_spec_format():
    assert aot._fmt_spec(jnp.zeros((3, 4), jnp.float32)) == "float32:3x4"
    assert aot._fmt_spec(jnp.zeros((5,), jnp.int32)) == "int32:5"
    assert aot._fmt_spec(jnp.zeros((), jnp.float32)) == "float32:scalar"


def test_export_unknown_variant_fails(tmp_path):
    with pytest.raises(SystemExit, match="unknown variant"):
        aot.export_all(str(tmp_path), only=["nope"])
