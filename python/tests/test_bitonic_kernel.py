"""L1 bitonic-network kernel vs jnp.sort oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitonic as bk
from compile.kernels import ref


def _rand(n, dtype, seed):
    k = jax.random.PRNGKey(seed)
    if jnp.issubdtype(dtype, jnp.floating):
        return jax.random.normal(k, (n,), dtype=jnp.float32).astype(dtype)
    return jax.random.randint(k, (n,), -1000, 1000, dtype=dtype)


@pytest.mark.parametrize("n", [2, 4, 8, 64, 256, 1024])
def test_sort_pow2_f32(n):
    x = _rand(n, jnp.float32, n)
    np.testing.assert_array_equal(bk.sort(x), ref.sort(x))


@pytest.mark.parametrize("n", [4, 128, 512])
def test_sort_pow2_i32(n):
    x = _rand(n, jnp.int32, n)
    np.testing.assert_array_equal(bk.sort(x), ref.sort(x))


def test_sort_single_element():
    x = jnp.array([42.0], dtype=jnp.float32)
    np.testing.assert_array_equal(bk.sort(x), x)


def test_sort_rejects_non_pow2():
    with pytest.raises(AssertionError, match="power-of-two"):
        bk.sort(jnp.zeros((1000,), jnp.float32))


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 2048), seed=st.integers(0, 2**16))
def test_sort_padded_any_length_f32(n, seed):
    """Hypothesis sweep: arbitrary lengths via +inf sentinel padding."""
    x = _rand(n, jnp.float32, seed)
    np.testing.assert_array_equal(bk.sort_padded(x), ref.sort(x))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 1024), seed=st.integers(0, 2**16))
def test_sort_padded_any_length_i32(n, seed):
    x = _rand(n, jnp.int32, seed)
    np.testing.assert_array_equal(bk.sort_padded(x), ref.sort(x))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_sort_duplicates_and_presorted(seed):
    """Few-unique and adversarial (sorted / reverse) inputs."""
    k = jax.random.PRNGKey(seed)
    x = jax.random.randint(k, (256,), 0, 4, dtype=jnp.int32)
    np.testing.assert_array_equal(bk.sort(x), ref.sort(x))
    asc = jnp.arange(256, dtype=jnp.int32)
    np.testing.assert_array_equal(bk.sort(asc), asc)
    np.testing.assert_array_equal(bk.sort(asc[::-1]), asc)


def test_sort_is_permutation():
    x = _rand(512, jnp.float32, 9)
    got = np.asarray(bk.sort(x))
    assert sorted(np.asarray(x).tolist()) == got.tolist()


@pytest.mark.parametrize(
    "k,j", [(2, 1), (4, 2), (4, 1), (8, 4), (8, 2), (8, 1)]
)
def test_single_stage_is_involution_free_and_pairwise(k, j):
    """One substage orders each (i, i^j) pair per its k-block direction."""
    n = 16
    x = _rand(n, jnp.float32, k * 31 + j)
    out = np.asarray(bk.sort_stage(x, k, j))
    xin = np.asarray(x)
    for i in range(n):
        p = i ^ j
        lo_i, hi_i = min(i, p), max(i, p)
        pair = sorted([xin[lo_i], xin[hi_i]])
        if (i & k) == 0:  # ascending block
            assert out[lo_i] == pair[0] and out[hi_i] == pair[1]
        else:
            assert out[lo_i] == pair[1] and out[hi_i] == pair[0]


def test_comparator_count():
    # n=8: log=3 -> 6 substages * 4 comparators = 24
    assert bk.comparator_count(8) == 24
    assert bk.comparator_count(2) == 1
