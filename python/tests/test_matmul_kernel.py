"""L1 matmul kernel vs pure-jnp oracle — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mk
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rand(shape, dtype, seed):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, shape, dtype=jnp.float32).astype(dtype)


TILE_EXACT = [(128, 128, 128), (128, 256, 128), (256, 128, 384)]


@pytest.mark.parametrize("m,k,n", TILE_EXACT)
def test_matmul_tile_exact(m, k, n):
    x = _rand((m, k), jnp.float32, 0)
    y = _rand((k, n), jnp.float32, 1)
    got = mk.matmul(x, y)
    want = ref.matmul(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_rejects_ragged_shapes():
    x = _rand((100, 128), jnp.float32, 0)
    y = _rand((128, 128), jnp.float32, 1)
    with pytest.raises(AssertionError, match="matmul_padded"):
        mk.matmul(x, y)


def test_matmul_rejects_contraction_mismatch():
    x = _rand((128, 128), jnp.float32, 0)
    y = _rand((256, 128), jnp.float32, 1)
    with pytest.raises(AssertionError, match="contraction"):
        mk.matmul_padded(x, y)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 160),
    k=st.integers(1, 160),
    n=st.integers(1, 160),
    seed=st.integers(0, 2**16),
)
def test_matmul_padded_matches_ref_f32(m, k, n, seed):
    """Hypothesis sweep over ragged shapes (paper's order-1000 path)."""
    x = _rand((m, k), jnp.float32, seed)
    y = _rand((k, n), jnp.float32, seed + 1)
    got = mk.matmul_padded(x, y)
    want = ref.matmul(x, y)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_matmul_padded_matches_ref_bf16(m, k, n, seed):
    """bf16 inputs, f32 accumulation: tolerance scaled to bf16 mantissa."""
    x = _rand((m, k), jnp.bfloat16, seed)
    y = _rand((k, n), jnp.bfloat16, seed + 1)
    got = mk.matmul_padded(x, y)
    want = ref.matmul(x, y)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("block", [32, 64, 128])
def test_matmul_block_shape_invariance(block):
    """Result must not depend on the chosen tile shape."""
    x = _rand((128, 128), jnp.float32, 7)
    y = _rand((128, 128), jnp.float32, 8)
    got = mk.matmul(x, y, block_m=block, block_n=block, block_k=block)
    want = ref.matmul(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_order_1000_paper_case():
    """The paper's crossover order: 1000 is not a tile multiple."""
    x = _rand((1000, 1000), jnp.float32, 3)
    y = _rand((1000, 1000), jnp.float32, 4)
    got = mk.matmul_padded(x, y)
    want = ref.matmul(x, y)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_zero_and_identity():
    n = 128
    eye = jnp.eye(n, dtype=jnp.float32)
    x = _rand((n, n), jnp.float32, 5)
    np.testing.assert_allclose(mk.matmul(x, eye), x, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        mk.matmul(x, jnp.zeros((n, n), jnp.float32)), jnp.zeros((n, n)), atol=0
    )


def test_vmem_bytes_model():
    # 128^3 f32 tiles: 2*(64KiB+64KiB) + 64KiB = 320 KiB — fits 16 MiB VMEM.
    b = mk.vmem_bytes(128, 128, 128, 32)
    assert b == 2 * (128 * 128 * 4 + 128 * 128 * 4) + 128 * 128 * 4
    assert b < 16 * 1024 * 1024


def test_mxu_utilization_model():
    assert mk.mxu_utilization(128, 128, 128, 128, 128, 128) == 1.0
    u = mk.mxu_utilization(1000, 1000, 1000, 128, 128, 128)
    assert 0.85 < u < 1.0  # 1000^3 / 1024^3
