"""L2 model graphs: shapes, numerics vs oracles, registry completeness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=dtype)


def test_registry_names_and_specs():
    reg = model.registry()
    # Every paper-sweep size must be present.
    for n in (64, 128, 256, 512, 1000, 1024):
        assert f"matmul_{n}" in reg
    for n in (1000, 1100, 1500, 2000):
        assert f"bitonic_{n}" in reg
    for name, (fn, specs) in reg.items():
        assert callable(fn)
        assert all(isinstance(s, jax.ShapeDtypeStruct) for s in specs), name


def test_matmul_model_numerics():
    fn, specs = model.build_matmul(96)
    x, y = _rand(specs[0].shape, 0), _rand(specs[1].shape, 1)
    (got,) = fn(x, y)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_rect_model_numerics():
    fn, specs = model.build_matmul_rect(50, 70, 30)
    x, y = _rand((50, 70), 2), _rand((70, 30), 3)
    (got,) = fn(x, y)
    assert got.shape == (50, 30)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_chain_model_numerics():
    fn, _ = model.build_matmul_chain(64)
    a, b, c = _rand((64, 64), 4), _rand((64, 64), 5), _rand((64, 64), 6)
    (got,) = fn(a, b, c)
    np.testing.assert_allclose(got, ref.matmul_chain(a, b, c), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n", [1000, 1024, 7])
def test_bitonic_model_numerics(n):
    fn, _ = model.build_bitonic(n)
    x = _rand((n,), n)
    (got,) = fn(x)
    np.testing.assert_array_equal(got, ref.sort(x))


def test_topk_model_numerics():
    fn, _ = model.build_topk_of_sorted(200, 10)
    x = _rand((200,), 11)
    (got,) = fn(x)
    np.testing.assert_array_equal(got, ref.sort(x)[:10])


def test_models_are_jittable():
    """Every registry variant must trace under jit (lowering precondition)."""
    reg = model.registry()
    for name in ("matmul_64", "bitonic_1024", "matmul_chain_256", "topk_2048_16"):
        fn, specs = reg[name]
        jax.jit(fn).lower(*specs)  # raises if untraceable


def test_matmul_native_matches_pallas_variant():
    """The native-dot artifact must agree with the Pallas-kernel artifact."""
    fn_native, specs = model.build_matmul_native(96)
    fn_pallas, _ = model.build_matmul(96)
    x, y = _rand((96, 96), 20), _rand((96, 96), 21)
    (a,) = fn_native(x, y)
    (b,) = fn_pallas(x, y)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
