"""Analyzer conformance: each pass proves it fires on bad input and
stays quiet on good input, over inline Rust fixture snippets.

These tests pin the *analysis semantics* — lock scoping rules, the
condvar exception, wildcard literal matching, suppression grammar — so
the passes can be refactored without silently losing a detector. The
final test runs the real driver over the real repo: the committed
baselines and suppressions must keep `--check` green.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]
sys.path.insert(0, str(REPO / "tools"))

from analyze import (  # noqa: E402
    atomics,
    conformance,
    ledger,
    lexer,
    locks,
    modules,
    report,
    unsafe_ffi,
)


def make_repo(tmp_path, files):
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return tmp_path


def ids(res):
    return [f.id for f in res.findings]


# ---------------------------------------------------------------- lexer


def test_nested_block_comments_strip_fully():
    src = "a /* outer /* inner */ still comment */ b"
    assert lexer.strip_comments(src).split() == ["a", "b"]


def test_line_comment_inside_string_is_not_a_comment():
    src = 'let url = "http://x"; // real comment\nlet s = "// not a comment";'
    out = lexer.strip_comments(src)
    assert '"http://x"' in out
    assert '"// not a comment"' in out
    assert "real comment" not in out


def test_raw_strings_and_char_literals_survive():
    src = 'let r = r#"raw " with // stuff"#;\nlet c = \'/\'; let l: &\'static str = "x";'
    out = lexer.strip_comments(src)
    assert 'raw " with // stuff' in out
    assert "'static" in out  # lifetime not eaten as a char literal


def test_string_literals_extracts_values_and_lines():
    lits = lexer.string_literals('let a = "one";\nlet b = "two\\n";')
    assert [(l.value, l.line) for l in lits] == [("one", 1), ("two\n", 2)]


def test_strip_test_blocks_removes_cfg_test_mod():
    src = 'fn real() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let x = "inside"; }\n}\n'
    out = lexer.strip_test_blocks(src)
    assert "real" in out and "inside" not in out


# -------------------------------------------------------------- symbols


SYMBOL_TREE = {
    "rust/src/lib.rs": "pub mod a;\npub mod b;\n",
    "rust/src/a.rs": """
        pub struct Widget;
        pub enum Color { Red, Green }
        pub fn make() {}
    """,
    "rust/src/b.rs": """
        pub use crate::a::Widget;
        use crate::a::Color::Red;
        use crate::a::{make, Color};
    """,
}


def test_symbols_clean_tree_resolves(tmp_path):
    repo = make_repo(tmp_path, SYMBOL_TREE)
    res = modules.run(repo)
    assert ids(res) == []
    assert res.stats["uses_checked"] >= 3


def test_symbols_missing_item_and_bad_variant_fail(tmp_path):
    bad = dict(SYMBOL_TREE)
    bad["rust/src/b.rs"] = """
        use crate::a::Gadget;
        use crate::a::Color::Blue;
    """
    repo = make_repo(tmp_path, bad)
    res = modules.run(repo)
    found = ids(res)
    assert any("Gadget" in i for i in found)
    assert any("Blue" in i for i in found), "enum variants are item-grade"


def test_symbols_reexport_chain_is_verified(tmp_path):
    repo = make_repo(
        tmp_path,
        {
            "rust/src/lib.rs": "pub mod a;\npub mod b;\npub mod c;\n",
            "rust/src/a.rs": "pub struct Real;\n",
            # b re-exports something a does NOT define: importing it
            # through the chain must fail, not be trusted at the leaf.
            "rust/src/b.rs": "pub use crate::a::Phantom;\n",
            "rust/src/c.rs": "use crate::b::Phantom;\n",
        },
    )
    res = modules.run(repo)
    assert any("Phantom" in i for i in ids(res))


# ---------------------------------------------------------------- locks


def locks_run(tmp_path, body, extra=""):
    repo = make_repo(
        tmp_path,
        {
            "rust/src/lib.rs": textwrap.dedent(
                """
                use std::sync::Mutex;
                pub struct S { a: Mutex<u32>, b: Mutex<u32> }
                """
            )
            + textwrap.dedent(body)
            + textwrap.dedent(extra)
        },
    )
    return locks.run(repo)


def test_locks_guard_across_send_detected(tmp_path):
    res = locks_run(
        tmp_path,
        """
        impl S {
            fn f(&self, tx: &std::sync::mpsc::Sender<u32>) {
                let g = self.a.lock().unwrap();
                tx.send(*g).unwrap();
            }
        }
        """,
    )
    assert any("guard-across-blocking" in i and "send" in i for i in ids(res))


def test_locks_guard_released_by_scope_and_drop(tmp_path):
    res = locks_run(
        tmp_path,
        """
        impl S {
            fn scoped(&self, tx: &std::sync::mpsc::Sender<u32>) {
                let v = { let g = self.a.lock().unwrap(); *g };
                tx.send(v).unwrap();
            }
            fn dropped(&self, tx: &std::sync::mpsc::Sender<u32>) {
                let g = self.a.lock().unwrap();
                drop(g);
                tx.send(1).unwrap();
            }
            fn derived(&self, tx: &std::sync::mpsc::Sender<u32>) {
                let v = self.a.lock().unwrap().wrapping_add(1);
                tx.send(v).unwrap();
            }
        }
        """,
    )
    assert ids(res) == [], "scope exit, drop(), and derived-value chains all release"


def test_locks_condvar_wait_with_held_guard_is_exempt(tmp_path):
    res = locks_run(
        tmp_path,
        """
        pub struct Q { mu: Mutex<u32>, cv: std::sync::Condvar }
        impl Q {
            fn wait_nonzero(&self) {
                let mut g = self.mu.lock().unwrap();
                while *g == 0 {
                    g = self.cv.wait(g).unwrap();
                }
            }
        }
        """,
    )
    assert ids(res) == []


def test_locks_order_cycle_detected(tmp_path):
    res = locks_run(
        tmp_path,
        """
        impl S {
            fn ab(&self) {
                let g = self.a.lock().unwrap();
                let h = self.b.lock().unwrap();
            }
            fn ba(&self) {
                let h = self.b.lock().unwrap();
                let g = self.a.lock().unwrap();
            }
        }
        """,
    )
    assert any("lock-order-cycle" in i for i in ids(res))


def test_locks_consistent_order_is_clean(tmp_path):
    res = locks_run(
        tmp_path,
        """
        impl S {
            fn ab(&self) {
                let g = self.a.lock().unwrap();
                let h = self.b.lock().unwrap();
            }
            fn ab2(&self) {
                let g = self.a.lock().unwrap();
                let h = self.b.lock().unwrap();
            }
        }
        """,
    )
    assert ids(res) == []


def test_locks_double_acquire_detected(tmp_path):
    res = locks_run(
        tmp_path,
        """
        impl S {
            fn f(&self) {
                let g = self.a.lock().unwrap();
                let h = self.a.lock().unwrap();
            }
        }
        """,
    )
    assert any("double-acquire" in i for i in ids(res))


def test_locks_guard_returning_helper_counts_as_acquisition(tmp_path):
    res = locks_run(
        tmp_path,
        """
        impl S {
            fn a_guard(&self) -> std::sync::MutexGuard<'_, u32> {
                self.a.lock().unwrap()
            }
            fn f(&self, tx: &std::sync::mpsc::Sender<u32>) {
                let g = self.a_guard();
                tx.send(*g).unwrap();
            }
        }
        """,
    )
    assert any("guard-across-blocking" in i and ":f:" in i for i in ids(res))


# -------------------------------------------------------------- atomics


ATOMIC_SRC = {
    "rust/src/lib.rs": """
        use std::sync::atomic::{AtomicU64, Ordering};
        pub fn bump(c: &AtomicU64) {
            c.fetch_add(1, Ordering::Relaxed);
            c.load(Ordering::Acquire);
        }
    """
}


def test_atomics_bless_then_clean(tmp_path):
    repo = make_repo(tmp_path, ATOMIC_SRC)
    baselines = repo / "tools" / "baselines"
    baselines.mkdir(parents=True)
    inv = atomics.inventory(repo)
    (baselines / atomics.BASELINE_NAME).write_text(atomics.render_baseline(inv))
    assert ids(atomics.run(repo)) == []


def test_atomics_drift_fails(tmp_path):
    repo = make_repo(tmp_path, ATOMIC_SRC)
    baselines = repo / "tools" / "baselines"
    baselines.mkdir(parents=True)
    inv = atomics.inventory(repo)
    (baselines / atomics.BASELINE_NAME).write_text(atomics.render_baseline(inv))
    # A new Relaxed site appears without a re-bless.
    lib = repo / "rust" / "src" / "lib.rs"
    lib.write_text(lib.read_text() + "\npub fn sneak(c: &AtomicU64) { c.store(0, Ordering::Relaxed); }\n")
    res = atomics.run(repo)
    assert any(i.startswith("atomics:drift:lib.rs") for i in ids(res))
    # cmp::Ordering variants are not atomics.
    lib.write_text(lib.read_text() + "\npub fn cmpish() -> std::cmp::Ordering { std::cmp::Ordering::Less }\n")
    assert atomics.inventory(repo)["lib.rs"] == inv["lib.rs"] | {"Relaxed": 2}


# --------------------------------------------------------------- unsafe


UNSAFE_SRC = {
    "rust/src/pool/job.rs": """
        pub struct JobRef { data: *const (), exec: unsafe fn(*const ()) }
        unsafe impl Send for JobRef {}
        pub unsafe fn run(j: JobRef) {
            unsafe { (j.exec)(j.data) }
        }
        // unsafe in a comment and "unsafe" in a string do not count
        pub fn s() -> &'static str { "unsafe" }
    """
}


def test_unsafe_classifies_and_blesses_clean(tmp_path):
    repo = make_repo(tmp_path, UNSAFE_SRC)
    inv = unsafe_ffi.inventory(repo)
    # one fn-pointer type + one unsafe fn, one unsafe impl, one block;
    # the comment and string occurrences are invisible.
    assert inv == {"pool/job.rs": {"fn": 2, "impl": 1, "block": 1}}
    baselines = repo / "tools" / "baselines"
    baselines.mkdir(parents=True)
    (baselines / unsafe_ffi.BASELINE_NAME).write_text(unsafe_ffi.render_baseline(inv))
    assert ids(unsafe_ffi.run(repo)) == []


def test_unsafe_drift_fails(tmp_path):
    repo = make_repo(tmp_path, UNSAFE_SRC)
    baselines = repo / "tools" / "baselines"
    baselines.mkdir(parents=True)
    inv = unsafe_ffi.inventory(repo)
    (baselines / unsafe_ffi.BASELINE_NAME).write_text(unsafe_ffi.render_baseline(inv))
    job = repo / "rust" / "src" / "pool" / "job.rs"
    job.write_text(job.read_text() + "\npub fn sneak(p: *const u32) -> u32 { unsafe { *p } }\n")
    res = unsafe_ffi.run(repo)
    assert any(i.startswith("unsafe:drift:pool/job.rs") for i in ids(res))


def test_unsafe_containment_fails_even_when_blessed(tmp_path):
    src = dict(UNSAFE_SRC)
    src["rust/src/coordinator/server.rs"] = """
        pub fn oops(p: *const u32) -> u32 { unsafe { *p } }
    """
    repo = make_repo(tmp_path, src)
    baselines = repo / "tools" / "baselines"
    baselines.mkdir(parents=True)
    inv = unsafe_ffi.inventory(repo)
    (baselines / unsafe_ffi.BASELINE_NAME).write_text(unsafe_ffi.render_baseline(inv))
    res = unsafe_ffi.run(repo)
    assert any(i == "unsafe:containment:coordinator/server.rs" for i in ids(res))
    # The blessed-but-contained file stays clean.
    assert not any(i.startswith("unsafe:drift:") for i in ids(res))


def test_unsafe_missing_baseline_fails(tmp_path):
    repo = make_repo(tmp_path, UNSAFE_SRC)
    (repo / "tools" / "baselines").mkdir(parents=True)
    assert "unsafe:missing-baseline" in ids(unsafe_ffi.run(repo))


# ---------------------------------------------------------- conformance


CONFORMANCE_REPO = {
    "rust/src/coordinator/server.rs": r'''
    fn respond() {
        let r = format!("ERR BUSY lane {lane} full (depth {d})");
        let t = "queue: len={} max={}\n";
    }
    ''',
    "rust/src/coordinator/faults.rs": """
    pub enum ErrCode { Busy }
    impl ErrCode {
        pub fn name(&self) -> &'static str {
            match self { ErrCode::Busy => "BUSY" }
        }
        pub fn retriable(&self) -> bool {
            matches!(self, ErrCode::Busy)
        }
    }
    """,
    "rust/src/cli/mod.rs": """
    fn cmd_serve(args: &Args) {
        let d = args.get_parsed::<usize>("queue-depth");
    }
    """,
    "rust/src/config/mod.rs": """
    fn from_table(t: &Table) {
        if let Some(sec) = t.get("serving") {
            let v = sec.get("queue_depth");
        }
    }
    """,
    "docs/PROTOCOL.md": """
    ```text
    ERR BUSY lane <l> full (depth <d>)
    queue: len=<l> max=<m>
    ```
    | code | retriable |
    |------|-----------|
    | BUSY | yes       |
    """,
    "README.md": "Use `--queue-depth N` and `[serving]` with `queue_depth`.\n",
}


def test_conformance_clean_fixture_passes(tmp_path):
    repo = make_repo(tmp_path, CONFORMANCE_REPO)
    assert ids(conformance.run(repo)) == []


def test_conformance_protocol_drift_fails(tmp_path):
    files = dict(CONFORMANCE_REPO)
    files["docs/PROTOCOL.md"] = files["docs/PROTOCOL.md"].replace(
        "ERR BUSY lane <l> full (depth <d>)\n", ""
    )
    repo = make_repo(tmp_path, files)
    res = conformance.run(repo)
    assert any("undocumented-wire-literal" in i and "ERR-BUSY" in i for i in ids(res))


def test_conformance_retriable_mismatch_fails(tmp_path):
    files = dict(CONFORMANCE_REPO)
    files["docs/PROTOCOL.md"] = files["docs/PROTOCOL.md"].replace("| BUSY | yes", "| BUSY | no")
    repo = make_repo(tmp_path, files)
    res = conformance.run(repo)
    assert "conformance:taxonomy-retriable-mismatch:BUSY" in ids(res)


def test_conformance_undocumented_flag_and_config_fail(tmp_path):
    files = dict(CONFORMANCE_REPO)
    files["README.md"] = "nothing documented here\n"
    repo = make_repo(tmp_path, files)
    found = ids(conformance.run(repo))
    assert "conformance:undocumented-flag:cmd_serve:--queue-depth" in found
    assert "conformance:undocumented-config:[serving]" in found
    assert "conformance:undocumented-config:queue_depth" in found


def test_conformance_test_module_literals_are_ignored(tmp_path):
    files = dict(CONFORMANCE_REPO)
    files["rust/src/coordinator/server.rs"] += """
    #[cfg(test)]
    mod tests {
        fn t() { let fake = "ERR IMAGINARY not on the wire"; }
    }
    """
    repo = make_repo(tmp_path, files)
    assert ids(conformance.run(repo)) == []


# --------------------------------------------------------------- ledger


LEDGER_STRUCT = """
pub struct Ledger {
    pub spawns: u64,
    pub syncs: u64,
}
"""


def ledger_repo(tmp_path, use_site):
    return make_repo(
        tmp_path,
        {
            "rust/src/overhead/ledger.rs": LEDGER_STRUCT,
            "rust/src/sim.rs": use_site,
        },
    )


def test_ledger_full_literal_passes(tmp_path):
    repo = ledger_repo(tmp_path, "fn f() -> Ledger { Ledger { spawns: 0, syncs: 1 } }\n")
    assert ids(ledger.run(repo)) == []


def test_ledger_missing_field_and_spread_fail(tmp_path):
    repo = ledger_repo(
        tmp_path,
        """
        fn f() -> Ledger { Ledger { spawns: 0 } }
        fn g() -> Ledger { Ledger { spawns: 0, ..Default::default() } }
        """,
    )
    found = ids(ledger.run(repo))
    assert any(i.startswith("ledger:missing-fields:sim.rs") for i in found)
    assert any(i.startswith("ledger:spread:sim.rs") for i in found)


def test_ledger_patterns_and_tests_exempt(tmp_path):
    repo = ledger_repo(
        tmp_path,
        """
        fn f(l: Ledger) -> u64 {
            let Ledger { spawns, .. } = l;
            spawns
        }
        #[cfg(test)]
        mod tests {
            fn t() -> Ledger { Ledger { spawns: 1, ..Default::default() } }
        }
        """,
    )
    assert ids(ledger.run(repo)) == []


# --------------------------------------------------- suppressions/report


def test_suppression_requires_reason():
    with pytest.raises(report.SuppressionError):
        report.parse_suppressions("locks:some-id\n")


def test_suppression_honored_and_stale_warned():
    res = report.PassResult("locks")
    res.finding("locks:x", "boom")
    active, suppressed, stale = report.apply_suppressions(
        [res], {"locks:x": "deliberate", "locks:gone": "fixed long ago"}
    )
    assert active == [] and len(suppressed) == 1 and stale == ["locks:gone"]


# ---------------------------------------------------------- real driver


def test_driver_check_is_green_on_this_repo():
    """The committed baselines + suppressions keep the real tree green.

    This is the acceptance pin: all five passes, ≥70 modules, exit 0.
    """
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "ohm_analyze.py"), "--check"],
        capture_output=True,
        text=True,
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "pass symbols" in proc.stdout
    modules_line = next(l for l in proc.stdout.splitlines() if "modules=" in l)
    count = int(modules_line.split("modules=")[1].split()[0])
    assert count >= 70
