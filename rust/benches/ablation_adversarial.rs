//! Bench: adversarial-input ablation — comparison counts per
//! (distribution × pivot strategy); explains the random pivot's existence.

use ohm::bench::Runner;
use ohm::sort::{baselines, serial_quicksort, PivotStrategy};
use ohm::workload::arrays::{self, Distribution};

fn main() {
    let mut r = Runner::new("ablation_adversarial");
    let n = 2000usize;
    for dist in [
        Distribution::UniformRandom,
        Distribution::Sorted,
        Distribution::Reverse,
        Distribution::FewUnique { k: 4 },
        Distribution::Sawtooth { run: 100 },
    ] {
        for s in [
            PivotStrategy::Left,
            PivotStrategy::Mean,
            PivotStrategy::Right,
            PivotStrategy::Random,
            PivotStrategy::MedianOf3,
        ] {
            let mut xs = arrays::generate(n, dist, 42);
            let ops = serial_quicksort(&mut xs, s, 42);
            r.record(
                &format!("comparisons/{}", s.name()),
                &format!("dist={}", dist.name()),
                vec![ops.comparisons as f64],
                "ops",
            );
        }
        // Input-insensitive baselines for contrast.
        let mut xs = arrays::generate(n, dist, 42);
        let m = baselines::mergesort(&mut xs);
        r.record("comparisons/mergesort", &format!("dist={}", dist.name()), vec![m.comparisons as f64], "ops");
        let mut xs = arrays::generate(n, dist, 42);
        let b = baselines::bitonic(&mut xs);
        r.record("comparisons/bitonic", &format!("dist={}", dist.name()), vec![b.comparisons as f64], "ops");
    }
    r.finish();
}
