//! Bench: cores ablation (`abl-cores`) — ideal Amdahl vs overhead-adjusted
//! speedup, and a simulated strong-scaling run for the matmul tree.

use ohm::bench::Runner;
use ohm::experiments::fig2::matmul_tree;
use ohm::overhead::{amdahl, OverheadParams, WorkEstimate};
use ohm::sim::Machine;

fn main() {
    let mut r = Runner::new("ablation_cores");
    let params = OverheadParams::paper_2022();

    for (label, work_ns, bytes) in [
        ("matmul-512", 512f64.powi(3), (2 * 512 * 512 * 4) as u64),
        ("matmul-64", 64f64.powi(3), (2 * 64 * 64 * 4) as u64),
        ("sort-2000", 2000.0 * 11.0 * 225.0, 16_000u64),
    ] {
        let est = WorkEstimate::fully_parallel(work_ns, bytes);
        for (p, ideal, adj) in amdahl::sweep(&params, &est, &[1, 2, 4, 8, 16, 32]) {
            r.record(&format!("{label}/ideal"), &format!("cores={p}"), vec![ideal], "x");
            r.record(&format!("{label}/adjusted"), &format!("cores={p}"), vec![adj], "x");
        }
    }

    // Strong scaling of the actual simulated schedule (matmul 512,
    // manager-agnostic fixed 4-per-core tasks).
    for p in [1usize, 2, 4, 8, 16] {
        let machine = Machine::new(p, params);
        let rep = machine.run(&matmul_tree(512, 1.0, 4 * p), false);
        r.record("matmul-512/simulated-speedup", &format!("cores={p}"), vec![rep.speedup()], "x");
    }

    r.finish();
}
