//! Bench: grain ablation (`abl-grain`) — virtual time vs task count /
//! fork cutoff, plus the manager's predicted optimum for comparison.

use ohm::bench::Runner;
use ohm::config::ExperimentConfig;
use ohm::experiments::fig2::matmul_tree;
use ohm::overhead::{model, OverheadParams, WorkEstimate};
use ohm::sim::Machine;
use ohm::sort::{parallel::simulate_with_cutoff, PivotStrategy, SortCostModel};
use ohm::workload::arrays;

fn main() {
    let mut r = Runner::new("ablation_grain");
    let cfg = ExperimentConfig::default();
    let params = OverheadParams::paper_2022();
    let machine = Machine::new(cfg.cores, params);

    // Matmul 512: task-count sweep + manager prediction.
    let n = 512usize;
    let mut tasks = 1usize;
    while tasks <= 16 * cfg.cores {
        let rep = machine.run(&matmul_tree(n, 1.0, tasks), false);
        r.record("matmul-512/sweep", &format!("tasks={tasks}"), vec![rep.makespan_ns / 1e3], "us(virtual)");
        tasks *= 2;
    }
    let est = WorkEstimate::fully_parallel((n as f64).powi(3), (2 * n * n * 4) as u64);
    let (best_tasks, best_pred) = model::best_grain(&params, &est, cfg.cores, 64 * cfg.cores);
    r.record(
        "matmul-512/manager-pick",
        &format!("tasks={best_tasks}"),
        vec![best_pred / 1e3],
        "us(virtual)",
    );

    // Quicksort 2000: cutoff sweep.
    let model_s = SortCostModel::paper_2022();
    let mut cutoff = 16usize;
    while cutoff <= 2000 {
        let mut xs = arrays::uniform_i64(2000, cfg.seed);
        let rep = simulate_with_cutoff(&mut xs, PivotStrategy::Mean, cutoff, cfg.seed, &model_s, &machine);
        r.record("sort-2000/sweep", &format!("cutoff={cutoff}"), vec![rep.makespan_ns / 1e3], "us(virtual)");
        cutoff *= 2;
    }

    r.finish();
}
