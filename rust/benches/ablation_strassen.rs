//! Bench: Strassen cutoff ablation — the extension case for the paper's
//! "division effort vs problem size" rule: the optimal cutoff balances
//! saved multiplications against extra additions (and allocation churn).

use ohm::bench::{BenchCfg, Runner};
use ohm::dla::{matmul, strassen};
use ohm::pool::ThreadPool;
use ohm::workload::matrices;

fn main() {
    let mut r = Runner::with_cfg(
        "ablation_strassen",
        BenchCfg { warmup_iters: 1, sample_count: 5, max_total_ns: 20_000_000_000 },
    );
    let n = 256usize;
    let a = matrices::uniform(n, n, 1);
    let b = matrices::uniform(n, n, 2);

    r.measure("classical-ikj", &format!("order={n}"), || matmul::serial(&a, &b));
    for cutoff in [16usize, 32, 64, 128] {
        r.measure("strassen", &format!("order={n},cutoff={cutoff}"), || {
            strassen::strassen(&a, &b, cutoff)
        });
        // Model ops for the same configuration (deterministic).
        r.record(
            "strassen-model-ops",
            &format!("order={n},cutoff={cutoff}"),
            vec![strassen::work_ops(n, cutoff)],
            "ops",
        );
    }
    let pool = ThreadPool::new(4);
    r.measure("strassen-parallel-2lvl", &format!("order={n},cutoff=64"), || {
        strassen::strassen_parallel(&a, &b, &pool, 64, 2)
    });
    r.finish();
}
