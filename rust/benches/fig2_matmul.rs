//! Bench: regenerates **Figure 2** (matmul serial vs parallel by order).
//!
//! Virtual-time series (deterministic, the paper's actual figure) plus
//! wall-clock measurements of the real engines on this host (perf
//! tracking for §Perf). Output: console + `target/ohm-bench/fig2_matmul.csv`.

use ohm::bench::{BenchCfg, Runner};
use ohm::dla::matmul;
use ohm::experiments::fig2;
use ohm::pool::ThreadPool;
use ohm::workload::matrices;

fn main() {
    let mut r = Runner::new("fig2_matmul");

    // --- The paper's figure: virtual time per order (3 engines) -------
    for &n in &[16usize, 32, 64, 128, 256, 512, 750, 1000, 1500, 2048] {
        let (serial, naive, managed) = fig2::row(n, 1.0, 4);
        r.record("fig2/serial", &format!("order={n}"), vec![serial * 1e3], "us(virtual)");
        r.record("fig2/parallel-naive", &format!("order={n}"), vec![naive * 1e3], "us(virtual)");
        r.record("fig2/parallel-managed", &format!("order={n}"), vec![managed * 1e3], "us(virtual)");
    }

    // --- Host wall-clock: real engines (perf baseline for §Perf) ------
    let mut wall = Runner::with_cfg(
        "fig2_matmul_wall",
        BenchCfg { warmup_iters: 1, sample_count: 5, max_total_ns: 10_000_000_000 },
    );
    let pool = ThreadPool::new(4);
    for &n in &[64usize, 128, 256] {
        let a = matrices::uniform(n, n, 1);
        let b = matrices::uniform(n, n, 2);
        wall.measure("serial-ijk", &format!("order={n}"), || matmul::serial_ijk(&a, &b));
        wall.measure("serial-ikj", &format!("order={n}"), || matmul::serial(&a, &b));
        wall.measure("blocked-64", &format!("order={n}"), || matmul::blocked(&a, &b, 64));
        wall.measure("pool-parallel-8t", &format!("order={n}"), || matmul::parallel(&a, &b, &pool, 8));
    }

    r.finish();
    wall.finish();
}
