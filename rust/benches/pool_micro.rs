//! Bench: pool micro-benchmarks — the L3 hot paths behind every α/β/γ
//! constant (join latency, scope spawn throughput, deque churn).
//! §Perf tracks these before/after optimization.

use ohm::bench::{BenchCfg, Runner};
use ohm::pool::ThreadPool;
use std::sync::atomic::{AtomicU64, Ordering};

fn main() {
    let mut r = Runner::with_cfg(
        "pool_micro",
        BenchCfg { warmup_iters: 3, sample_count: 11, max_total_ns: 8_000_000_000 },
    );

    for threads in [1usize, 2, 4] {
        let pool = ThreadPool::new(threads);

        // join with trivial branches: pure fork-join overhead (α+β path).
        r.measure("join-noop", &format!("threads={threads}"), || {
            pool.join(|| std::hint::black_box(1), || std::hint::black_box(2))
        });

        // Nested join tree, 1024 leaves: amortized fork-join cost.
        r.measure("join-tree-1024", &format!("threads={threads}"), || {
            fn tree(pool: &ThreadPool, depth: usize) -> u64 {
                if depth == 0 {
                    return 1;
                }
                let (a, b) = pool.join(|| tree(pool, depth - 1), || tree(pool, depth - 1));
                a + b
            }
            tree(&pool, 10)
        });

        // scope spawn throughput, 1000 empty tasks (spawn+steal churn).
        r.measure("scope-1000-noop", &format!("threads={threads}"), || {
            let c = AtomicU64::new(0);
            pool.scope(|s| {
                for _ in 0..1000 {
                    let c = &c;
                    s.spawn(move |_| {
                        c.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            c.load(Ordering::Relaxed)
        });

        // install round-trip (external thread → worker → back).
        r.measure("install-roundtrip", &format!("threads={threads}"), || {
            pool.install(|| std::hint::black_box(7))
        });

        // for_each_index with real (small) work per task.
        r.measure("for-each-256x1us", &format!("threads={threads}"), || {
            pool.for_each_index(256, |i| {
                let mut acc = i as u64;
                for k in 0..220 {
                    acc = acc.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(k);
                }
                std::hint::black_box(acc);
            })
        });
    }

    r.finish();
}
