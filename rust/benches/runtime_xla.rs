//! Bench: PJRT runtime — artifact execution latency (the L1/L2 serving
//! path) vs the pure-rust engines; cold compile vs warm cache.
//!
//! Skips politely when `make artifacts` has not run.

use ohm::bench::{BenchCfg, Runner};
use ohm::dla::matmul;
use ohm::runtime::{self, Runtime};
use ohm::workload::{arrays, matrices};

fn main() {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("runtime_xla: no artifacts (run `make artifacts`); skipping");
        return;
    }
    let rt = Runtime::load(&dir).expect("load runtime");
    let mut r = Runner::with_cfg(
        "runtime_xla",
        BenchCfg { warmup_iters: 1, sample_count: 5, max_total_ns: 20_000_000_000 },
    );

    // Cold compile time per artifact (fresh runtime each).
    for name in ["matmul_64", "matmul_256", "bitonic_1000"] {
        let fresh = Runtime::load(&dir).unwrap();
        let t = std::time::Instant::now();
        fresh.warm(name).unwrap();
        r.record("compile-cold", &format!("artifact={name}"), vec![t.elapsed().as_nanos() as f64], "ns");
    }

    // Warm execution latency: XLA (pallas-lowered HLO) vs rust serial.
    for n in [64usize, 128, 256] {
        let a = matrices::uniform(n, n, 1);
        let b = matrices::uniform(n, n, 2);
        rt.warm(&format!("matmul_{n}")).unwrap();
        r.measure("matmul-xla", &format!("order={n}"), || {
            runtime::matmul_xla(&rt, &a, &b).unwrap()
        });
        r.measure("matmul-rust-serial", &format!("order={n}"), || matmul::serial(&a, &b));
    }

    // §Perf L2: interpret-pallas tile loop vs XLA native fused dot.
    for n in [256usize, 1000] {
        let name = format!("matmul_native_{n}");
        if rt.manifest().get(&name).is_none() {
            continue; // older artifact bundle
        }
        let a = matrices::uniform(n, n, 1);
        let b = matrices::uniform(n, n, 2);
        rt.warm(&name).unwrap();
        rt.warm(&format!("matmul_{n}")).unwrap();
        r.measure("matmul-xla-native-dot", &format!("order={n}"), || {
            rt.exec_f32(&name, &[a.data(), b.data()]).unwrap()
        });
        r.measure("matmul-xla-pallas-interp", &format!("order={n}"), || {
            rt.exec_f32(&format!("matmul_{n}"), &[a.data(), b.data()]).unwrap()
        });
    }

    for n in [1000usize, 2000] {
        let xs = arrays::uniform_f32(n, 3);
        rt.warm(&format!("bitonic_{n}")).unwrap();
        r.measure("sort-xla-bitonic", &format!("n={n}"), || runtime::sort_xla(&rt, &xs).unwrap());
        r.measure("sort-rust-std", &format!("n={n}"), || {
            let mut v = xs.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v
        });
    }

    r.finish();
}
