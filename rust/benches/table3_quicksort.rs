//! Bench: regenerates **Table 3 / Figure 5** (quicksort, serial vs
//! parallel × pivot strategy) plus host wall-clock sort baselines.

use ohm::bench::{BenchCfg, Runner};
use ohm::config::ExperimentConfig;
use ohm::experiments::table3;
use ohm::exec::ExecCtx;
use ohm::sort::{parallel_quicksort, serial_quicksort, PivotStrategy};
use ohm::workload::arrays;

fn main() {
    let mut r = Runner::new("table3_quicksort");

    // --- The paper's table: virtual ms per (n, column) ----------------
    let cfg = ExperimentConfig { reps: 3, ..Default::default() };
    for (n, cells) in table3::grid(&cfg) {
        let cols = ["serial", "par-left", "par-mean", "par-right", "par-random"];
        for (name, ms) in cols.iter().zip(cells) {
            r.record(&format!("table3/{name}"), &format!("n={n}"), vec![ms * 1e3], "us(virtual)");
        }
    }

    // --- Host wall-clock: serial vs threaded quicksort ----------------
    let mut wall = Runner::with_cfg(
        "table3_quicksort_wall",
        BenchCfg { warmup_iters: 1, sample_count: 7, max_total_ns: 8_000_000_000 },
    );
    let ctx = ExecCtx::threaded(4);
    for &n in &[10_000usize, 100_000] {
        let proto = arrays::uniform_i64(n, 9);
        for s in [PivotStrategy::Left, PivotStrategy::Mean, PivotStrategy::Random, PivotStrategy::MedianOf3] {
            wall.measure(&format!("serial-{}", s.name()), &format!("n={n}"), || {
                let mut xs = proto.clone();
                serial_quicksort(&mut xs, s, 1);
                xs
            });
        }
        wall.measure("threaded-mean-4t", &format!("n={n}"), || {
            let mut xs = proto.clone();
            parallel_quicksort(&mut xs, PivotStrategy::Mean, &ctx);
            xs
        });
        wall.measure("std-sort-unstable", &format!("n={n}"), || {
            let mut xs = proto.clone();
            xs.sort_unstable();
            xs
        });
    }

    r.finish();
    wall.finish();
}
