//! The `ohm bench` harness: a machine-readable kernel-performance
//! trajectory (`BENCH_matmul.json` / `BENCH_sort.json`), committed per PR
//! and regression-gated in CI (`tools/bench_gate.py`).
//!
//! Two modes:
//!
//! * **virtual** — the committed baseline. Every number is a closed-form
//!   evaluation of the calibrated overhead model
//!   ([`overhead::model`](crate::overhead::model) with
//!   [`OverheadParams::paper_2022`]): serial time, best-grain parallel
//!   time, the α/β/γ/δ overhead breakdown at the chosen grain, and the
//!   serial/parallel crossover size. Virtual numbers are exactly
//!   reproducible on any machine (no wall clock, no libm beyond `log2`),
//!   which is what makes a *committed* perf file meaningful to diff —
//!   they change only when the model, the parameters, or the estimates
//!   change.
//! * **wall** — measured on the host: the real kernels run with
//!   [`Stopwatch`] timing, the pool's metrics delta converted to a
//!   [`Ledger`] and priced by the same params, and every parallel result
//!   checksum-verified against the serial reference before its time is
//!   accepted. Wall numbers are host-specific and are *not* committed;
//!   the CI gate compares them with a wide (15%) tolerance when used.
//!
//! Schema (`ohm-bench/v1`) is documented in `docs/BENCH.md`; the gate's
//! Python mirror of the virtual arithmetic lives in `tools/bench_gate.py`.

use crate::dla::{matmul, microkernel};
use crate::overhead::{CostModel, Ledger, OverheadParams, StaticCostModel, WorkEstimate};
use crate::pool::ThreadPool;
use crate::sort::{samplesort_inplace, serial_quicksort, PivotStrategy, SortCostModel};
use crate::util::Stopwatch;
use crate::workload::{arrays, matrices};

/// Calibrated matmul multiply-add cost used by the virtual sweep
/// (1 ns/op — the `paper_2022` work scale).
pub const MATMUL_OP_NS: f64 = 1.0;

/// Default sweep sizes (matmul order n ⇒ n³ work).
pub const MATMUL_SIZES: [usize; 6] = [16, 32, 64, 128, 256, 512];
/// Default sweep sizes (sort element count).
pub const SORT_SIZES: [usize; 7] = [100, 300, 1000, 3000, 10_000, 30_000, 100_000];

/// Which kernel domain a document covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topic {
    Matmul,
    Sort,
}

impl Topic {
    pub fn name(self) -> &'static str {
        match self {
            Topic::Matmul => "matmul",
            Topic::Sort => "sort",
        }
    }

    pub fn default_sizes(self) -> Vec<usize> {
        match self {
            Topic::Matmul => MATMUL_SIZES.to_vec(),
            Topic::Sort => SORT_SIZES.to_vec(),
        }
    }

    /// The model estimate for one problem size — the single source of
    /// truth shared by virtual mode, wall-mode grain choice, and the
    /// crossover search (and mirrored by `tools/bench_gate.py`).
    pub fn estimate(self, n: usize) -> WorkEstimate {
        match self {
            // n³ multiply-adds; distribution payload = A + C (B shared).
            Topic::Matmul => WorkEstimate::fully_parallel(
                n as f64 * n as f64 * n as f64 * MATMUL_OP_NS,
                (2 * n * n * 4) as u64,
            ),
            Topic::Sort => crate::sort::estimate(n, &SortCostModel::paper_2022()),
        }
    }
}

/// Per-event overhead charge at the chosen grain, in ns (Ledger classes
/// priced by [`OverheadParams`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverheadBreakdown {
    pub spawn_ns: f64,
    pub sync_ns: f64,
    pub msg_ns: f64,
    pub byte_ns: f64,
}

impl OverheadBreakdown {
    pub fn total_ns(&self) -> f64 {
        self.spawn_ns + self.sync_ns + self.msg_ns + self.byte_ns
    }

    /// Price a measured ledger with the given params.
    pub fn from_ledger(ledger: &Ledger, params: &OverheadParams) -> Self {
        OverheadBreakdown {
            spawn_ns: params.alpha_spawn_ns * ledger.spawns as f64,
            sync_ns: params.beta_sync_ns * ledger.syncs as f64,
            msg_ns: params.gamma_msg_ns * ledger.messages as f64,
            byte_ns: params.delta_byte_ns * ledger.bytes as f64,
        }
    }

    /// The model's predicted charge for `tasks` tasks on `p` cores —
    /// the same event counts `predict_parallel_ns` assumes.
    pub fn predicted(params: &OverheadParams, est: &WorkEstimate, p: usize, tasks: usize) -> Self {
        let migrations = tasks as f64 * (p.saturating_sub(1)) as f64 / p as f64;
        let bytes_moved = est.dist_bytes as f64 * (p.saturating_sub(1)) as f64 / p as f64;
        OverheadBreakdown {
            spawn_ns: params.alpha_spawn_ns * tasks as f64,
            sync_ns: params.beta_sync_ns * tasks as f64,
            msg_ns: params.gamma_msg_ns * migrations,
            byte_ns: params.delta_byte_ns * bytes_moved,
        }
    }
}

/// One measured (or predicted) sweep point.
#[derive(Debug, Clone)]
pub struct BenchPoint {
    pub n: usize,
    pub serial_ns: f64,
    pub parallel_ns: f64,
    /// Task count the parallel time was taken at (model best grain).
    pub tasks: usize,
    pub speedup: f64,
    pub overhead: OverheadBreakdown,
}

/// A complete `BENCH_<topic>.json` document.
#[derive(Debug, Clone)]
pub struct BenchDoc {
    pub topic: Topic,
    /// `"virtual"` or `"wall"`.
    pub mode: &'static str,
    pub cores: usize,
    pub params: OverheadParams,
    /// Smallest sweep size where parallel beats serial, if any.
    pub crossover_n: Option<usize>,
    pub points: Vec<BenchPoint>,
    pub provenance: String,
}

/// Deterministic model-based sweep (the committed baseline).
pub fn virtual_doc(
    topic: Topic,
    sizes: &[usize],
    cores: usize,
    params: &OverheadParams,
) -> BenchDoc {
    let cost = StaticCostModel::new(*params);
    let points = sizes
        .iter()
        .map(|&n| {
            let est = topic.estimate(n);
            let serial_ns = cost.predict_serial_ns(&est);
            let (tasks, parallel_ns) = cost.predict_parallel_ns(&est, cores);
            BenchPoint {
                n,
                serial_ns,
                parallel_ns,
                tasks,
                speedup: serial_ns / parallel_ns,
                overhead: OverheadBreakdown::predicted(params, &est, cores, tasks),
            }
        })
        .collect();
    BenchDoc {
        topic,
        mode: "virtual",
        cores,
        params: *params,
        crossover_n: cost.crossover(cores, sizes, &|n| topic.estimate(n)),
        points,
        provenance: format!(
            "closed-form overhead model (overhead::model, paper_2022 params), {cores} cores; \
             deterministic — no wall clock"
        ),
    }
}

/// Host-measured sweep. Each parallel result is checksum-verified against
/// the serial reference before its timing is recorded; a mismatch panics
/// (a wrong fast kernel must never produce a bench number).
pub fn wall_doc(topic: Topic, sizes: &[usize], cores: usize, params: &OverheadParams) -> BenchDoc {
    let cost = StaticCostModel::new(*params);
    let pool = ThreadPool::new(cores);
    let samples = 3usize;
    let points = sizes
        .iter()
        .map(|&n| {
            let est = topic.estimate(n);
            let (tasks, _) = cost.predict_parallel_ns(&est, cores);
            let (serial_ns, parallel_ns, ledger) = match topic {
                Topic::Matmul => wall_matmul_point(n, &pool, tasks, samples, est.dist_bytes),
                Topic::Sort => wall_sort_point(n, &pool, tasks, samples),
            };
            BenchPoint {
                n,
                serial_ns,
                parallel_ns,
                tasks,
                speedup: serial_ns / parallel_ns,
                overhead: OverheadBreakdown::from_ledger(&ledger, params),
            }
        })
        .collect();
    // Wall crossover: first sweep size whose measured speedup exceeds 1.
    let crossover_n = {
        let pts: &Vec<BenchPoint> = &points;
        pts.iter().find(|p| p.speedup > 1.0).map(|p| p.n)
    };
    BenchDoc {
        topic,
        mode: "wall",
        cores,
        params: *params,
        crossover_n,
        points,
        provenance: format!("host-measured, min of {samples} samples, {cores}-thread pool"),
    }
}

fn wall_matmul_point(
    n: usize,
    pool: &ThreadPool,
    tasks: usize,
    samples: usize,
    dist_bytes: u64,
) -> (f64, f64, Ledger) {
    let a = matrices::uniform(n, n, 0xA0 ^ n as u64);
    let b = matrices::uniform(n, n, 0xB0 ^ n as u64);
    let want = matmul::serial(&a, &b);
    let serial_ns = min_time_ns(samples, || {
        let c = microkernel::multiply(&a, &b);
        assert_eq!(c, want, "microkernel checksum mismatch at n={n}");
    });
    let before = pool.metrics();
    let parallel_ns = min_time_ns(samples, || {
        let c = matmul::parallel(&a, &b, pool, tasks);
        assert_eq!(c, want, "parallel checksum mismatch at n={n}");
    });
    let delta = pool.metrics().delta_since(&before);
    debug_assert!(delta.overhead_events() > 0, "parallel matmul must fork");
    (serial_ns, parallel_ns, Ledger::from_metrics(&delta, dist_bytes))
}

fn wall_sort_point(
    n: usize,
    pool: &ThreadPool,
    tasks: usize,
    samples: usize,
) -> (f64, f64, Ledger) {
    let orig = arrays::uniform_i64(n, 0xC0 ^ n as u64);
    let mut want = orig.clone();
    serial_quicksort(&mut want, PivotStrategy::MedianOf3, 7);
    let serial_ns = min_time_ns(samples, || {
        let mut xs = orig.clone();
        serial_quicksort(&mut xs, PivotStrategy::MedianOf3, 7);
        assert_eq!(xs, want, "serial sort checksum mismatch at n={n}");
    });
    let buckets = tasks.max(2);
    let before = pool.metrics();
    let parallel_ns = min_time_ns(samples, || {
        let mut xs = orig.clone();
        samplesort_inplace(&mut xs, buckets, Some(pool), 7);
        assert_eq!(xs, want, "samplesort checksum mismatch at n={n}");
    });
    let delta = pool.metrics().delta_since(&before);
    (serial_ns, parallel_ns, Ledger::from_metrics(&delta, (n * 8) as u64))
}

fn min_time_ns(samples: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    (0..samples.max(1))
        .map(|_| {
            let sw = Stopwatch::start();
            f();
            sw.elapsed_ns() as f64
        })
        .fold(f64::INFINITY, f64::min)
}

// --- JSON emission (hand-rolled: the workspace is offline, no serde) ---

fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".into()
    }
}

impl BenchDoc {
    /// Serialize as the `ohm-bench/v1` JSON documented in `docs/BENCH.md`.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(4096);
        s.push_str("{\n");
        s.push_str("  \"schema\": \"ohm-bench/v1\",\n");
        s.push_str(&format!("  \"topic\": \"{}\",\n", self.topic.name()));
        s.push_str(&format!("  \"mode\": \"{}\",\n", self.mode));
        s.push_str(&format!("  \"cores\": {},\n", self.cores));
        s.push_str(&format!(
            "  \"params\": {{\"alpha_spawn_ns\": {}, \"beta_sync_ns\": {}, \"gamma_msg_ns\": {}, \"delta_byte_ns\": {}}},\n",
            jf(self.params.alpha_spawn_ns),
            jf(self.params.beta_sync_ns),
            jf(self.params.gamma_msg_ns),
            jf(self.params.delta_byte_ns)
        ));
        match self.crossover_n {
            Some(n) => s.push_str(&format!("  \"crossover_n\": {n},\n")),
            None => s.push_str("  \"crossover_n\": null,\n"),
        }
        s.push_str("  \"points\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            let o = &p.overhead;
            s.push_str(&format!(
                "    {{\"n\": {}, \"serial_ns\": {}, \"parallel_ns\": {}, \"tasks\": {}, \"speedup\": {}, \
                 \"overhead\": {{\"spawn_ns\": {}, \"sync_ns\": {}, \"msg_ns\": {}, \"byte_ns\": {}, \"total_ns\": {}}}}}{}\n",
                p.n,
                jf(p.serial_ns),
                jf(p.parallel_ns),
                p.tasks,
                jf(p.speedup),
                jf(o.spawn_ns),
                jf(o.sync_ns),
                jf(o.msg_ns),
                jf(o.byte_ns),
                jf(o.total_ns()),
                if i + 1 < self.points.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!("  \"provenance\": \"{}\"\n", self.provenance.replace('"', "'")));
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_matmul_has_crossover_and_speedup_above_it() {
        let doc = virtual_doc(Topic::Matmul, &MATMUL_SIZES, 4, &OverheadParams::paper_2022());
        let x = doc.crossover_n.expect("matmul sweep must cross over");
        assert_eq!(x, 64, "paper_2022 4-core matmul crossover");
        for p in doc.points.iter().filter(|p| p.n >= x) {
            assert!(p.speedup > 1.0, "n={} speedup={}", p.n, p.speedup);
        }
        for p in doc.points.iter().filter(|p| p.n < x) {
            assert!(p.speedup < 1.0, "below crossover parallel must lose (n={})", p.n);
        }
    }

    #[test]
    fn virtual_sort_crossover_in_sweep() {
        let doc = virtual_doc(Topic::Sort, &SORT_SIZES, 4, &OverheadParams::paper_2022());
        let x = doc.crossover_n.expect("sort sweep must cross over");
        assert!(SORT_SIZES.contains(&x));
        let last = doc.points.last().unwrap();
        assert!(last.speedup > 1.5, "large sorts must show real speedup: {}", last.speedup);
    }

    #[test]
    fn virtual_overhead_breakdown_is_consistent() {
        // serial − (parallel − overhead) must equal the modeled compute
        // gap: parallel = critical_path + overhead exactly.
        let doc = virtual_doc(Topic::Matmul, &[256], 4, &OverheadParams::paper_2022());
        let p = &doc.points[0];
        let est = Topic::Matmul.estimate(256);
        let waves = p.tasks.div_ceil(4) as f64;
        let critical = est.total_work_ns * waves / p.tasks as f64;
        assert!((p.parallel_ns - (critical + p.overhead.total_ns())).abs() < 1e-6);
    }

    #[test]
    fn json_shape_round_trips_key_fields() {
        let doc = virtual_doc(Topic::Matmul, &[16, 64], 4, &OverheadParams::paper_2022());
        let j = doc.to_json();
        assert!(j.contains("\"schema\": \"ohm-bench/v1\""));
        assert!(j.contains("\"topic\": \"matmul\""));
        assert!(j.contains("\"mode\": \"virtual\""));
        assert!(j.contains("\"crossover_n\": 64"));
        assert_eq!(j.matches("\"n\": ").count(), 2, "one per sweep point");
        // Determinism: same inputs, same bytes.
        let again = virtual_doc(Topic::Matmul, &[16, 64], 4, &OverheadParams::paper_2022());
        assert_eq!(j, again.to_json());
    }

    #[test]
    fn wall_mode_small_sweep_verifies_checksums() {
        // Tiny sizes: exercises the measurement + checksum path quickly.
        // (Timing values are not asserted — only correctness plumbing.)
        let doc = wall_doc(Topic::Matmul, &[16, 32], 2, &OverheadParams::paper_2022());
        assert_eq!(doc.points.len(), 2);
        assert!(doc.points.iter().all(|p| p.serial_ns > 0.0 && p.parallel_ns > 0.0));
        let doc = wall_doc(Topic::Sort, &[100, 1000], 2, &OverheadParams::paper_2022());
        assert_eq!(doc.points.len(), 2);
        assert!(doc.points.iter().all(|p| p.serial_ns > 0.0));
    }
}
