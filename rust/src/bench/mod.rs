//! Measurement harness (offline `criterion` substitute).
//!
//! Each file in `rust/benches/` is a `harness = false` binary that uses
//! [`Runner`] to measure closures with warmup, adaptive iteration counts,
//! and robust statistics, then emits an aligned console table and a CSV
//! under `target/ohm-bench/` for EXPERIMENTS.md.
//!
//! Virtual-time experiments (the simulator) do not need repetition for
//! statistical confidence — they are deterministic — so [`Runner::record`]
//! also accepts externally-computed values (e.g. simulated microseconds).

pub mod kernel;

use crate::stats::Summary;
use crate::util::timer::fmt_ns;
use std::fs;
use std::io::Write as _;
use std::path::PathBuf;
use std::time::Instant;

/// Bench configuration (env-overridable for quick smoke runs).
#[derive(Debug, Clone)]
pub struct BenchCfg {
    pub warmup_iters: usize,
    pub sample_count: usize,
    /// Stop sampling early once total measured time exceeds this budget.
    pub max_total_ns: u64,
}

impl Default for BenchCfg {
    fn default() -> Self {
        BenchCfg {
            warmup_iters: crate::util::env_or("OHM_BENCH_WARMUP", 3),
            sample_count: crate::util::env_or("OHM_BENCH_SAMPLES", 15),
            max_total_ns: crate::util::env_or("OHM_BENCH_BUDGET_NS", 5_000_000_000),
        }
    }
}

/// One measured row.
#[derive(Debug, Clone)]
pub struct Record {
    pub name: String,
    /// Free-form parameter columns (e.g. "n=1000,pivot=mean").
    pub params: String,
    pub summary: Summary,
    /// Unit label for values ("ns" for wall time, "us(virtual)" for sim).
    pub unit: &'static str,
}

/// Collects records for one bench binary and writes console + CSV output.
pub struct Runner {
    bench_name: String,
    cfg: BenchCfg,
    records: Vec<Record>,
}

impl std::fmt::Debug for Runner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runner").finish_non_exhaustive()
    }
}

impl Runner {
    pub fn new(bench_name: &str) -> Self {
        eprintln!("== bench: {bench_name}");
        Runner { bench_name: bench_name.into(), cfg: BenchCfg::default(), records: Vec::new() }
    }

    pub fn with_cfg(bench_name: &str, cfg: BenchCfg) -> Self {
        eprintln!("== bench: {bench_name}");
        Runner { bench_name: bench_name.into(), cfg, records: Vec::new() }
    }

    /// Measure wall time of `f` (ns). `f` is run `warmup_iters` times
    /// untimed, then up to `sample_count` timed runs within the budget.
    pub fn measure<T>(&mut self, name: &str, params: &str, mut f: impl FnMut() -> T) -> &Record {
        for _ in 0..self.cfg.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.cfg.sample_count);
        let budget_start = Instant::now();
        for _ in 0..self.cfg.sample_count {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
            if budget_start.elapsed().as_nanos() as u64 > self.cfg.max_total_ns {
                break;
            }
        }
        self.push(name, params, samples, "ns")
    }

    /// Record externally-computed values (e.g. deterministic virtual time).
    pub fn record(&mut self, name: &str, params: &str, values: Vec<f64>, unit: &'static str) -> &Record {
        self.push(name, params, values, unit)
    }

    fn push(&mut self, name: &str, params: &str, samples: Vec<f64>, unit: &'static str) -> &Record {
        let summary = Summary::of(&samples).expect("bench produced no samples");
        let med = if unit == "ns" { fmt_ns(summary.median) } else { format!("{:.1}{unit}", summary.median) };
        eprintln!(
            "  {name:<38} {params:<34} median={med:>12}  rsd={:>5.1}%  n={}",
            summary.rsd() * 100.0,
            summary.n
        );
        self.records.push(Record { name: name.into(), params: params.into(), summary, unit });
        self.records.last().unwrap()
    }

    /// All records so far (for in-bench comparisons / assertions).
    pub fn records(&self) -> &[Record] {
        &self.records
    }

    /// Write `target/ohm-bench/<bench_name>.csv` and return its path.
    pub fn finish(self) -> PathBuf {
        let dir = PathBuf::from("target/ohm-bench");
        fs::create_dir_all(&dir).expect("create bench output dir");
        let path = dir.join(format!("{}.csv", self.bench_name));
        let mut f = fs::File::create(&path).expect("create bench csv");
        writeln!(f, "bench,name,params,unit,n,mean,std,min,median,p90,max").unwrap();
        for r in &self.records {
            let s = &r.summary;
            writeln!(
                f,
                "{},{},\"{}\",{},{},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3}",
                self.bench_name, r.name, r.params, r.unit, s.n, s.mean, s.std, s.min, s.median, s.p90, s.max
            )
            .unwrap();
        }
        eprintln!("== wrote {}", path.display());
        path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_produces_positive_samples() {
        let cfg = BenchCfg { warmup_iters: 1, sample_count: 5, max_total_ns: u64::MAX };
        let mut r = Runner::with_cfg("unit-test", cfg);
        let rec = r.measure("spin", "k=1000", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(rec.summary.mean > 0.0);
        assert_eq!(rec.summary.n, 5);
    }

    #[test]
    fn record_and_csv_roundtrip() {
        let mut r = Runner::with_cfg("unit-test-csv", BenchCfg::default());
        r.record("sim", "n=4", vec![1.0, 2.0, 3.0], "us");
        let path = r.finish();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("bench,name,params"));
        assert!(text.contains("unit-test-csv,sim,\"n=4\",us,3,"));
    }

    #[test]
    fn budget_stops_early() {
        let cfg = BenchCfg { warmup_iters: 0, sample_count: 1000, max_total_ns: 1 };
        let mut r = Runner::with_cfg("unit-test-budget", cfg);
        let rec = r.measure("sleepy", "", || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(rec.summary.n < 1000);
    }
}
