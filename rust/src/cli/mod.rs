//! Command-line interface (offline `clap` substitute) and the launcher.
//!
//! ```text
//! ohm experiment <id|all> [--out-dir D] [--cores N] [--reps N] [--config F]
//! ohm matmul --n N [--engine serial|threaded|simulated|xla] [--cores N]
//!            [--algo strassen [--cutoff C]]
//! ohm sort --n N [--pivot left|mean|right|random|median3] [--engine ...]
//! ohm serve [--jobs N] [--threads N] [--no-xla] [--seed S]
//!           [--listen ADDR [--conns N] [--serve-threads N] [--queue-depth N]
//!            [--batch-max N] [--batch-linger-us U] [--config F]]
//!           # TCP front end: concurrent readers, bounded admission queue
//!           # (overflow → ERR BUSY), cross-connection shape batching
//! ohm calibrate [--budget-ms N]
//! ohm gantt (--matmul N | --sort N) [--cores N]
//! ohm artifacts [--dir D]
//! ```
//!
//! `run()` returns the console output as a `String` so the whole surface
//! is unit-testable; `main.rs` just prints it.

pub mod parser;

use crate::config::ExperimentConfig;
use crate::coordinator::{Coordinator, CoordinatorCfg};
use crate::dla::matmul;
use crate::exec::ExecCtx;
use crate::overhead::calibrate::Calibration;
use crate::overhead::OverheadParams;
use crate::report::gantt;
use crate::runtime::Runtime;
use crate::sort::{parallel_quicksort, PivotStrategy};
use crate::workload::traces::{self, TraceSpec};
use crate::workload::{arrays, matrices};
use anyhow::{bail, Context, Result};
use parser::Args;
use std::fmt::Write as _;
use std::path::Path;

const USAGE: &str = "usage: ohm <experiment|matmul|sort|serve|calibrate|gantt|artifacts> [flags]
  experiment <id|all>   regenerate paper tables/figures (see DESIGN.md §5)
  matmul --n N          run one overhead-managed matmul
  sort --n N            run one overhead-managed quicksort
  serve                 run a job trace through the coordinator
                        (--listen ADDR for the concurrent TCP front end;
                         --serve-threads N reader threads, --queue-depth N
                         admission bound → ERR BUSY past it, --batch-max /
                         --batch-linger-us shape-batch formation,
                         --config F reads a [serving] section)
  calibrate             probe host overhead constants
  gantt                 render a simulated schedule
  artifacts             list AOT artifacts\n";

/// Entry point; `argv` excludes the binary name.
pub fn run(argv: &[String]) -> Result<String> {
    let args = Args::parse(argv)?;
    match args.command() {
        None | Some("help") => Ok(USAGE.to_string()),
        Some("experiment") => cmd_experiment(&args),
        Some("matmul") => cmd_matmul(&args),
        Some("sort") => cmd_sort(&args),
        Some("serve") => cmd_serve(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("gantt") => cmd_gantt(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn experiment_cfg(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(c) = args.get_parsed::<usize>("cores")? {
        cfg.cores = c;
    }
    if let Some(r) = args.get_parsed::<usize>("reps")? {
        cfg.reps = r.max(1);
    }
    if let Some(d) = args.get("out-dir") {
        cfg.out_dir = d.to_string();
    }
    if let Some(s) = args.get_parsed::<u64>("seed")? {
        cfg.seed = s;
    }
    Ok(cfg)
}

fn cmd_experiment(args: &Args) -> Result<String> {
    let id = args.positional(1).context("experiment id required (or `all`)")?;
    let cfg = experiment_cfg(args)?;
    let outs = if id == "all" {
        crate::experiments::run_all(&cfg)?
    } else {
        vec![crate::experiments::run(id, &cfg)?]
    };
    let dir = Path::new(&cfg.out_dir);
    let mut text = String::new();
    for out in &outs {
        let paths = crate::experiments::save(out, dir)?;
        writeln!(text, "== {} — {}", out.id, out.title).unwrap();
        text.push_str(&out.text);
        for p in paths {
            writeln!(text, "  wrote {}", p.display()).unwrap();
        }
        text.push('\n');
    }
    Ok(text)
}

fn make_ctx(args: &Args, default_engine: &str) -> Result<ExecCtx> {
    let cores = args.get_parsed::<usize>("cores")?.unwrap_or(4);
    let engine = args.get("engine").unwrap_or(default_engine);
    Ok(match engine {
        "serial" => ExecCtx::serial(),
        "threaded" => ExecCtx::threaded(cores),
        "simulated" => ExecCtx::simulated(cores, OverheadParams::paper_2022()),
        other => bail!("unknown engine {other:?} (serial|threaded|simulated|xla)"),
    })
}

fn cmd_matmul(args: &Args) -> Result<String> {
    let n = args.get_parsed::<usize>("n")?.context("--n required")?;
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(42);
    let a = matrices::uniform(n, n, seed);
    let b = matrices::uniform(n, n, seed ^ 0xABCD);
    if args.get("engine") == Some("xla") {
        let rt = Runtime::load(&Runtime::default_dir())?;
        let sw = crate::util::Stopwatch::start();
        let c = crate::runtime::matmul_xla(&rt, &a, &b)?;
        return Ok(format!(
            "matmul n={n} engine=xla ({}): {:.3} ms, ‖C‖_F = {:.3}\n",
            rt.platform(),
            sw.elapsed_ns() as f64 / 1e6,
            c.frobenius()
        ));
    }
    if args.get("algo") == Some("strassen") {
        let cutoff = args.get_parsed::<usize>("cutoff")?.unwrap_or(crate::dla::strassen::DEFAULT_CUTOFF);
        let sw = crate::util::Stopwatch::start();
        let c = crate::dla::strassen::strassen(&a, &b, cutoff);
        return Ok(format!(
            "matmul n={n} algo=strassen cutoff={cutoff}: {:.3} ms wall, {:.0} model-ops (classical {:.0})\n‖C‖_F = {:.3}\n",
            sw.elapsed_ns() as f64 / 1e6,
            crate::dla::strassen::work_ops(n, cutoff),
            (n as f64).powi(3),
            c.frobenius(),
        ));
    }
    let ctx = make_ctx(args, "simulated")?;
    let (c, rep) = matmul::run(&a, &b, &ctx);
    Ok(format!(
        "matmul n={n} engine={}: {:.3} ms ({}), speedup {}, ledger: {}\n‖C‖_F = {:.3}\n",
        ctx.engine_name(),
        rep.time_us() / 1e3,
        if rep.virtual_ns.is_some() { "virtual" } else { "wall" },
        rep.speedup().map_or("n/a".into(), |s| format!("{s:.2}×")),
        rep.ledger.summary(),
        c.frobenius(),
    ))
}

fn cmd_sort(args: &Args) -> Result<String> {
    let n = args.get_parsed::<usize>("n")?.context("--n required")?;
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(42);
    let pivot = match args.get("pivot") {
        Some(p) => PivotStrategy::from_name(p).with_context(|| format!("bad pivot {p:?}"))?,
        None => PivotStrategy::Mean,
    };
    if args.get("engine") == Some("xla") {
        let rt = Runtime::load(&Runtime::default_dir())?;
        let xs = arrays::uniform_f32(n, seed);
        let sw = crate::util::Stopwatch::start();
        let out = crate::runtime::sort_xla(&rt, &xs)?;
        let ok = out.windows(2).all(|w| w[0] <= w[1]);
        return Ok(format!(
            "sort n={n} engine=xla: {:.3} ms, sorted={ok}\n",
            sw.elapsed_ns() as f64 / 1e6
        ));
    }
    let ctx = make_ctx(args, "simulated")?;
    let mut xs = arrays::uniform_i64(n, seed);
    let rep = parallel_quicksort(&mut xs, pivot, &ctx);
    Ok(format!(
        "sort n={n} pivot={} engine={}: {:.3} ms ({}), speedup {}, ledger: {}\nsorted={}\n",
        pivot.name(),
        ctx.engine_name(),
        rep.time_us() / 1e3,
        if rep.virtual_ns.is_some() { "virtual" } else { "wall" },
        rep.speedup().map_or("n/a".into(), |s| format!("{s:.2}×")),
        rep.ledger.summary(),
        crate::sort::is_sorted(&xs),
    ))
}

fn cmd_serve(args: &Args) -> Result<String> {
    if let Some(addr) = args.get("listen") {
        // TCP serving mode: line protocol behind the admission-controlled
        // serving layer (see coordinator::server for the threading model).
        let mut serving = match args.get("config") {
            Some(path) => crate::config::ServingConfig::load(Path::new(path))?,
            None => crate::config::ServingConfig::default(),
        };
        if let Some(v) = args.get_parsed::<usize>("serve-threads")? {
            serving.serve_threads = v.max(1);
        }
        if let Some(v) = args.get_parsed::<usize>("queue-depth")? {
            serving.queue_depth = v.max(1);
        }
        if let Some(v) = args.get_parsed::<usize>("batch-max")? {
            serving.batch_max = v.max(1);
        }
        if let Some(v) = args.get_parsed::<u64>("batch-linger-us")? {
            serving.batch_linger_us = v;
        }
        let threads = args.get_parsed::<usize>("threads")?.unwrap_or(4);
        let conns = args.get_parsed::<usize>("conns")?;
        let mut cfg = CoordinatorCfg { threads, ..Default::default() };
        serving.apply(&mut cfg);
        let server = crate::coordinator::server::Server::bind(addr)?;
        eprintln!(
            "ohm serving on {} ({} reader threads, queue depth {}, batch ≤{})",
            server.local_addr(),
            cfg.serve_threads,
            cfg.queue_depth,
            cfg.batch_max,
        );
        server.serve(cfg, conns)?;
        return Ok(format!("server on {} finished\n", server.local_addr()));
    }
    let jobs = args.get_parsed::<usize>("jobs")?.unwrap_or(50);
    let threads = args.get_parsed::<usize>("threads")?.unwrap_or(4);
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(42);
    let runtime = if args.has("no-xla") {
        None
    } else {
        Runtime::load(&Runtime::default_dir()).ok()
    };
    let rt_desc = match &runtime {
        Some(rt) => format!("xla runtime: {} ({} artifacts)", rt.platform(), rt.names().len()),
        None => "xla runtime: disabled".to_string(),
    };
    let mut coord = Coordinator::new(CoordinatorCfg { threads, ..Default::default() }, runtime);
    let spec = TraceSpec { jobs, ..Default::default() };
    let trace = traces::generate(&spec, seed);
    let results = coord.run_trace(&trace);
    let ok = results.iter().filter(|r| r.ok).count();
    let mut out = format!("{rt_desc}\nran {} jobs: {ok} ok, {} failed\n", results.len(), results.len() - ok);
    out.push_str(&coord.telemetry.render());
    Ok(out)
}

fn cmd_calibrate(args: &Args) -> Result<String> {
    let budget = args.get_parsed::<u64>("budget-ms")?.unwrap_or(1000);
    let cal = Calibration::with_fallback(budget);
    Ok(format!(
        "calibration (probed={}):\n  α spawn  = {:>12.1} ns\n  β sync   = {:>12.1} ns\n  γ msg    = {:>12.1} ns\n  δ byte   = {:>12.4} ns\n  matmul op = {:>11.3} ns\n  sort op   = {:>11.3} ns\n",
        cal.probed,
        cal.params.alpha_spawn_ns,
        cal.params.beta_sync_ns,
        cal.params.gamma_msg_ns,
        cal.params.delta_byte_ns,
        cal.matmul_op_ns,
        cal.sort_op_ns,
    ))
}

fn cmd_gantt(args: &Args) -> Result<String> {
    let cores = args.get_parsed::<usize>("cores")?.unwrap_or(4);
    let ctx = ExecCtx::simulated(cores, OverheadParams::paper_2022()).with_trace(true);
    let render = |rep: &crate::exec::RunReport| {
        let mut out = gantt::render(&rep.timeline, cores, 100);
        // Quantitative Fig-1: where the machine time actually went.
        let sim_report = crate::sim::SimReport {
            makespan_ns: rep.virtual_ns.unwrap_or(0.0),
            serial_ns: rep.serial_equiv_ns.unwrap_or(0.0),
            ledger: rep.ledger,
            core_busy_ns: vec![0.0; cores],
            timeline: rep.timeline.clone(),
        };
        out.push_str(&crate::sim::Breakdown::of(&sim_report).summary());
        out.push('\n');
        out
    };
    if let Some(n) = args.get_parsed::<usize>("matmul")? {
        let a = matrices::uniform(n, n, 1);
        let b = matrices::uniform(n, n, 2);
        let (_, rep) = matmul::run(&a, &b, &ctx);
        return Ok(render(&rep));
    }
    if let Some(n) = args.get_parsed::<usize>("sort")? {
        let mut xs = arrays::uniform_i64(n, 1);
        let rep = parallel_quicksort(&mut xs, PivotStrategy::Mean, &ctx);
        return Ok(render(&rep));
    }
    bail!("gantt needs --matmul N or --sort N")
}

fn cmd_artifacts(args: &Args) -> Result<String> {
    let dir = args.get("dir").map(Path::new).map(Path::to_path_buf).unwrap_or_else(Runtime::default_dir);
    let rt = Runtime::load(&dir)?;
    let mut out = format!("artifact dir: {} (platform {})\n", dir.display(), rt.platform());
    for name in rt.names() {
        let spec = rt.manifest().get(name).unwrap();
        let ins: Vec<String> = spec
            .inputs
            .iter()
            .map(|t| format!("{}[{}]", t.dtype, t.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("×")))
            .collect();
        writeln!(out, "  {:<26} {} -> {:?}", name, ins.join(", "), spec.output.dims).unwrap();
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn call(argv: &[&str]) -> Result<String> {
        run(&argv.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn help_and_unknown() {
        assert!(call(&[]).unwrap().contains("usage"));
        assert!(call(&["bogus"]).is_err());
    }

    #[test]
    fn matmul_simulated() {
        let out = call(&["matmul", "--n", "64"]).unwrap();
        assert!(out.contains("matmul n=64"), "{out}");
        assert!(out.contains("virtual"));
    }

    #[test]
    fn sort_all_engines_cpu() {
        for engine in ["serial", "threaded", "simulated"] {
            let out = call(&["sort", "--n", "500", "--engine", engine, "--pivot", "left"]).unwrap();
            assert!(out.contains("sorted=true"), "{engine}: {out}");
        }
    }

    #[test]
    fn sort_rejects_bad_pivot() {
        assert!(call(&["sort", "--n", "10", "--pivot", "zzz"]).is_err());
    }

    #[test]
    fn gantt_renders() {
        let out = call(&["gantt", "--sort", "2000"]).unwrap();
        assert!(out.contains("core  0"), "{out}");
    }

    #[test]
    fn calibrate_fast_budget() {
        let out = call(&["calibrate", "--budget-ms", "50"]).unwrap();
        assert!(out.contains("α spawn"));
    }

    #[test]
    fn serve_listen_rejects_malformed_flags_before_binding() {
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--queue-depth", "abc"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--serve-threads", "x"]).is_err());
    }

    #[test]
    fn experiment_single_to_tmpdir() {
        let dir = std::env::temp_dir().join("ohm-cli-exp");
        let out = call(&["experiment", "table1", "--out-dir", dir.to_str().unwrap()]).unwrap();
        assert!(out.contains("Table 1"));
        assert!(dir.join("table1.txt").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
