//! Command-line interface (offline `clap` substitute) and the launcher.
//!
//! ```text
//! ohm experiment <id|all> [--out-dir D] [--cores N] [--reps N] [--config F]
//! ohm matmul --n N [--engine serial|threaded|simulated|xla] [--cores N]
//!            [--algo strassen [--cutoff C]]
//! ohm sort --n N [--pivot left|mean|right|random|median3] [--engine ...]
//! ohm serve [--jobs N] [--threads N] [--no-xla] [--seed S]
//!           [--listen ADDR [--conns N] [--serve-threads N] [--queue-depth N]
//!            [--batch-max N] [--batch-linger-us U] [--lanes N]
//!            [--steal true|false | --no-steal]
//!            [--admission fixed|adaptive] [--slo-p90-us N]
//!            [--slo CLASS=US[,CLASS=US...]] [--admission-window-ms N]
//!            [--rebalance off|adaptive] [--rebalance-window-ms N]
//!            [--cache on|off] [--cache-entries N] [--cache-bytes N]
//!            [--cost-model on|off] [--faults SPEC]
//!            [--io threads|reactor] [--reactor-threads N] [--config F]]
//!           # TCP front end: concurrent readers, per-shape-class dispatch
//!           # lanes with work stealing, bounded per-lane admission queues
//!           # (overflow → ERR BUSY), SLO-driven adaptive admission
//!           # (rolling p90 queue wait past the class's SLO → ERR
//!           # OVERLOADED; per-class budgets via --slo / [admission.slo]),
//!           # epoch-versioned routing with load-driven lane
//!           # repartitioning (--rebalance adaptive re-buckets hot shape
//!           # classes onto cold lanes within their kind span),
//!           # warm result cache (repeat (kind, seed) requests answered
//!           # engine=cache without queueing; single-flight, LRU +
//!           # byte-bounded, off by default), cost-model-driven
//!           # scheduling (--cost-model on: jobs below the predicted
//!           # serial/parallel crossover run serial-inline on the lane
//!           # thread, admission sheds on predicted queue wait, the
//!           # rebalancer weighs classes by predicted cost; off by
//!           # default), cross-connection shape
//!           # batching, DRAIN protocol for rolling restarts, and the
//!           # connection edge itself (--io reactor: a fixed epoll
//!           # reactor pool multiplexes every connection instead of a
//!           # thread per socket; replies byte-identical) — see
//!           # docs/PROTOCOL.md
//! ohm loadgen --addr HOST:PORT [--clients N] [--reqs N] [--seed S]
//!             [--retries N] [--backoff-us U] [--repeat-seeds]
//!             [--skew S] [--open-conns N] [--drain [--out FILE]]
//!           # drive a running server: N concurrent clients × mixed
//!           # matmul/sort shapes (round-robin, or Zipf(S)-skewed with
//!           # --skew for a reproducible lane-imbalanced trace), verify
//!           # checksums against the serial engine, report
//!           # client-observed latency p50/p90/p99
//!           # (split hit-path vs miss-path when a result cache answers),
//!           # goodput vs offered load under jittered retries (one
//!           # retry policy keyed on the ERR taxonomy), optionally
//!           # DRAIN and save the final STATS
//! ohm chaos --matrix [--seed N] [--out FILE]
//!           # deterministic fault×feature conformance sweep: each cell
//!           # boots an in-process server with one injected fault armed
//!           # (--faults spec) against a feature set (cache, adaptive
//!           # rebalance, cost model), drives a seeded trace, then
//!           # asserts admitted==finished, checksum bit-identity vs the
//!           # serial reference, bounded drain exit, and regime-pure
//!           # telemetry — see docs/CHAOS.md
//! ohm bench [--json] [--topic matmul|sort|all] [--mode virtual|wall]
//!           [--cores N] [--sizes N,N,...] [--out DIR]
//!           # kernel perf trajectory: size sweep per topic, serial vs
//!           # best-grain parallel, α/β/γ/δ overhead breakdown, crossover
//!           # size; --json writes BENCH_<topic>.json (schema ohm-bench/v1,
//!           # docs/BENCH.md) for the committed baselines tools/bench_gate.py
//!           # regression-gates in CI
//! ohm calibrate [--budget-ms N]
//! ohm gantt (--matmul N | --sort N) [--cores N]
//! ohm artifacts [--dir D]
//! ```
//!
//! `run()` returns the console output as a `String` so the whole surface
//! is unit-testable; `main.rs` just prints it.

pub mod parser;

use crate::config::ExperimentConfig;
use crate::coordinator::{Coordinator, CoordinatorCfg, ErrCode};
use crate::dla::matmul;
use crate::exec::ExecCtx;
use crate::overhead::calibrate::Calibration;
use crate::overhead::OverheadParams;
use crate::report::gantt;
use crate::runtime::Runtime;
use crate::sort::{parallel_quicksort, PivotStrategy};
use crate::workload::traces::{self, TraceKind, TraceSpec};
use crate::workload::{arrays, matrices};
use anyhow::{bail, Context, Result};
use parser::Args;
use std::fmt::Write as _;
use std::path::Path;

const USAGE: &str = "usage: ohm <experiment|matmul|sort|serve|loadgen|chaos|bench|calibrate|gantt|artifacts> [flags]
  experiment <id|all>   regenerate paper tables/figures (see DESIGN.md §5)
  matmul --n N          run one overhead-managed matmul
  sort --n N            run one overhead-managed quicksort
  serve                 run a job trace through the coordinator
                        (--listen ADDR for the concurrent TCP front end;
                         --serve-threads N reader threads, --queue-depth N
                         per-lane admission bound → ERR BUSY past it,
                         --admission fixed|adaptive + --slo-p90-us N soft
                         admission → ERR OVERLOADED past the queue-wait SLO,
                         --slo CLASS=US[,...] per-shape-class SLO overrides
                         (e.g. --slo matmul/2^6=2500,sort/2^9=800),
                         --lanes N shape-class dispatch lanes, --steal
                         true|false (or --no-steal) idle-lane work stealing,
                         --rebalance off|adaptive + --rebalance-window-ms N
                         load-driven lane repartitioning (epoch-versioned
                         routing; hot classes move to cold lanes within
                         their kind span, STATS gains a routing table),
                         --cache on|off + --cache-entries/--cache-bytes
                         warm result cache (repeat requests answered
                         engine=cache without queueing), --cost-model
                         on|off cost-model-driven scheduling (predicted
                         crossover → engine=serial-inline dispatch,
                         predictive admission, cost-weighted rebalance;
                         STATS gains a cost-model table), --batch-max /
                         --batch-linger-us shape-batch formation, DRAIN
                         protocol command for rolling restarts, --faults
                         SPEC deterministic fault injection (e.g.
                         kill-lane=@3,drop-reply=0.1; off by default —
                         grammar: docs/CHAOS.md), --io threads|reactor
                         connection edge: blocking reader threads
                         (default) or a fixed epoll reactor pool
                         (--reactor-threads N, default ≈ cores; replies
                         byte-identical, STATS gains a reactor table),
                         --config F
                         reads [serving] + [lanes] + [admission] +
                         [admission.slo] + [rebalance] + [cache] +
                         [costmodel] + [faults];
                         protocol reference: docs/PROTOCOL.md)
  loadgen               drive a running --listen server with concurrent
                        clients and checksum verification (--addr HOST:PORT,
                        --clients N, --reqs N per client, --retries N +
                        --backoff-us U jittered retry of BUSY/OVERLOADED,
                        --repeat-seeds for a cache-hitting repeated-seed
                        trace, --skew S for a Zipf(S)-skewed shape mix
                        (reproducible lane imbalance), --open-conns N to
                        hold N mostly-idle extra connections open through
                        the run (C10k pressure; reports the held-conn
                        count and probes the server's reactor thread
                        count), --drain to finish
                        with a DRAIN, --out FILE to save the final STATS;
                        prints client-side p50/p90/p99 — hit vs miss path
                        when cached — plus goodput vs offered load and
                        shed counts)
  chaos                 deterministic fault-injection conformance matrix
                        (--matrix sweeps the 6 fault kinds × base/full
                        feature sets plus 2 no-fault baselines, each cell
                        asserting admitted==finished, checksum
                        bit-identity, bounded drain exit, and regime-pure
                        telemetry; --seed N pins the schedule, --out FILE
                        saves the per-cell report; docs/CHAOS.md)
  bench                 kernel perf sweep: serial vs best-grain parallel
                        with the α/β/γ/δ overhead breakdown and the
                        serial/parallel crossover size per topic
                        (--topic matmul|sort|all, --mode virtual|wall,
                        --cores N, --sizes N,N,..., --json writes
                        BENCH_<topic>.json baselines to --out DIR;
                        schema + gate threshold: docs/BENCH.md)
  calibrate             probe host overhead constants
  gantt                 render a simulated schedule
  artifacts             list AOT artifacts\n";

/// Entry point; `argv` excludes the binary name.
pub fn run(argv: &[String]) -> Result<String> {
    let args = Args::parse(argv)?;
    match args.command() {
        None | Some("help") => Ok(USAGE.to_string()),
        Some("experiment") => cmd_experiment(&args),
        Some("matmul") => cmd_matmul(&args),
        Some("sort") => cmd_sort(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("bench") => cmd_bench(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("gantt") => cmd_gantt(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn experiment_cfg(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(c) = args.get_parsed::<usize>("cores")? {
        cfg.cores = c;
    }
    if let Some(r) = args.get_parsed::<usize>("reps")? {
        cfg.reps = r.max(1);
    }
    if let Some(d) = args.get("out-dir") {
        cfg.out_dir = d.to_string();
    }
    if let Some(s) = args.get_parsed::<u64>("seed")? {
        cfg.seed = s;
    }
    Ok(cfg)
}

fn cmd_experiment(args: &Args) -> Result<String> {
    let id = args.positional(1).context("experiment id required (or `all`)")?;
    let cfg = experiment_cfg(args)?;
    let outs = if id == "all" {
        crate::experiments::run_all(&cfg)?
    } else {
        vec![crate::experiments::run(id, &cfg)?]
    };
    let dir = Path::new(&cfg.out_dir);
    let mut text = String::new();
    for out in &outs {
        let paths = crate::experiments::save(out, dir)?;
        writeln!(text, "== {} — {}", out.id, out.title).unwrap();
        text.push_str(&out.text);
        for p in paths {
            writeln!(text, "  wrote {}", p.display()).unwrap();
        }
        text.push('\n');
    }
    Ok(text)
}

fn make_ctx(args: &Args, default_engine: &str) -> Result<ExecCtx> {
    let cores = args.get_parsed::<usize>("cores")?.unwrap_or(4);
    let engine = args.get("engine").unwrap_or(default_engine);
    Ok(match engine {
        "serial" => ExecCtx::serial(),
        "threaded" => ExecCtx::threaded(cores),
        "simulated" => ExecCtx::simulated(cores, OverheadParams::paper_2022()),
        other => bail!("unknown engine {other:?} (serial|threaded|simulated|xla)"),
    })
}

fn cmd_matmul(args: &Args) -> Result<String> {
    let n = args.get_parsed::<usize>("n")?.context("--n required")?;
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(42);
    let a = matrices::uniform(n, n, seed);
    let b = matrices::uniform(n, n, seed ^ 0xABCD);
    if args.get("engine") == Some("xla") {
        let rt = Runtime::load(&Runtime::default_dir())?;
        let sw = crate::util::Stopwatch::start();
        let c = crate::runtime::matmul_xla(&rt, &a, &b)?;
        return Ok(format!(
            "matmul n={n} engine=xla ({}): {:.3} ms, ‖C‖_F = {:.3}\n",
            rt.platform(),
            sw.elapsed_ns() as f64 / 1e6,
            c.frobenius()
        ));
    }
    if args.get("algo") == Some("strassen") {
        let cutoff = args.get_parsed::<usize>("cutoff")?.unwrap_or(crate::dla::strassen::DEFAULT_CUTOFF);
        let sw = crate::util::Stopwatch::start();
        let c = crate::dla::strassen::strassen(&a, &b, cutoff);
        return Ok(format!(
            "matmul n={n} algo=strassen cutoff={cutoff}: {:.3} ms wall, {:.0} model-ops (classical {:.0})\n‖C‖_F = {:.3}\n",
            sw.elapsed_ns() as f64 / 1e6,
            crate::dla::strassen::work_ops(n, cutoff),
            (n as f64).powi(3),
            c.frobenius(),
        ));
    }
    let ctx = make_ctx(args, "simulated")?;
    let (c, rep) = matmul::run(&a, &b, &ctx);
    Ok(format!(
        "matmul n={n} engine={}: {:.3} ms ({}), speedup {}, ledger: {}\n‖C‖_F = {:.3}\n",
        ctx.engine_name(),
        rep.time_us() / 1e3,
        if rep.virtual_ns.is_some() { "virtual" } else { "wall" },
        rep.speedup().map_or("n/a".into(), |s| format!("{s:.2}×")),
        rep.ledger.summary(),
        c.frobenius(),
    ))
}

fn cmd_sort(args: &Args) -> Result<String> {
    let n = args.get_parsed::<usize>("n")?.context("--n required")?;
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(42);
    let pivot = match args.get("pivot") {
        Some(p) => PivotStrategy::from_name(p).with_context(|| format!("bad pivot {p:?}"))?,
        None => PivotStrategy::Mean,
    };
    if args.get("engine") == Some("xla") {
        let rt = Runtime::load(&Runtime::default_dir())?;
        let xs = arrays::uniform_f32(n, seed);
        let sw = crate::util::Stopwatch::start();
        let out = crate::runtime::sort_xla(&rt, &xs)?;
        let ok = out.windows(2).all(|w| w[0] <= w[1]);
        return Ok(format!(
            "sort n={n} engine=xla: {:.3} ms, sorted={ok}\n",
            sw.elapsed_ns() as f64 / 1e6
        ));
    }
    let ctx = make_ctx(args, "simulated")?;
    let mut xs = arrays::uniform_i64(n, seed);
    let rep = parallel_quicksort(&mut xs, pivot, &ctx);
    Ok(format!(
        "sort n={n} pivot={} engine={}: {:.3} ms ({}), speedup {}, ledger: {}\nsorted={}\n",
        pivot.name(),
        ctx.engine_name(),
        rep.time_us() / 1e3,
        if rep.virtual_ns.is_some() { "virtual" } else { "wall" },
        rep.speedup().map_or("n/a".into(), |s| format!("{s:.2}×")),
        rep.ledger.summary(),
        crate::sort::is_sorted(&xs),
    ))
}

fn cmd_serve(args: &Args) -> Result<String> {
    if let Some(addr) = args.get("listen") {
        // TCP serving mode: line protocol behind the admission-controlled
        // serving layer (see coordinator::server for the threading model).
        let mut serving = match args.get("config") {
            Some(path) => crate::config::ServingConfig::load(Path::new(path))?,
            None => crate::config::ServingConfig::default(),
        };
        if let Some(v) = args.get("io") {
            serving.io = crate::coordinator::IoMode::parse(v)
                .with_context(|| format!("flag --io: unknown mode {v:?} (threads|reactor)"))?;
        }
        if let Some(v) = args.get_parsed::<usize>("reactor-threads")? {
            // 0 is the internal derive-from-parallelism sentinel, not a
            // valid explicit setting.
            if v == 0 {
                bail!("flag --reactor-threads: must be ≥ 1 (omit to derive from available parallelism)");
            }
            serving.reactor_threads = v;
        }
        if let Some(v) = args.get_parsed::<usize>("serve-threads")? {
            serving.serve_threads = v.max(1);
        }
        if let Some(v) = args.get_parsed::<usize>("queue-depth")? {
            serving.queue_depth = v.max(1);
        }
        if let Some(v) = args.get_parsed::<usize>("batch-max")? {
            serving.batch_max = v.max(1);
        }
        if let Some(v) = args.get_parsed::<u64>("batch-linger-us")? {
            serving.batch_linger_us = v;
        }
        if let Some(v) = args.get_parsed::<usize>("lanes")? {
            serving.lanes = v.max(1);
        }
        if args.has("steal") {
            serving.steal = match args.get("steal") {
                // Bare `--steal` (no value) switches it on.
                Some("") | None => true,
                Some(v) => match v.parse::<bool>() {
                    Ok(b) => b,
                    Err(_) => bail!("flag --steal: cannot parse {v:?} (true|false)"),
                },
            };
        }
        if args.has("no-steal") {
            serving.steal = false;
        }
        if let Some(v) = args.get("admission") {
            serving.admission = crate::coordinator::AdmissionMode::from_name(v)
                .with_context(|| format!("flag --admission: unknown mode {v:?} (fixed|adaptive)"))?;
        }
        if let Some(v) = args.get_parsed::<f64>("slo-p90-us")? {
            // Reject rather than clamp: a negative (or NaN) SLO clamped
            // to 0 would shed every request after the first — a total
            // outage from a sign typo.
            if !v.is_finite() || v < 0.0 {
                bail!("flag --slo-p90-us: must be a finite value ≥ 0, got {v:?}");
            }
            serving.slo_p90_us = v;
        }
        if let Some(v) = args.get("slo") {
            // Per-shape-class SLO overrides: `--slo matmul/2^6=2500`
            // (comma-separated for several classes). Appended after any
            // [admission.slo] config entries, so the CLI wins per class.
            for part in v.split(',') {
                let (name, us) = part
                    .split_once('=')
                    .with_context(|| format!("flag --slo: expected class=µs, got {part:?}"))?;
                let class = crate::coordinator::ShapeClass::parse(name).with_context(|| {
                    format!("flag --slo: unknown shape class {name:?} (e.g. matmul/2^6)")
                })?;
                let slo: f64 = us
                    .trim()
                    .parse()
                    .ok()
                    .with_context(|| format!("flag --slo: cannot parse µs value {us:?}"))?;
                if !slo.is_finite() || slo < 0.0 {
                    bail!("flag --slo: {name}: must be a finite value ≥ 0, got {slo:?}");
                }
                serving.slo_overrides.push((class, slo));
            }
        }
        if let Some(v) = args.get_parsed::<u64>("admission-window-ms")? {
            serving.admission_window_ms = v.max(1);
        }
        if let Some(v) = args.get("rebalance") {
            serving.rebalance = crate::coordinator::RebalanceMode::from_name(v)
                .with_context(|| format!("flag --rebalance: unknown mode {v:?} (off|adaptive)"))?;
        }
        if let Some(v) = args.get_parsed::<u64>("rebalance-window-ms")? {
            serving.rebalance_window_ms = v.max(1);
        }
        if let Some(v) = args.get("cache") {
            serving.cache = match v {
                "on" => true,
                "off" => false,
                other => bail!("flag --cache: unknown mode {other:?} (on|off)"),
            };
        }
        // Reject degenerate cache budgets rather than clamp (mirrors the
        // --slo-p90-us rule): a zero or negative cap would construct a
        // cache that can hold nothing while still paying lookup and
        // single-flight overhead on every request.
        if let Some(v) = args.get_parsed::<i64>("cache-entries")? {
            if v < 1 {
                bail!("flag --cache-entries: must be ≥ 1, got {v} (use --cache off to disable)");
            }
            serving.cache_entries = v as usize;
        }
        if let Some(v) = args.get_parsed::<i64>("cache-bytes")? {
            if v < 1 {
                bail!("flag --cache-bytes: must be ≥ 1, got {v} (use --cache off to disable)");
            }
            serving.cache_bytes = v as u64;
        }
        if let Some(v) = args.get("cost-model") {
            serving.cost_model = match v {
                "on" => true,
                "off" => false,
                other => bail!("flag --cost-model: unknown mode {other:?} (on|off)"),
            };
        }
        if let Some(v) = args.get("faults") {
            // Validate at flag time: a typoed kind or trigger must fail
            // before the listener binds, not at server start.
            crate::coordinator::FaultPlan::parse(v)
                .with_context(|| format!("flag --faults: bad spec {v:?} (see docs/CHAOS.md)"))?;
            serving.faults = v.to_string();
        }
        let threads = args.get_parsed::<usize>("threads")?.unwrap_or(4);
        let conns = args.get_parsed::<usize>("conns")?;
        let mut cfg = CoordinatorCfg { threads, ..Default::default() };
        serving.apply(&mut cfg);
        let server = crate::coordinator::server::Server::bind(addr)?;
        let cache_desc = if cfg.cache {
            format!("cache on ({} entries, {} bytes)", cfg.cache_entries, cfg.cache_bytes)
        } else {
            "cache off".to_string()
        };
        // Non-default routing/SLO extras only: the default banner stays
        // byte-identical to the pre-routing-layer server.
        let mut extras = String::new();
        if cfg.rebalance == crate::coordinator::RebalanceMode::Adaptive {
            extras.push_str(&format!(
                ", rebalance adaptive (window {}ms)",
                cfg.rebalance_window_ms
            ));
        }
        if !cfg.slo_overrides.is_empty() {
            extras.push_str(&format!(", {} per-class slo overrides", cfg.slo_overrides.len()));
        }
        if cfg.cost_model {
            extras.push_str(", cost model on");
        }
        if cfg.faults != "off" {
            extras.push_str(&format!(", faults {}", cfg.faults));
        }
        if cfg.io == crate::coordinator::IoMode::Reactor {
            extras.push_str(&format!(
                ", io reactor ({} reactor threads)",
                cfg.effective_reactor_threads()
            ));
        }
        eprintln!(
            "ohm serving on {} ({} reader threads, {} dispatch lanes (steal={}), per-lane queue depth {}, batch ≤{}, admission {} (slo p90 {:.0}µs), {}{})",
            server.local_addr(),
            cfg.serve_threads,
            cfg.lanes,
            cfg.steal,
            cfg.queue_depth,
            cfg.batch_max,
            cfg.admission.name(),
            cfg.slo_p90_us,
            cache_desc,
            extras,
        );
        server.serve(cfg, conns)?;
        return Ok(format!("server on {} finished\n", server.local_addr()));
    }
    let jobs = args.get_parsed::<usize>("jobs")?.unwrap_or(50);
    let threads = args.get_parsed::<usize>("threads")?.unwrap_or(4);
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(42);
    let runtime = if args.has("no-xla") {
        None
    } else {
        Runtime::load(&Runtime::default_dir()).ok()
    };
    let rt_desc = match &runtime {
        Some(rt) => format!("xla runtime: {} ({} artifacts)", rt.platform(), rt.names().len()),
        None => "xla runtime: disabled".to_string(),
    };
    let mut coord = Coordinator::new(CoordinatorCfg { threads, ..Default::default() }, runtime);
    let spec = TraceSpec { jobs, ..Default::default() };
    let trace = traces::generate(&spec, seed);
    let results = coord.run_trace(&trace);
    let ok = results.iter().filter(|r| r.ok).count();
    let mut out = format!("{rt_desc}\nran {} jobs: {ok} ok, {} failed\n", results.len(), results.len() - ok);
    out.push_str(&coord.telemetry.render());
    Ok(out)
}

/// Mixed shapes with no AOT artifacts, so routing stays on the CPU
/// engines and checksums are reproducible against the serial reference
/// on every checkout (mirrors the integration load suite).
const LOADGEN_SHAPES: &[(&str, usize)] =
    &[("MATMUL", 24), ("SORT", 300), ("MATMUL", 48), ("SORT", 999)];

/// Drive a running `serve --listen` server: N concurrent clients send
/// mixed matmul/sort shapes, every `OK` reply's checksum is verified
/// against the serial engine, client-observed request latency is
/// reported as exact p50/p90/p99 (alongside `ERR BUSY` and
/// `ERR OVERLOADED` reject counts, so adaptive-admission sheds are
/// visible from the client side), and `--drain` finishes with the
/// `DRAIN` protocol (asserting post-drain admission answers
/// `ERR DRAINING`), optionally saving the final STATS block to `--out`.
///
/// Overload-aware retries: `--retries N` re-sends a request answered
/// `ERR OVERLOADED` / `ERR BUSY` up to N times with jittered linear
/// backoff (`--backoff-us`, deterministic per-client jitter), so
/// shed-heavy runs report **goodput vs offered load** instead of a
/// misleading `ok` total — only requests still rejected after the
/// retry budget count as busy/shed. `--repeat-seeds` reuses one seed
/// per shape (instead of a unique seed per request), turning the run
/// into a repeated-seed trace that exercises a server-side `--cache
/// on` warm result cache; replies served with `engine=cache` are then
/// reported as a separate hit-path latency line next to the miss path.
/// `--skew <s>` replaces the balanced round-robin shape mix with
/// independent Zipf(s) draws (rank 0 the most popular shape), producing
/// a reproducible shape-class-skewed trace — the demand pattern the
/// server's `--rebalance adaptive` lane repartitioning exists for; the
/// realized mix is printed as a `skew=... shape mix:` line.
///
/// Errors (checksum mismatch, truncated reply, unclean drain) exit
/// nonzero — this is the CI serving-smoke entry point.
fn cmd_loadgen(args: &Args) -> Result<String> {
    use std::io::{BufRead, BufReader, Write};
    let addr = args
        .get("addr")
        .context("--addr required (host:port of a running `ohm serve --listen`)")?
        .to_string();
    let clients = args.get_parsed::<usize>("clients")?.unwrap_or(8).max(1);
    let reqs = args.get_parsed::<usize>("reqs")?.unwrap_or(6).max(1);
    let seed0 = args.get_parsed::<u64>("seed")?.unwrap_or(1);
    let drain = args.has("drain");
    let out_path = args.get("out").map(|s| s.to_string());
    let retries = args.get_parsed::<usize>("retries")?.unwrap_or(0);
    let backoff_us = args.get_parsed::<u64>("backoff-us")?.unwrap_or(500).max(1);
    let repeat_seeds = args.has("repeat-seeds");
    let open_conns = args.get_parsed::<usize>("open-conns")?.unwrap_or(0);
    let skew = match args.get_parsed::<f64>("skew")? {
        Some(s) if !s.is_finite() || s < 0.0 => {
            bail!("flag --skew: must be a finite Zipf exponent ≥ 0, got {s:?}")
        }
        s => s,
    };

    // Idle-connection ballast (`--open-conns N`): hold N extra
    // connections open for the whole run — mix, percentiles, and DRAIN
    // included — so the serving edge is exercised under C10k-style fd
    // pressure, not just request pressure. Each slot is verified live
    // with one PING and then left idle. Meant for `--io reactor`
    // servers: a thread-per-connection server parks a reader on every
    // idle connection, so its pool would wedge long before the mix
    // starts.
    let mut held: Vec<std::net::TcpStream> = Vec::with_capacity(open_conns);
    // The server's `reactor: threads=…` STATS trailer, probed through
    // the first held slot — the held-connection report below pairs the
    // client-side fd count with the server-side reactor thread count.
    let mut reactor_trailer: Option<String> = None;
    if open_conns > 0 {
        for i in 0..open_conns {
            let stream = std::net::TcpStream::connect(addr.as_str()).with_context(|| {
                format!("loadgen --open-conns: connect #{i} failed (server conn budget or fd limit?)")
            })?;
            {
                // Borrowed reader/writer halves: `try_clone` would dup
                // the fd and double the measured footprint.
                let mut w = &stream;
                writeln!(w, "PING")?;
                w.flush()?;
                let mut line = String::new();
                BufReader::new(&stream).read_line(&mut line)?;
                if line.trim() != "PONG" {
                    bail!("loadgen --open-conns: slot {i} answered {:?}, want PONG", line.trim());
                }
            }
            held.push(stream);
        }
        if let Some(first) = held.first() {
            let mut w = first;
            writeln!(w, "STATS")?;
            w.flush()?;
            let mut reader = BufReader::new(first);
            loop {
                let mut line = String::new();
                if reader.read_line(&mut line)? == 0 {
                    bail!("loadgen --open-conns: server closed mid-STATS probe");
                }
                let line = line.trim();
                if line == "." {
                    break;
                }
                if line.starts_with("reactor: threads=") {
                    reactor_trailer = Some(line.to_string());
                }
            }
        }
    }

    // Which LOADGEN_SHAPES index client `c`'s request `k` uses. The
    // default is the historical round-robin (a balanced trace); with
    // `--skew <s>` each request is an independent Zipf(s) draw over the
    // shapes (rank 0 the most popular), so a shape-class-skewed —
    // lane-imbalanced — trace is reproducible from the CLI. The draw is
    // deterministic per (seed, client): the reference checksums, the
    // client threads, and a rerun of the same command all agree.
    let shape_plan: Vec<Vec<usize>> = (0..clients)
        .map(|c| match skew {
            None => (0..reqs).map(|k| (c + k) % LOADGEN_SHAPES.len()).collect(),
            Some(s) => {
                let weights: Vec<f64> = (0..LOADGEN_SHAPES.len())
                    .map(|rank| 1.0 / ((rank + 1) as f64).powf(s))
                    .collect();
                let total: f64 = weights.iter().sum();
                let mut rng = crate::util::Pcg32::new(
                    seed0.wrapping_mul(0xA076_1D64_78BD_642F).wrapping_add(c as u64),
                );
                (0..reqs)
                    .map(|_| {
                        let mut u = rng.f64() * total;
                        for (i, w) in weights.iter().enumerate() {
                            if u < *w {
                                return i;
                            }
                            u -= w;
                        }
                        LOADGEN_SHAPES.len() - 1
                    })
                    .collect()
            }
        })
        .collect();

    // The workload seed for client `c`'s request `k` of shape
    // `shape_idx`. Default: unique per request (every execution is
    // cold). With --repeat-seeds the seed depends only on the shape, so
    // every request for a shape is the identical deterministic job —
    // the repeated-seed trace a warm result cache exists for.
    let seed_for = move |c: usize, k: usize, shape_idx: usize| -> u64 {
        if repeat_seeds {
            seed0 + shape_idx as u64
        } else {
            seed0 + (c * 1000 + k) as u64
        }
    };

    // Serial reference checksums, computed up front (one shared
    // reference coordinator; the clients only compare strings).
    let mut reference = Coordinator::new(CoordinatorCfg { threads: 1, ..Default::default() }, None);
    let mut expected: Vec<Vec<String>> = Vec::with_capacity(clients);
    for c in 0..clients {
        let mut per = Vec::with_capacity(reqs);
        for k in 0..reqs {
            let idx = shape_plan[c][k];
            let (cmd, n) = LOADGEN_SHAPES[idx];
            let kind = if cmd == "MATMUL" { TraceKind::Matmul { n } } else { TraceKind::Sort { n } };
            let r = reference.submit(kind, seed_for(c, k, idx));
            per.push(format!("checksum={:.4}", r.checksum));
        }
        expected.push(per);
    }

    /// One request's final outcome after any retries.
    struct ClientReply {
        reply: String,
        /// Client-observed latency of the *final* attempt, µs.
        latency_us: f64,
        /// Rejected attempts consumed before that outcome.
        retries: usize,
    }

    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            let plan = shape_plan[c].clone();
            std::thread::spawn(move || -> std::io::Result<Vec<ClientReply>> {
                let stream = std::net::TcpStream::connect(addr.as_str())?;
                let mut reader = BufReader::new(stream.try_clone()?);
                let mut out = stream;
                // Deterministic per-client jitter source (splitmix-style
                // scramble of the client id + base seed).
                let mut rng = crate::util::Pcg32::new(
                    seed0.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(c as u64),
                );
                let mut replies = Vec::with_capacity(reqs);
                for k in 0..reqs {
                    let idx = plan[k];
                    let (cmd, n) = LOADGEN_SHAPES[idx];
                    let seed = seed_for(c, k, idx);
                    let mut attempt = 0usize;
                    let final_reply = loop {
                        let sw = std::time::Instant::now();
                        writeln!(out, "{cmd} {n} {seed}")?;
                        out.flush()?;
                        let mut line = String::new();
                        reader.read_line(&mut line)?;
                        // Client-observed latency: request write → reply
                        // read, so it includes queue wait, service, and
                        // the wire.
                        let latency_us = sw.elapsed().as_nanos() as f64 / 1e3;
                        let reply = line.trim().to_string();
                        // One retry policy, keyed on the wire error
                        // taxonomy (PROTOCOL.md): only codes the server
                        // classifies as retriable (BUSY, OVERLOADED) are
                        // re-sent; DRAINING, FAULT, and MALFORMED are
                        // terminal answers.
                        let retryable =
                            ErrCode::classify(&reply).is_some_and(|code| code.retriable());
                        if retryable && attempt < retries {
                            attempt += 1;
                            // Jittered linear backoff in [base/2, base],
                            // base growing with the attempt count, so
                            // coordinated clients decorrelate instead of
                            // re-stampeding the lane in lockstep.
                            let base = backoff_us.saturating_mul(attempt as u64);
                            let wait = base / 2 + rng.below(base / 2 + 1);
                            std::thread::sleep(std::time::Duration::from_micros(wait));
                            continue;
                        }
                        break ClientReply { reply, latency_us, retries: attempt };
                    };
                    replies.push(final_reply);
                }
                writeln!(out, "QUIT")?;
                out.flush()?;
                Ok(replies)
            })
        })
        .collect();

    let mut ok = 0usize;
    let mut busy = 0usize;
    let mut shed = 0usize;
    let mut total_retries = 0usize;
    let mut cache_hits = 0usize;
    let mut latencies_us: Vec<f64> = Vec::with_capacity(clients * reqs);
    let mut hit_latencies_us: Vec<f64> = Vec::new();
    let mut miss_latencies_us: Vec<f64> = Vec::new();
    let mut problems: Vec<String> = Vec::new();
    for (c, h) in handles.into_iter().enumerate() {
        let replies = match h.join() {
            Ok(Ok(r)) => r,
            Ok(Err(e)) => bail!("loadgen client {c}: io error: {e}"),
            Err(_) => bail!("loadgen client {c} panicked"),
        };
        for (k, r) in replies.iter().enumerate() {
            total_retries += r.retries;
            let reply = &r.reply;
            if reply.starts_with("OK ") {
                ok += 1;
                // Served requests only: a reject returns in µs and would
                // drag the percentiles below what any served request saw.
                latencies_us.push(r.latency_us);
                // Warm-cache hits identify themselves as engine=cache;
                // split them out so the hit path's client-side latency
                // is visible next to the executed (miss) path's.
                if reply.contains(" engine=cache ") {
                    cache_hits += 1;
                    hit_latencies_us.push(r.latency_us);
                } else {
                    miss_latencies_us.push(r.latency_us);
                }
                let want = &expected[c][k];
                if !reply.contains(want.as_str()) {
                    problems.push(format!("client {c} req {k}: got {reply:?}, want {want}"));
                }
            } else {
                // Tally through the same taxonomy the retry loop used:
                // the two retriable rejects are load signals (expected
                // under overload, never a protocol failure); every other
                // code — and anything unclassifiable — is a problem.
                match ErrCode::classify(reply) {
                    Some(ErrCode::Busy) => busy += 1,
                    Some(ErrCode::Overloaded) => shed += 1,
                    _ => problems.push(format!("client {c} req {k}: unexpected reply {reply:?}")),
                }
            }
        }
    }
    if !problems.is_empty() {
        bail!("loadgen: {} checksum/protocol failures:\n{}", problems.len(), problems.join("\n"));
    }

    let mut text = format!(
        "loadgen: {clients} clients x {reqs} reqs -> {ok} ok, {busy} busy, {shed} shed, 0 mismatches\n"
    );
    // Goodput vs offered load: how much of the offered request stream
    // was eventually served, and what the retry budget spent getting
    // there. Without retries this collapses to ok/offered, making
    // shed-heavy runs' real service rate explicit instead of burying
    // sheds next to an `ok` total that looks healthy.
    let offered = clients * reqs;
    text.push_str(&format!(
        "offered={} goodput={} ({:.1}%) retries={} (budget {}/req, backoff {}µs)\n",
        offered,
        ok,
        100.0 * ok as f64 / offered as f64,
        total_retries,
        retries,
        backoff_us,
    ));
    // The realized Zipf draw, so a skewed run documents its own
    // imbalance (and a rerun can be eyeballed against it).
    if let Some(s) = skew {
        let mut counts = vec![0usize; LOADGEN_SHAPES.len()];
        for per in &shape_plan {
            for &i in per {
                counts[i] += 1;
            }
        }
        let mix: Vec<String> = LOADGEN_SHAPES
            .iter()
            .zip(&counts)
            .map(|((cmd, n), count)| format!("{}/{n}={count}", cmd.to_lowercase()))
            .collect();
        text.push_str(&format!("skew={s} shape mix: {}\n", mix.join(" ")));
    }
    // Exact percentiles of *client-observed* latency (request write →
    // reply read: queue wait + service + wire) over served (OK) requests.
    // Not the same quantity as the server's STATS queue-wait digests —
    // those isolate the wait component — but an upper envelope on them,
    // and exact: loadgen keeps every sample.
    let percentile_line = |lat: &mut Vec<f64>, label: &str| -> String {
        lat.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        format!(
            "{label} (µs): p50={:.1} p90={:.1} p99={:.1} max={:.1} (n={})\n",
            crate::stats::percentile_sorted(lat, 50.0),
            crate::stats::percentile_sorted(lat, 90.0),
            crate::stats::percentile_sorted(lat, 99.0),
            lat[lat.len() - 1],
            lat.len(),
        )
    };
    if !latencies_us.is_empty() {
        text.push_str(&percentile_line(&mut latencies_us, "client latency, served reqs"));
    }
    if open_conns > 0 {
        text.push_str(&format!(
            "open-conns: held={} idle connections through the run{}\n",
            held.len(),
            if drain { " and drain" } else { "" },
        ));
        match &reactor_trailer {
            Some(t) => text.push_str(&format!("open-conns: server {t}\n")),
            None => text.push_str("open-conns: server io=threads (no reactor table)\n"),
        }
    }
    // Hit-path vs miss-path split, once any reply came from the warm
    // cache: the lower hit p50 is the managed-away redundant work,
    // measured where it matters — at the client.
    if cache_hits > 0 {
        text.push_str(&percentile_line(&mut hit_latencies_us, "cache hit-path latency"));
        if !miss_latencies_us.is_empty() {
            text.push_str(&percentile_line(&mut miss_latencies_us, "cache miss-path latency"));
        }
    }
    if drain {
        let stream = std::net::TcpStream::connect(addr.as_str())?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut conn = stream;
        writeln!(conn, "DRAIN")?;
        conn.flush()?;
        let mut block = String::new();
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                bail!("loadgen: server closed mid-DRAIN:\n{block}");
            }
            if line.trim() == "." {
                break;
            }
            block.push_str(&line);
        }
        if !block.starts_with("DRAINED") {
            bail!("loadgen: unexpected DRAIN response:\n{block}");
        }
        // Post-drain admission must answer ERR DRAINING, not BUSY/OK.
        writeln!(conn, "SORT 100 1")?;
        conn.flush()?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        if !line.starts_with("ERR DRAINING") {
            bail!("loadgen: post-drain request answered {:?}, want ERR DRAINING", line.trim());
        }
        writeln!(conn, "QUIT")?;
        conn.flush()?;
        if let Some(path) = &out_path {
            std::fs::write(path, &block)
                .with_context(|| format!("writing STATS to {path}"))?;
            text.push_str(&format!("drain: clean (final STATS written to {path})\n"));
        } else {
            text.push_str("drain: clean\n");
        }
    }
    Ok(text)
}

/// Requests each chaos-matrix cell drives through its server. Small and
/// sequential on purpose: every fault trigger below is an `@N` one-shot
/// keyed to a deterministic opportunity count, and a sequential trace
/// keeps those counts reproducible run over run.
const CHAOS_REQS: usize = 12;

/// One matrix cell's client-side accounting. Every offered request ends
/// in exactly one bucket, so `ok + errs + drops == CHAOS_REQS` is a
/// checkable conservation law per cell.
struct ChaosOutcome {
    /// `OK` replies (each verified bit-identical to the serial engine).
    ok: usize,
    /// Classified fatal `ERR` replies (DRAINING / FAULT), plus retriable
    /// rejects that exhausted the retry budget.
    errs: usize,
    /// Replies lost to an injected wedge or drop: EOF or a half-written
    /// line. The request may have executed server-side, so these are
    /// never re-sent (exactly-once from the client's side).
    drops: usize,
    /// Total injections the server's DRAIN block reported.
    injected: u64,
}

/// The chaos/conformance scenario matrix (`ohm chaos --matrix`): sweep
/// every fault kind across a minimal and a fully-featured server config
/// (plus two no-fault baseline cells), and assert in every cell that the
/// serving stack's standing invariants hold *under* the injected fault:
///
/// - **admitted == finished** in the drained trailer (nothing admitted
///   is ever lost, even when a dispatcher is killed mid-flight);
/// - **checksum bit-identity**: every `OK` reply matches the serial
///   reference engine exactly;
/// - **exactly-once**: dropped/wedged replies are counted, not re-sent,
///   and the drain accounting must still close;
/// - **bounded exit**: the server thread ends within 30s of `DRAIN`;
/// - **no regime-mixed telemetry**: lane tables are uniformly
///   epoch-titled or uniformly not.
///
/// Determinism: the fault schedule, workload seeds, and request order
/// all derive from `--seed` (default 42), so a cell's verdict is
/// reproducible. `--out FILE` saves the per-cell report that CI uploads.
fn cmd_chaos(args: &Args) -> Result<String> {
    if !args.has("matrix") {
        bail!("chaos needs --matrix (the fault × feature scenario sweep; see docs/CHAOS.md)");
    }
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(42);
    let out_path = args.get("out").map(|s| s.to_string());

    // Per-kind one-shot triggers, staggered so each fault lands mid-trace
    // at a different point. `@N` counts *opportunities* (see faults.rs),
    // which a sequential trace makes deterministic: dispatcher loop
    // entries for kill-lane/stall, reply lines for wedge/drop, cache
    // miss-leaderships for abort-flight, stolen batches for delay-steal.
    const FAULT_CELLS: &[(&str, &str)] = &[
        ("kill-lane", "@4"),
        ("wedge-client", "@3"),
        ("stall-dispatcher", "@2"),
        ("drop-reply", "@5"),
        ("abort-flight", "@2"),
        ("delay-steal", "@1"),
    ];

    // The two feature sets every fault is crossed with. `base` is the
    // serving layer with every optional subsystem off; `full` turns on
    // the warm cache, adaptive rebalancing, the cost model, and adaptive
    // admission (SLO set sky-high so the governor never sheds — the
    // matrix tests fault handling, not overload handling).
    let base = CoordinatorCfg {
        threads: 1,
        serve_threads: 2,
        lanes: 2,
        steal: true,
        ..Default::default()
    };
    let full = CoordinatorCfg {
        threads: 1,
        serve_threads: 2,
        lanes: 4,
        steal: true,
        cache: true,
        cache_entries: 64,
        cache_bytes: 1 << 20,
        rebalance: crate::coordinator::RebalanceMode::Adaptive,
        rebalance_window_ms: 50,
        cost_model: true,
        admission: crate::coordinator::AdmissionMode::Adaptive,
        slo_p90_us: 1e9,
        ..Default::default()
    };
    let feature_sets = [("base", base), ("full", full)];

    let mut cells: Vec<(String, String, CoordinatorCfg)> = Vec::new();
    for (fname, cfg) in &feature_sets {
        cells.push(("none".to_string(), fname.to_string(), cfg.clone()));
    }
    for (kind, trigger) in FAULT_CELLS {
        for (fname, cfg) in &feature_sets {
            let mut armed = cfg.clone();
            armed.faults = format!("seed={seed},{kind}={trigger}");
            cells.push((kind.to_string(), fname.to_string(), armed));
        }
    }

    let mut report =
        format!("chaos matrix: {} cells x {CHAOS_REQS} reqs, seed {seed}\n", cells.len());
    let mut green = 0usize;
    for (i, (fault, features, cfg)) in cells.iter().enumerate() {
        // Distinct workload seeds per cell so a cross-cell cache or
        // batching artifact can't mask a divergence.
        let wseed = seed.wrapping_mul(10_000).wrapping_add(i as u64 * 100);
        match chaos_cell(cfg, wseed) {
            Ok(o) => {
                green += 1;
                writeln!(
                    report,
                    "cell {i:>2} fault={fault:<16} features={features:<4} ok={:<2} err={:<2} drop={:<2} injected={} verdict=PASS",
                    o.ok, o.errs, o.drops, o.injected
                )
                .unwrap();
            }
            Err(e) => {
                writeln!(
                    report,
                    "cell {i:>2} fault={fault:<16} features={features:<4} verdict=FAIL ({e:#})"
                )
                .unwrap();
            }
        }
    }
    writeln!(report, "chaos matrix: {green}/{} cells green (seed {seed})", cells.len()).unwrap();
    // Write the report before deciding pass/fail: a red matrix must
    // still leave the per-cell evidence on disk for the CI artifact.
    if let Some(path) = &out_path {
        std::fs::write(path, &report)
            .with_context(|| format!("writing chaos report to {path}"))?;
    }
    if green < cells.len() {
        bail!("chaos matrix: {} cells failed\n{report}", cells.len() - green);
    }
    Ok(report)
}

/// One matrix cell: boot an in-process server under `cfg`, drive
/// `CHAOS_REQS` sequential requests (a fresh connection per request, so
/// a wedged or dropped reply poisons only its own connection), then
/// `DRAIN` and check every invariant. Returns the cell's accounting on
/// success; any violated invariant is an `Err` carrying the evidence.
fn chaos_cell(cfg: &CoordinatorCfg, wseed: u64) -> Result<ChaosOutcome> {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::time::Duration;

    // The bit-identity oracle: the serial engine's checksum for every
    // request in the trace, computed before the server exists.
    let mut reference =
        Coordinator::new(CoordinatorCfg { threads: 1, ..Default::default() }, None);
    let expected: Vec<String> = (0..CHAOS_REQS)
        .map(|k| {
            let (cmd, n) = LOADGEN_SHAPES[k % LOADGEN_SHAPES.len()];
            let kind = if cmd == "MATMUL" { TraceKind::Matmul { n } } else { TraceKind::Sort { n } };
            let r = reference.submit(kind, wseed.wrapping_add(k as u64));
            format!("checksum={:.4}", r.checksum)
        })
        .collect();

    let server = crate::coordinator::server::Server::bind("127.0.0.1:0")?;
    let addr = server.local_addr();
    let serve_cfg = cfg.clone();
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        let result = server.serve(serve_cfg, None);
        let _ = done_tx.send(result);
    });

    let mut ok = 0usize;
    let mut errs = 0usize;
    let mut drops = 0usize;
    let mut drained_block = String::new();
    let drive = (|| -> Result<()> {
        for k in 0..CHAOS_REQS {
            let (cmd, n) = LOADGEN_SHAPES[k % LOADGEN_SHAPES.len()];
            let rseed = wseed.wrapping_add(k as u64);
            let mut attempts = 0usize;
            loop {
                let conn = TcpStream::connect(addr)?;
                conn.set_read_timeout(Some(Duration::from_secs(10)))?;
                let mut out = conn.try_clone()?;
                let mut reader = BufReader::new(conn);
                writeln!(out, "{cmd} {n} {rseed}")?;
                out.flush()?;
                let mut line = String::new();
                let got = reader.read_line(&mut line)?;
                if got == 0 || !line.ends_with('\n') {
                    // EOF (drop-reply) or a half-written line then EOF
                    // (wedge-client). The request may well have executed
                    // server-side, so re-sending would break exactly-once
                    // — count the loss and move on.
                    drops += 1;
                    break;
                }
                let reply = line.trim();
                if reply.starts_with("OK ") {
                    if !reply.contains(expected[k].as_str()) {
                        bail!(
                            "req {k}: checksum divergence: got {reply:?}, want {}",
                            expected[k]
                        );
                    }
                    ok += 1;
                    break;
                }
                match ErrCode::classify(reply) {
                    // Retriable rejects were never executed, so a re-send
                    // is safe; past the budget they count as errors.
                    Some(code) if code.retriable() && attempts < 3 => {
                        attempts += 1;
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Some(_) => {
                        errs += 1;
                        break;
                    }
                    None => bail!("req {k}: reply outside the error taxonomy: {reply:?}"),
                }
            }
        }

        // DRAIN on a fresh connection; its block carries the trailer and
        // telemetry every remaining invariant is read from.
        let conn = TcpStream::connect(addr)?;
        conn.set_read_timeout(Some(Duration::from_secs(20)))?;
        let mut out = conn.try_clone()?;
        let mut reader = BufReader::new(conn);
        writeln!(out, "DRAIN")?;
        out.flush()?;
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                bail!("server closed mid-DRAIN:\n{drained_block}");
            }
            if line.trim() == "." {
                break;
            }
            drained_block.push_str(&line);
        }
        if !drained_block.starts_with("DRAINED") {
            bail!("unexpected DRAIN response:\n{drained_block}");
        }

        // Invariant: nothing admitted was lost.
        let trailer = drained_block
            .lines()
            .find(|l| l.starts_with("drained: admitted="))
            .context("DRAIN block has no drained trailer")?;
        let counts: Vec<u64> = trailer
            .split(|ch: char| !ch.is_ascii_digit())
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().expect("digit runs parse"))
            .collect();
        if counts.len() != 2 || counts[0] != counts[1] {
            bail!("admitted != finished: {trailer:?}");
        }

        // Invariant: no regime-mixed telemetry — the lane tables in one
        // STATS snapshot are either all epoch-titled or all plain.
        let lane_titles: Vec<&str> =
            drained_block.lines().filter(|l| l.contains("dispatch lanes")).collect();
        let epoch_titled =
            lane_titles.iter().filter(|l| l.contains("dispatch lanes (epoch")).count();
        if epoch_titled != 0 && epoch_titled != lane_titles.len() {
            bail!("regime-mixed lane telemetry:\n{drained_block}");
        }

        // Invariant: the client-side accounting closes.
        if ok + errs + drops != CHAOS_REQS {
            bail!("accounting leak: ok={ok} errs={errs} drops={drops} != {CHAOS_REQS} offered");
        }
        Ok(())
    })();

    // If the drive failed before its DRAIN, send one best-effort DRAIN so
    // the serve thread still exits and the bounded-exit check below can
    // report the *original* failure instead of hanging.
    if drive.is_err() {
        let _ = (|| -> Result<()> {
            let mut conn = TcpStream::connect(addr)?;
            writeln!(conn, "DRAIN")?;
            conn.flush()?;
            Ok(())
        })();
    }

    // Invariant: bounded exit — the serve thread must end shortly after
    // the drain, injected faults or not.
    let serve_result = done_rx.recv_timeout(Duration::from_secs(30));
    let _ = handle.join();
    drive?;
    match serve_result {
        Ok(Ok(())) => {}
        Ok(Err(e)) => bail!("serve() returned an error: {e:#}"),
        Err(_) => bail!("server did not exit within 30s of DRAIN"),
    }

    let injected = drained_block
        .lines()
        .find(|l| l.starts_with("faults: spec="))
        .and_then(|l| l.rsplit("injected=").next())
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0);
    Ok(ChaosOutcome { ok, errs, drops, injected })
}

/// Kernel perf trajectory: per-topic size sweep of serial vs best-grain
/// parallel with the priced overhead breakdown and the crossover size.
/// `--json` writes the `BENCH_<topic>.json` baselines the CI `bench-gate`
/// job compares against (schema and thresholds: docs/BENCH.md).
fn cmd_bench(args: &Args) -> Result<String> {
    use crate::bench::kernel::{self, Topic};
    let mode = args.get("mode").unwrap_or("virtual");
    if !matches!(mode, "virtual" | "wall") {
        bail!("flag --mode: unknown mode {mode:?} (virtual|wall)");
    }
    let cores = args.get_parsed::<usize>("cores")?.unwrap_or(4).max(1);
    let topics: Vec<Topic> = match args.get("topic").unwrap_or("all") {
        "matmul" => vec![Topic::Matmul],
        "sort" => vec![Topic::Sort],
        "all" => vec![Topic::Matmul, Topic::Sort],
        other => bail!("flag --topic: unknown topic {other:?} (matmul|sort|all)"),
    };
    let sizes_override: Option<Vec<usize>> = match args.get("sizes") {
        Some(s) => Some(
            s.split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .ok()
                        .filter(|&n| n > 0)
                        .with_context(|| format!("flag --sizes: bad size {t:?}"))
                })
                .collect::<Result<Vec<_>>>()?,
        ),
        None => None,
    };
    let params = OverheadParams::paper_2022();
    let mut text = String::new();
    for topic in topics {
        let sizes = sizes_override.clone().unwrap_or_else(|| topic.default_sizes());
        let doc = match mode {
            "virtual" => kernel::virtual_doc(topic, &sizes, cores, &params),
            _ => kernel::wall_doc(topic, &sizes, cores, &params),
        };
        if args.has("json") {
            let dir = Path::new(args.get("out").unwrap_or("."));
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating bench output dir {}", dir.display()))?;
            let path = dir.join(format!("BENCH_{}.json", topic.name()));
            std::fs::write(&path, doc.to_json())
                .with_context(|| format!("writing {}", path.display()))?;
            writeln!(text, "wrote {}", path.display()).unwrap();
        } else {
            use crate::report::{table::f, AsciiTable};
            let crossover = doc
                .crossover_n
                .map_or("none in sweep".to_string(), |n| format!("n={n}"));
            let mut table = AsciiTable::new(
                &format!(
                    "bench {} ({} mode, {cores} cores) — serial/parallel crossover: {crossover}",
                    topic.name(),
                    doc.mode
                ),
                &["n", "serial ms", "parallel ms", "tasks", "speedup", "overhead ms"],
            );
            for p in &doc.points {
                table.row(vec![
                    p.n.to_string(),
                    f(p.serial_ns / 1e6, 3),
                    f(p.parallel_ns / 1e6, 3),
                    p.tasks.to_string(),
                    format!("{:.2}x", p.speedup),
                    f(p.overhead.total_ns() / 1e6, 3),
                ]);
            }
            text.push_str(&table.render());
            text.push('\n');
        }
    }
    Ok(text)
}

fn cmd_calibrate(args: &Args) -> Result<String> {
    let budget = args.get_parsed::<u64>("budget-ms")?.unwrap_or(1000);
    let cal = Calibration::with_fallback(budget);
    Ok(format!(
        "calibration (probed={}):\n  α spawn  = {:>12.1} ns\n  β sync   = {:>12.1} ns\n  γ msg    = {:>12.1} ns\n  δ byte   = {:>12.4} ns\n  matmul op = {:>11.3} ns\n  sort op   = {:>11.3} ns\n",
        cal.probed,
        cal.params.alpha_spawn_ns,
        cal.params.beta_sync_ns,
        cal.params.gamma_msg_ns,
        cal.params.delta_byte_ns,
        cal.matmul_op_ns,
        cal.sort_op_ns,
    ))
}

fn cmd_gantt(args: &Args) -> Result<String> {
    let cores = args.get_parsed::<usize>("cores")?.unwrap_or(4);
    let ctx = ExecCtx::simulated(cores, OverheadParams::paper_2022()).with_trace(true);
    let render = |rep: &crate::exec::RunReport| {
        let mut out = gantt::render(&rep.timeline, cores, 100);
        // Quantitative Fig-1: where the machine time actually went.
        let sim_report = crate::sim::SimReport {
            makespan_ns: rep.virtual_ns.unwrap_or(0.0),
            serial_ns: rep.serial_equiv_ns.unwrap_or(0.0),
            ledger: rep.ledger,
            core_busy_ns: vec![0.0; cores],
            timeline: rep.timeline.clone(),
        };
        out.push_str(&crate::sim::Breakdown::of(&sim_report).summary());
        out.push('\n');
        out
    };
    if let Some(n) = args.get_parsed::<usize>("matmul")? {
        let a = matrices::uniform(n, n, 1);
        let b = matrices::uniform(n, n, 2);
        let (_, rep) = matmul::run(&a, &b, &ctx);
        return Ok(render(&rep));
    }
    if let Some(n) = args.get_parsed::<usize>("sort")? {
        let mut xs = arrays::uniform_i64(n, 1);
        let rep = parallel_quicksort(&mut xs, PivotStrategy::Mean, &ctx);
        return Ok(render(&rep));
    }
    bail!("gantt needs --matmul N or --sort N")
}

fn cmd_artifacts(args: &Args) -> Result<String> {
    let dir = args.get("dir").map(Path::new).map(Path::to_path_buf).unwrap_or_else(Runtime::default_dir);
    let rt = Runtime::load(&dir)?;
    let mut out = format!("artifact dir: {} (platform {})\n", dir.display(), rt.platform());
    for name in rt.names() {
        let spec = rt.manifest().get(name).unwrap();
        let ins: Vec<String> = spec
            .inputs
            .iter()
            .map(|t| format!("{}[{}]", t.dtype, t.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("×")))
            .collect();
        writeln!(out, "  {:<26} {} -> {:?}", name, ins.join(", "), spec.output.dims).unwrap();
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn call(argv: &[&str]) -> Result<String> {
        run(&argv.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn help_and_unknown() {
        assert!(call(&[]).unwrap().contains("usage"));
        assert!(call(&["bogus"]).is_err());
    }

    #[test]
    fn matmul_simulated() {
        let out = call(&["matmul", "--n", "64"]).unwrap();
        assert!(out.contains("matmul n=64"), "{out}");
        assert!(out.contains("virtual"));
    }

    #[test]
    fn sort_all_engines_cpu() {
        for engine in ["serial", "threaded", "simulated"] {
            let out = call(&["sort", "--n", "500", "--engine", engine, "--pivot", "left"]).unwrap();
            assert!(out.contains("sorted=true"), "{engine}: {out}");
        }
    }

    #[test]
    fn sort_rejects_bad_pivot() {
        assert!(call(&["sort", "--n", "10", "--pivot", "zzz"]).is_err());
    }

    #[test]
    fn gantt_renders() {
        let out = call(&["gantt", "--sort", "2000"]).unwrap();
        assert!(out.contains("core  0"), "{out}");
    }

    #[test]
    fn bench_virtual_table_reports_crossover() {
        let out = call(&["bench", "--topic", "matmul", "--cores", "4"]).unwrap();
        assert!(out.contains("crossover: n=64"), "{out}");
        assert!(out.contains("speedup"), "{out}");
    }

    #[test]
    fn bench_json_writes_baseline_files() {
        let dir = std::env::temp_dir().join("ohm-cli-bench");
        let out = call(&["bench", "--json", "--out", dir.to_str().unwrap()]).unwrap();
        assert!(out.contains("BENCH_matmul.json"), "{out}");
        assert!(out.contains("BENCH_sort.json"), "{out}");
        let j = std::fs::read_to_string(dir.join("BENCH_matmul.json")).unwrap();
        assert!(j.contains("\"schema\": \"ohm-bench/v1\""));
        assert!(j.contains("\"mode\": \"virtual\""));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_rejects_bad_flags() {
        assert!(call(&["bench", "--topic", "fft"]).is_err());
        assert!(call(&["bench", "--mode", "turbo"]).is_err());
        assert!(call(&["bench", "--sizes", "10,x"]).is_err());
        assert!(call(&["bench", "--sizes", "0"]).is_err());
    }

    #[test]
    fn calibrate_fast_budget() {
        let out = call(&["calibrate", "--budget-ms", "50"]).unwrap();
        assert!(out.contains("α spawn"));
    }

    #[test]
    fn serve_listen_rejects_malformed_flags_before_binding() {
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--queue-depth", "abc"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--serve-threads", "x"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--lanes", "x"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--steal", "maybe"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--admission", "turbo"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--slo-p90-us", "x"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--slo-p90-us", "-5"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--slo-p90-us", "NaN"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--admission-window-ms", "x"]).is_err());
    }

    #[test]
    fn serve_listen_rejects_degenerate_cache_flags() {
        // Zero/negative budgets and unknown modes are flag errors, not
        // silently-clamped degenerate caches.
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--cache", "maybe"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--cache-entries", "0"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--cache-entries", "-3"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--cache-entries", "x"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--cache-bytes", "0"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--cache-bytes", "-1"]).is_err());
    }

    #[test]
    fn serve_listen_rejects_bad_cost_model_flag() {
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--cost-model", "maybe"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--cost-model", "true"]).is_err());
    }

    #[test]
    fn serve_listen_rejects_bad_io_flags() {
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--io", "epoll"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--io", "Reactor"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--reactor-threads", "0"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--reactor-threads", "x"]).is_err());
    }

    #[test]
    fn loadgen_rejects_bad_open_conns() {
        assert!(call(&["loadgen", "--addr", "127.0.0.1:1", "--open-conns", "x"]).is_err());
    }

    #[test]
    fn serve_listen_rejects_malformed_routing_and_slo_flags() {
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--rebalance", "turbo"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--rebalance-window-ms", "x"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--slo", "matmul=100"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--slo", "tensor/2^6=100"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--slo", "matmul/2^6"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--slo", "matmul/2^6=abc"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--slo", "matmul/2^6=-5"]).is_err());
        assert!(call(&[
            "serve", "--listen", "127.0.0.1:0", "--slo", "matmul/2^6=100,sort/2^9=",
        ])
        .is_err());
    }

    #[test]
    fn loadgen_requires_addr() {
        assert!(call(&["loadgen"]).is_err());
    }

    #[test]
    fn loadgen_rejects_bad_skew() {
        assert!(call(&["loadgen", "--addr", "127.0.0.1:1", "--skew", "abc"]).is_err());
        assert!(call(&["loadgen", "--addr", "127.0.0.1:1", "--skew", "-1.0"]).is_err());
        assert!(call(&["loadgen", "--addr", "127.0.0.1:1", "--skew", "NaN"]).is_err());
    }

    #[test]
    fn loadgen_skewed_trace_verifies_against_live_server() {
        let server = crate::coordinator::server::Server::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let h = std::thread::spawn(move || {
            server.serve(CoordinatorCfg { threads: 1, ..Default::default() }, None).unwrap();
        });
        // A strongly skewed mix still checksum-verifies every reply:
        // the reference coordinator replays the identical Zipf draw.
        let out = call(&[
            "loadgen", "--addr", &addr, "--clients", "3", "--reqs", "5", "--skew", "1.2",
            "--drain",
        ])
        .unwrap();
        h.join().unwrap();
        assert!(out.contains("15 ok, 0 busy, 0 shed, 0 mismatches"), "{out}");
        assert!(out.contains("skew=1.2 shape mix: "), "{out}");
        assert!(out.contains("matmul/24="), "{out}");
        assert!(out.contains("drain: clean"), "{out}");
    }

    #[test]
    fn loadgen_drives_live_server_and_drains_it() {
        let server = crate::coordinator::server::Server::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        // No max_conns: only the DRAIN protocol can end this serve call,
        // so a clean join proves the rolling-restart exit path.
        let h = std::thread::spawn(move || {
            server
                .serve(CoordinatorCfg { threads: 1, ..Default::default() }, None)
                .unwrap();
        });
        let stats_path = std::env::temp_dir().join("ohm-cli-loadgen-stats.txt");
        let out = call(&[
            "loadgen",
            "--addr",
            &addr,
            "--clients",
            "3",
            "--reqs",
            "2",
            "--drain",
            "--out",
            stats_path.to_str().unwrap(),
        ])
        .unwrap();
        h.join().unwrap();
        assert!(out.contains("6 ok, 0 busy, 0 shed, 0 mismatches"), "{out}");
        assert!(out.contains("client latency, served reqs (µs): p50="), "{out}");
        assert!(out.contains("p99="), "{out}");
        assert!(out.contains("drain: clean"), "{out}");
        let stats = std::fs::read_to_string(&stats_path).unwrap();
        assert!(stats.starts_with("DRAINED"), "{stats}");
        assert!(stats.contains("dispatch lanes"), "per-lane table in final STATS:\n{stats}");
        std::fs::remove_file(&stats_path).ok();
    }

    #[test]
    fn loadgen_repeat_seeds_against_cached_server_reports_hit_path() {
        let server = crate::coordinator::server::Server::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let cfg = CoordinatorCfg { threads: 1, cache: true, ..Default::default() };
        let h = std::thread::spawn(move || {
            server.serve(cfg, None).unwrap();
        });
        // Repeated seeds: one seed per shape, so after each shape's cold
        // execution every further request is a warm hit (or a coalesced
        // single-flight follower — also a hit).
        let out = call(&[
            "loadgen",
            "--addr",
            &addr,
            "--clients",
            "4",
            "--reqs",
            "4",
            "--repeat-seeds",
            "--retries",
            "2",
            "--backoff-us",
            "200",
            "--drain",
        ])
        .unwrap();
        h.join().unwrap();
        assert!(out.contains("16 ok, 0 busy, 0 shed, 0 mismatches"), "{out}");
        assert!(out.contains("offered=16 goodput=16 (100.0%)"), "{out}");
        assert!(out.contains("cache hit-path latency (µs): p50="), "{out}");
        assert!(out.contains("cache miss-path latency (µs): p50="), "{out}");
        assert!(out.contains("drain: clean"), "{out}");
    }

    #[test]
    fn serve_listen_rejects_bad_fault_specs_before_binding() {
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--faults", "nuke-it=@1"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--faults", "kill-lane=@0"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--faults", "kill-lane"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--faults", "drop-reply=1.5"]).is_err());
        assert!(call(&["serve", "--listen", "127.0.0.1:0", "--faults", "seed=7"]).is_err());
    }

    #[test]
    fn chaos_requires_matrix_and_a_parsable_seed() {
        assert!(call(&["chaos"]).is_err());
        assert!(call(&["chaos", "--matrix", "--seed", "x"]).is_err());
    }

    #[test]
    fn experiment_single_to_tmpdir() {
        let dir = std::env::temp_dir().join("ohm-cli-exp");
        let out = call(&["experiment", "table1", "--out-dir", dir.to_str().unwrap()]).unwrap();
        assert!(out.contains("Table 1"));
        assert!(dir.join("table1.txt").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
