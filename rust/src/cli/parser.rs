//! Minimal argv parser: positionals + `--flag [value]` pairs.
//!
//! A flag followed by another flag (or end of argv) is boolean
//! (`--no-xla`); otherwise it takes the next token as its value.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    positionals: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut i = 0usize;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` not supported");
                }
                let takes_value = i + 1 < argv.len() && !argv[i + 1].starts_with("--");
                if takes_value {
                    a.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    a.flags.insert(name.to_string(), String::new());
                    i += 1;
                }
            } else {
                a.positionals.push(tok.clone());
                i += 1;
            }
        }
        Ok(a)
    }

    /// The subcommand (first positional).
    pub fn command(&self) -> Option<&str> {
        self.positional(0)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    /// Raw flag value (empty string for boolean flags).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Boolean flag presence.
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Parse a typed flag value; `None` when absent, error when malformed.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(t) => Ok(Some(t)),
                Err(_) => bail!("flag --{name}: cannot parse {v:?}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(&toks.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse(&["sort", "--n", "100", "--no-xla", "--pivot", "mean"]);
        assert_eq!(a.command(), Some("sort"));
        assert_eq!(a.get("n"), Some("100"));
        assert!(a.has("no-xla"));
        assert_eq!(a.get("pivot"), Some("mean"));
        assert_eq!(a.get("absent"), None);
    }

    #[test]
    fn typed_parsing() {
        let a = parse(&["x", "--n", "42", "--bad", "abc"]);
        assert_eq!(a.get_parsed::<usize>("n").unwrap(), Some(42));
        assert_eq!(a.get_parsed::<usize>("missing").unwrap(), None);
        assert!(a.get_parsed::<usize>("bad").is_err());
    }

    #[test]
    fn boolean_flag_at_end() {
        let a = parse(&["serve", "--no-xla"]);
        assert!(a.has("no-xla"));
    }

    #[test]
    fn multiple_positionals() {
        let a = parse(&["experiment", "fig2", "--reps", "2"]);
        assert_eq!(a.positional(1), Some("fig2"));
    }
}
