//! Experiment configuration: a TOML-subset parser (offline `serde`/`toml`
//! substitute) plus the typed [`ExperimentConfig`] the launcher consumes.
//!
//! Supported syntax: `[section]` headers, `key = value` with integers,
//! floats, booleans, quoted strings, and flat arrays of those; `#`
//! comments. That subset covers every config this repo ships.

use crate::coordinator::{AdmissionMode, RebalanceMode, ShapeClass};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize_array(&self) -> Option<Vec<usize>> {
        match self {
            Value::Array(xs) => xs.iter().map(|v| v.as_usize()).collect(),
            _ => None,
        }
    }
}

fn parse_scalar(s: &str) -> Result<Value> {
    let s = s.trim();
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("unparseable value {s:?}")
}

fn parse_value(s: &str) -> Result<Value> {
    let s = s.trim();
    if let Some(stripped) = s.strip_prefix('[') {
        let inner = stripped.strip_suffix(']').context("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(parse_scalar)
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::Array(items));
    }
    parse_scalar(s)
}

/// Sections → keys → values.
pub type Table = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse TOML-subset text.
pub fn parse(text: &str) -> Result<Table> {
    let mut table: Table = BTreeMap::new();
    let mut section = String::new();
    table.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            // Only strip comments outside strings (strings in our configs
            // never contain '#'; documented subset).
            Some(i) => &raw[..i],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            let name = stripped
                .strip_suffix(']')
                .with_context(|| format!("line {}: bad section header", lineno + 1))?;
            section = name.trim().to_string();
            table.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let value = parse_value(v).with_context(|| format!("line {}", lineno + 1))?;
        table.get_mut(&section).unwrap().insert(k.trim().to_string(), value);
    }
    Ok(table)
}

/// Typed launcher config (defaults reproduce the paper's experiments).
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Virtual core count of the simulated machine.
    pub cores: usize,
    /// Matmul orders for Fig 2.
    pub matmul_orders: Vec<usize>,
    /// Element counts for Table 3 / Fig 5.
    pub sort_sizes: Vec<usize>,
    /// Workload seed.
    pub seed: u64,
    /// Output directory for CSV/reports.
    pub out_dir: String,
    /// Repetitions per cell (averaged over seeds).
    pub reps: usize,
    /// Overhead parameter set: "paper_2022" | "ideal" | "calibrated".
    pub params_name: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            cores: 4,
            matmul_orders: vec![16, 32, 64, 128, 256, 512, 1000],
            sort_sizes: vec![1000, 1100, 1500, 2000],
            seed: 42,
            out_dir: "reports".to_string(),
            reps: 3,
            params_name: "paper_2022".to_string(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-subset file ([experiment] section); missing keys
    /// keep their defaults.
    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_table(&parse(&text)?)
    }

    pub fn from_table(t: &Table) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        if let Some(sec) = t.get("experiment") {
            if let Some(v) = sec.get("cores") {
                cfg.cores = v.as_usize().context("cores")?;
            }
            if let Some(v) = sec.get("matmul_orders") {
                cfg.matmul_orders = v.as_usize_array().context("matmul_orders")?;
            }
            if let Some(v) = sec.get("sort_sizes") {
                cfg.sort_sizes = v.as_usize_array().context("sort_sizes")?;
            }
            if let Some(v) = sec.get("seed") {
                cfg.seed = v.as_usize().context("seed")? as u64;
            }
            if let Some(v) = sec.get("out_dir") {
                cfg.out_dir = v.as_str().context("out_dir")?.to_string();
            }
            if let Some(v) = sec.get("reps") {
                cfg.reps = v.as_usize().context("reps")?.max(1);
            }
            if let Some(v) = sec.get("params") {
                cfg.params_name = v.as_str().context("params")?.to_string();
            }
        }
        Ok(cfg)
    }

    /// Resolve the overhead parameter set by name.
    pub fn params(&self) -> crate::overhead::OverheadParams {
        match self.params_name.as_str() {
            "ideal" => crate::overhead::OverheadParams::ideal(),
            "calibrated" => crate::overhead::calibrate::Calibration::with_fallback(500).params,
            _ => crate::overhead::OverheadParams::paper_2022(),
        }
    }
}

/// Serving-layer configuration (`[serving]` + `[lanes]` + `[admission]`
/// sections): the admission queues, reader pool, dispatch-lane sharding,
/// and SLO governor behind `ohm serve --listen`. Defaults mirror
/// [`CoordinatorCfg::default`](crate::coordinator::CoordinatorCfg).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    /// Connection-IO mode (`[serving] io = "threads"|"reactor"`):
    /// blocking reader threads (default) or the fixed epoll reactor
    /// pool. Replies are byte-identical either way.
    pub io: crate::coordinator::IoMode,
    /// Reactor event-loop threads (`[serving] reactor_threads = N`,
    /// reactor mode only); 0 = derive from available parallelism.
    pub reactor_threads: usize,
    /// Connection reader threads (threads mode only).
    pub serve_threads: usize,
    /// Per-lane admission-queue depth; requests past it answer `ERR BUSY`.
    pub queue_depth: usize,
    /// Maximum cross-connection shape-batch width.
    pub batch_max: usize,
    /// Batch-formation window after the first job of a batch, µs.
    pub batch_linger_us: u64,
    /// Dispatch lanes (`[lanes] lanes = N`): shape kinds partition the
    /// pool, size buckets hash within a kind's share.
    pub lanes: usize,
    /// Work-stealing fallback for idle lanes (`[lanes] steal = bool`).
    pub steal: bool,
    /// Admission mode (`[admission] mode = "fixed"|"adaptive"`): depth
    /// bound only, or the SLO governor on top of it.
    pub admission: AdmissionMode,
    /// p90 queue-wait SLO the adaptive governor defends, µs
    /// (`[admission] slo_p90_us = N`).
    pub slo_p90_us: f64,
    /// Per-shape-class SLO overrides (`[admission.slo]` section: one
    /// `matmul/2^6 = 2500`-style entry per class), layered over
    /// `slo_p90_us`.
    pub slo_overrides: Vec<(ShapeClass, f64)>,
    /// Rolling half-window for the governor's queue-wait digests, ms
    /// (`[admission] window_ms = N`).
    pub admission_window_ms: u64,
    /// Routing-rebalance mode (`[rebalance] mode = "off"|"adaptive"`);
    /// off by default, which pins the epoch-0 seed routing table.
    pub rebalance: RebalanceMode,
    /// Rebalancer decision window, ms (`[rebalance] window_ms = N`).
    pub rebalance_window_ms: u64,
    /// Warm result cache (`[cache] enabled = bool`); default off, which
    /// preserves pre-cache serving behaviour bit-for-bit.
    pub cache: bool,
    /// Global result-cache entry cap (`[cache] entries = N`, ≥ 1),
    /// split across the per-lane shards.
    pub cache_entries: usize,
    /// Global result-cache byte budget (`[cache] bytes = N`, ≥ 1),
    /// split across the per-lane shards.
    pub cache_bytes: u64,
    /// Cost-model-driven scheduling (`[costmodel] enabled = bool`):
    /// serial-inline dispatch below the predicted crossover, predictive
    /// admission, and cost-weighted rebalancing. Default off, which
    /// preserves pre-cost-model serving behaviour bit-for-bit.
    pub cost_model: bool,
    /// Fault-injection spec (`[faults] spec = "..."`), validated by
    /// [`FaultPlan::parse`](crate::coordinator::FaultPlan::parse).
    /// `"off"` by default, which disarms injection and preserves
    /// pre-harness serving behaviour bit-for-bit.
    pub faults: String,
}

impl Default for ServingConfig {
    /// Derived from [`CoordinatorCfg::default`](crate::coordinator::CoordinatorCfg)
    /// so the serving defaults live in exactly one place.
    fn default() -> Self {
        let c = crate::coordinator::CoordinatorCfg::default();
        ServingConfig {
            io: c.io,
            reactor_threads: c.reactor_threads,
            serve_threads: c.serve_threads,
            queue_depth: c.queue_depth,
            batch_max: c.batch_max,
            batch_linger_us: c.batch_linger_us,
            lanes: c.lanes,
            steal: c.steal,
            admission: c.admission,
            slo_p90_us: c.slo_p90_us,
            slo_overrides: c.slo_overrides,
            admission_window_ms: c.admission_window_ms,
            rebalance: c.rebalance,
            rebalance_window_ms: c.rebalance_window_ms,
            cache: c.cache,
            cache_entries: c.cache_entries,
            cache_bytes: c.cache_bytes,
            cost_model: c.cost_model,
            faults: c.faults,
        }
    }
}

impl ServingConfig {
    /// Load from a TOML-subset file ([serving] + [lanes] + [admission] +
    /// [cache] sections); missing keys keep their defaults.
    pub fn load(path: &Path) -> Result<ServingConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_table(&parse(&text)?)
    }

    pub fn from_table(t: &Table) -> Result<ServingConfig> {
        let mut cfg = ServingConfig::default();
        if let Some(sec) = t.get("serving") {
            if let Some(v) = sec.get("io") {
                let name = v.as_str().context("io")?;
                cfg.io = crate::coordinator::IoMode::parse(name)
                    .with_context(|| format!("unknown io mode {name:?} (threads|reactor)"))?;
            }
            if let Some(v) = sec.get("reactor_threads") {
                // 0 is not a valid explicit setting (it is the internal
                // "derive from parallelism" sentinel); omit the key for
                // that behaviour.
                let n = v.as_usize().context("reactor_threads")?;
                if n == 0 {
                    bail!("reactor_threads must be ≥ 1 (omit the key to derive from available parallelism)");
                }
                cfg.reactor_threads = n;
            }
            if let Some(v) = sec.get("serve_threads") {
                cfg.serve_threads = v.as_usize().context("serve_threads")?.max(1);
            }
            if let Some(v) = sec.get("queue_depth") {
                cfg.queue_depth = v.as_usize().context("queue_depth")?.max(1);
            }
            if let Some(v) = sec.get("batch_max") {
                cfg.batch_max = v.as_usize().context("batch_max")?.max(1);
            }
            if let Some(v) = sec.get("batch_linger_us") {
                cfg.batch_linger_us = v.as_usize().context("batch_linger_us")? as u64;
            }
        }
        if let Some(sec) = t.get("lanes") {
            if let Some(v) = sec.get("lanes") {
                cfg.lanes = v.as_usize().context("lanes")?.max(1);
            }
            if let Some(v) = sec.get("steal") {
                cfg.steal = v.as_bool().context("steal")?;
            }
        }
        if let Some(sec) = t.get("admission") {
            if let Some(v) = sec.get("mode") {
                let name = v.as_str().context("mode")?;
                cfg.admission = AdmissionMode::from_name(name)
                    .with_context(|| format!("unknown admission mode {name:?} (fixed|adaptive)"))?;
            }
            if let Some(v) = sec.get("slo_p90_us") {
                let slo = v.as_f64().context("slo_p90_us")?;
                // Reject rather than clamp: a negative/NaN SLO forced to
                // 0 means "shed everything" — fail fast instead.
                if !slo.is_finite() || slo < 0.0 {
                    bail!("slo_p90_us must be a finite value ≥ 0, got {slo:?}");
                }
                cfg.slo_p90_us = slo;
            }
            if let Some(v) = sec.get("window_ms") {
                cfg.admission_window_ms = v.as_usize().context("window_ms")?.max(1) as u64;
            }
        }
        if let Some(sec) = t.get("admission.slo") {
            // Per-shape-class SLO table: `matmul/2^6 = 2500` (µs per
            // class-name key). Unknown class names and degenerate SLOs
            // are config errors, not silent skips — a typoed class
            // would otherwise silently keep the default budget.
            for (key, v) in sec {
                let class = ShapeClass::parse(key).with_context(|| {
                    format!("[admission.slo]: unknown shape class {key:?} (e.g. matmul/2^6)")
                })?;
                let slo = v.as_f64().with_context(|| format!("[admission.slo] {key}"))?;
                if !slo.is_finite() || slo < 0.0 {
                    bail!("[admission.slo] {key}: must be a finite value ≥ 0, got {slo:?}");
                }
                cfg.slo_overrides.push((class, slo));
            }
        }
        if let Some(sec) = t.get("rebalance") {
            if let Some(v) = sec.get("mode") {
                let name = v.as_str().context("rebalance mode")?;
                cfg.rebalance = RebalanceMode::from_name(name).with_context(|| {
                    format!("unknown rebalance mode {name:?} (off|adaptive)")
                })?;
            }
            if let Some(v) = sec.get("window_ms") {
                let ms = v.as_usize().context("rebalance window_ms")?;
                cfg.rebalance_window_ms = ms.max(1) as u64;
            }
        }
        if let Some(sec) = t.get("cache") {
            if let Some(v) = sec.get("enabled") {
                cfg.cache = v.as_bool().context("cache enabled")?;
            }
            // Reject degenerate budgets rather than clamp (mirrors the
            // SLO-flag rule): a zero/negative entry cap or byte budget
            // would construct a cache that can hold nothing while still
            // paying lookup and single-flight overhead on every request.
            if let Some(v) = sec.get("entries") {
                let entries = v
                    .as_usize()
                    .context("cache entries must be a positive integer")?;
                if entries == 0 {
                    bail!("cache entries must be ≥ 1, got 0 (a zero-capacity cache is degenerate; use enabled = false instead)");
                }
                cfg.cache_entries = entries;
            }
            if let Some(v) = sec.get("bytes") {
                let bytes = v
                    .as_usize()
                    .context("cache bytes must be a positive integer")?;
                if bytes == 0 {
                    bail!("cache bytes must be ≥ 1, got 0 (a zero-byte cache is degenerate; use enabled = false instead)");
                }
                cfg.cache_bytes = bytes as u64;
            }
        }
        if let Some(sec) = t.get("costmodel") {
            if let Some(v) = sec.get("enabled") {
                cfg.cost_model = v.as_bool().context("costmodel enabled")?;
            }
        }
        if let Some(sec) = t.get("faults") {
            if let Some(v) = sec.get("spec") {
                let spec = v.as_str().context("faults spec")?;
                // Validate eagerly: a typoed kind or trigger must fail at
                // load, not at server start.
                crate::coordinator::FaultPlan::parse(spec)
                    .with_context(|| format!("[faults] spec = {spec:?}"))?;
                cfg.faults = spec.to_string();
            }
        }
        Ok(cfg)
    }

    /// Copy the serving fields onto a coordinator configuration.
    pub fn apply(&self, cfg: &mut crate::coordinator::CoordinatorCfg) {
        cfg.io = self.io;
        cfg.reactor_threads = self.reactor_threads;
        cfg.serve_threads = self.serve_threads;
        cfg.queue_depth = self.queue_depth;
        cfg.batch_max = self.batch_max;
        cfg.batch_linger_us = self.batch_linger_us;
        cfg.lanes = self.lanes;
        cfg.steal = self.steal;
        cfg.admission = self.admission;
        cfg.slo_p90_us = self.slo_p90_us;
        cfg.slo_overrides = self.slo_overrides.clone();
        cfg.admission_window_ms = self.admission_window_ms;
        cfg.rebalance = self.rebalance;
        cfg.rebalance_window_ms = self.rebalance_window_ms;
        cfg.cache = self.cache;
        cfg.cache_entries = self.cache_entries;
        cfg.cache_bytes = self.cache_bytes;
        cfg.cost_model = self.cost_model;
        cfg.faults = self.faults.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_scalars_arrays() {
        let t = parse(
            r#"
# top comment
top = 1
[experiment]
cores = 8
seed = 7          # trailing comment
out_dir = "out/x"
matmul_orders = [16, 32]
ratio = 0.5
flag = true
"#,
        )
        .unwrap();
        assert_eq!(t[""]["top"], Value::Int(1));
        let e = &t["experiment"];
        assert_eq!(e["cores"].as_usize(), Some(8));
        assert_eq!(e["out_dir"].as_str(), Some("out/x"));
        assert_eq!(e["matmul_orders"].as_usize_array(), Some(vec![16, 32]));
        assert_eq!(e["ratio"].as_f64(), Some(0.5));
        assert_eq!(e["flag"].as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = [1, oops]").is_err());
        assert!(parse("k = \"unterminated").is_err());
    }

    #[test]
    fn config_defaults_and_overrides() {
        let d = ExperimentConfig::default();
        assert_eq!(d.cores, 4);
        assert_eq!(d.sort_sizes, vec![1000, 1100, 1500, 2000]);
        let t = parse("[experiment]\ncores = 16\nsort_sizes = [100, 200]\n").unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.cores, 16);
        assert_eq!(c.sort_sizes, vec![100, 200]);
        assert_eq!(c.matmul_orders, d.matmul_orders, "unset keys keep defaults");
    }

    #[test]
    fn serving_defaults_and_overrides() {
        let d = ServingConfig::default();
        assert_eq!((d.serve_threads, d.queue_depth, d.batch_max, d.batch_linger_us), (4, 64, 16, 0));
        assert_eq!((d.lanes, d.steal), (2, true));
        let t = parse("[serving]\nserve_threads = 8\nqueue_depth = 2\nbatch_linger_us = 500\n").unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert_eq!(c.serve_threads, 8);
        assert_eq!(c.queue_depth, 2);
        assert_eq!(c.batch_max, d.batch_max, "unset keys keep defaults");
        assert_eq!(c.batch_linger_us, 500);
        assert_eq!((c.lanes, c.steal), (d.lanes, d.steal), "unset [lanes] keeps defaults");
        let mut coord = crate::coordinator::CoordinatorCfg::default();
        c.apply(&mut coord);
        assert_eq!(coord.serve_threads, 8);
        assert_eq!(coord.queue_depth, 2);
        assert_eq!(coord.batch_linger_us, 500);
    }

    #[test]
    fn lanes_section_overrides_and_applies() {
        let t = parse("[lanes]\nlanes = 4\nsteal = false\n").unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert_eq!(c.lanes, 4);
        assert!(!c.steal);
        let mut coord = crate::coordinator::CoordinatorCfg::default();
        c.apply(&mut coord);
        assert_eq!(coord.lanes, 4);
        assert!(!coord.steal);
        // lanes = 0 clamps to the single-dispatcher degenerate case.
        let t = parse("[lanes]\nlanes = 0\n").unwrap();
        assert_eq!(ServingConfig::from_table(&t).unwrap().lanes, 1);
        // non-bool steal is a config error, not a silent default.
        let t = parse("[lanes]\nsteal = 3\n").unwrap();
        assert!(ServingConfig::from_table(&t).is_err());
    }

    #[test]
    fn serving_io_mode_overrides_and_applies() {
        let d = ServingConfig::default();
        assert_eq!(d.io, crate::coordinator::IoMode::Threads, "threads is the default edge");
        assert_eq!(d.reactor_threads, 0, "reactor pool size derives from parallelism");
        let t = parse("[serving]\nio = \"reactor\"\nreactor_threads = 3\n").unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert_eq!(c.io, crate::coordinator::IoMode::Reactor);
        assert_eq!(c.reactor_threads, 3);
        let mut coord = crate::coordinator::CoordinatorCfg::default();
        c.apply(&mut coord);
        assert_eq!(coord.io, crate::coordinator::IoMode::Reactor);
        assert_eq!(coord.reactor_threads, 3);
        // Unknown mode and the 0 sentinel are config errors, not
        // silent defaults.
        let t = parse("[serving]\nio = \"epoll\"\n").unwrap();
        assert!(ServingConfig::from_table(&t).is_err());
        let t = parse("[serving]\nreactor_threads = 0\n").unwrap();
        assert!(ServingConfig::from_table(&t).is_err());
    }

    #[test]
    fn serving_defaults_match_coordinator_cfg() {
        let s = ServingConfig::default();
        let c = crate::coordinator::CoordinatorCfg::default();
        assert_eq!((s.io, s.reactor_threads), (c.io, c.reactor_threads));
        assert_eq!(
            (s.serve_threads, s.queue_depth, s.batch_max, s.batch_linger_us, s.lanes, s.steal),
            (c.serve_threads, c.queue_depth, c.batch_max, c.batch_linger_us, c.lanes, c.steal),
        );
        assert_eq!(
            (s.admission, s.slo_p90_us, s.admission_window_ms),
            (c.admission, c.slo_p90_us, c.admission_window_ms),
        );
        assert_eq!(
            (s.cache, s.cache_entries, s.cache_bytes),
            (c.cache, c.cache_entries, c.cache_bytes),
        );
        assert!(!s.cache, "the result cache defaults to off");
        assert_eq!(s.cost_model, c.cost_model);
        assert!(!s.cost_model, "the cost model defaults to off");
        assert_eq!(s.faults, c.faults);
        assert_eq!(s.faults, "off", "fault injection defaults to off");
        assert_eq!(
            (s.rebalance, s.rebalance_window_ms, s.slo_overrides.clone()),
            (c.rebalance, c.rebalance_window_ms, c.slo_overrides.clone()),
        );
        assert_eq!(s.rebalance, RebalanceMode::Off, "rebalancing defaults to off");
        assert!(s.slo_overrides.is_empty(), "uniform SLO by default");
    }

    #[test]
    fn rebalance_section_overrides_and_applies() {
        let t = parse("[rebalance]\nmode = \"adaptive\"\nwindow_ms = 100\n").unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert_eq!(c.rebalance, RebalanceMode::Adaptive);
        assert_eq!(c.rebalance_window_ms, 100);
        let mut coord = crate::coordinator::CoordinatorCfg::default();
        c.apply(&mut coord);
        assert_eq!(coord.rebalance, RebalanceMode::Adaptive);
        assert_eq!(coord.rebalance_window_ms, 100);
        // Unset keys keep defaults; window 0 clamps to 1.
        let t = parse("[rebalance]\nwindow_ms = 0\n").unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert_eq!(c.rebalance, RebalanceMode::Off);
        assert_eq!(c.rebalance_window_ms, 1);
        // Unknown mode is a config error, not a silent default.
        let t = parse("[rebalance]\nmode = \"sometimes\"\n").unwrap();
        assert!(ServingConfig::from_table(&t).is_err());
    }

    #[test]
    fn admission_slo_section_parses_per_class_overrides() {
        let toml = "[admission]\nmode = \"adaptive\"\nslo_p90_us = 5000\n\
                    [admission.slo]\nmatmul/2^6 = 2500\nsort/2^9 = 800.5\n";
        let t = parse(toml).unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert_eq!(c.slo_p90_us, 5000.0);
        let names: Vec<(String, f64)> =
            c.slo_overrides.iter().map(|(cl, us)| (cl.name(), *us)).collect();
        assert_eq!(
            names,
            vec![("matmul/2^6".to_string(), 2500.0), ("sort/2^9".to_string(), 800.5)]
        );
        let mut coord = crate::coordinator::CoordinatorCfg::default();
        c.apply(&mut coord);
        assert_eq!(coord.slo_overrides.len(), 2);
        // Unknown class names and degenerate SLOs are config errors.
        for bad in [
            "[admission.slo]\nmatmul/9 = 100\n",
            "[admission.slo]\ntensor/2^6 = 100\n",
            "[admission.slo]\nsort/2^9 = -5\n",
            "[admission.slo]\nsort/2^9 = \"fast\"\n",
        ] {
            let t = parse(bad).unwrap();
            assert!(ServingConfig::from_table(&t).is_err(), "must reject {bad:?}");
        }
    }

    #[test]
    fn cache_section_overrides_and_applies() {
        let t = parse("[cache]\nenabled = true\nentries = 128\nbytes = 65536\n").unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert!(c.cache);
        assert_eq!(c.cache_entries, 128);
        assert_eq!(c.cache_bytes, 65_536);
        let mut coord = crate::coordinator::CoordinatorCfg::default();
        c.apply(&mut coord);
        assert!(coord.cache);
        assert_eq!(coord.cache_entries, 128);
        assert_eq!(coord.cache_bytes, 65_536);
        // Unset [cache] keys keep their defaults.
        let d = ServingConfig::default();
        let t = parse("[cache]\nenabled = true\n").unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert_eq!((c.cache_entries, c.cache_bytes), (d.cache_entries, d.cache_bytes));
    }

    #[test]
    fn cache_section_rejects_degenerate_budgets() {
        // Zero/negative budgets are config errors, not silently-clamped
        // degenerate caches — same policy as the SLO flag.
        for bad in [
            "[cache]\nentries = 0\n",
            "[cache]\nentries = -4\n",
            "[cache]\nbytes = 0\n",
            "[cache]\nbytes = -1024\n",
            "[cache]\nenabled = 1\n",
        ] {
            let t = parse(bad).unwrap();
            assert!(ServingConfig::from_table(&t).is_err(), "must reject {bad:?}");
        }
    }

    #[test]
    fn costmodel_section_overrides_and_applies() {
        let t = parse("[costmodel]\nenabled = true\n").unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert!(c.cost_model);
        let mut coord = crate::coordinator::CoordinatorCfg::default();
        c.apply(&mut coord);
        assert!(coord.cost_model);
        // Non-bool values are config errors, not silent defaults.
        let t = parse("[costmodel]\nenabled = 1\n").unwrap();
        assert!(ServingConfig::from_table(&t).is_err());
    }

    #[test]
    fn faults_section_overrides_and_applies() {
        let t = parse("[faults]\nspec = \"seed=7,kill-lane=@2,drop-reply=0.25\"\n").unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert_eq!(c.faults, "seed=7,kill-lane=@2,drop-reply=0.25");
        let mut coord = crate::coordinator::CoordinatorCfg::default();
        c.apply(&mut coord);
        assert_eq!(coord.faults, "seed=7,kill-lane=@2,drop-reply=0.25");
        // A bad spec is a config error at load, not at server start.
        for bad in [
            "[faults]\nspec = \"nuke-it=@1\"\n",
            "[faults]\nspec = \"kill-lane=@0\"\n",
            "[faults]\nspec = \"seed=42\"\n",
            "[faults]\nspec = 3\n",
        ] {
            let t = parse(bad).unwrap();
            assert!(ServingConfig::from_table(&t).is_err(), "must reject {bad:?}");
        }
        // "off" round-trips as the disarmed default.
        let t = parse("[faults]\nspec = \"off\"\n").unwrap();
        assert_eq!(ServingConfig::from_table(&t).unwrap().faults, "off");
    }

    #[test]
    fn admission_section_overrides_and_applies() {
        let d = ServingConfig::default();
        assert_eq!(d.admission, AdmissionMode::Fixed, "fixed is the compatible default");
        let t = parse("[admission]\nmode = \"adaptive\"\nslo_p90_us = 2500\nwindow_ms = 100\n")
            .unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert_eq!(c.admission, AdmissionMode::Adaptive);
        assert_eq!(c.slo_p90_us, 2500.0);
        assert_eq!(c.admission_window_ms, 100);
        let mut coord = crate::coordinator::CoordinatorCfg::default();
        c.apply(&mut coord);
        assert_eq!(coord.admission, AdmissionMode::Adaptive);
        assert_eq!(coord.slo_p90_us, 2500.0);
        assert_eq!(coord.admission_window_ms, 100);
        // Unset [admission] keys keep their defaults.
        let t = parse("[admission]\nmode = \"adaptive\"\n").unwrap();
        let c = ServingConfig::from_table(&t).unwrap();
        assert_eq!(c.slo_p90_us, d.slo_p90_us);
        assert_eq!(c.admission_window_ms, d.admission_window_ms);
        // An unknown mode is a config error, not a silent default.
        let t = parse("[admission]\nmode = \"turbo\"\n").unwrap();
        assert!(ServingConfig::from_table(&t).is_err());
        // A negative SLO is rejected, not clamped to shed-everything 0.
        let t = parse("[admission]\nslo_p90_us = -5\n").unwrap();
        assert!(ServingConfig::from_table(&t).is_err());
    }

    #[test]
    fn params_by_name() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.params(), crate::overhead::OverheadParams::paper_2022());
        c.params_name = "ideal".into();
        assert_eq!(c.params(), crate::overhead::OverheadParams::ideal());
    }
}
