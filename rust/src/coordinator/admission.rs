//! SLO-driven adaptive admission: the governor that turns observed
//! queue-wait percentiles back into admission decisions.
//!
//! PR 1 bounded queue wait *indirectly* with a fixed per-lane depth: the
//! operator guesses how many queued jobs correspond to an acceptable
//! wait. The paper's framing says scheduling overhead must be managed at
//! the root, and the root quantity here is the wait itself — so the
//! adaptive mode closes the loop:
//!
//! * every dispatched job's measured queue wait is folded into a
//!   **rolling window** of fixed-memory [`Digest`]s on the lane it was
//!   *admitted* to (two half-windows, rotated by time, so the estimate
//!   tracks the recent past and forgets idle history);
//! * admission consults the rolling p90: above the configured SLO the
//!   lane starts **shedding** — requests answer `ERR OVERLOADED
//!   p90=<µs> slo=<µs>` (a soft reject, distinct from the hard `ERR
//!   BUSY` depth bound, which stays as the structural backstop);
//! * shedding ends with **hysteresis**: the lane re-admits once the
//!   rolling p90 falls to [`RECOVERY_FRACTION`] of the SLO, or the
//!   window drains with the lane queue empty (a truly idle lane is
//!   never stuck shedding, while a *stalled* lane — empty window but
//!   work still queued — keeps shedding on its last evidence), so the
//!   controller cannot flap around the threshold.
//!
//! [`AdmissionMode::Fixed`] keeps the PR 1 behaviour bit-for-bit: the
//! governor admits unconditionally and records nothing (unless the
//! load-driven rebalancer is on — it feeds off the same windows, so
//! [`Governor::with_recording`] can keep them populated in fixed mode
//! without changing any admission decision).
//!
//! The SLO itself is a **per-shape-class table** ([`SloTable`]): one
//! default `slo_p90_us` plus optional per-class overrides
//! (`[admission.slo]` config / `--slo class=µs`), so a slow-matmul lane
//! and a fast-sort lane defend different budgets. The rolling windows
//! stay per-*lane* (that is where the queue is), while the threshold —
//! and the shed latch — are per-*class* of the incoming request.

use super::costmodel::ServeCostModel;
use super::lanes::ShapeClass;
use super::routing::{class_slot, CLASS_SLOTS};
use crate::stats::Digest;
use std::collections::HashSet;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Hysteresis: a shedding lane re-admits once its rolling p90 falls to
/// this fraction of the SLO (not merely below the SLO itself).
pub const RECOVERY_FRACTION: f64 = 0.8;

/// How requests are admitted to a lane queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Depth-bound only (`ERR BUSY` past `queue_depth`); the governor is
    /// inert. The PR 1 contract.
    Fixed,
    /// Depth bound plus the SLO feedback loop: shed (`ERR OVERLOADED`)
    /// while a lane's rolling p90 queue wait exceeds the SLO.
    Adaptive,
}

impl AdmissionMode {
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionMode::Fixed => "fixed",
            AdmissionMode::Adaptive => "adaptive",
        }
    }

    pub fn from_name(s: &str) -> Option<AdmissionMode> {
        match s {
            "fixed" => Some(AdmissionMode::Fixed),
            "adaptive" => Some(AdmissionMode::Adaptive),
            _ => None,
        }
    }
}

/// Per-shape-class p90 queue-wait SLOs: a uniform default plus sparse
/// per-class overrides. With no overrides every class shares the
/// default, which reproduces the single-SLO behaviour decision-for-
/// decision.
#[derive(Debug, Clone)]
pub struct SloTable {
    default_us: f64,
    per_class: Vec<Option<f64>>,
}

impl SloTable {
    /// Every class defends `default_us` (the `--slo-p90-us` value).
    pub fn uniform(default_us: f64) -> SloTable {
        SloTable { default_us, per_class: vec![None; CLASS_SLOTS] }
    }

    /// Override one class's SLO (config `[admission.slo]` / `--slo`).
    pub fn set(&mut self, class: ShapeClass, slo_us: f64) {
        self.per_class[class_slot(class)] = Some(slo_us);
    }

    /// The SLO a request of `class` is admitted against.
    pub fn slo_for(&self, class: ShapeClass) -> f64 {
        self.per_class[class_slot(class)].unwrap_or(self.default_us)
    }

    /// The uniform default (classes without an override).
    pub fn default_us(&self) -> f64 {
        self.default_us
    }

    /// The configured overrides, in class order.
    pub fn overrides(&self) -> Vec<(ShapeClass, f64)> {
        self.per_class
            .iter()
            .enumerate()
            .filter_map(|(slot, v)| v.map(|us| (super::routing::slot_class(slot), us)))
            .collect()
    }
}

/// Why a request was shed: the observed rolling p90 and the SLO it
/// exceeded, both in µs (the server renders these into the
/// `ERR OVERLOADED` reply).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Overload {
    /// The rolling p90 evidence behind the shed, µs. `None` when the
    /// shedding lane has never completed a job — a *stalled* cold start
    /// with no measurement to report. Rendering that absence as `0`
    /// would claim a perfect wait on a wedged lane, so the reply spells
    /// it out as `p90=stalled` instead (see `docs/PROTOCOL.md`).
    pub p90_us: Option<f64>,
    pub slo_us: f64,
}

impl Overload {
    /// The `p90=` value for the `ERR OVERLOADED` reply: the observed
    /// rolling p90 in whole µs, or the explicit `stalled` marker when
    /// no completion was ever measured.
    pub fn p90_evidence(&self) -> String {
        match self.p90_us {
            Some(p90) => format!("{p90:.0}"),
            None => "stalled".to_string(),
        }
    }
}

/// Per-lane rolling-window state. Two half-windows: quantiles are read
/// over `previous ∪ current`, so every estimate covers between one and
/// two window lengths of history and old samples age out in at most two
/// rotations.
#[derive(Debug)]
struct LaneWindow {
    current: Digest,
    previous: Digest,
    started: Instant,
    /// Shape classes currently latched into shedding on this lane. The
    /// window (and therefore the p90 evidence) is per-lane; the latch is
    /// per-class because each class defends its own SLO — with a uniform
    /// [`SloTable`] the observable decisions collapse to the old
    /// single-latch behaviour exactly.
    shedding: HashSet<ShapeClass>,
    /// Last rolling p90 computed from a non-empty window: the shed
    /// evidence reported while a *stalled* lane (empty window, jobs
    /// still queued) waits for fresh completions. `None` until the
    /// first estimate exists — a lane that has never completed a job
    /// has no evidence, and the cold-start shed must say so
    /// (`p90=stalled`) rather than fabricate a 0µs measurement.
    last_p90_us: Option<f64>,
}

impl LaneWindow {
    fn new() -> LaneWindow {
        LaneWindow {
            current: Digest::new(),
            previous: Digest::new(),
            started: Instant::now(),
            shedding: HashSet::new(),
            last_p90_us: None,
        }
    }

    /// Advance the window clock: after one window length the current
    /// half becomes the previous half; after two, both are stale and the
    /// estimate starts empty (idle lanes forget their history).
    fn rotate(&mut self, window: Duration) {
        let elapsed = self.started.elapsed();
        if elapsed >= window * 2 {
            self.current = Digest::new();
            self.previous = Digest::new();
            self.started = Instant::now();
        } else if elapsed >= window {
            self.previous = std::mem::take(&mut self.current);
            self.started = Instant::now();
        }
    }

    /// Rolling p90 over both half-windows (`None` when no recent waits).
    /// A zipped union walk — no digest copy on the admission hot path.
    fn rolling_p90(&self) -> Option<f64> {
        Digest::quantile_union(&self.current, &self.previous, 0.9)
    }
}

/// The admission governor: one rolling window per lane, shared between
/// the reader threads (admission checks) and the lane dispatchers
/// (queue-wait observations). All state is behind per-lane mutexes, so
/// admission on lane A never contends with dispatch on lane B.
pub struct Governor {
    mode: AdmissionMode,
    slo: SloTable,
    window: Duration,
    /// Record queue waits into the windows. On in adaptive mode; the
    /// rebalancer turns it on in fixed mode too
    /// ([`with_recording`](Governor::with_recording)) since its
    /// imbalance signal reads the same windows.
    record_waits: bool,
    /// Predictive admission (`--cost-model on` + adaptive mode): shed
    /// when the cost model's predicted queue wait (per-class service
    /// EWMA × queue depth) already exceeds the class SLO — *before* the
    /// measured p90 degrades. `None` keeps measured-only admission
    /// decision-for-decision.
    cost: Option<Arc<ServeCostModel>>,
    lanes: Vec<Mutex<LaneWindow>>,
}

impl std::fmt::Debug for Governor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Governor").finish_non_exhaustive()
    }
}

impl Governor {
    /// `window_ms` is the rolling half-window length (clamped ≥ 1 ms).
    pub fn new(mode: AdmissionMode, slo: SloTable, window_ms: u64, lanes: usize) -> Governor {
        Governor {
            mode,
            slo,
            window: Duration::from_millis(window_ms.max(1)),
            record_waits: mode == AdmissionMode::Adaptive,
            cost: None,
            lanes: (0..lanes.max(1)).map(|_| Mutex::new(LaneWindow::new())).collect(),
        }
    }

    /// Force queue-wait recording even in fixed mode (the rebalancer
    /// reads the windows; admission decisions are unaffected).
    pub fn with_recording(mut self, record: bool) -> Governor {
        self.record_waits = self.record_waits || record;
        self
    }

    /// Attach the serving cost model (`--cost-model on`): adaptive
    /// admission additionally sheds on *predicted* queue wait. Fixed
    /// mode is unaffected — it still admits unconditionally.
    pub fn with_cost_model(mut self, cost: Option<Arc<ServeCostModel>>) -> Governor {
        self.cost = cost;
        self
    }

    pub fn mode(&self) -> AdmissionMode {
        self.mode
    }

    /// The uniform default SLO (classes without an override).
    pub fn slo_p90_us(&self) -> f64 {
        self.slo.default_us()
    }

    /// The per-class SLO table admission checks against.
    pub fn slo_table(&self) -> &SloTable {
        &self.slo
    }

    /// Lock one lane's window, tolerating poison (advisory state only).
    fn lane(&self, lane: usize) -> std::sync::MutexGuard<'_, LaneWindow> {
        self.lanes[lane].lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Record one dispatched job's measured queue wait against the lane
    /// it was admitted to. No-op unless the windows have a consumer
    /// (adaptive admission and/or the rebalancer).
    pub fn observe(&self, lane: usize, queue_wait_us: f64) {
        if !self.record_waits {
            return;
        }
        let mut w = self.lane(lane);
        w.rotate(self.window);
        w.current.record(queue_wait_us);
    }

    /// Admission check for a request of `class` routed to `lane`. `Ok`
    /// admits; `Err` is a shed with the evidence for the
    /// `ERR OVERLOADED` reply. The rolling-p90 evidence is the lane's;
    /// the SLO it is held against — and the shed latch — are the
    /// class's.
    ///
    /// `queued` reports the lane's current queue length; it
    /// distinguishes *idle* from *stalled* when the rolling window is
    /// empty: a window can drain because the lane is quiet (recover) or
    /// because a long batch has dispatched nothing for two windows while
    /// work piles up behind it (keep shedding — waits are not observed
    /// to be low, they are simply not observed). Lazy because reading it
    /// takes the lane queue's mutex, and the common non-empty-window
    /// path must not pay that on every admission.
    pub fn admit(
        &self,
        lane: usize,
        class: ShapeClass,
        queued: impl FnOnce() -> usize,
    ) -> Result<(), Overload> {
        if self.mode == AdmissionMode::Fixed {
            return Ok(());
        }
        let slo_us = self.slo.slo_for(class);
        let mut w = self.lane(lane);
        w.rotate(self.window);
        let Some(p90) = w.rolling_p90() else {
            if !w.shedding.is_empty() && queued() > 0 {
                // Stalled, not idle: nothing completed for two windows
                // but the queue is still backed up — and a stall wedges
                // the whole lane, so any latched class holds the shed
                // for every class queued behind it. Report the last
                // evidence we had — or, on the cold-start corner where
                // the lane has *never* completed a job, the explicit
                // `stalled` marker (never a fabricated p90=0).
                return Err(Overload { p90_us: w.last_p90_us, slo_us });
            }
            // Truly idle (or never loaded): nothing to defend.
            w.shedding.clear();
            return Ok(());
        };
        w.last_p90_us = Some(p90);
        if w.shedding.contains(&class) {
            if p90 <= slo_us * RECOVERY_FRACTION {
                w.shedding.remove(&class);
                Ok(())
            } else {
                Err(Overload { p90_us: Some(p90), slo_us })
            }
        } else if p90 > slo_us
            || (!w.shedding.is_empty() && p90 > slo_us * RECOVERY_FRACTION)
        {
            // Either this class's own SLO is blown, or the lane is in
            // overload recovery (some class latched) and this class sits
            // inside its *own* hysteresis band — admitting it would keep
            // the shared queue busy and park the lane's p90 above the
            // latched class's recovery point forever (starvation). With a
            // uniform SLO this clause is exactly the old lane-wide latch.
            w.shedding.insert(class);
            Err(Overload { p90_us: Some(p90), slo_us })
        } else {
            // Measured p90 is healthy. With the cost model attached,
            // also check the *predicted* wait for this request: observed
            // per-class service EWMA × current queue depth. A burst of
            // expensive jobs can fill the queue faster than the measured
            // window reacts — the prediction sheds ahead of the damage.
            // No latch: the prediction falls as the queue drains, so the
            // decision self-recovers without hysteresis.
            if let Some(cm) = &self.cost {
                if let Some(wait_us) = cm.predicted_wait_us(class, queued()) {
                    if wait_us > slo_us {
                        return Err(Overload { p90_us: Some(p90), slo_us });
                    }
                }
            }
            Ok(())
        }
    }

    /// Whether any class is currently latched shedding on a lane
    /// (test/observability hook).
    pub fn shedding(&self, lane: usize) -> bool {
        !self.lane(lane).shedding.is_empty()
    }

    /// Whether one specific class is latched shedding on a lane.
    pub fn shedding_class(&self, lane: usize, class: ShapeClass) -> bool {
        self.lane(lane).shedding.contains(&class)
    }

    /// The lane's current rolling p90 estimate, if any recent waits.
    pub fn rolling_p90(&self, lane: usize) -> Option<f64> {
        let mut w = self.lane(lane);
        w.rotate(self.window);
        w.rolling_p90()
    }

    /// The rebalancer's imbalance signal for one lane: the rolling p90
    /// and how many waits the two half-windows currently hold.
    pub fn window_load(&self, lane: usize) -> (Option<f64>, u64) {
        let mut w = self.lane(lane);
        w.rotate(self.window);
        (w.rolling_p90(), w.current.count() + w.previous.count())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traces::TraceKind;

    /// The class most tests route: sort/2^8.
    fn sc() -> ShapeClass {
        ShapeClass::of(&TraceKind::Sort { n: 300 })
    }

    fn governor(mode: AdmissionMode, slo_us: f64, window_ms: u64, lanes: usize) -> Governor {
        Governor::new(mode, SloTable::uniform(slo_us), window_ms, lanes)
    }

    #[test]
    fn mode_names_round_trip() {
        for mode in [AdmissionMode::Fixed, AdmissionMode::Adaptive] {
            assert_eq!(AdmissionMode::from_name(mode.name()), Some(mode));
        }
        assert_eq!(AdmissionMode::from_name("bogus"), None);
    }

    #[test]
    fn slo_table_defaults_and_overrides() {
        let mut t = SloTable::uniform(1_000.0);
        assert_eq!(t.default_us(), 1_000.0);
        assert_eq!(t.slo_for(sc()), 1_000.0);
        assert!(t.overrides().is_empty());
        let matmul = ShapeClass::of(&TraceKind::Matmul { n: 64 });
        t.set(matmul, 2_500.0);
        assert_eq!(t.slo_for(matmul), 2_500.0, "override wins for its class");
        assert_eq!(t.slo_for(sc()), 1_000.0, "other classes keep the default");
        assert_eq!(t.overrides(), vec![(matmul, 2_500.0)]);
    }

    #[test]
    fn fixed_mode_always_admits_and_records_nothing() {
        let g = governor(AdmissionMode::Fixed, 1.0, 1_000, 2);
        for _ in 0..10 {
            g.observe(0, 1e9);
            assert!(g.admit(0, sc(), || 0).is_ok());
        }
        assert!(g.rolling_p90(0).is_none(), "fixed mode keeps no window");
        assert!(!g.shedding(0));
    }

    #[test]
    fn fixed_mode_with_recording_keeps_windows_but_never_sheds() {
        // The rebalancer's configuration: fixed admission, recording on.
        let g = governor(AdmissionMode::Fixed, 1.0, 60_000, 2).with_recording(true);
        for _ in 0..10 {
            g.observe(0, 5_000.0);
        }
        let (p90, n) = g.window_load(0);
        assert_eq!(n, 10, "waits land in the window for the rebalancer");
        assert!(p90.is_some());
        assert!(g.admit(0, sc(), || 0).is_ok(), "admission decisions stay fixed-mode");
        assert!(!g.shedding(0));
    }

    #[test]
    fn adaptive_sheds_past_slo_and_reports_evidence() {
        // Window long enough that nothing rotates mid-test.
        let g = governor(AdmissionMode::Adaptive, 1_000.0, 60_000, 2);
        assert!(g.admit(0, sc(), || 0).is_ok(), "no samples yet: admit");
        for _ in 0..10 {
            g.observe(0, 5_000.0);
        }
        let over = g.admit(0, sc(), || 0).expect_err("p90 ≈ 5000 > slo 1000 must shed");
        assert_eq!(over.slo_us, 1_000.0);
        let p90 = over.p90_us.expect("measured shed carries numeric evidence");
        assert!(p90 > 1_000.0, "reported p90 {p90} must exceed the SLO");
        assert_eq!(over.p90_evidence(), format!("{p90:.0}"));
        assert!(g.shedding(0));
        assert!(g.shedding_class(0, sc()));
        assert!(g.admit(1, sc(), || 0).is_ok(), "sibling lane is independent");
        assert!(g.admit(0, sc(), || 0).is_err(), "still shedding without recovery evidence");
        let (p90, n) = g.window_load(0);
        assert_eq!(n, 10);
        assert!(p90.unwrap() > 1_000.0);
    }

    #[test]
    fn adaptive_admits_under_slo() {
        let g = governor(AdmissionMode::Adaptive, 1_000.0, 60_000, 1);
        for _ in 0..10 {
            g.observe(0, 100.0);
        }
        assert!(g.admit(0, sc(), || 0).is_ok());
        assert!(!g.shedding(0));
    }

    #[test]
    fn per_class_slos_shed_independently_on_one_lane() {
        // Two classes share a lane (and therefore one wait window), but
        // defend different budgets: the tight-SLO class sheds while the
        // loose-SLO class keeps being admitted.
        let loose = ShapeClass::of(&TraceKind::Sort { n: 300 }); // sort/2^8
        let tight = ShapeClass::of(&TraceKind::Sort { n: 1000 }); // sort/2^9
        let mut slo = SloTable::uniform(10_000.0);
        slo.set(tight, 100.0);
        let g = Governor::new(AdmissionMode::Adaptive, slo, 60_000, 1);
        for _ in 0..10 {
            g.observe(0, 5_000.0);
        }
        assert!(g.admit(0, loose, || 0).is_ok(), "5000 < 10000: loose class admits");
        let over = g.admit(0, tight, || 0).expect_err("5000 > 100: tight class sheds");
        assert_eq!(over.slo_us, 100.0, "the shed reports the class's own SLO");
        assert!(g.shedding_class(0, tight));
        assert!(!g.shedding_class(0, loose), "the latch is per class");
        assert!(g.admit(0, loose, || 0).is_ok(), "loose class unaffected by the latch");
    }

    #[test]
    fn recovery_band_sheds_unlatched_classes_while_a_peer_is_latched() {
        // Uniform SLO, two classes sharing one lane: once one class is
        // latched, a lane p90 inside the hysteresis band (0.8·slo, slo]
        // must shed the *other* class too — otherwise its traffic keeps
        // the shared queue busy and parks the p90 above the latched
        // class's recovery point forever. This is exactly the old
        // lane-wide latch behaviour under a uniform SLO.
        let a = ShapeClass::of(&TraceKind::Sort { n: 300 });
        let b = ShapeClass::of(&TraceKind::Sort { n: 1000 });
        let g = governor(AdmissionMode::Adaptive, 1_000.0, 100, 1);
        for _ in 0..10 {
            g.observe(0, 5_000.0);
        }
        assert!(g.admit(0, a, || 0).is_err(), "a latches at p90 ≈ 5000");
        // Age the overload out and land the window in the band.
        std::thread::sleep(Duration::from_millis(250));
        for _ in 0..10 {
            g.observe(0, 900.0);
        }
        let over = g.admit(0, b, || 0).expect_err("900 > 0.8·1000 with a latched: b sheds too");
        assert_eq!(over.slo_us, 1_000.0);
        assert!(g.shedding_class(0, b), "b latches in the band");
        assert!(g.admit(0, a, || 0).is_err(), "a still held by hysteresis");
        // Clear recovery reopens both classes.
        std::thread::sleep(Duration::from_millis(250));
        for _ in 0..10 {
            g.observe(0, 100.0);
        }
        assert!(g.admit(0, a, || 0).is_ok());
        assert!(g.admit(0, b, || 0).is_ok());
        assert!(!g.shedding(0));
    }

    #[test]
    fn recovery_needs_hysteresis_fraction() {
        // One half-window of high waits trips shedding; after rotations
        // replace it with waits just *below* the SLO but *above* the
        // recovery fraction, the lane must keep shedding; only clearly
        // lower waits (or an empty window) reopen it.
        let g = governor(AdmissionMode::Adaptive, 1_000.0, 100, 1);
        for _ in 0..10 {
            g.observe(0, 5_000.0);
        }
        assert!(g.admit(0, sc(), || 0).is_err());
        // Age the 5000µs samples fully out (≥ 2 windows), then observe
        // waits at 90% of the SLO — under the SLO, over the 80% recovery
        // threshold.
        std::thread::sleep(Duration::from_millis(250));
        for _ in 0..10 {
            g.observe(0, 900.0);
        }
        assert!(g.admit(0, sc(), || 0).is_err(), "900 > 0.8·1000: hysteresis holds the shed");
        // Now age those out and observe clearly-recovered waits.
        std::thread::sleep(Duration::from_millis(250));
        for _ in 0..10 {
            g.observe(0, 100.0);
        }
        assert!(g.admit(0, sc(), || 0).is_ok(), "100 ≤ 0.8·1000: recovered");
        assert!(!g.shedding(0));
    }

    #[test]
    fn idle_window_recovers_a_shedding_lane() {
        let g = governor(AdmissionMode::Adaptive, 0.0, 50, 1);
        g.observe(0, 50.0);
        assert!(g.admit(0, sc(), || 0).is_err(), "any positive wait exceeds slo 0");
        // No further traffic and an empty queue: after two window
        // lengths the rolling estimate is empty and the lane reopens.
        std::thread::sleep(Duration::from_millis(150));
        assert!(g.admit(0, sc(), || 0).is_ok(), "idle lane recovers by window expiry");
        assert!(!g.shedding(0));
    }

    #[test]
    fn stalled_lane_with_queued_work_does_not_idle_recover() {
        let g = governor(AdmissionMode::Adaptive, 1_000.0, 200, 1);
        for _ in 0..5 {
            g.observe(0, 5_000.0);
        }
        assert!(g.admit(0, sc(), || 3).is_err(), "over SLO: shed");
        // Both half-windows age out with zero completions — but jobs are
        // still queued, so this is a stall, not idleness: the shed must
        // hold, reporting the last known p90 as evidence.
        std::thread::sleep(Duration::from_millis(500));
        let over = g.admit(0, sc(), || 3).expect_err("stalled lane must keep shedding");
        let p90 = over.p90_us.expect("a lane that completed jobs reports its stale p90");
        assert!(p90 > 1_000.0, "stale evidence reported: {p90}");
        assert!(g.shedding(0));
        // Same moment, queue drained ⇒ genuinely idle ⇒ recover.
        assert!(g.admit(0, sc(), || 0).is_ok(), "empty queue turns the stall into idle recovery");
        assert!(!g.shedding(0));
    }

    #[test]
    fn predictive_admission_sheds_on_forecast_before_p90_degrades() {
        use crate::coordinator::costmodel::ServeCostModel;
        use crate::overhead::OverheadParams;

        let cm = Arc::new(ServeCostModel::new(OverheadParams::paper_2022(), 4));
        let g = governor(AdmissionMode::Adaptive, 1_000.0, 60_000, 1)
            .with_cost_model(Some(Arc::clone(&cm)));
        // Measured waits are healthy — classic admission would admit.
        for _ in 0..10 {
            g.observe(0, 100.0);
        }
        // But each sort/2^8 job is *known* (observed EWMA) to take 800µs…
        for _ in 0..10 {
            cm.observe(&TraceKind::Sort { n: 300 }, 800.0);
        }
        // …so 5 queued ahead forecast a 4000µs wait against a 1000µs SLO.
        let over = g.admit(0, sc(), || 5).expect_err("predicted wait 4000 > slo 1000");
        assert_eq!(over.slo_us, 1_000.0);
        assert!(!g.shedding(0), "predictive sheds never latch");
        assert!(g.admit(0, sc(), || 1).is_ok(), "shallow queue forecasts under the SLO");
        // Without the cost model the same state admits.
        let plain = governor(AdmissionMode::Adaptive, 1_000.0, 60_000, 1);
        for _ in 0..10 {
            plain.observe(0, 100.0);
        }
        assert!(plain.admit(0, sc(), || 5).is_ok());
    }

    #[test]
    fn cold_start_stall_reports_stalled_marker_not_zero() {
        let g = governor(AdmissionMode::Adaptive, 1_000.0, 60_000, 1);
        // Force the cold-start corner directly: a lane latched into
        // shedding (e.g. by state carried across an operator SLO change)
        // whose window never saw a completion — `last_p90_us` has no
        // value to report.
        g.lane(0).shedding.insert(sc());
        let over = g.admit(0, sc(), || 3).expect_err("shedding + queued work must keep shedding");
        assert_eq!(over.p90_us, None, "no completion ever measured ⇒ no numeric evidence");
        assert_eq!(
            over.p90_evidence(),
            "stalled",
            "the reply must say `p90=stalled`, never a fabricated `p90=0`"
        );
        assert_eq!(over.slo_us, 1_000.0, "the SLO itself is still reported");
        // The same cold corner with an empty queue is idleness, not a
        // stall: the lane reopens.
        assert!(g.admit(0, sc(), || 0).is_ok());
        assert!(!g.shedding(0));
    }
}
