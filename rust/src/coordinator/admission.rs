//! SLO-driven adaptive admission: the governor that turns observed
//! queue-wait percentiles back into admission decisions.
//!
//! PR 1 bounded queue wait *indirectly* with a fixed per-lane depth: the
//! operator guesses how many queued jobs correspond to an acceptable
//! wait. The paper's framing says scheduling overhead must be managed at
//! the root, and the root quantity here is the wait itself — so the
//! adaptive mode closes the loop:
//!
//! * every dispatched job's measured queue wait is folded into a
//!   **rolling window** of fixed-memory [`Digest`]s on the lane it was
//!   *admitted* to (two half-windows, rotated by time, so the estimate
//!   tracks the recent past and forgets idle history);
//! * admission consults the rolling p90: above the configured SLO the
//!   lane starts **shedding** — requests answer `ERR OVERLOADED
//!   p90=<µs> slo=<µs>` (a soft reject, distinct from the hard `ERR
//!   BUSY` depth bound, which stays as the structural backstop);
//! * shedding ends with **hysteresis**: the lane re-admits once the
//!   rolling p90 falls to [`RECOVERY_FRACTION`] of the SLO, or the
//!   window drains with the lane queue empty (a truly idle lane is
//!   never stuck shedding, while a *stalled* lane — empty window but
//!   work still queued — keeps shedding on its last evidence), so the
//!   controller cannot flap around the threshold.
//!
//! [`AdmissionMode::Fixed`] keeps the PR 1 behaviour bit-for-bit: the
//! governor admits unconditionally and records nothing.

use crate::stats::Digest;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Hysteresis: a shedding lane re-admits once its rolling p90 falls to
/// this fraction of the SLO (not merely below the SLO itself).
pub const RECOVERY_FRACTION: f64 = 0.8;

/// How requests are admitted to a lane queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Depth-bound only (`ERR BUSY` past `queue_depth`); the governor is
    /// inert. The PR 1 contract.
    Fixed,
    /// Depth bound plus the SLO feedback loop: shed (`ERR OVERLOADED`)
    /// while a lane's rolling p90 queue wait exceeds the SLO.
    Adaptive,
}

impl AdmissionMode {
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionMode::Fixed => "fixed",
            AdmissionMode::Adaptive => "adaptive",
        }
    }

    pub fn from_name(s: &str) -> Option<AdmissionMode> {
        match s {
            "fixed" => Some(AdmissionMode::Fixed),
            "adaptive" => Some(AdmissionMode::Adaptive),
            _ => None,
        }
    }
}

/// Why a request was shed: the observed rolling p90 and the SLO it
/// exceeded, both in µs (the server renders these into the
/// `ERR OVERLOADED` reply).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Overload {
    /// The rolling p90 evidence behind the shed, µs. `None` when the
    /// shedding lane has never completed a job — a *stalled* cold start
    /// with no measurement to report. Rendering that absence as `0`
    /// would claim a perfect wait on a wedged lane, so the reply spells
    /// it out as `p90=stalled` instead (see `docs/PROTOCOL.md`).
    pub p90_us: Option<f64>,
    pub slo_us: f64,
}

impl Overload {
    /// The `p90=` value for the `ERR OVERLOADED` reply: the observed
    /// rolling p90 in whole µs, or the explicit `stalled` marker when
    /// no completion was ever measured.
    pub fn p90_evidence(&self) -> String {
        match self.p90_us {
            Some(p90) => format!("{p90:.0}"),
            None => "stalled".to_string(),
        }
    }
}

/// Per-lane rolling-window state. Two half-windows: quantiles are read
/// over `previous ∪ current`, so every estimate covers between one and
/// two window lengths of history and old samples age out in at most two
/// rotations.
#[derive(Debug)]
struct LaneWindow {
    current: Digest,
    previous: Digest,
    started: Instant,
    shedding: bool,
    /// Last rolling p90 computed from a non-empty window: the shed
    /// evidence reported while a *stalled* lane (empty window, jobs
    /// still queued) waits for fresh completions. `None` until the
    /// first estimate exists — a lane that has never completed a job
    /// has no evidence, and the cold-start shed must say so
    /// (`p90=stalled`) rather than fabricate a 0µs measurement.
    last_p90_us: Option<f64>,
}

impl LaneWindow {
    fn new() -> LaneWindow {
        LaneWindow {
            current: Digest::new(),
            previous: Digest::new(),
            started: Instant::now(),
            shedding: false,
            last_p90_us: None,
        }
    }

    /// Advance the window clock: after one window length the current
    /// half becomes the previous half; after two, both are stale and the
    /// estimate starts empty (idle lanes forget their history).
    fn rotate(&mut self, window: Duration) {
        let elapsed = self.started.elapsed();
        if elapsed >= window * 2 {
            self.current = Digest::new();
            self.previous = Digest::new();
            self.started = Instant::now();
        } else if elapsed >= window {
            self.previous = std::mem::take(&mut self.current);
            self.started = Instant::now();
        }
    }

    /// Rolling p90 over both half-windows (`None` when no recent waits).
    /// A zipped union walk — no digest copy on the admission hot path.
    fn rolling_p90(&self) -> Option<f64> {
        Digest::quantile_union(&self.current, &self.previous, 0.9)
    }
}

/// The admission governor: one rolling window per lane, shared between
/// the reader threads (admission checks) and the lane dispatchers
/// (queue-wait observations). All state is behind per-lane mutexes, so
/// admission on lane A never contends with dispatch on lane B.
pub struct Governor {
    mode: AdmissionMode,
    slo_p90_us: f64,
    window: Duration,
    lanes: Vec<Mutex<LaneWindow>>,
}

impl Governor {
    /// `window_ms` is the rolling half-window length (clamped ≥ 1 ms).
    pub fn new(mode: AdmissionMode, slo_p90_us: f64, window_ms: u64, lanes: usize) -> Governor {
        Governor {
            mode,
            slo_p90_us,
            window: Duration::from_millis(window_ms.max(1)),
            lanes: (0..lanes.max(1)).map(|_| Mutex::new(LaneWindow::new())).collect(),
        }
    }

    pub fn mode(&self) -> AdmissionMode {
        self.mode
    }

    pub fn slo_p90_us(&self) -> f64 {
        self.slo_p90_us
    }

    /// Lock one lane's window, tolerating poison (advisory state only).
    fn lane(&self, lane: usize) -> std::sync::MutexGuard<'_, LaneWindow> {
        self.lanes[lane].lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Record one dispatched job's measured queue wait against the lane
    /// it was admitted to. No-op in [`AdmissionMode::Fixed`].
    pub fn observe(&self, lane: usize, queue_wait_us: f64) {
        if self.mode == AdmissionMode::Fixed {
            return;
        }
        let mut w = self.lane(lane);
        w.rotate(self.window);
        w.current.record(queue_wait_us);
    }

    /// Admission check for a request routed to `lane`. `Ok` admits;
    /// `Err` is a shed with the evidence for the `ERR OVERLOADED` reply.
    ///
    /// `queued` reports the lane's current queue length; it
    /// distinguishes *idle* from *stalled* when the rolling window is
    /// empty: a window can drain because the lane is quiet (recover) or
    /// because a long batch has dispatched nothing for two windows while
    /// work piles up behind it (keep shedding — waits are not observed
    /// to be low, they are simply not observed). Lazy because reading it
    /// takes the lane queue's mutex, and the common non-empty-window
    /// path must not pay that on every admission.
    pub fn admit(&self, lane: usize, queued: impl FnOnce() -> usize) -> Result<(), Overload> {
        if self.mode == AdmissionMode::Fixed {
            return Ok(());
        }
        let mut w = self.lane(lane);
        w.rotate(self.window);
        let Some(p90) = w.rolling_p90() else {
            if w.shedding && queued() > 0 {
                // Stalled, not idle: nothing completed for two windows
                // but the queue is still backed up. Hold the shed on the
                // last evidence we had — or, on the cold-start corner
                // where the lane has *never* completed a job, on the
                // explicit `stalled` marker (never a fabricated p90=0).
                return Err(Overload { p90_us: w.last_p90_us, slo_us: self.slo_p90_us });
            }
            // Truly idle (or never loaded): nothing to defend.
            w.shedding = false;
            return Ok(());
        };
        w.last_p90_us = Some(p90);
        if w.shedding {
            if p90 <= self.slo_p90_us * RECOVERY_FRACTION {
                w.shedding = false;
                Ok(())
            } else {
                Err(Overload { p90_us: Some(p90), slo_us: self.slo_p90_us })
            }
        } else if p90 > self.slo_p90_us {
            w.shedding = true;
            Err(Overload { p90_us: Some(p90), slo_us: self.slo_p90_us })
        } else {
            Ok(())
        }
    }

    /// Whether a lane is currently shedding (test/observability hook).
    pub fn shedding(&self, lane: usize) -> bool {
        self.lane(lane).shedding
    }

    /// The lane's current rolling p90 estimate, if any recent waits.
    pub fn rolling_p90(&self, lane: usize) -> Option<f64> {
        let mut w = self.lane(lane);
        w.rotate(self.window);
        w.rolling_p90()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_names_round_trip() {
        for mode in [AdmissionMode::Fixed, AdmissionMode::Adaptive] {
            assert_eq!(AdmissionMode::from_name(mode.name()), Some(mode));
        }
        assert_eq!(AdmissionMode::from_name("bogus"), None);
    }

    #[test]
    fn fixed_mode_always_admits_and_records_nothing() {
        let g = Governor::new(AdmissionMode::Fixed, 1.0, 1_000, 2);
        for _ in 0..10 {
            g.observe(0, 1e9);
            assert!(g.admit(0, || 0).is_ok());
        }
        assert!(g.rolling_p90(0).is_none(), "fixed mode keeps no window");
        assert!(!g.shedding(0));
    }

    #[test]
    fn adaptive_sheds_past_slo_and_reports_evidence() {
        // Window long enough that nothing rotates mid-test.
        let g = Governor::new(AdmissionMode::Adaptive, 1_000.0, 60_000, 2);
        assert!(g.admit(0, || 0).is_ok(), "no samples yet: admit");
        for _ in 0..10 {
            g.observe(0, 5_000.0);
        }
        let over = g.admit(0, || 0).expect_err("p90 ≈ 5000 > slo 1000 must shed");
        assert_eq!(over.slo_us, 1_000.0);
        let p90 = over.p90_us.expect("measured shed carries numeric evidence");
        assert!(p90 > 1_000.0, "reported p90 {p90} must exceed the SLO");
        assert_eq!(over.p90_evidence(), format!("{p90:.0}"));
        assert!(g.shedding(0));
        assert!(g.admit(1, || 0).is_ok(), "sibling lane is independent");
        assert!(g.admit(0, || 0).is_err(), "still shedding without recovery evidence");
    }

    #[test]
    fn adaptive_admits_under_slo() {
        let g = Governor::new(AdmissionMode::Adaptive, 1_000.0, 60_000, 1);
        for _ in 0..10 {
            g.observe(0, 100.0);
        }
        assert!(g.admit(0, || 0).is_ok());
        assert!(!g.shedding(0));
    }

    #[test]
    fn recovery_needs_hysteresis_fraction() {
        // One half-window of high waits trips shedding; after rotations
        // replace it with waits just *below* the SLO but *above* the
        // recovery fraction, the lane must keep shedding; only clearly
        // lower waits (or an empty window) reopen it.
        let g = Governor::new(AdmissionMode::Adaptive, 1_000.0, 100, 1);
        for _ in 0..10 {
            g.observe(0, 5_000.0);
        }
        assert!(g.admit(0, || 0).is_err());
        // Age the 5000µs samples fully out (≥ 2 windows), then observe
        // waits at 90% of the SLO — under the SLO, over the 80% recovery
        // threshold.
        std::thread::sleep(Duration::from_millis(250));
        for _ in 0..10 {
            g.observe(0, 900.0);
        }
        assert!(g.admit(0, || 0).is_err(), "900 > 0.8·1000: hysteresis holds the shed");
        // Now age those out and observe clearly-recovered waits.
        std::thread::sleep(Duration::from_millis(250));
        for _ in 0..10 {
            g.observe(0, 100.0);
        }
        assert!(g.admit(0, || 0).is_ok(), "100 ≤ 0.8·1000: recovered");
        assert!(!g.shedding(0));
    }

    #[test]
    fn idle_window_recovers_a_shedding_lane() {
        let g = Governor::new(AdmissionMode::Adaptive, 0.0, 50, 1);
        g.observe(0, 50.0);
        assert!(g.admit(0, || 0).is_err(), "any positive wait exceeds slo 0");
        // No further traffic and an empty queue: after two window
        // lengths the rolling estimate is empty and the lane reopens.
        std::thread::sleep(Duration::from_millis(150));
        assert!(g.admit(0, || 0).is_ok(), "idle lane recovers by window expiry");
        assert!(!g.shedding(0));
    }

    #[test]
    fn stalled_lane_with_queued_work_does_not_idle_recover() {
        let g = Governor::new(AdmissionMode::Adaptive, 1_000.0, 200, 1);
        for _ in 0..5 {
            g.observe(0, 5_000.0);
        }
        assert!(g.admit(0, || 3).is_err(), "over SLO: shed");
        // Both half-windows age out with zero completions — but jobs are
        // still queued, so this is a stall, not idleness: the shed must
        // hold, reporting the last known p90 as evidence.
        std::thread::sleep(Duration::from_millis(500));
        let over = g.admit(0, || 3).expect_err("stalled lane must keep shedding");
        let p90 = over.p90_us.expect("a lane that completed jobs reports its stale p90");
        assert!(p90 > 1_000.0, "stale evidence reported: {p90}");
        assert!(g.shedding(0));
        // Same moment, queue drained ⇒ genuinely idle ⇒ recover.
        assert!(g.admit(0, || 0).is_ok(), "empty queue turns the stall into idle recovery");
        assert!(!g.shedding(0));
    }

    #[test]
    fn cold_start_stall_reports_stalled_marker_not_zero() {
        let g = Governor::new(AdmissionMode::Adaptive, 1_000.0, 60_000, 1);
        // Force the cold-start corner directly: a lane latched into
        // shedding (e.g. by state carried across an operator SLO change)
        // whose window never saw a completion — `last_p90_us` has no
        // value to report.
        g.lane(0).shedding = true;
        let over = g.admit(0, || 3).expect_err("shedding + queued work must keep shedding");
        assert_eq!(over.p90_us, None, "no completion ever measured ⇒ no numeric evidence");
        assert_eq!(
            over.p90_evidence(),
            "stalled",
            "the reply must say `p90=stalled`, never a fabricated `p90=0`"
        );
        assert_eq!(over.slo_us, 1_000.0, "the SLO itself is still reported");
        // The same cold corner with an empty queue is idleness, not a
        // stall: the lane reopens.
        assert!(g.admit(0, || 0).is_ok());
        assert!(!g.shedding(0));
    }
}
