//! Warm result cache: sharded, bounded memoization of deterministic
//! job results, in front of the dispatch lanes.
//!
//! Every job this framework serves is a pure function of its request:
//! `(TraceKind, seed)` fully determines the generated input and
//! therefore the output checksum. Re-executing an identical request is
//! the purest form of the paper's *redundant work* overhead — cores
//! spent recomputing a value the system already produced — so the
//! serving layer eliminates it at the root instead of paying it
//! per-request: a hit is answered by the connection reader itself,
//! bypassing admission, the lane queues, and execution entirely. (It is
//! the serving analogue of the coordinator's warm *executable* cache:
//! that one skips recompilation, this one skips recomputation.)
//!
//! Design constraints, mirroring the rest of the serving layer:
//!
//! * **Sharded locking.** One shard per dispatch lane, selected by the
//!   same [`ShapeClass`] routing the lanes use — so cache traffic for
//!   lane A never contends with lane B, and no new *global* lock
//!   appears on the hot path.
//! * **Bounded.** Per-shard LRU (intrusive-list, O(1) touch/evict)
//!   under both an entry cap and a byte budget; a forever-running
//!   server cannot grow the cache without bound.
//! * **Single-flight.** Concurrent identical requests coalesce: the
//!   first becomes the *leader* (it executes through the normal
//!   admission path and fills the cache exactly once — the fill happens
//!   on the leader's reader thread, so it stays exactly-once even when
//!   work stealing executes the job on a thief lane); followers block
//!   on the leader's [`Flight`] and are served its result without ever
//!   touching a queue. A leader that is rejected or fails *aborts* the
//!   flight (guaranteed by [`Flight`]'s drop guard, so a panicking or
//!   shed leader can never strand its followers), and each follower
//!   then retries — at most one leader exists per key at any moment.
//! * **Cheap observability.** Per-shard hit/miss/eviction/occupancy
//!   counters are atomics read without taking any shard lock, so the
//!   STATS "result cache" table does no O(entries) work — the same
//!   contract the digest-backed telemetry upholds.
//!
//! The cache is off by default (`--cache on` enables it): with it off,
//! replies, STATS, and admission behaviour are untouched.

use super::lanes::ShapeClass;
use super::routing;
use crate::report::{table::f, AsciiTable};
use crate::workload::traces::TraceKind;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// The full deterministic input identity of a job: its kind (and size)
/// plus the workload seed. Two requests with equal keys are guaranteed
/// to produce bit-identical results.
pub type CacheKey = (TraceKind, u64);

/// A memoized successful result. Only `ok` executions are cached, so a
/// hit can always be rendered as an `OK` reply.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CachedResult {
    /// The reply checksum, stored verbatim — a hit renders the same
    /// bits a cold run would.
    pub checksum: f64,
}

/// Outcome of a cache lookup.
pub enum Lookup<'a> {
    /// Served: the memoized result (possibly by waiting for a
    /// concurrent leader's in-flight execution to complete).
    Hit(CachedResult),
    /// This caller is the single-flight leader for the key: it must
    /// execute the job and then [`fill`](Flight::fill) (on success) or
    /// [`abort`](Flight::abort) / drop (on rejection or failure) the
    /// flight.
    Miss(Flight<'a>),
}

impl std::fmt::Debug for Lookup<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Lookup::Hit(_) => f.write_str("Hit(..)"),
            Lookup::Miss(_) => f.write_str("Miss(..)"),
        }
    }
}

/// Rendezvous cell between a single-flight leader and its followers.
/// `None` outcome means the leader aborted (followers retry).
struct FlightCell {
    done: Mutex<Option<Option<CachedResult>>>,
    cv: Condvar,
}

impl FlightCell {
    fn new() -> FlightCell {
        FlightCell { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn wait(&self) -> Option<CachedResult> {
        let mut g = self.done.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(outcome) = *g {
                return outcome;
            }
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn complete(&self, outcome: Option<CachedResult>) {
        *self.done.lock().unwrap_or_else(|p| p.into_inner()) = Some(outcome);
        self.cv.notify_all();
    }
}

/// The single-flight leader's obligation. Dropping it without
/// [`fill`](Flight::fill) aborts the flight: followers wake and retry
/// (one of them becomes the next leader), and nothing is cached — so a
/// leader rejected by admission, failed by an engine, or killed by a
/// panic can never wedge its followers or poison the cache.
pub struct Flight<'a> {
    cache: &'a ResultCache,
    shard: usize,
    key: CacheKey,
    cell: Arc<FlightCell>,
    settled: bool,
}

impl std::fmt::Debug for Flight<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Flight").finish_non_exhaustive()
    }
}

impl Flight<'_> {
    /// Publish a successful result: insert it into the cache (evicting
    /// LRU entries past the shard's bounds) and wake every follower
    /// with it. Exactly-once by construction — there is one leader.
    pub fn fill(mut self, value: CachedResult) {
        self.settled = true;
        self.cache.settle(self.shard, self.key, &self.cell, Some(value));
    }

    /// Explicitly abort without caching. Equivalent to dropping the
    /// flight; spelled out at call sites where the abort is a decision
    /// rather than an unwind.
    pub fn abort(mut self) {
        self.settled = true;
        self.cache.settle(self.shard, self.key, &self.cell, None);
    }
}

impl Drop for Flight<'_> {
    fn drop(&mut self) {
        if !self.settled {
            self.cache.settle(self.shard, self.key, &self.cell, None);
        }
    }
}

const NIL: usize = usize::MAX;

/// One entry in the intrusive LRU list (slab-allocated; `prev`/`next`
/// are slab indices, `NIL`-terminated).
struct Node {
    key: CacheKey,
    value: CachedResult,
    prev: usize,
    next: usize,
}

/// Exact LRU over a slab + index map: O(1) get/insert/evict, no
/// per-operation allocation once the slab has grown to the entry cap.
struct Lru {
    map: HashMap<CacheKey, usize>,
    nodes: Vec<Node>,
    free: Vec<usize>,
    /// Most recently used.
    head: usize,
    /// Least recently used (the eviction candidate).
    tail: usize,
}

impl Lru {
    fn new() -> Lru {
        Lru { map: HashMap::new(), nodes: Vec::new(), free: Vec::new(), head: NIL, tail: NIL }
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.nodes[i].prev, self.nodes[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.nodes[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.nodes[next].prev = prev;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.nodes[i].prev = NIL;
        self.nodes[i].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Lookup + recency touch.
    fn get(&mut self, key: &CacheKey) -> Option<CachedResult> {
        let &i = self.map.get(key)?;
        self.unlink(i);
        self.push_front(i);
        Some(self.nodes[i].value)
    }

    /// Insert (or refresh) an entry at the recency head. Returns `true`
    /// when the key is new (occupancy grew).
    fn insert(&mut self, key: CacheKey, value: CachedResult) -> bool {
        if let Some(&i) = self.map.get(&key) {
            self.nodes[i].value = value;
            self.unlink(i);
            self.push_front(i);
            return false;
        }
        let node = Node { key, value, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.map.insert(key, i);
        self.push_front(i);
        true
    }

    /// Remove and return the least-recently-used key.
    fn evict_lru(&mut self) -> Option<CacheKey> {
        let i = self.tail;
        if i == NIL {
            return None;
        }
        self.unlink(i);
        let key = self.nodes[i].key;
        self.map.remove(&key);
        self.free.push(i);
        Some(key)
    }
}

/// Mutable shard state (behind the shard mutex).
struct ShardState {
    lru: Lru,
    /// In-flight single-flight registrations: key → the leader's cell.
    inflight: HashMap<CacheKey, Arc<FlightCell>>,
}

/// Lock-free shard counters, readable by STATS without the shard lock.
#[derive(Default)]
struct ShardCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    entries: AtomicU64,
    bytes: AtomicU64,
}

/// Point-in-time counter snapshot for one shard.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Lookups served from the cache (including single-flight followers
    /// served by a leader's completed execution).
    pub hits: u64,
    /// Lookups that made the caller a leader — every one corresponds to
    /// at most one execution (fewer when the leader was rejected).
    pub misses: u64,
    /// Entries evicted to stay within the entry cap / byte budget.
    pub evictions: u64,
    /// Current occupancy.
    pub entries: u64,
    /// Current footprint, bytes (`entries × entry_bytes()`).
    pub bytes: u64,
}

struct CacheShard {
    state: Mutex<ShardState>,
    counters: ShardCounters,
}

/// The sharded warm result cache. See the module docs for the design.
pub struct ResultCache {
    shards: Vec<CacheShard>,
    /// Per-shard entry cap (global `--cache-entries` split evenly,
    /// minimum 1).
    shard_entries: usize,
    /// Per-shard byte budget (global `--cache-bytes` split evenly,
    /// minimum one entry's footprint).
    shard_bytes: u64,
}

impl std::fmt::Debug for ResultCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResultCache").finish_non_exhaustive()
    }
}

impl ResultCache {
    /// `shards` mirrors the lane count (min 1); `entries` and `bytes`
    /// are *global* budgets split evenly across shards — floor division,
    /// so the shard caps never add up past the configured global bound.
    /// Zero budgets are rejected upstream (CLI/config validation);
    /// defensively, each shard still holds at least one entry, the one
    /// case (budget < one entry per shard) where the global bound is
    /// exceeded rather than serving a degenerate zero-capacity shard.
    pub fn new(shards: usize, entries: usize, bytes: u64) -> ResultCache {
        let shards = shards.max(1);
        ResultCache {
            shard_entries: (entries / shards).max(1),
            shard_bytes: (bytes / shards as u64).max(entry_bytes()),
            shards: (0..shards)
                .map(|_| CacheShard {
                    state: Mutex::new(ShardState { lru: Lru::new(), inflight: HashMap::new() }),
                    counters: ShardCounters::default(),
                })
                .collect(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard entry cap after splitting the global budget.
    pub fn shard_entry_cap(&self) -> usize {
        self.shard_entries
    }

    /// Per-shard byte budget after splitting the global budget.
    pub fn shard_byte_budget(&self) -> u64 {
        self.shard_bytes
    }

    /// The shard a key lives in: the canonical **seed** [`ShapeClass`]
    /// → lane mapping ([`routing::seed_lane`]), which the routing table
    /// keeps *epoch-invariant* ([`routing::RoutingTable::shard_of`]).
    /// Deliberately not the epoch's live lane assignment: a rebalance
    /// moves where a class executes, never where it is memoized, so LRU
    /// residency and in-flight single-flight leadership survive an
    /// epoch swap — the fill stays exactly-once across it.
    pub fn shard_of(&self, kind: &TraceKind) -> usize {
        routing::seed_lane(ShapeClass::of(kind), self.shards.len())
    }

    fn lock(&self, s: usize) -> std::sync::MutexGuard<'_, ShardState> {
        self.shards[s].state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Look up `(kind, seed)`. Returns [`Lookup::Hit`] when memoized —
    /// possibly after blocking on a concurrent leader's execution — or
    /// [`Lookup::Miss`] making this caller the single-flight leader.
    /// The blocking wait happens *outside* the shard lock, so followers
    /// never stall unrelated keys in the shard.
    pub fn lookup(&self, kind: &TraceKind, seed: u64) -> Lookup<'_> {
        let key = (*kind, seed);
        let s = self.shard_of(kind);
        loop {
            let cell = {
                let mut g = self.lock(s);
                if let Some(value) = g.lru.get(&key) {
                    self.shards[s].counters.hits.fetch_add(1, Ordering::Relaxed);
                    return Lookup::Hit(value);
                }
                match g.inflight.get(&key) {
                    Some(cell) => Arc::clone(cell),
                    None => {
                        let cell = Arc::new(FlightCell::new());
                        g.inflight.insert(key, Arc::clone(&cell));
                        self.shards[s].counters.misses.fetch_add(1, Ordering::Relaxed);
                        return Lookup::Miss(Flight {
                            cache: self,
                            shard: s,
                            key,
                            cell,
                            settled: false,
                        });
                    }
                }
            };
            // Follower: block on the leader's outcome with no shard
            // lock held. A filled flight is a hit; an aborted one loops
            // back — the retry either finds the key cached meanwhile or
            // promotes this caller to leader.
            if let Some(value) = cell.wait() {
                self.shards[s].counters.hits.fetch_add(1, Ordering::Relaxed);
                return Lookup::Hit(value);
            }
        }
    }

    /// Non-blocking [`lookup`](ResultCache::lookup), for reactor
    /// threads (which must never park on a condvar): identical
    /// outcomes, except that the case where `lookup` would block — a
    /// concurrent leader's execution in flight for this key — returns
    /// `None`. The caller then *bypasses* the cache for this one
    /// request: it executes through the normal admission path without
    /// a fill obligation, trading one redundant execution for never
    /// stalling the reactor's other connections. No counter moves on
    /// the bypass — it is neither a hit nor a leader registration.
    pub fn try_lookup(&self, kind: &TraceKind, seed: u64) -> Option<Lookup<'_>> {
        let key = (*kind, seed);
        let s = self.shard_of(kind);
        let mut g = self.lock(s);
        if let Some(value) = g.lru.get(&key) {
            self.shards[s].counters.hits.fetch_add(1, Ordering::Relaxed);
            return Some(Lookup::Hit(value));
        }
        if g.inflight.contains_key(&key) {
            return None;
        }
        let cell = Arc::new(FlightCell::new());
        g.inflight.insert(key, Arc::clone(&cell));
        self.shards[s].counters.misses.fetch_add(1, Ordering::Relaxed);
        Some(Lookup::Miss(Flight { cache: self, shard: s, key, cell, settled: false }))
    }

    /// Resolve a flight: deregister it, optionally insert the result
    /// (evicting past the shard bounds), refresh the occupancy
    /// counters, then wake the followers.
    fn settle(
        &self,
        s: usize,
        key: CacheKey,
        cell: &Arc<FlightCell>,
        outcome: Option<CachedResult>,
    ) {
        {
            let mut g = self.lock(s);
            g.inflight.remove(&key);
            if let Some(value) = outcome {
                g.lru.insert(key, value);
                while g.lru.len() > self.shard_entries
                    || g.lru.len() as u64 * entry_bytes() > self.shard_bytes
                {
                    if g.lru.evict_lru().is_none() {
                        break;
                    }
                    self.shards[s].counters.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
            let len = g.lru.len() as u64;
            self.shards[s].counters.entries.store(len, Ordering::Relaxed);
            self.shards[s].counters.bytes.store(len * entry_bytes(), Ordering::Relaxed);
        }
        cell.complete(outcome);
    }

    /// Counter snapshot per shard (no shard lock taken).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .map(|sh| ShardStats {
                hits: sh.counters.hits.load(Ordering::Relaxed),
                misses: sh.counters.misses.load(Ordering::Relaxed),
                evictions: sh.counters.evictions.load(Ordering::Relaxed),
                entries: sh.counters.entries.load(Ordering::Relaxed),
                bytes: sh.counters.bytes.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Element-wise sum over [`shard_stats`](ResultCache::shard_stats).
    pub fn totals(&self) -> ShardStats {
        self.shard_stats().iter().fold(ShardStats::default(), |a, s| ShardStats {
            hits: a.hits + s.hits,
            misses: a.misses + s.misses,
            evictions: a.evictions + s.evictions,
            entries: a.entries + s.entries,
            bytes: a.bytes + s.bytes,
        })
    }

    /// Render the STATS "result cache" table plus its counter trailer
    /// line. Reads only the atomic counters — O(shards), never
    /// O(entries), and takes no shard lock.
    pub fn render(&self) -> String {
        let mut t = AsciiTable::new(
            &format!(
                "result cache (per shard: ≤{} entries, ≤{} bytes)",
                self.shard_entries, self.shard_bytes
            ),
            &["shard", "hits", "misses", "evictions", "entries", "bytes"],
        );
        for (i, s) in self.shard_stats().iter().enumerate() {
            t.row(vec![
                i.to_string(),
                s.hits.to_string(),
                s.misses.to_string(),
                s.evictions.to_string(),
                s.entries.to_string(),
                s.bytes.to_string(),
            ]);
        }
        let total = self.totals();
        let ratio = if total.hits + total.misses > 0 {
            100.0 * total.hits as f64 / (total.hits + total.misses) as f64
        } else {
            0.0
        };
        let mut out = t.render();
        out.push_str(&format!(
            "cache: hits={} misses={} evictions={} entries={} bytes={} hit_ratio={}%\n",
            total.hits,
            total.misses,
            total.evictions,
            total.entries,
            total.bytes,
            f(ratio, 1),
        ));
        out
    }
}

/// Accounted in-memory footprint of one cache entry: the slab node plus
/// the index-map entry. Every entry costs the same, so a shard's byte
/// footprint is exactly `entries × entry_bytes()` and the byte budget
/// is enforced without per-entry measurement.
pub fn entry_bytes() -> u64 {
    (std::mem::size_of::<Node>()
        + std::mem::size_of::<CacheKey>()
        + 2 * std::mem::size_of::<usize>()) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    const SORT: fn(usize) -> TraceKind = |n| TraceKind::Sort { n };

    fn fill(cache: &ResultCache, kind: TraceKind, seed: u64, checksum: f64) {
        match cache.lookup(&kind, seed) {
            Lookup::Miss(flight) => flight.fill(CachedResult { checksum }),
            Lookup::Hit(_) => panic!("expected a miss for {kind:?}/{seed}"),
        }
    }

    #[test]
    fn miss_fill_hit_round_trip() {
        let cache = ResultCache::new(1, 8, 1 << 20);
        fill(&cache, SORT(300), 7, 123.5);
        match cache.lookup(&SORT(300), 7) {
            Lookup::Hit(v) => assert_eq!(v.checksum.to_bits(), 123.5f64.to_bits()),
            Lookup::Miss(_) => panic!("filled key must hit"),
        }
        let t = cache.totals();
        assert_eq!((t.hits, t.misses, t.entries), (1, 1, 1));
        assert_eq!(t.bytes, entry_bytes());
    }

    #[test]
    fn distinct_seeds_are_distinct_keys() {
        let cache = ResultCache::new(1, 8, 1 << 20);
        fill(&cache, SORT(300), 1, 1.0);
        assert!(
            matches!(cache.lookup(&SORT(300), 2), Lookup::Miss(_)),
            "same shape, different seed must miss"
        );
    }

    #[test]
    fn lru_evicts_least_recently_used_within_entry_cap() {
        let cache = ResultCache::new(1, 3, 1 << 20);
        for seed in 1..=3 {
            fill(&cache, SORT(100), seed, seed as f64);
        }
        // Touch seed 1 so seed 2 becomes the LRU, then overflow.
        assert!(matches!(cache.lookup(&SORT(100), 1), Lookup::Hit(_)));
        fill(&cache, SORT(100), 4, 4.0);
        assert_eq!(cache.totals().entries, 3, "entry cap enforced");
        assert_eq!(cache.totals().evictions, 1);
        assert!(matches!(cache.lookup(&SORT(100), 1), Lookup::Hit(_)), "touched entry survives");
        assert!(matches!(cache.lookup(&SORT(100), 2), Lookup::Miss(_)), "LRU entry evicted");
    }

    #[test]
    fn byte_budget_bounds_occupancy() {
        // Entry cap generous, byte budget only 2 entries wide.
        let cache = ResultCache::new(1, 100, 2 * entry_bytes());
        for seed in 1..=5 {
            fill(&cache, SORT(100), seed, seed as f64);
        }
        let t = cache.totals();
        assert!(t.entries <= 2, "byte budget must bound occupancy, got {}", t.entries);
        assert!(t.bytes <= 2 * entry_bytes());
        assert_eq!(t.evictions, 3);
    }

    #[test]
    fn try_lookup_bypasses_inflight_leaders_without_blocking() {
        let cache = ResultCache::new(1, 8, 1 << 20);
        // Cold key: try_lookup wins leadership exactly like lookup.
        let flight = match cache.try_lookup(&SORT(300), 7) {
            Some(Lookup::Miss(f)) => f,
            other => panic!("cold key must make a leader, got {other:?}"),
        };
        // While the leader is in flight, try_lookup declines to wait.
        assert!(cache.try_lookup(&SORT(300), 7).is_none(), "inflight key bypasses");
        assert_eq!(cache.totals().misses, 1, "a bypass is not a leader registration");
        flight.fill(CachedResult { checksum: 9.25 });
        match cache.try_lookup(&SORT(300), 7) {
            Some(Lookup::Hit(v)) => assert_eq!(v.checksum.to_bits(), 9.25f64.to_bits()),
            other => panic!("filled key must hit, got {other:?}"),
        }
        assert_eq!(cache.totals().hits, 1);
    }

    #[test]
    fn abort_caches_nothing_and_renders() {
        let cache = ResultCache::new(2, 8, 1 << 20);
        match cache.lookup(&SORT(100), 1) {
            Lookup::Miss(flight) => flight.abort(),
            Lookup::Hit(_) => panic!("cold cache"),
        }
        assert!(matches!(cache.lookup(&SORT(100), 1), Lookup::Miss(_)), "abort caches nothing");
        let s = cache.render();
        assert!(s.contains("result cache"), "{s}");
        assert!(s.contains("hit_ratio=0.0%"), "{s}");
        assert_eq!(cache.totals().misses, 2);
    }
}
