//! The serving layer's cost-model handle: [`ServeCostModel`] maps
//! [`ShapeClass`]es onto the overhead layer's online
//! [`CostTable`](crate::overhead::CostTable) and answers the three
//! serve-time questions the redesign wires in (`--cost-model on`):
//!
//! * **Dispatch** — [`should_inline`](ServeCostModel::should_inline):
//!   is this job predicted below the serial/parallel crossover? If so
//!   the dispatcher runs it serial-inline on the lane thread
//!   (`engine=serial-inline`), skipping the fork-join overhead the
//!   model says would dominate — the paper's central trade-off acted on
//!   per request instead of per calibration run.
//! * **Admission** — [`predicted_wait_us`](ServeCostModel::predicted_wait_us):
//!   expected queue wait if admitted now (observed per-class service
//!   EWMA × queue depth). The adaptive governor sheds on this *before*
//!   the measured p90 degrades.
//! * **Rebalancing** — [`class_cost_ns`](ServeCostModel::class_cost_ns):
//!   predicted per-job cost of a class, so the rebalancer weighs a wide
//!   matmul class above a thin sort class instead of comparing raw
//!   request counts.
//!
//! Predictions start from the static paper calibration and are
//! bias-corrected online: every completed execution feeds the table's
//! EWMA (`observe`), so a class whose real service time drifts from the
//! model pulls its own predictions with it. The arithmetic lives in
//! [`crate::overhead::costmodel`]; this module owns only the
//! ShapeClass ↔ slot mapping and the STATS rendering.

use super::lanes::ShapeClass;
use super::routing::{class_slot, slot_class, CLASS_SLOTS};
use super::{matmul_work_est, sort_work_est};
use crate::overhead::{CostModel, CostTable, OverheadParams, WorkEstimate};
use crate::report::{table::f, AsciiTable};
use crate::workload::traces::TraceKind;

/// Serving-layer cost model: one [`CostTable`] slot per addressable
/// shape class, shared by the lane dispatchers (observe + inline
/// decisions), the admission governor (predicted wait), and the
/// rebalancer (class weights).
pub struct ServeCostModel {
    table: CostTable,
}

impl std::fmt::Debug for ServeCostModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServeCostModel").finish_non_exhaustive()
    }
}

/// The work estimate the serving layer prices a job kind at — the same
/// estimates [`Coordinator::route`](super::Coordinator::route) feeds the
/// per-region manager, so serve-time and execute-time decisions price
/// one model.
fn estimate(kind: &TraceKind) -> WorkEstimate {
    match kind {
        TraceKind::Matmul { n } => matmul_work_est(*n),
        TraceKind::Sort { n } => sort_work_est(*n),
    }
}

/// A class's representative job size: the lower edge of its
/// power-of-two bucket (`2^bucket`). Used to price a *class* (not a
/// specific job) for rebalancing weights.
fn representative_kind(class: ShapeClass) -> TraceKind {
    let n = 1usize << class.bucket().min(usize::BITS as u8 - 1);
    if class.kind_id() == 0 {
        TraceKind::Matmul { n }
    } else {
        TraceKind::Sort { n }
    }
}

impl ServeCostModel {
    /// Calibrated table over the full class space; `cores` is the CPU
    /// pool width the parallel predictions assume (`cfg.threads`).
    pub fn new(params: OverheadParams, cores: usize) -> ServeCostModel {
        ServeCostModel { table: CostTable::new(CLASS_SLOTS, params, cores) }
    }

    /// Serve-time crossover: true when the static serial prediction
    /// beats the bias-corrected parallel prediction — the job should run
    /// serial-inline on the lane thread, skipping fork-join overhead.
    pub fn should_inline(&self, kind: &TraceKind) -> bool {
        let est = estimate(kind);
        let slot = class_slot(ShapeClass::of(kind));
        let serial_ns = self.table.static_model().predict_serial_ns(&est);
        serial_ns <= self.table.predict_parallel_ns(slot, &est)
    }

    /// Feed back one completed execution (any engine): refreshes the
    /// class's observed-service EWMA and its prediction bias.
    pub fn observe(&self, kind: &TraceKind, service_us: f64) {
        let est = estimate(kind);
        let slot = class_slot(ShapeClass::of(kind));
        let cm = self.table.static_model();
        let (_, parallel_ns) = cm.predict_parallel_ns(&est, self.table.cores());
        let predicted_ns = cm.predict_serial_ns(&est).min(parallel_ns);
        self.table.observe(slot, predicted_ns, service_us * 1e3);
    }

    /// Record one serial-inline execution for the class.
    pub fn note_inline(&self, kind: &TraceKind) {
        self.table.note_inline(class_slot(ShapeClass::of(kind)));
    }

    /// Predicted queue wait, µs, if a job of `class` were admitted to a
    /// lane with `queued` jobs ahead of it: observed per-class service
    /// EWMA × depth. `None` until the class has completed at least one
    /// job — predicting from zero evidence is how admission governors
    /// cause outages, so the governor falls back to measured p90 alone.
    pub fn predicted_wait_us(&self, class: ShapeClass, queued: usize) -> Option<f64> {
        let slot = class_slot(class);
        self.table
            .expected_service_ns(slot)
            .map(|service_ns| service_ns * queued as f64 / 1e3)
    }

    /// Predicted per-job cost of a class, ns — the rebalancer's weight.
    /// The observed EWMA when the class has history; otherwise the
    /// static model's cheapest-engine prediction at the class's
    /// representative size, so a never-served wide matmul class still
    /// outweighs a never-served thin sort class.
    pub fn class_cost_ns(&self, class: ShapeClass) -> f64 {
        let slot = class_slot(class);
        if let Some(ns) = self.table.expected_service_ns(slot) {
            return ns;
        }
        let est = estimate(&representative_kind(class));
        let cm = self.table.static_model();
        let (_, parallel_ns) = cm.predict_parallel_ns(&est, self.table.cores());
        cm.predict_serial_ns(&est).min(parallel_ns)
    }

    /// Total serial-inline executions across all classes.
    pub fn inline_count(&self) -> u64 {
        self.table.inline_total()
    }

    /// The STATS/DRAIN "cost model" table: per-class predicted vs
    /// observed service time, bias, samples, and inline-serial count for
    /// every class with history, plus a trailer with the predicted
    /// serve-time crossover per kind. Rendered only with `--cost-model
    /// on`, so those blocks stay byte-identical when it is off.
    pub fn render(&self) -> String {
        let cores = self.table.cores();
        let cm = self.table.static_model();
        let mut t = AsciiTable::new(
            "cost model (per shape class)",
            &["class", "predicted (µs)", "observed (µs)", "bias", "samples", "inline"],
        );
        for slot in 0..CLASS_SLOTS {
            let c = self.table.snapshot(slot);
            if c.samples == 0 && c.inline_serial == 0 {
                continue;
            }
            let class = slot_class(slot);
            let predicted_ns = self.class_cost_ns(class);
            let observed = if c.samples > 0 { f(c.observed_ns / 1e3, 1) } else { "-".into() };
            t.row(vec![
                class.name(),
                f(predicted_ns / 1e3, 1),
                observed,
                f(c.bias, 2),
                c.samples.to_string(),
                c.inline_serial.to_string(),
            ]);
        }
        let mut out = if t.is_empty() { String::new() } else { t.render() };
        let fmt_n = |x: Option<usize>| x.map_or("-".to_string(), |n| n.to_string());
        let matmul_x = cm.crossover(cores, &crate::bench::kernel::MATMUL_SIZES, &|n| {
            matmul_work_est(n)
        });
        let sort_x =
            cm.crossover(cores, &crate::bench::kernel::SORT_SIZES, &|n| sort_work_est(n));
        out.push_str(&format!(
            "cost model: cores={} crossover matmul n={} sort n={} inline_serial={}\n",
            cores,
            fmt_n(matmul_x),
            fmt_n(sort_x),
            self.inline_count(),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ServeCostModel {
        ServeCostModel::new(OverheadParams::paper_2022(), 4)
    }

    #[test]
    fn below_crossover_shapes_inline_and_large_ones_pool() {
        let m = model();
        // Every default loadgen shape sits below the paper_2022 4-core
        // crossover, so the CI smoke's inline count is deterministic.
        for kind in [
            TraceKind::Matmul { n: 24 },
            TraceKind::Matmul { n: 48 },
            TraceKind::Sort { n: 300 },
            TraceKind::Sort { n: 999 },
        ] {
            assert!(m.should_inline(&kind), "{kind:?} is below crossover");
        }
        assert!(!m.should_inline(&TraceKind::Matmul { n: 512 }));
        assert!(!m.should_inline(&TraceKind::Sort { n: 100_000 }));
    }

    #[test]
    fn learned_bias_can_flip_the_inline_decision() {
        let m = model();
        let kind = TraceKind::Matmul { n: 128 };
        assert!(!m.should_inline(&kind), "above crossover at unit bias");
        // The pool consistently takes ~4× the static parallel prediction
        // (contention the model never priced): the bias correction pulls
        // the class under the crossover.
        let est = super::estimate(&kind);
        let (_, parallel_ns) =
            m.table.static_model().predict_parallel_ns(&est, m.table.cores());
        for _ in 0..40 {
            m.observe(&kind, parallel_ns * 8.0 / 1e3);
        }
        assert!(m.should_inline(&kind), "learned slowdown must flip the decision");
    }

    #[test]
    fn predicted_wait_needs_evidence_then_scales_with_depth() {
        let m = model();
        let class = ShapeClass::of(&TraceKind::Sort { n: 300 });
        assert_eq!(m.predicted_wait_us(class, 5), None, "no samples: no prediction");
        for _ in 0..10 {
            m.observe(&TraceKind::Sort { n: 300 }, 200.0); // 200µs service
        }
        let w3 = m.predicted_wait_us(class, 3).unwrap();
        let w6 = m.predicted_wait_us(class, 6).unwrap();
        assert!((w3 - 600.0).abs() < 30.0, "3 deep ≈ 600µs: {w3}");
        assert!((w6 - 2.0 * w3).abs() < 1e-6, "wait is linear in depth");
        assert_eq!(m.predicted_wait_us(class, 0), Some(0.0));
    }

    #[test]
    fn class_weights_rank_wide_matmul_above_thin_sort() {
        let m = model();
        let wide = ShapeClass::of(&TraceKind::Matmul { n: 256 });
        let thin = ShapeClass::of(&TraceKind::Sort { n: 300 });
        assert!(
            m.class_cost_ns(wide) > 100.0 * m.class_cost_ns(thin),
            "static weights: {} vs {}",
            m.class_cost_ns(wide),
            m.class_cost_ns(thin)
        );
        // Observed history overrides the static weight.
        for _ in 0..10 {
            m.observe(&TraceKind::Sort { n: 300 }, 50_000.0); // 50ms measured
        }
        assert!((m.class_cost_ns(thin) - 50_000_000.0).abs() < 500_000.0);
    }

    #[test]
    fn render_shows_classes_with_history_and_the_crossover_trailer() {
        let m = model();
        let quiet = m.render();
        assert!(!quiet.contains("cost model (per shape class)"), "no rows yet: {quiet}");
        assert!(quiet.contains("cost model: cores=4 crossover matmul n=64 sort n="), "{quiet}");
        m.observe(&TraceKind::Matmul { n: 48 }, 120.0);
        m.note_inline(&TraceKind::Matmul { n: 48 });
        let s = m.render();
        assert!(s.contains("cost model (per shape class)"), "{s}");
        assert!(s.contains("matmul/2^5"), "{s}");
        assert!(s.contains("inline_serial=1"), "{s}");
    }
}
