//! Deterministic fault injection and the wire error taxonomy.
//!
//! The paper's thesis is that parallelism overheads must be managed at
//! the root or they surface at execution time — and the nastiest place
//! they surface is during *compound* failure: a lane dying mid-flight
//! while a client wedges and a drain races a rebalance. This module
//! makes those failures reproducible:
//!
//! * [`FaultPlan`] — a seeded schedule of injected faults, armed via
//!   `--faults <spec>` (or `[faults]` in a serving config), off by
//!   default. Each injection site in the serving stack asks
//!   [`FaultPlan::should_fire`] before proceeding; the plan decides
//!   deterministically (exact Nth-opportunity triggers) or
//!   pseudo-randomly (seeded per-opportunity rates, PCG32). A disarmed
//!   plan leaves the serving output byte-identical to a build without
//!   this module — hooks render nothing and count nothing.
//! * [`FaultKind`] — the six injected failure modes.
//! * [`ErrCode`] — the wire error taxonomy. Every `ERR` line the server
//!   can emit classifies into exactly one code with a fixed
//!   retriable/fatal verdict, so clients need one retry policy instead
//!   of per-string special cases. The taxonomy classifies the existing
//!   wire strings; it does not change them (`--faults off` output stays
//!   byte-identical across versions).
//!
//! Injected faults are never silent: every firing is recorded as a
//! fault event in telemetry and lands in the serving [`Ledger`]'s
//! `faults` counter, so the overhead they cause is attributed in the
//! same books as every other source.
//!
//! [`Ledger`]: crate::overhead::Ledger

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::report::table::AsciiTable;
use crate::util::Pcg32;

/// The injected failure modes, one per serving-stack layer boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic a dispatcher lane thread at its next batch opportunity.
    /// Exercises the lane-loop recovery path: the queue closes, queued
    /// envelopes are reject-drained (so `admitted == finished` holds),
    /// and blocked readers get `ERR internal dispatcher unavailable`.
    KillLane,
    /// Wedge a client connection: write half of one reply line, flush,
    /// stall briefly, then close without the rest. The client sees a
    /// truncated line and EOF — the classic half-written-then-silent
    /// peer.
    WedgeClient,
    /// Stall the dispatcher between obtaining a batch and executing it,
    /// inflating queue waits behind it (scheduling overhead surfaced).
    StallDispatcher,
    /// Drop a reply before it reaches the socket: the request executed
    /// exactly once, but the client never hears about it and the
    /// connection closes.
    DropReply,
    /// Abort a single-flight leader right after registration: followers
    /// coalesced onto it wake and retry as their own leaders.
    AbortFlight,
    /// Delay a stolen batch before execution, stretching the cross-lane
    /// migration window.
    DelaySteal,
}

impl FaultKind {
    /// All kinds, in spec/report order. Index = the kind's slot in the
    /// plan's counter arrays.
    pub const ALL: [FaultKind; 6] = [
        FaultKind::KillLane,
        FaultKind::WedgeClient,
        FaultKind::StallDispatcher,
        FaultKind::DropReply,
        FaultKind::AbortFlight,
        FaultKind::DelaySteal,
    ];

    /// The spec name, as written in `--faults` and rendered in STATS.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::KillLane => "kill-lane",
            FaultKind::WedgeClient => "wedge-client",
            FaultKind::StallDispatcher => "stall-dispatcher",
            FaultKind::DropReply => "drop-reply",
            FaultKind::AbortFlight => "abort-flight",
            FaultKind::DelaySteal => "delay-steal",
        }
    }

    fn parse(s: &str) -> Option<FaultKind> {
        FaultKind::ALL.into_iter().find(|k| k.name() == s)
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// When a rule fires at its injection site.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Trigger {
    /// Fire exactly once, on the Nth opportunity (1-based).
    At(u64),
    /// Fire on each opportunity with probability `p`, decided by a
    /// PCG32 stream keyed on (plan seed, kind, opportunity index) — so
    /// the schedule replays bit-identically from the seed regardless of
    /// thread interleaving between *different* kinds.
    Rate(f64),
}

/// A seeded fault schedule. Constructed once at server start from the
/// `--faults` spec; injection sites share it behind the server's
/// `Arc<Shared>` and ask [`should_fire`](FaultPlan::should_fire) at
/// each opportunity. Counters are atomics so sites never contend on a
/// lock in the hot path.
#[derive(Debug)]
pub struct FaultPlan {
    spec: String,
    seed: u64,
    rules: [Option<Trigger>; 6],
    /// Opportunities seen per kind (every `should_fire` call on a kind
    /// that has a rule).
    sites: [AtomicU64; 6],
    /// Faults actually injected per kind.
    fired: [AtomicU64; 6],
}

/// Default PRNG seed when the spec doesn't carry `seed=`.
pub const DEFAULT_FAULT_SEED: u64 = 42;

impl FaultPlan {
    /// Parse a `--faults` spec. Grammar (comma-separated, no spaces):
    ///
    /// ```text
    /// off
    /// [seed=N,]kind=@K[,kind=@K|kind=P ...]
    /// ```
    ///
    /// where `kind` is one of the [`FaultKind`] names, `@K` fires
    /// exactly on the K-th opportunity (1-based), and `P` in `(0, 1]`
    /// fires with that probability per opportunity. `off` (the default)
    /// returns `Ok(None)`: no plan, no hooks, no output.
    pub fn parse(spec: &str) -> Result<Option<FaultPlan>> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "off" {
            return Ok(None);
        }
        let mut seed = DEFAULT_FAULT_SEED;
        let mut rules: [Option<Trigger>; 6] = [None; 6];
        for item in spec.split(',') {
            let Some((key, val)) = item.split_once('=') else {
                bail!("fault spec item {item:?} is not key=value (spec {spec:?})");
            };
            if key == "seed" {
                seed = val
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault seed {val:?} is not a u64"))?;
                continue;
            }
            let Some(kind) = FaultKind::parse(key) else {
                bail!(
                    "unknown fault kind {key:?}; expected one of {}",
                    FaultKind::ALL.map(|k| k.name()).join(", ")
                );
            };
            if rules[kind.idx()].is_some() {
                bail!("duplicate fault kind {key:?} in spec {spec:?}");
            }
            let trigger = if let Some(n) = val.strip_prefix('@') {
                let n: u64 = n
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault trigger {val:?} needs @N with N ≥ 1"))?;
                if n == 0 {
                    bail!("fault trigger @0 never fires; opportunities are 1-based");
                }
                Trigger::At(n)
            } else {
                let p: f64 = val
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault rate {val:?} is not a probability"))?;
                if !(p > 0.0 && p <= 1.0) {
                    bail!("fault rate {val:?} must be in (0, 1]");
                }
                Trigger::Rate(p)
            };
            rules[kind.idx()] = Some(trigger);
        }
        if rules.iter().all(|r| r.is_none()) {
            bail!("fault spec {spec:?} sets a seed but no fault kinds");
        }
        Ok(Some(FaultPlan {
            spec: spec.to_string(),
            seed,
            rules,
            sites: std::array::from_fn(|_| AtomicU64::new(0)),
            fired: std::array::from_fn(|_| AtomicU64::new(0)),
        }))
    }

    /// Should this opportunity for `kind` inject its fault? Counts the
    /// opportunity and decides per the kind's trigger. Kinds with no
    /// rule always answer `false` without counting — a plan armed for
    /// `kill-lane` leaves every other site untouched.
    pub fn should_fire(&self, kind: FaultKind) -> bool {
        let i = kind.idx();
        let Some(rule) = self.rules[i] else {
            return false;
        };
        let n = self.sites[i].fetch_add(1, Ordering::Relaxed) + 1;
        let fire = match rule {
            Trigger::At(k) => n == k,
            Trigger::Rate(p) => {
                // Key the stream on (seed, kind, opportunity) so the
                // verdict for opportunity n is a pure function of the
                // spec — independent of scheduling order across kinds.
                let key = self
                    .seed
                    ^ ((i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)).wrapping_add(n);
                Pcg32::new(key).f64() < p
            }
        };
        if fire {
            self.fired[i].fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// The spec string this plan was parsed from.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// Faults injected so far for one kind.
    pub fn fired(&self, kind: FaultKind) -> u64 {
        self.fired[kind.idx()].load(Ordering::Relaxed)
    }

    /// Total faults injected so far, across kinds.
    pub fn fired_total(&self) -> u64 {
        self.fired.iter().map(|f| f.load(Ordering::Relaxed)).sum()
    }

    /// Render the fault-injection table for STATS/DRAIN. Only called
    /// when a plan is armed — a disarmed server renders nothing, which
    /// is what keeps `--faults off` output byte-identical to builds
    /// that predate fault injection.
    pub fn render(&self) -> String {
        let mut t = AsciiTable::new(
            "fault injection (deterministic, seeded)",
            &["kind", "trigger", "opportunities", "injected"],
        );
        for kind in FaultKind::ALL {
            let Some(rule) = self.rules[kind.idx()] else {
                continue;
            };
            let trigger = match rule {
                Trigger::At(k) => format!("@{k}"),
                Trigger::Rate(p) => format!("p={p}"),
            };
            t.row(vec![
                kind.name().to_string(),
                trigger,
                self.sites[kind.idx()].load(Ordering::Relaxed).to_string(),
                self.fired(kind).to_string(),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "faults: spec={} seed={} injected={}\n",
            self.spec,
            self.seed,
            self.fired_total()
        ));
        out
    }
}

/// The wire error taxonomy: every `ERR` line the server can emit maps
/// to exactly one code with a fixed retriable/fatal verdict. This is a
/// *classification* of the existing wire strings, not a new wire format
/// — the strings themselves are frozen by the byte-identity conformance
/// tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// Hard admission rejection: the routed lane's queue is at its
    /// depth bound. Transient by construction — retriable.
    Busy,
    /// Soft admission rejection from the adaptive governor: predicted
    /// queue wait would blow the SLO. Transient — retriable.
    Overloaded,
    /// The server is draining; it will never accept this request.
    /// Fatal — go elsewhere.
    Draining,
    /// The serving stack itself failed (dead dispatcher, engine panic,
    /// injected fault). Fatal: retrying against a dead lane just spins.
    Fault,
    /// The request never made sense (unknown command, bad argument,
    /// empty line). Fatal: resending the same bytes cannot help.
    Malformed,
}

impl ErrCode {
    /// The canonical code token, as documented in PROTOCOL.md.
    pub fn code(self) -> &'static str {
        match self {
            ErrCode::Busy => "BUSY",
            ErrCode::Overloaded => "OVERLOADED",
            ErrCode::Draining => "DRAINING",
            ErrCode::Fault => "FAULT",
            ErrCode::Malformed => "MALFORMED",
        }
    }

    /// Whether a client should retry with backoff (`true`) or give up
    /// (`false`). The whole point of the taxonomy: one policy, keyed on
    /// the code, instead of per-string special cases.
    pub fn retriable(self) -> bool {
        matches!(self, ErrCode::Busy | ErrCode::Overloaded)
    }

    /// Classify a wire reply line. Returns `None` for non-error lines
    /// (`OK …`, `PONG`, …). Recognises both the token-first forms
    /// (`ERR BUSY …`) and the legacy prose forms the server still emits
    /// (`ERR internal dispatcher unavailable`, `ERR MATMUL needs n
    /// in …`, `ERR unknown command …`).
    pub fn classify(reply: &str) -> Option<ErrCode> {
        let rest = reply.strip_prefix("ERR ")?;
        let first = rest.split_whitespace().next().unwrap_or("");
        match first {
            "BUSY" => Some(ErrCode::Busy),
            "OVERLOADED" => Some(ErrCode::Overloaded),
            "DRAINING" => Some(ErrCode::Draining),
            "FAULT" => Some(ErrCode::Fault),
            "MALFORMED" => Some(ErrCode::Malformed),
            // Legacy prose forms, frozen on the wire by the conformance
            // tests but classified here so clients get one policy.
            "internal" => Some(ErrCode::Fault),
            "unknown" | "empty" => Some(ErrCode::Malformed),
            _ => {
                if rest.contains("needs n in") {
                    Some(ErrCode::Malformed)
                } else if rest.contains("failed on engine") {
                    Some(ErrCode::Fault)
                } else {
                    None
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_and_empty_specs_disarm() {
        assert!(FaultPlan::parse("off").unwrap().is_none());
        assert!(FaultPlan::parse("").unwrap().is_none());
        assert!(FaultPlan::parse("  off  ").unwrap().is_none());
    }

    #[test]
    fn at_trigger_fires_exactly_once_on_the_nth_opportunity() {
        let plan = FaultPlan::parse("kill-lane=@3").unwrap().unwrap();
        let fires: Vec<bool> =
            (0..6).map(|_| plan.should_fire(FaultKind::KillLane)).collect();
        assert_eq!(fires, vec![false, false, true, false, false, false]);
        assert_eq!(plan.fired(FaultKind::KillLane), 1);
        assert_eq!(plan.fired_total(), 1);
    }

    #[test]
    fn unruled_kinds_never_fire_or_count() {
        let plan = FaultPlan::parse("kill-lane=@1").unwrap().unwrap();
        assert!(!plan.should_fire(FaultKind::DropReply));
        assert_eq!(plan.fired(FaultKind::DropReply), 0);
        let s = plan.render();
        assert!(s.contains("kill-lane"), "{s}");
        assert!(!s.contains("drop-reply"), "unruled kinds stay out of the table: {s}");
    }

    #[test]
    fn rate_trigger_replays_bit_identically_from_the_seed() {
        let a = FaultPlan::parse("seed=7,drop-reply=0.5").unwrap().unwrap();
        let b = FaultPlan::parse("seed=7,drop-reply=0.5").unwrap().unwrap();
        let sa: Vec<bool> = (0..64).map(|_| a.should_fire(FaultKind::DropReply)).collect();
        let sb: Vec<bool> = (0..64).map(|_| b.should_fire(FaultKind::DropReply)).collect();
        assert_eq!(sa, sb);
        assert!(sa.iter().any(|&f| f), "p=0.5 over 64 opportunities must fire");
        assert!(sa.iter().any(|&f| !f), "and must also skip");
        let c = FaultPlan::parse("seed=8,drop-reply=0.5").unwrap().unwrap();
        let sc: Vec<bool> = (0..64).map(|_| c.should_fire(FaultKind::DropReply)).collect();
        assert_ne!(sa, sc, "a different seed gives a different schedule");
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for bad in [
            "kill-lane",            // not key=value
            "nuke-it=@1",           // unknown kind
            "kill-lane=@0",         // 1-based
            "kill-lane=1.5",        // rate out of range
            "kill-lane=0",          // rate must be > 0
            "seed=42",              // seed with no kinds
            "seed=x,kill-lane=@1",  // unparseable seed
            "kill-lane=@1,kill-lane=@2", // duplicate kind
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec {bad:?} must be rejected");
        }
    }

    #[test]
    fn render_carries_spec_seed_and_counts() {
        let plan = FaultPlan::parse("seed=9,wedge-client=@2").unwrap().unwrap();
        plan.should_fire(FaultKind::WedgeClient);
        plan.should_fire(FaultKind::WedgeClient);
        let s = plan.render();
        assert!(s.contains("fault injection"), "{s}");
        assert!(s.contains("wedge-client"), "{s}");
        assert!(s.contains("@2"), "{s}");
        assert!(s.contains("faults: spec=seed=9,wedge-client=@2 seed=9 injected=1"), "{s}");
    }

    #[test]
    fn classify_covers_every_wire_error_the_server_emits() {
        let cases = [
            ("ERR BUSY lane 0 full (depth 64)", Some(ErrCode::Busy)),
            ("ERR OVERLOADED p90=1234 slo=1000", Some(ErrCode::Overloaded)),
            ("ERR DRAINING SORT rejected: server is draining", Some(ErrCode::Draining)),
            ("ERR FAULT injected: lane killed", Some(ErrCode::Fault)),
            ("ERR MALFORMED", Some(ErrCode::Malformed)),
            ("ERR internal dispatcher unavailable", Some(ErrCode::Fault)),
            ("ERR MATMUL needs n in 1..=4096", Some(ErrCode::Malformed)),
            ("ERR SORT needs n in 1..=4096", Some(ErrCode::Malformed)),
            ("ERR unknown command \"FROB\"", Some(ErrCode::Malformed)),
            ("ERR empty request", Some(ErrCode::Malformed)),
            ("ERR SORT n=100 failed on engine cpu-serial", Some(ErrCode::Fault)),
            ("OK MATMUL n=24 engine=xla us=1.0 queue_us=0.5 checksum=1.0000", None),
            ("PONG", None),
            ("DRAINED", None),
        ];
        for (line, want) in cases {
            assert_eq!(ErrCode::classify(line), want, "line {line:?}");
        }
    }

    #[test]
    fn retriable_verdicts_are_pinned() {
        assert!(ErrCode::Busy.retriable());
        assert!(ErrCode::Overloaded.retriable());
        assert!(!ErrCode::Draining.retriable());
        assert!(!ErrCode::Fault.retriable());
        assert!(!ErrCode::Malformed.retriable());
    }

    #[test]
    fn codes_render_their_wire_tokens() {
        for (code, tok) in [
            (ErrCode::Busy, "BUSY"),
            (ErrCode::Overloaded, "OVERLOADED"),
            (ErrCode::Draining, "DRAINING"),
            (ErrCode::Fault, "FAULT"),
            (ErrCode::Malformed, "MALFORMED"),
        ] {
            assert_eq!(code.code(), tok);
        }
    }
}
