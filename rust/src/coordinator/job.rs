//! Job model for the coordinator: typed requests + results.

use crate::workload::traces::{TraceJob, TraceKind};

/// A schedulable request.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub id: u64,
    pub kind: TraceKind,
    pub seed: u64,
    /// Arrival offset, µs (0 for ad-hoc submissions).
    pub arrival_us: u64,
}

impl Job {
    pub fn from_trace(id: u64, t: &TraceJob) -> Job {
        Job { id, kind: t.kind, seed: t.seed, arrival_us: t.arrival_us }
    }

    /// Stable key for shape-batching: jobs with equal keys can share a
    /// compiled executable / decision.
    pub fn shape_key(&self) -> String {
        match self.kind {
            TraceKind::Matmul { n } => format!("matmul/{n}"),
            TraceKind::Sort { n } => format!("sort/{n}"),
        }
    }
}

/// Which engine the policy routed a job to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoutedEngine {
    Xla,
    CpuSerial,
    CpuParallel,
    /// Served from the warm result cache — no engine executed at all.
    /// Never returned by routing; stamped by the server's hit path so
    /// replies and telemetry name where the answer came from.
    Cache,
    /// Executed serially right on the lane thread because the cost model
    /// predicted the job below the serial/parallel crossover — the
    /// fork-join machinery (and its α/β/γ/δ overhead) was skipped
    /// entirely. Never returned by routing; stamped by the dispatcher's
    /// cost-model path (`--cost-model on`). Checksums are bit-identical
    /// to pooled execution of the same `(kind, n, seed)`.
    SerialInline,
}

impl RoutedEngine {
    pub fn name(&self) -> &'static str {
        match self {
            RoutedEngine::Xla => "xla",
            RoutedEngine::CpuSerial => "cpu-serial",
            RoutedEngine::CpuParallel => "cpu-parallel",
            RoutedEngine::Cache => "cache",
            RoutedEngine::SerialInline => "serial-inline",
        }
    }
}

/// Completed-job record.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub shape_key: String,
    pub engine: RoutedEngine,
    /// Wall-clock service time, µs.
    pub service_us: f64,
    /// Time spent waiting in the serving admission queue, µs (0 for
    /// direct submissions that never queue).
    pub queue_us: f64,
    /// Checksum of the output (cross-engine sanity).
    pub checksum: f64,
    pub ok: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_keys() {
        let j = Job { id: 1, kind: TraceKind::Matmul { n: 64 }, seed: 0, arrival_us: 0 };
        assert_eq!(j.shape_key(), "matmul/64");
        let s = Job { id: 2, kind: TraceKind::Sort { n: 1000 }, seed: 0, arrival_us: 0 };
        assert_eq!(s.shape_key(), "sort/1000");
    }

    #[test]
    fn from_trace_copies_fields() {
        let t = TraceJob { arrival_us: 55, kind: TraceKind::Sort { n: 10 }, seed: 9 };
        let j = Job::from_trace(3, &t);
        assert_eq!((j.id, j.arrival_us, j.seed), (3, 55, 9));
    }
}
