//! Sharded dispatch lanes: per-shape-class queues with work stealing —
//! the serving layer's answer to head-of-line blocking.
//!
//! PR 1's single dispatcher was an unmanaged synchronization root: one
//! slow matmul batch head-of-line-blocked every queued sort, and the
//! whole cost surfaced as `queue_ns` in the serving ledger. The paper's
//! thesis says such overheads must be managed "to the root level", so the
//! lane pool removes the root cause structurally instead of measuring it
//! away:
//!
//! * every job maps to a [`ShapeClass`] — its kind (matmul vs. sort)
//!   plus a power-of-two size bucket;
//! * **kinds partition the lane pool** (matmul classes own the first
//!   half, rounded up; sort classes the rest), so with ≥ 2 lanes a slow
//!   matmul can never queue ahead of a sort, *by construction*;
//! * size buckets hash (FNV-1a) onto the lanes within their kind's
//!   partition, so hot shapes spread across a wider pool;
//! * an idle lane **steals** a shape-pure run from a sibling's queue
//!   head ([`BoundedQueue::try_pop_run`] moves the run under one lock,
//!   keeping delivery exactly-once), so sharding never strands work.
//!
//! Batches stay shape-pure in every path: a lane's own batch is a
//! same-kind run from its queue head, and a stolen batch is a same-kind
//! run from the victim's head. The server spawns one dispatcher thread
//! per lane; each owns its own `Coordinator` (and CPU thread pool), so a
//! saturated lane cannot stall its siblings' execution either.
//!
//! The lane is also the unit of **admission feedback**: the governor
//! ([`super::admission`]) keeps one rolling queue-wait window per lane,
//! keyed by this module's routing — so a matmul lane blowing its SLO
//! sheds matmuls while the sort lanes keep admitting.

use super::queue::{BoundedQueue, PopTimeout};
use super::{Job, JobResult};
use crate::workload::traces::TraceKind;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// How long a lane blocks on its own queue before re-checking for
/// stealable work elsewhere (and how long it naps once its queue is
/// closed but siblings are still draining).
pub const STEAL_TICK: Duration = Duration::from_millis(1);

/// One queued request: the job, the lane it was admitted to, its
/// admission timestamp (queue-wait clock), and the reply rendezvous
/// back to the owning reader.
#[derive(Debug)]
pub struct Envelope {
    pub job: Job,
    /// The lane this envelope was admitted to — set authoritatively by
    /// [`LanePool::admit`]. Queue-wait attribution (admission governor,
    /// per-lane telemetry) keys on this, not on whichever dispatcher
    /// ends up executing the job after a steal.
    pub lane: usize,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<JobResult>,
}

/// A dispatched unit of work: a shape-pure envelope run plus whether it
/// was stolen from a sibling lane.
#[derive(Debug)]
pub struct LaneBatch {
    pub envelopes: Vec<Envelope>,
    pub stolen: bool,
}

/// The unit of lane affinity: job kind plus power-of-two size bucket.
/// Jobs in one class share execution character (engine choice, service
/// time magnitude), so giving each class a stable lane keeps slow and
/// fast traffic out of each other's queues.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeClass {
    /// 0 = matmul, 1 = sort.
    kind: u8,
    /// `floor(log2(n))` of the job size.
    bucket: u8,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl ShapeClass {
    pub fn of(kind: &TraceKind) -> ShapeClass {
        let (k, n) = match kind {
            TraceKind::Matmul { n } => (0u8, *n),
            TraceKind::Sort { n } => (1u8, *n),
        };
        let bucket = (usize::BITS - 1 - n.max(1).leading_zeros()) as u8;
        ShapeClass { kind: k, bucket }
    }

    /// Stable lane assignment. With one lane everything shares it; with
    /// more, matmul classes own lanes `[0, ceil(lanes/2))` and sort
    /// classes own the rest, and the size bucket hashes within the
    /// kind's span. The kind partition is the head-of-line guarantee:
    /// for `lanes >= 2`, no matmul ever queues on a sort lane.
    pub fn lane(&self, lanes: usize) -> usize {
        let lanes = lanes.max(1);
        if lanes == 1 {
            return 0;
        }
        let sort_span = lanes / 2;
        let (base, span) =
            if self.kind == 0 { (0, lanes - sort_span) } else { (lanes - sort_span, sort_span) };
        base + (fnv1a(&[self.kind, self.bucket]) % span as u64) as usize
    }

    /// Human-readable class label, e.g. `matmul/2^6`.
    pub fn name(&self) -> String {
        let kind = if self.kind == 0 { "matmul" } else { "sort" };
        format!("{kind}/2^{}", self.bucket)
    }
}

fn same_shape(a: &Envelope, b: &Envelope) -> bool {
    a.job.kind == b.job.kind
}

/// The sharded admission layer: one bounded queue per lane, shape-class
/// routing on push, work stealing on pop.
pub struct LanePool {
    queues: Vec<BoundedQueue<Envelope>>,
    steal: bool,
}

impl LanePool {
    /// `lanes` queues (min 1) of `depth` each; `steal` enables the idle
    /// lane fallback.
    pub fn new(lanes: usize, depth: usize, steal: bool) -> LanePool {
        LanePool { queues: (0..lanes.max(1)).map(|_| BoundedQueue::new(depth)).collect(), steal }
    }

    pub fn lane_count(&self) -> usize {
        self.queues.len()
    }

    /// Stealing is meaningful only with siblings to steal from.
    pub fn steal_enabled(&self) -> bool {
        self.steal && self.queues.len() > 1
    }

    /// The lane a job of this kind routes to.
    pub fn route(&self, kind: &TraceKind) -> usize {
        ShapeClass::of(kind).lane(self.queues.len())
    }

    /// A lane's queue (panics on an out-of-range lane index).
    pub fn queue(&self, lane: usize) -> &BoundedQueue<Envelope> {
        &self.queues[lane]
    }

    /// Admission: push the envelope onto its routed lane, stamping
    /// [`Envelope::lane`] so downstream attribution cannot diverge from
    /// the queue actually used. `Ok(lane)` on success; `Err(envelope)`
    /// when that lane is at depth or closed — the caller turns that
    /// into `ERR BUSY` / `ERR DRAINING`.
    pub fn admit(&self, mut env: Envelope) -> Result<usize, Envelope> {
        let lane = self.route(&env.job.kind);
        env.lane = lane;
        self.queues[lane].try_push(env).map(|()| lane)
    }

    /// Non-blocking steal: scan the sibling lanes round-robin starting
    /// after `thief` and take one shape-pure run (≤ `max`) from the
    /// first non-empty queue head. Exactly-once holds because the run
    /// moves out under the victim queue's lock.
    pub fn steal(&self, thief: usize, max: usize) -> Option<(usize, Vec<Envelope>)> {
        let n = self.queues.len();
        for off in 1..n {
            let victim = (thief + off) % n;
            let run = self.queues[victim].try_pop_run(max, same_shape);
            if !run.is_empty() {
                return Some((victim, run));
            }
        }
        None
    }

    /// Close every lane queue (graceful: queued work still drains).
    pub fn close_all(&self) {
        for q in &self.queues {
            q.close();
        }
    }

    /// Items currently queued across all lanes.
    pub fn total_len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Largest per-lane occupancy high-water mark.
    pub fn max_occupancy(&self) -> usize {
        self.queues.iter().map(|q| q.max_len()).max().unwrap_or(0)
    }

    /// True once every lane queue is closed and empty.
    pub fn drained(&self) -> bool {
        self.queues.iter().all(|q| q.is_closed() && q.is_empty())
    }

    /// Next unit of work for `lane`'s dispatcher: the local queue first
    /// (with shape-batch formation up to `max` wide over `linger`), then
    /// a steal from a sibling when the local queue stays empty for a
    /// [`STEAL_TICK`]. Returns `None` only when every lane is closed and
    /// drained — the dispatcher's exit condition.
    pub fn next_batch(&self, lane: usize, max: usize, linger: Duration) -> Option<LaneBatch> {
        let own = &self.queues[lane];
        loop {
            match own.pop_timeout(STEAL_TICK) {
                PopTimeout::Item(first) => {
                    let mut batch = vec![first];
                    let extra = own.drain_run(&batch[0], max.max(1) - 1, linger, same_shape);
                    batch.extend(extra);
                    return Some(LaneBatch { envelopes: batch, stolen: false });
                }
                PopTimeout::Closed => {
                    // Local work is done. Help drain the siblings, or
                    // exit once the whole pool is dry.
                    if !self.steal_enabled() {
                        return None;
                    }
                    match self.steal(lane, max) {
                        Some((_victim, run)) => {
                            return Some(LaneBatch { envelopes: run, stolen: true })
                        }
                        None => {
                            if self.drained() {
                                return None;
                            }
                            std::thread::sleep(STEAL_TICK);
                        }
                    }
                }
                PopTimeout::TimedOut => {
                    if self.steal_enabled() {
                        if let Some((_victim, run)) = self.steal(lane, max) {
                            return Some(LaneBatch { envelopes: run, stolen: true });
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(id: u64, kind: TraceKind) -> (Envelope, mpsc::Receiver<JobResult>) {
        let (tx, rx) = mpsc::channel();
        let e = Envelope {
            job: Job { id, kind, seed: 0, arrival_us: 0 },
            lane: 0, // stamped by admit(); raw-push tests leave it unused
            enqueued: Instant::now(),
            reply: tx,
        };
        (e, rx)
    }

    #[test]
    fn shape_class_buckets_by_log2() {
        let a = ShapeClass::of(&TraceKind::Matmul { n: 64 });
        let b = ShapeClass::of(&TraceKind::Matmul { n: 100 });
        let c = ShapeClass::of(&TraceKind::Matmul { n: 128 });
        assert_eq!(a.name(), "matmul/2^6");
        assert_eq!(b.name(), "matmul/2^6", "64..127 share a bucket");
        assert_eq!(c.name(), "matmul/2^7");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(ShapeClass::of(&TraceKind::Sort { n: 1000 }).name(), "sort/2^9");
    }

    #[test]
    fn kinds_partition_the_lane_pool() {
        for lanes in 2..6 {
            for n in [1usize, 24, 300, 600, 1024, 4096] {
                let m = ShapeClass::of(&TraceKind::Matmul { n }).lane(lanes);
                let s = ShapeClass::of(&TraceKind::Sort { n }).lane(lanes);
                let matmul_span = lanes - lanes / 2;
                assert!(m < matmul_span, "matmul/{n} on lane {m} of {lanes}");
                assert!(s >= matmul_span && s < lanes, "sort/{n} on lane {s} of {lanes}");
            }
        }
        // Degenerate single lane: everything shares it.
        assert_eq!(ShapeClass::of(&TraceKind::Matmul { n: 64 }).lane(1), 0);
        assert_eq!(ShapeClass::of(&TraceKind::Sort { n: 64 }).lane(1), 0);
    }

    #[test]
    fn admit_routes_to_the_shape_class_lane() {
        let pool = LanePool::new(2, 8, false);
        let (m, _mrx) = env(1, TraceKind::Matmul { n: 600 });
        let (s, _srx) = env(2, TraceKind::Sort { n: 300 });
        assert_eq!(pool.admit(m).unwrap(), 0, "matmul owns lane 0");
        assert_eq!(pool.admit(s).unwrap(), 1, "sort owns lane 1");
        assert_eq!(pool.queue(0).len(), 1);
        assert_eq!(pool.queue(1).len(), 1);
        assert_eq!(pool.total_len(), 2);
        assert_eq!(pool.queue(0).pop().unwrap().lane, 0, "admit stamps the admitted lane");
        assert_eq!(pool.queue(1).pop().unwrap().lane, 1, "admit stamps the admitted lane");
    }

    #[test]
    fn admit_rejects_at_lane_depth() {
        let pool = LanePool::new(2, 1, false);
        let (a, _arx) = env(1, TraceKind::Sort { n: 100 });
        let (b, _brx) = env(2, TraceKind::Sort { n: 100 });
        assert!(pool.admit(a).is_ok());
        let back = pool.admit(b).expect_err("lane at depth rejects");
        assert_eq!(back.job.id, 2, "rejected envelope handed back");
        assert!(pool.queue(0).is_empty(), "matmul lane unused by sorts");
    }

    #[test]
    fn steal_takes_a_shape_pure_run_from_a_sibling() {
        let pool = LanePool::new(2, 8, true);
        let mut rxs = Vec::new();
        for (id, kind) in [
            (1, TraceKind::Sort { n: 100 }),
            (2, TraceKind::Sort { n: 200 }),
            (3, TraceKind::Matmul { n: 16 }),
        ] {
            // Push everything onto the sort lane directly to stage a
            // mixed backlog (admit would route the matmul elsewhere).
            let (e, rx) = env(id, kind);
            pool.queue(1).try_push(e).map_err(|_| "push").unwrap();
            rxs.push(rx);
        }
        let (victim, run) = pool.steal(0, 8).expect("backlog to steal");
        assert_eq!(victim, 1);
        let ids: Vec<u64> = run.iter().map(|e| e.job.id).collect();
        assert_eq!(ids, vec![1, 2], "same-kind head run only, FIFO preserved");
        assert_eq!(pool.queue(1).len(), 1, "the mismatched matmul stays queued");
    }

    #[test]
    fn next_batch_drains_own_then_steals_then_exits() {
        let pool = LanePool::new(2, 8, true);
        let (a, _arx) = env(1, TraceKind::Matmul { n: 32 });
        let (b, _brx) = env(2, TraceKind::Sort { n: 100 });
        pool.admit(a).unwrap();
        pool.admit(b).unwrap();
        pool.close_all();
        // Lane 0 takes its own matmul first...
        let own = pool.next_batch(0, 8, Duration::ZERO).expect("own work first");
        assert!(!own.stolen);
        assert_eq!(own.envelopes[0].job.id, 1);
        // ...then steals the sort stranded on lane 1...
        let stolen = pool.next_batch(0, 8, Duration::ZERO).expect("steals the leftover");
        assert!(stolen.stolen);
        assert_eq!(stolen.envelopes[0].job.id, 2);
        // ...and exits once the pool is dry.
        assert!(pool.next_batch(0, 8, Duration::ZERO).is_none());
        assert!(pool.drained());
    }

    #[test]
    fn next_batch_without_steal_exits_on_own_close() {
        let pool = LanePool::new(2, 8, false);
        let (b, _brx) = env(2, TraceKind::Sort { n: 100 });
        pool.admit(b).unwrap();
        pool.close_all();
        // Lane 0 (matmul lane) has nothing and may not steal: exits even
        // though lane 1 still holds work for its own dispatcher.
        assert!(pool.next_batch(0, 8, Duration::ZERO).is_none());
        assert!(pool.next_batch(1, 8, Duration::ZERO).is_some());
    }
}
