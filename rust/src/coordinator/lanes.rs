//! Sharded dispatch lanes: per-shape-class queues with work stealing —
//! the serving layer's answer to head-of-line blocking.
//!
//! PR 1's single dispatcher was an unmanaged synchronization root: one
//! slow matmul batch head-of-line-blocked every queued sort, and the
//! whole cost surfaced as `queue_ns` in the serving ledger. The paper's
//! thesis says such overheads must be managed "to the root level", so the
//! lane pool removes the root cause structurally instead of measuring it
//! away:
//!
//! * every job maps to a [`ShapeClass`] — its kind (matmul vs. sort)
//!   plus a power-of-two size bucket;
//! * **kinds partition the lane pool** (matmul classes own the first
//!   half, rounded up; sort classes the rest), so with ≥ 2 lanes a slow
//!   matmul can never queue ahead of a sort, *by construction*;
//! * size buckets hash (FNV-1a) onto the lanes within their kind's
//!   partition, so hot shapes spread across a wider pool — and since
//!   the routing layer ([`super::routing`]) became epoch-versioned,
//!   that assignment is a swappable [`super::routing::RoutingTable`]:
//!   the rebalancer may re-bucket a hot class within its kind's span,
//!   while [`LanePool::admit`] stamps every envelope with the
//!   `(lane, epoch)` it was admitted under so in-flight attribution
//!   never mixes regimes;
//! * an idle lane **steals** a shape-pure run from a sibling's queue
//!   head ([`BoundedQueue::try_pop_run`] moves the run under one lock,
//!   keeping delivery exactly-once), so sharding never strands work.
//!
//! Batches stay shape-pure in every path: a lane's own batch is a
//! same-kind run from its queue head, and a stolen batch is a same-kind
//! run from the victim's head. The server spawns one dispatcher thread
//! per lane; each owns its own `Coordinator` (and CPU thread pool), so a
//! saturated lane cannot stall its siblings' execution either.
//!
//! The lane is also the unit of **admission feedback**: the governor
//! ([`super::admission`]) keeps one rolling queue-wait window per lane,
//! keyed by this module's routing — so a matmul lane blowing its SLO
//! sheds matmuls while the sort lanes keep admitting.

use super::queue::{BoundedQueue, PopTimeout};
use super::routing::{self, Router};
use super::{Job, JobResult};
use crate::net::Outbox;
use crate::workload::traces::TraceKind;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// How long a lane blocks on its own queue before re-checking for
/// stealable work elsewhere (and how long it naps once its queue is
/// closed but siblings are still draining).
pub const STEAL_TICK: Duration = Duration::from_millis(1);

/// One queued request: the job, the lane it was admitted to, its
/// admission timestamp (queue-wait clock), and the reply rendezvous
/// back to the owning reader.
#[derive(Debug)]
pub struct Envelope {
    pub job: Job,
    /// The lane this envelope was admitted to — set authoritatively by
    /// [`LanePool::admit`]. Queue-wait attribution (admission governor,
    /// per-lane telemetry) keys on this, not on whichever dispatcher
    /// ends up executing the job after a steal.
    pub lane: usize,
    /// The routing epoch the envelope was admitted under — stamped by
    /// [`LanePool::admit`] from the same table snapshot as `lane`, so a
    /// later epoch swap can never re-attribute an in-flight job: its
    /// queue-wait and steal accounting stay keyed to the regime that
    /// admitted it.
    pub epoch: u64,
    pub enqueued: Instant,
    pub reply: ReplySink,
}

/// A completed (or abandoned) job on its way back to the reactor that
/// admitted it, keyed by request id so the reactor can find the owning
/// connection.
#[derive(Debug)]
pub enum Completion {
    /// The dispatcher executed the job (ok or failed) — the result is
    /// formatted into the wire reply by the reactor.
    Done { id: u64, result: JobResult },
    /// The envelope was dropped without executing (dispatcher died,
    /// reject-drain) — the reactor answers the internal error, exactly
    /// like a threaded reader observing its reply channel disconnect.
    Gone { id: u64 },
}

/// The reply rendezvous back from a dispatcher, abstract over the two
/// IO modes: a blocked reader's mpsc channel (`--io threads`) or the
/// admitting reactor's outbox (`--io reactor`). Consuming `send` keeps
/// delivery exactly-once in both shapes.
#[derive(Debug)]
pub enum ReplySink {
    Channel(mpsc::Sender<JobResult>),
    Outbox(OutboxTicket),
}

impl ReplySink {
    /// Deliver the result. A hung-up receiver (reader gone, reactor
    /// shut) just drops it — same contract the bare channel had.
    pub fn send(self, result: JobResult) {
        match self {
            ReplySink::Channel(tx) => {
                let _ = tx.send(result);
            }
            ReplySink::Outbox(mut ticket) => ticket.deliver(result),
        }
    }
}

/// An outbox reservation for one admitted request. Mirrors the mpsc
/// sender's disconnect semantics: dropping the ticket undelivered
/// pushes [`Completion::Gone`], so a reactor's pending request can
/// never wait forever — the exact analogue of a blocked reader seeing
/// `RecvError` when a dying dispatcher drops its envelope.
pub struct OutboxTicket {
    outbox: Arc<Outbox<Completion>>,
    /// The request id ([`Job::id`]) the reactor indexed its pending
    /// connection under.
    id: u64,
    sent: bool,
}

impl std::fmt::Debug for OutboxTicket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OutboxTicket").field("id", &self.id).finish_non_exhaustive()
    }
}

impl OutboxTicket {
    pub fn new(outbox: Arc<Outbox<Completion>>, id: u64) -> OutboxTicket {
        OutboxTicket { outbox, id, sent: false }
    }

    fn deliver(&mut self, result: JobResult) {
        self.sent = true;
        self.outbox.push(Completion::Done { id: self.id, result });
    }
}

impl Drop for OutboxTicket {
    fn drop(&mut self) {
        if !self.sent {
            self.outbox.push(Completion::Gone { id: self.id });
        }
    }
}

/// A dispatched unit of work: a shape-pure envelope run plus whether it
/// was stolen from a sibling lane.
#[derive(Debug)]
pub struct LaneBatch {
    pub envelopes: Vec<Envelope>,
    pub stolen: bool,
}

/// The unit of lane affinity: job kind plus power-of-two size bucket.
/// Jobs in one class share execution character (engine choice, service
/// time magnitude), so giving each class a stable lane keeps slow and
/// fast traffic out of each other's queues.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeClass {
    /// 0 = matmul, 1 = sort.
    kind: u8,
    /// `floor(log2(n))` of the job size.
    bucket: u8,
}

impl ShapeClass {
    pub fn of(kind: &TraceKind) -> ShapeClass {
        let (k, n) = match kind {
            TraceKind::Matmul { n } => (0u8, *n),
            TraceKind::Sort { n } => (1u8, *n),
        };
        let bucket = (usize::BITS - 1 - n.max(1).leading_zeros()) as u8;
        ShapeClass { kind: k, bucket }
    }

    /// Construct from raw parts (`kind` 0 = matmul / 1 = sort, `bucket`
    /// a `floor(log2 n)` value) — the routing table and SLO config use
    /// this to enumerate/parse classes. `None` outside the valid space.
    pub fn from_parts(kind: u8, bucket: u8) -> Option<ShapeClass> {
        ((kind as usize) < routing::KINDS && (bucket as usize) < routing::MAX_BUCKETS)
            .then_some(ShapeClass { kind, bucket })
    }

    /// 0 = matmul, 1 = sort (the kind-partition dimension).
    pub fn kind_id(&self) -> u8 {
        self.kind
    }

    /// `floor(log2 n)` size bucket.
    pub fn bucket(&self) -> u8 {
        self.bucket
    }

    /// The *seed* (epoch-0) lane assignment — the static kind-partition
    /// + FNV-bucket rule, now canonically owned by
    /// [`routing::seed_lane`]; an epoch-versioned server consults its
    /// [`routing::RoutingTable`] instead, which may have re-bucketed
    /// this class within its kind's span.
    pub fn lane(&self, lanes: usize) -> usize {
        routing::seed_lane(*self, lanes)
    }

    /// Human-readable class label, e.g. `matmul/2^6`.
    pub fn name(&self) -> String {
        let kind = if self.kind == 0 { "matmul" } else { "sort" };
        format!("{kind}/2^{}", self.bucket)
    }

    /// Parse a [`name`](ShapeClass::name)-format label
    /// (`matmul/2^<bucket>` / `sort/2^<bucket>`) — the `[admission.slo]`
    /// config keys and `--slo` override grammar.
    pub fn parse(s: &str) -> Option<ShapeClass> {
        let (kind_name, bucket) = s.trim().split_once("/2^")?;
        let kind = match kind_name {
            "matmul" => 0u8,
            "sort" => 1u8,
            _ => return None,
        };
        ShapeClass::from_parts(kind, bucket.parse().ok()?)
    }
}

fn same_shape(a: &Envelope, b: &Envelope) -> bool {
    a.job.kind == b.job.kind
}

/// The sharded admission layer: one bounded queue per lane, shape-class
/// routing on push (via the epoch-versioned [`Router`]), work stealing
/// on pop.
pub struct LanePool {
    queues: Vec<BoundedQueue<Envelope>>,
    router: Arc<Router>,
    steal: bool,
}

impl std::fmt::Debug for LanePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LanePool").finish_non_exhaustive()
    }
}

impl LanePool {
    /// `lanes` queues (min 1) of `depth` each; `steal` enables the idle
    /// lane fallback. Routing stays pinned to the epoch-0 seed table —
    /// the historical static assignment; use
    /// [`with_router`](LanePool::with_router) to share a rebalanceable
    /// router.
    pub fn new(lanes: usize, depth: usize, steal: bool) -> LanePool {
        LanePool::with_router(Arc::new(Router::new(lanes)), depth, steal)
    }

    /// A pool routed by a shared [`Router`], so the server's rebalancer
    /// can republish the ShapeClass → lane table under it. The queue
    /// count is pinned to the router's lane count.
    pub fn with_router(router: Arc<Router>, depth: usize, steal: bool) -> LanePool {
        LanePool {
            queues: (0..router.lane_count()).map(|_| BoundedQueue::new(depth)).collect(),
            router,
            steal,
        }
    }

    pub fn lane_count(&self) -> usize {
        self.queues.len()
    }

    /// Stealing is meaningful only with siblings to steal from.
    pub fn steal_enabled(&self) -> bool {
        self.steal && self.queues.len() > 1
    }

    /// The lane a job of this kind routes to under the current epoch.
    pub fn route(&self, kind: &TraceKind) -> usize {
        self.router.route(kind).0
    }

    /// The routing handle this pool admits through.
    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// A lane's queue (panics on an out-of-range lane index).
    pub fn queue(&self, lane: usize) -> &BoundedQueue<Envelope> {
        &self.queues[lane]
    }

    /// Admission: push the envelope onto its routed lane, stamping
    /// [`Envelope::lane`] and [`Envelope::epoch`] from one routing-table
    /// snapshot so downstream attribution cannot diverge from the queue
    /// actually used — nor mix regimes across an epoch swap. `Ok(lane)`
    /// on success; `Err(envelope)` when that lane is at depth or closed
    /// — the caller turns that into `ERR BUSY` / `ERR DRAINING`.
    pub fn admit(&self, mut env: Envelope) -> Result<usize, Envelope> {
        let (lane, epoch) = self.router.route(&env.job.kind);
        env.lane = lane;
        env.epoch = epoch;
        self.queues[lane].try_push(env).map(|()| lane)
    }

    /// Non-blocking steal: scan the sibling lanes round-robin starting
    /// after `thief` and take one shape-pure run (≤ `max`) from the
    /// first non-empty queue head. Exactly-once holds because the run
    /// moves out under the victim queue's lock.
    pub fn steal(&self, thief: usize, max: usize) -> Option<(usize, Vec<Envelope>)> {
        let n = self.queues.len();
        for off in 1..n {
            let victim = (thief + off) % n;
            let run = self.queues[victim].try_pop_run(max, same_shape);
            if !run.is_empty() {
                return Some((victim, run));
            }
        }
        None
    }

    /// Close every lane queue (graceful: queued work still drains).
    pub fn close_all(&self) {
        for q in &self.queues {
            q.close();
        }
    }

    /// Items currently queued across all lanes.
    pub fn total_len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Largest per-lane occupancy high-water mark.
    pub fn max_occupancy(&self) -> usize {
        self.queues.iter().map(|q| q.max_len()).max().unwrap_or(0)
    }

    /// True once every lane queue is closed and empty.
    pub fn drained(&self) -> bool {
        self.queues.iter().all(|q| q.is_closed() && q.is_empty())
    }

    /// Next unit of work for `lane`'s dispatcher: the local queue first
    /// (with shape-batch formation up to `max` wide over `linger`), then
    /// a steal from a sibling when the local queue stays empty for a
    /// [`STEAL_TICK`]. Returns `None` only when every lane is closed and
    /// drained — the dispatcher's exit condition.
    pub fn next_batch(&self, lane: usize, max: usize, linger: Duration) -> Option<LaneBatch> {
        let own = &self.queues[lane];
        loop {
            match own.pop_timeout(STEAL_TICK) {
                PopTimeout::Item(first) => {
                    let mut batch = vec![first];
                    let extra = own.drain_run(&batch[0], max.max(1) - 1, linger, same_shape);
                    batch.extend(extra);
                    return Some(LaneBatch { envelopes: batch, stolen: false });
                }
                PopTimeout::Closed => {
                    // Local work is done. Help drain the siblings, or
                    // exit once the whole pool is dry.
                    if !self.steal_enabled() {
                        return None;
                    }
                    match self.steal(lane, max) {
                        Some((_victim, run)) => {
                            return Some(LaneBatch { envelopes: run, stolen: true })
                        }
                        None => {
                            if self.drained() {
                                return None;
                            }
                            std::thread::sleep(STEAL_TICK);
                        }
                    }
                }
                PopTimeout::TimedOut => {
                    if self.steal_enabled() {
                        if let Some((_victim, run)) = self.steal(lane, max) {
                            return Some(LaneBatch { envelopes: run, stolen: true });
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(id: u64, kind: TraceKind) -> (Envelope, mpsc::Receiver<JobResult>) {
        let (tx, rx) = mpsc::channel();
        let e = Envelope {
            job: Job { id, kind, seed: 0, arrival_us: 0 },
            lane: 0,  // stamped by admit(); raw-push tests leave it unused
            epoch: 0, // likewise
            enqueued: Instant::now(),
            reply: ReplySink::Channel(tx),
        };
        (e, rx)
    }

    #[test]
    fn shape_class_buckets_by_log2() {
        let a = ShapeClass::of(&TraceKind::Matmul { n: 64 });
        let b = ShapeClass::of(&TraceKind::Matmul { n: 100 });
        let c = ShapeClass::of(&TraceKind::Matmul { n: 128 });
        assert_eq!(a.name(), "matmul/2^6");
        assert_eq!(b.name(), "matmul/2^6", "64..127 share a bucket");
        assert_eq!(c.name(), "matmul/2^7");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(ShapeClass::of(&TraceKind::Sort { n: 1000 }).name(), "sort/2^9");
    }

    #[test]
    fn shape_class_parse_round_trips_names() {
        for kind in [TraceKind::Matmul { n: 100 }, TraceKind::Sort { n: 1000 }] {
            let c = ShapeClass::of(&kind);
            assert_eq!(ShapeClass::parse(&c.name()), Some(c), "{}", c.name());
        }
        assert_eq!(ShapeClass::parse(" matmul/2^6 "), ShapeClass::from_parts(0, 6));
        assert!(ShapeClass::parse("matmul/6").is_none(), "bucket must be spelled 2^b");
        assert!(ShapeClass::parse("tensor/2^6").is_none(), "unknown kind");
        assert!(ShapeClass::parse("sort/2^64").is_none(), "bucket out of range");
        assert!(ShapeClass::parse("sort/2^-1").is_none());
        assert!(ShapeClass::from_parts(2, 0).is_none(), "kind out of range");
    }

    #[test]
    fn kinds_partition_the_lane_pool() {
        for lanes in 2..6 {
            for n in [1usize, 24, 300, 600, 1024, 4096] {
                let m = ShapeClass::of(&TraceKind::Matmul { n }).lane(lanes);
                let s = ShapeClass::of(&TraceKind::Sort { n }).lane(lanes);
                let matmul_span = lanes - lanes / 2;
                assert!(m < matmul_span, "matmul/{n} on lane {m} of {lanes}");
                assert!(s >= matmul_span && s < lanes, "sort/{n} on lane {s} of {lanes}");
            }
        }
        // Degenerate single lane: everything shares it.
        assert_eq!(ShapeClass::of(&TraceKind::Matmul { n: 64 }).lane(1), 0);
        assert_eq!(ShapeClass::of(&TraceKind::Sort { n: 64 }).lane(1), 0);
    }

    #[test]
    fn admit_routes_to_the_shape_class_lane() {
        let pool = LanePool::new(2, 8, false);
        let (m, _mrx) = env(1, TraceKind::Matmul { n: 600 });
        let (s, _srx) = env(2, TraceKind::Sort { n: 300 });
        assert_eq!(pool.admit(m).unwrap(), 0, "matmul owns lane 0");
        assert_eq!(pool.admit(s).unwrap(), 1, "sort owns lane 1");
        assert_eq!(pool.queue(0).len(), 1);
        assert_eq!(pool.queue(1).len(), 1);
        assert_eq!(pool.total_len(), 2);
        assert_eq!(pool.queue(0).pop().unwrap().lane, 0, "admit stamps the admitted lane");
        assert_eq!(pool.queue(1).pop().unwrap().lane, 1, "admit stamps the admitted lane");
    }

    #[test]
    fn admit_stamps_lane_and_epoch_from_one_snapshot() {
        let pool = LanePool::new(4, 8, false);
        let kind = TraceKind::Sort { n: 1000 }; // sort/2^9 → seed lane 3 of 4
        let (a, _arx) = env(1, kind);
        assert_eq!(pool.admit(a).unwrap(), 3);
        // Republish the class onto the other sort lane: the queued
        // envelope keeps its admitted (lane, epoch); new admissions get
        // the new pair.
        let table = pool.router().load().with_move(ShapeClass::of(&kind), 2).unwrap();
        pool.router().publish(table).unwrap();
        let (b, _brx) = env(2, kind);
        assert_eq!(pool.admit(b).unwrap(), 2, "new epoch routes to the moved lane");
        let old = pool.queue(3).pop().unwrap();
        assert_eq!((old.lane, old.epoch), (3, 0), "in-flight job keeps its admitted epoch");
        let new = pool.queue(2).pop().unwrap();
        assert_eq!((new.lane, new.epoch), (2, 1));
    }

    #[test]
    fn admit_rejects_at_lane_depth() {
        let pool = LanePool::new(2, 1, false);
        let (a, _arx) = env(1, TraceKind::Sort { n: 100 });
        let (b, _brx) = env(2, TraceKind::Sort { n: 100 });
        assert!(pool.admit(a).is_ok());
        let back = pool.admit(b).expect_err("lane at depth rejects");
        assert_eq!(back.job.id, 2, "rejected envelope handed back");
        assert!(pool.queue(0).is_empty(), "matmul lane unused by sorts");
    }

    #[test]
    fn steal_takes_a_shape_pure_run_from_a_sibling() {
        let pool = LanePool::new(2, 8, true);
        let mut rxs = Vec::new();
        for (id, kind) in [
            (1, TraceKind::Sort { n: 100 }),
            (2, TraceKind::Sort { n: 200 }),
            (3, TraceKind::Matmul { n: 16 }),
        ] {
            // Push everything onto the sort lane directly to stage a
            // mixed backlog (admit would route the matmul elsewhere).
            let (e, rx) = env(id, kind);
            pool.queue(1).try_push(e).map_err(|_| "push").unwrap();
            rxs.push(rx);
        }
        let (victim, run) = pool.steal(0, 8).expect("backlog to steal");
        assert_eq!(victim, 1);
        let ids: Vec<u64> = run.iter().map(|e| e.job.id).collect();
        assert_eq!(ids, vec![1, 2], "same-kind head run only, FIFO preserved");
        assert_eq!(pool.queue(1).len(), 1, "the mismatched matmul stays queued");
    }

    #[test]
    fn next_batch_drains_own_then_steals_then_exits() {
        let pool = LanePool::new(2, 8, true);
        let (a, _arx) = env(1, TraceKind::Matmul { n: 32 });
        let (b, _brx) = env(2, TraceKind::Sort { n: 100 });
        pool.admit(a).unwrap();
        pool.admit(b).unwrap();
        pool.close_all();
        // Lane 0 takes its own matmul first...
        let own = pool.next_batch(0, 8, Duration::ZERO).expect("own work first");
        assert!(!own.stolen);
        assert_eq!(own.envelopes[0].job.id, 1);
        // ...then steals the sort stranded on lane 1...
        let stolen = pool.next_batch(0, 8, Duration::ZERO).expect("steals the leftover");
        assert!(stolen.stolen);
        assert_eq!(stolen.envelopes[0].job.id, 2);
        // ...and exits once the pool is dry.
        assert!(pool.next_batch(0, 8, Duration::ZERO).is_none());
        assert!(pool.drained());
    }

    #[test]
    fn next_batch_without_steal_exits_on_own_close() {
        let pool = LanePool::new(2, 8, false);
        let (b, _brx) = env(2, TraceKind::Sort { n: 100 });
        pool.admit(b).unwrap();
        pool.close_all();
        // Lane 0 (matmul lane) has nothing and may not steal: exits even
        // though lane 1 still holds work for its own dispatcher.
        assert!(pool.next_batch(0, 8, Duration::ZERO).is_none());
        assert!(pool.next_batch(1, 8, Duration::ZERO).is_some());
    }
}
