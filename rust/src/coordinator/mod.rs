//! The coordinator: OHM's serving-style front end.
//!
//! A stream of jobs (matmul / sort requests) is routed per-job by the
//! **overhead-aware policy**:
//!
//! * matmul with a matching AOT artifact → the **XLA engine** (PJRT,
//!   compiled once per shape, cached — Python never runs);
//! * otherwise → CPU, where the [`Manager`](crate::overhead::Manager)
//!   picks serial or pool-parallel execution per the paper's methodology;
//! * sorts with a matching `bitonic_<n>` artifact can opt into XLA too.
//!
//! Consecutive same-shape jobs are dispatched as one **shape batch**,
//! amortizing executable lookup and decision-making (and, on a warm
//! cache, skipping recompilation entirely) — the coordinator-level
//! analogue of the paper's "don't pay setup costs per work item".
//!
//! The TCP front end ([`server`]) puts a concurrent, admission-controlled
//! serving layer in front of this: reader threads route each request to a
//! sharded [`lanes::LanePool`] — one bounded [`queue::BoundedQueue`] per
//! shape-class lane (overflow ⇒ `ERR BUSY`), one dispatcher thread per
//! lane extending shape-batching **across connections**, with
//! work-stealing between lanes so sharding never strands work. A `DRAIN`
//! protocol command stops admission, completes every admitted job, and
//! reports a final `STATS` snapshot (the rolling-restart primitive).
//! Queue wait, batch width, rejections, and per-lane steal/imbalance
//! counters are tracked as first-class overhead categories in
//! [`Telemetry`] and the serving [`Ledger`](crate::overhead::Ledger).
//!
//! Admission itself comes in two modes ([`admission`]): the **fixed**
//! depth bound alone, or the **adaptive** governor that feeds each
//! lane's measured queue-wait percentiles (streaming
//! [`Digest`](crate::stats::Digest)s, fixed memory) back into the
//! admission decision — shedding with `ERR OVERLOADED` while a lane's
//! rolling p90 wait exceeds the configured SLO and re-admitting with
//! hysteresis once it recovers. In front of all of that sits the
//! optional warm **result cache** ([`cache`]): deterministic
//! `(kind, seed)` repeats are answered by the reader itself —
//! single-flight, sharded per lane, LRU + byte-bounded — without
//! consuming any admission budget. The ShapeClass → lane assignment
//! itself is owned by the epoch-versioned [`routing`] layer: with
//! `--rebalance adaptive` a [`routing::Rebalancer`] thread re-buckets
//! hot shape classes onto cold lanes (within their kind span) from the
//! governor's observed per-lane queue-wait imbalance, publishing a new
//! routing epoch while in-flight jobs keep their admitted epoch's
//! attribution. The wire protocol is specified in
//! `docs/PROTOCOL.md` and the data flow in `docs/ARCHITECTURE.md`.

pub mod admission;
pub mod cache;
pub mod costmodel;
pub mod faults;
pub mod job;
pub mod lanes;
pub mod queue;
pub mod routing;
pub mod server;
pub mod telemetry;

pub use admission::{AdmissionMode, Governor, SloTable};
pub use cache::ResultCache;
pub use costmodel::ServeCostModel;
pub use faults::{ErrCode, FaultKind, FaultPlan};
pub use job::{Job, JobResult, RoutedEngine};
pub use lanes::{LanePool, ShapeClass};
pub use queue::BoundedQueue;
pub use routing::{RebalanceMode, Router, RoutingTable};
pub use telemetry::Telemetry;

use crate::dla::matmul;
use crate::exec::ExecCtx;
use crate::overhead::Decision;
use crate::runtime::{self, Runtime};
use crate::sort::{self, PivotStrategy};
use crate::util::Stopwatch;
use crate::workload::traces::{TraceJob, TraceKind};
use crate::workload::{arrays, matrices};

/// Coordinator configuration (execution policy + serving layer).
#[derive(Debug, Clone)]
pub struct CoordinatorCfg {
    /// Worker threads for the CPU-parallel engine.
    pub threads: usize,
    /// Route sort jobs to XLA bitonic artifacts when available.
    pub xla_sort: bool,
    /// Pivot strategy for CPU sorts.
    pub pivot: PivotStrategy,
    /// Serving layer: connection reader threads (`--serve-threads`).
    pub serve_threads: usize,
    /// Serving layer: admission-queue depth; pushes beyond this answer
    /// `ERR BUSY` (`--queue-depth`).
    pub queue_depth: usize,
    /// Serving layer: maximum cross-connection shape-batch width.
    pub batch_max: usize,
    /// Serving layer: batch-formation window after the first job of a
    /// batch is popped, in µs (0 = dispatch immediately).
    pub batch_linger_us: u64,
    /// Serving layer: dispatch lanes (`--lanes`). Shape kinds partition
    /// the pool, size buckets hash within a kind's share; `queue_depth`
    /// applies per lane. 1 restores the single-dispatcher behaviour.
    pub lanes: usize,
    /// Serving layer: let an idle lane steal a shape-pure run from a
    /// sibling's queue head (`--steal`). Work conservation at the cost
    /// of occasionally thinner batches on the victim lane.
    pub steal: bool,
    /// Serving layer: admission mode (`--admission fixed|adaptive`).
    /// `Fixed` keeps only the depth bound; `Adaptive` adds the SLO
    /// governor (soft `ERR OVERLOADED` rejects driven by each lane's
    /// rolling p90 queue wait).
    pub admission: admission::AdmissionMode,
    /// Serving layer: the p90 queue-wait SLO the adaptive governor
    /// defends, in µs (`--slo-p90-us`). Ignored in `Fixed` mode.
    pub slo_p90_us: f64,
    /// Serving layer: per-shape-class SLO overrides (`--slo
    /// class=µs[,class=µs...]` / `[admission.slo]` config), layered
    /// over `slo_p90_us` so e.g. matmul and sort classes defend
    /// different budgets. Empty = one uniform SLO.
    pub slo_overrides: Vec<(lanes::ShapeClass, f64)>,
    /// Serving layer: routing-rebalance mode (`--rebalance
    /// off|adaptive`). `Off` (default) pins the epoch-0 seed table —
    /// the historical static assignment, bit-for-bit; `Adaptive` runs
    /// the [`routing::Rebalancer`] thread, re-bucketing hot shape
    /// classes onto cold lanes within their kind span from observed
    /// per-lane queue-wait imbalance.
    pub rebalance: routing::RebalanceMode,
    /// Serving layer: the rebalancer's decision window, ms
    /// (`--rebalance-window-ms`). Ignored with `--rebalance off`.
    pub rebalance_window_ms: u64,
    /// Serving layer: rolling half-window length for the governor's
    /// queue-wait digests, ms (`--admission-window-ms`). Estimates cover
    /// one to two windows of recent history.
    pub admission_window_ms: u64,
    /// Serving layer: enable the warm result cache (`--cache on|off`).
    /// Off by default — with it off, replies, STATS, and admission
    /// behaviour are byte-for-byte what they were without the cache.
    pub cache: bool,
    /// Serving layer: global result-cache entry cap (`--cache-entries`),
    /// split evenly across the per-lane shards. Must be ≥ 1.
    pub cache_entries: usize,
    /// Serving layer: global result-cache byte budget (`--cache-bytes`),
    /// split evenly across the per-lane shards. Must be ≥ 1.
    pub cache_bytes: u64,
    /// Serving layer: consult the online cost model at serve time
    /// (`--cost-model on|off`). Off by default — with it off, dispatch,
    /// admission, rebalancing, replies, and STATS are byte-for-byte what
    /// they were without the cost model. On, jobs predicted below the
    /// serial/parallel crossover run serial-inline on the lane thread
    /// (`engine=serial-inline`), the adaptive governor sheds on predicted
    /// queue wait, and the rebalancer weighs classes by predicted cost.
    pub cost_model: bool,
    /// Serving layer: fault-injection spec (`--faults <spec>` /
    /// `[faults]` config), parsed by [`faults::FaultPlan::parse`].
    /// `"off"` (the default) disarms injection entirely — replies,
    /// STATS, and DRAIN output are byte-for-byte what they were before
    /// the fault harness existed.
    pub faults: String,
    /// Serving layer: connection IO model (`--io threads|reactor`).
    /// `Threads` (default) keeps the blocking reader pool; `Reactor`
    /// serves every connection from a fixed epoll reactor pool
    /// (threads ≈ cores, not ≈ connections) with byte-identical
    /// replies. Linux only; other targets refuse it at startup.
    pub io: IoMode,
    /// Serving layer: reactor pool size under `--io reactor`
    /// (`--reactor-threads`). 0 (default) = auto: the host's available
    /// parallelism, capped at 8. Ignored under `--io threads`.
    pub reactor_threads: usize,
}

/// Connection-layer IO model (`--io`): blocking reader threads or the
/// event-driven epoll reactor pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoMode {
    /// One blocking reader thread per active connection (the default).
    Threads,
    /// A fixed pool of epoll reactor threads multiplexing every
    /// connection (`rust/src/net/` + `server::reactor`).
    Reactor,
}

impl IoMode {
    /// Parse the `--io` / `[serving] io` value.
    pub fn parse(name: &str) -> Option<IoMode> {
        match name {
            "threads" => Some(IoMode::Threads),
            "reactor" => Some(IoMode::Reactor),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            IoMode::Threads => "threads",
            IoMode::Reactor => "reactor",
        }
    }
}

impl Default for CoordinatorCfg {
    fn default() -> Self {
        CoordinatorCfg {
            threads: 4,
            xla_sort: true,
            pivot: PivotStrategy::Mean,
            serve_threads: 4,
            queue_depth: 64,
            batch_max: 16,
            batch_linger_us: 0,
            lanes: 2,
            steal: true,
            admission: admission::AdmissionMode::Fixed,
            slo_p90_us: 10_000.0,
            slo_overrides: Vec::new(),
            rebalance: routing::RebalanceMode::Off,
            rebalance_window_ms: 500,
            admission_window_ms: 500,
            cache: false,
            cache_entries: 4096,
            cache_bytes: 4 * 1024 * 1024,
            cost_model: false,
            faults: "off".to_string(),
            io: IoMode::Threads,
            reactor_threads: 0,
        }
    }
}

impl CoordinatorCfg {
    /// The reactor pool size `--io reactor` actually runs with: the
    /// configured `reactor_threads`, or (at 0 = auto) the host's
    /// available parallelism capped at 8 — threads ≈ cores, never ≈
    /// connections.
    pub fn effective_reactor_threads(&self) -> usize {
        if self.reactor_threads > 0 {
            return self.reactor_threads;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
    }
}

/// The coordinator instance.
pub struct Coordinator {
    cfg: CoordinatorCfg,
    cpu: ExecCtx,
    /// Dedicated serial context for the cost model's inline path: no
    /// thread pool, no fork-join machinery — the lane thread itself runs
    /// the kernel. Cheap to hold (no worker threads are spawned).
    serial: ExecCtx,
    runtime: Option<Runtime>,
    pub telemetry: Telemetry,
    next_id: u64,
}

impl std::fmt::Debug for Coordinator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Coordinator").finish_non_exhaustive()
    }
}

impl Coordinator {
    /// Build with an optional XLA runtime (None ⇒ CPU-only routing).
    pub fn new(cfg: CoordinatorCfg, runtime: Option<Runtime>) -> Coordinator {
        let cpu = ExecCtx::threaded(cfg.threads);
        let serial = ExecCtx::serial();
        Coordinator { cfg, cpu, serial, runtime, telemetry: Telemetry::default(), next_id: 1 }
    }

    /// Route a job without executing it (policy unit under test).
    pub fn route(&self, kind: &TraceKind) -> RoutedEngine {
        match kind {
            TraceKind::Matmul { n } => match &self.runtime {
                Some(rt) if runtime::has_matmul(rt, *n) => RoutedEngine::Xla,
                _ => self.cpu_engine_for(matmul_work_est(*n)),
            },
            TraceKind::Sort { n } => match &self.runtime {
                Some(rt) if self.cfg.xla_sort && runtime::has_sort(rt, *n) => RoutedEngine::Xla,
                _ => self.cpu_engine_for(sort_work_est(*n)),
            },
        }
    }

    fn cpu_engine_for(&self, est: crate::overhead::WorkEstimate) -> RoutedEngine {
        match self.cpu.manager.decide(&est) {
            Decision::Parallel { .. } => RoutedEngine::CpuParallel,
            Decision::Serial { .. } => RoutedEngine::CpuSerial,
        }
    }

    /// Submit one ad-hoc job; returns its result.
    pub fn submit(&mut self, kind: TraceKind, seed: u64) -> JobResult {
        let job = Job { id: self.next_id, kind, seed, arrival_us: 0 };
        self.next_id += 1;
        let r = self.execute_job(&job);
        self.telemetry.record(&r);
        r
    }

    /// Run a whole trace, dispatching consecutive same-shape jobs as
    /// batches. Returns per-job results in submission order.
    pub fn run_trace(&mut self, trace: &[TraceJob]) -> Vec<JobResult> {
        let mut results = Vec::with_capacity(trace.len());
        let mut i = 0usize;
        while i < trace.len() {
            let mut j = i + 1;
            let key = Job::from_trace(0, &trace[i]).shape_key();
            while j < trace.len() && Job::from_trace(0, &trace[j]).shape_key() == key {
                j += 1;
            }
            self.telemetry.record_batch(j - i);
            for t in &trace[i..j] {
                let job = Job::from_trace(self.next_id, t);
                self.next_id += 1;
                let r = self.execute_job(&job);
                self.telemetry.record(&r);
                results.push(r);
            }
            i = j;
        }
        results
    }

    /// Route and execute one job (no telemetry side effects). Takes
    /// `&self`: the serving dispatcher calls this for every queued job
    /// and records telemetry itself (with queue wait filled in).
    pub fn execute_job(&self, job: &Job) -> JobResult {
        let engine = self.route(&job.kind);
        let sw = Stopwatch::start();
        let (checksum, ok) = match (&job.kind, engine) {
            (TraceKind::Matmul { n }, RoutedEngine::Xla) => {
                let a = matrices::uniform(*n, *n, job.seed);
                let b = matrices::uniform(*n, *n, job.seed ^ 0xABCD);
                match runtime::matmul_xla(self.runtime.as_ref().unwrap(), &a, &b) {
                    Ok(c) => (c.frobenius(), true),
                    Err(_) => (0.0, false),
                }
            }
            (TraceKind::Matmul { n }, _) => {
                let a = matrices::uniform(*n, *n, job.seed);
                let b = matrices::uniform(*n, *n, job.seed ^ 0xABCD);
                let (c, _) = matmul::run(&a, &b, &self.cpu);
                (c.frobenius(), true)
            }
            (TraceKind::Sort { n }, RoutedEngine::Xla) => {
                let xs = arrays::uniform_f32(*n, job.seed);
                match runtime::sort_xla(self.runtime.as_ref().unwrap(), &xs) {
                    Ok(sorted) => {
                        let ok = sorted.windows(2).all(|w| w[0] <= w[1]);
                        (sorted.iter().map(|&v| v as f64).sum(), ok)
                    }
                    Err(_) => (0.0, false),
                }
            }
            (TraceKind::Sort { n }, _) => {
                let mut xs = arrays::uniform_i64(*n, job.seed);
                let _ = sort::parallel_quicksort(&mut xs, self.cfg.pivot, &self.cpu);
                let ok = sort::is_sorted(&xs);
                (xs.iter().map(|&v| v as f64).sum(), ok)
            }
        };
        JobResult {
            id: job.id,
            shape_key: job.shape_key(),
            engine,
            service_us: sw.elapsed_ns() as f64 / 1e3,
            queue_us: 0.0,
            checksum,
            ok,
        }
    }

    /// Execute one job serially, inline on the calling (lane) thread —
    /// the cost model's below-crossover path (`--cost-model on`). The
    /// fork-join machinery is never touched: the kernel runs under the
    /// dedicated serial [`ExecCtx`], and the result is stamped
    /// [`RoutedEngine::SerialInline`]. Checksums are bit-identical to
    /// pooled execution of the same `(kind, n, seed)`: the packed matmul
    /// microkernel is gate-tested identical to the serial reference, and
    /// a sorted array's element sum is engine-independent.
    pub fn execute_job_inline(&self, job: &Job) -> JobResult {
        let sw = Stopwatch::start();
        let (checksum, ok) = match &job.kind {
            TraceKind::Matmul { n } => {
                let a = matrices::uniform(*n, *n, job.seed);
                let b = matrices::uniform(*n, *n, job.seed ^ 0xABCD);
                let (c, _) = matmul::run(&a, &b, &self.serial);
                (c.frobenius(), true)
            }
            TraceKind::Sort { n } => {
                let mut xs = arrays::uniform_i64(*n, job.seed);
                let _ = sort::parallel_quicksort(&mut xs, self.cfg.pivot, &self.serial);
                let ok = sort::is_sorted(&xs);
                (xs.iter().map(|&v| v as f64).sum(), ok)
            }
        };
        JobResult {
            id: job.id,
            shape_key: job.shape_key(),
            engine: RoutedEngine::SerialInline,
            service_us: sw.elapsed_ns() as f64 / 1e3,
            queue_us: 0.0,
            checksum,
            ok,
        }
    }
}

pub(crate) fn matmul_work_est(n: usize) -> crate::overhead::WorkEstimate {
    crate::overhead::WorkEstimate::fully_parallel((n as f64).powi(3), (2 * n * n * 4) as u64)
}

pub(crate) fn sort_work_est(n: usize) -> crate::overhead::WorkEstimate {
    sort::estimate(n, &sort::SortCostModel::host(4.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traces::{self, TraceSpec};

    fn cpu_coordinator() -> Coordinator {
        Coordinator::new(CoordinatorCfg { threads: 2, ..Default::default() }, None)
    }

    #[test]
    fn routes_small_matmul_serial_large_parallel() {
        let c = cpu_coordinator();
        assert_eq!(c.route(&TraceKind::Matmul { n: 8 }), RoutedEngine::CpuSerial);
        assert_eq!(c.route(&TraceKind::Matmul { n: 512 }), RoutedEngine::CpuParallel);
    }

    #[test]
    fn submit_executes_and_records() {
        let mut c = cpu_coordinator();
        let r = c.submit(TraceKind::Sort { n: 500 }, 3);
        assert!(r.ok);
        assert_eq!(r.shape_key, "sort/500");
        assert_eq!(c.telemetry.completed, 1);
    }

    #[test]
    fn trace_runs_all_jobs_exactly_once() {
        let mut c = cpu_coordinator();
        let spec = TraceSpec {
            jobs: 20,
            matmul_orders: vec![16, 32],
            sort_sizes: vec![100, 200],
            ..Default::default()
        };
        let trace = traces::generate(&spec, 7);
        let results = c.run_trace(&trace);
        assert_eq!(results.len(), 20);
        let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 20, "every job completes exactly once");
        assert!(results.iter().all(|r| r.ok));
        assert!(c.telemetry.batches >= 1);
        assert_eq!(c.telemetry.completed, 20);
    }

    #[test]
    fn batching_groups_consecutive_shapes() {
        let mut c = cpu_coordinator();
        let t = |n: usize| TraceJob { arrival_us: 0, kind: TraceKind::Sort { n }, seed: 1 };
        let trace = vec![t(100), t(100), t(100), t(200), t(100)];
        c.run_trace(&trace);
        assert_eq!(c.telemetry.batches, 3, "three consecutive-shape groups");
        assert_eq!(c.telemetry.batched_jobs, 5);
    }

    #[test]
    fn inline_serial_checksums_are_bit_identical_to_pooled() {
        let c = cpu_coordinator();
        for kind in [
            TraceKind::Matmul { n: 48 },
            TraceKind::Matmul { n: 128 },
            TraceKind::Sort { n: 999 },
        ] {
            let job = Job { id: 1, kind, seed: 7, arrival_us: 0 };
            let pooled = c.execute_job(&job);
            let inline = c.execute_job_inline(&job);
            assert_eq!(inline.engine, RoutedEngine::SerialInline);
            assert!(pooled.ok && inline.ok);
            assert_eq!(
                pooled.checksum.to_bits(),
                inline.checksum.to_bits(),
                "inline vs pooled checksum diverged for {:?}",
                job.kind
            );
        }
    }

    #[test]
    fn routing_is_deterministic() {
        let c = cpu_coordinator();
        for _ in 0..5 {
            assert_eq!(c.route(&TraceKind::Matmul { n: 100 }), c.route(&TraceKind::Matmul { n: 100 }));
        }
    }
}
