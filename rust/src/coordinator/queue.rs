//! Bounded MPMC job queue with admission control — the serving layer's
//! backpressure substrate.
//!
//! The paper's thesis applied to serving: the handoff between connection
//! readers and the dispatcher is a synchronization point, and if it is
//! unbounded the queueing overhead it hides "later surfaces at execution
//! time" as unbounded latency. So admission is explicit: [`try_push`] is
//! non-blocking and **rejects** once the configured depth is reached (the
//! server answers `ERR BUSY`), keeping queue wait — a first-class overhead
//! category in [`super::Telemetry`] — bounded by design. The depth bound
//! is the *hard* admission layer; the SLO-driven governor
//! ([`super::admission`]) sits in front of it as the *soft* layer,
//! shedding on observed wait rather than on occupancy.
//!
//! Implementation: `Mutex<VecDeque>` + condvar. Multiple producers
//! (connection reader threads) and multiple consumers are supported;
//! [`pop_batch`] additionally drains a consecutive same-key run from the
//! queue head so a dispatcher can extend shape-batching *across*
//! connections while preserving global FIFO order. The sharded lane
//! dispatchers ([`super::lanes`]) compose the finer-grained primitives
//! directly: [`pop_timeout`] (bounded wait on the local queue),
//! [`drain_run`] (batch formation behind a popped head), and
//! [`try_pop_run`] (the exactly-once unit of cross-lane work stealing).
//!
//! [`try_push`]: BoundedQueue::try_push
//! [`pop_batch`]: BoundedQueue::pop_batch
//! [`pop_timeout`]: BoundedQueue::pop_timeout
//! [`drain_run`]: BoundedQueue::drain_run
//! [`try_pop_run`]: BoundedQueue::try_pop_run

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Outcome of [`pop_timeout`](BoundedQueue::pop_timeout): distinguishes
/// "nothing yet" from "nothing ever again" so a dispatch lane can decide
/// between stealing and exiting.
#[derive(Debug)]
pub enum PopTimeout<T> {
    /// An item arrived within the window.
    Item(T),
    /// The window elapsed with the queue still open but empty.
    TimedOut,
    /// The queue is closed and fully drained.
    Closed,
}

/// A bounded multi-producer multi-consumer FIFO queue.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
}

impl<T> std::fmt::Debug for BoundedQueue<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BoundedQueue").finish_non_exhaustive()
    }
}

struct Inner<T> {
    items: VecDeque<T>,
    depth: usize,
    closed: bool,
    /// High-water mark of occupancy (telemetry; never exceeds `depth`).
    max_len: usize,
}

impl<T> Inner<T> {
    /// Pop up to `max_extra` consecutive items matching `key` from the
    /// head — the one batch-formation loop shared by every drain path
    /// (own-queue batches and stolen runs), so the FIFO/shape-pure
    /// semantics cannot drift between them.
    fn drain_matching(&mut self, key: &T, max_extra: usize, same: impl Fn(&T, &T) -> bool) -> Vec<T> {
        let mut out = Vec::new();
        while out.len() < max_extra {
            let take = match self.items.front() {
                Some(item) => same(key, item),
                None => false,
            };
            if !take {
                break;
            }
            out.push(self.items.pop_front().expect("front was Some"));
        }
        out
    }
}

impl<T> BoundedQueue<T> {
    /// A queue admitting at most `depth` queued items (min 1).
    pub fn new(depth: usize) -> BoundedQueue<T> {
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                depth: depth.max(1),
                closed: false,
                max_len: 0,
            }),
            not_empty: Condvar::new(),
        }
    }

    /// Configured admission bound.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().depth
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// High-water mark of occupancy observed so far.
    pub fn max_len(&self) -> usize {
        self.inner.lock().unwrap().max_len
    }

    /// True once [`close`](BoundedQueue::close) has been called. Lets the
    /// server distinguish "full" (back off and retry: `ERR BUSY`) from
    /// "shutting down / dispatcher gone" when a push is refused.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Admission control: non-blocking push. Returns the item back when
    /// the queue is at depth (or closed) — the caller turns that into
    /// backpressure (`ERR BUSY`) instead of queueing unboundedly.
    /// Rejection *counting* is the caller's concern (the server records it
    /// in `Telemetry`), so there is exactly one authoritative counter.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.items.len() >= g.depth {
            return Err(item);
        }
        g.items.push_back(item);
        if g.items.len() > g.max_len {
            g.max_len = g.items.len();
        }
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop: waits for an item; returns `None` once the queue is
    /// closed *and* drained (close is graceful — queued work completes).
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Blocking pop with a deadline: waits up to `timeout` for an item.
    /// Unlike [`pop`](BoundedQueue::pop), the caller learns *why* nothing
    /// came back — a dispatch lane reacts to [`PopTimeout::TimedOut`] by
    /// attempting a steal and to [`PopTimeout::Closed`] by winding down.
    pub fn pop_timeout(&self, timeout: Duration) -> PopTimeout<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return PopTimeout::Item(item);
            }
            if g.closed {
                return PopTimeout::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopTimeout::TimedOut;
            }
            g = self.not_empty.wait_timeout(g, deadline - now).unwrap().0;
        }
    }

    /// Non-blocking batch pop: take the head item plus the consecutive
    /// same-key run behind it (up to `max` total), or an empty vec when
    /// the queue is empty. The run moves out under one lock acquisition,
    /// which is what makes cross-lane work stealing exactly-once: an item
    /// is either still queued or owned by exactly one thief.
    pub fn try_pop_run(&self, max: usize, same: impl Fn(&T, &T) -> bool) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let first = match g.items.pop_front() {
            Some(item) => item,
            None => return Vec::new(),
        };
        let mut batch = vec![first];
        let extra = g.drain_matching(&batch[0], max.max(1) - 1, same);
        batch.extend(extra);
        batch
    }

    /// Drain up to `max_extra` further items matching `key` from the
    /// queue head, optionally lingering up to `linger` for the run to
    /// grow. Draining stops at the first key mismatch, so global FIFO
    /// order is preserved and a batch is always a consecutive same-key
    /// run.
    ///
    /// The linger is interruptible: it ends early as soon as the batch
    /// cannot grow further — the head run reaches `max_extra`, a
    /// different-key item blocks the head (FIFO means later same-key
    /// arrivals queue behind it), the queue is full (admission control
    /// rejects anything that could have joined), or the queue closes.
    pub fn drain_run(
        &self,
        key: &T,
        max_extra: usize,
        linger: Duration,
        same: impl Fn(&T, &T) -> bool,
    ) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        if !linger.is_zero() {
            let deadline = Instant::now() + linger;
            loop {
                let head_run = g.items.iter().take_while(|item| same(key, *item)).count();
                let batch_full = head_run >= max_extra;
                let blocked = head_run < g.items.len(); // mismatched key at/behind head
                let queue_full = g.items.len() >= g.depth; // nothing new can be admitted
                if g.closed || batch_full || blocked || queue_full {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                g = self.not_empty.wait_timeout(g, deadline - now).unwrap().0;
            }
        }
        g.drain_matching(key, max_extra, same)
    }

    /// Pop a shape batch: block for the first item, then
    /// [`drain_run`](BoundedQueue::drain_run) the consecutive same-key
    /// run behind it (up to `max - 1` extras, lingering up to `linger`).
    /// Returns an empty vec only when the queue is closed and drained.
    pub fn pop_batch(
        &self,
        max: usize,
        linger: Duration,
        same: impl Fn(&T, &T) -> bool,
    ) -> Vec<T> {
        let first = match self.pop() {
            Some(item) => item,
            None => return Vec::new(),
        };
        let max = max.max(1);
        let mut batch = vec![first];
        let extra = self.drain_run(&batch[0], max - 1, linger, &same);
        batch.extend(extra);
        batch
    }

    /// Close the queue: wakes all blocked consumers; further pushes are
    /// rejected; already-queued items still drain.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_and_admission_bound() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.try_push(1), Ok(()));
        assert_eq!(q.try_push(2), Ok(()));
        assert_eq!(q.try_push(3), Err(3), "third push exceeds depth 2");
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.try_push(4), Ok(()));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.max_len(), 2);
    }

    #[test]
    fn close_wakes_blocked_consumer_and_drains() {
        let q = Arc::new(BoundedQueue::<u32>::new(4));
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = q2.pop() {
                got.push(v);
            }
            got
        });
        q.try_push(7).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        assert!(!q.is_closed());
        q.close();
        assert!(q.is_closed());
        assert_eq!(consumer.join().unwrap(), vec![7]);
        assert_eq!(q.try_push(8), Err(8), "closed queue rejects pushes");
    }

    #[test]
    fn pop_batch_drains_consecutive_same_key_run() {
        let q = BoundedQueue::new(8);
        for item in [(1u8, 'a'), (1, 'b'), (1, 'c'), (2, 'd'), (1, 'e')] {
            q.try_push(item).unwrap();
        }
        q.close();
        let b1 = q.pop_batch(2, Duration::ZERO, |x, y| x.0 == y.0);
        assert_eq!(b1, vec![(1, 'a'), (1, 'b')], "capped at max width");
        let b2 = q.pop_batch(8, Duration::ZERO, |x, y| x.0 == y.0);
        assert_eq!(b2, vec![(1, 'c')], "stops at the shape boundary");
        let b3 = q.pop_batch(8, Duration::ZERO, |x, y| x.0 == y.0);
        assert_eq!(b3, vec![(2, 'd')]);
        let b4 = q.pop_batch(8, Duration::ZERO, |x, y| x.0 == y.0);
        assert_eq!(b4, vec![(1, 'e')]);
        assert!(q.pop_batch(8, Duration::ZERO, |x, y| x.0 == y.0).is_empty());
    }

    #[test]
    fn linger_ends_early_when_queue_fills() {
        // depth 2: pop_batch takes 'a' (len 1), then a producer fills the
        // queue back to depth at ~40ms — admission control now rejects
        // anything that could join, so the linger must end well before its
        // 2s window instead of stalling on a batch that cannot grow.
        let q = Arc::new(BoundedQueue::new(2));
        q.try_push((1u8, 'a')).unwrap();
        q.try_push((1u8, 'b')).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            q2.try_push((1u8, 'c')).unwrap();
        });
        let start = std::time::Instant::now();
        let batch = q.pop_batch(8, Duration::from_millis(2_000), |x, y| x.0 == y.0);
        producer.join().unwrap();
        assert_eq!(batch, vec![(1, 'a'), (1, 'b'), (1, 'c')]);
        assert!(
            start.elapsed() < Duration::from_millis(1_500),
            "full queue must cut the linger short, took {:?}",
            start.elapsed()
        );
    }

    #[test]
    fn pop_timeout_distinguishes_empty_from_closed() {
        let q = BoundedQueue::<u32>::new(4);
        q.try_push(9).unwrap();
        match q.pop_timeout(Duration::from_millis(5)) {
            PopTimeout::Item(v) => assert_eq!(v, 9),
            other => panic!("expected an item, got {other:?}"),
        }
        let start = std::time::Instant::now();
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), PopTimeout::TimedOut));
        assert!(start.elapsed() >= Duration::from_millis(10), "must wait the window out");
        q.close();
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), PopTimeout::Closed));
    }

    #[test]
    fn try_pop_run_takes_head_run_without_blocking() {
        let q = BoundedQueue::new(8);
        for item in [(1u8, 'a'), (1, 'b'), (2, 'c')] {
            q.try_push(item).unwrap();
        }
        let run = q.try_pop_run(8, |x, y| x.0 == y.0);
        assert_eq!(run, vec![(1, 'a'), (1, 'b')], "head run only");
        let run = q.try_pop_run(1, |x, y| x.0 == y.0);
        assert_eq!(run, vec![(2, 'c')]);
        assert!(q.try_pop_run(8, |x: &(u8, char), y| x.0 == y.0).is_empty(), "empty queue");
    }

    #[test]
    fn linger_lets_a_cross_producer_batch_form() {
        let q = Arc::new(BoundedQueue::new(8));
        q.try_push((1u8, 0u32)).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            q2.try_push((1u8, 1u32)).unwrap();
            q2.try_push((1u8, 2u32)).unwrap();
        });
        let batch = q.pop_batch(8, Duration::from_millis(200), |x, y| x.0 == y.0);
        producer.join().unwrap();
        assert_eq!(batch.len(), 3, "items arriving during the linger join the batch");
    }
}
