//! Epoch-versioned routing: the single authority for ShapeClass → lane
//! (and class → cache-shard) assignment, plus the load-driven
//! rebalancer that republishes it.
//!
//! Before this layer existed, the kind-partition + FNV-bucket rule was
//! duplicated across the lane pool, the result cache, and the server —
//! and it was *static*: a skewed workload pinned its hot shape classes
//! to one lane while sibling lanes idled, and the admission governor
//! then shed load that spare capacity could have served. The paper's
//! thesis says scheduling overheads must be managed at the root, and
//! the root cause here is the assignment itself, so this module makes
//! it a first-class, swappable object:
//!
//! * [`RoutingTable`] — an immutable snapshot of the full class → lane
//!   assignment, stamped with a monotonically increasing **epoch**.
//!   Epoch 0 is the *seed table*: exactly the historical kind-partition
//!   + FNV-bucket rule ([`seed_lane`]), so `--rebalance off` (which
//!   never publishes a successor) behaves bit-for-bit like the static
//!   scheme.
//! * [`Router`] — the shared handle: readers load the current table
//!   (an `Arc` swap behind an `RwLock`, O(1) and contention-free on the
//!   read side), and the rebalancer publishes successors. Epochs only
//!   move forward; a stale publish is rejected.
//! * [`Rebalancer`] — the feedback controller (`--rebalance adaptive`):
//!   each window it reads the admission governor's per-lane rolling
//!   p90s and window sample counts plus the router's per-class request
//!   counters, and when one lane's wait p90 dwarfs its coldest sibling
//!   within the same kind span ([`REBALANCE_RATIO`], with hysteresis
//!   re-arming via [`REARM_RATIO`]/[`REARM_TICKS`]) it moves the
//!   hottest class on the hot lane onto the cold lane and publishes a
//!   new epoch.
//!
//! Two invariants make an epoch swap safe everywhere else:
//!
//! * **In-flight jobs keep their admitted epoch.** [`super::lanes::LanePool::admit`]
//!   stamps each envelope with the `(lane, epoch)` pair read from one
//!   table snapshot; queue-wait attribution, steal accounting, and the
//!   per-lane telemetry series all key on that stamp, so a job admitted
//!   under epoch N is never re-routed or re-attributed by a later swap.
//! * **The cache-shard map is epoch-invariant.** [`RoutingTable::shard_of`]
//!   always answers the seed assignment, no matter the epoch: a class
//!   whose *dispatch lane* moves keeps its *cache shard*, so LRU
//!   residency survives the swap and single-flight leadership (which is
//!   registered per shard) stays exactly-once across it.
//!
//! The kind partition itself is preserved by construction: a move is
//! only legal within the class's kind span ([`kind_span`]), so a slow
//! matmul still can never queue ahead of a sort.

use super::costmodel::ServeCostModel;
use super::lanes::ShapeClass;
use crate::report::AsciiTable;
use crate::workload::traces::TraceKind;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Shape kinds (matmul, sort) — the partition dimension.
pub const KINDS: usize = 2;
/// Size buckets per kind (`floor(log2 n)` of a `usize`-sized job).
pub const MAX_BUCKETS: usize = usize::BITS as usize;
/// Total addressable shape classes; the routing table is fully
/// materialized over this (small) space.
pub const CLASS_SLOTS: usize = KINDS * MAX_BUCKETS;

/// FNV-1a, the seed table's bucket-spreading hash (stability matters:
/// epoch 0 must reproduce the historical assignment bit-for-bit).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The contiguous `(base, span)` of lanes a kind owns. With one lane
/// everything shares it; otherwise matmul owns the first `ceil(lanes/2)`
/// lanes and sort the rest — the structural head-of-line guarantee.
pub fn kind_span(kind: u8, lanes: usize) -> (usize, usize) {
    let lanes = lanes.max(1);
    if lanes == 1 {
        return (0, 1);
    }
    let sort_span = lanes / 2;
    if kind == 0 {
        (0, lanes - sort_span)
    } else {
        (lanes - sort_span, sort_span)
    }
}

/// The epoch-0 assignment: the class's size bucket FNV-hashes onto the
/// lanes within its kind's span. This is the one canonical copy of the
/// rule previously duplicated across `lanes.rs`, `cache.rs`, and the
/// server; [`ShapeClass::lane`] delegates here.
pub fn seed_lane(class: ShapeClass, lanes: usize) -> usize {
    let (base, span) = kind_span(class.kind_id(), lanes);
    base + (fnv1a(&[class.kind_id(), class.bucket()]) % span as u64) as usize
}

/// Dense index of a class in the materialized table.
pub fn class_slot(class: ShapeClass) -> usize {
    class.kind_id() as usize * MAX_BUCKETS + class.bucket() as usize
}

/// Inverse of [`class_slot`].
pub fn slot_class(slot: usize) -> ShapeClass {
    ShapeClass::from_parts((slot / MAX_BUCKETS) as u8, (slot % MAX_BUCKETS) as u8)
        .expect("every slot < CLASS_SLOTS is a valid class")
}

/// An immutable, epoch-stamped snapshot of the full ShapeClass → lane
/// assignment (plus the epoch-invariant class → cache-shard map).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoutingTable {
    epoch: u64,
    lanes: usize,
    /// Lane per [`class_slot`]; fully materialized so `lane_of` is one
    /// indexed load with no hashing on the admission hot path.
    assign: Vec<u16>,
}

impl RoutingTable {
    /// Epoch 0: the historical static assignment, bit-for-bit.
    pub fn seed(lanes: usize) -> RoutingTable {
        let lanes = lanes.max(1);
        let assign =
            (0..CLASS_SLOTS).map(|slot| seed_lane(slot_class(slot), lanes) as u16).collect();
        RoutingTable { epoch: 0, lanes, assign }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn lane_count(&self) -> usize {
        self.lanes
    }

    /// The dispatch lane a class routes to under this epoch.
    pub fn lane_of(&self, class: ShapeClass) -> usize {
        self.assign[class_slot(class)] as usize
    }

    /// The result-cache shard a class's keys live in. **Epoch-invariant
    /// by design** — always the seed assignment — so cached entries and
    /// in-flight single-flight registrations survive a lane move: only
    /// where a class *executes* changes, never where it is *memoized*.
    pub fn shard_of(&self, class: ShapeClass) -> usize {
        seed_lane(class, self.lanes)
    }

    /// A successor table (epoch + 1) with `class` reassigned to lane
    /// `to`. Rejects a move that would break the kind partition: the
    /// target must lie within the class's own kind span.
    pub fn with_move(&self, class: ShapeClass, to: usize) -> Result<RoutingTable> {
        let (base, span) = kind_span(class.kind_id(), self.lanes);
        if to < base || to >= base + span {
            bail!(
                "routing: lane {to} is outside the {} span [{base}, {})",
                class.name(),
                base + span
            );
        }
        let mut next = self.clone();
        next.epoch = self.epoch + 1;
        next.assign[class_slot(class)] = to as u16;
        Ok(next)
    }

    /// Classes whose assignment differs from the seed table, with their
    /// current lane (empty at epoch 0 by construction).
    pub fn moved(&self) -> Vec<(ShapeClass, usize)> {
        (0..CLASS_SLOTS)
            .map(slot_class)
            .filter(|c| self.lane_of(*c) != self.shard_of(*c))
            .map(|c| (c, self.lane_of(c)))
            .collect()
    }

    /// Count of classes assigned differently between two tables.
    fn diff_count(&self, other: &RoutingTable) -> u64 {
        self.assign.iter().zip(other.assign.iter()).filter(|(a, b)| a != b).count() as u64
    }
}

/// Whether the rebalancer runs (`--rebalance off|adaptive`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebalanceMode {
    /// Never publish a successor epoch: routing stays the epoch-0 seed
    /// table for the server's lifetime (the historical behaviour).
    Off,
    /// Run the [`Rebalancer`] thread: republish the table when observed
    /// per-lane queue waits show a persistent imbalance.
    Adaptive,
}

impl RebalanceMode {
    pub fn name(&self) -> &'static str {
        match self {
            RebalanceMode::Off => "off",
            RebalanceMode::Adaptive => "adaptive",
        }
    }

    pub fn from_name(s: &str) -> Option<RebalanceMode> {
        match s {
            "off" => Some(RebalanceMode::Off),
            "adaptive" => Some(RebalanceMode::Adaptive),
            _ => None,
        }
    }
}

/// The shared routing handle: O(1) snapshot loads for readers, epoch-
/// monotonic publishes for the rebalancer, and per-class request
/// counters (the rebalancer's "which class is hot" signal, and the
/// routing STATS table's traffic column).
pub struct Router {
    table: RwLock<Arc<RoutingTable>>,
    /// Total classes moved across all published epochs.
    moves: AtomicU64,
    /// Requests routed per [`class_slot`] (counted at routing time, so
    /// shed/rejected requests still register demand — a lane shedding
    /// 100% of a hot class must still look hot to the rebalancer).
    traffic: Vec<AtomicU64>,
}

impl std::fmt::Debug for Router {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Router").finish_non_exhaustive()
    }
}

impl Router {
    pub fn new(lanes: usize) -> Router {
        Router {
            table: RwLock::new(Arc::new(RoutingTable::seed(lanes))),
            moves: AtomicU64::new(0),
            traffic: (0..CLASS_SLOTS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn lane_count(&self) -> usize {
        self.load().lane_count()
    }

    /// Snapshot the current table (cheap: one `Arc` clone under a read
    /// lock held for nanoseconds).
    pub fn load(&self) -> Arc<RoutingTable> {
        Arc::clone(&self.table.read().unwrap_or_else(|p| p.into_inner()))
    }

    /// Route a job kind under the current epoch: `(lane, epoch)` read
    /// from one snapshot, so the pair is always internally consistent.
    pub fn route(&self, kind: &TraceKind) -> (usize, u64) {
        let t = self.load();
        (t.lane_of(ShapeClass::of(kind)), t.epoch())
    }

    /// Record one routed request against its class (admitted or not).
    pub fn note_request(&self, kind: &TraceKind) {
        self.traffic[class_slot(ShapeClass::of(kind))].fetch_add(1, Ordering::Relaxed);
    }

    /// Publish a successor table. The epoch must advance strictly and
    /// the lane count must match; returns the number of classes that
    /// moved (also accumulated into [`moves`](Router::moves)).
    pub fn publish(&self, next: RoutingTable) -> Result<u64> {
        let mut g = self.table.write().unwrap_or_else(|p| p.into_inner());
        if next.lane_count() != g.lane_count() {
            bail!("routing: lane count changed {} → {}", g.lane_count(), next.lane_count());
        }
        if next.epoch() <= g.epoch() {
            bail!("routing: stale epoch {} (current {})", next.epoch(), g.epoch());
        }
        let moved = next.diff_count(&g);
        self.moves.fetch_add(moved, Ordering::Relaxed);
        *g = Arc::new(next);
        Ok(moved)
    }

    /// Total classes moved across all epochs.
    pub fn moves(&self) -> u64 {
        self.moves.load(Ordering::Relaxed)
    }

    /// Per-[`class_slot`] routed-request counts.
    pub fn traffic_snapshot(&self) -> Vec<u64> {
        self.traffic.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// The STATS "routing" table + trailer: one row per shape class
    /// that has seen traffic or been moved off its seed lane, then
    /// `routing: epoch=<e> moves=<m> lanes=<n>`. Reads one table
    /// snapshot and the atomic counters — no O(work) scans.
    pub fn render(&self) -> String {
        let table = self.load();
        let traffic = self.traffic_snapshot();
        let mut t = AsciiTable::new(
            "routing (shape class → lane)",
            &["class", "lane", "seed lane", "requests"],
        );
        for slot in 0..CLASS_SLOTS {
            let class = slot_class(slot);
            let (lane, seed) = (table.lane_of(class), table.shard_of(class));
            if traffic[slot] == 0 && lane == seed {
                continue;
            }
            t.row(vec![
                class.name(),
                lane.to_string(),
                seed.to_string(),
                traffic[slot].to_string(),
            ]);
        }
        let mut out = if t.is_empty() { String::new() } else { t.render() };
        out.push_str(&format!(
            "routing: epoch={} moves={} lanes={}\n",
            table.epoch(),
            self.moves(),
            table.lane_count()
        ));
        out
    }
}

/// One lane's load evidence for a rebalance decision: the admission
/// governor's rolling p90 queue wait, how many waits the window holds,
/// and the lane queue's current occupancy. The occupancy disambiguates
/// an *empty* window: no samples with an empty queue is an idle lane (a
/// good move target), while no samples with work still queued is a
/// **stalled** lane — nothing has completed for two windows — which
/// must never be mistaken for cold capacity.
#[derive(Debug, Clone, Copy, Default)]
pub struct LaneLoad {
    pub p90_us: Option<f64>,
    pub samples: u64,
    pub queued: usize,
}

/// Act when the hot lane's rolling p90 is at least this multiple of its
/// coldest same-span sibling's (or the sibling has no samples at all).
pub const REBALANCE_RATIO: f64 = 3.0;
/// Hysteresis re-arm: after a move, a kind span only re-arms once its
/// hot/cold ratio falls to this (the new regime has genuinely evened
/// out) — or after [`REARM_TICKS`] windows, whichever comes first.
pub const REARM_RATIO: f64 = 1.5;
/// Re-arm a span after this many windows even if still skewed, so a
/// workload that stays pathological can be chased further.
pub const REARM_TICKS: u32 = 10;
/// The hot lane must hold at least this many waits in its rolling
/// window before its p90 counts as evidence.
pub const MIN_WINDOW_SAMPLES: u64 = 1;
/// Cost-model churn gate (`--cost-model on`): a move only publishes when
/// its predicted benefit — the candidate class's window traffic × the
/// hot/cold p90 gap, µs — exceeds this. An epoch swap is not free (the
/// moved class arrives at a lane with cold locality, and the span goes
/// hysteresis-blind for a window), so marginal wins are left alone.
pub const CHURN_COST_US: f64 = 10_000.0;

/// A published reassignment.
#[derive(Debug, Clone, Copy)]
pub struct Move {
    pub class: ShapeClass,
    pub from: usize,
    pub to: usize,
    /// The epoch the move was published as.
    pub epoch: u64,
}

/// The load-driven feedback controller. One instance per server, ticked
/// once per rebalance window by its own thread; all decision state
/// (hysteresis arms, traffic deltas) lives here, so the decision step
/// is a pure function of its inputs and unit-testable without threads.
pub struct Rebalancer {
    /// Per-kind hysteresis: a span that just moved a class is disarmed
    /// until its load evens out (or [`REARM_TICKS`] windows pass).
    armed: [bool; KINDS],
    ticks_since_move: [u32; KINDS],
    /// The last `(class, from-lane)` moved per kind: moving that class
    /// straight back to the lane it left requires *measured* evidence
    /// there (see the anti-ping-pong check in [`tick`](Rebalancer::tick)).
    last_move: [Option<(ShapeClass, usize)>; KINDS],
    last_traffic: Vec<u64>,
    /// Predicted-cost placement (`--cost-model on`): candidate classes
    /// are ranked by window traffic × predicted per-job cost instead of
    /// raw traffic, and a move must clear [`CHURN_COST_US`]. `None`
    /// keeps the traffic-delta greedy rule decision-for-decision.
    cost: Option<Arc<ServeCostModel>>,
}

impl std::fmt::Debug for Rebalancer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Rebalancer").finish_non_exhaustive()
    }
}

impl Default for Rebalancer {
    fn default() -> Self {
        Rebalancer::new()
    }
}

impl Rebalancer {
    pub fn new() -> Rebalancer {
        Rebalancer {
            armed: [true; KINDS],
            ticks_since_move: [0; KINDS],
            last_move: [None; KINDS],
            last_traffic: vec![0; CLASS_SLOTS],
            cost: None,
        }
    }

    /// Attach the serving cost model: candidate selection weighs demand
    /// by predicted per-job cost (a wide matmul class outweighs a thin
    /// sort class at equal traffic) and marginal moves are suppressed by
    /// the [`CHURN_COST_US`] gate.
    pub fn with_cost_model(mut self, cost: Option<Arc<ServeCostModel>>) -> Rebalancer {
        self.cost = cost;
        self
    }

    /// One decision window: inspect per-lane loads, publish at most one
    /// move (the hottest class on the hottest lane → the coldest lane
    /// within the same kind span), and return it. `loads` is indexed by
    /// lane.
    pub fn tick(&mut self, router: &Router, loads: &[LaneLoad]) -> Option<Move> {
        let table = router.load();
        let traffic = router.traffic_snapshot();
        let delta: Vec<u64> = traffic
            .iter()
            .enumerate()
            .map(|(i, now)| now.saturating_sub(self.last_traffic.get(i).copied().unwrap_or(0)))
            .collect();
        self.last_traffic = traffic;

        let mut published = None;
        for kind in 0..KINDS as u8 {
            let (base, span) = kind_span(kind, table.lane_count());
            if span < 2 {
                continue;
            }
            let pressure = |l: usize| loads.get(l).and_then(|x| x.p90_us).unwrap_or(0.0);
            let samples = |l: usize| loads.get(l).map_or(0, |x| x.samples);
            // Stalled ≠ idle: an empty window over a *non-empty* queue
            // means completions stopped, not that the lane has spare
            // capacity — such a lane must never be picked as the move
            // target (and its missing samples already disqualify it as
            // a measured hot lane).
            let stalled = |l: usize| samples(l) == 0 && loads.get(l).map_or(0, |x| x.queued) > 0;
            let hot = (base..base + span)
                .max_by(|a, b| pressure(*a).total_cmp(&pressure(*b)))
                .expect("span >= 2");
            let Some(cold) = (base..base + span)
                .filter(|&l| !stalled(l))
                .min_by(|a, b| pressure(*a).total_cmp(&pressure(*b)))
            else {
                continue; // every lane in the span is stalled: hands off
            };
            let (hot_p90, cold_p90) = (pressure(hot), pressure(cold));
            let balanced =
                hot_p90 <= 0.0 || (samples(cold) > 0 && hot_p90 <= REARM_RATIO * cold_p90);
            if !self.armed[kind as usize] {
                // Disarmed span: count windows toward the forced re-arm,
                // or re-arm early once the load has evened out. Either
                // way, act next window at the earliest — a fresh move
                // must see at least one window of the new regime.
                self.ticks_since_move[kind as usize] += 1;
                if balanced || self.ticks_since_move[kind as usize] >= REARM_TICKS {
                    self.armed[kind as usize] = true;
                }
                continue;
            }
            if published.is_some() || hot == cold {
                continue;
            }
            if samples(hot) < MIN_WINDOW_SAMPLES || hot_p90 <= 0.0 {
                continue;
            }
            let imbalanced =
                samples(cold) == 0 || cold_p90 <= 0.0 || hot_p90 >= REBALANCE_RATIO * cold_p90;
            if !imbalanced {
                continue;
            }
            // The hottest class currently assigned to the hot lane, by
            // routed requests this window (demand, not completions — a
            // 100%-shed class must still register). With the cost model
            // attached, demand is weighed by predicted per-job cost:
            // moving one wide matmul class relieves more queue-seconds
            // than moving a thin sort class with more requests.
            let on_hot = || {
                (0..CLASS_SLOTS)
                    .filter(|&slot| delta[slot] > 0)
                    .map(slot_class)
                    .filter(|c| c.kind_id() == kind && table.lane_of(*c) == hot)
            };
            let candidate = match &self.cost {
                Some(cm) => {
                    let weight =
                        |c: &ShapeClass| delta[class_slot(*c)] as f64 * cm.class_cost_ns(*c);
                    on_hot().max_by(|a, b| weight(a).total_cmp(&weight(b)))
                }
                None => on_hot().max_by_key(|c| delta[class_slot(*c)]),
            };
            let Some(class) = candidate else { continue };
            // Churn gate: the move's predicted benefit (this window's
            // demand for the class × the wait gap it would cross) must
            // be worth an epoch swap.
            if self.cost.is_some() {
                let benefit_us = delta[class_slot(class)] as f64 * (hot_p90 - cold_p90);
                if benefit_us < CHURN_COST_US {
                    continue;
                }
            }
            // Anti-ping-pong: a class's traffic follows it, so the lane
            // it just left always looks empty afterwards. Moving it
            // straight back on that vacuum alone would oscillate forever
            // on a perfectly healthy workload — the return trip needs
            // *measured* evidence (samples on the old lane showing it
            // genuinely colder).
            if samples(cold) == 0 && self.last_move[kind as usize] == Some((class, cold)) {
                continue;
            }
            let Ok(next) = table.with_move(class, cold) else { continue };
            let epoch = next.epoch();
            if router.publish(next).is_ok() {
                self.armed[kind as usize] = false;
                self.ticks_since_move[kind as usize] = 0;
                self.last_move[kind as usize] = Some((class, hot));
                published = Some(Move { class, from: hot, to: cold, epoch });
            }
        }
        published
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn class(kind: u8, bucket: u8) -> ShapeClass {
        ShapeClass::from_parts(kind, bucket).unwrap()
    }

    #[test]
    fn seed_table_matches_the_historical_static_rule() {
        for lanes in 1..=8 {
            let t = RoutingTable::seed(lanes);
            assert_eq!(t.epoch(), 0);
            for slot in 0..CLASS_SLOTS {
                let c = slot_class(slot);
                assert_eq!(t.lane_of(c), c.lane(lanes), "class {} lanes {lanes}", c.name());
                assert_eq!(t.shard_of(c), c.lane(lanes), "shard == seed lane at epoch 0");
            }
            assert!(t.moved().is_empty());
        }
    }

    #[test]
    fn with_move_respects_the_kind_partition() {
        let t = RoutingTable::seed(4);
        let sort = class(1, 9); // sort span is lanes {2, 3}
        let moved = t.with_move(sort, 2).unwrap();
        assert_eq!(moved.epoch(), 1);
        assert_eq!(moved.lane_of(sort), 2);
        assert_eq!(moved.shard_of(sort), t.shard_of(sort), "cache shard never moves");
        assert!(t.with_move(sort, 0).is_err(), "matmul span is off limits");
        assert!(t.with_move(sort, 4).is_err(), "out of range");
        let matmul = class(0, 4); // matmul span is lanes {0, 1}
        assert!(t.with_move(matmul, 3).is_err(), "sort span is off limits");
        assert!(t.with_move(matmul, 1).is_ok());
    }

    #[test]
    fn router_publish_is_epoch_monotonic() {
        let r = Router::new(4);
        let t0 = r.load();
        let sort = class(1, 9);
        let t1 = t0.with_move(sort, 2).unwrap();
        assert_eq!(r.publish(t1.clone()).unwrap(), 1, "one class moved");
        assert_eq!(r.load().epoch(), 1);
        assert_eq!(r.moves(), 1);
        // Re-publishing the same epoch — or anything older — is stale.
        assert!(r.publish(t1).is_err());
        assert!(r.publish(RoutingTable::seed(4)).is_err(), "epoch 0 is stale now");
        assert!(r.publish(RoutingTable::seed(6).with_move(sort, 4).unwrap()).is_err(),
            "lane-count change rejected");
        assert_eq!(r.load().epoch(), 1, "failed publishes leave the table untouched");
    }

    #[test]
    fn route_tracks_the_published_epoch() {
        let r = Router::new(4);
        let kind = TraceKind::Sort { n: 1000 }; // sort/2^9 → seed lane 3
        let (lane0, epoch0) = r.route(&kind);
        assert_eq!(epoch0, 0);
        let moved = r.load().with_move(ShapeClass::of(&kind), 2).unwrap();
        r.publish(moved).unwrap();
        let (lane1, epoch1) = r.route(&kind);
        assert_eq!(epoch1, 1);
        assert_ne!(lane0, lane1, "the class moved lanes");
        assert_eq!(lane1, 2);
    }

    #[test]
    fn traffic_counters_and_render() {
        let r = Router::new(4);
        let kind = TraceKind::Sort { n: 1000 };
        for _ in 0..3 {
            r.note_request(&kind);
        }
        let s = r.render();
        assert!(s.contains("sort/2^9"), "{s}");
        assert!(s.contains("routing: epoch=0 moves=0 lanes=4"), "{s}");
        let moved = r.load().with_move(ShapeClass::of(&kind), 2).unwrap();
        r.publish(moved).unwrap();
        let s = r.render();
        assert!(s.contains("routing: epoch=1 moves=1 lanes=4"), "{s}");
    }

    #[test]
    fn rebalancer_moves_hot_class_to_cold_lane_with_hysteresis() {
        let r = Router::new(4);
        let hot_kind = TraceKind::Sort { n: 1000 }; // sort/2^9 → lane 3
        for _ in 0..10 {
            r.note_request(&hot_kind);
        }
        let mut reb = Rebalancer::new();
        // Lane 3 hot, lane 2 silent: imbalance with an empty sibling.
        let loads = |hot_lane: usize, p90: f64| -> Vec<LaneLoad> {
            let mut v = vec![LaneLoad::default(); 4];
            v[hot_lane] = LaneLoad { p90_us: Some(p90), samples: 8, queued: 0 };
            v
        };
        let mv = reb.tick(&r, &loads(3, 5_000.0)).expect("imbalance must move");
        assert_eq!((mv.from, mv.to, mv.epoch), (3, 2, 1));
        assert_eq!(mv.class.name(), "sort/2^9");
        assert_eq!(r.load().lane_of(mv.class), 2);
        // Disarmed: the same evidence (now on lane 2) must not ping-pong
        // the class straight back.
        for _ in 0..10 {
            r.note_request(&hot_kind);
        }
        assert!(reb.tick(&r, &loads(2, 5_000.0)).is_none(), "hysteresis holds");
        // Even after the forced re-arm, the return trip to lane 3 is
        // blocked while lane 3 is merely *empty* — the vacuum behind the
        // move is not evidence, and without this a healthy steady
        // workload would oscillate between the two lanes forever.
        for _ in 0..REARM_TICKS + 2 {
            r.note_request(&hot_kind);
            assert!(
                reb.tick(&r, &loads(2, 5_000.0)).is_none(),
                "empty-lane return trip must stay blocked"
            );
        }
        // With *measured* evidence that lane 3 is genuinely colder
        // (samples on both sides, ratio past the threshold), the return
        // move is legitimate.
        let mut measured = vec![LaneLoad::default(); 4];
        measured[2] = LaneLoad { p90_us: Some(6_000.0), samples: 8, queued: 0 };
        measured[3] = LaneLoad { p90_us: Some(100.0), samples: 4, queued: 0 };
        r.note_request(&hot_kind);
        let mv2 = reb.tick(&r, &measured).expect("measured imbalance re-moves");
        assert_eq!((mv2.from, mv2.to, mv2.epoch), (2, 3, 2));
        assert_eq!(r.load().lane_of(mv2.class), 3);
    }

    #[test]
    fn rebalancer_never_targets_a_stalled_lane() {
        // 6 lanes ⇒ sort span {3, 4, 5}; sort/2^9 seed-routes to lane 3.
        let r = Router::new(6);
        let hot_kind = TraceKind::Sort { n: 1000 };
        for _ in 0..10 {
            r.note_request(&hot_kind);
        }
        let mut reb = Rebalancer::new();
        let mut loads = vec![LaneLoad::default(); 6];
        loads[3] = LaneLoad { p90_us: Some(5_000.0), samples: 8, queued: 4 };
        // Lane 4 has an empty window but a backed-up queue: *stalled*,
        // not idle — the move must pick the genuinely idle lane 5.
        loads[4] = LaneLoad { p90_us: None, samples: 0, queued: 7 };
        let mv = reb.tick(&r, &loads).expect("imbalance with an idle sibling moves");
        assert_eq!((mv.from, mv.to), (3, 5), "stalled lane 4 skipped as target");

        // 4 lanes ⇒ sort span {2, 3}: when the only sibling is stalled,
        // no move happens at all.
        let r = Router::new(4);
        for _ in 0..10 {
            r.note_request(&hot_kind);
        }
        let mut reb = Rebalancer::new();
        let mut loads = vec![LaneLoad::default(); 4];
        loads[3] = LaneLoad { p90_us: Some(5_000.0), samples: 8, queued: 4 };
        loads[2] = LaneLoad { p90_us: None, samples: 0, queued: 3 };
        assert!(reb.tick(&r, &loads).is_none(), "never move onto a stalled lane");
        assert_eq!(r.load().epoch(), 0);
    }

    #[test]
    fn rebalancer_ignores_balanced_and_evidence_free_spans() {
        let r = Router::new(4);
        for kind in [TraceKind::Sort { n: 1000 }, TraceKind::Sort { n: 300 }] {
            for _ in 0..5 {
                r.note_request(&kind);
            }
        }
        let mut reb = Rebalancer::new();
        // No samples anywhere: nothing to act on.
        assert!(reb.tick(&r, &[LaneLoad::default(); 4]).is_none());
        // Balanced waits (ratio < REBALANCE_RATIO): still nothing.
        let balanced: Vec<LaneLoad> = (0..4)
            .map(|l| {
                let p90 = if l == 3 { 1_000.0 } else { 600.0 };
                LaneLoad { p90_us: Some(p90), samples: 8, queued: 0 }
            })
            .collect();
        assert!(reb.tick(&r, &balanced).is_none());
        assert_eq!(r.load().epoch(), 0);
        assert_eq!(r.moves(), 0);
    }

    #[test]
    fn rebalancer_needs_traffic_to_pick_a_class() {
        let r = Router::new(4);
        let mut reb = Rebalancer::new();
        let mut loads = vec![LaneLoad::default(); 4];
        loads[3] = LaneLoad { p90_us: Some(9_000.0), samples: 8, queued: 0 };
        // Hot waits but zero routed requests this window: no candidate
        // class, no move (stale heat must not shuffle idle classes).
        assert!(reb.tick(&r, &loads).is_none());
        assert_eq!(r.load().epoch(), 0);
    }

    #[test]
    fn cost_weighted_candidate_prefers_the_expensive_class() {
        use crate::overhead::OverheadParams;

        // Find two sort classes — one thin, one wide — that share seed
        // lane 3 of a 4-lane pool, so both are candidates on the same
        // hot lane.
        let t = RoutingTable::seed(4);
        let on3: Vec<u8> = (4..24).filter(|&b| t.lane_of(class(1, b)) == 3).collect();
        let (thin, wide) = (*on3.first().unwrap(), *on3.last().unwrap());
        assert!(wide >= thin + 4, "need a genuinely wider class on the lane: {on3:?}");
        let seed_traffic = |r: &Router| {
            for _ in 0..10 {
                r.note_request(&TraceKind::Sort { n: 1usize << thin });
            }
            r.note_request(&TraceKind::Sort { n: 1usize << wide });
        };
        let mut loads = vec![LaneLoad::default(); 4];
        loads[3] = LaneLoad { p90_us: Some(50_000.0), samples: 8, queued: 0 };

        // Traffic-delta rule: 10 thin requests beat 1 wide request.
        let r = Router::new(4);
        seed_traffic(&r);
        let mv = Rebalancer::new().tick(&r, &loads).expect("imbalance moves");
        assert_eq!(mv.class, class(1, thin), "raw traffic picks the thin class");

        // Cost-weighted rule: one wide job is predicted to cost far more
        // queue time than ten thin ones, so the wide class moves.
        let cm = Arc::new(ServeCostModel::new(OverheadParams::paper_2022(), 4));
        let r = Router::new(4);
        seed_traffic(&r);
        let mut reb = Rebalancer::new().with_cost_model(Some(Arc::clone(&cm)));
        let mv = reb.tick(&r, &loads).expect("imbalance moves");
        assert_eq!(mv.class, class(1, wide), "predicted cost outweighs raw traffic");

        // Churn gate: a marginal win is not worth an epoch swap — one
        // request across a 5000µs gap is under CHURN_COST_US.
        let r = Router::new(4);
        r.note_request(&TraceKind::Sort { n: 1usize << wide });
        let mut loads = vec![LaneLoad::default(); 4];
        loads[3] = LaneLoad { p90_us: Some(5_000.0), samples: 8, queued: 0 };
        let mut reb = Rebalancer::new().with_cost_model(Some(cm));
        assert!(reb.tick(&r, &loads).is_none(), "benefit 5000µs < churn cost");
        assert_eq!(r.load().epoch(), 0);
    }

    #[test]
    fn single_lane_and_two_lane_pools_never_rebalance() {
        for lanes in [1, 2] {
            let r = Router::new(lanes);
            for _ in 0..10 {
                r.note_request(&TraceKind::Sort { n: 1000 });
            }
            let mut reb = Rebalancer::new();
            let loads: Vec<LaneLoad> = (0..lanes)
                .map(|_| LaneLoad { p90_us: Some(9_000.0), samples: 9, queued: 0 })
                .collect();
            assert!(reb.tick(&r, &loads).is_none(), "span width 1 cannot move ({lanes} lanes)");
        }
    }
}
