//! Line-protocol TCP front end for the coordinator — the serving shape
//! of the framework (requests in, routed execution, latency out).
//!
//! Protocol (one request per line, ASCII):
//!
//! ```text
//! MATMUL <n> [seed]      → OK MATMUL n=<n> engine=<e> us=<t> checksum=<c>
//! SORT <n> [seed]        → OK SORT n=<n> engine=<e> us=<t> checksum=<c>
//! STATS                  → multi-line telemetry table, terminated by "."
//! PING                   → PONG
//! QUIT                   → BYE (closes the connection)
//! ```
//!
//! Unknown/malformed input answers `ERR <reason>` and keeps the
//! connection open. One worker thread serves connections sequentially
//! (the CPU pool underneath is already parallel); this is deliberately a
//! *thin* request loop per DESIGN.md — the paper's contribution lives in
//! the manager/policy, not in connection juggling.

use super::{Coordinator, CoordinatorCfg};
use crate::workload::traces::TraceKind;
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

/// A running server bound to a local port.
pub struct Server {
    listener: TcpListener,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str) -> Result<Server> {
        Ok(Server { listener: TcpListener::bind(addr)? })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("bound socket has an address")
    }

    /// Serve until `max_conns` connections have completed (None = forever).
    pub fn serve(&self, cfg: CoordinatorCfg, max_conns: Option<usize>) -> Result<()> {
        let runtime = crate::runtime::Runtime::load(&crate::runtime::Runtime::default_dir()).ok();
        let mut coord = Coordinator::new(cfg, runtime);
        let mut served = 0usize;
        for stream in self.listener.incoming() {
            handle_conn(stream?, &mut coord)?;
            served += 1;
            if max_conns.is_some_and(|m| served >= m) {
                break;
            }
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, coord: &mut Coordinator) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break; // client hung up
        }
        match respond(coord, line.trim()) {
            Response::Line(s) => writeln!(out, "{s}")?,
            Response::Block(s) => {
                for l in s.lines() {
                    writeln!(out, "{l}")?;
                }
                writeln!(out, ".")?;
            }
            Response::Bye => {
                writeln!(out, "BYE")?;
                break;
            }
        }
        out.flush()?;
    }
    let _ = peer;
    Ok(())
}

enum Response {
    Line(String),
    Block(String),
    Bye,
}

fn respond(coord: &mut Coordinator, line: &str) -> Response {
    let mut toks = line.split_whitespace();
    match toks.next().map(|s| s.to_ascii_uppercase()).as_deref() {
        Some("PING") => Response::Line("PONG".into()),
        Some("QUIT") => Response::Bye,
        Some("STATS") => Response::Block(coord.telemetry.render()),
        Some(cmd @ ("MATMUL" | "SORT")) => {
            let n: usize = match toks.next().and_then(|t| t.parse().ok()) {
                Some(n) if n > 0 && n <= 4096 => n,
                _ => return Response::Line(format!("ERR {cmd} needs n in 1..=4096")),
            };
            let seed: u64 = toks.next().and_then(|t| t.parse().ok()).unwrap_or(42);
            let kind = if cmd == "MATMUL" { TraceKind::Matmul { n } } else { TraceKind::Sort { n } };
            let r = coord.submit(kind, seed);
            if r.ok {
                Response::Line(format!(
                    "OK {cmd} n={n} engine={} us={:.1} checksum={:.4}",
                    r.engine.name(),
                    r.service_us,
                    r.checksum
                ))
            } else {
                Response::Line(format!("ERR {cmd} n={n} failed on engine {}", r.engine.name()))
            }
        }
        Some(other) => Response::Line(format!("ERR unknown command {other:?}")),
        None => Response::Line("ERR empty request".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn roundtrip(lines: &[&str]) -> Vec<String> {
        let server = Server::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let h = std::thread::spawn(move || {
            server
                .serve(CoordinatorCfg { threads: 2, ..Default::default() }, Some(1))
                .unwrap();
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        for l in lines {
            writeln!(conn, "{l}").unwrap();
        }
        writeln!(conn, "QUIT").unwrap();
        conn.flush().unwrap();
        let reader = BufReader::new(conn);
        let out: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        h.join().unwrap();
        out
    }

    #[test]
    fn ping_and_quit() {
        let out = roundtrip(&["PING"]);
        assert_eq!(out, vec!["PONG".to_string(), "BYE".to_string()]);
    }

    #[test]
    fn matmul_and_sort_requests() {
        let out = roundtrip(&["MATMUL 32 7", "SORT 500"]);
        assert!(out[0].starts_with("OK MATMUL n=32"), "{out:?}");
        assert!(out[0].contains("checksum="));
        assert!(out[1].starts_with("OK SORT n=500"), "{out:?}");
    }

    #[test]
    fn stats_block_and_errors() {
        let out = roundtrip(&["SORT 100", "STATS", "FROB", "MATMUL 0", "MATMUL abc"]);
        assert!(out.iter().any(|l| l.contains("coordinator telemetry")));
        assert!(out.iter().any(|l| l == "."), "stats block terminator");
        assert!(out.iter().any(|l| l.starts_with("ERR unknown command")));
        assert_eq!(out.iter().filter(|l| l.starts_with("ERR MATMUL needs n")).count(), 2);
    }
}
