//! Line-protocol TCP front end for the coordinator — the serving shape
//! of the framework (requests in, admission-controlled routed execution,
//! latency out).
//!
//! Protocol (one request per line, ASCII):
//!
//! ```text
//! MATMUL <n> [seed]      → OK MATMUL n=<n> engine=<e> us=<t> queue_us=<q> checksum=<c>
//! SORT <n> [seed]        → OK SORT n=<n> engine=<e> us=<t> queue_us=<q> checksum=<c>
//! STATS                  → multi-line telemetry table, terminated by "."
//! DRAIN                  → stops admission, completes every admitted job,
//!                          answers "DRAINED" + final STATS ("." terminated),
//!                          then the server exits (rolling-restart primitive)
//! PING                   → PONG
//! QUIT                   → BYE (closes the connection)
//! ```
//!
//! With `--cache on`, a repeat of an identical deterministic request
//! (`(kind, n, seed)` equal) is answered from the warm result cache by
//! the reader itself — `engine=cache`, `queue_us=0`, checksum
//! bit-identical to the cold run — bypassing admission and the lane
//! queues entirely; concurrent identical requests coalesce onto one
//! execution (single-flight).
//!
//! Unknown/malformed input answers `ERR <reason>` and keeps the
//! connection open; a request whose lane queue is at depth answers
//! `ERR BUSY ...` (backpressure, not queueing); under `--admission
//! adaptive`, a request routed to a lane whose rolling p90 queue wait
//! exceeds the SLO answers `ERR OVERLOADED p90=<µs> slo=<µs>` (a soft
//! shed — retryable after backoff, unlike the hard depth bound); a
//! request arriving after `DRAIN` answers `ERR DRAINING` (terminal, not
//! retryable-soon). The complete wire grammar, with a worked session
//! transcript, is documented in `docs/PROTOCOL.md`.
//!
//! ## Threading model
//!
//! The serving layer manages its own overhead per the paper's thesis —
//! every handoff is explicit, bounded, and measured:
//!
//! * the **accept loop** (caller thread) hands each connection to a pool
//!   of `serve_threads` **reader threads**; a reader owns one connection
//!   at a time and processes its lines in order;
//! * `MATMUL`/`SORT` requests become [`Job`]s routed by shape class onto
//!   a sharded [`LanePool`] — one bounded queue per **dispatch lane**
//!   (depth `queue_depth` each). The [`Governor`] checks the lane's
//!   rolling queue-wait p90 against the SLO first (**shed** with `ERR
//!   OVERLOADED` in adaptive mode); a full lane then **rejects** with
//!   `ERR BUSY` instead of absorbing unbounded latency;
//! * one **dispatcher thread per lane** owns its own [`Coordinator`]
//!   (and CPU pool) and drains its queue in **shape batches** —
//!   consecutive same-shape jobs, *across connections*, up to
//!   `batch_max` wide with an optional `batch_linger_us` formation
//!   window. Kinds partition the lanes, so a slow matmul batch can never
//!   head-of-line-block queued sorts; an idle lane **steals** a
//!   shape-pure run from a sibling so sharding never strands work;
//! * each reader blocks on its job's reply channel, so per-connection
//!   response order is preserved while cross-connection execution batches;
//! * with `--rebalance adaptive`, one **rebalancer thread** reads the
//!   governor's per-lane wait windows each rebalance window and
//!   republishes the epoch-versioned routing table when a kind span is
//!   persistently imbalanced — in-flight jobs keep their admitted
//!   epoch's `(lane, epoch)` attribution across the swap.
//!
//! Queue wait, batch width, rejections, and per-lane steal/imbalance
//! counters land in the shared [`Telemetry`] (rendered by `STATS`)
//! alongside per-engine service times.
//!
//! Capacity interplay: each reader holds at most one job in flight, so
//! total queue occupancy is bounded by the reader count — `ERR BUSY`
//! fires when a lane's `queue_depth` is set *below* the number of readers
//! concurrently pushing that lane (load-shedding mode). Beyond readers +
//! handoff buffer, overload parks in the OS accept backlog (the accept
//! loop blocks on a bounded channel), so no in-process queue is ever
//! unbounded.
//!
//! With `--io reactor` only the *edge* of this model changes shape: a
//! fixed pool of epoll reactor threads (threads ≈ cores, never ≈
//! connections; see [`reactor`]) multiplexes every connection with
//! nonblocking reads, incremental line reassembly, and
//! `EPOLLOUT`-driven write backpressure, admitting through the same
//! governor into the same lanes. Dispatchers hand completed replies
//! back through a per-reactor outbox + eventfd wake instead of a
//! per-request channel. Replies are byte-identical either way; the
//! dispatcher/lane/cache/admission core stays synchronous in both
//! modes.

use super::admission::{Governor, SloTable};
use super::cache::{self, ResultCache};
use super::costmodel::ServeCostModel;
use super::faults::{FaultKind, FaultPlan};
use super::lanes::{Envelope, LanePool, ReplySink, ShapeClass};
use super::routing::{LaneLoad, RebalanceMode, Rebalancer, Router};
use super::{Coordinator, CoordinatorCfg, IoMode, Job, JobResult, RoutedEngine, Telemetry};
use crate::net::EventFd;
use crate::overhead::OverheadParams;
use crate::workload::traces::TraceKind;
use anyhow::Result;
use std::cell::Cell;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

mod reactor;

/// State shared by readers and the lane dispatchers.
struct Shared {
    lanes: LanePool,
    /// The epoch-versioned ShapeClass → lane table (shared with the
    /// lane pool; the rebalancer publishes successors under it).
    router: Arc<Router>,
    /// Rebalance mode, for gating the routing STATS block (and the
    /// rebalancer thread itself).
    rebalance: RebalanceMode,
    /// Tells the rebalancer thread to exit at wind-down.
    rebalance_stop: AtomicBool,
    /// Adaptive-admission state: readers consult it before pushing, lane
    /// dispatchers feed it measured queue waits (inert in fixed mode).
    governor: Governor,
    /// Warm result cache (`--cache on`), one shard per lane. `None`
    /// when disabled — every request then takes exactly the pre-cache
    /// path, byte for byte.
    cache: Option<ResultCache>,
    /// The serving cost model (`--cost-model on`): dispatchers consult
    /// it for the serial-inline crossover and feed it observed service
    /// times; the governor and rebalancer hold their own `Arc` clones.
    /// `None` when disabled — every decision then takes exactly the
    /// pre-cost-model path, byte for byte.
    cost: Option<Arc<ServeCostModel>>,
    /// The deterministic fault-injection plan (`--faults <spec>`).
    /// `None` when disarmed (the default) — every hook below then takes
    /// exactly the pre-harness path: no counting, no extra output, so
    /// replies, STATS, and DRAIN stay byte-identical.
    faults: Option<FaultPlan>,
    telemetry: Mutex<Telemetry>,
    next_id: AtomicU64,
    /// Set by `DRAIN`: admission answers `ERR DRAINING` from then on.
    draining: AtomicBool,
    /// Set once the drain completed: the accept loop exits.
    shutdown: AtomicBool,
    /// Jobs admitted to a lane queue. Incremented *before* the push (and
    /// rolled back on rejection) so the drain wait can never observe a
    /// queued-but-uncounted job.
    admitted: AtomicU64,
    /// Jobs finished by a dispatcher (after telemetry, before the reply).
    finished: AtomicU64,
    /// Threaded-mode connection registry: one clone per live reader
    /// connection, keyed by an id private to this map. The DRAIN path
    /// read-shuts these to wake blocked readers with EOF — no poll tick
    /// anywhere. Empty in reactor mode.
    conns: Mutex<HashMap<u64, TcpStream>>,
    /// Key source for `conns`.
    next_conn: AtomicU64,
    /// Wakes the Linux epoll accept loop at drain. `None` where
    /// eventfds don't exist — the loopback self-connect fallback then
    /// wakes the blocking accept loop instead.
    accept_wake: Option<EventFd>,
    /// The reactor pool (`--io reactor`); `None` in threaded mode, and
    /// every reactor-specific hook below then renders/does nothing.
    reactors: Option<Arc<reactor::ReactorSet>>,
    /// Listener address, used to wake the accept loop at shutdown on
    /// targets without the accept eventfd.
    local_addr: SocketAddr,
}

/// A running server bound to a local port.
pub struct Server {
    listener: TcpListener,
}

impl std::fmt::Debug for Server {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Server").finish_non_exhaustive()
    }
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str) -> Result<Server> {
        Ok(Server { listener: TcpListener::bind(addr)? })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("bound socket has an address")
    }

    /// Serve until `max_conns` connections have been accepted (None =
    /// forever) or a `DRAIN` completes, then wind down: readers finish
    /// their connections, the lane queues close, and every dispatcher
    /// completes queued work before return.
    pub fn serve(&self, cfg: CoordinatorCfg, max_conns: Option<usize>) -> Result<()> {
        let lane_count = cfg.lanes.max(1);
        let mut telemetry = Telemetry::default();
        telemetry.init_lanes(lane_count);
        telemetry.init_admission(
            cfg.admission.name(),
            cfg.slo_p90_us,
            cfg.slo_overrides.iter().map(|(c, us)| (c.name(), *us)).collect(),
        );
        let mut slo = SloTable::uniform(cfg.slo_p90_us);
        for (class, us) in &cfg.slo_overrides {
            slo.set(*class, *us);
        }
        let router = Arc::new(Router::new(lane_count));
        let cost = cfg
            .cost_model
            .then(|| Arc::new(ServeCostModel::new(OverheadParams::paper_2022(), cfg.threads.max(1))));
        // `--io reactor` needs the kernel substrate (epoll + eventfd) up
        // front: refuse at startup with the reason, rather than wedging
        // at runtime on a target without it.
        let reactors = match cfg.io {
            IoMode::Threads => None,
            IoMode::Reactor => Some(Arc::new(
                reactor::ReactorSet::new(cfg.effective_reactor_threads())
                    .map_err(|e| anyhow::anyhow!("--io reactor unavailable: {e}"))?,
            )),
        };
        let shared = Arc::new(Shared {
            lanes: LanePool::with_router(Arc::clone(&router), cfg.queue_depth, cfg.steal),
            router,
            rebalance: cfg.rebalance,
            rebalance_stop: AtomicBool::new(false),
            governor: Governor::new(cfg.admission, slo, cfg.admission_window_ms, lane_count)
                // The rebalancer reads the governor's wait windows, so
                // keep them populated even under fixed admission.
                .with_recording(cfg.rebalance == RebalanceMode::Adaptive)
                // Predictive admission (adaptive mode only): shed on
                // forecast queue wait before the measured p90 degrades.
                .with_cost_model(cost.clone()),
            cache: cfg
                .cache
                .then(|| ResultCache::new(lane_count, cfg.cache_entries, cfg.cache_bytes)),
            cost,
            faults: FaultPlan::parse(&cfg.faults)?,
            telemetry: Mutex::new(telemetry),
            next_id: AtomicU64::new(1),
            draining: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            admitted: AtomicU64::new(0),
            finished: AtomicU64::new(0),
            conns: Mutex::new(HashMap::new()),
            next_conn: AtomicU64::new(1),
            accept_wake: EventFd::new().ok(),
            reactors,
            local_addr: self.local_addr(),
        });

        // One dispatcher per lane, each owning its own Coordinator (and
        // CPU thread pool), so a saturated lane cannot stall a sibling's
        // execution any more than its queue.
        let dispatchers: Vec<_> = (0..lane_count)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                let cfg = cfg.clone();
                std::thread::spawn(move || lane_loop(lane, &shared, &cfg))
            })
            .collect();

        // Load-driven repartitioning (`--rebalance adaptive`): one
        // feedback thread reading the governor's per-lane windows each
        // rebalance window and republishing the routing table when a
        // kind span is persistently imbalanced. With `--rebalance off`
        // no thread exists and routing stays the epoch-0 seed table.
        let rebalancer = (cfg.rebalance == RebalanceMode::Adaptive).then(|| {
            let shared = Arc::clone(&shared);
            let window = Duration::from_millis(cfg.rebalance_window_ms.max(1));
            std::thread::spawn(move || rebalance_loop(&shared, window))
        });

        // Reader pool (`--io threads` only): serve_threads workers, one
        // connection each at a time. The handoff buffer is bounded (2×
        // the pool) so overload parks in the OS accept backlog instead
        // of an unbounded in-process channel — the accept loop blocks
        // once readers and buffer are saturated.
        let mut conn_tx = None;
        let mut readers = Vec::new();
        if shared.reactors.is_none() {
            let (tx, rx) = mpsc::sync_channel::<TcpStream>(cfg.serve_threads.max(1) * 2);
            let conn_rx = Arc::new(Mutex::new(rx));
            conn_tx = Some(tx);
            readers = (0..cfg.serve_threads.max(1))
                .map(|_| {
                    let shared = Arc::clone(&shared);
                    let conn_rx = Arc::clone(&conn_rx);
                    std::thread::spawn(move || loop {
                        let next = conn_rx.lock().unwrap().recv();
                        match next {
                            // Per-connection IO errors end that connection only.
                            Ok(stream) => {
                                let _ = handle_conn(stream, &shared);
                            }
                            Err(_) => break, // accept loop done
                        }
                    })
                })
                .collect();
        }

        // Reactor pool (`--io reactor`): a fixed set of event-loop
        // threads adopting connections round-robin from the accept loop.
        let reactor_threads: Vec<_> = match &shared.reactors {
            Some(set) => (0..set.thread_count())
                .map(|i| {
                    let shared = Arc::clone(&shared);
                    std::thread::spawn(move || reactor::reactor_loop(i, &shared))
                })
                .collect(),
            None => Vec::new(),
        };

        // Accept loop. An accept error must still run the wind-down
        // below — otherwise the dispatchers (and their thread pools)
        // leak, blocked forever — so capture the outcome instead of
        // returning early. On Linux the loop multiplexes the listener
        // with the drain eventfd, so DRAIN wakes it without the
        // loopback self-connect the blocking fallback needs.
        let mut accepted = 0usize;
        let dispatch = |stream: TcpStream| match (&shared.reactors, &conn_tx) {
            (Some(set), _) => set.assign(stream),
            (None, Some(tx)) => tx.send(stream).expect("reader pool outlives the accept loop"),
            (None, None) => unreachable!("threads mode always has a reader pool"),
        };
        #[cfg(target_os = "linux")]
        let accept_result: Result<()> = if shared.accept_wake.is_some() {
            accept_epoll(&self.listener, &shared, &dispatch, max_conns, &mut accepted)
        } else {
            accept_blocking(&self.listener, &shared, &dispatch, max_conns, &mut accepted)
        };
        #[cfg(not(target_os = "linux"))]
        let accept_result: Result<()> =
            accept_blocking(&self.listener, &shared, &dispatch, max_conns, &mut accepted);
        drop(dispatch);
        drop(conn_tx);
        for r in readers {
            let _ = r.join();
        }
        // Reactors wind down strictly after the accept loop (no new
        // adoptions) and strictly before the dispatchers close: a
        // reactor flushing its last in-flight replies still needs live
        // dispatchers to complete them.
        if let Some(set) = &shared.reactors {
            set.finish_accepting();
        }
        for h in reactor_threads {
            let _ = h.join();
        }
        shared.lanes.close_all();
        for d in dispatchers {
            let _ = d.join();
        }
        shared.rebalance_stop.store(true, Ordering::SeqCst);
        if let Some(h) = rebalancer {
            let _ = h.join();
        }
        accept_result
    }
}

/// The portable accept path: blocking `incoming()`, woken at drain by
/// the DRAIN arm's loopback self-connect fallback.
fn accept_blocking(
    listener: &TcpListener,
    shared: &Shared,
    dispatch: &dyn Fn(TcpStream),
    max_conns: Option<usize>,
    accepted: &mut usize,
) -> Result<()> {
    for stream in listener.incoming() {
        // A completed DRAIN wakes this loop with a connection it can
        // drop on arrival; exit (rolling-restart path).
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(stream) => {
                dispatch(stream);
                *accepted += 1;
                if max_conns.is_some_and(|m| *accepted >= m) {
                    break;
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// The Linux accept path: a nonblocking listener multiplexed with the
/// drain eventfd, so a completed DRAIN wakes the loop directly —
/// wildcard binds included — with no self-connect.
#[cfg(target_os = "linux")]
fn accept_epoll(
    listener: &TcpListener,
    shared: &Shared,
    dispatch: &dyn Fn(TcpStream),
    max_conns: Option<usize>,
    accepted: &mut usize,
) -> Result<()> {
    use crate::net::{Interest, Poller};
    use std::os::unix::io::AsRawFd;
    const TOKEN_LISTENER: u64 = 0;
    const TOKEN_WAKE: u64 = 1;
    let wake = shared.accept_wake.as_ref().expect("epoll accept requires the wake eventfd");
    listener.set_nonblocking(true)?;
    let poller = Poller::new()?;
    poller.add(listener.as_raw_fd(), TOKEN_LISTENER, Interest::readable())?;
    poller.add(wake.raw(), TOKEN_WAKE, Interest::readable())?;
    let mut events = Vec::new();
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        poller.poll_io(&mut events, None)?;
        for ev in &events {
            if ev.token == TOKEN_WAKE {
                wake.drain();
            }
        }
        // Accept everything ready (level-triggered: anything left is
        // re-reported on the next poll_io).
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return Ok(());
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    dispatch(stream);
                    *accepted += 1;
                    if max_conns.is_some_and(|m| *accepted >= m) {
                        return Ok(());
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            }
        }
    }
}

/// The rebalancer thread body: tick the decision loop once per window
/// (polling a fine-grained clock so shutdown/drain is prompt), publish
/// at most one move per tick, and pre-open the new epoch's telemetry
/// table so per-lane series split regimes cleanly.
fn rebalance_loop(shared: &Shared, window: Duration) {
    // With the cost model attached, candidate classes are weighed by
    // predicted per-job cost and marginal moves are churn-gated.
    let mut rebalancer = Rebalancer::new().with_cost_model(shared.cost.clone());
    let poll = Duration::from_millis(10).min(window);
    let mut elapsed = Duration::ZERO;
    loop {
        if shared.rebalance_stop.load(Ordering::SeqCst)
            || shared.shutdown.load(Ordering::SeqCst)
            || shared.draining.load(Ordering::SeqCst)
        {
            return;
        }
        std::thread::sleep(poll);
        elapsed += poll;
        if elapsed < window {
            continue;
        }
        elapsed = Duration::ZERO;
        let loads: Vec<LaneLoad> = (0..shared.lanes.lane_count())
            .map(|lane| {
                let (p90_us, samples) = shared.governor.window_load(lane);
                // Queue occupancy distinguishes idle from stalled when
                // the window is empty (a stalled lane must never look
                // like a cold move target).
                LaneLoad { p90_us, samples, queued: shared.lanes.queue(lane).len() }
            })
            .collect();
        if let Some(mv) = rebalancer.tick(&shared.router, &loads) {
            telemetry_lock(shared).begin_epoch(mv.epoch);
            eprintln!(
                "ohm: routing epoch {}: moved {} lane {} → {} (load-driven rebalance)",
                mv.epoch,
                mv.class.name(),
                mv.from,
                mv.to
            );
        }
    }
}

/// Lock the shared telemetry, tolerating poison: telemetry is advisory
/// stats, and a panicking writer must not cascade panics into readers.
fn telemetry_lock(shared: &Shared) -> std::sync::MutexGuard<'_, Telemetry> {
    shared.telemetry.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Lane dispatcher entry: run the batch loop, and if it dies for any
/// reason, reject-drain this lane's queue so every queued envelope's
/// reply sender drops — blocked readers then see a disconnect ("ERR
/// internal dispatcher unavailable") instead of waiting forever. The
/// drops still count as finished so a concurrent DRAIN cannot hang.
fn lane_loop(lane: usize, shared: &Shared, cfg: &CoordinatorCfg) {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        lane_dispatch(lane, shared, cfg);
    }));
    if outcome.is_err() {
        eprintln!("ohm: dispatch lane {lane} died (panic); rejecting its queued jobs");
        let q = shared.lanes.queue(lane);
        q.close();
        while q.pop().is_some() {
            shared.finished.fetch_add(1, Ordering::SeqCst);
        }
    }
}

/// Drain this lane's queue in cross-connection shape batches (stealing
/// from siblings when idle) until the whole pool is closed and dry.
fn lane_dispatch(lane: usize, shared: &Shared, cfg: &CoordinatorCfg) {
    let runtime = crate::runtime::Runtime::load(&crate::runtime::Runtime::default_dir()).ok();
    let coord = Coordinator::new(cfg.clone(), runtime);
    let linger = Duration::from_micros(cfg.batch_linger_us);
    loop {
        // kill-lane fires *before* the next pop, never after: an
        // injected panic here strands no popped-but-unfinished
        // envelope, so `lane_loop`'s reject-drain keeps
        // admitted == finished exact. One opportunity per batch cycle.
        if let Some(plan) = &shared.faults {
            if plan.should_fire(FaultKind::KillLane) {
                telemetry_lock(shared).record_fault();
                panic!("injected fault: kill-lane {lane}");
            }
        }
        let Some(batch) = shared.lanes.next_batch(lane, cfg.batch_max, linger) else {
            break;
        };
        // Batches are shape-pure runs from one queue, so every envelope
        // in a run shares its admitted epoch except across the instant
        // of a swap; attribute the batch to its head's epoch.
        let epoch = batch.envelopes[0].epoch;
        telemetry_lock(shared).record_lane_batch(lane, epoch, batch.envelopes.len(), batch.stolen);
        if let Some(plan) = &shared.faults {
            // stall-dispatcher holds a popped batch hostage: queue wait
            // inflates behind it — scheduling overhead, surfaced.
            if plan.should_fire(FaultKind::StallDispatcher) {
                telemetry_lock(shared).record_fault();
                std::thread::sleep(Duration::from_millis(20));
            }
            // delay-steal stretches the cross-lane migration window of a
            // stolen batch (only stolen batches are opportunities).
            if batch.stolen && plan.should_fire(FaultKind::DelaySteal) {
                telemetry_lock(shared).record_fault();
                std::thread::sleep(Duration::from_millis(10));
            }
        }
        for env in batch.envelopes {
            execute_one(&coord, shared, env);
        }
    }
}

/// Execute one envelope: contain engine panics (a poisoned job must
/// answer ERR to its own reader, not wedge the lane), record telemetry
/// with the queue wait filled in, then reply. Per-lane accounting keys
/// on the envelope's *admitted* lane, not on whichever dispatcher runs
/// it, so the executing lane is not a parameter.
fn execute_one(coord: &Coordinator, shared: &Shared, env: Envelope) {
    let queue_us = env.enqueued.elapsed().as_nanos() as f64 / 1e3;
    // Queue wait is attributed to the lane the job was *admitted* to (a
    // stolen job's wait indicts the victim's queue, not the thief's) —
    // both in the governor and in the per-lane telemetry below, so the
    // STATS admission table shows exactly the waits the governor acts
    // on. Observed before the reply is sent, so a client that has seen
    // its own OK can rely on the sample being in the rolling window.
    let admit_lane = env.lane;
    let admit_epoch = env.epoch;
    shared.governor.observe(admit_lane, queue_us);
    // Serve-time crossover (`--cost-model on`): a job the model predicts
    // below the serial/parallel crossover runs serially right here on
    // the lane thread — the fork-join machinery (and its α/β/γ/δ
    // overhead) is skipped entirely. Checksums are bit-identical to
    // pooled execution, so the reply differs only in `engine=`.
    let inline = shared.cost.as_ref().is_some_and(|cm| cm.should_inline(&env.job.kind));
    let executed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if inline {
            coord.execute_job_inline(&env.job)
        } else {
            coord.execute_job(&env.job)
        }
    }))
    .ok();
    let panicked = executed.is_none();
    let mut r = executed.unwrap_or_else(|| {
        // Re-route only on the (rare) panic path, to label the fallback
        // with the engine that would have run.
        let routed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            coord.route(&env.job.kind)
        }))
        .unwrap_or(RoutedEngine::CpuSerial);
        JobResult {
            id: env.job.id,
            shape_key: env.job.shape_key(),
            engine: routed,
            service_us: 0.0,
            queue_us: 0.0,
            checksum: 0.0,
            ok: false,
        }
    });
    r.queue_us = queue_us;
    // Close the feedback loop: every completed execution (any engine)
    // refreshes the class's service-time EWMA, pulling future inline /
    // admission / rebalance predictions toward what this machine
    // actually measures.
    if !panicked {
        if let Some(cm) = &shared.cost {
            cm.observe(&env.job.kind, r.service_us);
            if r.engine == RoutedEngine::SerialInline {
                cm.note_inline(&env.job.kind);
            }
        }
    }
    {
        let mut t = telemetry_lock(shared);
        if panicked {
            // Count the failure, but don't push a fabricated 0µs sample
            // into an engine's service-time series.
            t.failed += 1;
        } else {
            t.record(&r);
        }
        t.record_lane_served(admit_lane, admit_epoch, queue_us);
    }
    shared.finished.fetch_add(1, Ordering::SeqCst);
    // A receiver that hung up mid-flight (reader gone, reactor shut)
    // just drops the result.
    env.reply.send(r);
}

thread_local! {
    /// The [`Shared::conns`] registry key of the connection this reader
    /// thread is currently serving, so the DRAIN sweep can skip the very
    /// connection that issued the DRAIN — its pipelined post-drain lines
    /// must still be answered (`ERR DRAINING`, `BYE`), per the protocol.
    static CURRENT_CONN: Cell<Option<u64>> = const { Cell::new(None) };
}

/// Registry guard: deregisters the connection (and clears the
/// thread-local) however `handle_conn` exits, so the DRAIN sweep never
/// touches a dead entry.
struct ConnGuard<'a> {
    shared: &'a Shared,
    id: u64,
}

impl<'a> ConnGuard<'a> {
    fn register(shared: &'a Shared, id: u64, stream: TcpStream) -> ConnGuard<'a> {
        shared.conns.lock().unwrap_or_else(|p| p.into_inner()).insert(id, stream);
        CURRENT_CONN.with(|c| c.set(Some(id)));
        ConnGuard { shared, id }
    }
}

impl Drop for ConnGuard<'_> {
    fn drop(&mut self) {
        CURRENT_CONN.with(|c| c.set(None));
        self.shared.conns.lock().unwrap_or_else(|p| p.into_inner()).remove(&self.id);
    }
}

fn handle_conn(stream: TcpStream, shared: &Shared) -> Result<()> {
    let id = shared.next_conn.fetch_add(1, Ordering::Relaxed);
    let _guard = ConnGuard::register(shared, id, stream.try_clone()?);
    // Steady-state readers block in `read_line` with *no* timeout — a
    // completed DRAIN wakes them by read-shutting the registered clone
    // (EOF), not by a poll tick. Only a connection adopted after the
    // shutdown flag is already up (it raced the accept loop's exit, so
    // the sweep may have run before it registered) polls the flag on a
    // short tick instead of blocking forever.
    if shared.shutdown.load(Ordering::SeqCst) {
        stream.set_read_timeout(Some(Duration::from_millis(1)))?;
    }
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = BufWriter::new(stream.try_clone()?);
    // `line` accumulates across interrupted reads: a partial line that
    // arrived before a wake must not be dropped on retry.
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break, // client hung up (or the DRAIN sweep's EOF)
            Ok(_) => {
                let response = respond(shared, line.trim());
                line.clear();
                match response {
                    Response::Line(s) => {
                        if let Some(plan) = &shared.faults {
                            // wedge-client: half a reply line, a flush so
                            // it reaches the wire, a stall, then close —
                            // the peer sees a truncated line and EOF.
                            if plan.should_fire(FaultKind::WedgeClient) {
                                telemetry_lock(shared).record_fault();
                                let bytes = s.as_bytes();
                                out.write_all(&bytes[..bytes.len() / 2])?;
                                out.flush()?;
                                std::thread::sleep(Duration::from_millis(50));
                                break;
                            }
                            // drop-reply: the request executed (exactly
                            // once), but its reply never reaches the
                            // socket — the connection just closes.
                            if plan.should_fire(FaultKind::DropReply) {
                                telemetry_lock(shared).record_fault();
                                break;
                            }
                        }
                        writeln!(out, "{s}")?
                    }
                    Response::Block(s) => {
                        for l in s.lines() {
                            writeln!(out, "{l}")?;
                        }
                        writeln!(out, ".")?;
                    }
                    Response::Bye => {
                        writeln!(out, "BYE")?;
                        out.flush()?;
                        break;
                    }
                }
                out.flush()?;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Only the post-shutdown straggler path above sets a
                // read timeout, so a tick here means the server is
                // exiting and this connection should go with it.
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    // Flush-and-close before this reader moves on: reject lines (`ERR
    // BUSY`, `ERR DRAINING`) and BYE must reach the wire complete, with
    // the FIN strictly after them — a client may never observe EOF in
    // place of a truncated error line.
    out.flush()?;
    let _ = stream.shutdown(Shutdown::Write);
    Ok(())
}

enum Response {
    Line(String),
    Block(String),
    Bye,
}

fn respond(shared: &Shared, line: &str) -> Response {
    let mut toks = line.split_whitespace();
    match toks.next().map(|s| s.to_ascii_uppercase()).as_deref() {
        Some("PING") => Response::Line("PONG".into()),
        Some("QUIT") => Response::Bye,
        Some("STATS") => {
            // Snapshot under the lock, render (sorts + formatting) outside
            // it. Queue-wait and batch-width series are fixed-memory
            // digests, so the clone cost no longer scales with the sample
            // count; only the capped per-engine/per-shape service-time
            // vectors (≤ SAMPLE_CAP each) are copied.
            let snapshot = telemetry_lock(shared).clone();
            let mut block = snapshot.render();
            block.push_str(&queue_line(shared));
            block.push_str(&cache_block(shared));
            block.push_str(&cost_model_block(shared));
            block.push_str(&routing_block(shared));
            block.push_str(&faults_block(shared));
            block.push_str(&reactor_block(shared));
            Response::Block(block)
        }
        Some("DRAIN") => {
            // Stop admission atomically: requests racing past the flag
            // either land in a still-open lane queue (and are completed
            // below) or see the closed queue and answer ERR DRAINING.
            shared.draining.store(true, Ordering::SeqCst);
            shared.lanes.close_all();
            // Every admitted job completes: lane queues close gracefully,
            // work stealing keeps helping, and `finished` counts each
            // envelope exactly once (including panic-path rejects).
            while shared.admitted.load(Ordering::SeqCst) != shared.finished.load(Ordering::SeqCst)
            {
                std::thread::sleep(Duration::from_millis(1));
            }
            let snapshot = telemetry_lock(shared).clone();
            let mut block = String::from("DRAINED\n");
            block.push_str(&snapshot.render());
            block.push_str(&queue_line(shared));
            block.push_str(&cache_block(shared));
            block.push_str(&cost_model_block(shared));
            block.push_str(&routing_block(shared));
            block.push_str(&faults_block(shared));
            block.push_str(&reactor_block(shared));
            block.push_str(&format!(
                "drained: admitted={} finished={}\n",
                shared.admitted.load(Ordering::SeqCst),
                shared.finished.load(Ordering::SeqCst),
            ));
            // Rolling-restart exit: raise the flag first, then wake
            // everything blocked on the serving edge so each loop
            // observes it — deterministically, with no poll tick
            // anywhere.
            shared.shutdown.store(true, Ordering::SeqCst);
            // Threaded readers blocked in `read_line` on idle
            // connections: shut their read halves. EOF wakes them
            // immediately, while bytes already received (pipelined
            // requests) still drain first. The draining connection
            // itself is skipped: its post-DRAIN lines must still be
            // answered.
            {
                let skip = CURRENT_CONN.with(|c| c.get());
                let conns = shared.conns.lock().unwrap_or_else(|p| p.into_inner());
                for (id, conn) in conns.iter() {
                    if Some(*id) == skip {
                        continue;
                    }
                    let _ = conn.shutdown(Shutdown::Read);
                }
            }
            // The accept loop: its eventfd on Linux; where eventfds
            // don't exist, the legacy loopback self-connect (a wildcard
            // bind address is not connectable on every platform, so
            // rewrite it to loopback on the bound port).
            match &shared.accept_wake {
                Some(wake) => wake.signal(),
                None => {
                    let mut wake = shared.local_addr;
                    if wake.ip().is_unspecified() {
                        wake.set_ip(if wake.is_ipv4() {
                            std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                        } else {
                            std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                        });
                    }
                    let _ = TcpStream::connect(wake);
                }
            }
            // Every reactor: wind down — close idle connections, flush
            // in-flight replies, then exit (bounded, event-driven).
            if let Some(set) = &shared.reactors {
                set.wake_all();
            }
            Response::Block(block)
        }
        Some(cmd @ ("MATMUL" | "SORT")) => {
            let cmd: &'static str = if cmd == "MATMUL" { "MATMUL" } else { "SORT" };
            // Threaded path: admit through the shared pipeline, then
            // block this reader on the reply channel — per-connection
            // response order is preserved while cross-connection
            // execution batches.
            let (reply_tx, reply_rx) = mpsc::channel();
            match admit_job(shared, cmd, &mut toks, true, move |_id| {
                ReplySink::Channel(reply_tx)
            }) {
                Admit::Now(line) => Response::Line(line),
                Admit::Queued(pending) => {
                    Response::Line(finish_reply(pending, reply_rx.recv().ok()))
                }
            }
        }
        Some(other) => Response::Line(format!("ERR unknown command {other:?}")),
        None => Response::Line("ERR empty request".into()),
    }
}

/// Admission outcome for a job line, shared by both IO modes.
enum Admit<'a> {
    /// Answered immediately: cache hit, validation error, shed, or
    /// reject — the complete wire line.
    Now(String),
    /// Queued: the result arrives through the envelope's reply sink;
    /// render the wire line with [`finish_reply`] when it lands.
    Queued(PendingReply<'a>),
}

/// A queued request awaiting its dispatcher reply: everything needed to
/// render the wire line once the [`JobResult`] lands, including the
/// single-flight fill obligation (dropping it aborts the flight, so a
/// lost reply can never strand cache followers).
struct PendingReply<'a> {
    /// The [`Job::id`] — reactors key their pending-connection index on
    /// it to route the completion back.
    id: u64,
    cmd: &'static str,
    n: usize,
    flight: Option<cache::Flight<'a>>,
}

/// Everything between a parsed `MATMUL`/`SORT` command token and the
/// lane queue: argument validation, the drain check, the warm-cache
/// consult, fault hooks, routing, soft admission, and the bounded push.
/// One pipeline for both IO modes, so replies stay byte-identical;
/// the modes differ only in `block_on_flight` — may this caller park on
/// a concurrent single-flight leader's condvar? A reactor thread must
/// not, so it passes `false` and a contended key *bypasses* the cache
/// ([`ResultCache::try_lookup`]): one redundant execution, never a
/// stalled event loop. `make_sink` builds the reply sink and runs only
/// if the request reaches envelope construction — validation, hit, and
/// shed paths never construct one.
fn admit_job<'a>(
    shared: &'a Shared,
    cmd: &'static str,
    toks: &mut std::str::SplitWhitespace<'_>,
    block_on_flight: bool,
    make_sink: impl FnOnce(u64) -> ReplySink,
) -> Admit<'a> {
    let n: usize = match toks.next().and_then(|t| t.parse().ok()) {
        Some(n) if n > 0 && n <= 4096 => n,
        _ => return Admit::Now(format!("ERR {cmd} needs n in 1..=4096")),
    };
    let seed: u64 = toks.next().and_then(|t| t.parse().ok()).unwrap_or(42);
    if shared.draining.load(Ordering::SeqCst) {
        return Admit::Now(format!("ERR DRAINING {cmd} rejected: server is draining"));
    }
    let kind = if cmd == "MATMUL" { TraceKind::Matmul { n } } else { TraceKind::Sort { n } };
    // Warm result cache, consulted after the drain check (DRAIN is
    // terminal — a draining server must not keep answering, even from
    // memory) but before *any* admission state: a hit is served right
    // here on the calling thread. It consumes no admission budget,
    // touches no lane queue, and contributes nothing to the queue-wait
    // digests — so hits keep flowing even while the lane itself is
    // shedding. A miss makes this caller the single-flight leader:
    // concurrent identical requests coalesce onto `flight`, and the
    // leader fills the cache exactly once in [`finish_reply`]
    // (admission-side fill, so exactly-once holds even when work
    // stealing runs the job on a thief lane). Every rejection or
    // failure path from here on drops `flight`, which aborts it —
    // followers wake and retry rather than hang.
    let mut flight = None;
    if let Some(cache) = &shared.cache {
        let sw = Instant::now();
        let looked = if block_on_flight {
            Some(cache.lookup(&kind, seed))
        } else {
            cache.try_lookup(&kind, seed)
        };
        match looked {
            Some(cache::Lookup::Hit(hit)) => {
                let lookup_us = sw.elapsed().as_nanos() as f64 / 1e3;
                telemetry_lock(shared).record_cache_hit(lookup_us);
                return Admit::Now(format!(
                    "OK {cmd} n={n} engine={} us={lookup_us:.1} queue_us=0.0 checksum={:.4}",
                    RoutedEngine::Cache.name(),
                    hit.checksum
                ));
            }
            Some(cache::Lookup::Miss(f)) => flight = Some(f),
            // A concurrent leader is in flight and this caller may not
            // wait: bypass the cache for this one request.
            None => {}
        }
    }
    // abort-flight: give up the just-won single-flight leadership
    // before execution. Followers coalesced onto this flight wake and
    // retry as their own leaders; the request itself still executes and
    // replies normally — only the cache fill is lost. One opportunity
    // per won leadership.
    if let Some(plan) = &shared.faults {
        if flight.is_some() && plan.should_fire(FaultKind::AbortFlight) {
            telemetry_lock(shared).record_fault();
            drop(flight.take());
        }
    }
    // Route under the current epoch (and register demand with the
    // router's per-class traffic counters — sheds included, so a
    // 100%-shed hot class still looks hot to the rebalancer). Soft
    // admission next: the governor sheds when this lane's rolling p90
    // queue wait exceeds the *class's* SLO (adaptive mode only; in
    // fixed mode admit() returns before taking any lock, and the lazy
    // `queued` closure keeps the queue mutex untouched outside the rare
    // empty-window path). Distinct from ERR BUSY — the queue may well
    // have room; it is the *wait*, not the depth, that is out of
    // budget.
    let class = ShapeClass::of(&kind);
    shared.router.note_request(&kind);
    let (lane, epoch) = shared.router.route(&kind);
    if let Err(over) = shared.governor.admit(lane, class, || shared.lanes.queue(lane).len()) {
        telemetry_lock(shared).record_shed(lane, epoch);
        return Admit::Now(format!(
            "ERR OVERLOADED p90={} slo={:.0}",
            over.p90_evidence(),
            over.slo_us
        ));
    }
    let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
    let envelope = Envelope {
        job: Job { id, kind, seed, arrival_us: 0 },
        lane,  // provisional; admit() re-stamps authoritatively
        epoch, // likewise
        enqueued: Instant::now(),
        reply: make_sink(id),
    };
    // Count before the push (rolled back on rejection): the DRAIN wait
    // must never see a queued job missing from `admitted`.
    shared.admitted.fetch_add(1, Ordering::SeqCst);
    if shared.lanes.admit(envelope).is_err() {
        shared.admitted.fetch_sub(1, Ordering::SeqCst);
        if shared.draining.load(Ordering::SeqCst) {
            return Admit::Now(format!("ERR DRAINING {cmd} rejected: server is draining"));
        }
        // Closed without draining ⇒ that lane's dispatcher is gone: an
        // internal condition, not backpressure — clients retrying on
        // BUSY must not spin against a dead lane.
        if shared.lanes.queue(lane).is_closed() {
            return Admit::Now("ERR internal dispatcher unavailable".into());
        }
        telemetry_lock(shared).record_rejected();
        return Admit::Now(format!(
            "ERR BUSY lane {lane} full (depth {})",
            shared.lanes.queue(lane).depth()
        ));
    }
    Admit::Queued(PendingReply { id, cmd, n, flight })
}

/// Render the wire reply for a queued request once its dispatcher
/// outcome is known. `None` means the envelope was dropped without a
/// result (dispatcher died, reject-drain) — the internal error, exactly
/// what a threaded reader's disconnected reply channel means. Only an
/// `ok` result fills the single-flight obligation; failed or lost
/// executions drop the flight, aborting it (followers retry).
fn finish_reply(mut pending: PendingReply<'_>, result: Option<JobResult>) -> String {
    match result {
        Some(r) if r.ok => {
            // Leader fill: publish the verbatim checksum so a later hit
            // renders bit-identically, and wake any single-flight
            // followers with it.
            if let Some(f) = pending.flight.take() {
                f.fill(cache::CachedResult { checksum: r.checksum });
            }
            format!(
                "OK {} n={} engine={} us={:.1} queue_us={:.1} checksum={:.4}",
                pending.cmd,
                pending.n,
                r.engine.name(),
                r.service_us,
                r.queue_us,
                r.checksum
            )
        }
        Some(r) => {
            format!("ERR {} n={} failed on engine {}", pending.cmd, pending.n, r.engine.name())
        }
        None => "ERR internal dispatcher unavailable".into(),
    }
}

/// One reactor-parsed request line. Job lines go through the shared
/// admission pipeline with the non-blocking cache consult and a
/// reactor-outbox reply sink; everything else answers inline via
/// [`respond`] — byte-identical to the threaded path by construction.
enum Step<'a> {
    Respond(Response),
    Pending(PendingReply<'a>),
}

fn reactor_step<'a>(
    shared: &'a Shared,
    line: &str,
    make_sink: impl FnOnce(u64) -> ReplySink,
) -> Step<'a> {
    let mut toks = line.split_whitespace();
    match toks.next().map(|s| s.to_ascii_uppercase()).as_deref() {
        Some(cmd @ ("MATMUL" | "SORT")) => {
            let cmd: &'static str = if cmd == "MATMUL" { "MATMUL" } else { "SORT" };
            match admit_job(shared, cmd, &mut toks, false, make_sink) {
                Admit::Now(s) => Step::Respond(Response::Line(s)),
                Admit::Queued(p) => Step::Pending(p),
            }
        }
        _ => Step::Respond(respond(shared, line)),
    }
}

/// The result-cache table appended to STATS/DRAIN blocks: per-shard
/// hits/misses/evictions/occupancy plus the hit-ratio trailer, read
/// from atomic counters (no shard lock, no O(entries) work). Empty with
/// the cache disabled, keeping those blocks byte-identical to a
/// cache-less server.
fn cache_block(shared: &Shared) -> String {
    shared.cache.as_ref().map_or_else(String::new, ResultCache::render)
}

/// The cost-model table appended to STATS/DRAIN blocks: per-class
/// predicted vs observed service time, bias, and inline-serial counts,
/// plus the crossover trailer. Empty with `--cost-model off`, keeping
/// those blocks byte-identical to a cost-model-less server.
fn cost_model_block(shared: &Shared) -> String {
    shared.cost.as_ref().map_or_else(String::new, |c| c.render())
}

/// The routing table appended to STATS/DRAIN blocks: per-class lane
/// assignment (vs the seed lane) with request counts, plus the
/// `routing: epoch=<e> moves=<m>` trailer. Rendered only under
/// `--rebalance adaptive` — with rebalancing off, routing is the
/// immutable seed table and these blocks stay byte-identical to a
/// pre-routing-layer server.
fn routing_block(shared: &Shared) -> String {
    match shared.rebalance {
        RebalanceMode::Off => String::new(),
        RebalanceMode::Adaptive => shared.router.render(),
    }
}

/// The fault-injection table appended to STATS/DRAIN blocks: per-kind
/// trigger, opportunity, and injection counts, plus the `faults:
/// spec=… injected=…` trailer. Empty with `--faults off`, keeping those
/// blocks byte-identical to a server without the fault harness.
fn faults_block(shared: &Shared) -> String {
    shared.faults.as_ref().map_or_else(String::new, FaultPlan::render)
}

/// The reactor table appended to STATS/DRAIN blocks: per-reactor
/// connection, adoption, wakeup, and delivered-reply counts, plus the
/// `reactor: threads=… conns=…` trailer. Empty under `--io threads`,
/// keeping those blocks byte-identical to a pre-reactor server.
fn reactor_block(shared: &Shared) -> String {
    shared.reactors.as_ref().map_or_else(String::new, |set| set.render())
}

/// The occupancy line appended to STATS/DRAIN blocks.
fn queue_line(shared: &Shared) -> String {
    format!(
        "queue: len={} max={} depth={} lanes={} steal={}\n",
        shared.lanes.total_len(),
        shared.lanes.max_occupancy(),
        shared.lanes.queue(0).depth(),
        shared.lanes.lane_count(),
        shared.lanes.steal_enabled(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn roundtrip(lines: &[&str]) -> Vec<String> {
        let server = Server::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let h = std::thread::spawn(move || {
            server
                .serve(CoordinatorCfg { threads: 2, ..Default::default() }, Some(1))
                .unwrap();
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        for l in lines {
            writeln!(conn, "{l}").unwrap();
        }
        writeln!(conn, "QUIT").unwrap();
        conn.flush().unwrap();
        let reader = BufReader::new(conn);
        let out: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        h.join().unwrap();
        out
    }

    #[test]
    fn ping_and_quit() {
        let out = roundtrip(&["PING"]);
        assert_eq!(out, vec!["PONG".to_string(), "BYE".to_string()]);
    }

    #[test]
    fn matmul_and_sort_requests() {
        let out = roundtrip(&["MATMUL 32 7", "SORT 500"]);
        assert!(out[0].starts_with("OK MATMUL n=32"), "{out:?}");
        assert!(out[0].contains("checksum="));
        assert!(out[0].contains("queue_us="));
        assert!(out[1].starts_with("OK SORT n=500"), "{out:?}");
    }

    #[test]
    fn stats_block_and_errors() {
        let out = roundtrip(&["SORT 100", "STATS", "FROB", "MATMUL 0", "MATMUL abc"]);
        assert!(out.iter().any(|l| l.contains("coordinator telemetry")));
        assert!(out.iter().any(|l| l == "."), "stats block terminator");
        assert!(out.iter().any(|l| l.starts_with("queue: len=")), "queue line in stats");
        assert!(out.iter().any(|l| l.contains("lanes=2")), "lane count in stats: {out:?}");
        assert!(out.iter().any(|l| l.starts_with("ERR unknown command")));
        assert_eq!(out.iter().filter(|l| l.starts_with("ERR MATMUL needs n")).count(), 2);
    }

    #[test]
    fn requests_on_one_connection_answer_in_order() {
        let out = roundtrip(&["SORT 200 1", "SORT 300 2", "SORT 200 3", "PING"]);
        assert!(out[0].starts_with("OK SORT n=200"), "{out:?}");
        assert!(out[1].starts_with("OK SORT n=300"), "{out:?}");
        assert!(out[2].starts_with("OK SORT n=200"), "{out:?}");
        assert_eq!(out[3], "PONG");
        assert_eq!(out[4], "BYE");
    }

    #[test]
    fn warm_cache_hit_replies_bit_identical_checksum_from_cache_engine() {
        let server = Server::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let h = std::thread::spawn(move || {
            server
                .serve(CoordinatorCfg { threads: 2, cache: true, ..Default::default() }, Some(1))
                .unwrap();
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        for l in ["SORT 300 7", "SORT 300 7", "SORT 300 8", "QUIT"] {
            writeln!(conn, "{l}").unwrap();
        }
        conn.flush().unwrap();
        let out: Vec<String> = BufReader::new(conn).lines().map(|l| l.unwrap()).collect();
        h.join().unwrap();
        assert!(out[0].starts_with("OK SORT n=300"), "{out:?}");
        assert!(!out[0].contains("engine=cache"), "cold run executes: {out:?}");
        assert!(out[1].contains("engine=cache"), "repeat is served warm: {out:?}");
        assert!(out[1].contains("queue_us=0.0"), "hits never queue: {out:?}");
        let checksum = |s: &str| {
            s.split_whitespace().find(|t| t.starts_with("checksum=")).unwrap().to_string()
        };
        assert_eq!(checksum(&out[0]), checksum(&out[1]), "bit-identical checksum: {out:?}");
        assert!(!out[2].contains("engine=cache"), "different seed misses: {out:?}");
    }

    #[test]
    fn routing_block_only_renders_under_adaptive_rebalance() {
        // Default (--rebalance off): STATS must stay byte-compatible
        // with the pre-routing-layer server — no routing table, no
        // epoch trailer.
        let out = roundtrip(&["SORT 200 1", "STATS"]);
        assert!(!out.iter().any(|l| l.starts_with("routing")), "{out:?}");
        assert!(!out.iter().any(|l| l.contains("epoch")), "{out:?}");
        // Adaptive: the routing trailer (epoch 0, no moves yet) and the
        // per-class assignment row appear.
        let server = Server::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let cfg = CoordinatorCfg {
            threads: 1,
            rebalance: super::RebalanceMode::Adaptive,
            ..Default::default()
        };
        let h = std::thread::spawn(move || server.serve(cfg, Some(1)).unwrap());
        let mut conn = TcpStream::connect(addr).unwrap();
        for l in ["SORT 200 1", "STATS", "QUIT"] {
            writeln!(conn, "{l}").unwrap();
        }
        conn.flush().unwrap();
        let out: Vec<String> = BufReader::new(conn).lines().map(|l| l.unwrap()).collect();
        h.join().unwrap();
        assert!(
            out.iter().any(|l| l.starts_with("routing: epoch=0 moves=0")),
            "routing trailer missing: {out:?}"
        );
        assert!(out.iter().any(|l| l.contains("sort/2^7")), "per-class row missing: {out:?}");
    }

    #[test]
    fn cost_model_serves_small_jobs_inline_with_identical_checksums() {
        let run = |cost_model: bool| {
            let server = Server::bind("127.0.0.1:0").unwrap();
            let addr = server.local_addr();
            let cfg = CoordinatorCfg { threads: 2, cost_model, ..Default::default() };
            let h = std::thread::spawn(move || server.serve(cfg, Some(1)).unwrap());
            let mut conn = TcpStream::connect(addr).unwrap();
            for l in ["SORT 300 7", "MATMUL 32 9", "STATS", "QUIT"] {
                writeln!(conn, "{l}").unwrap();
            }
            conn.flush().unwrap();
            let out: Vec<String> = BufReader::new(conn).lines().map(|l| l.unwrap()).collect();
            h.join().unwrap();
            out
        };
        let on = run(true);
        let off = run(false);
        // Both shapes sit below the predicted crossover: served inline.
        assert!(on[0].contains("engine=serial-inline"), "{on:?}");
        assert!(on[1].contains("engine=serial-inline"), "{on:?}");
        assert!(!off.iter().any(|l| l.contains("serial-inline")), "{off:?}");
        // Inline execution is the same arithmetic on the same seed.
        let checksum = |s: &str| {
            s.split_whitespace().find(|t| t.starts_with("checksum=")).unwrap().to_string()
        };
        assert_eq!(checksum(&on[0]), checksum(&off[0]), "inline checksum matches pooled");
        assert_eq!(checksum(&on[1]), checksum(&off[1]));
        // STATS gains the cost-model table + trailer only when on.
        assert!(on.iter().any(|l| l.contains("cost model (per shape class)")), "{on:?}");
        assert!(on.iter().any(|l| l.starts_with("cost model: cores=2 crossover")), "{on:?}");
        assert!(on.iter().any(|l| l.contains("inline_serial=2")), "{on:?}");
        assert!(!off.iter().any(|l| l.contains("cost model")), "off is byte-identical: {off:?}");
    }

    /// Like `roundtrip`, but with an explicit config (fault specs etc.)
    /// and an explicit connection budget.
    fn roundtrip_cfg(cfg: CoordinatorCfg, conns: &[&[&str]]) -> Vec<Vec<String>> {
        let server = Server::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let n = conns.len();
        let h = std::thread::spawn(move || server.serve(cfg, Some(n)).unwrap());
        let mut all = Vec::new();
        for lines in conns {
            let mut conn = TcpStream::connect(addr).unwrap();
            for l in *lines {
                writeln!(conn, "{l}").unwrap();
            }
            conn.flush().unwrap();
            let out: Vec<String> = BufReader::new(conn).lines().map(|l| l.unwrap()).collect();
            all.push(out);
        }
        h.join().unwrap();
        all
    }

    #[test]
    fn injected_lane_kill_answers_internal_error_and_drains_clean() {
        // kill-lane=@1: the single dispatcher panics at its first batch
        // opportunity, before any pop — so no job ever executes and
        // every admission answers the internal error. The request may
        // race the panic into the still-open queue (recovery then pops
        // it as finished) or find it closed (admission rolls back), so
        // the drain balances at 1/1 or 0/0 — never apart.
        let cfg = CoordinatorCfg {
            threads: 1,
            lanes: 1,
            faults: "kill-lane=@1".to_string(),
            ..Default::default()
        };
        let out = &roundtrip_cfg(cfg, &[&["SORT 200 1", "STATS", "DRAIN", "QUIT"]])[0];
        assert_eq!(out[0], "ERR internal dispatcher unavailable", "{out:?}");
        assert!(out.iter().any(|l| l.contains("fault injection")), "{out:?}");
        assert!(out.iter().any(|l| l.contains("kill-lane")), "{out:?}");
        assert!(out.iter().any(|l| l.contains("faults=1")), "ledger carries the fault: {out:?}");
        assert!(
            out.iter().any(|l| l.starts_with("faults: spec=kill-lane=@1 seed=42 injected=1")),
            "{out:?}"
        );
        let drained = out
            .iter()
            .find(|l| l.starts_with("drained: admitted="))
            .unwrap_or_else(|| panic!("no drained trailer: {out:?}"));
        let nums: Vec<&str> = drained.split('=').collect();
        let admitted: u64 = nums[1].split_whitespace().next().unwrap().parse().unwrap();
        let finished: u64 = nums[2].trim().parse().unwrap();
        assert_eq!(admitted, finished, "{out:?}");
    }

    #[test]
    fn dropped_reply_closes_the_connection_after_exactly_once_execution() {
        let cfg = CoordinatorCfg {
            threads: 1,
            lanes: 1,
            faults: "drop-reply=@1".to_string(),
            ..Default::default()
        };
        let out = roundtrip_cfg(cfg, &[&["SORT 200 1"], &["DRAIN", "QUIT"]]);
        assert!(out[0].is_empty(), "the reply was dropped, the conn closed: {:?}", out[0]);
        // The job still executed exactly once: the drain balances at 1/1.
        assert!(
            out[1].iter().any(|l| l.starts_with("drained: admitted=1 finished=1")),
            "{:?}",
            out[1]
        );
        assert!(out[1].iter().any(|l| l.contains("drop-reply")), "{:?}", out[1]);
    }

    #[test]
    fn wedged_client_sees_half_a_line_then_eof() {
        use std::io::Read;
        let server = Server::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let cfg = CoordinatorCfg {
            threads: 1,
            lanes: 1,
            faults: "wedge-client=@1".to_string(),
            ..Default::default()
        };
        let h = std::thread::spawn(move || server.serve(cfg, Some(2)).unwrap());
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, "SORT 200 1").unwrap();
        conn.flush().unwrap();
        let mut got = String::new();
        conn.read_to_string(&mut got).unwrap();
        assert!(got.starts_with("OK SORT"), "the half that arrived is a reply prefix: {got:?}");
        assert!(!got.contains('\n'), "never a complete line: {got:?}");
        assert!(!got.contains("checksum="), "the tail was withheld: {got:?}");
        drop(conn);
        let mut conn = TcpStream::connect(addr).unwrap();
        for l in ["DRAIN", "QUIT"] {
            writeln!(conn, "{l}").unwrap();
        }
        conn.flush().unwrap();
        let out: Vec<String> = BufReader::new(conn).lines().map(|l| l.unwrap()).collect();
        h.join().unwrap();
        assert!(
            out.iter().any(|l| l.starts_with("drained: admitted=1 finished=1")),
            "the wedged request still executed exactly once: {out:?}"
        );
    }

    #[test]
    fn aborted_single_flight_leader_still_replies_but_skips_the_fill() {
        let cfg = CoordinatorCfg {
            threads: 1,
            lanes: 1,
            cache: true,
            faults: "abort-flight=@1".to_string(),
            ..Default::default()
        };
        let out =
            &roundtrip_cfg(cfg, &[&["SORT 300 7", "SORT 300 7", "SORT 300 7", "QUIT"]])[0];
        assert!(out[0].starts_with("OK SORT n=300"), "{out:?}");
        assert!(!out[0].contains("engine=cache"), "cold run executes: {out:?}");
        assert!(
            !out[1].contains("engine=cache"),
            "the aborted flight filled nothing, so the repeat re-executes: {out:?}"
        );
        assert!(out[2].contains("engine=cache"), "the second leader's fill serves this: {out:?}");
        let checksum = |s: &str| {
            s.split_whitespace().find(|t| t.starts_with("checksum=")).unwrap().to_string()
        };
        assert_eq!(checksum(&out[0]), checksum(&out[1]), "{out:?}");
        assert_eq!(checksum(&out[1]), checksum(&out[2]), "{out:?}");
    }

    #[test]
    fn faults_off_stats_and_drain_render_no_fault_output() {
        let out = roundtrip(&["SORT 200 1", "STATS", "DRAIN"]);
        assert!(
            !out.iter().any(|l| l.contains("fault") || l.contains("FAULT")),
            "a disarmed harness leaves no trace: {out:?}"
        );
    }

    #[test]
    fn drain_reports_then_rejects_later_jobs() {
        let out = roundtrip(&["SORT 200 1", "DRAIN", "SORT 200 2"]);
        assert!(out[0].starts_with("OK SORT n=200"), "{out:?}");
        assert!(out.iter().any(|l| l == "DRAINED"), "{out:?}");
        assert!(
            out.iter().any(|l| l.starts_with("drained: admitted=1 finished=1")),
            "{out:?}"
        );
        assert!(out.iter().any(|l| l == "."), "drain block terminator: {out:?}");
        assert!(
            out.iter().any(|l| l.starts_with("ERR DRAINING SORT rejected")),
            "post-drain admission must answer ERR DRAINING: {out:?}"
        );
        assert_eq!(out.last().map(|s| s.as_str()), Some("BYE"));
    }
}
