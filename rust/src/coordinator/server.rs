//! Line-protocol TCP front end for the coordinator — the serving shape
//! of the framework (requests in, admission-controlled routed execution,
//! latency out).
//!
//! Protocol (one request per line, ASCII):
//!
//! ```text
//! MATMUL <n> [seed]      → OK MATMUL n=<n> engine=<e> us=<t> queue_us=<q> checksum=<c>
//! SORT <n> [seed]        → OK SORT n=<n> engine=<e> us=<t> queue_us=<q> checksum=<c>
//! STATS                  → multi-line telemetry table, terminated by "."
//! PING                   → PONG
//! QUIT                   → BYE (closes the connection)
//! ```
//!
//! Unknown/malformed input answers `ERR <reason>` and keeps the
//! connection open; a request that arrives while the admission queue is
//! at depth answers `ERR BUSY ...` (backpressure, not queueing).
//!
//! ## Threading model
//!
//! The serving layer manages its own overhead per the paper's thesis —
//! every handoff is explicit, bounded, and measured:
//!
//! * the **accept loop** (caller thread) hands each connection to a pool
//!   of `serve_threads` **reader threads**; a reader owns one connection
//!   at a time and processes its lines in order;
//! * `MATMUL`/`SORT` requests become [`Job`]s pushed onto a bounded
//!   [`BoundedQueue`] (depth `queue_depth`). A full queue **rejects**
//!   with `ERR BUSY` instead of absorbing unbounded latency;
//! * a single **dispatcher thread** owns the [`Coordinator`] (and the XLA
//!   runtime) and drains the queue in **shape batches** — consecutive
//!   same-shape jobs, *across connections*, up to `batch_max` wide, with
//!   an optional `batch_linger_us` formation window — amortizing routing
//!   and executable lookup exactly like trace-mode batching;
//! * each reader blocks on its job's reply channel, so per-connection
//!   response order is preserved while cross-connection execution batches.
//!
//! Queue wait, batch width, and rejections land in the shared
//! [`Telemetry`] (rendered by `STATS`) alongside per-engine service times.
//!
//! Capacity interplay: each reader holds at most one job in flight, so
//! queue occupancy is bounded by the reader count — `ERR BUSY` fires
//! when `queue_depth` is set *below* the number of concurrently pushing
//! readers (load-shedding mode). Beyond readers + handoff buffer,
//! overload parks in the OS accept backlog (the accept loop blocks on a
//! bounded channel), so no in-process queue is ever unbounded. Request
//! pipelining that decouples occupancy from reader count is a ROADMAP
//! follow-up.

use super::queue::BoundedQueue;
use super::{Coordinator, CoordinatorCfg, Job, JobResult, RoutedEngine, Telemetry};
use crate::workload::traces::TraceKind;
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// One queued request: the job, its admission timestamp (queue-wait
/// clock), and the reply rendezvous back to the owning reader.
struct Envelope {
    job: Job,
    enqueued: Instant,
    reply: mpsc::Sender<JobResult>,
}

/// State shared by readers and the dispatcher.
struct Shared {
    queue: BoundedQueue<Envelope>,
    telemetry: Mutex<Telemetry>,
    next_id: AtomicU64,
}

/// A running server bound to a local port.
pub struct Server {
    listener: TcpListener,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str) -> Result<Server> {
        Ok(Server { listener: TcpListener::bind(addr)? })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("bound socket has an address")
    }

    /// Serve until `max_conns` connections have been accepted (None =
    /// forever), then drain: readers finish their connections, the queue
    /// closes, and the dispatcher completes queued work before return.
    pub fn serve(&self, cfg: CoordinatorCfg, max_conns: Option<usize>) -> Result<()> {
        let shared = Arc::new(Shared {
            queue: BoundedQueue::new(cfg.queue_depth),
            telemetry: Mutex::new(Telemetry::default()),
            next_id: AtomicU64::new(1),
        });

        // Dispatcher: the single consumer; owns the Coordinator (and the
        // XLA runtime when artifacts are present).
        let dispatcher = {
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            std::thread::spawn(move || dispatch_loop(&shared, &cfg))
        };

        // Reader pool: serve_threads workers, one connection each at a time.
        // The handoff buffer is bounded (2× the pool) so overload parks in
        // the OS accept backlog instead of an unbounded in-process channel —
        // the accept loop blocks once readers and buffer are saturated.
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(cfg.serve_threads.max(1) * 2);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let readers: Vec<_> = (0..cfg.serve_threads.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                let conn_rx = Arc::clone(&conn_rx);
                std::thread::spawn(move || loop {
                    let next = conn_rx.lock().unwrap().recv();
                    match next {
                        // Per-connection IO errors end that connection only.
                        Ok(stream) => {
                            let _ = handle_conn(stream, &shared);
                        }
                        Err(_) => break, // accept loop done
                    }
                })
            })
            .collect();

        // Accept loop. An accept error must still run the drain below —
        // otherwise the dispatcher (and its thread pool) leaks, blocked in
        // pop() forever — so capture the outcome instead of returning early.
        let mut accepted = 0usize;
        let mut accept_result: Result<()> = Ok(());
        for stream in self.listener.incoming() {
            match stream {
                Ok(stream) => {
                    conn_tx.send(stream).expect("reader pool outlives the accept loop");
                    accepted += 1;
                    if max_conns.is_some_and(|m| accepted >= m) {
                        break;
                    }
                }
                Err(e) => {
                    accept_result = Err(e.into());
                    break;
                }
            }
        }
        drop(conn_tx);
        for r in readers {
            let _ = r.join();
        }
        shared.queue.close();
        let _ = dispatcher.join();
        accept_result
    }
}

/// Lock the shared telemetry, tolerating poison: telemetry is advisory
/// stats, and a panicking writer must not cascade panics into readers.
fn telemetry_lock(shared: &Shared) -> std::sync::MutexGuard<'_, Telemetry> {
    shared.telemetry.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Dispatcher entry: run the batch loop, and if it dies for any reason,
/// reject-drain the queue so every queued envelope's reply sender drops —
/// blocked readers then see a disconnect ("ERR internal dispatcher
/// unavailable") instead of waiting forever.
fn dispatch_loop(shared: &Shared, cfg: &CoordinatorCfg) {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        dispatch_batches(shared, cfg);
    }));
    if outcome.is_err() {
        eprintln!(
            "ohm: serving dispatcher died (panic); rejecting queued and future jobs"
        );
        shared.queue.close();
        while shared.queue.pop().is_some() {}
    }
}

/// Drain the queue in cross-connection shape batches until closed.
fn dispatch_batches(shared: &Shared, cfg: &CoordinatorCfg) {
    let runtime = crate::runtime::Runtime::load(&crate::runtime::Runtime::default_dir()).ok();
    let coord = Coordinator::new(cfg.clone(), runtime);
    let linger = Duration::from_micros(cfg.batch_linger_us);
    loop {
        // Compare kinds directly: shape_key() is a bijection of kind but
        // allocates a String per call — too hot for the batch scan.
        let batch = shared.queue.pop_batch(cfg.batch_max, linger, |a, b| a.job.kind == b.job.kind);
        if batch.is_empty() {
            break; // closed and drained
        }
        telemetry_lock(shared).record_batch(batch.len());
        for env in batch {
            let queue_us = env.enqueued.elapsed().as_nanos() as f64 / 1e3;
            // Contain engine panics: a poisoned job must answer ERR to its
            // own reader, not wedge every later reader on a dead dispatcher.
            let executed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                coord.execute_job(&env.job)
            }))
            .ok();
            let panicked = executed.is_none();
            let mut r = executed.unwrap_or_else(|| {
                // Re-route only on the (rare) panic path, to label the
                // fallback with the engine that would have run.
                let routed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    coord.route(&env.job.kind)
                }))
                .unwrap_or(RoutedEngine::CpuSerial);
                JobResult {
                    id: env.job.id,
                    shape_key: env.job.shape_key(),
                    engine: routed,
                    service_us: 0.0,
                    queue_us: 0.0,
                    checksum: 0.0,
                    ok: false,
                }
            });
            r.queue_us = queue_us;
            {
                let mut t = telemetry_lock(shared);
                if panicked {
                    // Count the failure, but don't push a fabricated 0µs
                    // sample into an engine's service-time series.
                    t.failed += 1;
                } else {
                    t.record(&r);
                }
                t.record_served(queue_us);
            }
            // A reader that hung up mid-flight just drops the result.
            let _ = env.reply.send(r);
        }
    }
}

fn handle_conn(stream: TcpStream, shared: &Shared) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break; // client hung up
        }
        match respond(shared, line.trim()) {
            Response::Line(s) => writeln!(out, "{s}")?,
            Response::Block(s) => {
                for l in s.lines() {
                    writeln!(out, "{l}")?;
                }
                writeln!(out, ".")?;
            }
            Response::Bye => {
                writeln!(out, "BYE")?;
                break;
            }
        }
        out.flush()?;
    }
    Ok(())
}

enum Response {
    Line(String),
    Block(String),
    Bye,
}

fn respond(shared: &Shared, line: &str) -> Response {
    let mut toks = line.split_whitespace();
    match toks.next().map(|s| s.to_ascii_uppercase()).as_deref() {
        Some("PING") => Response::Line("PONG".into()),
        Some("QUIT") => Response::Bye,
        Some("STATS") => {
            // Snapshot under the lock, render (sorts + formatting) outside
            // it. The clone is still O(samples) under the lock — bounded by
            // SAMPLE_CAP/SHAPE_CAP, and STATS is an operator command, so we
            // accept it; streaming aggregates are a ROADMAP follow-up.
            let snapshot = telemetry_lock(shared).clone();
            let mut block = snapshot.render();
            block.push_str(&format!(
                "queue: len={} max={} depth={}\n",
                shared.queue.len(),
                shared.queue.max_len(),
                shared.queue.depth(),
            ));
            Response::Block(block)
        }
        Some(cmd @ ("MATMUL" | "SORT")) => {
            let n: usize = match toks.next().and_then(|t| t.parse().ok()) {
                Some(n) if n > 0 && n <= 4096 => n,
                _ => return Response::Line(format!("ERR {cmd} needs n in 1..=4096")),
            };
            let seed: u64 = toks.next().and_then(|t| t.parse().ok()).unwrap_or(42);
            let kind = if cmd == "MATMUL" { TraceKind::Matmul { n } } else { TraceKind::Sort { n } };
            let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
            let (reply_tx, reply_rx) = mpsc::channel();
            let envelope = Envelope {
                job: Job { id, kind, seed, arrival_us: 0 },
                enqueued: Instant::now(),
                reply: reply_tx,
            };
            if shared.queue.try_push(envelope).is_err() {
                // Closed ⇒ the dispatcher is gone (or we're draining):
                // that's an internal condition, not backpressure — clients
                // retrying on BUSY must not spin against a dead server.
                if shared.queue.is_closed() {
                    return Response::Line("ERR internal dispatcher unavailable".into());
                }
                telemetry_lock(shared).record_rejected();
                return Response::Line(format!(
                    "ERR BUSY queue full (depth {})",
                    shared.queue.depth()
                ));
            }
            match reply_rx.recv() {
                Ok(r) if r.ok => Response::Line(format!(
                    "OK {cmd} n={n} engine={} us={:.1} queue_us={:.1} checksum={:.4}",
                    r.engine.name(),
                    r.service_us,
                    r.queue_us,
                    r.checksum
                )),
                Ok(r) => {
                    Response::Line(format!("ERR {cmd} n={n} failed on engine {}", r.engine.name()))
                }
                Err(_) => Response::Line("ERR internal dispatcher unavailable".into()),
            }
        }
        Some(other) => Response::Line(format!("ERR unknown command {other:?}")),
        None => Response::Line("ERR empty request".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn roundtrip(lines: &[&str]) -> Vec<String> {
        let server = Server::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let h = std::thread::spawn(move || {
            server
                .serve(CoordinatorCfg { threads: 2, ..Default::default() }, Some(1))
                .unwrap();
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        for l in lines {
            writeln!(conn, "{l}").unwrap();
        }
        writeln!(conn, "QUIT").unwrap();
        conn.flush().unwrap();
        let reader = BufReader::new(conn);
        let out: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        h.join().unwrap();
        out
    }

    #[test]
    fn ping_and_quit() {
        let out = roundtrip(&["PING"]);
        assert_eq!(out, vec!["PONG".to_string(), "BYE".to_string()]);
    }

    #[test]
    fn matmul_and_sort_requests() {
        let out = roundtrip(&["MATMUL 32 7", "SORT 500"]);
        assert!(out[0].starts_with("OK MATMUL n=32"), "{out:?}");
        assert!(out[0].contains("checksum="));
        assert!(out[0].contains("queue_us="));
        assert!(out[1].starts_with("OK SORT n=500"), "{out:?}");
    }

    #[test]
    fn stats_block_and_errors() {
        let out = roundtrip(&["SORT 100", "STATS", "FROB", "MATMUL 0", "MATMUL abc"]);
        assert!(out.iter().any(|l| l.contains("coordinator telemetry")));
        assert!(out.iter().any(|l| l == "."), "stats block terminator");
        assert!(out.iter().any(|l| l.starts_with("queue: len=")), "queue line in stats");
        assert!(out.iter().any(|l| l.starts_with("ERR unknown command")));
        assert_eq!(out.iter().filter(|l| l.starts_with("ERR MATMUL needs n")).count(), 2);
    }

    #[test]
    fn requests_on_one_connection_answer_in_order() {
        let out = roundtrip(&["SORT 200 1", "SORT 300 2", "SORT 200 3", "PING"]);
        assert!(out[0].starts_with("OK SORT n=200"), "{out:?}");
        assert!(out[1].starts_with("OK SORT n=300"), "{out:?}");
        assert!(out[2].starts_with("OK SORT n=200"), "{out:?}");
        assert_eq!(out[3], "PONG");
        assert_eq!(out[4], "BYE");
    }
}
