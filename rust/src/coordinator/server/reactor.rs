//! Event-driven connection layer (`--io reactor`): a fixed pool of
//! epoll event-loop threads replaces the thread-per-connection reader
//! pool at the serving edge.
//!
//! Each reactor owns a [`Poller`] multiplexing (a) an inbox eventfd for
//! connections assigned round-robin by the accept loop, (b) an outbox
//! eventfd for [`Completion`]s pushed by lane dispatchers, and (c) every
//! adopted connection, nonblocking, with a per-connection state machine
//! ([`Conn`]): incremental line reassembly across partial reads
//! ([`LineBuf`]), pending-write buffering with `EPOLLOUT`-driven
//! backpressure ([`WriteBuf`]), and at most one admitted job in flight
//! per connection (mirroring the threaded invariant that bounds queue
//! occupancy by connection count).
//!
//! The dispatcher/lane/cache/admission core stays synchronous and
//! untouched: reactors call the same [`admit_job`](super::admit_job)
//! pipeline (via [`reactor_step`](super::reactor_step)) the threaded
//! readers use, so replies are byte-identical in both modes. The only
//! divergences are structural: a reactor never parks on another
//! leader's single-flight condvar (`try_lookup` bypasses the cache
//! instead), and a reply for a queued job returns through the owning
//! reactor's [`Outbox`] + eventfd wake instead of a per-request mpsc
//! channel.
//!
//! DRAIN wind-down is event-driven, with no poll tick: the DRAIN arm
//! calls [`ReactorSet::wake_all`] after raising the shutdown flag, and
//! each reactor then treats every connection as at-EOF — buffered lines
//! are answered (`ERR DRAINING` for jobs), in-flight replies are
//! flushed as their completions land, idle connections close — bounded
//! by [`SHUTDOWN_GRACE`] for peers that stop reading.

use super::{finish_reply, reactor_step, telemetry_lock, Response, Shared, Step};
use crate::coordinator::faults::FaultKind;
use crate::coordinator::lanes::{Completion, OutboxTicket, ReplySink};
use crate::net::{Interest, LineBuf, Outbox, Poller, WriteBuf};
use crate::report::AsciiTable;
use std::collections::HashMap;
use std::io::{self, Read};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Wind-down poll period: while the shutdown flag is up a reactor polls
/// on this tick instead of blocking forever, so straggling completions
/// and the grace deadline are both observed promptly.
const SHUTDOWN_TICK: Duration = Duration::from_millis(25);

/// Hard bound on post-shutdown lingering: a connection whose peer stops
/// reading (unflushable reply) or whose completion never lands is
/// force-closed this long after the shutdown flag rises, keeping
/// DRAIN's bounded-exit guarantee unconditional.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(2);

/// One reactor's shared half: what the accept loop, the dispatchers,
/// and STATS touch from outside the event-loop thread.
struct ReactorShared {
    /// Accept-loop → reactor connection handoff.
    inbox: Outbox<TcpStream>,
    /// Dispatcher → reactor completion handoff. `Arc` because every
    /// admitted envelope's [`OutboxTicket`] holds a clone.
    outbox: Arc<Outbox<Completion>>,
    stats: ReactorStats,
}

/// Monitoring counters, all `Relaxed`: single-writer gauges/counters
/// read racily by STATS, never load-bearing.
struct ReactorStats {
    /// Currently adopted connections (gauge).
    conns: AtomicU64,
    /// Connections ever adopted.
    accepted: AtomicU64,
    /// Dispatcher completions delivered to a connection.
    replies: AtomicU64,
}

/// The reactor pool handle held by [`Shared`]: assignment, wakeups, and
/// the STATS rendering for every reactor thread.
pub(super) struct ReactorSet {
    reactors: Vec<ReactorShared>,
    /// Round-robin assignment cursor.
    next: AtomicUsize,
    /// Raised when the accept loop has exited: no further assignments
    /// will arrive, so a reactor with no connections may exit.
    accepting_done: AtomicBool,
}

impl std::fmt::Debug for ReactorSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReactorSet").field("threads", &self.reactors.len()).finish_non_exhaustive()
    }
}

impl ReactorSet {
    /// Fails exactly where the kernel substrate (epoll + eventfd) is
    /// unavailable — the caller surfaces that at startup.
    pub(super) fn new(threads: usize) -> io::Result<ReactorSet> {
        let reactors = (0..threads.max(1))
            .map(|_| {
                Ok(ReactorShared {
                    inbox: Outbox::new()?,
                    outbox: Arc::new(Outbox::new()?),
                    stats: ReactorStats {
                        conns: AtomicU64::new(0),
                        accepted: AtomicU64::new(0),
                        replies: AtomicU64::new(0),
                    },
                })
            })
            .collect::<io::Result<Vec<_>>>()?;
        Ok(ReactorSet {
            reactors,
            next: AtomicUsize::new(0),
            accepting_done: AtomicBool::new(false),
        })
    }

    pub(super) fn thread_count(&self) -> usize {
        self.reactors.len()
    }

    /// Hand a fresh connection to the next reactor, round-robin. Plain
    /// modular assignment, not least-loaded: connections are cheap to
    /// hold (a few KiB of buffers) and the load they carry is bounded
    /// downstream by lane admission, so placement barely matters.
    pub(super) fn assign(&self, stream: TcpStream) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.reactors.len();
        self.reactors[i].inbox.push(stream);
    }

    /// Nudge every reactor to recheck its exit conditions (DRAIN, end
    /// of accepting). Spurious wakes are harmless by design.
    pub(super) fn wake_all(&self) {
        for r in &self.reactors {
            r.outbox.signal();
        }
    }

    /// Called once the accept loop has exited: reactors drain existing
    /// connections and then return instead of blocking forever.
    pub(super) fn finish_accepting(&self) {
        self.accepting_done.store(true, Ordering::SeqCst);
        self.wake_all();
    }

    fn done_accepting(&self) -> bool {
        self.accepting_done.load(Ordering::SeqCst)
    }

    /// The `STATS` reactor table plus its machine-readable trailer
    /// (grammar in `docs/PROTOCOL.md`). Rendered only in reactor mode —
    /// threaded-mode STATS output stays byte-identical to pre-reactor
    /// builds.
    pub(super) fn render(&self) -> String {
        let mut t = AsciiTable::new(
            "reactor (event-driven connection layer)",
            &["reactor", "conns", "accepted", "wakeups", "replies"],
        );
        let (mut conns, mut accepted, mut wakeups, mut replies) = (0u64, 0u64, 0u64, 0u64);
        for (i, r) in self.reactors.iter().enumerate() {
            let c = r.stats.conns.load(Ordering::Relaxed);
            let a = r.stats.accepted.load(Ordering::Relaxed);
            let w = r.inbox.signals() + r.outbox.signals();
            let p = r.stats.replies.load(Ordering::Relaxed);
            conns += c;
            accepted += a;
            wakeups += w;
            replies += p;
            t.row(vec![
                i.to_string(),
                c.to_string(),
                a.to_string(),
                w.to_string(),
                p.to_string(),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "reactor: threads={} conns={} accepted={} wakeups={} replies={}\n",
            self.reactors.len(),
            conns,
            accepted,
            wakeups,
            replies
        ));
        out
    }
}

/// Reactor thread body. A substrate error ends this reactor (logged);
/// non-Linux builds never get here — [`ReactorSet::new`] already
/// refused at startup.
pub(super) fn reactor_loop(index: usize, shared: &Shared) {
    #[cfg(target_os = "linux")]
    if let Err(e) = run(index, shared) {
        eprintln!("ohm: reactor {index} exited with error: {e}");
    }
    #[cfg(not(target_os = "linux"))]
    let _ = (index, shared);
}

/// Per-connection state machine. `'a` ties the in-flight reply (and its
/// single-flight obligation) to the server's shared state.
struct Conn<'a> {
    stream: TcpStream,
    rbuf: LineBuf,
    wbuf: WriteBuf,
    /// The one admitted-but-unanswered job, if any. While `Some`, the
    /// connection stops reading (per-connection order is preserved
    /// exactly as when a threaded reader blocks on its reply channel).
    inflight: Option<super::PendingReply<'a>>,
    /// Last interest registered with the poller, to elide no-op
    /// `EPOLL_CTL_MOD`s.
    interest: Interest,
    /// Flush pending writes, then close (BYE, faults, overflow).
    closing: bool,
    /// Peer sent FIN (or shutdown treats it as such): answer what is
    /// buffered, flush, close.
    eof: bool,
    /// Unrecoverable socket error: close now, pending writes dropped.
    dead: bool,
}

#[cfg(target_os = "linux")]
fn raw_fd(stream: &TcpStream) -> crate::net::sys::RawFd {
    use std::os::unix::io::AsRawFd;
    stream.as_raw_fd()
}

/// Register a fresh connection: nonblocking (accepted sockets do not
/// inherit the listener's nonblocking flag), read-interest, counters.
#[cfg(target_os = "linux")]
fn adopt<'a>(
    poller: &Poller,
    me: &ReactorShared,
    stream: TcpStream,
    token: u64,
) -> io::Result<Conn<'a>> {
    crate::net::sys::set_nonblocking(raw_fd(&stream))?;
    poller.add(raw_fd(&stream), token, Interest::readable())?;
    me.stats.accepted.fetch_add(1, Ordering::Relaxed);
    me.stats.conns.fetch_add(1, Ordering::Relaxed);
    Ok(Conn {
        stream,
        rbuf: LineBuf::new(),
        wbuf: WriteBuf::new(),
        inflight: None,
        interest: Interest::readable(),
        closing: false,
        eof: false,
        dead: false,
    })
}

/// Queue one reply line, applying the connection-level fault hooks the
/// threaded writer applies at the same point — so the chaos matrix
/// exercises identical client-visible failures in both IO modes.
fn push_line(shared: &Shared, conn: &mut Conn<'_>, line: &str) {
    if let Some(plan) = &shared.faults {
        // wedge-client: half a reply line, then close — the peer sees a
        // truncated line and EOF. The threaded hook also stalls 50 ms
        // before closing; an event loop must never sleep, so the
        // reactor skips the stall (the client-visible failure — partial
        // line + EOF — is unchanged).
        if plan.should_fire(FaultKind::WedgeClient) {
            telemetry_lock(shared).record_fault();
            let bytes = line.as_bytes();
            conn.wbuf.push(&bytes[..bytes.len() / 2]);
            conn.closing = true;
            return;
        }
        // drop-reply: the request executed (exactly once), but its
        // reply never reaches the socket — the connection just closes.
        if plan.should_fire(FaultKind::DropReply) {
            telemetry_lock(shared).record_fault();
            conn.closing = true;
            return;
        }
    }
    conn.wbuf.push(line.as_bytes());
    conn.wbuf.push(b"\n");
}

/// Queue a multi-line block with its `.` terminator (STATS/DRAIN). No
/// fault hooks — the threaded writer applies none to blocks either.
fn push_block(conn: &mut Conn<'_>, block: &str) {
    for l in block.lines() {
        conn.wbuf.push(l.as_bytes());
        conn.wbuf.push(b"\n");
    }
    conn.wbuf.push(b".\n");
}

/// Pump one connection as far as it will go without blocking: flush,
/// read, parse/answer, repeat until no forward progress. Each activity
/// is gated by the state flags, so this is safe to call on any event
/// (spurious included) — it simply does nothing when nothing is ready.
#[cfg(target_os = "linux")]
fn drive<'a>(
    shared: &'a Shared,
    me: &ReactorShared,
    pending_index: &mut HashMap<u64, u64>,
    token: u64,
    conn: &mut Conn<'a>,
) {
    loop {
        // Writes first: draining the pending tail may reopen the
        // backpressure gate for the parse loop below.
        match conn.wbuf.flush_into(&mut (&conn.stream)) {
            Ok(_) => {}
            Err(_) => {
                conn.dead = true;
                return;
            }
        }
        let mut progressed = false;
        // Read while this connection may accept another request: no job
        // in flight (per-connection ordering), pending writes under the
        // soft cap (a wedged client bounds its own memory), not already
        // winding down.
        while conn.inflight.is_none() && conn.wbuf.accepting() && !conn.closing && !conn.eof {
            let mut buf = [0u8; 4096];
            match (&conn.stream).read(&mut buf) {
                Ok(0) => {
                    conn.eof = true;
                    progressed = true;
                }
                Ok(n) => {
                    conn.rbuf.extend(&buf[..n]);
                    progressed = true;
                    // A newline-free line past LINE_MAX is not this
                    // protocol: protective close instead of unbounded
                    // buffering (the threaded reader's BufReader has no
                    // such bound — its thread is the bound).
                    if conn.rbuf.overflowed() {
                        conn.closing = true;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    return;
                }
            }
        }
        // Answer buffered lines under the same gates. At EOF the
        // unterminated tail is answered too — `read_line` on the
        // threaded path returns it as a final line, and both modes must
        // agree byte for byte.
        while conn.inflight.is_none() && conn.wbuf.accepting() && !conn.closing {
            let line = match conn
                .rbuf
                .next_line()
                .or_else(|| if conn.eof { conn.rbuf.take_tail() } else { None })
            {
                Some(l) => l,
                None => break,
            };
            progressed = true;
            let step = reactor_step(shared, line.trim(), |id| {
                ReplySink::Outbox(OutboxTicket::new(Arc::clone(&me.outbox), id))
            });
            match step {
                Step::Respond(Response::Line(s)) => push_line(shared, conn, &s),
                Step::Respond(Response::Block(s)) => push_block(conn, &s),
                Step::Respond(Response::Bye) => {
                    conn.wbuf.push(b"BYE\n");
                    conn.closing = true;
                }
                Step::Pending(p) => {
                    pending_index.insert(p.id, token);
                    conn.inflight = Some(p);
                }
            }
        }
        if !progressed {
            return;
        }
    }
}

/// The event loop proper.
#[cfg(target_os = "linux")]
fn run(index: usize, shared: &Shared) -> io::Result<()> {
    let set = shared.reactors.as_ref().expect("reactor thread requires the reactor set");
    let me = &set.reactors[index];
    const TOKEN_INBOX: u64 = 0;
    const TOKEN_OUTBOX: u64 = 1;
    const TOKEN_BASE: u64 = 2;
    let poller = Poller::new()?;
    poller.add(me.inbox.wake_fd().raw(), TOKEN_INBOX, Interest::readable())?;
    poller.add(me.outbox.wake_fd().raw(), TOKEN_OUTBOX, Interest::readable())?;
    let mut conns: HashMap<u64, Conn<'_>> = HashMap::new();
    let mut pending_index: HashMap<u64, u64> = HashMap::new();
    let mut next_token = TOKEN_BASE;
    let mut events = Vec::new();
    let mut grace: Option<Instant> = None;
    loop {
        let shutting = shared.shutdown.load(Ordering::SeqCst);
        // Adopt newly assigned connections. Both outboxes are drained
        // unconditionally each iteration (cheap when empty), which also
        // resets their eventfd levels.
        for stream in me.inbox.drain() {
            if shutting {
                // Raced the accept loop's exit: the server is done,
                // drop the connection unserved (the client sees a clean
                // EOF, same as the threaded straggler path).
                continue;
            }
            if let Ok(conn) = adopt(&poller, me, stream, next_token) {
                conns.insert(next_token, conn);
                next_token += 1;
            }
        }
        // Deliver dispatcher completions to their waiting connections.
        let mut touched: Vec<u64> = Vec::new();
        for completion in me.outbox.drain() {
            let (id, result) = match completion {
                Completion::Done { id, result } => (id, Some(result)),
                // The envelope died without a result (dispatcher gone);
                // render the same internal error a threaded reader's
                // disconnected reply channel produces.
                Completion::Gone { id } => (id, None),
            };
            // Unindexed ids are tickets whose connection already closed
            // (force-close under grace): the result is dropped, exactly
            // as a threaded reader dropping its reply receiver.
            let Some(token) = pending_index.remove(&id) else { continue };
            let Some(conn) = conns.get_mut(&token) else { continue };
            let Some(pending) = conn.inflight.take() else { continue };
            let line = finish_reply(pending, result);
            push_line(shared, conn, &line);
            me.stats.replies.fetch_add(1, Ordering::Relaxed);
            touched.push(token);
        }
        // DRAIN wind-down: treat every connection as at-EOF — stop
        // reading, answer what is buffered (`ERR DRAINING` for jobs),
        // flush, close. Event-driven; the old 500 ms reader tick is
        // gone in both IO modes.
        if shutting {
            for (token, conn) in conns.iter_mut() {
                conn.eof = true;
                if !touched.contains(token) {
                    touched.push(*token);
                }
            }
            let since = *grace.get_or_insert_with(Instant::now);
            if since.elapsed() > SHUTDOWN_GRACE {
                for conn in conns.values_mut() {
                    conn.dead = true;
                }
            }
        }
        // Settle every touched connection: pump it forward, then close
        // or re-register interest.
        for token in touched {
            if let Some(conn) = conns.get_mut(&token) {
                drive(shared, me, &mut pending_index, token, conn);
            }
            settle(&poller, me, &mut conns, &mut pending_index, token);
        }
        if conns.is_empty() && (shutting || set.done_accepting()) {
            // One final inbox look: `assign` may have raced
            // `finish_accepting`. A straggler found here while not
            // shutting down is adopted and served; at shutdown it is
            // dropped unserved.
            let stragglers = me.inbox.drain();
            if stragglers.is_empty() || shutting {
                return Ok(());
            }
            for stream in stragglers {
                if let Ok(conn) = adopt(&poller, me, stream, next_token) {
                    conns.insert(next_token, conn);
                    next_token += 1;
                }
            }
        }
        let timeout = if shutting { Some(SHUTDOWN_TICK) } else { None };
        poller.poll_io(&mut events, timeout)?;
        for i in 0..events.len() {
            let ev = events[i];
            if ev.token < TOKEN_BASE {
                // Inbox/outbox wake: handled by the unconditional
                // drains at the top of the loop.
                continue;
            }
            if let Some(conn) = conns.get_mut(&ev.token) {
                drive(shared, me, &mut pending_index, ev.token, conn);
            }
            settle(&poller, me, &mut conns, &mut pending_index, ev.token);
        }
    }
}

/// Post-drive bookkeeping for one connection: close it when its state
/// machine is finished, otherwise converge its poller interest.
///
/// Close conditions, in order: a dead socket closes immediately
/// (pending writes are unsalvageable); `closing` waits only for the
/// write buffer to flush (BYE and fault truncations must reach the
/// wire); EOF closes once nothing remains — no job in flight, no
/// unflushed reply, no unanswered buffered bytes.
#[cfg(target_os = "linux")]
fn settle(
    poller: &Poller,
    me: &ReactorShared,
    conns: &mut HashMap<u64, Conn<'_>>,
    pending_index: &mut HashMap<u64, u64>,
    token: u64,
) {
    let Some(conn) = conns.get_mut(&token) else { return };
    let close = conn.dead
        || (conn.closing && conn.wbuf.is_empty())
        || (conn.eof && conn.inflight.is_none() && conn.wbuf.is_empty() && conn.rbuf.pending() == 0);
    if close {
        let mut conn = conns.remove(&token).expect("checked above");
        // A force-closed connection may still hold an in-flight reply:
        // unindex it so the late completion is dropped, and drop the
        // pending itself (aborting its single-flight, so cache
        // followers retry instead of hanging).
        if let Some(p) = conn.inflight.take() {
            pending_index.remove(&p.id);
        }
        let _ = poller.remove(raw_fd(&conn.stream));
        // FIN after everything flushed: a client must never observe EOF
        // in place of a complete reply it was owed.
        let _ = conn.stream.shutdown(Shutdown::Write);
        me.stats.conns.fetch_sub(1, Ordering::Relaxed);
        return;
    }
    let want = Interest {
        readable: conn.inflight.is_none() && conn.wbuf.accepting() && !conn.closing && !conn.eof,
        writable: !conn.wbuf.is_empty(),
    };
    if want != conn.interest {
        if poller.modify(raw_fd(&conn.stream), token, want).is_ok() {
            conn.interest = want;
        }
    }
}
