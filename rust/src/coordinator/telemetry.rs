//! Coordinator telemetry: per-engine service-time accounting plus the
//! serving layer's own overhead categories.
//!
//! The paper tracks α/β/γ/δ inside a run; the serving layer adds the
//! categories that surface *in front of* execution — **queue wait**,
//! **shape-batch width**, and **admission rejections** — and folds queue
//! wait into a serving [`Ledger`] so the front end is reported with the
//! same vocabulary as the engines underneath it.

use super::job::{JobResult, RoutedEngine};
use crate::overhead::Ledger;
use crate::report::{table::f, AsciiTable};
use crate::stats::Summary;
use std::collections::BTreeMap;

/// Caps: a forever-running server must not grow telemetry without bound.
/// `SAMPLE_CAP` bounds samples per series — at the cap a series is
/// decimated (every other sample dropped), keeping a representative
/// spread at half rate. `SHAPE_CAP` bounds the number of per-shape
/// series — a client cycling every legal `n` must not mint unbounded
/// map entries; overflow shapes aggregate under `shape:other`.
const SAMPLE_CAP: usize = 16_384;
const SHAPE_CAP: usize = 512;

fn push_sample(series: &mut Vec<f64>, sample: f64) {
    if series.len() >= SAMPLE_CAP {
        let mut keep = false;
        series.retain(|_| {
            keep = !keep;
            keep
        });
    }
    series.push(sample);
}

/// Per-lane serving counters: lane imbalance (skewed queue waits, steal
/// traffic, thin batches) is a first-class overhead, reported per lane so
/// a hot shape class is visible instead of averaged away.
#[derive(Debug, Default, Clone)]
pub struct LaneStats {
    /// Jobs executed by this lane's dispatcher (own + stolen).
    pub dispatched: u64,
    /// Batches this lane dispatched.
    pub batches: u64,
    /// Batches this lane stole from a sibling's queue.
    pub steals: u64,
    /// Jobs inside those stolen batches.
    pub stolen_jobs: u64,
    queue_wait_us: Vec<f64>,
    batch_widths: Vec<f64>,
}

impl LaneStats {
    /// Queue-wait summary over this lane's served jobs, if any.
    pub fn queue_wait(&self) -> Option<Summary> {
        Summary::of(&self.queue_wait_us)
    }

    /// Batch-width summary over this lane's batches, if any.
    pub fn batch_width(&self) -> Option<Summary> {
        Summary::of(&self.batch_widths)
    }
}

/// Aggregates job results for reporting. `Clone` so readers can snapshot
/// it under a lock and render outside.
#[derive(Debug, Default, Clone)]
pub struct Telemetry {
    per_engine: BTreeMap<&'static str, Vec<f64>>,
    per_shape: BTreeMap<String, Vec<f64>>,
    pub completed: u64,
    pub failed: u64,
    /// Shape-batch statistics: same-shape groups dispatched.
    pub batches: u64,
    pub batched_jobs: u64,
    /// Widest batch dispatched so far.
    pub max_batch_width: u64,
    /// Requests rejected by admission control (`ERR BUSY`).
    pub rejected: u64,
    /// Serving-layer overhead ledger: queue wait (ns) plus the handoff
    /// events (enqueue + reply message, reply rendezvous) per served job,
    /// and cross-lane steal migrations.
    pub serving_ledger: Ledger,
    /// Per-dispatch-lane counters (empty outside serving mode).
    pub lanes: Vec<LaneStats>,
    queue_wait_us: Vec<f64>,
    batch_widths: Vec<f64>,
}

impl Telemetry {
    pub fn record(&mut self, r: &JobResult) {
        if r.ok {
            self.completed += 1;
        } else {
            self.failed += 1;
        }
        push_sample(self.per_engine.entry(r.engine.name()).or_default(), r.service_us);
        let shape = if self.per_shape.contains_key(&r.shape_key) || self.per_shape.len() < SHAPE_CAP
        {
            r.shape_key.clone()
        } else {
            "other".to_string()
        };
        push_sample(self.per_shape.entry(shape).or_default(), r.service_us);
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batched_jobs += size as u64;
        self.max_batch_width = self.max_batch_width.max(size as u64);
        push_sample(&mut self.batch_widths, size as f64);
    }

    /// Record the serving-layer overhead of one dispatched job: its queue
    /// wait plus the handoff events (enqueue message, reply message,
    /// reply rendezvous) charged to the serving ledger.
    pub fn record_served(&mut self, queue_wait_us: f64) {
        push_sample(&mut self.queue_wait_us, queue_wait_us);
        self.serving_ledger.queue_ns += (queue_wait_us * 1e3) as u64;
        self.serving_ledger.messages += 2;
        self.serving_ledger.syncs += 1;
    }

    /// Record one admission rejection (`ERR BUSY`).
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    /// Size the per-lane counters (called once at server start).
    pub fn init_lanes(&mut self, n: usize) {
        self.lanes = vec![LaneStats::default(); n];
    }

    /// Record one dispatched batch against its lane. A stolen batch is a
    /// cross-lane migration: one γ message in the serving ledger, broken
    /// out in its `steals` counter.
    pub fn record_lane_batch(&mut self, lane: usize, width: usize, stolen: bool) {
        self.record_batch(width);
        if stolen {
            self.serving_ledger.steals += 1;
            self.serving_ledger.messages += 1;
        }
        if let Some(l) = self.lanes.get_mut(lane) {
            l.batches += 1;
            l.dispatched += width as u64;
            if stolen {
                l.steals += 1;
                l.stolen_jobs += width as u64;
            }
            push_sample(&mut l.batch_widths, width as f64);
        }
    }

    /// Record one served job against its lane (plus the global serving
    /// categories via [`record_served`](Telemetry::record_served)).
    pub fn record_lane_served(&mut self, lane: usize, queue_wait_us: f64) {
        self.record_served(queue_wait_us);
        if let Some(l) = self.lanes.get_mut(lane) {
            push_sample(&mut l.queue_wait_us, queue_wait_us);
        }
    }

    /// Total stolen batches across all lanes.
    pub fn total_steals(&self) -> u64 {
        self.lanes.iter().map(|l| l.steals).sum()
    }

    pub fn engine_count(&self, e: RoutedEngine) -> usize {
        self.per_engine.get(e.name()).map_or(0, |v| v.len())
    }

    /// Queue-wait summary over served jobs, if any were queued.
    pub fn queue_wait(&self) -> Option<Summary> {
        Summary::of(&self.queue_wait_us)
    }

    /// Batch-width summary over dispatched batches.
    pub fn batch_width(&self) -> Option<Summary> {
        Summary::of(&self.batch_widths)
    }

    /// Render the service-time summary table.
    pub fn render(&self) -> String {
        let mut t = AsciiTable::new(
            "coordinator telemetry: service time (µs)",
            &["group", "jobs", "mean", "median", "p90", "max"],
        );
        for (name, vals) in self.per_engine.iter().map(|(k, v)| (format!("engine:{k}"), v)).chain(
            self.per_shape.iter().map(|(k, v)| (format!("shape:{k}"), v)),
        ) {
            if let Some(s) = Summary::of(vals) {
                t.row(vec![
                    name,
                    s.n.to_string(),
                    f(s.mean, 1),
                    f(s.median, 1),
                    f(s.p90, 1),
                    f(s.max, 1),
                ]);
            }
        }
        let mut out = t.render();
        // The serving table only renders when the serving layer actually
        // ran (queue waits or rejections): trace-mode batching alone is
        // coordinator batching, not serving overhead.
        if self.queue_wait().is_some() || self.rejected > 0 {
            let mut serving = AsciiTable::new(
                "serving overhead",
                &["category", "n", "mean", "median", "p90", "max"],
            );
            if let Some(s) = self.queue_wait() {
                serving.row(vec![
                    "queue-wait (µs)".to_string(),
                    s.n.to_string(),
                    f(s.mean, 1),
                    f(s.median, 1),
                    f(s.p90, 1),
                    f(s.max, 1),
                ]);
            }
            if let Some(s) = self.batch_width() {
                serving.row(vec![
                    "batch-width (jobs)".to_string(),
                    s.n.to_string(),
                    f(s.mean, 2),
                    f(s.median, 1),
                    f(s.p90, 1),
                    f(s.max, 0),
                ]);
            }
            if !serving.is_empty() {
                out.push_str(&serving.render());
            }
        }
        // Per-lane breakdown, once any lane has dispatched: imbalance
        // (skewed waits, steal traffic) must be visible per lane.
        if self.lanes.iter().any(|l| l.batches > 0) {
            let mut lt = AsciiTable::new(
                "dispatch lanes",
                &[
                    "lane",
                    "jobs",
                    "batches",
                    "mean width",
                    "steals",
                    "stolen jobs",
                    "wait mean (µs)",
                    "wait p90 (µs)",
                ],
            );
            for (i, l) in self.lanes.iter().enumerate() {
                let width = l.batch_width().map_or("-".to_string(), |s| f(s.mean, 2));
                let (wait_mean, wait_p90) = match l.queue_wait() {
                    Some(s) => (f(s.mean, 1), f(s.p90, 1)),
                    None => ("-".to_string(), "-".to_string()),
                };
                lt.row(vec![
                    i.to_string(),
                    l.dispatched.to_string(),
                    l.batches.to_string(),
                    width,
                    l.steals.to_string(),
                    l.stolen_jobs.to_string(),
                    wait_mean,
                    wait_p90,
                ]);
            }
            out.push_str(&lt.render());
        }
        out.push_str(&format!(
            "completed={} failed={} rejected={} steals={} batches={} (avg batch {:.1}, max width {})\n",
            self.completed,
            self.failed,
            self.rejected,
            self.total_steals(),
            self.batches,
            if self.batches > 0 { self.batched_jobs as f64 / self.batches as f64 } else { 0.0 },
            self.max_batch_width,
        ));
        if self.serving_ledger.total_events() > 0 || self.serving_ledger.queue_ns > 0 {
            out.push_str(&format!("serving ledger: {}\n", self.serving_ledger.summary()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(engine: RoutedEngine, us: f64, ok: bool) -> JobResult {
        JobResult {
            id: 0,
            shape_key: "matmul/64".into(),
            engine,
            service_us: us,
            queue_us: 0.0,
            checksum: 0.0,
            ok,
        }
    }

    #[test]
    fn records_and_renders() {
        let mut t = Telemetry::default();
        t.record(&res(RoutedEngine::Xla, 100.0, true));
        t.record(&res(RoutedEngine::Xla, 200.0, true));
        t.record(&res(RoutedEngine::CpuSerial, 50.0, false));
        t.record_batch(2);
        assert_eq!(t.completed, 2);
        assert_eq!(t.failed, 1);
        assert_eq!(t.engine_count(RoutedEngine::Xla), 2);
        let s = t.render();
        assert!(s.contains("engine:xla"));
        assert!(s.contains("shape:matmul/64"));
        assert!(s.contains("batches=1"));
    }

    #[test]
    fn serving_categories_flow_into_render_and_ledger() {
        let mut t = Telemetry::default();
        t.record(&res(RoutedEngine::CpuSerial, 80.0, true));
        t.record_batch(3);
        t.record_served(1500.0);
        t.record_served(500.0);
        t.record_rejected();
        assert_eq!(t.rejected, 1);
        assert_eq!(t.max_batch_width, 3);
        assert_eq!(t.serving_ledger.queue_ns, 2_000_000, "1500µs + 500µs in ns");
        assert_eq!(t.serving_ledger.messages, 4);
        assert_eq!(t.serving_ledger.syncs, 2);
        let s = t.render();
        assert!(s.contains("queue-wait"), "{s}");
        assert!(s.contains("batch-width"), "{s}");
        assert!(s.contains("rejected=1"), "{s}");
        assert!(s.contains("max width 3"), "{s}");
        assert!(s.contains("serving ledger:"), "{s}");
    }

    #[test]
    fn lane_stats_track_steals_and_render() {
        let mut t = Telemetry::default();
        t.init_lanes(2);
        t.record_lane_batch(0, 3, false);
        t.record_lane_batch(1, 2, true);
        t.record_lane_served(0, 100.0);
        t.record_lane_served(0, 300.0);
        t.record_lane_served(1, 50.0);
        assert_eq!(t.lanes[0].batches, 1);
        assert_eq!(t.lanes[0].dispatched, 3);
        assert_eq!(t.lanes[0].steals, 0);
        assert_eq!(t.lanes[1].steals, 1);
        assert_eq!(t.lanes[1].stolen_jobs, 2);
        assert_eq!(t.total_steals(), 1);
        assert_eq!(t.batches, 2, "lane batches roll up into the global counter");
        assert_eq!(t.serving_ledger.steals, 1);
        assert_eq!(t.serving_ledger.messages, 7, "2 per served job + 1 per steal");
        assert_eq!(t.lanes[0].queue_wait().unwrap().n, 2);
        let s = t.render();
        assert!(s.contains("dispatch lanes"), "{s}");
        assert!(s.contains("steals=1"), "{s}");
    }

    #[test]
    fn shape_series_count_stays_bounded() {
        let mut t = Telemetry::default();
        for n in 0..(super::SHAPE_CAP + 50) {
            let mut r = res(RoutedEngine::CpuSerial, 10.0, true);
            r.shape_key = format!("sort/{n}");
            t.record(&r);
        }
        assert!(t.per_shape.len() <= super::SHAPE_CAP + 1, "grew to {}", t.per_shape.len());
        assert!(t.per_shape.contains_key("other"), "overflow shapes aggregate under 'other'");
    }

    #[test]
    fn sample_series_stay_bounded() {
        let mut series = Vec::new();
        for i in 0..(super::SAMPLE_CAP * 2 + 10) {
            super::push_sample(&mut series, i as f64);
        }
        assert!(series.len() <= super::SAMPLE_CAP, "series grew to {}", series.len());
        assert!(series.len() > super::SAMPLE_CAP / 4, "decimation dropped too much");
    }

    #[test]
    fn empty_serving_stats_stay_out_of_render() {
        let mut t = Telemetry::default();
        t.record(&res(RoutedEngine::CpuSerial, 10.0, true));
        let s = t.render();
        assert!(!s.contains("serving overhead"), "{s}");
        assert!(!s.contains("serving ledger"), "{s}");
    }
}
