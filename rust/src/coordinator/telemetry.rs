//! Coordinator telemetry: per-engine service-time accounting plus the
//! serving layer's own overhead categories.
//!
//! The paper tracks α/β/γ/δ inside a run; the serving layer adds the
//! categories that surface *in front of* execution — **queue wait**,
//! **shape-batch width**, **admission rejections** (`ERR BUSY`), and
//! **admission sheds** (`ERR OVERLOADED`) — and folds queue wait into a
//! serving [`Ledger`] so the front end is reported with the same
//! vocabulary as the engines underneath it.
//!
//! Queue-wait and batch-width series are **streaming digests**
//! ([`Digest`]): fixed memory per series regardless of uptime, O(1)
//! `Clone`, and percentile queries with a bounded relative error. That
//! is what lets `STATS` snapshot telemetry under the dispatcher-shared
//! lock without an `O(samples)` buffer copy, and what feeds the adaptive
//! admission governor its per-lane percentiles
//! ([`super::admission::Governor`]).

use super::job::{JobResult, RoutedEngine};
use crate::overhead::Ledger;
use crate::report::{table::f, AsciiTable};
use crate::stats::{Digest, DigestSummary, Summary};
use std::collections::BTreeMap;

/// Caps: a forever-running server must not grow telemetry without bound.
/// `SAMPLE_CAP` bounds samples per service-time series — at the cap a
/// series is decimated (every other sample dropped), keeping a
/// representative spread at half rate. `SHAPE_CAP` bounds the number of
/// per-shape series — a client cycling every legal `n` must not mint
/// unbounded map entries; overflow shapes aggregate under `shape:other`.
/// (Queue-wait and batch-width series need no cap: they are fixed-memory
/// [`Digest`]s by construction.)
const SAMPLE_CAP: usize = 16_384;
const SHAPE_CAP: usize = 512;

fn push_sample(series: &mut Vec<f64>, sample: f64) {
    if series.len() >= SAMPLE_CAP {
        let mut keep = false;
        series.retain(|_| {
            keep = !keep;
            keep
        });
    }
    series.push(sample);
}

/// Epoch tables kept per server: the per-lane series are keyed on
/// `(lane, routing epoch)` so STATS never mixes pre- and post-rebalance
/// regimes in one row — but a forever-rebalancing server must not grow
/// telemetry without bound, so only the newest `EPOCH_CAP` epochs are
/// retained (older tables age out of the snapshot; their global
/// counters are already rolled up).
pub const EPOCH_CAP: usize = 6;

/// Per-lane serving counters: lane imbalance (skewed queue waits, steal
/// traffic, thin batches, shed hotspots) is a first-class overhead,
/// reported per lane so a hot shape class is visible instead of averaged
/// away.
#[derive(Debug, Default, Clone)]
pub struct LaneStats {
    /// Jobs executed by this lane's dispatcher (own + stolen).
    pub dispatched: u64,
    /// Batches this lane dispatched.
    pub batches: u64,
    /// Batches this lane stole from a sibling's queue.
    pub steals: u64,
    /// Jobs inside those stolen batches.
    pub stolen_jobs: u64,
    /// Requests routed to this lane that the admission governor shed
    /// (`ERR OVERLOADED`).
    pub sheds: u64,
    queue_wait_us: Digest,
    batch_widths: Digest,
}

impl LaneStats {
    /// Queue-wait percentile snapshot over jobs *admitted* to this lane
    /// (stolen jobs still count against the victim's queue — same
    /// attribution as the admission governor).
    pub fn queue_wait(&self) -> Option<DigestSummary> {
        self.queue_wait_us.summary()
    }

    /// Batch-width percentile snapshot over this lane's batches.
    pub fn batch_width(&self) -> Option<DigestSummary> {
        self.batch_widths.summary()
    }
}

/// One routing epoch's worth of per-lane counters. The per-lane
/// telemetry series are keyed on `(lane, epoch)`: a job admitted under
/// epoch N is recorded against epoch N's table even when it completes
/// after a rebalance published N+1, so no row ever conflates pre- and
/// post-rebalance traffic.
#[derive(Debug, Default, Clone)]
pub struct EpochLanes {
    pub epoch: u64,
    pub lanes: Vec<LaneStats>,
}

/// Admission-governor identity for the STATS "admission" table: which
/// mode the server runs, the default SLO it defends, and any per-class
/// overrides.
#[derive(Debug, Clone)]
pub struct AdmissionInfo {
    pub mode: &'static str,
    pub slo_p90_us: f64,
    /// Per-shape-class SLO overrides (class name → µs), rendered as a
    /// trailer under the admission table; empty with a uniform SLO.
    pub slo_overrides: Vec<(String, f64)>,
}

/// Aggregates job results for reporting. `Clone` so readers can snapshot
/// it under a lock and render outside; the serving-layer series are
/// digests, so the clone cost is independent of how many jobs ran.
#[derive(Debug, Default, Clone)]
pub struct Telemetry {
    per_engine: BTreeMap<&'static str, Vec<f64>>,
    per_shape: BTreeMap<String, Vec<f64>>,
    pub completed: u64,
    pub failed: u64,
    /// Shape-batch statistics: same-shape groups dispatched.
    pub batches: u64,
    pub batched_jobs: u64,
    /// Widest batch dispatched so far.
    pub max_batch_width: u64,
    /// Requests rejected by the hard depth bound (`ERR BUSY`).
    pub rejected: u64,
    /// Requests shed by the adaptive admission governor
    /// (`ERR OVERLOADED`) — the soft-reject path.
    pub shed: u64,
    /// Serving-layer overhead ledger: queue wait (ns) plus the handoff
    /// events (enqueue + reply message, reply rendezvous) per served job,
    /// cross-lane steal migrations, and governor sheds.
    pub serving_ledger: Ledger,
    /// Per-dispatch-lane counters, one table per routing epoch (empty
    /// outside serving mode; a single epoch-0 entry on a server that
    /// never rebalances). Ordered by epoch; at most [`EPOCH_CAP`]
    /// entries are retained.
    pub lane_epochs: Vec<EpochLanes>,
    /// Lane count per epoch table, fixed at server start.
    lane_count: usize,
    /// Admission mode + SLO, set at server start (None outside serving).
    pub admission: Option<AdmissionInfo>,
    queue_wait_us: Digest,
    batch_widths: Digest,
}

impl Telemetry {
    pub fn record(&mut self, r: &JobResult) {
        if r.ok {
            self.completed += 1;
        } else {
            self.failed += 1;
        }
        if r.engine == RoutedEngine::SerialInline {
            // Fork-join overhead *avoided*: the cost model ran this job
            // serially on the lane thread instead of paying α/β/γ/δ.
            self.serving_ledger.inline_serial += 1;
        }
        push_sample(self.per_engine.entry(r.engine.name()).or_default(), r.service_us);
        let shape = if self.per_shape.contains_key(&r.shape_key) || self.per_shape.len() < SHAPE_CAP
        {
            r.shape_key.clone()
        } else {
            "other".to_string()
        };
        push_sample(self.per_shape.entry(shape).or_default(), r.service_us);
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batched_jobs += size as u64;
        self.max_batch_width = self.max_batch_width.max(size as u64);
        self.batch_widths.record(size as f64);
    }

    /// Record the serving-layer overhead of one dispatched job: its queue
    /// wait plus the handoff events (enqueue message, reply message,
    /// reply rendezvous) charged to the serving ledger.
    pub fn record_served(&mut self, queue_wait_us: f64) {
        self.queue_wait_us.record(queue_wait_us);
        self.serving_ledger.queue_ns += (queue_wait_us * 1e3) as u64;
        self.serving_ledger.messages += 2;
        self.serving_ledger.syncs += 1;
    }

    /// Record one admission rejection (`ERR BUSY`, the hard depth bound).
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    /// Record one warm-cache hit: a request served by its reader from
    /// the result cache, without execution or queueing. Counts toward
    /// `completed` and the `engine:cache` service-time series (the time
    /// the cache took to serve it — near-zero for a plain hit, the wait
    /// for the leader's execution for a coalesced single-flight
    /// follower), and lands
    /// in the serving ledger as redundant work *managed away*
    /// (`cache_hits`). It must NOT touch the queue-wait digests or any
    /// per-lane admission state: a hit never queued, so folding it into
    /// the governor's evidence would corrupt the feedback loop.
    pub fn record_cache_hit(&mut self, lookup_us: f64) {
        self.completed += 1;
        self.serving_ledger.cache_hits += 1;
        push_sample(self.per_engine.entry(RoutedEngine::Cache.name()).or_default(), lookup_us);
    }

    /// Record one governor shed (`ERR OVERLOADED`) against the
    /// `(lane, epoch)` the request was routed under. A shed is
    /// scheduling overhead *managed away*, so it also lands in the
    /// serving ledger.
    pub fn record_shed(&mut self, lane: usize, epoch: u64) {
        self.shed += 1;
        self.serving_ledger.sheds += 1;
        if let Some(l) = self.lane_slot(lane, epoch) {
            l.sheds += 1;
        }
    }

    /// Record one injected fault (the `--faults` harness firing). Lands
    /// in the serving ledger so deliberately-caused failure overhead is
    /// attributed in the same books as every other source — never
    /// mysterious. The fault-injection table itself is rendered by the
    /// server from the live [`FaultPlan`](crate::coordinator::FaultPlan).
    pub fn record_fault(&mut self) {
        self.serving_ledger.faults += 1;
    }

    /// Size the per-lane counters (called once at server start): one
    /// epoch-0 table of `n` lanes.
    pub fn init_lanes(&mut self, n: usize) {
        self.lane_count = n;
        self.lane_epochs = vec![EpochLanes { epoch: 0, lanes: vec![LaneStats::default(); n] }];
    }

    /// Record the admission governor's identity (called once at server
    /// start) so STATS can render the admission table.
    pub fn init_admission(
        &mut self,
        mode: &'static str,
        slo_p90_us: f64,
        slo_overrides: Vec<(String, f64)>,
    ) {
        self.admission = Some(AdmissionInfo { mode, slo_p90_us, slo_overrides });
    }

    /// Open a fresh per-lane table for a newly published routing epoch
    /// (idempotent; prunes tables beyond [`EPOCH_CAP`], oldest first).
    /// Recording against an epoch creates its table on demand too, so
    /// the rebalancer's call ordering cannot race job completions.
    pub fn begin_epoch(&mut self, epoch: u64) {
        let _ = self.lane_slot(0, epoch);
    }

    /// The `(lane, epoch)` stats cell, creating (and pruning) the
    /// epoch's table as needed. `None` when lane telemetry is not
    /// initialized, the lane is out of range, or the epoch has already
    /// aged out of the retained window.
    fn lane_slot(&mut self, lane: usize, epoch: u64) -> Option<&mut LaneStats> {
        if lane >= self.lane_count {
            return None;
        }
        if !self.lane_epochs.iter().any(|e| e.epoch == epoch) {
            let at = self
                .lane_epochs
                .iter()
                .position(|e| e.epoch > epoch)
                .unwrap_or(self.lane_epochs.len());
            self.lane_epochs.insert(
                at,
                EpochLanes { epoch, lanes: vec![LaneStats::default(); self.lane_count] },
            );
            while self.lane_epochs.len() > EPOCH_CAP {
                self.lane_epochs.remove(0);
            }
        }
        let idx = self.lane_epochs.iter().position(|e| e.epoch == epoch)?;
        self.lane_epochs[idx].lanes.get_mut(lane)
    }

    /// Record one dispatched batch against its `(lane, epoch)`. A stolen
    /// batch is a cross-lane migration: one γ message in the serving
    /// ledger, broken out in its `steals` counter.
    pub fn record_lane_batch(&mut self, lane: usize, epoch: u64, width: usize, stolen: bool) {
        self.record_batch(width);
        if stolen {
            self.serving_ledger.steals += 1;
            self.serving_ledger.messages += 1;
        }
        if let Some(l) = self.lane_slot(lane, epoch) {
            l.batches += 1;
            l.dispatched += width as u64;
            if stolen {
                l.steals += 1;
                l.stolen_jobs += width as u64;
            }
            l.batch_widths.record(width as f64);
        }
    }

    /// Record one served job's queue wait against the `(lane, epoch)` it
    /// was *admitted* under — the same attribution the admission
    /// governor uses, so the STATS admission table shows exactly the
    /// waits the governor acts on even when work stealing executes the
    /// job elsewhere (and never mixes regimes across a rebalance) —
    /// plus the global serving categories via
    /// [`record_served`](Telemetry::record_served).
    pub fn record_lane_served(&mut self, lane: usize, epoch: u64, queue_wait_us: f64) {
        self.record_served(queue_wait_us);
        if let Some(l) = self.lane_slot(lane, epoch) {
            l.queue_wait_us.record(queue_wait_us);
        }
    }

    /// Total stolen batches across all lanes and epochs.
    pub fn total_steals(&self) -> u64 {
        self.lane_epochs.iter().flat_map(|e| e.lanes.iter()).map(|l| l.steals).sum()
    }

    /// One epoch's per-lane stats (test/observability hook).
    pub fn epoch_lanes(&self, epoch: u64) -> Option<&[LaneStats]> {
        self.lane_epochs.iter().find(|e| e.epoch == epoch).map(|e| e.lanes.as_slice())
    }

    pub fn engine_count(&self, e: RoutedEngine) -> usize {
        self.per_engine.get(e.name()).map_or(0, |v| v.len())
    }

    /// Queue-wait percentile snapshot over served jobs, if any queued.
    pub fn queue_wait(&self) -> Option<DigestSummary> {
        self.queue_wait_us.summary()
    }

    /// Batch-width percentile snapshot over dispatched batches.
    pub fn batch_width(&self) -> Option<DigestSummary> {
        self.batch_widths.summary()
    }

    /// Render the service-time summary table.
    pub fn render(&self) -> String {
        let mut t = AsciiTable::new(
            "coordinator telemetry: service time (µs)",
            &["group", "jobs", "mean", "median", "p90", "max"],
        );
        for (name, vals) in self.per_engine.iter().map(|(k, v)| (format!("engine:{k}"), v)).chain(
            self.per_shape.iter().map(|(k, v)| (format!("shape:{k}"), v)),
        ) {
            if let Some(s) = Summary::of(vals) {
                t.row(vec![
                    name,
                    s.n.to_string(),
                    f(s.mean, 1),
                    f(s.median, 1),
                    f(s.p90, 1),
                    f(s.max, 1),
                ]);
            }
        }
        let mut out = t.render();
        // The serving table only renders when the serving layer actually
        // ran (queue waits, rejections, or sheds): trace-mode batching
        // alone is coordinator batching, not serving overhead.
        if self.queue_wait().is_some() || self.rejected > 0 || self.shed > 0 {
            let mut serving = AsciiTable::new(
                "serving overhead",
                &["category", "n", "mean", "p50", "p90", "max"],
            );
            if let Some(s) = self.queue_wait() {
                serving.row(vec![
                    "queue-wait (µs)".to_string(),
                    s.n.to_string(),
                    f(s.mean, 1),
                    f(s.p50, 1),
                    f(s.p90, 1),
                    f(s.max, 1),
                ]);
            }
            if let Some(s) = self.batch_width() {
                serving.row(vec![
                    "batch-width (jobs)".to_string(),
                    s.n.to_string(),
                    f(s.mean, 2),
                    f(s.p50, 1),
                    f(s.p90, 1),
                    f(s.max, 0),
                ]);
            }
            if !serving.is_empty() {
                out.push_str(&serving.render());
            }
        }
        // Per-lane breakdown, once any lane has dispatched: imbalance
        // (skewed waits, steal traffic) must be visible per lane. One
        // table per routing epoch — a server that never rebalances has
        // exactly one, titled as before; epoch suffixes appear only once
        // a swap has split the series, so regimes are never mixed.
        let multi_epoch = self.lane_epochs.len() > 1
            || self.lane_epochs.first().is_some_and(|e| e.epoch != 0);
        for el in &self.lane_epochs {
            if !el.lanes.iter().any(|l| l.batches > 0) {
                continue;
            }
            let title = if multi_epoch {
                format!("dispatch lanes (epoch {})", el.epoch)
            } else {
                "dispatch lanes".to_string()
            };
            let mut lt = AsciiTable::new(
                &title,
                &[
                    "lane",
                    "jobs",
                    "batches",
                    "mean width",
                    "steals",
                    "stolen jobs",
                    "wait mean (µs)",
                    "wait p90 (µs)",
                ],
            );
            for (i, l) in el.lanes.iter().enumerate() {
                let width = l.batch_width().map_or("-".to_string(), |s| f(s.mean, 2));
                let (wait_mean, wait_p90) = match l.queue_wait() {
                    Some(s) => (f(s.mean, 1), f(s.p90, 1)),
                    None => ("-".to_string(), "-".to_string()),
                };
                lt.row(vec![
                    i.to_string(),
                    l.dispatched.to_string(),
                    l.batches.to_string(),
                    width,
                    l.steals.to_string(),
                    l.stolen_jobs.to_string(),
                    wait_mean,
                    wait_p90,
                ]);
            }
            out.push_str(&lt.render());
        }
        // Admission table: per-lane queue-wait percentiles (from the
        // digests — no per-sample buffer exists to consult) plus shed
        // counts, under the governor's mode and SLO — again one table
        // per routing epoch, so admission evidence never mixes regimes.
        if let Some(adm) = &self.admission {
            for el in &self.lane_epochs {
                if !el.lanes.iter().any(|l| l.queue_wait().is_some() || l.sheds > 0) {
                    continue;
                }
                let title = if multi_epoch {
                    format!(
                        "admission (mode={}, slo p90={}µs, epoch {})",
                        adm.mode,
                        f(adm.slo_p90_us, 0),
                        el.epoch
                    )
                } else {
                    format!("admission (mode={}, slo p90={}µs)", adm.mode, f(adm.slo_p90_us, 0))
                };
                let mut at = AsciiTable::new(
                    &title,
                    &["lane", "served", "p50 (µs)", "p90 (µs)", "p99 (µs)", "max (µs)", "sheds"],
                );
                for (i, l) in el.lanes.iter().enumerate() {
                    let (served, p50, p90, p99, max) = match l.queue_wait() {
                        Some(s) => {
                            (s.n.to_string(), f(s.p50, 1), f(s.p90, 1), f(s.p99, 1), f(s.max, 1))
                        }
                        None => {
                            let dash = || "-".to_string();
                            ("0".to_string(), dash(), dash(), dash(), dash())
                        }
                    };
                    at.row(vec![i.to_string(), served, p50, p90, p99, max, l.sheds.to_string()]);
                }
                out.push_str(&at.render());
            }
            if !adm.slo_overrides.is_empty() {
                let rendered: Vec<String> = adm
                    .slo_overrides
                    .iter()
                    .map(|(class, us)| format!("{class}={}µs", f(*us, 0)))
                    .collect();
                out.push_str(&format!("admission slo overrides: {}\n", rendered.join(" ")));
            }
        }
        out.push_str(&format!(
            "completed={} failed={} rejected={} shed={} steals={} batches={} (avg batch {:.1}, max width {})\n",
            self.completed,
            self.failed,
            self.rejected,
            self.shed,
            self.total_steals(),
            self.batches,
            if self.batches > 0 { self.batched_jobs as f64 / self.batches as f64 } else { 0.0 },
            self.max_batch_width,
        ));
        if self.serving_ledger.total_events() > 0
            || self.serving_ledger.queue_ns > 0
            || self.serving_ledger.sheds > 0
            || self.serving_ledger.cache_hits > 0
            || self.serving_ledger.inline_serial > 0
            || self.serving_ledger.faults > 0
        {
            out.push_str(&format!("serving ledger: {}\n", self.serving_ledger.summary()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(engine: RoutedEngine, us: f64, ok: bool) -> JobResult {
        JobResult {
            id: 0,
            shape_key: "matmul/64".into(),
            engine,
            service_us: us,
            queue_us: 0.0,
            checksum: 0.0,
            ok,
        }
    }

    #[test]
    fn records_and_renders() {
        let mut t = Telemetry::default();
        t.record(&res(RoutedEngine::Xla, 100.0, true));
        t.record(&res(RoutedEngine::Xla, 200.0, true));
        t.record(&res(RoutedEngine::CpuSerial, 50.0, false));
        t.record_batch(2);
        assert_eq!(t.completed, 2);
        assert_eq!(t.failed, 1);
        assert_eq!(t.engine_count(RoutedEngine::Xla), 2);
        let s = t.render();
        assert!(s.contains("engine:xla"));
        assert!(s.contains("shape:matmul/64"));
        assert!(s.contains("batches=1"));
    }

    #[test]
    fn serving_categories_flow_into_render_and_ledger() {
        let mut t = Telemetry::default();
        t.record(&res(RoutedEngine::CpuSerial, 80.0, true));
        t.record_batch(3);
        t.record_served(1500.0);
        t.record_served(500.0);
        t.record_rejected();
        assert_eq!(t.rejected, 1);
        assert_eq!(t.max_batch_width, 3);
        assert_eq!(t.serving_ledger.queue_ns, 2_000_000, "1500µs + 500µs in ns");
        assert_eq!(t.serving_ledger.messages, 4);
        assert_eq!(t.serving_ledger.syncs, 2);
        let s = t.render();
        assert!(s.contains("queue-wait"), "{s}");
        assert!(s.contains("batch-width"), "{s}");
        assert!(s.contains("rejected=1"), "{s}");
        assert!(s.contains("max width 3"), "{s}");
        assert!(s.contains("serving ledger:"), "{s}");
    }

    #[test]
    fn lane_stats_track_steals_and_render() {
        let mut t = Telemetry::default();
        t.init_lanes(2);
        t.record_lane_batch(0, 0, 3, false);
        t.record_lane_batch(1, 0, 2, true);
        t.record_lane_served(0, 0, 100.0);
        t.record_lane_served(0, 0, 300.0);
        t.record_lane_served(1, 0, 50.0);
        let lanes = t.epoch_lanes(0).unwrap();
        assert_eq!(lanes[0].batches, 1);
        assert_eq!(lanes[0].dispatched, 3);
        assert_eq!(lanes[0].steals, 0);
        assert_eq!(lanes[1].steals, 1);
        assert_eq!(lanes[1].stolen_jobs, 2);
        assert_eq!(lanes[0].queue_wait().unwrap().n, 2);
        assert_eq!(t.total_steals(), 1);
        assert_eq!(t.batches, 2, "lane batches roll up into the global counter");
        assert_eq!(t.serving_ledger.steals, 1);
        assert_eq!(t.serving_ledger.messages, 7, "2 per served job + 1 per steal");
        let s = t.render();
        assert!(s.contains("dispatch lanes"), "{s}");
        assert!(!s.contains("dispatch lanes (epoch"), "single epoch keeps the plain title: {s}");
        assert!(s.contains("steals=1"), "{s}");
    }

    #[test]
    fn lane_series_key_on_lane_and_epoch_so_regimes_never_mix() {
        let mut t = Telemetry::default();
        t.init_lanes(2);
        // Epoch 0 traffic on lane 1, then a rebalance publishes epoch 1
        // and later jobs land there — including a straggler admitted
        // under epoch 0 that completes after the swap.
        t.record_lane_batch(1, 0, 2, false);
        t.record_lane_served(1, 0, 900.0);
        t.begin_epoch(1);
        t.record_lane_batch(0, 1, 1, false);
        t.record_lane_served(0, 1, 40.0);
        t.record_lane_served(1, 0, 950.0); // straggler: epoch-0 attribution
        let e0 = t.epoch_lanes(0).unwrap();
        let e1 = t.epoch_lanes(1).unwrap();
        assert_eq!(e0[1].queue_wait().unwrap().n, 2, "both epoch-0 waits, straggler included");
        assert_eq!(e1[0].queue_wait().unwrap().n, 1);
        assert!(e1[1].queue_wait().is_none(), "epoch 1 lane 1 saw nothing");
        let s = t.render();
        assert!(s.contains("dispatch lanes (epoch 0)"), "{s}");
        assert!(s.contains("dispatch lanes (epoch 1)"), "{s}");
    }

    #[test]
    fn epoch_tables_stay_bounded() {
        let mut t = Telemetry::default();
        t.init_lanes(2);
        for epoch in 0..20u64 {
            t.record_lane_served(0, epoch, 100.0);
        }
        assert!(t.lane_epochs.len() <= super::EPOCH_CAP, "grew to {}", t.lane_epochs.len());
        assert!(t.epoch_lanes(19).is_some(), "newest epoch retained");
        assert!(t.epoch_lanes(0).is_none(), "oldest epoch aged out");
        assert_eq!(t.queue_wait().unwrap().n, 20, "global rollups keep every sample");
    }

    #[test]
    fn sheds_count_per_lane_and_into_the_ledger() {
        let mut t = Telemetry::default();
        t.init_lanes(2);
        t.init_admission("adaptive", 1_000.0, Vec::new());
        t.record_lane_served(0, 0, 2_500.0);
        t.record_shed(0, 0);
        t.record_shed(0, 0);
        t.record_shed(1, 0);
        assert_eq!(t.shed, 3);
        let lanes = t.epoch_lanes(0).unwrap();
        assert_eq!(lanes[0].sheds, 2);
        assert_eq!(lanes[1].sheds, 1);
        assert_eq!(t.serving_ledger.sheds, 3);
        assert_eq!(t.rejected, 0, "sheds are distinct from hard rejections");
        let s = t.render();
        assert!(s.contains("admission (mode=adaptive, slo p90=1000µs)"), "{s}");
        assert!(s.contains("shed=3"), "{s}");
        assert!(s.contains("sheds=3"), "ledger line carries sheds: {s}");
        assert!(!s.contains("slo overrides"), "uniform SLO renders no overrides line: {s}");
    }

    #[test]
    fn admission_table_renders_lane_percentiles_from_digests() {
        let mut t = Telemetry::default();
        t.init_lanes(2);
        t.init_admission("adaptive", 5_000.0, vec![("sort/2^9".to_string(), 800.0)]);
        for wait in [100.0, 200.0, 400.0, 800.0] {
            t.record_lane_served(0, 0, wait);
        }
        let s = t.render();
        assert!(s.contains("admission (mode=adaptive"), "{s}");
        assert!(s.contains("admission slo overrides: sort/2^9=800µs"), "{s}");
        let lanes = t.epoch_lanes(0).unwrap();
        let lane0 = lanes[0].queue_wait().unwrap();
        assert_eq!(lane0.n, 4);
        assert!(lane0.p50 <= lane0.p90 && lane0.p90 <= lane0.p99 && lane0.p99 <= lane0.max);
        assert_eq!(lane0.max, 800.0, "digest max is exact");
        assert!(lanes[1].queue_wait().is_none(), "idle lane renders dashes");
    }

    #[test]
    fn cache_hits_count_completed_and_ledger_but_never_queue_digests() {
        let mut t = Telemetry::default();
        t.init_lanes(2);
        t.record_cache_hit(4.0);
        t.record_cache_hit(6.0);
        assert_eq!(t.completed, 2, "hits are served requests");
        assert_eq!(t.serving_ledger.cache_hits, 2);
        assert_eq!(t.engine_count(RoutedEngine::Cache), 2);
        assert!(t.queue_wait().is_none(), "hits bypass the queue-wait digest");
        assert!(
            t.lane_epochs.iter().flat_map(|e| e.lanes.iter()).all(|l| l.queue_wait().is_none()),
            "and every lane digest"
        );
        assert_eq!(t.serving_ledger.queue_ns, 0, "no fabricated queue time");
        let s = t.render();
        assert!(s.contains("engine:cache"), "{s}");
        assert!(s.contains("cache_hits=2"), "ledger line carries the hits: {s}");
    }

    #[test]
    fn inline_serial_results_land_in_the_ledger() {
        let mut t = Telemetry::default();
        t.record(&res(RoutedEngine::SerialInline, 90.0, true));
        t.record(&res(RoutedEngine::SerialInline, 110.0, true));
        t.record(&res(RoutedEngine::CpuParallel, 500.0, true));
        assert_eq!(t.serving_ledger.inline_serial, 2);
        assert_eq!(t.engine_count(RoutedEngine::SerialInline), 2);
        let s = t.render();
        assert!(s.contains("engine:serial-inline"), "{s}");
        assert!(s.contains("inline_serial=2"), "ledger line carries the count: {s}");
    }

    #[test]
    fn injected_faults_land_in_the_ledger_and_gate_its_line() {
        let mut t = Telemetry::default();
        assert!(!t.render().contains("serving ledger:"), "quiet telemetry renders no ledger");
        t.record_fault();
        t.record_fault();
        assert_eq!(t.serving_ledger.faults, 2);
        let s = t.render();
        assert!(s.contains("serving ledger:"), "faults alone surface the ledger line: {s}");
        assert!(s.contains("faults=2"), "{s}");
    }

    #[test]
    fn admission_table_absent_without_governor_info() {
        let mut t = Telemetry::default();
        t.init_lanes(2);
        t.record_lane_served(0, 0, 100.0);
        let s = t.render();
        assert!(!s.contains("admission (mode="), "{s}");
    }

    #[test]
    fn stats_snapshot_clone_renders_identically() {
        // The STATS path renders from a clone taken under the telemetry
        // lock; with digest-backed series the clone must lose nothing —
        // byte-identical output under a fixed workload.
        let mut t = Telemetry::default();
        t.init_lanes(2);
        t.init_admission("adaptive", 2_000.0, Vec::new());
        for i in 0..500 {
            t.record(&res(RoutedEngine::CpuSerial, 10.0 + i as f64, true));
            t.record_lane_batch(i % 2, (i >= 250) as u64, 1 + i % 4, i % 7 == 0);
            t.record_lane_served(i % 2, (i >= 250) as u64, (i * 13 % 4_000) as f64 + 0.5);
        }
        t.record_rejected();
        t.record_shed(1, 1);
        assert_eq!(t.render(), t.clone().render(), "snapshot clone must be lossless");
    }

    #[test]
    fn shape_series_count_stays_bounded() {
        let mut t = Telemetry::default();
        for n in 0..(super::SHAPE_CAP + 50) {
            let mut r = res(RoutedEngine::CpuSerial, 10.0, true);
            r.shape_key = format!("sort/{n}");
            t.record(&r);
        }
        assert!(t.per_shape.len() <= super::SHAPE_CAP + 1, "grew to {}", t.per_shape.len());
        assert!(t.per_shape.contains_key("other"), "overflow shapes aggregate under 'other'");
    }

    #[test]
    fn sample_series_stay_bounded() {
        let mut series = Vec::new();
        for i in 0..(super::SAMPLE_CAP * 2 + 10) {
            super::push_sample(&mut series, i as f64);
        }
        assert!(series.len() <= super::SAMPLE_CAP, "series grew to {}", series.len());
        assert!(series.len() > super::SAMPLE_CAP / 4, "decimation dropped too much");
    }

    #[test]
    fn queue_wait_memory_is_fixed_not_per_sample() {
        let mut t = Telemetry::default();
        t.init_lanes(1);
        for i in 0..100_000 {
            t.record_lane_served(0, 0, (i % 1000) as f64 + 1.0);
        }
        assert_eq!(t.queue_wait().unwrap().n, 100_000);
        // The series is a fixed-size digest: cloning it cannot scale with
        // the sample count (compile-time guarantee, asserted for intent).
        assert!(Digest::memory_bytes() < 4096);
    }

    #[test]
    fn empty_serving_stats_stay_out_of_render() {
        let mut t = Telemetry::default();
        t.record(&res(RoutedEngine::CpuSerial, 10.0, true));
        let s = t.render();
        assert!(!s.contains("serving overhead"), "{s}");
        assert!(!s.contains("serving ledger"), "{s}");
    }
}
