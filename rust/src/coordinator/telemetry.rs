//! Coordinator telemetry: per-engine service-time accounting.

use super::job::{JobResult, RoutedEngine};
use crate::report::{table::f, AsciiTable};
use crate::stats::Summary;
use std::collections::BTreeMap;

/// Aggregates job results for reporting.
#[derive(Debug, Default)]
pub struct Telemetry {
    per_engine: BTreeMap<&'static str, Vec<f64>>,
    per_shape: BTreeMap<String, Vec<f64>>,
    pub completed: u64,
    pub failed: u64,
    /// Shape-batch statistics: consecutive same-shape groups dispatched.
    pub batches: u64,
    pub batched_jobs: u64,
}

impl Telemetry {
    pub fn record(&mut self, r: &JobResult) {
        if r.ok {
            self.completed += 1;
        } else {
            self.failed += 1;
        }
        self.per_engine.entry(r.engine.name()).or_default().push(r.service_us);
        self.per_shape.entry(r.shape_key.clone()).or_default().push(r.service_us);
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batched_jobs += size as u64;
    }

    pub fn engine_count(&self, e: RoutedEngine) -> usize {
        self.per_engine.get(e.name()).map_or(0, |v| v.len())
    }

    /// Render the service-time summary table.
    pub fn render(&self) -> String {
        let mut t = AsciiTable::new(
            "coordinator telemetry: service time (µs)",
            &["group", "jobs", "mean", "median", "p90", "max"],
        );
        for (name, vals) in self.per_engine.iter().map(|(k, v)| (format!("engine:{k}"), v)).chain(
            self.per_shape.iter().map(|(k, v)| (format!("shape:{k}"), v)),
        ) {
            if let Some(s) = Summary::of(vals) {
                t.row(vec![
                    name,
                    s.n.to_string(),
                    f(s.mean, 1),
                    f(s.median, 1),
                    f(s.p90, 1),
                    f(s.max, 1),
                ]);
            }
        }
        let mut out = t.render();
        out.push_str(&format!(
            "completed={} failed={} batches={} (avg batch {:.1})\n",
            self.completed,
            self.failed,
            self.batches,
            if self.batches > 0 { self.batched_jobs as f64 / self.batches as f64 } else { 0.0 },
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(engine: RoutedEngine, us: f64, ok: bool) -> JobResult {
        JobResult { id: 0, shape_key: "matmul/64".into(), engine, service_us: us, checksum: 0.0, ok }
    }

    #[test]
    fn records_and_renders() {
        let mut t = Telemetry::default();
        t.record(&res(RoutedEngine::Xla, 100.0, true));
        t.record(&res(RoutedEngine::Xla, 200.0, true));
        t.record(&res(RoutedEngine::CpuSerial, 50.0, false));
        t.record_batch(2);
        assert_eq!(t.completed, 2);
        assert_eq!(t.failed, 1);
        assert_eq!(t.engine_count(RoutedEngine::Xla), 2);
        let s = t.render();
        assert!(s.contains("engine:xla"));
        assert!(s.contains("shape:matmul/64"));
        assert!(s.contains("batches=1"));
    }
}
