//! Matrix-chain multiplication (the paper's "matrix chain multiplication
//! problems" mention): optimal parenthesization by dynamic programming,
//! then overhead-managed evaluation of the chosen tree.

use super::matmul;
use super::matrix::Matrix;
use crate::exec::{ExecCtx, RunReport};

/// DP solution: minimal multiply-add cost and split table.
#[derive(Debug, Clone)]
pub struct ChainPlan {
    /// Dimensions: matrix `i` is `dims[i] × dims[i+1]`.
    pub dims: Vec<usize>,
    /// `split[i][j]` = k of the optimal top split of the product i..=j.
    split: Vec<Vec<usize>>,
    /// Minimal multiply-add count.
    pub cost: f64,
}

/// Classic O(n³) matrix-chain-order DP.
pub fn plan(dims: &[usize]) -> ChainPlan {
    let n = dims.len() - 1;
    assert!(n >= 1, "need at least one matrix");
    let mut cost = vec![vec![0.0f64; n]; n];
    let mut split = vec![vec![0usize; n]; n];
    for len in 2..=n {
        for i in 0..=n - len {
            let j = i + len - 1;
            cost[i][j] = f64::INFINITY;
            for k in i..j {
                let c = cost[i][k]
                    + cost[k + 1][j]
                    + dims[i] as f64 * dims[k + 1] as f64 * dims[j + 1] as f64;
                if c < cost[i][j] {
                    cost[i][j] = c;
                    split[i][j] = k;
                }
            }
        }
    }
    ChainPlan { dims: dims.to_vec(), split, cost: cost[0][n - 1] }
}

impl ChainPlan {
    /// Multiply-add cost of always associating left-to-right (baseline).
    /// `(((M₁·M₂)·M₃)…)`: step `i` costs `d₀·dᵢ·dᵢ₊₁`.
    pub fn left_assoc_cost(&self) -> f64 {
        let d = &self.dims;
        (1..d.len() - 1)
            .map(|i| d[0] as f64 * d[i] as f64 * d[i + 1] as f64)
            .sum()
    }

    /// Evaluate the optimal tree over `mats` with the overhead-managed
    /// matmul; returns the product and the merged run report.
    pub fn evaluate(&self, mats: &[Matrix], ctx: &ExecCtx) -> (Matrix, RunReport) {
        assert_eq!(mats.len() + 1, self.dims.len());
        for (i, m) in mats.iter().enumerate() {
            assert_eq!((m.rows(), m.cols()), (self.dims[i], self.dims[i + 1]));
        }
        self.eval_range(mats, 0, mats.len() - 1, ctx)
    }

    fn eval_range(&self, mats: &[Matrix], i: usize, j: usize, ctx: &ExecCtx) -> (Matrix, RunReport) {
        if i == j {
            return (mats[i].clone(), RunReport::wall_only(0));
        }
        let k = self.split[i][j];
        let (l, rl) = self.eval_range(mats, i, k, ctx);
        let (r, rr) = self.eval_range(mats, k + 1, j, ctx);
        let (prod, rp) = matmul::run(&l, &r, ctx);
        let mut rep = rp;
        rep.wall_ns += rl.wall_ns + rr.wall_ns;
        rep.virtual_ns = match (rep.virtual_ns, rl.virtual_ns, rr.virtual_ns) {
            (Some(c), a, b) => Some(c + a.unwrap_or(0.0) + b.unwrap_or(0.0)),
            (None, _, _) => None,
        };
        rep.serial_equiv_ns = match (rep.serial_equiv_ns, rl.serial_equiv_ns, rr.serial_equiv_ns) {
            (Some(c), a, b) => Some(c + a.unwrap_or(0.0) + b.unwrap_or(0.0)),
            (None, _, _) => None,
        };
        rep.ledger = rep.ledger.merged(&rl.ledger).merged(&rr.ledger);
        (prod, rep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::matrices;

    #[test]
    fn clrs_textbook_instance() {
        // CLRS example: dims 30,35,15,5,10,20,25 → optimal 15125.
        let p = plan(&[30, 35, 15, 5, 10, 20, 25]);
        assert_eq!(p.cost as u64, 15_125);
    }

    #[test]
    fn optimal_no_worse_than_left_assoc() {
        let p = plan(&[40, 20, 30, 10, 30]);
        assert!(p.cost <= p.left_assoc_cost());
        // Known: optimal = 26000 for this instance.
        assert_eq!(p.cost as u64, 26_000);
    }

    #[test]
    fn evaluate_matches_direct_product() {
        let dims = [6usize, 10, 4, 8];
        let mats: Vec<Matrix> = (0..3)
            .map(|i| matrices::small_int(dims[i], dims[i + 1], i as u64))
            .collect();
        let p = plan(&dims);
        let ctx = ExecCtx::serial();
        let (got, _) = p.evaluate(&mats, &ctx);
        let want = matmul::serial(&matmul::serial(&mats[0], &mats[1]), &mats[2]);
        assert!(got.approx_eq(&want, 1e-6));
    }

    #[test]
    fn single_matrix_chain_is_identity() {
        let m = matrices::small_int(3, 4, 9);
        let p = plan(&[3, 4]);
        let (got, _) = p.evaluate(std::slice::from_ref(&m), &ExecCtx::serial());
        assert_eq!(got, m);
        assert_eq!(p.cost, 0.0);
    }
}
