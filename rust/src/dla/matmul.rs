//! Matrix multiplication: every engine the paper compares.
//!
//! * [`serial_ijk`] — the textbook triple loop "in serial order of
//!   occurrence of the rows" (paper Table 1's serial column). Cache-hostile
//!   on purpose: it is the baseline whose "repetitive nature of common
//!   computations" the paper calls an overhead in itself.
//! * [`serial`] — ikj loop order (the honest serial baseline: contiguous
//!   inner loop, auto-vectorizable).
//! * [`blocked`] — cache-tiled serial (the L3 twin of the L1 Pallas tiling).
//! * [`parallel`] — master-slave row-block distribution on the work-stealing
//!   pool: the master splits C's rows into `tasks` disjoint chunks, each
//!   chunk is one spawned task, no synchronization inside a chunk (the
//!   paper's management of the "inter product addition" overhead).
//! * [`simulated`] — the same distribution recorded on a [`SimCtx`] with
//!   calibrated per-op costs, for virtual-time experiments.
//! * [`run`] — the overhead-managed entry point: consults the
//!   [`Manager`](crate::overhead::Manager) (serial-vs-parallel + grain) and
//!   dispatches to the context's engine.

use super::matrix::Matrix;
use super::microkernel;
use crate::exec::{Engine, ExecCtx, RunReport};
use crate::overhead::{Ledger, WorkEstimate};
use crate::pool::ThreadPool;
use crate::sim::SimCtx;
use crate::util::Stopwatch;

/// Multiply-add count of an (m,k)×(k,n) matmul.
pub fn flops(m: usize, k: usize, n: usize) -> f64 {
    m as f64 * k as f64 * n as f64
}

/// Naive i-j-k triple loop (paper's serial processing methodology).
pub fn serial_ijk(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "contraction mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a.get(i, kk) * b.get(kk, j);
            }
            c.set(i, j, acc);
        }
    }
    c
}

/// Cache-friendly i-k-j loop order; the default serial engine.
pub fn serial(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "contraction mismatch");
    let (m, n) = (a.rows(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        matmul_row(a, b, c.row_mut(i), i);
    }
    let _ = (m, n);
    c
}

/// One output row: c_row += a[i,:] · B. Shared by serial and parallel
/// engines (identical arithmetic ⇒ bit-identical results).
///
/// §Perf: branch-free slice iteration — the zipped loop has no bounds
/// checks or data-dependent branches, so LLVM auto-vectorizes the inner
/// axpy (measured 1.5–1.7× over the indexed/branchy version on the
/// order-256 wall bench; see EXPERIMENTS.md §Perf).
#[inline]
fn matmul_row(a: &Matrix, b: &Matrix, c_row: &mut [f32], i: usize) {
    let n = b.cols();
    debug_assert_eq!(c_row.len(), n);
    let a_row = a.row(i);
    let b_data = b.data();
    for (kk, &aik) in a_row.iter().enumerate() {
        let brow = &b_data[kk * n..kk * n + n];
        for (c, &bv) in c_row.iter_mut().zip(brow) {
            *c += aik * bv;
        }
    }
}

/// Cache-blocked serial matmul with `bs`×`bs` tiles.
pub fn blocked(a: &Matrix, b: &Matrix, bs: usize) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "contraction mismatch");
    assert!(bs > 0);
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for i0 in (0..m).step_by(bs) {
        for k0 in (0..k).step_by(bs) {
            for j0 in (0..n).step_by(bs) {
                let i1 = (i0 + bs).min(m);
                let k1 = (k0 + bs).min(k);
                let j1 = (j0 + bs).min(n);
                for i in i0..i1 {
                    // §Perf: slice the j-tile once per (i, kk) so the
                    // innermost loop is a branch-free vectorizable axpy.
                    let crow = &mut c.row_mut(i)[j0..j1];
                    for kk in k0..k1 {
                        let aik = a.get(i, kk);
                        let brow = &b.row(kk)[j0..j1];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
    c
}

/// Master-slave row-block parallel matmul on the pool: C's rows are split
/// into `tasks` chunks; each chunk is one task writing a disjoint slice of
/// C (no output synchronization — the paper's Table 1 management rule).
pub fn parallel(a: &Matrix, b: &Matrix, pool: &ThreadPool, tasks: usize) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "contraction mismatch");
    let (m, n) = (a.rows(), b.cols());
    let tasks = tasks.clamp(1, m.max(1));
    let mut c = Matrix::zeros(m, n);
    let chunk_rows = m.div_ceil(tasks);
    {
        let chunks: Vec<(usize, &mut [f32])> = c
            .data_mut()
            .chunks_mut(chunk_rows * n)
            .enumerate()
            .collect();
        pool.scope(|s| {
            for (ci, chunk) in chunks {
                s.spawn(move |_| {
                    // Packed microkernel per chunk; bit-identical to the
                    // per-row axpy it replaces (see `dla::microkernel`).
                    let rows = chunk.len() / n;
                    microkernel::multiply_rows(a, b, chunk, ci * chunk_rows, rows);
                });
            }
        });
    }
    c
}

/// Virtual-time twin of [`parallel`]: computes the real result while
/// recording the fork-join structure with calibrated costs.
///
/// Costs: each chunk is `rows·k·n` multiply-adds at `op_ns` each; the
/// distribution payload per slave is its A row-block plus its C row-block
/// (B stays in shared memory, as under OpenMP).
pub fn simulated(a: &Matrix, b: &Matrix, ctx: &mut SimCtx, op_ns: f64, tasks: usize) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "contraction mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let tasks = tasks.clamp(1, m.max(1));
    let mut c = Matrix::zeros(m, n);
    let chunk_rows = m.div_ceil(tasks);
    let row_bytes = (k + n) as u64 * 4; // A row + C row
    let chunks: Vec<(usize, &mut [f32])> =
        c.data_mut().chunks_mut(chunk_rows * n).enumerate().collect();
    let inputs: Vec<((usize, &mut [f32]), u64)> = chunks
        .into_iter()
        .map(|(ci, chunk)| {
            let rows = chunk.len() / n;
            (((ci, chunk)), rows as u64 * row_bytes)
        })
        .collect();
    ctx.fork_each(inputs, |(ci, chunk), cc| {
        let row0 = ci * chunk_rows;
        let rows = chunk.len() / n;
        for (r, crow) in chunk.chunks_mut(n).enumerate() {
            matmul_row(a, b, crow, row0 + r);
        }
        cc.work(rows as f64 * flops(1, k, n) * op_ns, "matmul-chunk");
    });
    c
}

/// Work estimate for the manager: total multiply-adds × calibrated op cost;
/// distribution bytes = A + C (B shared).
pub fn estimate(a: &Matrix, b: &Matrix, op_ns: f64) -> WorkEstimate {
    let work = flops(a.rows(), a.cols(), b.cols()) * op_ns;
    WorkEstimate::fully_parallel(work, a.nbytes() + (a.rows() * b.cols() * 4) as u64)
}

/// Overhead-managed matmul: decide serial/parallel + grain via the
/// context's manager, execute on its engine, return result + report.
pub fn run(a: &Matrix, b: &Matrix, ctx: &ExecCtx) -> (Matrix, RunReport) {
    let est = estimate(a, b, ctx.cal.matmul_op_ns);
    let decision = ctx.manager.decide(&est);
    let sw = Stopwatch::start();
    match &ctx.engine {
        Engine::Serial => {
            let c = serial(a, b);
            let mut rep = RunReport::wall_only(sw.elapsed_ns());
            rep.ledger.compute_ns = est.total_work_ns as u64;
            (c, rep)
        }
        Engine::Threaded(pool) => {
            let before = pool.metrics();
            let (c, tasks_used) = match decision {
                crate::overhead::Decision::Parallel { tasks, .. } => (parallel(a, b, pool, tasks), tasks),
                crate::overhead::Decision::Serial { .. } => (serial(a, b), 0),
            };
            let delta = pool.metrics().delta_since(&before);
            let mut rep = RunReport::wall_only(sw.elapsed_ns());
            rep.ledger = Ledger::from_metrics(&delta, if tasks_used > 0 { est.dist_bytes } else { 0 });
            rep.ledger.compute_ns = est.total_work_ns as u64;
            (c, rep)
        }
        Engine::Simulated(machine) => {
            let mut sc = SimCtx::new();
            let c = match decision {
                crate::overhead::Decision::Parallel { tasks, .. } => {
                    simulated(a, b, &mut sc, ctx.cal.matmul_op_ns, tasks)
                }
                crate::overhead::Decision::Serial { .. } => {
                    let c = serial(a, b);
                    sc.work(est.total_work_ns, "matmul-serial");
                    c
                }
            };
            let sim = machine.run(&sc.into_node(), ctx.trace);
            let rep = RunReport {
                wall_ns: sw.elapsed_ns(),
                virtual_ns: Some(sim.makespan_ns),
                serial_equiv_ns: Some(sim.serial_ns),
                ledger: sim.ledger,
                timeline: sim.timeline,
            };
            (c, rep)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overhead::OverheadParams;
    use crate::workload::matrices;

    fn small() -> (Matrix, Matrix) {
        (matrices::small_int(13, 17, 1), matrices::small_int(17, 9, 2))
    }

    #[test]
    fn ikj_matches_ijk() {
        let (a, b) = small();
        assert_eq!(serial(&a, &b), serial_ijk(&a, &b));
    }

    #[test]
    fn blocked_matches_serial_various_block_sizes() {
        let (a, b) = small();
        let want = serial(&a, &b);
        for bs in [1, 3, 4, 16, 64] {
            assert_eq!(blocked(&a, &b, bs), want, "bs={bs}");
        }
    }

    #[test]
    fn parallel_bit_identical_to_serial() {
        let (a, b) = small();
        let want = serial(&a, &b);
        let pool = ThreadPool::new(3);
        for tasks in [1, 2, 5, 13, 50] {
            assert_eq!(parallel(&a, &b, &pool, tasks), want, "tasks={tasks}");
        }
    }

    #[test]
    fn simulated_bit_identical_to_serial() {
        let (a, b) = small();
        let want = serial(&a, &b);
        let mut sc = SimCtx::new();
        let got = simulated(&a, &b, &mut sc, 1.0, 4);
        assert_eq!(got, want);
        let tree = sc.into_node();
        assert!((tree.total_work_ns() - flops(13, 17, 9)).abs() < 1e-6);
        assert_eq!(tree.spawn_count(), 4);
    }

    #[test]
    fn known_2x2_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = serial(&a, &b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn run_serial_engine() {
        let (a, b) = small();
        let ctx = ExecCtx::serial();
        let (c, rep) = run(&a, &b, &ctx);
        assert_eq!(c, serial(&a, &b));
        assert!(rep.virtual_ns.is_none());
    }

    #[test]
    fn run_threaded_engine_fills_ledger_when_parallel() {
        let a = matrices::uniform(200, 200, 3);
        let b = matrices::uniform(200, 200, 4);
        let ctx = ExecCtx::threaded(2);
        let (c, rep) = run(&a, &b, &ctx);
        assert!(c.approx_eq(&serial(&a, &b), 1e-6));
        // 200³ ops ≈ 8ms estimated: should go parallel and spawn tasks.
        assert!(rep.ledger.spawns > 0, "ledger: {:?}", rep.ledger);
    }

    #[test]
    fn run_simulated_engine_reports_virtual_time_and_speedup() {
        let a = matrices::uniform(128, 128, 5);
        let b = matrices::uniform(128, 128, 6);
        let ctx = ExecCtx::simulated(4, OverheadParams::paper_2022());
        let (c, rep) = run(&a, &b, &ctx);
        assert!(c.approx_eq(&serial(&a, &b), 1e-6));
        let v = rep.virtual_ns.expect("virtual time");
        assert!(v > 0.0);
        let s = rep.speedup().expect("speedup");
        assert!(s > 1.0 && s <= 4.0, "speedup {s}");
    }

    #[test]
    fn run_simulated_small_matrix_stays_serial() {
        // 8³ = 512 ops ≈ 0.5µs — far below the paper cutoff: manager must
        // refuse to parallelize, so no spawns in the ledger.
        let a = matrices::uniform(8, 8, 7);
        let b = matrices::uniform(8, 8, 8);
        let ctx = ExecCtx::simulated(4, OverheadParams::paper_2022());
        let (_, rep) = run(&a, &b, &ctx);
        assert_eq!(rep.ledger.spawns, 0);
        assert!((rep.speedup().unwrap() - 1.0).abs() < 1e-9);
    }
}
