//! Row-major f32 matrix — the DLA domain's value type.

/// Dense row-major matrix of `f32`.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Bytes occupied by the element storage (distribution-cost input).
    pub fn nbytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<f32>()) as u64
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.set(c, r, self.get(r, c));
            }
        }
        t
    }

    /// Largest absolute element-wise difference (result comparisons).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Approximate equality with tolerance scaled to magnitude.
    pub fn approx_eq(&self, other: &Matrix, tol: f32) -> bool {
        if (self.rows, self.cols) != (other.rows, other.cols) {
            return false;
        }
        self.data.iter().zip(&other.data).all(|(a, b)| {
            let scale = a.abs().max(b.abs()).max(1.0);
            (a - b).abs() <= tol * scale
        })
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_fn_layout() {
        let m = Matrix::from_fn(2, 3, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.get(0, 2), 2.0);
        assert_eq!(m.get(1, 0), 10.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
        assert_eq!(m.nbytes(), 24);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_fn(3, 5, |r, c| (r * 7 + c * 3) as f32);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(4, 2), m.get(2, 4));
    }

    #[test]
    fn diff_and_approx() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 100.0]);
        let b = Matrix::from_vec(1, 2, vec![1.0, 100.1]);
        assert!((a.max_abs_diff(&b) - 0.1).abs() < 1e-5);
        assert!(a.approx_eq(&b, 1.1e-3));
        assert!(!a.approx_eq(&b, 1e-6));
        let c = Matrix::zeros(2, 1);
        assert!(!a.approx_eq(&c, 1.0));
    }

    #[test]
    fn frobenius_known_value() {
        let m = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.frobenius() - 5.0).abs() < 1e-12);
    }
}
