//! Packed register-blocked matmul microkernel — the layer *below* the
//! row-block [`parallel`](super::matmul::parallel) distribution and the
//! [`strassen`](super::strassen) recursion.
//!
//! The existing [`blocked`](super::matmul::blocked) engine tiles the
//! loop nest but still walks `A` and `B` in their row-major layouts, so
//! the inner axpy strides through `B` one full row per `k` step. This
//! kernel adds the two classical GEMM refinements under it:
//!
//! * **Packing** — for each `KC`-deep slice of the contraction, `A` is
//!   repacked into `MR`-row panels and `B` into `NR`-column panels, both
//!   k-major, so the microkernel reads two small contiguous streams
//!   regardless of the matrices' true leading dimensions;
//! * **Register tiling** — an `MR`×`NR` accumulator block lives in
//!   registers across the whole `KC` loop, turning ~`MR·NR` loads per
//!   `k` step into `MR + NR`.
//!
//! **Bit-exactness contract.** Every output element accumulates its
//! products in strictly ascending `k` order — `KC` slices are processed
//! in order and the microkernel's `k` loop is ascending — which is
//! exactly the accumulation order of the serial reference
//! (`matmul::serial`'s axpy walks `k` ascending). Products are computed
//! as a single f32 multiply followed by an f32 add (Rust never
//! contracts to FMA implicitly), so the result is **bit-identical** to
//! `serial`, not merely close: the property tests in
//! `rust/tests/prop_kernels.rs` assert `==`, including non-power-of-two
//! and size-0/1 edges. That is what lets it slot under Strassen's base
//! case and `parallel`'s row chunks without perturbing any existing
//! cross-engine equality test.

use super::matrix::Matrix;

/// Microkernel rows (register-tile height).
pub const MR: usize = 4;
/// Microkernel columns (register-tile width; two f32x4 lanes).
pub const NR: usize = 8;
/// Contraction depth per packed slice (panel working set ≈ L2-sized:
/// `KC·(MR+NR)·4` bytes per active pair of panels).
pub const KC: usize = 256;

/// `C = A·B` via the packed microkernel. Drop-in replacement for
/// [`super::matmul::serial`] with identical (bit-exact) results.
pub fn multiply(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "contraction mismatch");
    let mut c = Matrix::zeros(a.rows(), b.cols());
    multiply_rows(a, b, c.data_mut(), 0, a.rows());
    c
}

/// Compute rows `[row0, row0 + rows)` of `C = A·B` into `out`
/// (`rows × b.cols()` row-major). This is the entry point the parallel
/// engine uses: each spawned task owns a disjoint row chunk of `C` and
/// runs the packed kernel on it independently.
pub fn multiply_rows(a: &Matrix, b: &Matrix, out: &mut [f32], row0: usize, rows: usize) {
    let (k, n) = (a.cols(), b.cols());
    debug_assert_eq!(out.len(), rows * n);
    if rows == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    let rows_main = rows - rows % MR;
    let n_main = n - n % NR;
    // Panel buffers, reused across KC slices.
    let mut apack = vec![0.0f32; rows_main.max(1) * KC.min(k)];
    let mut bpack = vec![0.0f32; n_main.max(1) * KC.min(k)];
    let mut k0 = 0;
    while k0 < k {
        let kc = KC.min(k - k0);
        // Pack A[row0.., k0..k0+kc] into MR-row panels, k-major: panel
        // `ir` holds kc groups of MR consecutive row elements.
        for ir in (0..rows_main).step_by(MR) {
            let dst = &mut apack[ir * kc..(ir + MR) * kc];
            for (kk, group) in dst.chunks_exact_mut(MR).enumerate() {
                for (r, slot) in group.iter_mut().enumerate() {
                    *slot = a.get(row0 + ir + r, k0 + kk);
                }
            }
        }
        // Pack B[k0..k0+kc, ..n_main] into NR-column panels, k-major.
        for jr in (0..n_main).step_by(NR) {
            let dst = &mut bpack[jr * kc..(jr + NR) * kc];
            for (kk, group) in dst.chunks_exact_mut(NR).enumerate() {
                group.copy_from_slice(&b.row(k0 + kk)[jr..jr + NR]);
            }
        }
        // Main region: MR×NR register tiles over the packed panels.
        for ir in (0..rows_main).step_by(MR) {
            let ap = &apack[ir * kc..(ir + MR) * kc];
            for jr in (0..n_main).step_by(NR) {
                let bp = &bpack[jr * kc..(jr + NR) * kc];
                kernel(ap, bp, kc, out, ir, jr, n);
            }
            // Column tail for the main rows: scalar axpy, k ascending.
            if n_main < n {
                for r in 0..MR {
                    let crow = &mut out[(ir + r) * n + n_main..(ir + r) * n + n];
                    for kk in 0..kc {
                        let aik = ap[kk * MR + r];
                        let brow = &b.row(k0 + kk)[n_main..];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
        // Row tail: plain k-ascending axpy over the whole width.
        for i in rows_main..rows {
            let crow = &mut out[i * n..(i + 1) * n];
            for kk in 0..kc {
                let aik = a.get(row0 + i, k0 + kk);
                let brow = b.row(k0 + kk);
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
        k0 += kc;
    }
}

/// The MR×NR register-tile kernel: load the accumulator block from `C`,
/// stream the two packed panels over `kc` ascending, write back. The
/// accumulator array is small enough (`MR·NR` f32) for LLVM to keep it
/// entirely in vector registers.
#[inline]
fn kernel(ap: &[f32], bp: &[f32], kc: usize, out: &mut [f32], ir: usize, jr: usize, n: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    for (r, row) in acc.iter_mut().enumerate() {
        row.copy_from_slice(&out[(ir + r) * n + jr..(ir + r) * n + jr + NR]);
    }
    for kk in 0..kc {
        let av = &ap[kk * MR..kk * MR + MR];
        let bv = &bp[kk * NR..kk * NR + NR];
        for (row, &aik) in acc.iter_mut().zip(av) {
            for (cv, &bvv) in row.iter_mut().zip(bv) {
                *cv += aik * bvv;
            }
        }
    }
    for (r, row) in acc.iter().enumerate() {
        out[(ir + r) * n + jr..(ir + r) * n + jr + NR].copy_from_slice(row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dla::matmul;
    use crate::workload::matrices;

    #[test]
    fn bit_identical_to_serial_square() {
        for n in [1usize, 2, 4, 16, 64, 128] {
            let a = matrices::uniform(n, n, n as u64);
            let b = matrices::uniform(n, n, n as u64 + 100);
            assert_eq!(multiply(&a, &b), matmul::serial(&a, &b), "n={n}");
        }
    }

    #[test]
    fn bit_identical_rectangular_and_ragged() {
        // Shapes straddling every MR/NR/KC edge: primes, exact tiles,
        // one-off tiles.
        for (m, k, n) in [(3, 5, 7), (4, 8, 8), (5, 9, 9), (13, 17, 9), (31, 257, 33)] {
            let a = matrices::uniform(m, k, 7);
            let b = matrices::uniform(k, n, 8);
            assert_eq!(multiply(&a, &b), matmul::serial(&a, &b), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn degenerate_dims() {
        let a = matrices::uniform(0, 4, 1);
        let b = matrices::uniform(4, 3, 2);
        assert_eq!(multiply(&a, &b).rows(), 0);
        let a = matrices::uniform(3, 0, 1);
        let b = matrices::uniform(0, 2, 2);
        let c = multiply(&a, &b);
        assert!(c.data().iter().all(|&v| v == 0.0), "empty contraction is zero");
        let a = matrices::uniform(2, 3, 1);
        let b = matrices::uniform(3, 0, 2);
        assert_eq!(multiply(&a, &b).data().len(), 0);
    }

    #[test]
    fn multiply_rows_computes_one_chunk() {
        let a = matrices::uniform(10, 12, 3);
        let b = matrices::uniform(12, 11, 4);
        let want = matmul::serial(&a, &b);
        let mut chunk = vec![0.0f32; 4 * 11];
        multiply_rows(&a, &b, &mut chunk, 5, 4);
        for r in 0..4 {
            assert_eq!(&chunk[r * 11..(r + 1) * 11], want.row(5 + r), "row {r}");
        }
    }
}
