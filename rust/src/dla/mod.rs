//! Dense Linear Algebra domain (paper §"Overheads of parallelism in
//! Matrix Multiplication and their Management": Table 1, Fig 1, Fig 2).

pub mod chain;
pub mod matmul;
pub mod matrix;
pub mod microkernel;
pub mod strassen;

pub use matrix::Matrix;
