//! Strassen matrix multiplication — the extension case study for the
//! paper's methodology.
//!
//! Strassen trades one multiplication for ~18 additions per recursion
//! level, so it only pays above a *cutoff* order — the same
//! "size of problem vs effort of division" trade-off the paper manages
//! for fork-join. OHM treats the Strassen cutoff exactly like the fork
//! cutoff: predicted from calibrated per-op costs, ablated in
//! `ablation_grain`-style sweeps, and testable.
//!
//! The recursion is also a natural fork-join workload: the seven
//! sub-products are independent (spawnable on the pool), while the
//! combining additions synchronize — a richer dependency structure than
//! row-block matmul, which is why the paper's "each problem space
//! requires detailed and independent analysis" conclusion applies.

use super::matrix::Matrix;
use super::microkernel;
use crate::pool::ThreadPool;

/// Below this order, fall back to the tuned classical kernel.
pub const DEFAULT_CUTOFF: usize = 64;

/// Serial Strassen with classical fallback below `cutoff`.
pub fn strassen(a: &Matrix, b: &Matrix, cutoff: usize) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "contraction mismatch");
    assert!(a.rows() == a.cols() && b.rows() == b.cols(), "square only");
    let n = a.rows();
    let cutoff = cutoff.max(2);
    if n <= cutoff || n % 2 != 0 {
        // Base case: the packed microkernel (bit-identical to
        // `matmul::serial`, so Strassen's cross-engine tests still hold).
        return microkernel::multiply(a, b);
    }
    let (a11, a12, a21, a22) = split(a);
    let (b11, b12, b21, b22) = split(b);

    let m1 = strassen(&add(&a11, &a22), &add(&b11, &b22), cutoff);
    let m2 = strassen(&add(&a21, &a22), &b11, cutoff);
    let m3 = strassen(&a11, &sub(&b12, &b22), cutoff);
    let m4 = strassen(&a22, &sub(&b21, &b11), cutoff);
    let m5 = strassen(&add(&a11, &a12), &b22, cutoff);
    let m6 = strassen(&sub(&a21, &a11), &add(&b11, &b12), cutoff);
    let m7 = strassen(&sub(&a12, &a22), &add(&b21, &b22), cutoff);

    combine(n, &m1, &m2, &m3, &m4, &m5, &m6, &m7)
}

/// Pool-parallel Strassen: the seven sub-products fork on the pool at the
/// top `levels` of the recursion (7-way scope), then serial below.
pub fn strassen_parallel(
    a: &Matrix,
    b: &Matrix,
    pool: &ThreadPool,
    cutoff: usize,
    levels: usize,
) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "contraction mismatch");
    assert!(a.rows() == a.cols() && b.rows() == b.cols(), "square only");
    let n = a.rows();
    if levels == 0 || n <= cutoff.max(2) || n % 2 != 0 {
        return strassen(a, b, cutoff);
    }
    let (a11, a12, a21, a22) = split(a);
    let (b11, b12, b21, b22) = split(b);

    // The seven products are independent: classic master-slave fork.
    let inputs: [(Matrix, Matrix); 7] = [
        (add(&a11, &a22), add(&b11, &b22)),
        (add(&a21, &a22), b11.clone()),
        (a11.clone(), sub(&b12, &b22)),
        (a22.clone(), sub(&b21, &b11)),
        (add(&a11, &a12), b22.clone()),
        (sub(&a21, &a11), add(&b11, &b12)),
        (sub(&a12, &a22), add(&b21, &b22)),
    ];
    let mut products: Vec<Option<Matrix>> = (0..7).map(|_| None).collect();
    {
        let slots: Vec<(&mut Option<Matrix>, &(Matrix, Matrix))> =
            products.iter_mut().zip(inputs.iter()).collect();
        pool.scope(|s| {
            for (slot, (x, y)) in slots {
                s.spawn(move |_| {
                    *slot = Some(strassen_parallel(x, y, pool, cutoff, levels - 1));
                });
            }
        });
    }
    let p: Vec<Matrix> = products.into_iter().map(Option::unwrap).collect();
    combine(n, &p[0], &p[1], &p[2], &p[3], &p[4], &p[5], &p[6])
}

/// Multiply-add count of Strassen at the given cutoff (work model for the
/// overhead manager: n^log2(7) multiplies + O(n²) adds per level).
pub fn work_ops(n: usize, cutoff: usize) -> f64 {
    if n <= cutoff.max(2) || n % 2 != 0 {
        return (n as f64).powi(3);
    }
    let half = n / 2;
    7.0 * work_ops(half, cutoff) + 18.0 * (half as f64) * (half as f64)
}

fn split(m: &Matrix) -> (Matrix, Matrix, Matrix, Matrix) {
    let h = m.rows() / 2;
    let quad = |r0: usize, c0: usize| {
        Matrix::from_fn(h, h, |r, c| m.get(r0 + r, c0 + c))
    };
    (quad(0, 0), quad(0, h), quad(h, 0), quad(h, h))
}

fn add(x: &Matrix, y: &Matrix) -> Matrix {
    let mut out = x.clone();
    for (o, &v) in out.data_mut().iter_mut().zip(y.data()) {
        *o += v;
    }
    out
}

fn sub(x: &Matrix, y: &Matrix) -> Matrix {
    let mut out = x.clone();
    for (o, &v) in out.data_mut().iter_mut().zip(y.data()) {
        *o -= v;
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn combine(
    n: usize,
    m1: &Matrix,
    m2: &Matrix,
    m3: &Matrix,
    m4: &Matrix,
    m5: &Matrix,
    m6: &Matrix,
    m7: &Matrix,
) -> Matrix {
    let h = n / 2;
    let mut c = Matrix::zeros(n, n);
    for r in 0..h {
        for col in 0..h {
            let c11 = m1.get(r, col) + m4.get(r, col) - m5.get(r, col) + m7.get(r, col);
            let c12 = m3.get(r, col) + m5.get(r, col);
            let c21 = m2.get(r, col) + m4.get(r, col);
            let c22 = m1.get(r, col) - m2.get(r, col) + m3.get(r, col) + m6.get(r, col);
            c.set(r, col, c11);
            c.set(r, col + h, c12);
            c.set(r + h, col, c21);
            c.set(r + h, col + h, c22);
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dla::matmul;
    use crate::workload::matrices;

    #[test]
    fn matches_classical_pow2() {
        for n in [2usize, 4, 8, 64, 128] {
            let a = matrices::uniform(n, n, n as u64);
            let b = matrices::uniform(n, n, n as u64 + 1);
            let got = strassen(&a, &b, 8);
            let want = matmul::serial(&a, &b);
            assert!(got.approx_eq(&want, 1e-3), "n={n}: |Δ|={}", got.max_abs_diff(&want));
        }
    }

    #[test]
    fn odd_orders_fall_back_cleanly() {
        // 100 = 4·25: recursion stops at the odd order 25.
        let a = matrices::uniform(100, 100, 1);
        let b = matrices::uniform(100, 100, 2);
        let got = strassen(&a, &b, 8);
        assert!(got.approx_eq(&matmul::serial(&a, &b), 1e-3));
    }

    #[test]
    fn parallel_matches_serial_strassen() {
        let pool = ThreadPool::new(3);
        let a = matrices::uniform(128, 128, 3);
        let b = matrices::uniform(128, 128, 4);
        let ser = strassen(&a, &b, 16);
        let par = strassen_parallel(&a, &b, &pool, 16, 2);
        // Same recursion/splitting order ⇒ identical float schedule.
        assert_eq!(ser, par);
    }

    #[test]
    fn small_int_exactness() {
        let a = matrices::small_int(64, 64, 5);
        let b = matrices::small_int(64, 64, 6);
        // Integer-valued inputs in a small range: Strassen's adds and
        // subtracts are exact in f32, so the result is exactly classical.
        assert_eq!(strassen(&a, &b, 8), matmul::serial(&a, &b));
    }

    #[test]
    fn work_model_beats_cubic_above_cutoff() {
        let classical = 1024f64.powi(3);
        let s = work_ops(1024, 64);
        assert!(s < classical, "strassen {s} !< classical {classical}");
        // And respects the fallback below cutoff.
        assert_eq!(work_ops(32, 64), 32f64.powi(3));
        // Crossover behaviour: tiny cutoff does MORE total ops at small n
        // (the addition overhead) — the paper's division-overhead story.
        assert!(work_ops(64, 2) > 0.5 * work_ops(64, 64));
    }
}
