//! Unified execution backends.
//!
//! Domain operations (matmul, sort) run under an [`ExecCtx`] that selects
//! one of three engines sharing identical algorithmic code paths:
//!
//! * **Serial** — reference engine; also the paper's baseline columns.
//! * **Threaded** — the real work-stealing pool ([`crate::pool`]); measures
//!   wall-clock and fills the ledger from pool metrics. The engine of
//!   choice on genuine multicore hosts.
//! * **Simulated** — the discrete-event machine ([`crate::sim`]); executes
//!   the computation for real (single-threaded) while charging calibrated
//!   overheads against a virtual clock. The engine behind every number in
//!   EXPERIMENTS.md (this container has one physical core).
//!
//! The [`crate::overhead::Manager`] is consulted by domain code to pick
//! serial-vs-parallel and grain, making the paper's management policy a
//! cross-cutting concern rather than per-algorithm ad-hoc tuning.
//!
//! Serving-layer overhead (admission-queue wait in front of an engine) is
//! deliberately *not* an engine concern: it is measured by the
//! coordinator's dispatcher and recorded in the serving
//! [`Telemetry`](crate::coordinator::Telemetry) / `Ledger::queue_ns`,
//! so engine `RunReport`s stay comparable with and without the TCP front
//! end in the path.

use crate::overhead::{calibrate::Calibration, Ledger, Manager, OverheadParams};
use crate::pool::ThreadPool;
use crate::sim::Machine;

/// Execution engine selection.
pub enum Engine {
    Serial,
    Threaded(ThreadPool),
    Simulated(Machine),
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Engine::Serial => write!(f, "Serial"),
            Engine::Threaded(p) => write!(f, "Threaded({})", p.threads()),
            Engine::Simulated(m) => write!(f, "Simulated({} cores)", m.cores),
        }
    }
}

/// Execution context: engine + overhead policy + calibrated op costs.
#[derive(Debug)]
pub struct ExecCtx {
    pub engine: Engine,
    pub manager: Manager,
    pub cal: Calibration,
    /// Record full Gantt timelines on the simulated engine.
    pub trace: bool,
}

impl ExecCtx {
    /// Serial reference context.
    pub fn serial() -> Self {
        let cal = Calibration::paper_defaults();
        ExecCtx { engine: Engine::Serial, manager: Manager::new(cal.params, 1), cal, trace: false }
    }

    /// Real thread pool with `threads` workers.
    pub fn threaded(threads: usize) -> Self {
        let cal = Calibration::paper_defaults();
        ExecCtx {
            engine: Engine::Threaded(ThreadPool::new(threads)),
            manager: Manager::new(cal.params, threads),
            cal,
            trace: false,
        }
    }

    /// Simulated machine with `cores` virtual cores and overhead `params`.
    pub fn simulated(cores: usize, params: OverheadParams) -> Self {
        let mut cal = Calibration::paper_defaults();
        cal.params = params;
        ExecCtx {
            engine: Engine::Simulated(Machine::new(cores, params)),
            manager: Manager::new(params, cores),
            cal,
            trace: false,
        }
    }

    /// Replace the calibration (op costs + params) wholesale.
    pub fn with_calibration(mut self, cal: Calibration) -> Self {
        let cores = self.cores();
        self.manager = Manager::new(cal.params, cores);
        if let Engine::Simulated(m) = &mut self.engine {
            m.params = cal.params;
        }
        self.cal = cal;
        self
    }

    pub fn with_trace(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    /// Core count visible to the manager.
    pub fn cores(&self) -> usize {
        match &self.engine {
            Engine::Serial => 1,
            Engine::Threaded(p) => p.threads(),
            Engine::Simulated(m) => m.cores,
        }
    }

    pub fn engine_name(&self) -> &'static str {
        match &self.engine {
            Engine::Serial => "serial",
            Engine::Threaded(_) => "threaded",
            Engine::Simulated(_) => "simulated",
        }
    }
}

/// Outcome of one executed region.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Real wall-clock of the run, ns.
    pub wall_ns: u64,
    /// Virtual time, ns (simulated engine only).
    pub virtual_ns: Option<f64>,
    /// Serial-equivalent time for the same work, ns (virtual engines).
    pub serial_equiv_ns: Option<f64>,
    pub ledger: Ledger,
    /// Gantt timeline (simulated engine with `trace` on).
    pub timeline: Vec<crate::sim::Segment>,
}

impl RunReport {
    pub fn wall_only(wall_ns: u64) -> Self {
        RunReport {
            wall_ns,
            virtual_ns: None,
            serial_equiv_ns: None,
            ledger: Ledger::default(),
            timeline: Vec::new(),
        }
    }

    /// The experiment clock: virtual time when simulated, else wall time,
    /// in microseconds.
    pub fn time_us(&self) -> f64 {
        match self.virtual_ns {
            Some(v) => v / 1e3,
            None => self.wall_ns as f64 / 1e3,
        }
    }

    /// Speedup vs the serial equivalent (virtual engines), if known.
    pub fn speedup(&self) -> Option<f64> {
        match (self.virtual_ns, self.serial_equiv_ns) {
            (Some(v), Some(s)) if v > 0.0 => Some(s / v),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_constructors_report_cores() {
        assert_eq!(ExecCtx::serial().cores(), 1);
        assert_eq!(ExecCtx::threaded(3).cores(), 3);
        assert_eq!(ExecCtx::simulated(8, OverheadParams::paper_2022()).cores(), 8);
    }

    #[test]
    fn engine_names() {
        assert_eq!(ExecCtx::serial().engine_name(), "serial");
        assert_eq!(ExecCtx::threaded(2).engine_name(), "threaded");
        assert_eq!(ExecCtx::simulated(2, OverheadParams::ideal()).engine_name(), "simulated");
    }

    #[test]
    fn report_clock_prefers_virtual() {
        let mut r = RunReport::wall_only(5_000);
        assert!((r.time_us() - 5.0).abs() < 1e-9);
        r.virtual_ns = Some(9_000.0);
        r.serial_equiv_ns = Some(18_000.0);
        assert!((r.time_us() - 9.0).abs() < 1e-9);
        assert!((r.speedup().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn with_calibration_updates_manager_and_machine() {
        let mut cal = Calibration::paper_defaults();
        cal.params = OverheadParams::ideal();
        let ctx = ExecCtx::simulated(4, OverheadParams::paper_2022()).with_calibration(cal);
        assert_eq!(ctx.manager.params, OverheadParams::ideal());
        match &ctx.engine {
            Engine::Simulated(m) => assert_eq!(m.params, OverheadParams::ideal()),
            _ => unreachable!(),
        }
    }
}
