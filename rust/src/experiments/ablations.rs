//! Ablations on the design choices DESIGN.md calls out:
//!
//! * `abl-grain` — the grain/cutoff sweep behind the manager's decision
//!   ("size of problem should be comparable to the efforts necessary for
//!   dividing the tasks"): too-fine grains drown in α/β, too-coarse grains
//!   idle cores; the manager's pick should sit near the sweep minimum.
//! * `abl-cores` — Amdahl curve vs overhead-adjusted speedup (the paper's
//!   criticism of Amdahl's law, quantified).
//! * `abl-adversarial` — why random/median pivots exist at all: operation
//!   counts per pivot strategy on sorted/reverse/few-unique inputs.

use super::{fig2::matmul_tree, ExpOutput};
use crate::config::ExperimentConfig;
use crate::overhead::{amdahl, WorkEstimate};
use crate::report::{table::f, AsciiTable, Chart};
use crate::sim::Machine;
use crate::sort::{parallel::simulate_with_cutoff, serial_quicksort, PivotStrategy, SortCostModel};
use crate::workload::arrays::{self, Distribution};

/// Grain sweep: matmul (tasks) and quicksort (cutoff) on the simulator.
pub fn grain(cfg: &ExperimentConfig) -> ExpOutput {
    let params = cfg.params();
    let machine = Machine::new(cfg.cores, params);
    let mut text = String::new();
    let mut csv_rows = Vec::new();

    // Matmul n=512: sweep task counts.
    let n = 512usize;
    let mut t = AsciiTable::new(
        &format!("abl-grain: matmul order {n}, {} cores — virtual ms by task count", cfg.cores),
        &["tasks", "time_ms", "spawns", "idle_frac"],
    );
    let mut best: Option<(usize, f64)> = None;
    let mut tasks = 1usize;
    while tasks <= 16 * cfg.cores {
        let rep = machine.run(&matmul_tree(n, 1.0, tasks), false);
        let ms = rep.makespan_ns / 1e6;
        if best.map_or(true, |(_, b)| ms < b) {
            best = Some((tasks, ms));
        }
        t.row(vec![tasks.to_string(), f(ms, 3), rep.ledger.spawns.to_string(), f(rep.idle_fraction(), 3)]);
        csv_rows.push(vec!["matmul".into(), tasks.to_string(), f(ms, 4)]);
        tasks *= 2;
    }
    let (best_tasks, best_ms) = best.unwrap();
    text.push_str(&t.render());
    text.push_str(&format!("sweep minimum: {best_tasks} tasks at {best_ms:.3} ms\n\n"));

    // Quicksort n=max(sort_sizes): sweep serial cutoffs.
    let n = cfg.sort_sizes.iter().copied().max().unwrap_or(2000);
    let model = SortCostModel::paper_2022();
    let mut t = AsciiTable::new(
        &format!("abl-grain: quicksort n={n}, {} cores — virtual ms by fork cutoff", cfg.cores),
        &["cutoff", "time_ms", "spawns"],
    );
    let mut cutoff = 16usize;
    while cutoff <= n {
        let mut xs = arrays::uniform_i64(n, cfg.seed);
        let rep = simulate_with_cutoff(&mut xs, PivotStrategy::Mean, cutoff, cfg.seed, &model, &machine);
        t.row(vec![cutoff.to_string(), f(rep.makespan_ns / 1e6, 3), rep.ledger.spawns.to_string()]);
        csv_rows.push(vec!["sort".into(), cutoff.to_string(), f(rep.makespan_ns / 1e6, 4)]);
        cutoff *= 2;
    }
    text.push_str(&t.render());

    ExpOutput {
        id: "abl-grain",
        title: "Grain ablation (task count / fork cutoff)",
        text,
        csv: vec![("abl_grain".into(), vec!["domain", "grain", "time_ms"], csv_rows)],
    }
}

/// Core-count sweep: ideal Amdahl vs overhead-adjusted speedup.
pub fn cores(cfg: &ExperimentConfig) -> ExpOutput {
    let params = cfg.params();
    let core_counts = [1usize, 2, 4, 8, 16, 32];
    let mut text = String::new();
    let mut csv_rows = Vec::new();
    let mut chart = Chart::new("abl-cores: speedup vs cores", "cores", "speedup");
    for (label, work_ns, bytes) in [
        ("matmul-512", 512f64.powi(3), (2 * 512 * 512 * 4) as u64),
        ("matmul-64", 64f64.powi(3), (2 * 64 * 64 * 4) as u64),
        ("sort-2000", 2000.0 * 11.0 * 225.0, 16_000u64),
    ] {
        let est = WorkEstimate::fully_parallel(work_ns, bytes);
        let rows = amdahl::sweep(&params, &est, &core_counts);
        let mut t = AsciiTable::new(
            &format!("abl-cores: {label} (work {:.2} ms)", work_ns / 1e6),
            &["cores", "ideal (Amdahl)", "adjusted (with overheads)", "gap"],
        );
        let mut pts = Vec::new();
        for (p, ideal, adj) in &rows {
            t.row(vec![p.to_string(), f(*ideal, 2), f(*adj, 2), f(ideal - adj, 2)]);
            csv_rows.push(vec![label.into(), p.to_string(), f(*ideal, 3), f(*adj, 3)]);
            pts.push((*p as f64, *adj));
        }
        chart.series(label, pts);
        text.push_str(&t.render());
        if let Some(sat) = amdahl::saturation_point(&params, &est, 32) {
            text.push_str(&format!("  speedup saturates at {sat} cores — adding more SLOWS it down\n"));
        }
        text.push('\n');
    }
    text.push_str(&chart.render());
    ExpOutput {
        id: "abl-cores",
        title: "Cores ablation: Amdahl vs overhead-adjusted speedup",
        text,
        csv: vec![("abl_cores".into(), vec!["workload", "cores", "ideal", "adjusted"], csv_rows)],
    }
}

/// Heterogeneous-cores ablation (paper ref [1], "Task Scheduling on
/// Adaptive Multi-Core"): the same matmul tree on (a) four nominal
/// cores, (b) one 2× core + two 1× + one 0.5× (same aggregate speed
/// 4.5 vs 4.0), (c) big.LITTLE-style 2×2. The EFT scheduler loads fast
/// cores more; with overheads, heterogeneity shifts the optimal grain.
pub fn hetero(cfg: &ExperimentConfig) -> ExpOutput {
    let params = cfg.params();
    let machines: [(&str, Machine); 3] = [
        ("4x1.0 (homogeneous)", Machine::new(4, params)),
        ("2.0+1.0+1.0+0.5", Machine::heterogeneous(vec![2.0, 1.0, 1.0, 0.5], params)),
        ("big.LITTLE 2x1.5+2x0.5", Machine::heterogeneous(vec![1.5, 1.5, 0.5, 0.5], params)),
    ];
    let n = 512usize;
    let mut t = AsciiTable::new(
        &format!("abl-hetero: matmul order {n} — virtual ms by machine and task count"),
        &["machine", "tasks=4", "tasks=8", "tasks=16", "tasks=32", "best"],
    );
    let mut csv_rows = Vec::new();
    let mut text_notes = String::new();
    for (name, m) in &machines {
        let mut cells = Vec::new();
        let mut best = (0usize, f64::INFINITY);
        for tasks in [4usize, 8, 16, 32] {
            let rep = m.run(&matmul_tree(n, 1.0, tasks), false);
            let ms = rep.makespan_ns / 1e6;
            if ms < best.1 {
                best = (tasks, ms);
            }
            cells.push(f(ms, 2));
            csv_rows.push(vec![name.to_string(), tasks.to_string(), f(ms, 4)]);
        }
        let mut row = vec![name.to_string()];
        row.extend(cells);
        row.push(format!("{} tasks", best.0));
        t.row(row);
        // Utilization skew on the heterogeneous machines.
        let rep = m.run(&matmul_tree(n, 1.0, best.0), true);
        let (busiest, busy) = crate::sim::analysis::busiest_core(&rep.timeline, m.cores);
        text_notes.push_str(&format!(
            "  {name}: busiest core {busiest} carries {:.0}% of busy time
",
            100.0 * busy / rep.core_busy_ns.iter().sum::<f64>().max(1e-9)
        ));
    }
    ExpOutput {
        id: "abl-hetero",
        title: "Heterogeneous-cores ablation (adaptive multi-core)",
        text: t.render() + &text_notes,
        csv: vec![("abl_hetero".into(), vec!["machine", "tasks", "time_ms"], csv_rows)],
    }
}

/// Adversarial-input ablation: comparisons by (distribution × pivot).
pub fn adversarial(cfg: &ExperimentConfig) -> ExpOutput {
    let n = 2000usize;
    let dists = [
        Distribution::UniformRandom,
        Distribution::Sorted,
        Distribution::Reverse,
        Distribution::FewUnique { k: 4 },
    ];
    let strategies = [
        PivotStrategy::Left,
        PivotStrategy::Mean,
        PivotStrategy::Right,
        PivotStrategy::Random,
        PivotStrategy::MedianOf3,
    ];
    let mut t = AsciiTable::new(
        &format!("abl-adversarial: quicksort comparisons, n={n} (×1000)"),
        &["distribution", "left", "mean", "right", "random", "median3"],
    );
    let mut csv_rows = Vec::new();
    let mut text_notes = String::new();
    for dist in dists {
        let mut row = vec![dist.name()];
        for s in strategies {
            let mut xs = arrays::generate(n, dist, cfg.seed);
            let ops = serial_quicksort(&mut xs, s, cfg.seed);
            row.push(f(ops.comparisons as f64 / 1e3, 1));
            csv_rows.push(vec![dist.name(), s.name().into(), ops.comparisons.to_string()]);
        }
        t.row(row);
    }
    // The headline: left on sorted input is quadratic.
    let mut sorted_in = arrays::generate(n, Distribution::Sorted, cfg.seed);
    let left_sorted = serial_quicksort(&mut sorted_in, PivotStrategy::Left, cfg.seed);
    let mut uni = arrays::generate(n, Distribution::UniformRandom, cfg.seed);
    let left_uni = serial_quicksort(&mut uni, PivotStrategy::Left, cfg.seed);
    text_notes.push_str(&format!(
        "\nleft pivot degenerates on sorted input: {}k comparisons vs {}k on uniform (~{}×)\n\
         — this is why the paper studies random pivots despite their Table 3 cost.\n",
        left_sorted.comparisons / 1000,
        left_uni.comparisons / 1000,
        left_sorted.comparisons / left_uni.comparisons.max(1),
    ));
    ExpOutput {
        id: "abl-adversarial",
        title: "Adversarial-input ablation (pivot robustness)",
        text: t.render() + &text_notes,
        csv: vec![("abl_adversarial".into(), vec!["distribution", "pivot", "comparisons"], csv_rows)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig { reps: 1, ..Default::default() }
    }

    #[test]
    fn grain_sweep_has_interior_minimum_for_matmul() {
        let out = grain(&cfg());
        assert!(out.text.contains("sweep minimum"));
        // The csv has both domains.
        let domains: std::collections::HashSet<_> =
            out.csv[0].2.iter().map(|r| r[0].clone()).collect();
        assert!(domains.contains("matmul") && domains.contains("sort"));
    }

    #[test]
    fn cores_gap_grows() {
        let out = cores(&cfg());
        assert!(out.text.contains("Amdahl"));
        // Small workload must saturate.
        assert!(out.text.contains("saturates"), "{}", out.text);
    }

    #[test]
    fn adversarial_left_blows_up_on_sorted() {
        let out = adversarial(&cfg());
        assert!(out.text.contains("degenerates on sorted"));
    }
}
