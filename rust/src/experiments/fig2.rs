//! Fig 2: matmul runtime, serial vs parallel, across matrix orders.
//!
//! Three curves:
//!
//! * **serial** — `n³ · op_ns` on one core (no overheads, by definition);
//! * **parallel-naive** — the paper's measured platform: one raw thread
//!   per row block with 2012-Windows thread costs
//!   ([`OverheadParams::openmp_2012`]); its crossover with serial lands at
//!   order ≈10³, reproducing the paper's "minimum 1000 and above" claim;
//! * **parallel-managed** — the same machine under OHM's manager (pooled
//!   tasks, overhead-optimal grain, [`OverheadParams::paper_2022`]): the
//!   crossover moves down by an order of magnitude, which is the paper's
//!   thesis — *manage* the overheads and parallelism pays off much earlier.
//!
//! Matmul's task graph is data-independent, so this experiment builds the
//! cost trees directly (no element computation) — the equivalence of tree
//! and real execution is pinned by `dla::matmul` unit tests.

use super::ExpOutput;
use crate::config::ExperimentConfig;
use crate::overhead::{model, OverheadParams, WorkEstimate};
use crate::report::{table::f, AsciiTable, Chart};
use crate::sim::{Machine, Node, SimCtx};

/// Build the row-block fork-join tree of an n×n matmul without computing.
pub fn matmul_tree(n: usize, op_ns: f64, tasks: usize) -> Node {
    let tasks = tasks.clamp(1, n.max(1));
    let chunk_rows = n.div_ceil(tasks);
    let row_bytes = (2 * n * 4) as u64; // A row + C row
    let mut c = SimCtx::new();
    let mut row = 0usize;
    let mut inputs = Vec::new();
    while row < n {
        let rows = chunk_rows.min(n - row);
        inputs.push((rows, rows as u64 * row_bytes));
        row += rows;
    }
    c.fork_each(inputs, |rows, cc| {
        cc.work(rows as f64 * (n * n) as f64 * op_ns, "matmul-chunk");
    });
    c.into_node()
}

/// One Fig-2 row: (order, serial_ms, naive_ms, managed_ms).
pub fn row(n: usize, op_ns: f64, cores: usize) -> (f64, f64, f64) {
    let serial_ns = (n as f64).powi(3) * op_ns;

    // Naive: one task per row on the unmanaged 2012 platform.
    let naive_machine = Machine::new(cores, OverheadParams::openmp_2012());
    let naive = naive_machine.run(&matmul_tree(n, op_ns, n), false);

    // Managed: pooled tasks, grain chosen by the manager.
    let params = OverheadParams::paper_2022();
    let est = WorkEstimate::fully_parallel(serial_ns, (2 * n * n * 4) as u64);
    let (tasks, _) = model::best_grain(&params, &est, cores, 64 * cores);
    let managed_machine = Machine::new(cores, params);
    let managed = managed_machine.run(&matmul_tree(n, op_ns, tasks), false);

    (serial_ns / 1e6, naive.makespan_ns / 1e6, managed.makespan_ns / 1e6)
}

pub fn run(cfg: &ExperimentConfig) -> ExpOutput {
    let op_ns = 1.0; // calibrated per-multiply-add cost (paper scale)
    let mut t = AsciiTable::new(
        "Figure 2 (data): matmul runtime by matrix order, ms (virtual, 4-core sim)",
        &["order", "serial", "parallel-naive(2012)", "parallel-managed(OHM)"],
    );
    let mut chart = Chart::new("Figure 2: serial vs parallel matmul", "order", "time ms");
    let mut rows = Vec::new();
    let (mut s_pts, mut n_pts, mut m_pts) = (Vec::new(), Vec::new(), Vec::new());
    let mut crossover_naive = None;
    let mut crossover_managed = None;
    for &n in &cfg.matmul_orders {
        let (s, nv, mg) = row(n, op_ns, cfg.cores);
        if nv < s && crossover_naive.is_none() {
            crossover_naive = Some(n);
        }
        if mg < s && crossover_managed.is_none() {
            crossover_managed = Some(n);
        }
        t.row(vec![n.to_string(), f(s, 3), f(nv, 3), f(mg, 3)]);
        rows.push(vec![n.to_string(), f(s, 4), f(nv, 4), f(mg, 4)]);
        s_pts.push((n as f64, s));
        n_pts.push((n as f64, nv));
        m_pts.push((n as f64, mg));
    }
    chart.series("serial", s_pts);
    chart.series("naive", n_pts);
    chart.series("managed", m_pts);
    let mut text = t.render();
    text.push('\n');
    text.push_str(&chart.render());
    text.push_str(&format!(
        "\ncrossover (parallel beats serial): naive at order {} — paper claims ≥1000; \
         managed at order {} — the gain from overhead management.\n",
        crossover_naive.map_or("none".into(), |n| n.to_string()),
        crossover_managed.map_or("none".into(), |n| n.to_string()),
    ));
    ExpOutput {
        id: "fig2",
        title: "Fig 2: matmul serial vs parallel across orders",
        text,
        csv: vec![(
            "fig2_matmul".into(),
            vec!["order", "serial_ms", "naive_ms", "managed_ms"],
            rows,
        )],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_crossover_near_paper_threshold() {
        // Scan a fine grid: the serial/naive crossover must land in
        // [500, 1500] — the paper's "minimum 1000 and above" band.
        let mut crossover = None;
        for n in (100..=2000).step_by(50) {
            let (s, nv, _) = row(n, 1.0, 4);
            if nv < s {
                crossover = Some(n);
                break;
            }
        }
        let c = crossover.expect("naive parallel must eventually win");
        assert!((500..=1500).contains(&c), "naive crossover at {c}");
    }

    #[test]
    fn managed_crossover_much_earlier() {
        let mut crossover = None;
        for n in (8..=1024).step_by(8) {
            let (s, _, mg) = row(n, 1.0, 4);
            if mg < s {
                crossover = Some(n);
                break;
            }
        }
        let c = crossover.expect("managed parallel must win");
        assert!(c <= 256, "managed crossover at {c} — should be far below 1000");
    }

    #[test]
    fn large_order_speedup_approaches_cores() {
        let (s, _, mg) = row(2048, 1.0, 4);
        let speedup = s / mg;
        assert!(speedup > 2.0 && speedup <= 4.0, "speedup {speedup}");
    }

    #[test]
    fn tree_work_is_exact() {
        let tree = matmul_tree(100, 2.0, 7);
        assert!((tree.total_work_ns() - 100.0f64.powi(3) * 2.0).abs() < 1e-3);
        assert_eq!(tree.spawn_count(), 7);
    }

    #[test]
    fn run_produces_full_sweep() {
        let cfg = ExperimentConfig {
            matmul_orders: vec![64, 128],
            ..Default::default()
        };
        let out = run(&cfg);
        assert_eq!(out.csv[0].2.len(), 2);
        assert!(out.text.contains("crossover"));
    }
}
