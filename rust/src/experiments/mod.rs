//! Experiment runners — one per paper table/figure plus ablations
//! (DESIGN.md §5 experiment index).
//!
//! Each runner produces an [`ExpOutput`]: a console rendering (tables,
//! charts, diagrams) plus CSV series, saved under the config's `out_dir`.
//! All numeric experiments run on the simulated machine with
//! paper-calibrated overheads — deterministic, reproducible (see
//! DESIGN.md §Substitutions).

pub mod ablations;
pub mod fig2;
pub mod paper_text;
pub mod table3;

use crate::config::ExperimentConfig;
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

/// One experiment's rendered output.
#[derive(Debug, Clone)]
pub struct ExpOutput {
    pub id: &'static str,
    pub title: &'static str,
    /// Console rendering.
    pub text: String,
    /// CSV artifacts: (file stem, headers, rows).
    pub csv: Vec<(String, Vec<&'static str>, Vec<Vec<String>>)>,
}

/// All experiment ids, in presentation order.
pub const ALL: &[&str] = &[
    "table1", "fig1", "fig2", "fig3", "table2", "fig4", "table3", "fig5",
    "abl-grain", "abl-cores", "abl-adversarial", "abl-hetero",
];

/// Run one experiment by id.
pub fn run(id: &str, cfg: &ExperimentConfig) -> Result<ExpOutput> {
    Ok(match id {
        "table1" => paper_text::table1(cfg),
        "table2" => paper_text::table2(cfg),
        "fig1" => paper_text::fig1(),
        "fig3" => paper_text::fig3(),
        "fig4" => paper_text::fig4(),
        "fig2" => fig2::run(cfg),
        "table3" => table3::run_table(cfg),
        "fig5" => table3::run_fig5(cfg),
        "abl-grain" => ablations::grain(cfg),
        "abl-cores" => ablations::cores(cfg),
        "abl-adversarial" => ablations::adversarial(cfg),
        "abl-hetero" => ablations::hetero(cfg),
        _ => bail!("unknown experiment {id:?}; known: {ALL:?}"),
    })
}

/// Run every experiment.
pub fn run_all(cfg: &ExperimentConfig) -> Result<Vec<ExpOutput>> {
    ALL.iter().map(|id| run(id, cfg)).collect()
}

/// Persist an output under `dir`: `<id>.txt` plus each CSV.
pub fn save(out: &ExpOutput, dir: &Path) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::new();
    let txt = dir.join(format!("{}.txt", out.id));
    std::fs::write(&txt, &out.text)?;
    paths.push(txt);
    for (stem, headers, rows) in &out.csv {
        let p = dir.join(format!("{stem}.csv"));
        crate::report::csv::write_csv(&p, headers, rows)?;
        paths.push(p);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            matmul_orders: vec![16, 32, 64],
            sort_sizes: vec![200, 400],
            reps: 1,
            ..Default::default()
        }
    }

    #[test]
    fn unknown_id_rejected() {
        assert!(run("nope", &tiny_cfg()).is_err());
    }

    #[test]
    fn qualitative_experiments_run() {
        for id in ["table1", "table2", "fig1", "fig3", "fig4"] {
            let out = run(id, &tiny_cfg()).unwrap();
            assert!(!out.text.is_empty(), "{id}");
        }
    }

    #[test]
    fn save_writes_files() {
        let out = run("table1", &tiny_cfg()).unwrap();
        let dir = std::env::temp_dir().join("ohm-exp-save-test");
        let paths = save(&out, &dir).unwrap();
        assert!(paths[0].exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
