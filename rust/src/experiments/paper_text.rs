//! Qualitative paper artifacts (Table 1, Table 2, Fig 1, Fig 3, Fig 4) as
//! experiment outputs, parameterized by the live overhead model.

use super::ExpOutput;
use crate::config::ExperimentConfig;
use crate::report::paper;
use crate::sort::SortCostModel;

pub fn table1(cfg: &ExperimentConfig) -> ExpOutput {
    ExpOutput {
        id: "table1",
        title: "Table 1: matmul serial vs parallel scope analysis",
        text: paper::table1(&cfg.params(), cfg.cores, 1.0),
        csv: vec![],
    }
}

pub fn table2(cfg: &ExperimentConfig) -> ExpOutput {
    ExpOutput {
        id: "table2",
        title: "Table 2: parametric analysis for parallel quicksort",
        text: paper::table2(&cfg.params(), cfg.cores, &SortCostModel::paper_2022()),
        csv: vec![],
    }
}

pub fn fig1() -> ExpOutput {
    ExpOutput { id: "fig1", title: "Fig 1: overhead analysis & management (matmul)", text: paper::fig1(), csv: vec![] }
}

pub fn fig3() -> ExpOutput {
    ExpOutput { id: "fig3", title: "Fig 3: serial quicksort algorithm", text: paper::fig3(), csv: vec![] }
}

pub fn fig4() -> ExpOutput {
    ExpOutput { id: "fig4", title: "Fig 4: parallel quicksort workflow", text: paper::fig4(), csv: vec![] }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_emit_text() {
        let cfg = ExperimentConfig::default();
        assert!(table1(&cfg).text.contains("Order of matrix"));
        assert!(table2(&cfg).text.contains("Pivot"));
        assert!(fig1().text.contains("FORK-JOIN SWITCH"));
        assert!(fig3().text.contains("QUICKSORT"));
        assert!(fig4().text.contains("master"));
    }
}
