//! Table 3 / Fig 5: quicksort serial vs parallel across pivot strategies.
//!
//! Grid: element counts (paper: 1000, 1100, 1500, 2000) × {serial,
//! parallel-left, parallel-mean, parallel-right, parallel-random} on the
//! 4-core simulated machine with [`SortCostModel::paper_2022`]. Values are
//! virtual milliseconds, averaged over `reps` seeds.
//!
//! Paper shapes pinned by tests: every deterministic parallel pivot beats
//! serial for n ≥ 1000; random is the slowest parallel variant (it pays
//! the locked-`rand()` selection cost); the serial/parallel gap widens
//! with n.

use super::ExpOutput;
use crate::config::ExperimentConfig;
use crate::exec::ExecCtx;
use crate::report::{table::f, AsciiTable, Chart};
use crate::sort::{parallel::run_with_model, PivotStrategy, SortCostModel};
use crate::workload::arrays;

/// Mean virtual ms for one (n, column) cell over `reps` seeds.
fn cell_ms(n: usize, strategy: Option<PivotStrategy>, cfg: &ExperimentConfig) -> f64 {
    let model = SortCostModel::paper_2022();
    let mut total = 0.0;
    for rep in 0..cfg.reps {
        let seed = cfg.seed.wrapping_add(rep as u64 * 7919);
        let mut xs = arrays::uniform_i64(n, seed);
        let t = match strategy {
            None => {
                let ctx = ExecCtx::serial();
                run_with_model(&mut xs, PivotStrategy::Left, &ctx, &model, seed)
            }
            Some(s) => {
                let ctx = ExecCtx::simulated(cfg.cores, cfg.params());
                run_with_model(&mut xs, s, &ctx, &model, seed)
            }
        };
        total += t.virtual_ns.expect("virtual time") / 1e6;
    }
    total / cfg.reps as f64
}

/// The full grid as (n, serial, left, mean, right, random) rows.
pub fn grid(cfg: &ExperimentConfig) -> Vec<(usize, [f64; 5])> {
    cfg.sort_sizes
        .iter()
        .map(|&n| {
            (
                n,
                [
                    cell_ms(n, None, cfg),
                    cell_ms(n, Some(PivotStrategy::Left), cfg),
                    cell_ms(n, Some(PivotStrategy::Mean), cfg),
                    cell_ms(n, Some(PivotStrategy::Right), cfg),
                    cell_ms(n, Some(PivotStrategy::Random), cfg),
                ],
            )
        })
        .collect()
}

const HEADERS: [&str; 6] =
    ["elements", "serial", "parallel left", "parallel mean", "parallel right", "parallel random"];

pub fn run_table(cfg: &ExperimentConfig) -> ExpOutput {
    let g = grid(cfg);
    let mut t = AsciiTable::new(
        "Table 3: Comparative results of serial to parallel quicksort (virtual ms, 4-core sim)",
        &HEADERS,
    );
    let mut rows = Vec::new();
    for (n, cells) in &g {
        let mut row = vec![n.to_string()];
        row.extend(cells.iter().map(|&v| f(v, 3)));
        t.row(row.clone());
        rows.push(row);
    }
    let mut text = t.render();
    // The paper's own reference values, for side-by-side shape comparison.
    let mut p = AsciiTable::new("Paper's Table 3 (reference, their units)", &HEADERS);
    for (n, vals) in [
        (1000, [2.246, 1.4, 1.247, 1.37, 2.293]),
        (1100, [2.403, 1.57, 1.714, 1.68, 2.512]),
        (1500, [3.682, 1.65, 1.839, 1.932, 2.824]),
        (2000, [3.838, 2.074, 1.933, 2.151, 3.136]),
    ] {
        let mut row = vec![n.to_string()];
        row.extend(vals.iter().map(|&v: &f64| f(v, 3)));
        p.row(row);
    }
    text.push('\n');
    text.push_str(&p.render());
    ExpOutput {
        id: "table3",
        title: "Table 3: quicksort serial vs parallel by pivot strategy",
        text,
        csv: vec![("table3_quicksort".into(), HEADERS.to_vec(), rows)],
    }
}

pub fn run_fig5(cfg: &ExperimentConfig) -> ExpOutput {
    let g = grid(cfg);
    let mut chart =
        Chart::new("Figure 5: quicksort runtimes by pivot strategy", "elements", "time ms");
    let series_names = ["serial", "par-left", "par-mean", "par-right", "par-random"];
    for (i, name) in series_names.iter().enumerate() {
        chart.series(name, g.iter().map(|(n, c)| (*n as f64, c[i])).collect());
    }
    let mut rows = Vec::new();
    for (n, cells) in &g {
        let mut row = vec![n.to_string()];
        row.extend(cells.iter().map(|&v| f(v, 4)));
        rows.push(row);
    }
    ExpOutput {
        id: "fig5",
        title: "Fig 5: graphical form of Table 3",
        text: chart.render(),
        csv: vec![("fig5_quicksort_series".into(), HEADERS.to_vec(), rows)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig { reps: 3, ..Default::default() }
    }

    #[test]
    fn paper_shapes_hold() {
        let g = grid(&cfg());
        for (n, c) in &g {
            let [serial, left, mean, right, _random] = *c;
            // Deterministic parallel pivots beat serial at every n ≥ 1000.
            assert!(left < serial, "n={n}: left {left} !< serial {serial}");
            assert!(mean < serial, "n={n}: mean {mean} !< serial {serial}");
            assert!(right < serial, "n={n}: right {right} !< serial {serial}");
        }
        // Random is the slowest parallel variant in aggregate (and the
        // paper's per-n claim holds at the endpoints; mid-sizes can flip
        // on unlucky left-pivot trees, as any single measurement could).
        let mean_of = |i: usize| g.iter().map(|(_, c)| c[i]).sum::<f64>() / g.len() as f64;
        let (l, m, r, rnd) = (mean_of(1), mean_of(2), mean_of(3), mean_of(4));
        assert!(rnd > l && rnd > m && rnd > r, "random {rnd} vs l={l} m={m} r={r}");
        let endpoints = [&g[0], &g[g.len() - 1]];
        for (n, c) in endpoints {
            assert!(c[4] > c[2] && c[4] > c[3], "n={n}: random must be slowest: {c:?}");
        }
        // Gap grows with n: speedup(serial/mean) at max n > at min n.
        let first = &g[0];
        let last = &g[g.len() - 1];
        assert!(
            last.1[0] / last.1[2] > first.1[0] / first.1[2] * 0.95,
            "speedup should not shrink with n: {:?} vs {:?}",
            first,
            last
        );
    }

    #[test]
    fn random_near_or_above_serial_at_1000() {
        // Paper: 2.293 (random) vs 2.246 (serial) at n=1000 — random
        // roughly cancels the parallel gain at the smallest size.
        let g = grid(&cfg());
        let (_, c) = g.iter().find(|(n, _)| *n == 1000).unwrap();
        assert!(c[4] > 0.8 * c[0], "random {} should be near serial {}", c[4], c[0]);
    }

    #[test]
    fn outputs_render() {
        let small = ExperimentConfig { sort_sizes: vec![500, 1000], reps: 1, ..Default::default() };
        let t = run_table(&small);
        assert!(t.text.contains("Table 3"));
        assert!(t.text.contains("Paper's Table 3"));
        assert_eq!(t.csv[0].2.len(), 2);
        let f5 = run_fig5(&small);
        assert!(f5.text.contains("legend"));
    }
}
