//! # OHM — Overhead Management in Multi-Core Environment
//!
//! Production-shaped reproduction of *"Overhead Management in Multi-Core
//! Environment"* (Shrawankar & Joshi, CS.DC 2022) as a three-layer
//! Rust + JAX + Pallas framework.
//!
//! The paper's thesis: adding cores does not speed anything up unless the
//! overheads of parallelism — **thread creation**, **synchronization**,
//! **inter-core communication**, and **data distribution** — are identified
//! "to the root level" and managed, by switching between serial and parallel
//! execution (fork-join) with master-slave data distribution. OHM makes that
//! methodology executable:
//!
//! * [`pool`] — a from-scratch work-stealing fork-join thread pool (the
//!   paper's OpenMP "parallel sections" substitute), fully instrumented.
//! * [`sim`] — a deterministic discrete-event multicore simulator: the
//!   evaluation testbed. It executes the same task DAGs as the real pool but
//!   charges calibrated overhead costs against a virtual clock, which is how
//!   the paper's crossovers are reproduced on any host (see DESIGN.md
//!   §Substitutions).
//! * [`overhead`] — the paper's contribution as code: an analytic overhead
//!   model (α spawn, β sync, γ message, δ byte), a calibrator, a per-run
//!   overhead ledger, and an adaptive manager that decides serial-vs-parallel
//!   and picks grain sizes.
//! * [`dla`] / [`sort`] — the two evaluated domains: matrix multiplication
//!   (serial, blocked, master-slave parallel, simulated, XLA-offloaded) and
//!   quicksort (four pivot strategies × serial/parallel/simulated, plus
//!   mergesort / samplesort / bitonic baselines).
//! * [`runtime`] — PJRT/XLA execution of AOT-compiled JAX+Pallas artifacts
//!   (`artifacts/*.hlo.txt`); Python never runs on the request path.
//! * [`coordinator`] — the serving layer: concurrent TCP front end with
//!   sharded per-shape-class dispatch lanes (work stealing, DRAIN rolling
//!   restarts), overhead-aware backend policy, cross-connection shape
//!   batching, SLO-driven adaptive admission
//!   ([`coordinator::admission`]), and digest-backed telemetry. The wire
//!   protocol is documented in `docs/PROTOCOL.md`, the data flow in
//!   `docs/ARCHITECTURE.md`.
//! * [`net`] — vendored epoll/eventfd substrate (raw FFI, no crates.io
//!   dependency) behind the coordinator's `--io reactor` event-driven
//!   connection layer: poller, line/write buffers, and the
//!   exactly-once-wake outbox.
//! * [`experiments`] / [`report`] — one runner per paper table/figure
//!   (Table 1–3, Fig 1–5) plus ablations, with ASCII/CSV emitters.
//! * [`bench`], [`prop`], [`cli`], [`config`], [`stats`], [`workload`],
//!   [`util`] — in-repo substrates for criterion / proptest / clap / serde,
//!   which are unavailable in this offline build (DESIGN.md §2).
//!   [`stats::digest`] adds the fixed-memory streaming quantile digest
//!   behind serving percentiles and adaptive admission.
//!
//! ## Quickstart
//!
//! ```no_run
//! use ohm::exec::ExecCtx;
//! use ohm::overhead::OverheadParams;
//! use ohm::sort::{parallel_quicksort, PivotStrategy};
//! use ohm::workload::arrays;
//!
//! let mut data = arrays::uniform_i64(100_000, 42);
//! let ctx = ExecCtx::simulated(4, OverheadParams::paper_2022());
//! let rep = parallel_quicksort(&mut data, PivotStrategy::Mean, &ctx);
//! assert!(data.windows(2).all(|w| w[0] <= w[1]));
//! println!("virtual time: {} µs, spawns: {}", rep.time_us(), rep.ledger.spawns);
//! ```

// Lint wall. The CI lint job runs clippy with `-D warnings`, which
// elevates these to errors there: every public type is debuggable
// (operational types get manual `finish_non_exhaustive()` impls — their
// fields are locks, cells, and closures), unsafe operations stay
// explicit even inside `unsafe fn`, and identifiers stay ASCII.
#![warn(missing_debug_implementations)]
#![warn(unsafe_op_in_unsafe_fn)]
#![deny(non_ascii_idents)]
#![deny(macro_use_extern_crate)]

pub mod util;
pub mod stats;
pub mod workload;
pub mod prop;
pub mod bench;
pub mod pool;
pub mod sim;
pub mod overhead;
pub mod exec;
pub mod dla;
pub mod sort;
pub mod runtime;
pub mod net;
pub mod coordinator;
pub mod report;
pub mod config;
pub mod experiments;
pub mod cli;
