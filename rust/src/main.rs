//! `ohm` — launcher binary for the OHM framework.
//!
//! See `ohm help` (or `cli::USAGE`) for the command surface; DESIGN.md §5
//! maps each paper table/figure to `ohm experiment <id>`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match ohm::cli::run(&argv) {
        Ok(text) => print!("{text}"),
        Err(e) => {
            eprintln!("ohm: error: {e:#}");
            std::process::exit(1);
        }
    }
}
