//! Pure per-connection buffer state machines for the reactor: no
//! sockets, no syscalls — just bytes in, lines/flushes out — so the
//! split-at-every-boundary property tests (`tests/prop_connstate.rs`)
//! can drive them exhaustively without a kernel in the loop.
//!
//! [`LineBuf`] reassembles the line protocol across arbitrary read
//! fragmentation; [`WriteBuf`] holds the unflushed tail of replies for
//! a slow-reading peer and meters further request processing through
//! [`WriteBuf::accepting`] — the reactor stops parsing new requests
//! (and stops reading the socket) while a connection's pending writes
//! exceed [`WBUF_SOFT_MAX`], so a wedged client bounds its own memory
//! instead of blocking a reactor thread.

use std::collections::VecDeque;
use std::io;

/// Pending-write soft cap, per connection: above this, the reactor
/// defers further request processing until `EPOLLOUT` drains the
/// backlog. A soft cap — one in-flight reply may push past it — so the
/// hard bound is `WBUF_SOFT_MAX` + the largest single reply (a STATS
/// block, a few KiB).
pub const WBUF_SOFT_MAX: usize = 64 * 1024;

/// Longest accepted request line (bytes, newline exclusive). The
/// protocol's longest legal request is tens of bytes; a peer that
/// streams this much without a newline is not speaking it, and the
/// reactor closes the connection rather than buffering without bound.
pub const LINE_MAX: usize = 4 * 1024;

/// Incremental line reassembly: bytes from nonblocking reads go in,
/// complete `\n`-terminated lines come out, partial tails persist
/// across any split. Byte-for-byte equivalent to `BufRead::read_line`
/// on the whole stream (the property tests pin this).
#[derive(Debug, Default)]
pub struct LineBuf {
    buf: Vec<u8>,
    /// Scan resume point: bytes before this are known newline-free.
    scanned: usize,
}

impl LineBuf {
    pub fn new() -> LineBuf {
        LineBuf::default()
    }

    /// Append one read's worth of bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Buffered bytes not yet returned as a line.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Next complete line, newline stripped (lossy UTF-8, like the
    /// threaded reader's `read_line` + `trim` pipeline the caller
    /// applies on top). `None` while only a partial line is buffered.
    pub fn next_line(&mut self) -> Option<String> {
        let pos = self.buf[self.scanned..].iter().position(|&b| b == b'\n')?;
        let pos = self.scanned + pos;
        let line = String::from_utf8_lossy(&self.buf[..pos]).into_owned();
        self.buf.drain(..=pos);
        self.scanned = 0;
        Some(line)
    }

    /// True when a complete line is buffered — [`LineBuf::next_line`]
    /// would return `Some` — without extracting it. Advances the scan
    /// frontier on `false`, like `next_line`'s miss path.
    pub fn has_line(&mut self) -> bool {
        match self.buf[self.scanned..].iter().position(|&b| b == b'\n') {
            Some(_) => true,
            None => {
                self.scanned = self.buf.len();
                false
            }
        }
    }

    /// Drain the unterminated tail as a final line (lossy UTF-8). The
    /// EOF rule: `read_line` on the threaded path returns a trailing
    /// partial line as `Ok(n > 0)` when the stream ends without a
    /// newline, and answers it — the reactor calls this at EOF so both
    /// modes agree. `None` when nothing is buffered.
    pub fn take_tail(&mut self) -> Option<String> {
        if self.buf.is_empty() {
            return None;
        }
        let line = String::from_utf8_lossy(&self.buf).into_owned();
        self.buf.clear();
        self.scanned = 0;
        Some(line)
    }

    /// True when the partial tail exceeds [`LINE_MAX`] with no newline
    /// in sight — the protective-close condition.
    pub fn overflowed(&mut self) -> bool {
        if self.buf.len() <= LINE_MAX {
            return false;
        }
        // Remember the scan frontier so repeated overflow checks and
        // `next_line` calls stay O(new bytes), not O(buffer).
        match self.buf[self.scanned..].iter().position(|&b| b == b'\n') {
            Some(_) => false,
            None => {
                self.scanned = self.buf.len();
                true
            }
        }
    }
}

/// Pending reply bytes for one connection, flushed opportunistically
/// and on `EPOLLOUT`. FIFO over a `VecDeque` so partial flushes pop
/// from the front without compaction bookkeeping.
#[derive(Debug, Default)]
pub struct WriteBuf {
    buf: VecDeque<u8>,
}

impl WriteBuf {
    pub fn new() -> WriteBuf {
        WriteBuf::default()
    }

    /// Queue reply bytes (already newline-terminated by the caller).
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend(bytes);
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Unflushed bytes.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Backpressure gate: may the connection process another request?
    /// False once the pending tail passes [`WBUF_SOFT_MAX`].
    pub fn accepting(&self) -> bool {
        self.buf.len() < WBUF_SOFT_MAX
    }

    /// Write as much as the sink takes. `Ok(true)` = drained,
    /// `Ok(false)` = sink is full (`WouldBlock`; re-arm `EPOLLOUT`),
    /// `Err` = the connection is dead.
    pub fn flush_into(&mut self, w: &mut impl io::Write) -> io::Result<bool> {
        while !self.buf.is_empty() {
            let (front, _) = self.buf.as_slices();
            match w.write(front) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "peer accepts no bytes",
                    ))
                }
                Ok(n) => {
                    self.buf.drain(..n);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_reassemble_across_any_split() {
        let input = b"PING\nSORT 300 7\n\nQUIT\n";
        let whole = {
            let mut lb = LineBuf::new();
            lb.extend(input);
            std::iter::from_fn(move || lb.next_line()).collect::<Vec<_>>()
        };
        assert_eq!(whole, vec!["PING", "SORT 300 7", "", "QUIT"]);
        // Byte-at-a-time must agree.
        let mut lb = LineBuf::new();
        let mut lines = Vec::new();
        for b in input {
            lb.extend(&[*b]);
            while let Some(l) = lb.next_line() {
                lines.push(l);
            }
        }
        assert_eq!(lines, whole);
        assert_eq!(lb.pending(), 0);
    }

    #[test]
    fn partial_tail_survives_until_its_newline() {
        let mut lb = LineBuf::new();
        lb.extend(b"SORT 10");
        assert_eq!(lb.next_line(), None);
        assert_eq!(lb.pending(), 7);
        lb.extend(b"0 42\nPI");
        assert_eq!(lb.next_line().as_deref(), Some("SORT 100 42"));
        assert_eq!(lb.next_line(), None);
        lb.extend(b"NG\n");
        assert_eq!(lb.next_line().as_deref(), Some("PING"));
    }

    #[test]
    fn take_tail_mirrors_read_line_at_eof() {
        let mut lb = LineBuf::new();
        lb.extend(b"PING\nSTATS");
        assert_eq!(lb.next_line().as_deref(), Some("PING"));
        assert!(!lb.has_line());
        assert_eq!(lb.take_tail().as_deref(), Some("STATS"));
        assert_eq!(lb.take_tail(), None, "tail drains exactly once");
        assert_eq!(lb.pending(), 0);
        // A terminated stream leaves no tail.
        lb.extend(b"QUIT\n");
        assert!(lb.has_line());
        assert_eq!(lb.next_line().as_deref(), Some("QUIT"));
        assert_eq!(lb.take_tail(), None);
    }

    #[test]
    fn overflow_trips_only_without_a_newline() {
        let mut lb = LineBuf::new();
        lb.extend(&vec![b'x'; LINE_MAX + 1]);
        assert!(lb.overflowed(), "newline-free tail past LINE_MAX");
        let mut ok = LineBuf::new();
        ok.extend(&vec![b'y'; LINE_MAX + 1]);
        ok.extend(b"\n");
        assert!(!ok.overflowed(), "a terminated line is extractable, not an overflow");
        assert_eq!(ok.next_line().map(|l| l.len()), Some(LINE_MAX + 1));
    }

    /// A sink that takes `cap` bytes per write, then `WouldBlock`s.
    struct Throttled {
        taken: Vec<u8>,
        budget: usize,
    }

    impl io::Write for Throttled {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.budget == 0 {
                return Err(io::ErrorKind::WouldBlock.into());
            }
            let n = buf.len().min(self.budget);
            self.budget -= n;
            self.taken.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn partial_flush_keeps_order_and_reports_backpressure() {
        let mut wb = WriteBuf::new();
        wb.push(b"OK one\n");
        wb.push(b"OK two\n");
        let mut sink = Throttled { taken: Vec::new(), budget: 9 };
        assert!(!wb.flush_into(&mut sink).unwrap(), "sink stalled mid-reply");
        assert_eq!(wb.pending(), 5);
        sink.budget = usize::MAX;
        assert!(wb.flush_into(&mut sink).unwrap());
        assert_eq!(sink.taken, b"OK one\nOK two\n");
        assert!(wb.is_empty());
    }

    #[test]
    fn accepting_gate_closes_past_the_soft_cap() {
        let mut wb = WriteBuf::new();
        assert!(wb.accepting());
        wb.push(&vec![0u8; WBUF_SOFT_MAX - 1]);
        assert!(wb.accepting(), "one under the cap still accepts");
        wb.push(&[0]);
        assert!(!wb.accepting(), "at the cap the gate closes");
    }
}
