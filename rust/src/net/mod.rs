//! Vendored, offline-green event-driven IO substrate for the
//! coordinator's reactor connection layer (`--io reactor`).
//!
//! The paper's serving-edge overhead is thread-per-connection: every
//! idle client used to cost a blocked reader thread. This module is
//! the replacement's foundation — a minimal epoll/eventfd wrapper in
//! the same spirit as the `rust/vendor/` shims (raw `extern "C"`
//! declarations, no crates.io dependency; see DESIGN.md §2):
//!
//! * [`sys`] — the unsafe surface: raw `epoll_create1` / `epoll_ctl` /
//!   `epoll_wait` / `eventfd` / `fcntl` externs behind safe,
//!   errno-checked wrappers. The static analyzer's `unsafe` pass
//!   baselines every site here.
//! * [`poller`] — [`Poller`] (owned epoll instance, token-addressed
//!   readiness via `poll_io`) and [`EventFd`] (nonblocking cross-thread
//!   wake).
//! * [`conn`] — pure per-connection state: [`LineBuf`] (incremental
//!   line reassembly across partial reads) and [`WriteBuf`]
//!   (pending-reply backpressure with the [`conn::WBUF_SOFT_MAX`]
//!   gate).
//! * [`outbox`] — [`Outbox`], the mutex+eventfd batch handoff used for
//!   dispatcher→reactor completions and accept→reactor connection
//!   adoption, signaling exactly once per empty→non-empty batch.
//!
//! The reactor event loop itself lives with the serving layer
//! (`coordinator::server`), which composes these pieces; nothing in
//! this module knows about the wire protocol.
//!
//! Non-Linux targets compile all of this, but every fd-producing entry
//! point returns [`std::io::ErrorKind::Unsupported`] — the serving
//! layer then refuses `--io reactor` and the default threaded path
//! (pure `std`) carries on.

pub mod conn;
pub mod outbox;
pub mod poller;
pub mod sys;

pub use conn::{LineBuf, WriteBuf};
pub use outbox::Outbox;
pub use poller::{Event, EventFd, Interest, Poller};

/// Whether this build target has the reactor's kernel substrate
/// (epoll + eventfd). Tests use this to skip reactor cases instead of
/// failing them on exotic hosts.
pub fn supported() -> bool {
    cfg!(target_os = "linux")
}
