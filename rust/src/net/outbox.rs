//! Cross-thread handoff into a reactor: a mutex-guarded batch plus an
//! [`EventFd`] wake, signaled exactly once per empty→non-empty
//! transition.
//!
//! This is the reply path's message-passing half (the paper's
//! inter-core *communication* overhead, made explicit and countable):
//! dispatcher threads [`push`](Outbox::push) completed results, the
//! owning reactor hears one `EPOLLIN` edge on the eventfd and
//! [`drain`](Outbox::drain)s the whole batch. Pushes onto an already
//! non-empty outbox add **no** syscall — the pending wake covers them —
//! so a burst of N completions costs one wakeup, not N.
//!
//! The same shape carries new connections from the accept loop into a
//! reactor (`Outbox<TcpStream>`), so both handoffs share one audited
//! discipline: the mutex guards only the `Vec` push/swap, never a
//! syscall — the eventfd write happens strictly after the guard drops.

use super::poller::EventFd;
use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A wake-once batch queue. `T` is the payload (completions, accepted
/// sockets); the consumer owns the eventfd registration.
pub struct Outbox<T> {
    items: Mutex<Vec<T>>,
    wake: EventFd,
    /// Eventfd signal edges issued, for the exactly-once-per-batch
    /// property test and the STATS wakeup counter.
    signals: AtomicU64,
}

impl<T> std::fmt::Debug for Outbox<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Outbox").finish_non_exhaustive()
    }
}

impl<T> Outbox<T> {
    /// Fails only where eventfds do not exist (non-Linux), which is
    /// exactly where the reactor is unavailable.
    pub fn new() -> io::Result<Outbox<T>> {
        Ok(Outbox { items: Mutex::new(Vec::new()), wake: EventFd::new()?, signals: AtomicU64::new(0) })
    }

    /// The wake fd's owner-side handle, for epoll registration.
    pub fn wake_fd(&self) -> &EventFd {
        &self.wake
    }

    /// Queue one item; signal the consumer only on the empty→non-empty
    /// edge. The guard is dropped before the eventfd write, so no lock
    /// is ever held across a syscall.
    pub fn push(&self, item: T) {
        let was_empty = {
            let mut g = self.items.lock().unwrap_or_else(|p| p.into_inner());
            let was_empty = g.is_empty();
            g.push(item);
            was_empty
        };
        if was_empty {
            self.signal();
        }
    }

    /// Wake the consumer without queueing anything — the shutdown /
    /// drain nudge (the consumer rechecks its exit conditions on any
    /// wake, spurious included).
    pub fn signal(&self) {
        self.signals.fetch_add(1, Ordering::Relaxed);
        self.wake.signal();
    }

    /// Take the whole pending batch and reset the wake level. The
    /// eventfd is drained *before* the swap: a push racing in after the
    /// swap sees an empty vec and re-signals, so its batch is never
    /// silently stranded.
    pub fn drain(&self) -> Vec<T> {
        self.wake.drain();
        std::mem::take(&mut *self.items.lock().unwrap_or_else(|p| p.into_inner()))
    }

    /// Total signal edges issued so far.
    pub fn signals(&self) -> u64 {
        self.signals.load(Ordering::Relaxed)
    }
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;

    #[test]
    fn one_signal_per_batch_not_per_item() {
        let ob: Outbox<u32> = Outbox::new().unwrap();
        ob.push(1);
        ob.push(2);
        ob.push(3);
        assert_eq!(ob.signals(), 1, "pushes 2 and 3 ride the pending wake");
        assert_eq!(ob.drain(), vec![1, 2, 3]);
        ob.push(4);
        assert_eq!(ob.signals(), 2, "a fresh batch re-signals");
        assert_eq!(ob.drain(), vec![4]);
        assert!(ob.drain().is_empty(), "drain on empty is a quiet no-op");
        assert_eq!(ob.signals(), 2);
    }

    #[test]
    fn cross_thread_batch_arrives_with_one_wake() {
        use std::sync::Arc;
        let ob: Arc<Outbox<usize>> = Arc::new(Outbox::new().unwrap());
        let producers: Vec<_> = (0..4)
            .map(|i| {
                let ob = Arc::clone(&ob);
                std::thread::spawn(move || {
                    for j in 0..25 {
                        ob.push(i * 25 + j);
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        let mut got = Vec::new();
        while got.len() < 100 {
            got.extend(ob.drain());
        }
        got.sort_unstable();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
        assert!(ob.signals() <= 100, "never more than one signal per push");
    }
}
