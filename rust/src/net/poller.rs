//! Safe epoll + eventfd wrappers over [`super::sys`].
//!
//! [`Poller`] owns one epoll instance; registrations carry a caller
//! token (`u64`) that comes back verbatim in each [`Event`], so the
//! reactor maps readiness to connections without any fd→state table of
//! its own. The readiness wait is deliberately named `poll_io` — the
//! static lock analyzer treats `.wait(`-family calls as condvar waits,
//! and this is not one.
//!
//! [`EventFd`] is the cross-thread wakeup primitive: dispatchers and
//! the drain path `signal()` it, the owning reactor registers it for
//! `EPOLLIN` and `drain()`s it on wake. Nonblocking on both ends, so a
//! signal never stalls the signaling thread.

use super::sys::{self, RawFd};
use std::io;
use std::time::Duration;

/// What a registration wants to hear about. Read interest implies
/// peer-hangup notification (`EPOLLRDHUP`), so a half-closed idle
/// connection still wakes its reactor.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub fn readable() -> Interest {
        Interest { readable: true, writable: false }
    }

    fn bits(self) -> u32 {
        let mut bits = 0;
        if self.readable {
            bits |= sys::EPOLLIN | sys::EPOLLRDHUP;
        }
        if self.writable {
            bits |= sys::EPOLLOUT;
        }
        bits
    }
}

/// One readiness report: the registration's token plus decoded bits.
/// Error states surface as `hangup` — the reactor's close path handles
/// both identically (read to EOF, drop the connection).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

/// An owned epoll instance. `!Clone`; drop closes the epoll fd.
#[derive(Debug)]
pub struct Poller {
    epfd: RawFd,
}

/// `epoll_wait` output buffer width per call — a bound on events
/// *per wake*, not on registrations; level-triggered epoll re-reports
/// anything still ready on the next call.
const EVENTS_PER_WAKE: usize = 64;

impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { epfd: sys::epoll_create()? })
    }

    /// Register `fd` with `token`. The fd stays owned by the caller.
    pub fn add(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_ADD, fd, interest.bits(), token)
    }

    /// Re-arm an existing registration with a new interest mask.
    pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_MOD, fd, interest.bits(), token)
    }

    /// Deregister `fd`. Errors are ignored by design: the common caller
    /// is a close path where the kernel may already have dropped the
    /// registration with the last duplicate of the fd.
    pub fn remove(&self, fd: RawFd) {
        let _ = sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, fd, 0, 0);
    }

    /// Wait for readiness, replacing `out`'s contents with the ready
    /// set. `None` blocks indefinitely; `Some(d)` wakes after `d` even
    /// if nothing is ready (returning an empty set). Spurious wakes
    /// (`EINTR`) also return an empty set.
    pub fn poll_io(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<usize> {
        out.clear();
        let timeout_ms = match timeout {
            None => -1,
            // Round up so a 1µs timeout still sleeps, and saturate into
            // the C int domain.
            Some(d) => d.as_millis().max(1).min(i32::MAX as u128) as i32,
        };
        let mut buf = [sys::EpollEvent::empty(); EVENTS_PER_WAKE];
        let n = sys::epoll_wait(self.epfd, &mut buf, timeout_ms)?;
        for ev in &buf[..n] {
            // Copy out of the (packed on x86-64) ABI struct before use.
            let (bits, token) = (ev.events, ev.data);
            out.push(Event {
                token,
                readable: bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0,
                writable: bits & sys::EPOLLOUT != 0,
                hangup: bits & (sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP) != 0,
            });
        }
        Ok(n)
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        sys::close_fd(self.epfd);
    }
}

/// An owned eventfd in nonblocking mode; drop closes it.
#[derive(Debug)]
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        Ok(EventFd { fd: sys::eventfd_create()? })
    }

    /// The raw fd, for epoll registration.
    pub fn raw(&self) -> RawFd {
        self.fd
    }

    /// Wake whoever has this fd registered. Best-effort and
    /// nonblocking: a saturated counter already means a wake is
    /// pending, and a closed fd means the listener is gone — neither
    /// is actionable by the signaler.
    pub fn signal(&self) {
        let _ = sys::eventfd_signal(self.fd);
    }

    /// Reset the pending-wake level. Called by the owning reactor at
    /// the top of each wake so the next `signal()` edge is observable.
    pub fn drain(&self) {
        let _ = sys::eventfd_drain(self.fd);
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        sys::close_fd(self.fd);
    }
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;

    #[test]
    fn eventfd_wake_is_level_until_drained() {
        let poller = Poller::new().unwrap();
        let efd = EventFd::new().unwrap();
        poller.add(efd.raw(), 42, Interest::readable()).unwrap();
        let mut events = Vec::new();
        poller.poll_io(&mut events, Some(Duration::from_millis(1))).unwrap();
        assert!(events.is_empty(), "no signal yet");
        efd.signal();
        poller.poll_io(&mut events, Some(Duration::from_millis(1000))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 42);
        assert!(events[0].readable);
        // Level-triggered: still ready until drained.
        poller.poll_io(&mut events, Some(Duration::from_millis(1000))).unwrap();
        assert_eq!(events.len(), 1);
        efd.drain();
        poller.poll_io(&mut events, Some(Duration::from_millis(1))).unwrap();
        assert!(events.is_empty(), "drained: level cleared");
    }

    #[test]
    fn modify_switches_interest() {
        let poller = Poller::new().unwrap();
        let efd = EventFd::new().unwrap();
        efd.signal();
        poller.add(efd.raw(), 1, Interest::default()).unwrap();
        let mut events = Vec::new();
        poller.poll_io(&mut events, Some(Duration::from_millis(1))).unwrap();
        assert!(events.is_empty(), "empty interest mask hears nothing");
        poller.modify(efd.raw(), 1, Interest::readable()).unwrap();
        poller.poll_io(&mut events, Some(Duration::from_millis(1000))).unwrap();
        assert_eq!(events.len(), 1, "re-armed registration reports the pending level");
    }
}
