//! Raw Linux syscall bindings for the event-driven connection layer.
//!
//! Same vendoring policy as the `rust/vendor/` shims and the PJRT
//! `dlopen` loader: no crates.io dependency, just the handful of
//! `extern "C"` declarations the reactor needs — `epoll_create1`,
//! `epoll_ctl`, `epoll_wait`, `eventfd`, `fcntl`, plus raw fd
//! `read`/`write`/`close`. Everything is wrapped in safe functions
//! returning `io::Result` (errno is read via
//! `io::Error::last_os_error`), so `unsafe` stays confined to this
//! file and each site carries its own safety argument.
//!
//! On non-Linux targets every entry point compiles but returns
//! [`std::io::ErrorKind::Unsupported`]; callers degrade to the
//! blocking threaded IO path (`--io threads`), which uses only the
//! standard library.

use std::io;
use std::os::raw::c_int;

/// Raw file descriptor. Deliberately our own alias (not
/// `std::os::fd::RawFd`) so this module compiles on every target.
pub type RawFd = c_int;

// Event bits (uapi/linux/eventpoll.h).
pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

// epoll_ctl ops.
pub const EPOLL_CTL_ADD: c_int = 1;
pub const EPOLL_CTL_DEL: c_int = 2;
pub const EPOLL_CTL_MOD: c_int = 3;

// Creation flags (x86-64/aarch64 generic values).
pub const EPOLL_CLOEXEC: c_int = 0x80000;
pub const EFD_CLOEXEC: c_int = 0x80000;
pub const EFD_NONBLOCK: c_int = 0x800;

// fcntl.
pub const F_GETFL: c_int = 3;
pub const F_SETFL: c_int = 4;
pub const O_NONBLOCK: c_int = 0x800;

/// Kernel ABI for one epoll event. Packed on x86-64 (the kernel
/// declares the struct `__attribute__((packed))` there); naturally
/// aligned elsewhere. Fields are `Copy`, and callers copy them out
/// rather than taking references into the (possibly packed) struct.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Debug, Clone, Copy)]
pub struct EpollEvent {
    /// `EPOLLIN | EPOLLOUT | …` readiness bits.
    pub events: u32,
    /// Caller-chosen token, returned verbatim with each event.
    pub data: u64,
}

impl EpollEvent {
    /// Zeroed event, used to size the `epoll_wait` output buffer.
    pub fn empty() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

#[cfg(target_os = "linux")]
mod ffi {
    use super::EpollEvent;
    use std::os::raw::{c_int, c_uint, c_void};

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn eventfd(initval: c_uint, flags: c_int) -> c_int;
        pub fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
        pub fn close(fd: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    }
}

#[cfg(not(target_os = "linux"))]
fn unsupported() -> io::Error {
    io::Error::new(io::ErrorKind::Unsupported, "reactor IO requires Linux (epoll/eventfd)")
}

/// `epoll_create1(EPOLL_CLOEXEC)`.
#[cfg(target_os = "linux")]
pub fn epoll_create() -> io::Result<RawFd> {
    // Safety: no pointer arguments; the kernel validates the flags.
    let fd = unsafe { ffi::epoll_create1(EPOLL_CLOEXEC) };
    if fd < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(fd)
    }
}

#[cfg(not(target_os = "linux"))]
pub fn epoll_create() -> io::Result<RawFd> {
    Err(unsupported())
}

/// `epoll_ctl` with an interest mask + token (`ADD`/`MOD`), or
/// deregistration (`DEL`, where the event argument is ignored).
#[cfg(target_os = "linux")]
pub fn epoll_ctl(epfd: RawFd, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
    let mut ev = EpollEvent { events, data: token };
    // Safety: `ev` is a valid, live epoll_event for the duration of the
    // call; the kernel copies it before returning (and ignores it for
    // EPOLL_CTL_DEL).
    let rc = unsafe { ffi::epoll_ctl(epfd, op, fd, &mut ev) };
    if rc < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(())
    }
}

#[cfg(not(target_os = "linux"))]
pub fn epoll_ctl(_epfd: RawFd, _op: c_int, _fd: RawFd, _events: u32, _token: u64) -> io::Result<()> {
    Err(unsupported())
}

/// `epoll_wait` into `out`, returning the number of ready events.
/// `timeout_ms < 0` blocks indefinitely. `EINTR` is reported as zero
/// events (a spurious wake), not an error.
#[cfg(target_os = "linux")]
pub fn epoll_wait(epfd: RawFd, out: &mut [EpollEvent], timeout_ms: c_int) -> io::Result<usize> {
    if out.is_empty() {
        return Ok(0);
    }
    // Safety: `out` is a valid, writable buffer of `out.len()` events;
    // the kernel writes at most `maxevents` entries into it.
    let rc = unsafe { ffi::epoll_wait(epfd, out.as_mut_ptr(), out.len() as c_int, timeout_ms) };
    if rc < 0 {
        let err = io::Error::last_os_error();
        if err.kind() == io::ErrorKind::Interrupted {
            return Ok(0);
        }
        return Err(err);
    }
    Ok(rc as usize)
}

#[cfg(not(target_os = "linux"))]
pub fn epoll_wait(_epfd: RawFd, _out: &mut [EpollEvent], _timeout_ms: c_int) -> io::Result<usize> {
    Err(unsupported())
}

/// `eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC)`: a nonblocking wakeup
/// counter usable as an epoll registration target.
#[cfg(target_os = "linux")]
pub fn eventfd_create() -> io::Result<RawFd> {
    // Safety: no pointer arguments; the kernel validates the flags.
    let fd = unsafe { ffi::eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) };
    if fd < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(fd)
    }
}

#[cfg(not(target_os = "linux"))]
pub fn eventfd_create() -> io::Result<RawFd> {
    Err(unsupported())
}

/// Add one to an eventfd's counter (the wakeup edge). A full counter
/// (`EAGAIN`) means a wake is already pending, which is exactly the
/// semantic we want — report success.
#[cfg(target_os = "linux")]
pub fn eventfd_signal(fd: RawFd) -> io::Result<()> {
    let one: u64 = 1;
    // Safety: the buffer is 8 valid bytes, the size eventfd requires.
    let rc = unsafe {
        ffi::write(fd, (&one as *const u64).cast(), std::mem::size_of::<u64>())
    };
    if rc < 0 {
        let err = io::Error::last_os_error();
        if err.kind() == io::ErrorKind::WouldBlock {
            return Ok(());
        }
        return Err(err);
    }
    Ok(())
}

#[cfg(not(target_os = "linux"))]
pub fn eventfd_signal(_fd: RawFd) -> io::Result<()> {
    Err(unsupported())
}

/// Consume an eventfd's pending counter (level reset). `EAGAIN`
/// (nothing pending) is success: the fd was already quiet.
#[cfg(target_os = "linux")]
pub fn eventfd_drain(fd: RawFd) -> io::Result<()> {
    let mut counter: u64 = 0;
    // Safety: the buffer is 8 valid, writable bytes.
    let rc = unsafe {
        ffi::read(fd, (&mut counter as *mut u64).cast(), std::mem::size_of::<u64>())
    };
    if rc < 0 {
        let err = io::Error::last_os_error();
        if err.kind() == io::ErrorKind::WouldBlock {
            return Ok(());
        }
        return Err(err);
    }
    Ok(())
}

#[cfg(not(target_os = "linux"))]
pub fn eventfd_drain(_fd: RawFd) -> io::Result<()> {
    Err(unsupported())
}

/// Put a raw fd into nonblocking mode via `fcntl(F_GETFL/F_SETFL)` —
/// used on accepted sockets before epoll registration.
#[cfg(target_os = "linux")]
pub fn set_nonblocking(fd: RawFd) -> io::Result<()> {
    // Safety: fcntl with F_GETFL/F_SETFL takes no pointers; an invalid
    // fd is reported through errno, not UB.
    let flags = unsafe { ffi::fcntl(fd, F_GETFL, 0) };
    if flags < 0 {
        return Err(io::Error::last_os_error());
    }
    if flags & O_NONBLOCK != 0 {
        return Ok(());
    }
    // Safety: as above.
    let rc = unsafe { ffi::fcntl(fd, F_SETFL, flags | O_NONBLOCK) };
    if rc < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

#[cfg(not(target_os = "linux"))]
pub fn set_nonblocking(_fd: RawFd) -> io::Result<()> {
    Err(unsupported())
}

/// Close a raw fd owned by this module (epoll instances, eventfds).
/// Sockets stay owned by their `TcpStream`s and are never closed here.
#[cfg(target_os = "linux")]
pub fn close_fd(fd: RawFd) {
    // Safety: callers only pass fds this module created and owns;
    // double-close is excluded by the owning types' Drop impls.
    let _ = unsafe { ffi::close(fd) };
}

#[cfg(not(target_os = "linux"))]
pub fn close_fd(_fd: RawFd) {}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;

    #[test]
    fn eventfd_signal_then_drain_round_trips() {
        let fd = eventfd_create().unwrap();
        eventfd_signal(fd).unwrap();
        eventfd_signal(fd).unwrap();
        eventfd_drain(fd).unwrap();
        // Drained: a second drain is the EAGAIN fast path, still Ok.
        eventfd_drain(fd).unwrap();
        close_fd(fd);
    }

    #[test]
    fn epoll_sees_a_signaled_eventfd() {
        let ep = epoll_create().unwrap();
        let fd = eventfd_create().unwrap();
        epoll_ctl(ep, EPOLL_CTL_ADD, fd, EPOLLIN, 7).unwrap();
        let mut out = [EpollEvent::empty(); 4];
        assert_eq!(epoll_wait(ep, &mut out, 0).unwrap(), 0, "quiet eventfd: no events");
        eventfd_signal(fd).unwrap();
        assert_eq!(epoll_wait(ep, &mut out, 1000).unwrap(), 1);
        let (events, data) = (out[0].events, out[0].data);
        assert_ne!(events & EPOLLIN, 0);
        assert_eq!(data, 7, "token round-trips");
        close_fd(fd);
        close_fd(ep);
    }
}
