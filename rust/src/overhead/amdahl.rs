//! Amdahl's-law analysis — quantifying the paper's central criticism.
//!
//! "Only increasing the number of employed cores cannot optimize the
//! results": the ideal Amdahl speedup `1 / ((1-f) + f/p)` ignores the
//! overhead terms, which *grow* with `p`. This module computes both curves
//! so the `abl-cores` ablation can plot the widening gap (cf. Yavits et
//! al., the paper's ref [3]).

use super::model::{self, OverheadParams, WorkEstimate};

/// Ideal Amdahl speedup for parallel fraction `f` on `p` cores.
pub fn ideal_speedup(f: f64, p: usize) -> f64 {
    assert!((0.0..=1.0).contains(&f) && p >= 1);
    1.0 / ((1.0 - f) + f / p as f64)
}

/// Overhead-adjusted speedup predicted by the model for the best grain.
pub fn adjusted_speedup(params: &OverheadParams, est: &WorkEstimate, p: usize) -> f64 {
    let (_, tp) = model::best_grain(params, est, p, 64 * p);
    model::predict_serial_ns(est) / tp
}

/// One row of the cores ablation: `(p, ideal, adjusted)`.
pub fn sweep(params: &OverheadParams, est: &WorkEstimate, cores: &[usize]) -> Vec<(usize, f64, f64)> {
    cores
        .iter()
        .map(|&p| (p, ideal_speedup(est.parallel_fraction, p), adjusted_speedup(params, est, p)))
        .collect()
}

/// The core count beyond which adding cores *slows the region down*
/// (returns `None` if no maximum within `max_p`). This is the paper's
/// "challenge to Amdahl's law" made concrete.
pub fn saturation_point(params: &OverheadParams, est: &WorkEstimate, max_p: usize) -> Option<usize> {
    let mut best = (1usize, adjusted_speedup(params, est, 1));
    for p in 2..=max_p {
        let s = adjusted_speedup(params, est, p);
        if s > best.1 {
            best = (p, s);
        }
    }
    if best.0 < max_p {
        Some(best.0)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_limits() {
        assert!((ideal_speedup(1.0, 8) - 8.0).abs() < 1e-12);
        assert!((ideal_speedup(0.0, 8) - 1.0).abs() < 1e-12);
        // f=0.5: asymptote at 2.
        assert!(ideal_speedup(0.5, 1_000_000) < 2.0);
        assert!(ideal_speedup(0.5, 1_000_000) > 1.99);
    }

    #[test]
    fn adjusted_below_ideal_with_overheads() {
        let est = WorkEstimate::fully_parallel(1e8, 1 << 20);
        let params = OverheadParams::paper_2022();
        for p in [2, 4, 8, 16] {
            let adj = adjusted_speedup(&params, &est, p);
            let idl = ideal_speedup(1.0, p);
            assert!(adj < idl, "p={p}: adjusted {adj} !< ideal {idl}");
            assert!(adj > 0.0);
        }
    }

    #[test]
    fn gap_widens_with_cores() {
        let est = WorkEstimate::fully_parallel(1e8, 1 << 20);
        let params = OverheadParams::paper_2022();
        let rows = sweep(&params, &est, &[2, 4, 8, 16]);
        let gaps: Vec<f64> = rows.iter().map(|(_, i, a)| i - a).collect();
        assert!(gaps.windows(2).all(|w| w[1] >= w[0] - 1e-9), "gaps {gaps:?}");
    }

    #[test]
    fn small_work_saturates_early() {
        // 200µs of work with paper overheads: speedup peaks at small p.
        let est = WorkEstimate::fully_parallel(200_000.0, 4096);
        let params = OverheadParams::paper_2022();
        let sat = saturation_point(&params, &est, 64);
        assert!(sat.is_some(), "tiny region must saturate");
        assert!(sat.unwrap() <= 8, "saturation at {sat:?}");
    }

    #[test]
    fn huge_work_does_not_saturate_within_16() {
        let est = WorkEstimate::fully_parallel(1e11, 0);
        let params = OverheadParams::paper_2022();
        assert_eq!(saturation_point(&params, &est, 16), None);
    }
}
