//! Calibration: fit the overhead model's constants on the host.
//!
//! Three micro-benchmarks on the *real* pool produce overhead observations;
//! a least-squares fit recovers (α, β, γ). δ comes from a memcpy bandwidth
//! probe. A fourth probe measures the per-element cost of the serial
//! compute kernels, which converts domain work counts (n³ multiply-adds,
//! n·log n comparisons) into nanoseconds for `WorkEstimate`s.
//!
//! On hosts where the probes are too noisy (e.g. this 1-core container),
//! [`Calibration::with_fallback`] keeps measured per-element compute costs
//! but uses `OverheadParams::paper_2022()` for α/β/γ/δ — documented in
//! DESIGN.md §Substitutions.

use super::model::OverheadParams;
use crate::pool::metrics::MetricsSnapshot;
use crate::pool::ThreadPool;
use crate::stats;
use crate::util::timer::Stopwatch;

/// Calibration output.
#[derive(Debug, Clone)]
pub struct Calibration {
    pub params: OverheadParams,
    /// Cost of one fused multiply-add in the serial matmul inner loop, ns.
    pub matmul_op_ns: f64,
    /// Cost of one comparison+swap step in serial quicksort, ns.
    pub sort_op_ns: f64,
    /// Whether α/β/γ/δ came from host probes (false ⇒ paper defaults).
    pub probed: bool,
}

impl Calibration {
    /// Quick, deterministic-enough calibration for tests and defaults:
    /// paper overhead constants + synthetic compute costs.
    pub fn paper_defaults() -> Self {
        Calibration {
            params: OverheadParams::paper_2022(),
            matmul_op_ns: 1.0,
            sort_op_ns: 4.0,
            probed: false,
        }
    }

    /// Probe the host. `budget_ms` bounds total probing time.
    #[deprecated(
        since = "0.7.0",
        note = "positional-arg entry point; use `Calibration::with_fallback` (sane-checked) \
                or `Calibration::from_metrics` (recalibrate from measured pool metrics)"
    )]
    pub fn probe(budget_ms: u64) -> Self {
        let mut cal = Self::paper_defaults();
        cal.matmul_op_ns = probe_matmul_op_ns();
        cal.sort_op_ns = probe_sort_op_ns();
        if let Some(params) = probe_overheads(budget_ms) {
            cal.params = params;
            cal.probed = true;
        }
        cal
    }

    /// Recalibrate the overhead constants from a *measured* pool-metrics
    /// delta — the wall-mode bench path: run real work, snapshot the pool
    /// before/after, and rescale the paper constants by the contention
    /// the run actually exhibited. Deterministic for a given snapshot
    /// (no wall clock, no probes), so virtual and wall trajectories stay
    /// comparable:
    ///
    /// * α is inflated by the overflow-inline fraction — tasks executed
    ///   inline because a deque was full mean spawning cost more than
    ///   the uncontended constant assumes;
    /// * γ is inflated by the failed-steal ratio — thieves that probe
    ///   empty deques are inter-core traffic the per-message constant
    ///   never sees;
    /// * β and δ have no event-count analogue in the snapshot and keep
    ///   their calibrated values.
    pub fn from_metrics(delta: &MetricsSnapshot) -> OverheadParams {
        let base = OverheadParams::paper_2022();
        let spawn_contention = if delta.spawns > 0 {
            delta.overflow_inline as f64 / delta.spawns as f64
        } else {
            0.0
        };
        let steal_contention = if delta.steals + delta.failed_steals > 0 {
            delta.failed_steals as f64 / (delta.steals + delta.failed_steals) as f64
        } else {
            0.0
        };
        OverheadParams {
            alpha_spawn_ns: base.alpha_spawn_ns * (1.0 + spawn_contention),
            beta_sync_ns: base.beta_sync_ns,
            gamma_msg_ns: base.gamma_msg_ns * (1.0 + steal_contention),
            delta_byte_ns: base.delta_byte_ns,
        }
    }

    /// Probe, but fall back to paper overhead constants when the host fit
    /// is degenerate (negative or absurd coefficients — typical on a
    /// 1-core container where "parallel" probes never truly overlap).
    pub fn with_fallback(budget_ms: u64) -> Self {
        #[allow(deprecated)] // sane-checked wrapper over the raw probe
        let mut cal = Self::probe(budget_ms);
        let p = cal.params;
        let sane = p.alpha_spawn_ns > 0.0
            && p.beta_sync_ns > 0.0
            && p.gamma_msg_ns >= 0.0
            && p.delta_byte_ns >= 0.0
            && p.alpha_spawn_ns < 10_000_000.0;
        if !sane {
            cal.params = OverheadParams::paper_2022();
            cal.probed = false;
        }
        cal
    }
}

/// Per-element serial matmul cost: time a small ikj kernel.
fn probe_matmul_op_ns() -> f64 {
    let n = 96usize;
    let a = vec![1.000_3f32; n * n];
    let b = vec![0.999_7f32; n * n];
    let mut c = vec![0.0f32; n * n];
    // Warm.
    serial_matmul_probe(&a, &b, &mut c, n);
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let sw = Stopwatch::start();
        serial_matmul_probe(&a, &b, &mut c, n);
        best = best.min(sw.elapsed_ns() as f64);
    }
    std::hint::black_box(&c);
    (best / (n * n * n) as f64).max(0.05)
}

fn serial_matmul_probe(a: &[f32], b: &[f32], c: &mut [f32], n: usize) {
    c.fill(0.0);
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            let (crow, brow) = (&mut c[i * n..(i + 1) * n], &b[k * n..(k + 1) * n]);
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Per-element serial sort cost: time quicksorting a scrambled buffer,
/// divide by n·log₂n.
fn probe_sort_op_ns() -> f64 {
    let n = 64 * 1024usize;
    let mut rng = crate::util::Pcg32::new(0xCA11B);
    let proto: Vec<i64> = (0..n).map(|_| rng.range_i64(-1_000_000, 1_000_000)).collect();
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let mut buf = proto.clone();
        let sw = Stopwatch::start();
        buf.sort_unstable();
        best = best.min(sw.elapsed_ns() as f64);
        std::hint::black_box(&buf);
    }
    (best / (n as f64 * (n as f64).log2())).max(0.1)
}

/// Fit (α, β, γ) from pool micro-benchmarks. Returns `None` when the
/// design matrix is degenerate.
fn probe_overheads(budget_ms: u64) -> Option<OverheadParams> {
    let pool = ThreadPool::new(4);
    let deadline = std::time::Instant::now() + std::time::Duration::from_millis(budget_ms);
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut obs: Vec<f64> = Vec::new();

    // Spawn/sync storms at varying task counts: overhead_time(t) ≈
    // α·t + β·t (+ γ·steals). We record the measured event counts from the
    // pool metrics, which separates the columns.
    for &tasks in &[8usize, 32, 128, 512] {
        if std::time::Instant::now() > deadline {
            break;
        }
        for _rep in 0..5 {
            let before = pool.metrics();
            let sw = Stopwatch::start();
            pool.for_each_index(tasks, |_| {
                std::hint::black_box(0u64);
            });
            let elapsed = sw.elapsed_ns() as f64;
            let d = pool.metrics().delta_since(&before);
            rows.push(vec![
                (d.spawns + d.injected) as f64,
                d.latch_waits as f64,
                (d.steals + d.injected) as f64,
            ]);
            obs.push(elapsed);
        }
    }
    if rows.len() < 8 {
        return None;
    }
    let x = stats::least_squares(&rows, &obs);
    let (alpha, beta, gamma) = (x[0], x[1], x[2]);
    // δ: memcpy bandwidth probe.
    let delta = probe_copy_byte_ns();
    Some(OverheadParams {
        alpha_spawn_ns: alpha,
        beta_sync_ns: beta,
        gamma_msg_ns: gamma,
        delta_byte_ns: delta,
    })
}

fn probe_copy_byte_ns() -> f64 {
    let n = 8 << 20; // 8 MiB
    let src = vec![0xABu8; n];
    let mut dst = vec![0u8; n];
    dst.copy_from_slice(&src); // warm
    let sw = Stopwatch::start();
    for _ in 0..4 {
        dst.copy_from_slice(&src);
        std::hint::black_box(&mut dst);
    }
    (sw.elapsed_ns() as f64 / (4 * n) as f64).max(0.001)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_are_sane() {
        let c = Calibration::paper_defaults();
        assert!(!c.probed);
        assert!(c.params.alpha_spawn_ns > 0.0);
        assert!(c.matmul_op_ns > 0.0 && c.sort_op_ns > 0.0);
    }

    #[test]
    fn matmul_probe_positive_and_bounded() {
        let ns = probe_matmul_op_ns();
        assert!(ns > 0.01 && ns < 1000.0, "matmul op = {ns}ns");
    }

    #[test]
    fn sort_probe_positive_and_bounded() {
        let ns = probe_sort_op_ns();
        assert!(ns > 0.01 && ns < 1000.0, "sort op = {ns}ns");
    }

    #[test]
    fn copy_probe_positive() {
        let d = probe_copy_byte_ns();
        assert!(d > 0.0 && d < 100.0, "delta = {d}ns/B");
    }

    #[test]
    fn from_metrics_uncontended_run_keeps_paper_constants() {
        let quiet = MetricsSnapshot { spawns: 100, executed: 100, ..Default::default() };
        assert_eq!(Calibration::from_metrics(&quiet), OverheadParams::paper_2022());
        // A zero delta (no parallel work measured) is also the baseline.
        assert_eq!(
            Calibration::from_metrics(&MetricsSnapshot::default()),
            OverheadParams::paper_2022()
        );
    }

    #[test]
    fn from_metrics_contention_inflates_alpha_and_gamma() {
        let base = OverheadParams::paper_2022();
        let contended = MetricsSnapshot {
            spawns: 100,
            executed: 150,
            overflow_inline: 50, // half the spawns overflowed inline
            steals: 10,
            failed_steals: 30, // 75% of steal attempts found nothing
            ..Default::default()
        };
        let p = Calibration::from_metrics(&contended);
        assert!((p.alpha_spawn_ns - base.alpha_spawn_ns * 1.5).abs() < 1e-9);
        assert!((p.gamma_msg_ns - base.gamma_msg_ns * 1.75).abs() < 1e-9);
        assert_eq!(p.beta_sync_ns, base.beta_sync_ns, "β has no snapshot analogue");
        assert_eq!(p.delta_byte_ns, base.delta_byte_ns, "δ has no snapshot analogue");
    }

    #[test]
    fn with_fallback_always_usable() {
        let c = Calibration::with_fallback(200);
        assert!(c.params.alpha_spawn_ns > 0.0);
        assert!(c.params.beta_sync_ns > 0.0);
        assert!(c.params.delta_byte_ns >= 0.0);
        // Manager built from it must produce a finite cutoff.
        let m = crate::overhead::Manager::new(c.params, 4);
        let cut = m.serial_cutoff_ns(1.0, 1e12);
        assert!(cut.is_finite() && cut > 0.0);
    }
}
