//! The consumable cost-model API: the paper's overhead model packaged
//! for *callers that schedule work*, not just for offline analysis.
//!
//! Historically the analytic model lived as free functions in
//! [`model`](super::model) ("calibrate offline, never read again"): the
//! bench sweep and the per-region [`Manager`](super::manager::Manager)
//! called them directly, and the serving layer consulted nothing. This
//! module collapses that surface into two consumables:
//!
//! * [`CostModel`] + [`StaticCostModel`] — the trait a scheduling
//!   decision point programs against, with the calibrated-parameter
//!   closed-form evaluation as the canonical implementation. The static
//!   impl delegates to the `model` free functions, so its numbers are
//!   bit-identical to the historical call sites (the committed
//!   `BENCH_*.json` baselines gate this in CI).
//! * [`CostTable`] — a slot-indexed table of per-workload-class costs
//!   refreshed *online*: each completed execution feeds an EWMA of the
//!   observed service time and a prediction-bias correction (the same
//!   0.7/0.3 gain and 0.25–4.0 clamp as `Manager::observe`). The serving
//!   layer maps its `ShapeClass`es onto slots; this module stays
//!   layering-clean by knowing nothing about shape classes.
//!
//! The serving-side wiring (serve-time serial-inline crossover,
//! cost-weighted rebalancing, predictive admission) lives in
//! `coordinator/costmodel.rs`; this module owns the arithmetic.

use super::model::{self, OverheadParams, WorkEstimate};
use std::sync::Mutex;

/// EWMA retention for online refreshes (matches `Manager::observe`).
const EWMA_KEEP: f64 = 0.7;
/// EWMA gain for the newest observation.
const EWMA_GAIN: f64 = 0.3;
/// Bias-ratio clamp: one absurd sample cannot destabilize the policy.
const BIAS_CLAMP: (f64, f64) = (0.25, 4.0);

/// A queryable cost model: everything a scheduling decision point needs
/// to price serial vs parallel execution of an estimated region.
///
/// Object-safe, so serving components can hold `&dyn CostModel` without
/// caring whether the numbers are static (paper calibration) or
/// bias-corrected online estimates.
pub trait CostModel {
    /// The calibrated per-event overhead constants behind the predictions.
    fn params(&self) -> &OverheadParams;

    /// Predicted serial runtime for `est`, ns.
    fn predict_serial_ns(&self, est: &WorkEstimate) -> f64;

    /// Predicted best-grain parallel runtime for `est` on `cores` cores:
    /// `(tasks, ns)` at the canonical task-sweep bound (`64 × cores`,
    /// the same bound the bench sweep and its Python gate mirror use).
    fn predict_parallel_ns(&self, est: &WorkEstimate, cores: usize) -> (usize, f64);

    /// Smallest candidate size whose parallel prediction beats serial,
    /// if any (`est_of` maps a size to its work estimate).
    fn crossover(
        &self,
        cores: usize,
        candidates: &[usize],
        est_of: &dyn Fn(usize) -> WorkEstimate,
    ) -> Option<usize> {
        candidates.iter().copied().find(|&n| {
            let est = est_of(n);
            let (_, tp) = self.predict_parallel_ns(&est, cores);
            tp < self.predict_serial_ns(&est)
        })
    }

    /// Predicted fork-join overhead charge (the α/β/γ/δ sum alone) for
    /// executing `est` at the best grain on `cores` cores, ns — the cost
    /// a below-crossover serial-inline execution *avoids* paying.
    fn overhead_ns(&self, est: &WorkEstimate, cores: usize) -> f64 {
        let (tasks, _) = self.predict_parallel_ns(est, cores);
        let p = cores.max(1);
        let migrations = tasks as f64 * (p.saturating_sub(1)) as f64 / p as f64;
        let bytes_moved = est.dist_bytes as f64 * (p.saturating_sub(1)) as f64 / p as f64;
        let params = self.params();
        params.alpha_spawn_ns * tasks as f64
            + params.beta_sync_ns * tasks as f64
            + params.gamma_msg_ns * migrations
            + params.delta_byte_ns * bytes_moved
    }
}

/// The calibrated closed-form model: a thin, allocation-free wrapper
/// over the [`model`] free functions. This is what `paper_2022` params
/// look like as a [`CostModel`] — deterministic, host-independent, and
/// numerically identical to the historical direct calls (gate-checked
/// via the committed bench baselines).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StaticCostModel {
    params: OverheadParams,
}

impl StaticCostModel {
    pub fn new(params: OverheadParams) -> Self {
        StaticCostModel { params }
    }

    /// The paper-calibrated default.
    pub fn paper_2022() -> Self {
        Self::new(OverheadParams::paper_2022())
    }

    /// Best-grain search with an explicit task-count bound (the
    /// [`Manager`](super::manager::Manager) grain guard needs a custom
    /// bound; the trait method uses the canonical `64 × cores`).
    pub fn best_grain(&self, est: &WorkEstimate, cores: usize, max_tasks: usize) -> (usize, f64) {
        model::best_grain(&self.params, est, cores, max_tasks)
    }
}

impl CostModel for StaticCostModel {
    fn params(&self) -> &OverheadParams {
        &self.params
    }

    fn predict_serial_ns(&self, est: &WorkEstimate) -> f64 {
        model::predict_serial_ns(est)
    }

    fn predict_parallel_ns(&self, est: &WorkEstimate, cores: usize) -> (usize, f64) {
        model::best_grain(&self.params, est, cores, 64 * cores)
    }
}

/// One slot's online state: what the table has learned about a class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassCost {
    /// EWMA of observed service time, ns (0 until the first sample).
    pub observed_ns: f64,
    /// EWMA of observed/predicted ratio, applied as a multiplicative
    /// correction to static parallel predictions (1.0 = model trusted).
    pub bias: f64,
    /// Executions observed for this slot.
    pub samples: u64,
    /// Executions this slot ran serial-inline (below predicted crossover).
    pub inline_serial: u64,
}

impl Default for ClassCost {
    fn default() -> Self {
        ClassCost { observed_ns: 0.0, bias: 1.0, samples: 0, inline_serial: 0 }
    }
}

/// A calibrated, per-class cost table refreshed online from observed
/// timings — the "read it back at serve time" half of the redesign.
///
/// Slots are opaque indices: the caller owns the class → slot mapping
/// (the serving layer uses its `ShapeClass` encoding), which keeps this
/// module free of any serving-layer dependency. Each slot holds its own
/// lock, so concurrent dispatchers observing different classes never
/// contend.
#[derive(Debug)]
pub struct CostTable {
    model: StaticCostModel,
    cores: usize,
    slots: Vec<Mutex<ClassCost>>,
}

impl CostTable {
    pub fn new(slots: usize, params: OverheadParams, cores: usize) -> Self {
        CostTable {
            model: StaticCostModel::new(params),
            cores: cores.max(1),
            slots: (0..slots).map(|_| Mutex::new(ClassCost::default())).collect(),
        }
    }

    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    pub fn cores(&self) -> usize {
        self.cores
    }

    /// The static model the table layers its corrections over.
    pub fn static_model(&self) -> &StaticCostModel {
        &self.model
    }

    /// Feed back one completed execution: EWMA-refresh the observed
    /// service time and, when the static model offered a prediction,
    /// the bias correction. Degenerate inputs are ignored (a 0ns
    /// "observation" is clock noise, not evidence).
    pub fn observe(&self, slot: usize, predicted_ns: f64, actual_ns: f64) {
        if actual_ns <= 0.0 {
            return;
        }
        let mut c = self.slots[slot].lock().unwrap();
        c.observed_ns = if c.samples == 0 {
            actual_ns
        } else {
            EWMA_KEEP * c.observed_ns + EWMA_GAIN * actual_ns
        };
        c.samples += 1;
        if predicted_ns > 0.0 {
            let ratio = (actual_ns / predicted_ns).clamp(BIAS_CLAMP.0, BIAS_CLAMP.1);
            c.bias = EWMA_KEEP * c.bias + EWMA_GAIN * ratio;
        }
    }

    /// Record that a slot's job ran serial-inline on the lane thread.
    pub fn note_inline(&self, slot: usize) {
        self.slots[slot].lock().unwrap().inline_serial += 1;
    }

    /// Point-in-time copy of one slot.
    pub fn snapshot(&self, slot: usize) -> ClassCost {
        *self.slots[slot].lock().unwrap()
    }

    /// Bias-corrected parallel prediction for a slot: the static
    /// best-grain time scaled by the slot's learned bias.
    pub fn predict_parallel_ns(&self, slot: usize, est: &WorkEstimate) -> f64 {
        let (_, tp) = self.model.predict_parallel_ns(est, self.cores);
        tp * self.snapshot(slot).bias
    }

    /// Expected service time for a slot's jobs, ns: the observed EWMA
    /// once samples exist, `None` before (predicting from zero evidence
    /// is how admission governors cause outages).
    pub fn expected_service_ns(&self, slot: usize) -> Option<f64> {
        let c = self.snapshot(slot);
        (c.samples > 0).then_some(c.observed_ns)
    }

    /// Total serial-inline executions across all slots.
    pub fn inline_total(&self) -> u64 {
        self.slots.iter().map(|s| s.lock().unwrap().inline_serial).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(work_ns: f64) -> WorkEstimate {
        WorkEstimate::fully_parallel(work_ns, 0)
    }

    #[test]
    fn static_model_matches_free_functions_exactly() {
        let params = OverheadParams::paper_2022();
        let cm = StaticCostModel::new(params);
        for work in [1e4, 1e6, 1e8, 1e10] {
            let e = est(work);
            assert_eq!(cm.predict_serial_ns(&e), model::predict_serial_ns(&e));
            assert_eq!(cm.predict_parallel_ns(&e, 4), model::best_grain(&params, &e, 4, 256));
        }
        let cands: Vec<usize> = (1..=64).map(|i| i * 50).collect();
        let est_of = |n: usize| est(n as f64 * 10_000.0);
        assert_eq!(
            cm.crossover(4, &cands, &est_of),
            model::crossover(&params, 4, &cands, est_of),
            "trait crossover must reproduce the free-function crossover"
        );
    }

    #[test]
    fn overhead_ns_is_parallel_minus_critical_path() {
        let cm = StaticCostModel::paper_2022();
        let e = est(1e8);
        let (tasks, tp) = cm.predict_parallel_ns(&e, 4);
        let waves = tasks.div_ceil(4) as f64;
        let critical = e.total_work_ns * waves / tasks as f64;
        assert!((cm.overhead_ns(&e, 4) - (tp - critical)).abs() < 1e-6);
    }

    #[test]
    fn table_ewma_converges_after_step_change() {
        let t = CostTable::new(4, OverheadParams::paper_2022(), 4);
        // Regime 1: 100µs observed service time.
        for _ in 0..20 {
            t.observe(1, 0.0, 100_000.0);
        }
        assert!((t.expected_service_ns(1).unwrap() - 100_000.0).abs() < 1.0);
        // Step change: the class suddenly costs 400µs.
        for _ in 0..20 {
            t.observe(1, 0.0, 400_000.0);
        }
        let after = t.expected_service_ns(1).unwrap();
        assert!((after - 400_000.0).abs() < 4_000.0, "EWMA must converge: {after}");
        // Other slots were never touched.
        assert_eq!(t.expected_service_ns(0), None);
    }

    #[test]
    fn table_bias_tracks_misprediction_with_clamp() {
        let t = CostTable::new(2, OverheadParams::paper_2022(), 4);
        for _ in 0..20 {
            t.observe(0, 1000.0, 3000.0); // consistently 3× the prediction
        }
        let b = t.snapshot(0).bias;
        assert!((b - 3.0).abs() < 0.1, "bias {b}");
        t.observe(1, 1.0, 1e12); // absurd outlier: clamped to 4×
        assert!(t.snapshot(1).bias <= EWMA_KEEP + EWMA_GAIN * BIAS_CLAMP.1 + 1e-12);
        // Degenerate observations are ignored entirely.
        t.observe(1, 1000.0, 0.0);
        assert_eq!(t.snapshot(1).samples, 1);
    }

    #[test]
    fn bias_scales_parallel_prediction() {
        let t = CostTable::new(1, OverheadParams::paper_2022(), 4);
        let e = est(1e8);
        let base = t.predict_parallel_ns(0, &e);
        for _ in 0..30 {
            let (_, p) = t.static_model().predict_parallel_ns(&e, 4);
            t.observe(0, p, p * 2.0);
        }
        let corrected = t.predict_parallel_ns(0, &e);
        assert!(corrected > base * 1.8, "learned bias must inflate: {base} → {corrected}");
    }

    #[test]
    fn inline_counts_accumulate_per_slot() {
        let t = CostTable::new(3, OverheadParams::paper_2022(), 4);
        t.note_inline(0);
        t.note_inline(2);
        t.note_inline(2);
        assert_eq!(t.snapshot(0).inline_serial, 1);
        assert_eq!(t.snapshot(2).inline_serial, 2);
        assert_eq!(t.inline_total(), 3);
    }
}
