//! Per-run overhead accounting.
//!
//! A [`Ledger`] records how many of each overhead event *actually happened*
//! during a run — from the pool's metrics (threaded backend) or from the
//! simulator's schedule (simulated backend). The tested invariant
//! (DESIGN.md §7): `OverheadParams::charge(ledger)` reconstructs the
//! simulator's charged overhead exactly, and bounds the threaded backend's
//! measured overhead from below.

use crate::pool::metrics::MetricsSnapshot;

/// Counts of the paper's four overhead classes, plus bookkeeping.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Ledger {
    /// Task/thread creations (α events).
    pub spawns: u64,
    /// Synchronization events: joins, barriers, latch waits (β events).
    pub syncs: u64,
    /// Inter-core messages: steals, migrations, result hand-backs (γ).
    pub messages: u64,
    /// Work-steal migrations specifically (pool deque steals, or serving
    /// batches moved between dispatch lanes). A subset of `messages` —
    /// already priced there by `OverheadParams::charge` — broken out so
    /// lane/core imbalance is visible as its own overhead signal.
    pub steals: u64,
    /// Requests shed by the adaptive admission governor (`ERR
    /// OVERLOADED`): scheduling overhead *managed away* rather than paid.
    /// Each shed is queueing the SLO controller refused to absorb, so it
    /// is accounted here alongside the overheads that were paid — but,
    /// like `queue_ns`, it is bookkeeping that `OverheadParams::charge`
    /// does not price, and it is excluded from `total_events`.
    pub sheds: u64,
    /// Requests served from the warm result cache instead of being
    /// re-executed: redundant-work overhead *managed away* at the root.
    /// Like `sheds`, bookkeeping `OverheadParams::charge` does not
    /// price, excluded from `total_events`, and rendered in summaries
    /// only when nonzero (a cache-less run reads exactly as before).
    pub cache_hits: u64,
    /// Jobs the cost model ran serially inline on the lane thread
    /// because their predicted size sat below the serial/parallel
    /// crossover: fork-join overhead *avoided* rather than paid — the
    /// paper's central trade-off, accounted in the same managed-away
    /// vocabulary as `sheds`/`cache_hits` (unpriced by
    /// `OverheadParams::charge`, excluded from `total_events`, rendered
    /// only when nonzero so cost-model-off output stays byte-identical).
    pub inline_serial: u64,
    /// Faults injected by the deterministic fault harness (`--faults`):
    /// lane kills, wedged clients, dropped replies, stalled dispatch.
    /// Injected failure is overhead *deliberately caused*, so it is
    /// attributed in the same books — but like `sheds` it is
    /// bookkeeping `OverheadParams::charge` does not price, excluded
    /// from `total_events`, and rendered only when nonzero (a
    /// faults-off run reads exactly as before).
    pub faults: u64,
    /// Bytes moved across cores (δ).
    pub bytes: u64,
    /// Time spent waiting in a serving admission queue, ns. Measured (not
    /// modeled), so — like `compute_ns`/`idle_ns` — it is bookkeeping that
    /// `OverheadParams::charge` does not re-price.
    pub queue_ns: u64,
    /// Pure compute time, ns (virtual for sim, estimated for threaded).
    pub compute_ns: u64,
    /// Core-idle time summed over cores, ns (sim only).
    pub idle_ns: u64,
}

impl Ledger {
    /// Build from a pool metrics delta (threaded backend).
    ///
    /// Mapping: every job published for parallel execution is an α event;
    /// every latch wait is a β event; every successful steal and every
    /// injector hop is a γ message.
    pub fn from_metrics(delta: &MetricsSnapshot, bytes_moved: u64) -> Ledger {
        Ledger {
            spawns: delta.spawns + delta.injected,
            syncs: delta.latch_waits,
            messages: delta.steals + delta.injected,
            steals: delta.steals,
            sheds: 0,
            cache_hits: 0,
            inline_serial: 0,
            faults: 0,
            bytes: bytes_moved,
            queue_ns: 0,
            compute_ns: 0,
            idle_ns: 0,
        }
    }

    /// Element-wise sum (aggregate over jobs / repetition runs).
    pub fn merged(&self, other: &Ledger) -> Ledger {
        Ledger {
            spawns: self.spawns + other.spawns,
            syncs: self.syncs + other.syncs,
            messages: self.messages + other.messages,
            steals: self.steals + other.steals,
            sheds: self.sheds + other.sheds,
            cache_hits: self.cache_hits + other.cache_hits,
            inline_serial: self.inline_serial + other.inline_serial,
            faults: self.faults + other.faults,
            bytes: self.bytes + other.bytes,
            queue_ns: self.queue_ns + other.queue_ns,
            compute_ns: self.compute_ns + other.compute_ns,
            idle_ns: self.idle_ns + other.idle_ns,
        }
    }

    /// Total overhead events of all classes (coarse magnitude signal).
    /// `steals` is excluded: each steal is already one of `messages`.
    pub fn total_events(&self) -> u64 {
        self.spawns + self.syncs + self.messages
    }

    /// Human-readable one-liner for reports. `cache_hits=` and
    /// `inline_serial=` appear only when nonzero, so runs without a
    /// result cache or cost model (the defaults) keep their summary
    /// byte-for-byte unchanged.
    pub fn summary(&self) -> String {
        let cache = if self.cache_hits > 0 {
            format!(" cache_hits={}", self.cache_hits)
        } else {
            String::new()
        };
        let inline = if self.inline_serial > 0 {
            format!(" inline_serial={}", self.inline_serial)
        } else {
            String::new()
        };
        let faults = if self.faults > 0 {
            format!(" faults={}", self.faults)
        } else {
            String::new()
        };
        format!(
            "spawns={} syncs={} msgs={} steals={} sheds={}{}{}{} bytes={} queue={}µs compute={}µs idle={}µs",
            self.spawns,
            self.syncs,
            self.messages,
            self.steals,
            self.sheds,
            cache,
            inline,
            faults,
            self.bytes,
            self.queue_ns / 1_000,
            self.compute_ns / 1_000,
            self.idle_ns / 1_000,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_metrics_mapping() {
        let d = MetricsSnapshot {
            spawns: 10,
            executed: 12,
            steals: 3,
            failed_steals: 7,
            injected: 2,
            latch_waits: 5,
            joins: 4,
            overflow_inline: 0,
        };
        let l = Ledger::from_metrics(&d, 640);
        assert_eq!(l.spawns, 12); // 10 deque + 2 injected
        assert_eq!(l.syncs, 5);
        assert_eq!(l.messages, 5); // 3 steals + 2 injector hops
        assert_eq!(l.steals, 3, "steals broken out of the γ messages");
        assert_eq!(l.bytes, 640);
    }

    #[test]
    fn merge_adds_fields() {
        let a = Ledger { spawns: 1, syncs: 2, messages: 3, steals: 8, sheds: 9, cache_hits: 5, inline_serial: 2, faults: 1, bytes: 4, queue_ns: 7, compute_ns: 5, idle_ns: 6 };
        let b = Ledger { spawns: 10, syncs: 20, messages: 30, steals: 80, sheds: 90, cache_hits: 50, inline_serial: 20, faults: 10, bytes: 40, queue_ns: 70, compute_ns: 50, idle_ns: 60 };
        let m = a.merged(&b);
        assert_eq!(
            m,
            Ledger { spawns: 11, syncs: 22, messages: 33, steals: 88, sheds: 99, cache_hits: 55, inline_serial: 22, faults: 11, bytes: 44, queue_ns: 77, compute_ns: 55, idle_ns: 66 }
        );
        assert_eq!(
            m.total_events(),
            66,
            "steals, sheds, cache hits, inline-serial runs, and faults are not double-counted"
        );
    }

    #[test]
    fn summary_contains_fields() {
        let l = Ledger { spawns: 7, steals: 2, sheds: 3, queue_ns: 9_000, ..Default::default() };
        assert!(l.summary().contains("spawns=7"));
        assert!(l.summary().contains("steals=2"));
        assert!(l.summary().contains("sheds=3"));
        assert!(l.summary().contains("queue=9µs"));
    }

    #[test]
    fn summary_shows_cache_hits_only_when_present() {
        let quiet = Ledger { sheds: 3, ..Default::default() };
        assert!(
            !quiet.summary().contains("cache_hits"),
            "cache-less summaries stay byte-identical: {}",
            quiet.summary()
        );
        let warm = Ledger { sheds: 3, cache_hits: 4, ..Default::default() };
        assert!(warm.summary().contains("sheds=3 cache_hits=4"), "{}", warm.summary());
    }

    #[test]
    fn summary_shows_inline_serial_only_when_present() {
        let off = Ledger { sheds: 1, ..Default::default() };
        assert!(
            !off.summary().contains("inline_serial"),
            "cost-model-off summaries stay byte-identical: {}",
            off.summary()
        );
        let on = Ledger { sheds: 1, cache_hits: 2, inline_serial: 7, ..Default::default() };
        assert!(on.summary().contains("cache_hits=2 inline_serial=7"), "{}", on.summary());
    }

    #[test]
    fn summary_shows_faults_only_when_present() {
        let clean = Ledger { sheds: 1, ..Default::default() };
        assert!(
            !clean.summary().contains("faults"),
            "faults-off summaries stay byte-identical: {}",
            clean.summary()
        );
        let chaotic = Ledger { sheds: 1, inline_serial: 2, faults: 3, ..Default::default() };
        assert!(chaotic.summary().contains("inline_serial=2 faults=3"), "{}", chaotic.summary());
    }
}
