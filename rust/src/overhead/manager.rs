//! The adaptive manager: the paper's overhead *management* policy.
//!
//! Given a work estimate for an incoming region, the manager inverts the
//! overhead model to decide:
//!
//! 1. **serial vs parallel** — the fork-join switch ("parallelization if
//!    not implemented properly will definitely appear as an overhead");
//! 2. **grain** — how many tasks to split into, balancing load balance
//!    against α/β/γ charges ("size of problem being solved should be
//!    comparable to the efforts necessary for dividing the tasks").

use super::costmodel::{CostModel, StaticCostModel};
use super::model::{OverheadParams, WorkEstimate};

/// The manager's verdict for one region.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Run serially: predicted parallel time does not beat serial.
    Serial { predicted_ns: f64 },
    /// Run in parallel with `tasks` tasks over `cores` cores.
    Parallel { tasks: usize, cores: usize, predicted_ns: f64, predicted_serial_ns: f64 },
}

impl Decision {
    pub fn is_parallel(&self) -> bool {
        matches!(self, Decision::Parallel { .. })
    }

    pub fn predicted_ns(&self) -> f64 {
        match *self {
            Decision::Serial { predicted_ns } => predicted_ns,
            Decision::Parallel { predicted_ns, .. } => predicted_ns,
        }
    }
}

/// Overhead-aware execution planner, parameterized by machine shape.
#[derive(Debug, Clone)]
pub struct Manager {
    pub params: OverheadParams,
    pub cores: usize,
    /// Do not split below this many tasks' worth of work per task
    /// (guards against pathological estimates); default 1.
    pub min_task_work_ns: f64,
    /// Hysteresis margin: parallel must beat serial by this factor to be
    /// chosen (avoids flapping around the crossover); default 1.0 (off).
    pub margin: f64,
    /// EWMA correction from observed runs (see [`Manager::observe`]).
    bias: f64,
}

impl Manager {
    pub fn new(params: OverheadParams, cores: usize) -> Self {
        Manager { params, cores: cores.max(1), min_task_work_ns: 1.0, margin: 1.0, bias: 1.0 }
    }

    /// Decide how to execute a region with estimate `est`. The numbers
    /// come from the calibrated [`StaticCostModel`] (the same arithmetic
    /// the bench sweep and the serving layer's cost table consume).
    pub fn decide(&self, est: &WorkEstimate) -> Decision {
        let cost = StaticCostModel::new(self.params);
        let serial_ns = cost.predict_serial_ns(est);
        if self.cores == 1 {
            return Decision::Serial { predicted_ns: serial_ns };
        }
        let max_tasks_by_grain =
            ((est.total_work_ns / self.min_task_work_ns).floor() as usize).max(1);
        let max_tasks = (64 * self.cores).min(max_tasks_by_grain.max(self.cores));
        let (tasks, raw_parallel_ns) = cost.best_grain(est, self.cores, max_tasks);
        let parallel_ns = raw_parallel_ns * self.bias;
        if parallel_ns * self.margin < serial_ns {
            Decision::Parallel {
                tasks,
                cores: self.cores,
                predicted_ns: parallel_ns,
                predicted_serial_ns: serial_ns,
            }
        } else {
            Decision::Serial { predicted_ns: serial_ns }
        }
    }

    /// Online refinement: feed back an observed (predicted, actual)
    /// parallel-time pair; the manager maintains an EWMA correction bias
    /// applied to future parallel predictions. This closes the paper's
    /// loop — overheads are not just modeled *a priori* but re-estimated
    /// from the ledger of every run (DESIGN.md §6).
    pub fn observe(&mut self, predicted_ns: f64, actual_ns: f64) {
        if predicted_ns <= 0.0 || actual_ns <= 0.0 {
            return;
        }
        let ratio = (actual_ns / predicted_ns).clamp(0.25, 4.0);
        // EWMA with 0.3 gain: a few observations converge, one outlier
        // does not destabilize the policy.
        self.bias = 0.7 * self.bias + 0.3 * ratio;
    }

    /// Current prediction bias (1.0 = model trusted as-is).
    pub fn bias(&self) -> f64 {
        self.bias
    }

    /// The serial cutoff: largest work size (ns) in `[lo, hi]` for which
    /// the manager still picks serial (bisection; monotone by
    /// `overheads_make_small_problems_lose`).
    pub fn serial_cutoff_ns(&self, lo: f64, hi: f64) -> f64 {
        let mut lo = lo;
        let mut hi = hi;
        for _ in 0..64 {
            let mid = 0.5 * (lo + hi);
            let d = self.decide(&WorkEstimate::fully_parallel(mid, 0));
            if d.is_parallel() {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> Manager {
        Manager::new(OverheadParams::paper_2022(), 4)
    }

    #[test]
    fn small_work_goes_serial_large_goes_parallel() {
        let m = mgr();
        assert!(!m.decide(&WorkEstimate::fully_parallel(10_000.0, 0)).is_parallel());
        assert!(m.decide(&WorkEstimate::fully_parallel(1e9, 0)).is_parallel());
    }

    #[test]
    fn single_core_always_serial() {
        let m = Manager::new(OverheadParams::ideal(), 1);
        assert!(!m.decide(&WorkEstimate::fully_parallel(1e12, 0)).is_parallel());
    }

    #[test]
    fn parallel_prediction_beats_serial_when_chosen() {
        let m = mgr();
        if let Decision::Parallel { predicted_ns, predicted_serial_ns, tasks, cores } =
            m.decide(&WorkEstimate::fully_parallel(1e9, 1 << 20))
        {
            assert!(predicted_ns < predicted_serial_ns);
            assert!(tasks >= cores);
        } else {
            panic!("expected parallel");
        }
    }

    #[test]
    fn cutoff_is_consistent_with_decide() {
        let m = mgr();
        let cut = m.serial_cutoff_ns(1.0, 1e10);
        assert!(cut > 0.0 && cut < 1e10);
        assert!(!m.decide(&WorkEstimate::fully_parallel(cut * 0.9, 0)).is_parallel());
        assert!(m.decide(&WorkEstimate::fully_parallel(cut * 1.2, 0)).is_parallel());
    }

    #[test]
    fn margin_raises_cutoff() {
        let base = mgr();
        let mut cautious = mgr();
        cautious.margin = 2.0;
        let c0 = base.serial_cutoff_ns(1.0, 1e10);
        let c1 = cautious.serial_cutoff_ns(1.0, 1e10);
        assert!(c1 >= c0, "margin must delay the switch: {c0} vs {c1}");
    }

    #[test]
    fn observe_shifts_bias_and_decisions() {
        let mut m = mgr();
        assert!((m.bias() - 1.0).abs() < 1e-12);
        // Pick a work size near the cutoff where parallel barely wins.
        let cut = m.serial_cutoff_ns(1.0, 1e10);
        let est = WorkEstimate::fully_parallel(cut * 1.1, 0);
        assert!(m.decide(&est).is_parallel());
        // Report that parallel consistently ran 3x slower than predicted.
        for _ in 0..10 {
            let p = m.decide(&est).predicted_ns();
            m.observe(p, p * 3.0);
        }
        assert!(m.bias() > 1.5, "bias {}", m.bias());
        assert!(!m.decide(&est).is_parallel(), "borderline region should flip to serial");
        // And accurate feedback pulls it back toward 1.
        for _ in 0..20 {
            m.observe(1000.0, 1000.0);
        }
        assert!((m.bias() - 1.0).abs() < 0.1, "bias {}", m.bias());
    }

    #[test]
    fn observe_ignores_degenerate_inputs_and_clamps() {
        let mut m = mgr();
        m.observe(0.0, 100.0);
        m.observe(100.0, 0.0);
        assert!((m.bias() - 1.0).abs() < 1e-12);
        m.observe(1.0, 1e12); // absurd outlier: clamped to 4x
        assert!(m.bias() <= 0.7 + 0.3 * 4.0 + 1e-12);
    }

    #[test]
    fn distribution_bytes_penalize_parallel() {
        let m = mgr();
        let light = m.decide(&WorkEstimate::fully_parallel(5e6, 0));
        let heavy = m.decide(&WorkEstimate::fully_parallel(5e6, 200 << 20));
        if light.is_parallel() {
            // With 200 MiB to ship, parallel should be predicted slower
            // (or rejected outright).
            match heavy {
                Decision::Serial { .. } => {}
                Decision::Parallel { predicted_ns, .. } => {
                    assert!(predicted_ns > light.predicted_ns());
                }
            }
        }
    }
}
