//! The paper's contribution, made executable: identify the overheads of
//! parallelism *to the root level* and manage them.
//!
//! * [`model`] — analytic overhead model: per-event costs for **thread
//!   creation (α)**, **synchronization (β)**, **inter-core communication
//!   (γ per message, δ per byte)**, and a per-element compute cost; predicts
//!   serial and parallel runtimes and their crossover.
//! * [`ledger`] — per-run accounting of actual overhead events, filled in
//!   by the pool's metrics or the simulator's schedule; reconciling ledger
//!   vs model is a tested invariant.
//! * [`costmodel`] — the consumable scheduling API over the model: the
//!   [`CostModel`] trait (+ [`StaticCostModel`], the calibrated
//!   closed-form impl) and the online per-class [`CostTable`] refreshed
//!   from observed timings — what the serving layer consults at admit,
//!   dispatch, and rebalance time.
//! * [`calibrate`] — fits the model's constants from micro-benchmarks on
//!   the real pool (spawn storms, barrier storms, copy ping-pong) and from
//!   serial kernel timings; falls back to `OverheadParams::paper_2022()`.
//! * [`manager`] — the *management* policy: given a work estimate, decide
//!   serial vs parallel and pick the grain that minimizes predicted time
//!   (the paper's fork-join switching + "size of problem must be comparable
//!   to the efforts necessary for dividing" rule).
//! * [`amdahl`] — Amdahl's-law analyzer quantifying the paper's criticism:
//!   ideal speedup vs overhead-adjusted speedup.

pub mod amdahl;
pub mod calibrate;
pub mod costmodel;
pub mod ledger;
pub mod manager;
pub mod model;

pub use costmodel::{ClassCost, CostModel, CostTable, StaticCostModel};
pub use ledger::Ledger;
pub use manager::{Decision, Manager};
pub use model::{OverheadParams, WorkEstimate};
