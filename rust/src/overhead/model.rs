//! Analytic overhead model.
//!
//! For a fork-join region executed on `p` cores with `s` task spawns, `k`
//! synchronization events, `m` inter-core messages carrying `b` bytes total,
//! and per-core work `W_i` (ns):
//!
//! ```text
//! T_parallel = max_i(W_i) + α·s + β·k + γ·m + δ·b
//! T_serial   = Σ_i W_i
//! ```
//!
//! The paper's qualitative claims fall out quantitatively:
//! * small problems: `α·s + β·k` dominates `Σ W_i / p` ⇒ serial wins;
//! * the crossover size `n*` solves `T_serial(n*) = T_parallel(n*)`;
//! * "only increasing the number of employed cores cannot optimize the
//!   results": `dT/dp < 0` saturates while overhead terms grow with `p`.

use super::ledger::Ledger;

/// Calibrated per-event overhead costs, all in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverheadParams {
    /// Thread/task creation cost per spawn (α).
    pub alpha_spawn_ns: f64,
    /// Synchronization cost per join/barrier event (β).
    pub beta_sync_ns: f64,
    /// Inter-core message cost per migration (γ).
    pub gamma_msg_ns: f64,
    /// Per-byte transfer cost for distributed data (δ).
    pub delta_byte_ns: f64,
}

impl OverheadParams {
    /// Zero overheads — the idealized Amdahl machine.
    pub fn ideal() -> Self {
        OverheadParams { alpha_spawn_ns: 0.0, beta_sync_ns: 0.0, gamma_msg_ns: 0.0, delta_byte_ns: 0.0 }
    }

    /// Defaults calibrated so the 4-core simulator reproduces the *shape*
    /// of the paper's 2022 Windows/OpenMP results (Fig 2 crossover near
    /// order 10^3 work scale; Table 3 serial/parallel gap growing with n).
    /// `overhead::calibrate` refines these on the host when possible.
    pub fn paper_2022() -> Self {
        OverheadParams {
            alpha_spawn_ns: 25_000.0, // thread-pool task dispatch ≈ tens of µs on 2022 desktop
            beta_sync_ns: 8_000.0,
            gamma_msg_ns: 1_200.0,
            delta_byte_ns: 0.25,      // ≈ 4 GB/s effective cross-core copy
        }
    }

    /// The *unmanaged* platform Fig 2's parallel curve was measured on:
    /// raw per-region thread creation (no pool) on a ~2012-era Windows
    /// box — three orders of magnitude costlier per spawn than a pooled
    /// task. With one thread per matrix row (the paper's naive
    /// master-slave distribution) this puts the serial/parallel crossover
    /// at order ≈10³, exactly where the paper's Table 1 places it.
    pub fn openmp_2012() -> Self {
        OverheadParams {
            alpha_spawn_ns: 600_000.0, // CreateThread + first-touch faults
            beta_sync_ns: 120_000.0,   // WaitForMultipleObjects join
            gamma_msg_ns: 15_000.0,
            delta_byte_ns: 1.0,        // ≈1 GB/s effective cross-core copy
        }
    }

    /// Total overhead charge for a ledger of events.
    pub fn charge(&self, ledger: &Ledger) -> f64 {
        self.alpha_spawn_ns * ledger.spawns as f64
            + self.beta_sync_ns * ledger.syncs as f64
            + self.gamma_msg_ns * ledger.messages as f64
            + self.delta_byte_ns * ledger.bytes as f64
    }
}

/// Estimated fork-join region profile, before running it.
#[derive(Debug, Clone, Copy)]
pub struct WorkEstimate {
    /// Total sequential work, ns.
    pub total_work_ns: f64,
    /// Fraction of the work that is parallelizable (Amdahl's `f`).
    pub parallel_fraction: f64,
    /// Bytes that must be distributed to workers.
    pub dist_bytes: u64,
}

impl WorkEstimate {
    pub fn fully_parallel(total_work_ns: f64, dist_bytes: u64) -> Self {
        WorkEstimate { total_work_ns, parallel_fraction: 1.0, dist_bytes }
    }
}

/// Predicted runtime for executing `est` on `p` cores with `tasks` spawned
/// tasks (the grain decision: more tasks ⇒ better balance, more α/γ).
///
/// Balance model: with `t` equal tasks over `p` cores, the longest core
/// runs `ceil(t/p)/t` of the parallel work.
pub fn predict_parallel_ns(params: &OverheadParams, est: &WorkEstimate, p: usize, tasks: usize) -> f64 {
    assert!(p >= 1 && tasks >= 1);
    let par_work = est.total_work_ns * est.parallel_fraction;
    let ser_work = est.total_work_ns - par_work;
    let waves = tasks.div_ceil(p) as f64;
    let critical_path = par_work * waves / tasks as f64;
    // One spawn per task, one sync per task at the join barrier, and one
    // message per task that lands off the master core (fraction (p-1)/p).
    let migrations = tasks as f64 * (p.saturating_sub(1)) as f64 / p as f64;
    let bytes_moved = est.dist_bytes as f64 * (p.saturating_sub(1)) as f64 / p as f64;
    ser_work
        + critical_path
        + params.alpha_spawn_ns * tasks as f64
        + params.beta_sync_ns * tasks as f64
        + params.gamma_msg_ns * migrations
        + params.delta_byte_ns * bytes_moved
}

/// Predicted serial runtime (trivially the total work).
pub fn predict_serial_ns(est: &WorkEstimate) -> f64 {
    est.total_work_ns
}

/// Predicted best parallel time over a task-count sweep; returns
/// `(best_tasks, best_time_ns)`. Task counts tried are multiples of `p`
/// (whole waves) up to `max_tasks`.
pub fn best_grain(params: &OverheadParams, est: &WorkEstimate, p: usize, max_tasks: usize) -> (usize, f64) {
    let mut best = (p, predict_parallel_ns(params, est, p, p));
    let mut tasks = p;
    while tasks <= max_tasks {
        let t = predict_parallel_ns(params, est, p, tasks);
        if t < best.1 {
            best = (tasks, t);
        }
        tasks *= 2;
    }
    best
}

/// Work-size crossover: smallest `n` in `candidates` (ascending work sizes,
/// mapped to estimates by `est_of`) where parallel beats serial, if any.
pub fn crossover<F: Fn(usize) -> WorkEstimate>(
    params: &OverheadParams,
    p: usize,
    candidates: &[usize],
    est_of: F,
) -> Option<usize> {
    candidates.iter().copied().find(|&n| {
        let est = est_of(n);
        let (_, tp) = best_grain(params, &est, p, 64 * p);
        tp < predict_serial_ns(&est)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(work_ns: f64) -> WorkEstimate {
        WorkEstimate::fully_parallel(work_ns, 0)
    }

    #[test]
    fn ideal_machine_matches_amdahl() {
        let p = OverheadParams::ideal();
        let e = est(1_000_000.0);
        let t = predict_parallel_ns(&p, &e, 4, 4);
        assert!((t - 250_000.0).abs() < 1e-6);
    }

    #[test]
    fn serial_fraction_limits_speedup() {
        let p = OverheadParams::ideal();
        let e = WorkEstimate { total_work_ns: 1e6, parallel_fraction: 0.5, dist_bytes: 0 };
        let t = predict_parallel_ns(&p, &e, 1000, 1000);
        assert!(t >= 0.5e6, "Amdahl floor: {t}");
    }

    #[test]
    fn overheads_make_small_problems_lose() {
        let p = OverheadParams::paper_2022();
        // 100µs of work: spawning 4 tasks costs 4·25µs alone.
        let e = est(100_000.0);
        let (_, tp) = best_grain(&p, &e, 4, 64);
        assert!(tp > predict_serial_ns(&e), "parallel must lose on small work");
        // 100ms of work: parallel must win.
        let e = est(100_000_000.0);
        let (_, tp) = best_grain(&p, &e, 4, 64);
        assert!(tp < predict_serial_ns(&e), "parallel must win on large work");
    }

    #[test]
    fn crossover_exists_and_is_monotone_in_overhead() {
        let cands: Vec<usize> = (1..=64).map(|i| i * 50).collect(); // work units
        let est_of = |n: usize| est(n as f64 * 10_000.0);
        let cheap = OverheadParams { alpha_spawn_ns: 1000.0, ..OverheadParams::paper_2022() };
        let costly = OverheadParams::paper_2022();
        let x_cheap = crossover(&cheap, 4, &cands, est_of).expect("cheap crossover");
        let x_costly = crossover(&costly, 4, &cands, est_of).expect("costly crossover");
        assert!(x_cheap <= x_costly, "higher overhead ⇒ later crossover ({x_cheap} vs {x_costly})");
    }

    #[test]
    fn more_tasks_improve_balance_until_overhead_wins() {
        let p = OverheadParams::paper_2022();
        let e = est(1e9);
        let t_coarse = predict_parallel_ns(&p, &e, 4, 4);
        let (best_tasks, t_best) = best_grain(&p, &e, 4, 4096);
        assert!(t_best <= t_coarse);
        // And an absurd task count must be worse than the optimum.
        let t_absurd = predict_parallel_ns(&p, &e, 4, 1 << 20);
        assert!(t_absurd > t_best, "overhead must eventually dominate");
        assert!(best_tasks >= 4);
    }

    #[test]
    fn charge_is_linear_in_events() {
        let p = OverheadParams::paper_2022();
        let l1 = Ledger { spawns: 1, syncs: 2, messages: 3, bytes: 100, ..Default::default() };
        let l2 = Ledger { spawns: 2, syncs: 4, messages: 6, bytes: 200, ..Default::default() };
        assert!((p.charge(&l2) - 2.0 * p.charge(&l1)).abs() < 1e-9);
    }
}
