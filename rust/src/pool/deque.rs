//! Chase–Lev work-stealing deque (fixed capacity, SeqCst orderings).
//!
//! The owner pushes/pops at the *bottom* (LIFO — good locality, depth-first
//! fork-join); thieves steal from the *top* (FIFO — oldest, largest tasks,
//! which is what makes work-stealing's communication overhead logarithmic:
//! exactly the property the paper's master-slave distribution approximates
//! statically).
//!
//! Simplifications vs the full algorithm: fixed capacity (callers fall back
//! to inline execution or the global injector on overflow — see
//! [`super::ThreadPool`]) and SeqCst everywhere (we measure overheads with
//! the ledger/simulator, not by shaving fences; correctness first).

use super::job::JobRef;
use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicIsize, Ordering::SeqCst};
use std::cell::UnsafeCell;

/// Fixed-capacity Chase–Lev deque of [`JobRef`]s.
pub struct Deque {
    top: CachePadded<AtomicIsize>,
    bottom: CachePadded<AtomicIsize>,
    buf: Box<[UnsafeCell<JobRef>]>,
    mask: isize,
}

impl std::fmt::Debug for Deque {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Deque").finish_non_exhaustive()
    }
}

// SAFETY: JobRef slots are only read/written under the Chase-Lev protocol;
// JobRef itself is Send.
unsafe impl Send for Deque {}
unsafe impl Sync for Deque {}

pub enum Steal {
    Empty,
    Retry,
    Success(JobRef),
}

impl std::fmt::Debug for Steal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Steal::Empty => f.write_str("Empty"),
            Steal::Retry => f.write_str("Retry"),
            Steal::Success(_) => f.write_str("Success(..)"),
        }
    }
}

impl Deque {
    /// `capacity` must be a power of two.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity.is_power_of_two());
        let buf: Vec<UnsafeCell<JobRef>> =
            (0..capacity).map(|_| UnsafeCell::new(JobRef::null())).collect();
        Deque {
            top: CachePadded::new(AtomicIsize::new(0)),
            bottom: CachePadded::new(AtomicIsize::new(0)),
            buf: buf.into_boxed_slice(),
            mask: capacity as isize - 1,
        }
    }

    #[inline]
    fn slot(&self, i: isize) -> *mut JobRef {
        self.buf[(i & self.mask) as usize].get()
    }

    /// Owner-only: push at the bottom. Returns `false` when full (caller
    /// must run the job another way; nothing is written).
    ///
    /// # Safety
    /// Must only be called by the owning worker thread.
    pub unsafe fn push(&self, job: JobRef) -> bool {
        let b = self.bottom.load(SeqCst);
        let t = self.top.load(SeqCst);
        if b - t > self.mask {
            return false; // full
        }
        unsafe { *self.slot(b) = job };
        self.bottom.store(b + 1, SeqCst);
        true
    }

    /// Owner-only: pop from the bottom (most recently pushed).
    ///
    /// # Safety
    /// Must only be called by the owning worker thread.
    pub unsafe fn pop(&self) -> Option<JobRef> {
        let b = self.bottom.load(SeqCst) - 1;
        self.bottom.store(b, SeqCst);
        let t = self.top.load(SeqCst);
        if t > b {
            // Empty: restore.
            self.bottom.store(b + 1, SeqCst);
            return None;
        }
        let job = unsafe { *self.slot(b) };
        if t == b {
            // Last element: race with thieves via CAS on top.
            let won = self.top.compare_exchange(t, t + 1, SeqCst, SeqCst).is_ok();
            self.bottom.store(b + 1, SeqCst);
            return if won { Some(job) } else { None };
        }
        Some(job)
    }

    /// Thief: steal from the top (oldest).
    pub fn steal(&self) -> Steal {
        let t = self.top.load(SeqCst);
        let b = self.bottom.load(SeqCst);
        if t >= b {
            return Steal::Empty;
        }
        let job = unsafe { *self.slot(t) };
        if self.top.compare_exchange(t, t + 1, SeqCst, SeqCst).is_ok() {
            Steal::Success(job)
        } else {
            Steal::Retry
        }
    }

    /// Approximate occupancy (for metrics/back-pressure heuristics).
    pub fn len_hint(&self) -> usize {
        let b = self.bottom.load(SeqCst);
        let t = self.top.load(SeqCst);
        (b - t).max(0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::job::tests_support::{counting_job, CountPayload};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn lifo_pop_fifo_steal() {
        let d = Deque::new(8);
        let hits = Arc::new(AtomicUsize::new(0));
        let payloads: Vec<CountPayload> = (0..3).map(|_| CountPayload::new(hits.clone())).collect();
        unsafe {
            for p in &payloads {
                assert!(d.push(counting_job(p)));
            }
            // Owner pops newest first.
            let j = d.pop().unwrap();
            j.execute();
            assert_eq!(hits.load(Ordering::SeqCst), 1);
        }
        // Thief steals oldest.
        match d.steal() {
            Steal::Success(j) => unsafe { j.execute() },
            _ => panic!("expected steal success"),
        }
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        unsafe {
            assert!(d.pop().is_some());
            assert!(d.pop().is_none());
        }
    }

    #[test]
    fn overflow_reports_full() {
        let d = Deque::new(2);
        let hits = Arc::new(AtomicUsize::new(0));
        let p1 = CountPayload::new(hits.clone());
        let p2 = CountPayload::new(hits.clone());
        let p3 = CountPayload::new(hits.clone());
        unsafe {
            assert!(d.push(counting_job(&p1)));
            assert!(d.push(counting_job(&p2)));
            assert!(!d.push(counting_job(&p3)), "third push must report full");
        }
        assert_eq!(d.len_hint(), 2);
    }

    #[test]
    fn concurrent_steal_vs_pop_no_dup_no_loss() {
        // 2 thieves + owner pops; every job executed exactly once.
        const N: usize = 2000;
        let d = Arc::new(Deque::new(4096));
        let hits = Arc::new(AtomicUsize::new(0));
        let payloads: Arc<Vec<CountPayload>> =
            Arc::new((0..N).map(|_| CountPayload::new(hits.clone())).collect());

        std::thread::scope(|s| {
            let thieves: Vec<_> = (0..2)
                .map(|_| {
                    let d = d.clone();
                    s.spawn(move || {
                        let mut got = 0usize;
                        let mut dry = 0;
                        while dry < 10_000 {
                            match d.steal() {
                                Steal::Success(j) => {
                                    unsafe { j.execute() };
                                    got += 1;
                                    dry = 0;
                                }
                                Steal::Retry => {}
                                Steal::Empty => dry += 1,
                            }
                            std::hint::spin_loop();
                        }
                        got
                    })
                })
                .collect();

            // Owner: push all, interleaving pops.
            let mut popped = 0usize;
            unsafe {
                for p in payloads.iter() {
                    while !d.push(counting_job(p)) {
                        if let Some(j) = d.pop() {
                            j.execute();
                            popped += 1;
                        }
                    }
                    if popped % 3 == 0 {
                        if let Some(j) = d.pop() {
                            j.execute();
                            popped += 1;
                        }
                    }
                }
                while let Some(j) = d.pop() {
                    j.execute();
                }
            }
            let stolen: usize = thieves.into_iter().map(|t| t.join().unwrap()).sum();
            // Exactly-once execution across owner + thieves:
            assert_eq!(hits.load(Ordering::SeqCst), N);
            assert!(stolen <= N);
        });
    }
}
