//! Type-erased job pointers (the rayon `JobRef` technique).
//!
//! A [`JobRef`] is a raw `(data, execute)` pair. Stack jobs ([`StackJob`])
//! live in the frame of a blocked `join` caller — safe because the caller
//! does not return before the job's latch is set. Heap jobs ([`HeapJob`])
//! carry scope-spawned closures whose lifetime is enforced by the scope's
//! completion latch (see `pool::scope`).

use super::latch::Latch;
use std::any::Any;
use std::cell::UnsafeCell;

/// Erased executable job. `Copy` so it can sit in the deque ring buffer.
#[derive(Clone, Copy)]
pub struct JobRef {
    data: *const (),
    exec: unsafe fn(*const ()),
}

impl std::fmt::Debug for JobRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobRef").finish_non_exhaustive()
    }
}

unsafe impl Send for JobRef {}

impl JobRef {
    /// Erase `job`. # Safety: `job` must stay alive (and pinned) until
    /// `execute` has completed.
    pub unsafe fn new<T: Job>(job: *const T) -> JobRef {
        JobRef { data: job as *const (), exec: execute_shim::<T> }
    }

    pub fn null() -> JobRef {
        JobRef { data: std::ptr::null(), exec: noop }
    }

    /// Run the job. # Safety: call exactly once, on a live job.
    pub unsafe fn execute(self) {
        unsafe { (self.exec)(self.data) }
    }
}

unsafe fn noop(_: *const ()) {}

unsafe fn execute_shim<T: Job>(data: *const ()) {
    unsafe { T::execute(data as *const T) }
}

/// Implemented by concrete job representations.
pub trait Job {
    /// # Safety: called exactly once; `this` outlives the call.
    unsafe fn execute(this: *const Self);
}

/// A job allocated in the frame of a blocked caller (`join`'s `b` branch).
///
/// The caller waits on `latch` before reading `result` or returning, which
/// is what makes the borrowed closure sound.
pub struct StackJob<F, R> {
    f: UnsafeCell<Option<F>>,
    result: UnsafeCell<Option<R>>,
    /// Panic payload captured from the closure — re-raised (with its
    /// original message) in `take_result`, so panics propagate across
    /// the fork transparently.
    panic_payload: UnsafeCell<Option<Box<dyn Any + Send>>>,
    pub latch: Latch,
}

impl<F, R> std::fmt::Debug for StackJob<F, R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StackJob").finish_non_exhaustive()
    }
}

// SAFETY: access to `f`/`result` is ordered by the latch protocol.
unsafe impl<F: Send, R: Send> Sync for StackJob<F, R> {}

impl<F, R> StackJob<F, R>
where
    F: FnOnce() -> R + Send,
    R: Send,
{
    pub fn new(f: F) -> Self {
        StackJob {
            f: UnsafeCell::new(Some(f)),
            result: UnsafeCell::new(None),
            panic_payload: UnsafeCell::new(None),
            latch: Latch::new(),
        }
    }

    pub fn as_job_ref(&self) -> JobRef {
        unsafe { JobRef::new(self) }
    }

    /// # Safety: only after the latch is set.
    pub unsafe fn take_result(&self) -> R {
        if let Some(payload) = unsafe { (*self.panic_payload.get()).take() } {
            std::panic::resume_unwind(payload);
        }
        unsafe { (*self.result.get()).take().expect("StackJob executed without result") }
    }
}

impl<F, R> Job for StackJob<F, R>
where
    F: FnOnce() -> R + Send,
    R: Send,
{
    unsafe fn execute(this: *const Self) {
        let this = unsafe { &*this };
        let f = unsafe { (*this.f.get()).take().expect("StackJob executed twice") };
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
            Ok(r) => unsafe { *this.result.get() = Some(r) },
            Err(payload) => unsafe { *this.panic_payload.get() = Some(payload) },
        }
        // Set last: publishes result/panic payload to the waiter.
        this.latch.set();
    }
}

/// A heap-allocated fire-and-forget job (scope spawns).
pub struct HeapJob {
    f: Option<Box<dyn FnOnce() + Send>>,
}

impl std::fmt::Debug for HeapJob {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HeapJob").finish_non_exhaustive()
    }
}

impl HeapJob {
    /// Box the closure and return an erased, self-freeing JobRef.
    ///
    /// # Safety: caller must guarantee the closure's captures outlive
    /// execution (the Scope lifetime contract).
    pub unsafe fn into_job_ref(f: Box<dyn FnOnce() + Send>) -> JobRef {
        let boxed = Box::new(HeapJob { f: Some(f) });
        unsafe { JobRef::new(Box::into_raw(boxed)) }
    }
}

impl Job for HeapJob {
    unsafe fn execute(this: *const Self) {
        // Re-box to free after running.
        let mut boxed = unsafe { Box::from_raw(this as *mut HeapJob) };
        let f = boxed.f.take().expect("HeapJob executed twice");
        f();
    }
}

#[cfg(any(test, doctest))]
pub mod tests_support {
    //! Helpers shared by deque/pool unit tests.
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// A pinned payload whose execution bumps a shared counter.
    pub struct CountPayload {
        hits: Arc<AtomicUsize>,
    }

    impl std::fmt::Debug for CountPayload {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("CountPayload").finish_non_exhaustive()
        }
    }

    impl CountPayload {
        pub fn new(hits: Arc<AtomicUsize>) -> Self {
            CountPayload { hits }
        }
    }

    impl Job for CountPayload {
        unsafe fn execute(this: *const Self) {
            unsafe { &*this }.hits.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Erase a counting payload (payload must outlive execution).
    pub fn counting_job(p: &CountPayload) -> JobRef {
        unsafe { JobRef::new(p) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_job_roundtrip() {
        let job = StackJob::new(|| 6 * 7);
        let jref = job.as_job_ref();
        unsafe { jref.execute() };
        assert!(job.latch.probe());
        assert_eq!(unsafe { job.take_result() }, 42);
    }

    #[test]
    #[should_panic(expected = "inner")]
    fn stack_job_propagates_panic_with_original_message() {
        let job: StackJob<_, ()> = StackJob::new(|| panic!("inner"));
        let jref = job.as_job_ref();
        unsafe { jref.execute() };
        assert!(job.latch.probe());
        unsafe { job.take_result() };
    }

    #[test]
    fn heap_job_runs_and_frees() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let jref = unsafe { HeapJob::into_job_ref(Box::new(move || { h.fetch_add(1, Ordering::SeqCst); })) };
        unsafe { jref.execute() };
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }
}
