//! Completion latches: the pool's only blocking synchronization points.
//!
//! Every latch wait is exactly one of the paper's **synchronization
//! overheads** (β events); the pool counts them in
//! [`super::metrics::Metrics`] so the ledger can reconcile measured time
//! against the overhead model.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// One-shot latch: starts unset, `set()` once, waiters proceed.
///
/// `probe()` is the cheap non-blocking check used by workers that *help*
/// (steal) while waiting; `wait()` blocks on a condvar (used by external,
/// non-worker threads that have nothing to steal).
pub struct Latch {
    set: AtomicBool,
    mu: Mutex<()>,
    cv: Condvar,
}

impl std::fmt::Debug for Latch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Latch").finish_non_exhaustive()
    }
}

impl Default for Latch {
    fn default() -> Self {
        Self::new()
    }
}

impl Latch {
    pub fn new() -> Self {
        Latch { set: AtomicBool::new(false), mu: Mutex::new(()), cv: Condvar::new() }
    }

    #[inline]
    pub fn probe(&self) -> bool {
        self.set.load(Ordering::Acquire)
    }

    pub fn set(&self) {
        self.set.store(true, Ordering::Release);
        let _g = self.mu.lock().unwrap();
        self.cv.notify_all();
    }

    /// Block until set (condvar; timeout-poll defends against lost wakeups).
    pub fn wait(&self) {
        let mut g = self.mu.lock().unwrap();
        while !self.probe() {
            let (g2, _) = self.cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
            g = g2;
        }
    }
}

/// Counting latch: `wait()` until the count returns to zero
/// (scope-completion barrier). Starts at 0; `increment` per spawn.
pub struct CountLatch {
    count: AtomicUsize,
    mu: Mutex<()>,
    cv: Condvar,
}

impl std::fmt::Debug for CountLatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CountLatch").finish_non_exhaustive()
    }
}

impl Default for CountLatch {
    fn default() -> Self {
        Self::new()
    }
}

impl CountLatch {
    pub fn new() -> Self {
        CountLatch { count: AtomicUsize::new(0), mu: Mutex::new(()), cv: Condvar::new() }
    }

    pub fn increment(&self) {
        self.count.fetch_add(1, Ordering::SeqCst);
    }

    pub fn decrement(&self) {
        let prev = self.count.fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev > 0, "CountLatch underflow");
        if prev == 1 {
            let _g = self.mu.lock().unwrap();
            self.cv.notify_all();
        }
    }

    #[inline]
    pub fn is_done(&self) -> bool {
        self.count.load(Ordering::SeqCst) == 0
    }

    pub fn wait(&self) {
        let mut g = self.mu.lock().unwrap();
        while !self.is_done() {
            let (g2, _) = self.cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
            g = g2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn latch_set_unblocks_waiter() {
        let l = Arc::new(Latch::new());
        assert!(!l.probe());
        let l2 = l.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            l2.set();
        });
        l.wait();
        assert!(l.probe());
        h.join().unwrap();
    }

    #[test]
    fn count_latch_waits_for_all() {
        let l = Arc::new(CountLatch::new());
        for _ in 0..8 {
            l.increment();
        }
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || {
                    std::thread::sleep(Duration::from_millis(2));
                    l.decrement();
                })
            })
            .collect();
        l.wait();
        assert!(l.is_done());
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn count_latch_zero_is_immediately_done() {
        let l = CountLatch::new();
        l.wait(); // must not block
        assert!(l.is_done());
    }
}
