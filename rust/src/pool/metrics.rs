//! Pool instrumentation: every overhead event the paper names, counted.
//!
//! These counters feed [`crate::overhead::Ledger`]: spawns → α events,
//! latch waits → β events, steals/injections → γ events.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotone event counters, shared by all workers of one pool.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs made available for parallel execution (forks + scope spawns).
    pub spawns: AtomicU64,
    /// Jobs executed to completion (must equal spawns at quiescence).
    pub executed: AtomicU64,
    /// Successful steals (inter-core task migration = γ messages).
    pub steals: AtomicU64,
    /// Steal attempts that found nothing (contention signal).
    pub failed_steals: AtomicU64,
    /// Jobs routed through the global injector (external submissions).
    pub injected: AtomicU64,
    /// Latch waits entered (β synchronization events).
    pub latch_waits: AtomicU64,
    /// `join` calls (fork-join regions).
    pub joins: AtomicU64,
    /// Jobs executed inline because a deque was full (back-pressure).
    pub overflow_inline: AtomicU64,
}

/// A point-in-time copy of [`Metrics`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MetricsSnapshot {
    pub spawns: u64,
    pub executed: u64,
    pub steals: u64,
    pub failed_steals: u64,
    pub injected: u64,
    pub latch_waits: u64,
    pub joins: u64,
    pub overflow_inline: u64,
}

impl Metrics {
    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            spawns: self.spawns.load(Ordering::Relaxed),
            executed: self.executed.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            failed_steals: self.failed_steals.load(Ordering::Relaxed),
            injected: self.injected.load(Ordering::Relaxed),
            latch_waits: self.latch_waits.load(Ordering::Relaxed),
            joins: self.joins.load(Ordering::Relaxed),
            overflow_inline: self.overflow_inline.load(Ordering::Relaxed),
        }
    }
}

impl MetricsSnapshot {
    /// Difference of two snapshots (events inside a measured region).
    pub fn delta_since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            spawns: self.spawns - earlier.spawns,
            executed: self.executed - earlier.executed,
            steals: self.steals - earlier.steals,
            failed_steals: self.failed_steals - earlier.failed_steals,
            injected: self.injected - earlier.injected,
            latch_waits: self.latch_waits - earlier.latch_waits,
            joins: self.joins - earlier.joins,
            overflow_inline: self.overflow_inline - earlier.overflow_inline,
        }
    }

    /// Total α/β/γ-class events in this snapshot — the scalar the bench
    /// harness checks to confirm a "parallel" measurement actually forked.
    pub fn overhead_events(&self) -> u64 {
        self.spawns + self.injected + self.latch_waits + self.steals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_delta() {
        let m = Metrics::default();
        Metrics::bump(&m.spawns);
        let a = m.snapshot();
        Metrics::bump(&m.spawns);
        Metrics::bump(&m.steals);
        let b = m.snapshot();
        let d = b.delta_since(&a);
        assert_eq!(d.spawns, 1);
        assert_eq!(d.steals, 1);
        assert_eq!(d.executed, 0);
    }
}
