//! From-scratch work-stealing fork-join thread pool.
//!
//! This is OHM's substitute for the paper's OpenMP "parallel sections":
//! a fixed set of worker threads, one Chase–Lev deque per worker, a global
//! injector for external submissions, and two structured-parallelism
//! primitives:
//!
//! * [`ThreadPool::join`] — binary fork-join (the paper's fork-join
//!   switching technique); the calling worker runs branch `a` itself and
//!   exposes `b` for stealing, then *helps* (steals other work) while
//!   waiting — so a blocked join never idles a core.
//! * [`ThreadPool::scope`] — N-way fork with a completion barrier
//!   (master-slave distribution: the master spawns one task per slice).
//!
//! Every overhead event the paper names is counted in [`metrics::Metrics`]:
//! spawns (thread/task creation, α), latch waits (synchronization, β),
//! steals + injections (inter-core communication, γ). The overhead
//! [`crate::overhead::Ledger`] consumes these deltas.

pub mod deque;
pub mod job;
pub mod latch;
pub mod metrics;

use deque::{Deque, Steal};
use job::{HeapJob, JobRef, StackJob};
use latch::CountLatch;
use metrics::{Metrics, MetricsSnapshot};
use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Per-worker deque capacity (power of two). Overflow degrades gracefully
/// to inline execution (join) or the injector (scope), both counted.
const DEQUE_CAP: usize = 8192;

static POOL_IDS: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (pool id, worker index, shared ptr) for the current worker thread.
    static WORKER: Cell<Option<(u64, usize, *const Shared)>> = const { Cell::new(None) };
}

struct Shared {
    id: u64,
    deques: Vec<Deque>,
    injector: Mutex<VecDeque<JobRef>>,
    metrics: Metrics,
    shutdown: AtomicBool,
    sleepers: AtomicUsize,
    sleep_mu: Mutex<()>,
    sleep_cv: Condvar,
}

impl Shared {
    fn notify_if_sleeping(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let _g = self.sleep_mu.lock().unwrap();
            self.sleep_cv.notify_all();
        }
    }

    fn inject(&self, job: JobRef) {
        Metrics::bump(&self.metrics.injected);
        self.injector.lock().unwrap().push_back(job);
        self.notify_if_sleeping();
    }

    fn pop_injector(&self) -> Option<JobRef> {
        self.injector.lock().unwrap().pop_front()
    }

    /// One attempt to find and run a job as worker `idx`; returns whether
    /// any job was executed.
    fn find_and_run(&self, idx: usize, rot: &mut usize) -> bool {
        // 1. Own deque (LIFO — depth-first, cache-warm).
        if let Some(j) = unsafe { self.deques[idx].pop() } {
            // Count before running: the job's latch release may unblock a
            // joiner that reads the metrics immediately.
            Metrics::bump(&self.metrics.executed);
            unsafe { j.execute() };
            return true;
        }
        // 2. Steal from siblings (rotating start to spread contention).
        let n = self.deques.len();
        for k in 0..n {
            let victim = (idx + 1 + k + *rot) % n;
            if victim == idx {
                continue;
            }
            loop {
                match self.deques[victim].steal() {
                    Steal::Success(j) => {
                        Metrics::bump(&self.metrics.steals);
                        Metrics::bump(&self.metrics.executed);
                        unsafe { j.execute() };
                        *rot = rot.wrapping_add(1);
                        return true;
                    }
                    Steal::Retry => continue,
                    Steal::Empty => break,
                }
            }
        }
        Metrics::bump(&self.metrics.failed_steals);
        // 3. Global injector.
        if let Some(j) = self.pop_injector() {
            Metrics::bump(&self.metrics.executed);
            unsafe { j.execute() };
            return true;
        }
        false
    }
}

/// The work-stealing pool. Dropping it shuts workers down (after their
/// current queues drain; all public entry points block until their own
/// work completes, so a quiescent drop is the normal case).
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").finish_non_exhaustive()
    }
}

impl ThreadPool {
    /// Create a pool with `threads` workers (≥ 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            id: POOL_IDS.fetch_add(1, Ordering::SeqCst),
            deques: (0..threads).map(|_| Deque::new(DEQUE_CAP)).collect(),
            injector: Mutex::new(VecDeque::new()),
            metrics: Metrics::default(),
            shutdown: AtomicBool::new(false),
            sleepers: AtomicUsize::new(0),
            sleep_mu: Mutex::new(()),
            sleep_cv: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|idx| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("ohm-worker-{idx}"))
                    .spawn(move || worker_main(sh, idx))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, handles, threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    fn current_worker(&self) -> Option<usize> {
        WORKER.with(|w| match w.get() {
            Some((pid, idx, _)) if pid == self.shared.id => Some(idx),
            _ => None,
        })
    }

    /// Run `f` on a pool worker, blocking until it completes. Entry point
    /// for non-worker threads; re-entrant calls run inline.
    pub fn install<R: Send>(&self, f: impl FnOnce() -> R + Send) -> R {
        if self.current_worker().is_some() {
            return f();
        }
        let job = StackJob::new(f);
        // SAFETY: we block on the latch before the frame unwinds.
        self.shared.inject(job.as_job_ref());
        Metrics::bump(&self.shared.metrics.latch_waits);
        job.latch.wait();
        unsafe { job.take_result() }
    }

    /// Binary fork-join: run `a` and `b`, potentially in parallel; return
    /// both results. The paper's serial/parallel switch is exactly "call
    /// `join` vs call both closures" — see `overhead::Manager`.
    pub fn join<A, B, RA, RB>(&self, a: A, b: B) -> (RA, RB)
    where
        A: FnOnce() -> RA + Send,
        B: FnOnce() -> RB + Send,
        RA: Send,
        RB: Send,
    {
        Metrics::bump(&self.shared.metrics.joins);
        match self.current_worker() {
            Some(idx) => self.join_inside(idx, a, b),
            None => self.install(|| {
                let idx = self.current_worker().expect("install puts us on a worker");
                self.join_inside(idx, a, b)
            }),
        }
    }

    fn join_inside<A, B, RA, RB>(&self, idx: usize, a: A, b: B) -> (RA, RB)
    where
        A: FnOnce() -> RA + Send,
        B: FnOnce() -> RB + Send,
        RA: Send,
        RB: Send,
    {
        let sh = &*self.shared;
        let b_job = StackJob::new(b);
        // SAFETY: b_job is pinned in this frame; we do not leave before its
        // latch is set (including the panic path below).
        let pushed = unsafe { sh.deques[idx].push(b_job.as_job_ref()) };
        if pushed {
            Metrics::bump(&sh.metrics.spawns);
            sh.notify_if_sleeping();
        }
        let ra = match catch_unwind(AssertUnwindSafe(a)) {
            Ok(r) => r,
            Err(payload) => {
                if pushed {
                    self.wait_helping(idx, &b_job.latch);
                }
                resume_unwind(payload);
            }
        };
        if pushed {
            self.wait_helping(idx, &b_job.latch);
            let rb = unsafe { b_job.take_result() };
            (ra, rb)
        } else {
            // Deque full: degrade to serial execution of b, still through
            // the job so panic semantics are identical.
            Metrics::bump(&sh.metrics.overflow_inline);
            unsafe { b_job.as_job_ref().execute() };
            let rb = unsafe { b_job.take_result() };
            (ra, rb)
        }
    }

    /// Helping wait: until `l` is set, keep executing other pending work
    /// (own deque → steal → injector); never sleeps for long.
    fn wait_helping(&self, idx: usize, l: &latch::Latch) {
        let sh = &*self.shared;
        Metrics::bump(&sh.metrics.latch_waits);
        let mut rot = 0usize;
        let mut idle_spins = 0u32;
        while !l.probe() {
            if sh.find_and_run(idx, &mut rot) {
                idle_spins = 0;
            } else {
                idle_spins += 1;
                if idle_spins < 16 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Structured N-way fork with completion barrier.
    ///
    /// The closure receives a [`Scope`] on which `spawn` may be called any
    /// number of times (including from spawned tasks); `scope` returns only
    /// after every spawned task has finished. Spawned-task panics are
    /// collected and re-raised here.
    pub fn scope<'scope, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'scope>) -> R + Send,
        R: Send,
    {
        // §Perf: enter a worker first — spawns then go to the worker's
        // local deque instead of through the injector mutex (measured
        // ~3× on the 1000-task spawn-throughput micro-bench).
        if self.current_worker().is_none() {
            return self.install(|| self.scope(f));
        }
        let scope = Scope {
            pool_shared: Arc::clone(&self.shared),
            latch: CountLatch::new(),
            panicked: AtomicBool::new(false),
            _marker: PhantomData,
        };
        let r = f(&scope);
        // Wait for all spawned tasks, helping if we are a worker.
        Metrics::bump(&self.shared.metrics.latch_waits);
        match self.current_worker() {
            Some(idx) => {
                let sh = &*self.shared;
                let mut rot = 0usize;
                while !scope.latch.is_done() {
                    if !sh.find_and_run(idx, &mut rot) {
                        std::thread::yield_now();
                    }
                }
            }
            None => scope.latch.wait(),
        }
        if scope.panicked.load(Ordering::SeqCst) {
            panic!("ohm::pool: scoped task panicked");
        }
        r
    }

    /// Convenience: run `op` over `0..n` with one spawned task per index.
    /// This is the paper's master-slave distribution in one call.
    pub fn for_each_index<F>(&self, n: usize, op: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        let op_ref = &op;
        self.scope(|s| {
            for i in 0..n {
                s.spawn(move |_| op_ref(i));
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let _g = self.shared.sleep_mu.lock().unwrap();
            self.shared.sleep_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Spawn context for [`ThreadPool::scope`].
pub struct Scope<'scope> {
    pool_shared: Arc<Shared>,
    latch: CountLatch,
    panicked: AtomicBool,
    _marker: PhantomData<&'scope mut &'scope ()>,
}

impl std::fmt::Debug for Scope<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scope").finish_non_exhaustive()
    }
}

impl<'scope> Scope<'scope> {
    /// Spawn a task that may borrow anything alive for `'scope`.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce(&Scope<'scope>) + Send + 'scope,
    {
        self.latch.increment();
        // Send-able wrapper for the scope pointer (the pointee is Sync-safe:
        // CountLatch + AtomicBool + Arc).
        struct ScopePtr<'s>(*const Scope<'s>);
        unsafe impl Send for ScopePtr<'_> {}
        impl<'s> ScopePtr<'s> {
            // Method access forces the closure to capture the whole Send
            // wrapper, not the raw-pointer field (2021 disjoint capture).
            fn get(&self) -> *const Scope<'s> {
                self.0
            }
        }
        let self_ptr = ScopePtr(self as *const Scope<'scope>);
        let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
            // SAFETY: the scope outlives all spawned tasks (completion
            // barrier in `ThreadPool::scope`).
            let scope = unsafe { &*self_ptr.get() };
            if catch_unwind(AssertUnwindSafe(|| f(scope))).is_err() {
                scope.panicked.store(true, Ordering::SeqCst);
            }
            scope.latch.decrement();
        });
        // SAFETY: lifetime erasure justified by the completion barrier.
        let wrapped_static: Box<dyn FnOnce() + Send + 'static> =
            unsafe { std::mem::transmute(wrapped) };
        let jref = unsafe { HeapJob::into_job_ref(wrapped_static) };

        // Prefer the local deque when spawning from a worker of this pool.
        let local = WORKER.with(|w| match w.get() {
            Some((pid, idx, _)) if pid == self.pool_shared.id => Some(idx),
            _ => None,
        });
        // Publication paths are disjoint for the ledger: `spawns` counts
        // worker-deque publications, `injected` counts injector hops.
        match local {
            Some(idx) => {
                if unsafe { self.pool_shared.deques[idx].push(jref) } {
                    Metrics::bump(&self.pool_shared.metrics.spawns);
                    self.pool_shared.notify_if_sleeping();
                } else {
                    self.pool_shared.inject(jref);
                }
            }
            None => self.pool_shared.inject(jref),
        }
    }
}

fn worker_main(shared: Arc<Shared>, idx: usize) {
    WORKER.with(|w| w.set(Some((shared.id, idx, Arc::as_ptr(&shared)))));
    let mut rot = idx; // de-synchronize steal order across workers
    loop {
        if shared.find_and_run(idx, &mut rot) {
            continue;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        // Nothing to do: sleep briefly (timeout defends against lost wakeups).
        shared.sleepers.fetch_add(1, Ordering::SeqCst);
        {
            let g = shared.sleep_mu.lock().unwrap();
            let _ = shared.sleep_cv.wait_timeout(g, Duration::from_micros(200)).unwrap();
        }
        shared.sleepers.fetch_sub(1, Ordering::SeqCst);
    }
    WORKER.with(|w| w.set(None));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_runs_and_returns() {
        let pool = ThreadPool::new(2);
        let v = pool.install(|| 21 * 2);
        assert_eq!(v, 42);
    }

    #[test]
    fn join_returns_both_branches() {
        let pool = ThreadPool::new(2);
        let (a, b) = pool.join(|| 1 + 1, || "two");
        assert_eq!((a, b), (2, "two"));
    }

    #[test]
    fn join_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let xs = vec![1, 2, 3, 4, 5, 6];
        let (l, r) = xs.split_at(3);
        let (sl, sr) = pool.join(|| l.iter().sum::<i32>(), || r.iter().sum::<i32>());
        assert_eq!(sl + sr, 21);
    }

    #[test]
    fn nested_joins_recursive_sum() {
        let pool = ThreadPool::new(4);
        fn sum(pool: &ThreadPool, xs: &[u64]) -> u64 {
            if xs.len() <= 8 {
                return xs.iter().sum();
            }
            let (l, r) = xs.split_at(xs.len() / 2);
            let (a, b) = pool.join(|| sum(pool, l), || sum(pool, r));
            a + b
        }
        let xs: Vec<u64> = (0..10_000).collect();
        assert_eq!(sum(&pool, &xs), 10_000 * 9_999 / 2);
        let m = pool.metrics();
        assert!(m.joins > 0);
        assert_eq!(m.spawns + m.injected, m.executed, "all published jobs ran: {m:?}");
    }

    #[test]
    fn scope_spawn_mutates_disjoint_slices() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0usize; 64];
        {
            let chunks: Vec<&mut [usize]> = data.chunks_mut(16).collect();
            pool.scope(|s| {
                for (ci, chunk) in chunks.into_iter().enumerate() {
                    s.spawn(move |_| {
                        for (i, v) in chunk.iter_mut().enumerate() {
                            *v = ci * 100 + i;
                        }
                    });
                }
            });
        }
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, (i / 16) * 100 + i % 16);
        }
    }

    #[test]
    fn scope_nested_spawns() {
        let pool = ThreadPool::new(2);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..4 {
                let c = &counter;
                s.spawn(move |s2| {
                    c.fetch_add(1, Ordering::SeqCst);
                    for _ in 0..3 {
                        s2.spawn(move |_| {
                            c.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 4 + 12);
    }

    #[test]
    fn for_each_index_covers_all() {
        let pool = ThreadPool::new(4);
        let flags: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.for_each_index(100, |i| {
            flags[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(flags.iter().all(|f| f.load(Ordering::SeqCst) == 1));
    }

    #[test]
    #[should_panic(expected = "b dies")]
    fn join_propagates_b_panic() {
        let pool = ThreadPool::new(2);
        pool.join(|| 1, || -> i32 { panic!("b dies") });
    }

    #[test]
    #[should_panic(expected = "scoped task panicked")]
    fn scope_propagates_spawn_panic() {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            s.spawn(|_| panic!("spawn dies"));
        });
    }

    #[test]
    fn single_thread_pool_still_correct() {
        let pool = ThreadPool::new(1);
        let (a, b) = pool.join(|| 10, || 32);
        assert_eq!(a + b, 42);
        let n = AtomicUsize::new(0);
        pool.for_each_index(50, |_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn metrics_account_spawned_equals_executed_at_quiescence() {
        let pool = ThreadPool::new(3);
        pool.for_each_index(200, |_| {});
        let (..) = pool.join(|| (), || ());
        let m = pool.metrics();
        assert_eq!(m.spawns + m.injected, m.executed, "{m:?}");
    }
}
