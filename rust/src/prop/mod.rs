//! Minimal property-based testing framework (offline `proptest` substitute).
//!
//! Supports deterministic seeded generation, configurable case counts, and
//! greedy shrinking on failure. Used by the `rust/tests/prop_*.rs` suites
//! for coordinator, pool, simulator, sorting, and overhead-model invariants.
//!
//! ```no_run
//! # // no_run: rustdoc test binaries skip the cargo rpath config, so the
//! # // xla-linked crate cannot resolve libstdc++ at doctest run time.
//! use ohm::prop::{forall, Gen, Config};
//! forall(Config::default().cases(64), "reverse twice is identity", |g| {
//!     let v = g.vec_i64(0..200, -50..50);
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     if w == v { Ok(()) } else { Err(format!("mismatch on {v:?}")) }
//! });
//! ```

use crate::util::Pcg32;
use std::ops::Range;

/// Property-run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum shrink attempts after a failure.
    pub max_shrinks: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Seed overridable for replay: OHM_PROP_SEED=123 cargo test
        Config { cases: 100, seed: crate::util::env_or("OHM_PROP_SEED", 0xC0FFEE), max_shrinks: 200 }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Generation context handed to properties. Wraps a deterministic RNG and
/// records the *recipe seed* so failures can be replayed and shrunk.
pub struct Gen {
    rng: Pcg32,
    /// Size dampener in [0,1]: shrinking re-runs the property with smaller
    /// sizes by scaling every `usize_in`/`vec_*` upper bound down.
    scale: f64,
}

impl std::fmt::Debug for Gen {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Gen").finish_non_exhaustive()
    }
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Gen { rng: Pcg32::new(seed), scale }
    }

    /// Uniform usize in `range`, upper bound scaled down while shrinking.
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        assert!(range.start < range.end);
        let span = (range.end - range.start) as f64;
        let scaled = ((span * self.scale).ceil() as usize).max(1);
        range.start + self.rng.below(scaled as u64) as usize
    }

    pub fn i64_in(&mut self, range: Range<i64>) -> i64 {
        self.rng.range_i64(range.start, range.end)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Vec of i64 with length drawn from `len` and values from `vals`.
    pub fn vec_i64(&mut self, len: Range<usize>, vals: Range<i64>) -> Vec<i64> {
        let n = if len.start == len.end { len.start } else { self.usize_in(len) };
        (0..n).map(|_| self.i64_in(vals.clone())).collect()
    }

    /// A fresh child RNG (for seeding systems under test).
    pub fn rng(&mut self) -> Pcg32 {
        self.rng.split()
    }
}

/// Run `prop` for `cfg.cases` random cases. On failure, greedily shrink by
/// re-running the same case-seed with progressively smaller size scales and
/// report the smallest failure. Panics (test failure) with a replay seed.
pub fn forall<F>(cfg: Config, name: &str, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut meta = Pcg32::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let mut g = Gen::new(case_seed, 1.0);
        if let Err(first_msg) = prop(&mut g) {
            // Shrink: lower the scale until the property passes again, keep
            // the smallest failing scale.
            let mut best: (f64, String) = (1.0, first_msg);
            let mut lo = 0.0f64;
            let mut hi = 1.0f64;
            for _ in 0..cfg.max_shrinks.min(40) {
                let mid = (lo + hi) / 2.0;
                if mid <= 1e-3 {
                    break;
                }
                let mut g = Gen::new(case_seed, mid);
                match prop(&mut g) {
                    Err(msg) => {
                        best = (mid, msg);
                        hi = mid;
                    }
                    Ok(()) => {
                        lo = mid;
                    }
                }
            }
            panic!(
                "property '{name}' failed (case {case}, replay: OHM_PROP_SEED={} scale={:.4}):\n  {}",
                cfg.seed, best.0, best.1
            );
        }
    }
}

/// Convenience: assert-style helper for property bodies.
pub fn ensure(cond: bool, msg: impl FnOnce() -> String) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        forall(Config::default().cases(17), "count", |g| {
            let _ = g.u64();
            **counter.borrow_mut() += 1;
            Ok(())
        });
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_replay_info() {
        forall(Config::default().cases(5), "always fails", |g| {
            let v = g.vec_i64(1..100, 0..10);
            Err(format!("len={}", v.len()))
        });
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = Gen::new(42, 1.0);
        let mut b = Gen::new(42, 1.0);
        assert_eq!(a.vec_i64(0..50, -5..5), b.vec_i64(0..50, -5..5));
        assert_eq!(a.usize_in(0..100), b.usize_in(0..100));
    }

    #[test]
    fn scale_shrinks_sizes() {
        let mut big = Gen::new(1, 1.0);
        let mut small = Gen::new(1, 0.05);
        let lb: Vec<usize> = (0..32).map(|_| big.usize_in(0..1000)).collect();
        let ls: Vec<usize> = (0..32).map(|_| small.usize_in(0..1000)).collect();
        assert!(ls.iter().max() < lb.iter().max());
        assert!(*ls.iter().max().unwrap() <= 50);
    }

    #[test]
    fn ensure_helper() {
        assert!(ensure(true, || "no".into()).is_ok());
        assert_eq!(ensure(false, || "boom".into()), Err("boom".into()));
    }

    #[test]
    fn choose_covers_all_elements() {
        let mut g = Gen::new(3, 1.0);
        let xs = [1, 2, 3, 4];
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[*g.choose(&xs) as usize - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
