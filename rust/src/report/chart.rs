//! ASCII line charts — the console rendering of the paper's figures
//! (Fig 2, Fig 5) plus the CSV series behind them.

/// Multi-series scatter/line chart on a character grid.
#[derive(Debug, Clone)]
pub struct Chart {
    title: String,
    x_label: String,
    y_label: String,
    series: Vec<(String, char, Vec<(f64, f64)>)>,
    width: usize,
    height: usize,
}

const MARKS: [char; 8] = ['*', 'o', '+', 'x', '#', '@', '%', '&'];

impl Chart {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Self {
        Chart {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            series: Vec::new(),
            width: 72,
            height: 20,
        }
    }

    pub fn with_size(mut self, width: usize, height: usize) -> Self {
        self.width = width.max(16);
        self.height = height.max(6);
        self
    }

    /// Add a named series; markers cycle automatically.
    pub fn series(&mut self, name: &str, points: Vec<(f64, f64)>) -> &mut Self {
        let mark = MARKS[self.series.len() % MARKS.len()];
        self.series.push((name.to_string(), mark, points));
        self
    }

    /// Render the grid + legend.
    pub fn render(&self) -> String {
        let pts: Vec<(f64, f64)> =
            self.series.iter().flat_map(|(_, _, p)| p.iter().copied()).collect();
        if pts.is_empty() {
            return format!("{} (no data)\n", self.title);
        }
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &pts {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (_, mark, points) in &self.series {
            for &(x, y) in points {
                let cx = (((x - x0) / (x1 - x0)) * (self.width - 1) as f64).round() as usize;
                let cy = (((y - y0) / (y1 - y0)) * (self.height - 1) as f64).round() as usize;
                let row = self.height - 1 - cy;
                grid[row][cx] = *mark;
            }
        }
        let mut out = format!("{}\n", self.title);
        out.push_str(&format!("  {} (top={:.3}, bottom={:.3})\n", self.y_label, y1, y0));
        for row in &grid {
            out.push_str("  |");
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str("  +");
        out.push_str(&"-".repeat(self.width));
        out.push('\n');
        out.push_str(&format!("   {} (left={:.0}, right={:.0})\n", self.x_label, x0, x1));
        out.push_str("  legend:");
        for (name, mark, _) in &self.series {
            out.push_str(&format!("  {mark}={name}"));
        }
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_marks_and_legend() {
        let mut c = Chart::new("Fig X", "n", "time");
        c.series("serial", vec![(0.0, 0.0), (10.0, 10.0)]);
        c.series("parallel", vec![(0.0, 10.0), (10.0, 0.0)]);
        let s = c.render();
        assert!(s.contains("Fig X"));
        assert!(s.contains("*=serial"));
        assert!(s.contains("o=parallel"));
        assert!(s.contains('*') && s.contains('o'));
    }

    #[test]
    fn empty_chart_safe() {
        let s = Chart::new("E", "x", "y").render();
        assert!(s.contains("no data"));
    }

    #[test]
    fn degenerate_ranges_safe() {
        let mut c = Chart::new("D", "x", "y");
        c.series("s", vec![(5.0, 7.0), (5.0, 7.0)]);
        let s = c.render();
        assert!(s.contains('*'));
    }
}
