//! Tiny CSV writer (reports + EXPERIMENTS.md data series).

use anyhow::{Context, Result};
use std::io::Write as _;
use std::path::Path;

/// Quote a cell if it contains separators/quotes.
fn quote(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Write headers + rows to `path`, creating parent dirs.
pub fn write_csv(path: &Path, headers: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    writeln!(f, "{}", headers.iter().map(|h| quote(h)).collect::<Vec<_>>().join(","))?;
    for row in rows {
        assert_eq!(row.len(), headers.len(), "csv row arity");
        writeln!(f, "{}", row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let dir = std::env::temp_dir().join("ohm-csv-test");
        let path = dir.join("t.csv");
        write_csv(
            &path,
            &["a", "b"],
            &[vec!["plain".into(), "with,comma".into()], vec!["q\"uote".into(), "x".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\nplain,\"with,comma\"\n\"q\"\"uote\",x\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
