//! Gantt renderer for simulator timelines: one row per virtual core,
//! `█` compute, `α` spawn overhead, `β` sync overhead, `·` idle. Makes the
//! paper's "overhead surfacing" *visible* per run.

use crate::sim::{SegKind, Segment};

/// Render `timeline` (from `Machine::run(.., trace=true)`) across `cores`.
pub fn render(timeline: &[Segment], cores: usize, width: usize) -> String {
    let width = width.max(20);
    let makespan = timeline.iter().map(|s| s.end_ns).fold(0.0, f64::max);
    if makespan <= 0.0 || timeline.is_empty() {
        return "(empty timeline)\n".to_string();
    }
    let mut rows = vec![vec!['·'; width]; cores];
    for seg in timeline {
        let c0 = ((seg.start_ns / makespan) * (width as f64 - 1.0)).floor() as usize;
        let c1 = ((seg.end_ns / makespan) * (width as f64 - 1.0)).ceil() as usize;
        let ch = match seg.kind {
            SegKind::Work => '█',
            SegKind::Spawn => 'α',
            SegKind::Sync => 'β',
        };
        let row = &mut rows[seg.core];
        for cell in row.iter_mut().take(c1.min(width - 1) + 1).skip(c0) {
            // Overhead marks win over compute on shared cells (visibility).
            if *cell == '·' || ch != '█' {
                *cell = ch;
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("virtual makespan: {:.1} µs\n", makespan / 1e3));
    for (i, row) in rows.iter().enumerate() {
        out.push_str(&format!("core {i:>2} "));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str("        █ compute   α spawn   β sync   · idle\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overhead::OverheadParams;
    use crate::sim::{Machine, Node};

    #[test]
    fn renders_rows_per_core() {
        let tree = Node::Par {
            branches: vec![
                Node::Leaf { work_ns: 500.0, label: "w" },
                Node::Leaf { work_ns: 700.0, label: "w" },
            ],
            bytes: vec![8, 8],
        };
        let rep = Machine::new(2, OverheadParams::paper_2022()).run(&tree, true);
        let g = render(&rep.timeline, 2, 60);
        assert!(g.contains("core  0"));
        assert!(g.contains("core  1"));
        assert!(g.contains('█'));
        assert!(g.contains('α'));
        assert!(g.contains("virtual makespan"));
    }

    #[test]
    fn empty_timeline_safe() {
        assert!(render(&[], 4, 40).contains("empty"));
    }
}
