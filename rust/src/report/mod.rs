//! Report emitters: ASCII tables, CSV files, line charts, Gantt timelines,
//! and the paper's qualitative tables/figures as generated text.

pub mod chart;
pub mod csv;
pub mod gantt;
pub mod paper;
pub mod table;

pub use chart::Chart;
pub use table::AsciiTable;
