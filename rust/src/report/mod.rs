//! Report emitters: ASCII tables, CSV files, line charts, Gantt timelines,
//! and the paper's qualitative tables/figures as generated text.
//!
//! The serving layer reuses [`AsciiTable`] for its `STATS` telemetry
//! (service-time, queue-wait, and batch-width summaries) so server-side
//! output renders in the same shape as the experiment reports.

pub mod chart;
pub mod csv;
pub mod gantt;
pub mod paper;
pub mod table;

pub use chart::Chart;
pub use table::AsciiTable;
