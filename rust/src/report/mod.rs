//! Report emitters: ASCII tables, CSV files, line charts, Gantt timelines,
//! and the paper's qualitative tables/figures as generated text.
//!
//! The serving layer reuses [`AsciiTable`] for its `STATS` telemetry
//! (service-time, queue-wait, batch-width, and per-dispatch-lane
//! summaries — the same block a `DRAIN` reports as its final snapshot)
//! so server-side output renders in the same shape as the experiment
//! reports.

pub mod chart;
pub mod csv;
pub mod gantt;
pub mod paper;
pub mod table;

pub use chart::Chart;
pub use table::AsciiTable;
