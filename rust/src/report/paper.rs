//! Generated renditions of the paper's *qualitative* artifacts: Table 1,
//! Table 2, Fig 1, Fig 3 and Fig 4. Where the paper asserts a threshold
//! qualitatively, these emitters substantiate it with numbers computed
//! from the live overhead model (crossover order, managed cutoff), so the
//! "analysis tables" stay consistent with the measured system.

use crate::overhead::{Manager, OverheadParams};
use crate::report::table::AsciiTable;
use crate::sort::SortCostModel;

/// Table 1: comparative scope analysis for matmul parallelization,
/// with the crossover threshold filled in from the model.
pub fn table1(params: &OverheadParams, cores: usize, matmul_op_ns: f64) -> String {
    let mgr = Manager::new(*params, cores);
    let cutoff_ns = mgr.serial_cutoff_ns(1.0, 1e12);
    let crossover_order = (cutoff_ns / matmul_op_ns).cbrt().round() as usize;
    let mut t = AsciiTable::new(
        "Table 1: Comparative scope analysis for parallelization of Matrix multiplication",
        &["Parameter", "Scope of Serialization", "Scope of Parallelization"],
    );
    t.row(vec![
        "Order of matrix".into(),
        format!("Best below order ≈{crossover_order} (model crossover)"),
        format!("Best above order ≈{crossover_order}; paper states ≥1000 on its 2022 testbed"),
    ]);
    t.row(vec![
        "Input management".into(),
        "Single core owns all input".into(),
        format!("Master-slave: master splits C's rows among {cores} cores"),
    ]);
    t.row(vec![
        "Processing methodology".into(),
        "Row-column products in serial order (iterative)".into(),
        "Row blocks distributed; inter-product additions stay core-local".into(),
    ]);
    t.row(vec![
        "Time requirements".into(),
        "Grows as n³·op; no setup cost".into(),
        format!(
            "α={:.0}ns/spawn + β={:.0}ns/sync + γ={:.0}ns/msg + δ={:.3}ns/B, amortized over n³/p",
            params.alpha_spawn_ns, params.beta_sync_ns, params.gamma_msg_ns, params.delta_byte_ns
        ),
    ]);
    t.row(vec![
        "Nature of overhead".into(),
        "Repetition of common computations".into(),
        "Thread creation + inter-core communication; output sync avoided by disjoint row blocks".into(),
    ]);
    t.render()
}

/// Table 2: parametric analysis for parallel quicksort, with the managed
/// cutoff substantiated from the model.
pub fn table2(params: &OverheadParams, cores: usize, model: &SortCostModel) -> String {
    let mgr = Manager::new(*params, cores);
    let cutoff = crate::sort::parallel::managed_cutoff(&mgr, model);
    let cutoff_s = if cutoff == usize::MAX { "∞ (never fork)".to_string() } else { format!("{cutoff}") };
    let mut t = AsciiTable::new(
        "Table 2: Parametric analysis for quick sort execution on parallel systems",
        &["Parameter", "Analysis for parallelization"],
    );
    t.row(vec!["Dependence".into(), "Pivot selection and its final placement".into()]);
    t.row(vec!["Input".into(), "Complete array, initially owned by the master thread".into()]);
    t.row(vec![
        "Pivot selection".into(),
        "left | mean (O(n) scan) | right | random (locked rand()) | median3".into(),
    ]);
    t.row(vec![
        "Pivot placement".into(),
        "By the master (one Lomuto pass) — avoids per-core re-analysis and swap".into(),
    ]);
    t.row(vec![
        "Scope of parallelism".into(),
        format!("After placement: halves fork recursively until segments < {cutoff_s} elements (managed grain)"),
    ]);
    t.row(vec![
        "Output".into(),
        "In-place disjoint sub-arrays — no duplicated indices, no copy-back".into(),
    ]);
    t.row(vec![
        "Overhead observed".into(),
        format!(
            "Per fork: α={:.0}ns; per join: β={:.0}ns; migration γ={:.0}ns + δ·bytes",
            params.alpha_spawn_ns, params.beta_sync_ns, params.gamma_msg_ns
        ),
    ]);
    t.render()
}

/// Fig 1: overhead analysis + management methodology for matmul (flow text).
pub fn fig1() -> String {
    r#"Figure 1: Overhead analysis of matrix multiplication on parallel platforms
┌─────────────────────────────────────────────────────────────────────────┐
│ OVERHEAD REASONING              │ PROBLEM SCOPE                         │
│  thread creation (α)            │   C[i,:] = Σ_k A[i,k]·B[k,:]          │
│  synchronization (β) at joins   │   row-column ops independent;         │
│  inter-core messages (γ, δ·B)   │   inter-product adds dependent        │
│  fragmentation ⇒ sync per add   │   within one output element           │
├─────────────────────────────────┴───────────────────────────────────────┤
│ METHODOLOGY FOR OVERHEAD MANAGEMENT                                     │
│  1. estimate work  W = m·k·n · op_ns        (calibrated)                │
│  2. predict  T_par(p, tasks) = W/p·balance + α·t + β·t + γ·m + δ·b      │
│  3. FORK-JOIN SWITCH: serial if T_par ≥ T_serial, else fork             │
│  4. master-slave row blocks: disjoint writes ⇒ no output sync           │
│  5. keep inter-product additions core-local (no per-add sync)           │
└─────────────────────────────────────────────────────────────────────────┘
"#
    .to_string()
}

/// Fig 3: the serial quicksort algorithm (executable listing reference).
pub fn fig3() -> String {
    r#"Figure 3: Algorithm for quick sort serial execution
 1. procedure QUICKSORT(A, q, r)            -- rust: sort::serial_quicksort
 2.   if q < r then
 3.     x := pivot(A, strategy)             -- Fig-3 original: x := A[q]
 4.     s := partition(A, q, r, x)          -- Lomuto, instrumented
 5.     QUICKSORT(A, q, s-1)                -- recurse smaller side first
 6.     QUICKSORT(A, s+1, r)                -- (stack-bounded)
 7. end QUICKSORT
   -- parallel variant (Fig 4): steps 5 and 6 become pool.join(...) once
   -- the segment is larger than the managed cutoff.
"#
    .to_string()
}

/// Fig 4: workflow for parallel quicksort execution.
pub fn fig4() -> String {
    r#"Figure 4: Work flow for execution of quick sort on parallel platform
        ┌────────────────────────────┐
        │ master: full array of n    │
        └──────────────┬─────────────┘
                       ▼
        ┌────────────────────────────┐
        │ select pivot (strategy)    │──── mean: O(n) scan; random: locked rand()
        │ place pivot (1 Lomuto pass)│
        └──────┬──────────────┬──────┘
               ▼              ▼
        ┌────────────┐  ┌────────────┐
        │ left part  │  │ right part │   fork (α) ×2, distribute (γ, δ·bytes)
        │ → core A   │  │ → core B   │
        └──────┬─────┘  └─────┬──────┘
               ▼              ▼
          recurse while  segment > managed cutoff, else serial leaf
               ▼              ▼
        ┌────────────────────────────┐
        │ join barrier (β) — output  │
        │ already in place, no merge │
        └────────────────────────────┘
"#
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_mentions_model_crossover() {
        let s = table1(&OverheadParams::paper_2022(), 4, 1.0);
        assert!(s.contains("Order of matrix"));
        assert!(s.contains("crossover"));
        assert!(s.contains("Master-slave"));
    }

    #[test]
    fn table2_has_finite_cutoff() {
        let s = table2(&OverheadParams::paper_2022(), 4, &SortCostModel::paper_2022());
        assert!(s.contains("Pivot placement"));
        assert!(!s.contains("∞"), "4-core paper model must fork eventually:\n{s}");
    }

    #[test]
    fn table2_single_core_never_forks() {
        let s = table2(&OverheadParams::paper_2022(), 1, &SortCostModel::paper_2022());
        assert!(s.contains("∞"));
    }

    #[test]
    fn figures_nonempty() {
        for s in [fig1(), fig3(), fig4()] {
            assert!(s.lines().count() > 5);
        }
        assert!(fig4().contains("join barrier"));
        assert!(fig3().contains("QUICKSORT"));
    }
}
