//! Aligned ASCII tables (the console form of every paper table).

/// Column-aligned table builder.
#[derive(Debug, Clone)]
pub struct AsciiTable {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl AsciiTable {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        AsciiTable {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep = |l: &str, m: &str, r: &str| {
            let mut s = String::from(l);
            for (i, w) in widths.iter().enumerate() {
                s.push_str(&"─".repeat(w + 2));
                s.push_str(if i + 1 < ncol { m } else { r });
            }
            s.push('\n');
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("│");
            for (c, w) in cells.iter().zip(&widths) {
                let pad = w - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('│');
            }
            s.push('\n');
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep("┌", "┬", "┐"));
        out.push_str(&fmt_row(&self.headers));
        out.push_str(&sep("├", "┼", "┤"));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out.push_str(&sep("└", "┴", "┘"));
        out
    }
}

/// Format a float with `d` decimals (table-cell convenience).
pub fn f(v: f64, d: usize) -> String {
    format!("{v:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = AsciiTable::new("T", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("T\n"));
        assert!(s.contains("│ name   │ value │"));
        assert!(s.contains("│ longer │ 22    │"));
        let widths: Vec<usize> =
            s.lines().filter(|l| l.starts_with('│')).map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "ragged table:\n{s}");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        AsciiTable::new("", &["a", "b"]).row(vec!["only-one".into()]);
    }

    #[test]
    fn float_helper() {
        assert_eq!(f(1.23456, 3), "1.235");
        assert_eq!(f(2.0, 0), "2");
    }
}
