//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust loader.
//!
//! `artifacts/manifest.tsv` has one tab-separated line per artifact:
//!
//! ```text
//! name \t file \t n_inputs \t input_specs \t output_spec
//! ```
//!
//! where a spec is `dtype:d0xd1x...` (`float32:1000x1000`) or
//! `dtype:scalar`, and input_specs are `;`-joined. Keep in sync with
//! `aot.py::_fmt_spec`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor dtype + shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Parse `float32:32x16` / `int32:5` / `float32:scalar`.
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let (dtype, dims_s) = s.split_once(':').with_context(|| format!("bad spec {s:?}"))?;
        let dims = if dims_s == "scalar" {
            Vec::new()
        } else {
            dims_s
                .split('x')
                .map(|d| d.parse::<usize>().with_context(|| format!("bad dim in {s:?}")))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { dtype: dtype.to_string(), dims })
    }

    pub fn elem_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.dims.iter().map(|&d| d as i64).collect()
    }
}

/// One loadable artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

/// The parsed manifest (ordered for stable listings).
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` anchors the per-artifact file paths.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut artifacts = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 5 {
                bail!("manifest line {}: expected 5 columns, got {}", lineno + 1, cols.len());
            }
            let n_inputs: usize = cols[2].parse().context("n_inputs")?;
            let inputs = cols[3]
                .split(';')
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            if inputs.len() != n_inputs {
                bail!("manifest line {}: n_inputs {} != {} specs", lineno + 1, n_inputs, inputs.len());
            }
            let spec = ArtifactSpec {
                name: cols[0].to_string(),
                path: dir.join(cols[1]),
                inputs,
                output: TensorSpec::parse(cols[4])?,
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_parse() {
        let t = TensorSpec::parse("float32:32x16").unwrap();
        assert_eq!(t.dtype, "float32");
        assert_eq!(t.dims, vec![32, 16]);
        assert_eq!(t.elem_count(), 512);
        assert_eq!(t.dims_i64(), vec![32i64, 16]);
        let s = TensorSpec::parse("float32:scalar").unwrap();
        assert!(s.dims.is_empty());
        assert_eq!(s.elem_count(), 1);
        assert!(TensorSpec::parse("junk").is_err());
        assert!(TensorSpec::parse("f32:axb").is_err());
    }

    #[test]
    fn manifest_parse_roundtrip() {
        let text = "matmul_64\tmatmul_64.hlo.txt\t2\tfloat32:64x64;float32:64x64\tfloat32:64x64\n\
                    bitonic_8\tbitonic_8.hlo.txt\t1\tfloat32:8\tfloat32:8\n";
        let m = Manifest::parse(text, Path::new("/arts")).unwrap();
        assert_eq!(m.names(), vec!["bitonic_8", "matmul_64"]);
        let mm = m.get("matmul_64").unwrap();
        assert_eq!(mm.inputs.len(), 2);
        assert_eq!(mm.path, Path::new("/arts/matmul_64.hlo.txt"));
        assert_eq!(mm.output.dims, vec![64, 64]);
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("too\tfew\tcols\n", Path::new(".")).is_err());
        assert!(
            Manifest::parse("x\tf\t2\tfloat32:4\tfloat32:4\n", Path::new(".")).is_err(),
            "n_inputs mismatch must fail"
        );
    }

    #[test]
    fn manifest_skips_blank_and_comment_lines() {
        let text = "# comment\n\nbitonic_8\tb.hlo.txt\t1\tfloat32:8\tfloat32:8\n";
        let m = Manifest::parse(text, Path::new(".")).unwrap();
        assert_eq!(m.artifacts.len(), 1);
    }

    #[test]
    fn real_manifest_loads_when_built() {
        // Integration-ish: only when `make artifacts` has run.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("matmul_64").is_some());
            assert!(m.get("bitonic_1000").is_some());
            for a in m.artifacts.values() {
                assert!(a.path.exists(), "{} missing", a.path.display());
            }
        }
    }
}
