//! PJRT execution of AOT artifacts.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU plugin): load HLO
//! *text* (`HloModuleProto::from_text_file` — the id-safe interchange, see
//! aot.py), compile once per artifact on the PJRT CPU client, cache the
//! loaded executable, and execute with `f32` buffers. Python never runs
//! here; after `make artifacts` the binary is self-contained.

use super::artifact::{ArtifactSpec, Manifest};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A compiled artifact handle.
struct LoadedExec {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

/// The runtime: PJRT client + artifact manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, &'static LoadedExec>>,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("artifacts", &self.manifest.artifacts.len())
            .finish()
    }
}

impl Runtime {
    /// Open the artifact directory (default `artifacts/` at the repo root).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Conventional artifact directory: `$OHM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("OHM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named artifact.
    ///
    /// Executables are leaked intentionally: they live as long as the
    /// process, which matches the serving pattern (compile once, execute
    /// many) and sidesteps the `xla` crate's non-Sync handles.
    fn get_exec(&self, name: &str) -> Result<&'static LoadedExec> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e);
        }
        let spec = self
            .manifest
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}; have {:?}", self.manifest.names()))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .with_context(|| format!("parsing HLO text {}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let leaked: &'static LoadedExec = Box::leak(Box::new(LoadedExec { exe, spec }));
        self.cache.lock().unwrap().insert(name.to_string(), leaked);
        Ok(leaked)
    }

    /// Pre-compile an artifact (warm the cache); returns its spec.
    pub fn warm(&self, name: &str) -> Result<&ArtifactSpec> {
        Ok(&self.get_exec(name)?.spec)
    }

    /// Execute artifact `name` on f32 inputs; returns the flat f32 output.
    ///
    /// Inputs are validated against the manifest specs (count + element
    /// counts); dtype must be float32 for every artifact in this repo.
    pub fn exec_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let le = self.get_exec(name)?;
        if inputs.len() != le.spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", le.spec.inputs.len(), inputs.len());
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (i, (buf, spec)) in inputs.iter().zip(&le.spec.inputs).enumerate() {
            if spec.dtype != "float32" {
                bail!("{name}: input {i} dtype {} unsupported by exec_f32", spec.dtype);
            }
            if buf.len() != spec.elem_count() {
                bail!("{name}: input {i} has {} elems, expected {}", buf.len(), spec.elem_count());
            }
            let lit = xla::Literal::vec1(buf);
            let lit = if spec.dims.len() == 1 {
                lit
            } else {
                lit.reshape(&spec.dims_i64()).context("reshape input")?
            };
            lits.push(lit);
        }
        let result = le.exe.execute::<xla::Literal>(&lits).context("execute")?;
        let out_lit = result[0][0].to_literal_sync().context("fetch output")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = out_lit.to_tuple1().context("untuple output")?;
        let v = out.to_vec::<f32>().context("output to_vec")?;
        if v.len() != le.spec.output.elem_count() {
            bail!("{name}: output has {} elems, expected {}", v.len(), le.spec.output.elem_count());
        }
        Ok(v)
    }

    /// Names of artifacts present (sorted).
    pub fn names(&self) -> Vec<&str> {
        self.manifest.names()
    }
}

// Note: unit tests for the client live in `rust/tests/integration_runtime.rs`
// because they need built artifacts; manifest parsing is covered in
// `artifact.rs`.
