//! XLA/PJRT runtime: load and execute the AOT-compiled JAX+Pallas
//! artifacts from the rust hot path (L3 → L2/L1 bridge).
//!
//! Build-time: `make artifacts` runs `python -m compile.aot`, lowering the
//! L2 models (which call the L1 Pallas kernels with `interpret=True`) to
//! HLO text + `manifest.tsv`. Run-time: [`Runtime`] compiles each artifact
//! once on the PJRT CPU client and executes it with `f32` buffers —
//! Python is never on the request path.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use client::Runtime;

use crate::dla::Matrix;
use anyhow::{bail, Result};

/// Multiply square matrices through the `matmul_<n>` artifact.
pub fn matmul_xla(rt: &Runtime, a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.rows() != a.cols() || b.rows() != b.cols() || a.rows() != b.rows() {
        bail!(
            "matmul_xla handles square equal-order matrices, got {}x{} · {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        );
    }
    let n = a.rows();
    // §Perf: prefer the native-dot artifact when present — on the CPU
    // PJRT plugin it outperforms the interpret-lowered Pallas tile loop
    // (on a real TPU the preference would flip to the Mosaic build).
    let native = format!("matmul_native_{n}");
    let name = if rt.manifest().get(&native).is_some() { native } else { format!("matmul_{n}") };
    let out = rt.exec_f32(&name, &[a.data(), b.data()])?;
    Ok(Matrix::from_vec(n, n, out))
}

/// Sort f32 values ascending through the `bitonic_<n>` artifact.
pub fn sort_xla(rt: &Runtime, xs: &[f32]) -> Result<Vec<f32>> {
    let name = format!("bitonic_{}", xs.len());
    rt.exec_f32(&name, &[xs])
}

/// True if an artifact for a square matmul of order `n` exists.
pub fn has_matmul(rt: &Runtime, n: usize) -> bool {
    rt.manifest().get(&format!("matmul_{n}")).is_some()
}

/// True if an artifact for a bitonic sort of length `n` exists.
pub fn has_sort(rt: &Runtime, n: usize) -> bool {
    rt.manifest().get(&format!("bitonic_{n}")).is_some()
}
