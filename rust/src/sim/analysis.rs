//! Schedule analysis: quantitative overhead breakdown of a simulated run.
//!
//! The paper's Fig 1 reasons about overheads qualitatively; this module
//! measures them per run: how much virtual machine-time went to compute,
//! spawn overhead (α), synchronization (β), and idle — plus critical-path
//! utilization. Rendered by `ohm gantt` and usable programmatically.

use super::machine::{SegKind, Segment, SimReport};

/// Machine-time breakdown of one schedule (all in ns · cores).
#[derive(Debug, Clone, PartialEq)]
pub struct Breakdown {
    pub compute_ns: f64,
    pub spawn_ns: f64,
    pub sync_ns: f64,
    pub idle_ns: f64,
    pub makespan_ns: f64,
    pub cores: usize,
}

impl Breakdown {
    /// Analyze a traced report (needs `trace=true` timelines).
    pub fn of(report: &SimReport) -> Breakdown {
        let cores = report.core_busy_ns.len();
        let mut b = Breakdown {
            compute_ns: 0.0,
            spawn_ns: 0.0,
            sync_ns: 0.0,
            idle_ns: 0.0,
            makespan_ns: report.makespan_ns,
            cores,
        };
        for seg in &report.timeline {
            let d = seg.end_ns - seg.start_ns;
            match seg.kind {
                SegKind::Work => b.compute_ns += d,
                SegKind::Spawn => b.spawn_ns += d,
                SegKind::Sync => b.sync_ns += d,
            }
        }
        b.idle_ns = (report.makespan_ns * cores as f64
            - (b.compute_ns + b.spawn_ns + b.sync_ns))
            .max(0.0);
        b
    }

    /// Total machine-time rectangle.
    pub fn rect_ns(&self) -> f64 {
        self.makespan_ns * self.cores as f64
    }

    /// Fraction of machine time spent computing (the paper's "effective
    /// parallelization" measure).
    pub fn compute_fraction(&self) -> f64 {
        if self.rect_ns() == 0.0 {
            return 0.0;
        }
        self.compute_ns / self.rect_ns()
    }

    /// Fraction lost to explicit overheads (α + β segments).
    pub fn overhead_fraction(&self) -> f64 {
        if self.rect_ns() == 0.0 {
            return 0.0;
        }
        (self.spawn_ns + self.sync_ns) / self.rect_ns()
    }

    /// One-line report.
    pub fn summary(&self) -> String {
        format!(
            "machine-time: compute {:.1}%  spawn(α) {:.1}%  sync(β) {:.1}%  idle {:.1}%  (makespan {:.1} µs × {} cores)",
            100.0 * self.compute_fraction(),
            100.0 * self.spawn_ns / self.rect_ns().max(1e-12),
            100.0 * self.sync_ns / self.rect_ns().max(1e-12),
            100.0 * self.idle_ns / self.rect_ns().max(1e-12),
            self.makespan_ns / 1e3,
            self.cores
        )
    }
}

/// Longest chain of segments linked by (end → start) on the timeline —
/// an observable lower bound proxy for the schedule's critical path.
pub fn busiest_core(timeline: &[Segment], cores: usize) -> (usize, f64) {
    let mut busy = vec![0.0f64; cores];
    for s in timeline {
        busy[s.core] += s.end_ns - s.start_ns;
    }
    busy
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, &v)| (i, v))
        .unwrap_or((0, 0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overhead::OverheadParams;
    use crate::sim::{Machine, Node};

    fn traced(cores: usize) -> SimReport {
        let tree = Node::Par {
            branches: vec![
                Node::Leaf { work_ns: 4000.0, label: "w" },
                Node::Leaf { work_ns: 6000.0, label: "w" },
            ],
            bytes: vec![64, 64],
        };
        Machine::new(cores, OverheadParams::paper_2022()).run(&tree, true)
    }

    #[test]
    fn breakdown_conserves_machine_time() {
        let rep = traced(2);
        let b = Breakdown::of(&rep);
        let sum = b.compute_ns + b.spawn_ns + b.sync_ns + b.idle_ns;
        assert!((sum - b.rect_ns()).abs() < 1.0, "{sum} vs {}", b.rect_ns());
        assert!((b.compute_ns - 10_000.0).abs() < 1e-6);
        assert!(b.spawn_ns > 0.0 && b.sync_ns > 0.0);
    }

    #[test]
    fn fractions_in_unit_range() {
        let b = Breakdown::of(&traced(4));
        for f in [b.compute_fraction(), b.overhead_fraction()] {
            assert!((0.0..=1.0).contains(&f), "{f}");
        }
        assert!(b.summary().contains("compute"));
    }

    #[test]
    fn busiest_core_identified() {
        let rep = traced(2);
        let (core, busy) = busiest_core(&rep.timeline, 2);
        assert!(core < 2);
        assert!(busy >= 6000.0, "must include the long branch: {busy}");
    }

    #[test]
    fn serial_tree_is_all_compute_no_overhead() {
        let tree = Node::Leaf { work_ns: 1000.0, label: "w" };
        let rep = Machine::new(1, OverheadParams::paper_2022()).run(&tree, true);
        let b = Breakdown::of(&rep);
        assert!((b.compute_fraction() - 1.0).abs() < 1e-9);
        assert_eq!(b.overhead_fraction(), 0.0);
    }
}
