//! Series-parallel task-graph recording.
//!
//! Domain algorithms run **once, single-threaded, for real** (producing
//! correct results) against a [`SimCtx`]; the context records the fork-join
//! structure and per-segment work costs as a series-parallel [`Node`] tree.
//! [`super::machine::Machine`] then schedules that tree on N virtual cores.
//!
//! This mirrors how the paper separates *problem scope* (the dependency
//! structure, Figs 1 and 4) from *execution platform* (the multicore
//! machine): the tree is the problem scope; the machine is the platform.

/// A series-parallel computation tree.
#[derive(Debug, Clone)]
pub enum Node {
    /// A sequential segment of pure compute.
    Leaf { work_ns: f64, label: &'static str },
    /// Sequential composition.
    Seq(Vec<Node>),
    /// Parallel region (fork-join): branch `i` receives `bytes[i]` of input
    /// data (the master-slave distribution cost).
    Par { branches: Vec<Node>, bytes: Vec<u64> },
}

impl Node {
    /// Total compute in the tree (= serial execution time, ns).
    pub fn total_work_ns(&self) -> f64 {
        match self {
            Node::Leaf { work_ns, .. } => *work_ns,
            Node::Seq(parts) => parts.iter().map(|n| n.total_work_ns()).sum(),
            Node::Par { branches, .. } => branches.iter().map(|n| n.total_work_ns()).sum(),
        }
    }

    /// Critical-path compute (infinite cores, zero overheads), ns.
    pub fn span_ns(&self) -> f64 {
        match self {
            Node::Leaf { work_ns, .. } => *work_ns,
            Node::Seq(parts) => parts.iter().map(|n| n.span_ns()).sum(),
            Node::Par { branches, .. } => {
                branches.iter().map(|n| n.span_ns()).fold(0.0, f64::max)
            }
        }
    }

    /// Number of parallel branches in the whole tree (spawn count).
    pub fn spawn_count(&self) -> u64 {
        match self {
            Node::Leaf { .. } => 0,
            Node::Seq(parts) => parts.iter().map(|n| n.spawn_count()).sum(),
            Node::Par { branches, .. } => {
                branches.len() as u64 + branches.iter().map(|n| n.spawn_count()).sum::<u64>()
            }
        }
    }
}

/// Recording context passed through a simulated algorithm.
#[derive(Debug, Default)]
pub struct SimCtx {
    parts: Vec<Node>,
}

impl SimCtx {
    pub fn new() -> Self {
        SimCtx { parts: Vec::new() }
    }

    /// Record `ns` of sequential compute. Adjacent work segments with the
    /// same label are merged (keeps the task graph small).
    pub fn work(&mut self, ns: f64, label: &'static str) {
        debug_assert!(ns >= 0.0);
        if let Some(Node::Leaf { work_ns, label: l }) = self.parts.last_mut() {
            if *l == label {
                *work_ns += ns;
                return;
            }
        }
        self.parts.push(Node::Leaf { work_ns: ns, label });
    }

    /// Record a binary fork-join; closures run immediately (real results),
    /// their structure recorded as parallel branches. `bytes` are the
    /// distribution payloads for (a, b).
    pub fn join<RA, RB>(
        &mut self,
        bytes: (u64, u64),
        a: impl FnOnce(&mut SimCtx) -> RA,
        b: impl FnOnce(&mut SimCtx) -> RB,
    ) -> (RA, RB) {
        let mut ca = SimCtx::new();
        let ra = a(&mut ca);
        let mut cb = SimCtx::new();
        let rb = b(&mut cb);
        self.parts.push(Node::Par {
            branches: vec![ca.into_node(), cb.into_node()],
            bytes: vec![bytes.0, bytes.1],
        });
        (ra, rb)
    }

    /// Record an N-way fork-join (master-slave distribution): `f` is called
    /// once per element of `inputs` with a fresh child context.
    pub fn fork_each<T, R>(
        &mut self,
        inputs: Vec<(T, u64)>, // (input, distribution bytes)
        mut f: impl FnMut(T, &mut SimCtx) -> R,
    ) -> Vec<R> {
        let mut branches = Vec::with_capacity(inputs.len());
        let mut bytes = Vec::with_capacity(inputs.len());
        let mut results = Vec::with_capacity(inputs.len());
        for (input, b) in inputs {
            let mut c = SimCtx::new();
            results.push(f(input, &mut c));
            branches.push(c.into_node());
            bytes.push(b);
        }
        if !branches.is_empty() {
            self.parts.push(Node::Par { branches, bytes });
        }
        results
    }

    /// Finish recording, yielding the tree.
    pub fn into_node(mut self) -> Node {
        match self.parts.len() {
            0 => Node::Leaf { work_ns: 0.0, label: "empty" },
            1 => self.parts.pop().unwrap(),
            _ => Node::Seq(self.parts),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn work_merges_same_label() {
        let mut c = SimCtx::new();
        c.work(10.0, "a");
        c.work(5.0, "a");
        c.work(1.0, "b");
        let n = c.into_node();
        match &n {
            Node::Seq(parts) => assert_eq!(parts.len(), 2),
            _ => panic!("expected Seq, got {n:?}"),
        }
        assert!((n.total_work_ns() - 16.0).abs() < 1e-12);
    }

    #[test]
    fn join_records_par_and_returns_results() {
        let mut c = SimCtx::new();
        let (a, b) = c.join(
            (100, 200),
            |ca| {
                ca.work(30.0, "l");
                1
            },
            |cb| {
                cb.work(50.0, "r");
                2
            },
        );
        assert_eq!((a, b), (1, 2));
        let n = c.into_node();
        assert!((n.total_work_ns() - 80.0).abs() < 1e-12);
        assert!((n.span_ns() - 50.0).abs() < 1e-12);
        assert_eq!(n.spawn_count(), 2);
    }

    #[test]
    fn nested_join_span() {
        let mut c = SimCtx::new();
        c.join(
            (0, 0),
            |l| {
                l.join((0, 0), |x| x.work(10.0, "w"), |y| y.work(20.0, "w"));
            },
            |r| r.work(25.0, "w"),
        );
        let n = c.into_node();
        assert!((n.total_work_ns() - 55.0).abs() < 1e-12);
        assert!((n.span_ns() - 25.0).abs() < 1e-12, "span {}", n.span_ns());
        assert_eq!(n.spawn_count(), 4);
    }

    #[test]
    fn fork_each_collects_results_in_order() {
        let mut c = SimCtx::new();
        let rs = c.fork_each(vec![(1, 8), (2, 8), (3, 8)], |x, cc| {
            cc.work(x as f64, "chunk");
            x * 10
        });
        assert_eq!(rs, vec![10, 20, 30]);
        let n = c.into_node();
        assert_eq!(n.spawn_count(), 3);
        assert!((n.span_ns() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_ctx_is_zero_work() {
        let n = SimCtx::new().into_node();
        assert_eq!(n.total_work_ns(), 0.0);
        assert_eq!(n.spawn_count(), 0);
    }
}
