//! Deterministic discrete-event multicore machine.
//!
//! Flattens a series-parallel [`Node`] tree into an atomic-task DAG
//! (fork/join pseudo-tasks carry the α/β overhead charges; distribution
//! edges carry γ/δ when they cross cores) and schedules it with a greedy,
//! locality-aware, earliest-start list scheduler. Everything is integer-id
//! ordered, so a given (tree, machine) pair always produces the identical
//! schedule — bit-reproducible experiments.

use super::graph::Node;
use crate::overhead::{Ledger, OverheadParams};

/// Machine description: core count + calibrated overhead parameters.
#[derive(Debug, Clone)]
pub struct Machine {
    pub cores: usize,
    pub params: OverheadParams,
    /// Relative speed per core (1.0 = nominal). Homogeneous machines use
    /// an empty vec; heterogeneous ones (the paper's ref [1] "adaptive
    /// multi-core" setting) give e.g. `[2.0, 1.0, 1.0, 0.5]` — a task of
    /// `d` nominal ns takes `d / speed[c]` on core `c`.
    pub core_speeds: Vec<f64>,
}

/// What a scheduled segment was doing (for Gantt rendering).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegKind {
    Work,
    Spawn,
    Sync,
}

/// One scheduled interval on one core.
#[derive(Debug, Clone)]
pub struct Segment {
    pub core: usize,
    pub start_ns: f64,
    pub end_ns: f64,
    pub kind: SegKind,
    pub label: &'static str,
}

/// Result of simulating one computation tree.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Virtual wall-clock of the parallel schedule, ns.
    pub makespan_ns: f64,
    /// Serial execution time (= total compute), ns.
    pub serial_ns: f64,
    /// Overhead event accounting.
    pub ledger: Ledger,
    /// Per-core busy time, ns.
    pub core_busy_ns: Vec<f64>,
    /// Full schedule (Gantt) — only populated when `trace` was requested.
    pub timeline: Vec<Segment>,
}

impl SimReport {
    pub fn speedup(&self) -> f64 {
        if self.makespan_ns == 0.0 {
            1.0
        } else {
            self.serial_ns / self.makespan_ns
        }
    }

    pub fn time_us(&self) -> f64 {
        self.makespan_ns / 1e3
    }

    /// Total idle as a fraction of the machine-time rectangle.
    pub fn idle_fraction(&self) -> f64 {
        let rect = self.makespan_ns * self.core_busy_ns.len() as f64;
        if rect == 0.0 {
            0.0
        } else {
            (rect - self.core_busy_ns.iter().sum::<f64>()) / rect
        }
    }
}

// ---------------------------------------------------------------------------
// DAG flattening
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct Task {
    dur_ns: f64,
    kind: SegKind,
    label: &'static str,
    /// (pred task id, bytes shipped over that edge).
    preds: Vec<(usize, u64)>,
    succs: Vec<usize>,
    indegree: usize,
}

struct Dag {
    tasks: Vec<Task>,
    spawns: u64,
    syncs: u64,
}

impl Dag {
    fn push(&mut self, dur_ns: f64, kind: SegKind, label: &'static str) -> usize {
        self.tasks.push(Task { dur_ns, kind, label, preds: Vec::new(), succs: Vec::new(), indegree: 0 });
        self.tasks.len() - 1
    }

    fn edge(&mut self, from: usize, to: usize, bytes: u64) {
        self.tasks[to].preds.push((from, bytes));
        self.tasks[to].indegree += 1;
        self.tasks[from].succs.push(to);
    }

    /// Flatten `node` after `entry`; returns the exit task id.
    fn flatten(&mut self, node: &Node, entry: usize, params: &OverheadParams) -> usize {
        match node {
            Node::Leaf { work_ns, label } => {
                let t = self.push(*work_ns, SegKind::Work, label);
                self.edge(entry, t, 0);
                t
            }
            Node::Seq(parts) => {
                let mut cur = entry;
                for p in parts {
                    cur = self.flatten(p, cur, params);
                }
                cur
            }
            Node::Par { branches, bytes } => {
                let k = branches.len();
                self.spawns += k as u64;
                self.syncs += k as u64;
                // Fork pseudo-task: the master pays α per spawned task.
                let fork = self.push(params.alpha_spawn_ns * k as f64, SegKind::Spawn, "fork");
                self.edge(entry, fork, 0);
                // Join pseudo-task: β per task joining the barrier.
                let join = self.push(params.beta_sync_ns * k as f64, SegKind::Sync, "join");
                for (i, b) in branches.iter().enumerate() {
                    let sink = self.flatten_with_entry_bytes(b, fork, bytes[i], params);
                    self.edge(sink, join, 0);
                }
                join
            }
        }
    }

    /// Like `flatten` but the edge out of `entry` carries `bytes`
    /// (the master-slave distribution payload for this branch).
    fn flatten_with_entry_bytes(
        &mut self,
        node: &Node,
        entry: usize,
        bytes: u64,
        params: &OverheadParams,
    ) -> usize {
        match node {
            Node::Leaf { work_ns, label } => {
                let t = self.push(*work_ns, SegKind::Work, label);
                self.edge(entry, t, bytes);
                t
            }
            Node::Seq(parts) => {
                let mut iter = parts.iter();
                let first = iter.next().expect("Seq is never empty");
                let mut cur = self.flatten_with_entry_bytes(first, entry, bytes, params);
                for p in iter {
                    cur = self.flatten(p, cur, params);
                }
                cur
            }
            Node::Par { .. } => {
                // A Par directly under a Par: route bytes into its fork task.
                let stub = self.push(0.0, SegKind::Work, "recv");
                self.edge(entry, stub, bytes);
                self.flatten(node, stub, params)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scheduling
// ---------------------------------------------------------------------------

impl Machine {
    pub fn new(cores: usize, params: OverheadParams) -> Self {
        assert!(cores >= 1);
        Machine { cores, params, core_speeds: Vec::new() }
    }

    /// Heterogeneous machine: one entry per core, relative speed > 0.
    pub fn heterogeneous(speeds: Vec<f64>, params: OverheadParams) -> Self {
        assert!(!speeds.is_empty() && speeds.iter().all(|&s| s > 0.0));
        Machine { cores: speeds.len(), params, core_speeds: speeds }
    }

    #[inline]
    fn speed(&self, core: usize) -> f64 {
        self.core_speeds.get(core).copied().unwrap_or(1.0)
    }

    /// Simulate the tree; `trace` controls whether the full Gantt timeline
    /// is recorded (costs memory for big graphs).
    pub fn run(&self, tree: &Node, trace: bool) -> SimReport {
        let mut dag = Dag { tasks: Vec::new(), spawns: 0, syncs: 0 };
        let root = dag.push(0.0, SegKind::Work, "start");
        let _exit = dag.flatten(tree, root, &self.params);

        let n = dag.tasks.len();
        let mut finish = vec![0.0f64; n];
        let mut placed_core = vec![usize::MAX; n];
        let mut core_free = vec![0.0f64; self.cores];
        let mut core_busy = vec![0.0f64; self.cores];
        let mut indeg: Vec<usize> = dag.tasks.iter().map(|t| t.indegree).collect();
        let mut timeline = Vec::new();

        let mut messages = 0u64;
        let mut bytes_moved = 0u64;

        // Ready pool ordered by (earliest data-ready time, id) — binary heap
        // keyed on readiness keeps the event-driven order deterministic.
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        #[derive(PartialEq)]
        struct Ready(f64, usize);
        impl Eq for Ready {}
        impl PartialOrd for Ready {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for Ready {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                self.0.partial_cmp(&o.0).unwrap().then(self.1.cmp(&o.1))
            }
        }

        let mut heap: BinaryHeap<Reverse<Ready>> = BinaryHeap::new();
        heap.push(Reverse(Ready(0.0, root)));

        let mut scheduled = 0usize;
        while let Some(Reverse(Ready(_, tid))) = heap.pop() {
            scheduled += 1;
            // Pick the core minimizing actual start time; prefer the core
            // of the heaviest-payload predecessor on ties (locality).
            let task = &dag.tasks[tid];
            let mut best_core = 0usize;
            let mut best_start = f64::INFINITY;
            let mut best_finish = f64::INFINITY;
            for c in 0..self.cores {
                let mut data_ready = 0.0f64;
                for &(p, by) in &task.preds {
                    let mut t = finish[p];
                    if placed_core[p] != c && placed_core[p] != usize::MAX {
                        t += self.params.gamma_msg_ns + self.params.delta_byte_ns * by as f64;
                    }
                    data_ready = data_ready.max(t);
                }
                // Earliest *finish* time drives the choice on heterogeneous
                // machines (a slow core can start earlier yet finish later).
                let start = data_ready.max(core_free[c]);
                let finish_c = start + task.dur_ns / self.speed(c);
                if finish_c < best_finish {
                    best_finish = finish_c;
                    best_start = start;
                    best_core = c;
                }
            }
            // Charge communication for the chosen placement.
            for &(p, by) in &task.preds {
                if placed_core[p] != best_core && placed_core[p] != usize::MAX {
                    messages += 1;
                    bytes_moved += by;
                }
            }
            let scaled_dur = dag.tasks[tid].dur_ns / self.speed(best_core);
            let end = best_start + scaled_dur;
            finish[tid] = end;
            placed_core[tid] = best_core;
            core_free[best_core] = end;
            core_busy[best_core] += scaled_dur;
            if trace && dag.tasks[tid].dur_ns > 0.0 {
                timeline.push(Segment {
                    core: best_core,
                    start_ns: best_start,
                    end_ns: end,
                    kind: dag.tasks[tid].kind,
                    label: dag.tasks[tid].label,
                });
            }
            // Release successors.
            let succs = dag.tasks[tid].succs.clone();
            for s in succs {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    // Earliest possible readiness (same-core bound).
                    let ready = dag.tasks[s]
                        .preds
                        .iter()
                        .map(|&(p, _)| finish[p])
                        .fold(0.0, f64::max);
                    heap.push(Reverse(Ready(ready, s)));
                }
            }
        }
        assert_eq!(scheduled, n, "DAG had unreachable tasks (cycle?)");

        let makespan = finish.iter().copied().fold(0.0, f64::max);
        let serial = tree.total_work_ns();
        let compute: f64 = dag
            .tasks
            .iter()
            .filter(|t| t.kind == SegKind::Work)
            .map(|t| t.dur_ns)
            .sum();
        debug_assert!((compute - serial).abs() <= 1e-6 * serial.max(1.0));
        let idle: f64 = makespan * self.cores as f64 - core_busy.iter().sum::<f64>();

        SimReport {
            makespan_ns: makespan,
            serial_ns: serial,
            ledger: Ledger {
                spawns: dag.spawns,
                syncs: dag.syncs,
                messages,
                steals: 0,
                sheds: 0,
                cache_hits: 0,
                inline_serial: 0,
                faults: 0,
                bytes: bytes_moved,
                queue_ns: 0,
                compute_ns: compute as u64,
                idle_ns: idle.max(0.0) as u64,
            },
            core_busy_ns: core_busy,
            timeline,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::graph::SimCtx;

    fn leafy(ns: f64) -> Node {
        Node::Leaf { work_ns: ns, label: "w" }
    }

    #[test]
    fn sequential_tree_is_sum() {
        let m = Machine::new(4, OverheadParams::ideal());
        let tree = Node::Seq(vec![leafy(10.0), leafy(20.0), leafy(30.0)]);
        let r = m.run(&tree, false);
        assert!((r.makespan_ns - 60.0).abs() < 1e-9);
        assert!((r.serial_ns - 60.0).abs() < 1e-9);
        assert!((r.speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ideal_parallel_two_branches() {
        let m = Machine::new(2, OverheadParams::ideal());
        let tree = Node::Par { branches: vec![leafy(100.0), leafy(100.0)], bytes: vec![0, 0] };
        let r = m.run(&tree, false);
        assert!((r.makespan_ns - 100.0).abs() < 1e-9, "makespan {}", r.makespan_ns);
        assert!((r.speedup() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn one_core_parallel_serializes() {
        let m = Machine::new(1, OverheadParams::ideal());
        let tree = Node::Par { branches: vec![leafy(100.0), leafy(100.0)], bytes: vec![0, 0] };
        let r = m.run(&tree, false);
        assert!((r.makespan_ns - 200.0).abs() < 1e-9);
    }

    #[test]
    fn overheads_appear_in_makespan_and_ledger() {
        let params = OverheadParams {
            alpha_spawn_ns: 10.0,
            beta_sync_ns: 5.0,
            gamma_msg_ns: 2.0,
            delta_byte_ns: 0.5,
            };
        let m = Machine::new(2, params);
        let tree = Node::Par { branches: vec![leafy(100.0), leafy(100.0)], bytes: vec![64, 64] };
        let r = m.run(&tree, false);
        // fork 2·α=20, branches in parallel (one migrates: γ+δ·64=34),
        // join 2·β=10.
        assert_eq!(r.ledger.spawns, 2);
        assert_eq!(r.ledger.syncs, 2);
        assert!(r.ledger.messages >= 1, "at least the migrated branch");
        assert!(r.makespan_ns > 100.0 + 20.0 + 10.0 - 1e-9);
        // Charged overhead must reconstruct from the ledger (model↔ledger
        // consistency — the paper's 'root level' accounting).
        let charge = params.charge(&r.ledger);
        assert!(charge > 0.0);
        assert!(
            r.makespan_ns <= r.serial_ns + charge + 1e-9,
            "makespan {} > serial+charge {}",
            r.makespan_ns,
            r.serial_ns + charge
        );
    }

    #[test]
    fn more_cores_never_hurt_ideal_machine() {
        let tree = {
            let mut c = SimCtx::new();
            c.fork_each((0..16).map(|i| (i, 0u64)).collect(), |i, cc| {
                cc.work(10.0 + i as f64, "chunk");
            });
            c.into_node()
        };
        let mut prev = f64::INFINITY;
        for p in [1, 2, 4, 8, 16] {
            let r = Machine::new(p, OverheadParams::ideal()).run(&tree, false);
            assert!(r.makespan_ns <= prev + 1e-9, "p={p}: {} > {prev}", r.makespan_ns);
            prev = r.makespan_ns;
        }
    }

    #[test]
    fn busy_plus_idle_equals_rectangle() {
        let m = Machine::new(3, OverheadParams::paper_2022());
        let tree = {
            let mut c = SimCtx::new();
            c.fork_each((0..7).map(|i| (i, 128u64)).collect(), |i, cc| {
                cc.work(1000.0 * (i + 1) as f64, "chunk");
            });
            c.into_node()
        };
        let r = m.run(&tree, false);
        let rect = r.makespan_ns * 3.0;
        let busy: f64 = r.core_busy_ns.iter().sum();
        assert!((busy + r.ledger.idle_ns as f64 - rect).abs() < 1.0, "conservation");
        assert!(r.idle_fraction() >= 0.0 && r.idle_fraction() < 1.0);
    }

    #[test]
    fn deterministic_schedules() {
        let tree = {
            let mut c = SimCtx::new();
            c.join(
                (64, 64),
                |l| {
                    l.fork_each(vec![(1, 8u64), (2, 8)], |x, cc| cc.work(x as f64 * 7.0, "a"));
                },
                |rr| rr.work(11.0, "b"),
            );
            c.into_node()
        };
        let m = Machine::new(4, OverheadParams::paper_2022());
        let a = m.run(&tree, true);
        let b = m.run(&tree, true);
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.ledger, b.ledger);
        assert_eq!(a.timeline.len(), b.timeline.len());
    }

    #[test]
    fn heterogeneous_prefers_fast_cores() {
        // One fast core (4x) + three slow: independent equal tasks should
        // finish sooner than on four nominal cores... and the fast core
        // must take the largest busy share.
        let tree = {
            let mut c = SimCtx::new();
            c.fork_each((0..8).map(|_| ((), 0u64)).collect(), |_, cc| {
                cc.work(1000.0, "w");
            });
            c.into_node()
        };
        let hetero = Machine::heterogeneous(vec![4.0, 1.0, 1.0, 1.0], OverheadParams::ideal());
        let rep = hetero.run(&tree, false);
        let fast_busy = rep.core_busy_ns[0];
        let max_slow = rep.core_busy_ns[1..].iter().cloned().fold(0.0, f64::max);
        assert!(fast_busy >= max_slow, "fast core underused: {:?}", rep.core_busy_ns);
        // 8 tasks × 1000ns over speeds {4,1,1,1} (total speed 7): lower
        // bound 8000/7 ≈ 1143ns; homogeneous 4×1 machine needs 2000ns.
        let homo = Machine::new(4, OverheadParams::ideal()).run(&tree, false);
        assert!(rep.makespan_ns < homo.makespan_ns, "{} !< {}", rep.makespan_ns, homo.makespan_ns);
    }

    #[test]
    fn heterogeneous_slow_core_can_be_skipped() {
        // A single chain of work must land on the fast core only.
        let tree = Node::Seq(vec![leafy(100.0), leafy(100.0)]);
        let m = Machine::heterogeneous(vec![2.0, 0.1], OverheadParams::ideal());
        let rep = m.run(&tree, false);
        assert!((rep.makespan_ns - 100.0).abs() < 1e-9, "200ns of work at speed 2");
        assert_eq!(rep.core_busy_ns[1], 0.0, "slow core must stay idle");
    }

    #[test]
    fn trace_timeline_covers_busy_time() {
        let m = Machine::new(2, OverheadParams::paper_2022());
        let tree = Node::Par { branches: vec![leafy(50.0), leafy(60.0)], bytes: vec![8, 8] };
        let r = m.run(&tree, true);
        let total_seg: f64 = r.timeline.iter().map(|s| s.end_ns - s.start_ns).sum();
        let busy: f64 = r.core_busy_ns.iter().sum();
        assert!((total_seg - busy).abs() < 1e-9);
        assert!(r.timeline.iter().any(|s| s.kind == SegKind::Spawn));
        assert!(r.timeline.iter().any(|s| s.kind == SegKind::Sync));
    }
}
