//! Discrete-event multicore simulator — the evaluation testbed.
//!
//! The paper measured on an (unspecified) Windows multicore with OpenMP;
//! this container exposes one physical core, so wall-clock parallel speedup
//! is unobservable here. Per DESIGN.md §Substitutions, every numeric
//! experiment instead runs on this simulator: algorithms execute **for
//! real** (single-threaded, correct results) while recording their
//! fork-join structure ([`graph::SimCtx`]), and a [`machine::Machine`] with
//! calibrated overhead parameters schedules that structure on N virtual
//! cores, charging the paper's α/β/γ/δ overheads against a virtual clock.
//!
//! On a real multicore host the same experiments can run on the
//! [`crate::pool`] backend and measure wall-clock instead; the two backends
//! share the exact same domain code paths (see [`crate::exec`]).

pub mod analysis;
pub mod graph;
pub mod machine;

pub use analysis::Breakdown;
pub use graph::{Node, SimCtx};
pub use machine::{Machine, SegKind, Segment, SimReport};
