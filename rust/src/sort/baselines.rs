//! Baseline sorters: mergesort, samplesort, and the bitonic network
//! (the L3 twin of the L1 Pallas kernel — same compare-exchange schedule).

use super::quicksort::OpCounts;
use crate::pool::ThreadPool;
use crate::util::Pcg32;

/// Top-down mergesort, instrumented. Stable, worst-case n·log n — the
/// pivot-insensitive baseline for the adversarial ablation.
pub fn mergesort(xs: &mut [i64]) -> OpCounts {
    let mut ops = OpCounts::default();
    let mut buf = xs.to_vec();
    msort(xs, &mut buf, &mut ops);
    ops
}

fn msort(xs: &mut [i64], buf: &mut [i64], ops: &mut OpCounts) {
    let n = xs.len();
    if n <= 1 {
        return;
    }
    let mid = n / 2;
    {
        let (bl, br) = buf.split_at_mut(mid);
        msort(&mut xs[..mid], bl, ops);
        msort(&mut xs[mid..], br, ops);
    }
    // Merge xs[..mid] and xs[mid..] through buf.
    buf[..n].copy_from_slice(xs);
    let (mut i, mut j) = (0usize, mid);
    for out in xs.iter_mut() {
        let take_left = if i >= mid {
            false
        } else if j >= n {
            true
        } else {
            ops.comparisons += 1;
            buf[i] <= buf[j]
        };
        if take_left {
            *out = buf[i];
            i += 1;
        } else {
            *out = buf[j];
            j += 1;
        }
        ops.swaps += 1; // one element move
    }
}

/// Pool-parallel mergesort: halves fork on the pool down to `cutoff`,
/// merges happen on the joining side (the pivot-insensitive parallel
/// baseline the paper does not evaluate — included for the adversarial
/// ablation, where parallel quicksort with left/right pivots collapses).
pub fn mergesort_parallel(xs: &mut [i64], pool: &ThreadPool, cutoff: usize) -> OpCounts {
    let mut buf = xs.to_vec();
    msort_par(xs, &mut buf, pool, cutoff.max(32))
}

fn msort_par(xs: &mut [i64], buf: &mut [i64], pool: &ThreadPool, cutoff: usize) -> OpCounts {
    let n = xs.len();
    if n <= cutoff {
        let mut ops = OpCounts::default();
        msort(xs, buf, &mut ops);
        return ops;
    }
    let mid = n / 2;
    let (xl, xr) = xs.split_at_mut(mid);
    let mut ops = {
        let (bl, br) = buf.split_at_mut(mid);
        let (ol, or) = pool.join(
            || msort_par(xl, bl, pool, cutoff),
            || msort_par(xr, br, pool, cutoff),
        );
        ol.merged(&or)
    };
    // Merge the sorted halves through buf (serial: the join point).
    buf[..n].copy_from_slice(xs);
    let (mut i, mut j) = (0usize, mid);
    for out in xs.iter_mut() {
        let take_left = if i >= mid {
            false
        } else if j >= n {
            true
        } else {
            ops.comparisons += 1;
            buf[i] <= buf[j]
        };
        if take_left {
            *out = buf[i];
            i += 1;
        } else {
            *out = buf[j];
            j += 1;
        }
        ops.swaps += 1;
    }
    ops
}

/// Samplesort with `buckets` buckets: sample splitters, scatter, sort each
/// bucket (optionally on the pool — the p-way generalization of the
/// paper's 2-way master-slave split).
pub fn samplesort(xs: &mut [i64], buckets: usize, pool: Option<&ThreadPool>, seed: u64) -> OpCounts {
    let n = xs.len();
    let buckets = buckets.clamp(1, n.max(1));
    if n <= 64 || buckets == 1 {
        let mut ops = OpCounts::default();
        let mut rng = Pcg32::new(seed);
        super::quicksort::quicksort_rec(xs, super::PivotStrategy::MedianOf3, &mut rng, &mut ops);
        return ops;
    }
    let mut ops = OpCounts::default();
    let mut rng = Pcg32::new(seed);
    // Oversampled splitters.
    let oversample = 8;
    let mut sample: Vec<i64> =
        (0..buckets * oversample).map(|_| xs[rng.below(n as u64) as usize]).collect();
    sample.sort_unstable();
    ops.scan_ops += sample.len() as u64;
    let splitters: Vec<i64> =
        (1..buckets).map(|i| sample[i * oversample]).collect();
    // Scatter into buckets.
    let mut parts: Vec<Vec<i64>> = vec![Vec::with_capacity(n / buckets + 8); buckets];
    for &v in xs.iter() {
        let b = splitters.partition_point(|&s| s < v);
        ops.comparisons += (splitters.len().max(1)).ilog2() as u64 + 1;
        parts[b].push(v);
    }
    // Sort buckets (parallel when a pool is supplied).
    let bucket_ops: Vec<OpCounts> = match pool {
        Some(pool) => {
            let mut slots: Vec<OpCounts> = vec![OpCounts::default(); buckets];
            {
                let jobs: Vec<(&mut OpCounts, &mut Vec<i64>)> =
                    slots.iter_mut().zip(parts.iter_mut()).collect();
                pool.scope(|s| {
                    for (bi, (slot, part)) in jobs.into_iter().enumerate() {
                        s.spawn(move |_| {
                            let mut o = OpCounts::default();
                            let mut r = Pcg32::new(seed ^ (bi as u64) << 20);
                            super::quicksort::quicksort_rec(
                                part,
                                super::PivotStrategy::MedianOf3,
                                &mut r,
                                &mut o,
                            );
                            *slot = o;
                        });
                    }
                });
            }
            slots
        }
        None => parts
            .iter_mut()
            .enumerate()
            .map(|(bi, part)| {
                let mut o = OpCounts::default();
                let mut r = Pcg32::new(seed ^ (bi as u64) << 20);
                super::quicksort::quicksort_rec(part, super::PivotStrategy::MedianOf3, &mut r, &mut o);
                o
            })
            .collect(),
    };
    for o in bucket_ops {
        ops = ops.merged(&o);
    }
    // Gather.
    let mut i = 0;
    for part in parts {
        xs[i..i + part.len()].copy_from_slice(&part);
        i += part.len();
    }
    debug_assert_eq!(i, n);
    ops
}

/// In-place bitonic sorting network for power-of-two lengths — identical
/// (k, j) compare-exchange schedule to `python/compile/kernels/bitonic.py`.
pub fn bitonic_pow2(xs: &mut [i64]) -> OpCounts {
    let n = xs.len();
    assert!(n.is_power_of_two(), "bitonic needs power-of-two length");
    let mut ops = OpCounts::default();
    let mut k = 2usize;
    while k <= n {
        let mut j = k / 2;
        while j > 0 {
            for i in 0..n {
                let partner = i ^ j;
                if partner > i {
                    ops.comparisons += 1;
                    let ascending = (i & k) == 0;
                    if (xs[i] > xs[partner]) == ascending {
                        xs.swap(i, partner);
                        ops.swaps += 1;
                    }
                }
            }
            j /= 2;
        }
        k *= 2;
    }
    ops
}

/// Bitonic sort for any length: pad to the next power of two with `MAX`.
pub fn bitonic(xs: &mut [i64]) -> OpCounts {
    let n = xs.len();
    if n <= 1 {
        return OpCounts::default();
    }
    if n.is_power_of_two() {
        return bitonic_pow2(xs);
    }
    let np2 = n.next_power_of_two();
    let mut padded = Vec::with_capacity(np2);
    padded.extend_from_slice(xs);
    padded.resize(np2, i64::MAX);
    let ops = bitonic_pow2(&mut padded);
    xs.copy_from_slice(&padded[..n]);
    ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::{is_permutation, is_sorted};
    use crate::workload::arrays::{self, Distribution};

    fn check(f: impl Fn(&mut Vec<i64>) -> OpCounts, n: usize, dist: Distribution) {
        let orig = arrays::generate(n, dist, 77);
        let mut xs = orig.clone();
        let ops = f(&mut xs);
        assert!(is_sorted(&xs), "n={n} {}", dist.name());
        assert!(is_permutation(&xs, &orig));
        if n > 1 {
            assert!(ops.comparisons > 0);
        }
    }

    #[test]
    fn mergesort_sorts_everything() {
        for n in [0, 1, 2, 100, 1000] {
            check(|xs| mergesort(xs), n, Distribution::UniformRandom);
        }
        check(|xs| mergesort(xs), 500, Distribution::Reverse);
        check(|xs| mergesort(xs), 500, Distribution::FewUnique { k: 2 });
    }

    #[test]
    fn mergesort_comparisons_worst_case_bound() {
        let n = 1024usize;
        let orig = arrays::generate(n, Distribution::UniformRandom, 3);
        let mut xs = orig;
        let ops = mergesort(&mut xs);
        // n·log2(n) upper bound for merges.
        assert!(ops.comparisons <= (n as u64) * 10);
    }

    #[test]
    fn mergesort_parallel_matches_serial() {
        let pool = ThreadPool::new(3);
        for n in [0usize, 10, 100, 5000] {
            let orig = arrays::uniform_i64(n, 8);
            let mut a = orig.clone();
            let mut b = orig.clone();
            mergesort(&mut a);
            mergesort_parallel(&mut b, &pool, 64);
            assert_eq!(a, b, "n={n}");
        }
        check(|xs| mergesort_parallel(xs, &pool, 64), 3000, Distribution::Reverse);
        check(|xs| mergesort_parallel(xs, &pool, 64), 3000, Distribution::FewUnique { k: 2 });
    }

    #[test]
    fn samplesort_serial_and_parallel() {
        for n in [10, 65, 1000, 5000] {
            check(|xs| samplesort(xs, 8, None, 5), n, Distribution::UniformRandom);
        }
        let pool = ThreadPool::new(3);
        check(|xs| samplesort(xs, 8, Some(&pool), 5), 5000, Distribution::UniformRandom);
        check(|xs| samplesort(xs, 8, Some(&pool), 5), 3000, Distribution::FewUnique { k: 4 });
    }

    #[test]
    fn bitonic_pow2_and_padded() {
        for n in [2usize, 8, 1024] {
            check(|xs| bitonic(xs), n, Distribution::UniformRandom);
        }
        for n in [3usize, 1000, 1100] {
            check(|xs| bitonic(xs), n, Distribution::UniformRandom);
        }
        check(|xs| bitonic(xs), 1000, Distribution::Sorted);
    }

    #[test]
    fn bitonic_comparator_count_matches_kernel_model() {
        // Must equal python/compile/kernels/bitonic.py::comparator_count.
        let n = 8usize;
        let mut xs = arrays::uniform_i64(n, 1);
        let ops = bitonic_pow2(&mut xs);
        assert_eq!(ops.comparisons, 24); // log=3 → 6 substages × n/2
    }

    #[test]
    fn bitonic_is_input_insensitive() {
        // Comparison count is data-independent (the dataflow property that
        // makes it the TPU mapping of quicksort — DESIGN §Hardware-Adaptation).
        let mut a = arrays::generate(512, Distribution::Sorted, 0);
        let mut b = arrays::generate(512, Distribution::Reverse, 0);
        assert_eq!(bitonic_pow2(&mut a).comparisons, bitonic_pow2(&mut b).comparisons);
    }
}
