//! Sorting domain (paper §"Overheads of parallelism in sorting and their
//! management": Fig 3 algorithm, Table 2 analysis, Table 3 / Fig 5 results).
//!
//! The paper parallelizes quicksort with the scheme of Table 2 / Fig 4:
//! the **master places the first pivot** (avoiding per-core pivot
//! re-analysis), then the sub-array before the pivot goes to one core and
//! the one after to another, recursively — i.e. binary fork-join with a
//! serial cutoff. Four pivot-selection strategies are compared: leftmost,
//! mean, rightmost, random.
//!
//! All engines share one instrumented partition kernel, so operation
//! counts (comparisons, swaps, pivot scans, rng calls) are identical
//! across serial / threaded / simulated runs on the same input — the
//! simulator converts those counts to virtual time via [`SortCostModel`].

pub mod baselines;
pub mod parallel;
pub mod pivot;
pub mod quicksort;
pub mod samplesort_inplace;

pub use parallel::parallel_quicksort;
pub use pivot::PivotStrategy;
pub use quicksort::{serial_quicksort, OpCounts};
pub use samplesort_inplace::samplesort_inplace;

use crate::overhead::WorkEstimate;

/// Converts instrumented operation counts into (virtual) nanoseconds.
///
/// `paper_2022()` is fitted to Table 3's *serial* column: 2.246 time-units
/// for n=1000 uniform elements ⇒ ≈225 ns per comparison-swap step (their
/// units read as ms). `rng_ns` models the thread-safe-but-serialized
/// `rand()` the paper's random-pivot variant pays per selection — the
/// reason Table 3 shows random as the slowest parallel strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SortCostModel {
    /// One comparison + conditional swap in partition/insertion, ns.
    pub op_ns: f64,
    /// One element visit of the mean-pivot scan, ns (cheap adds).
    pub scan_op_ns: f64,
    /// One random-pivot selection (locked `rand()`), ns.
    pub rng_ns: f64,
}

impl SortCostModel {
    pub fn paper_2022() -> Self {
        SortCostModel { op_ns: 225.0, scan_op_ns: 20.0, rng_ns: 40_000.0 }
    }

    /// Host-calibrated model (per-op cost from `Calibration`).
    pub fn host(sort_op_ns: f64) -> Self {
        SortCostModel { op_ns: sort_op_ns, scan_op_ns: sort_op_ns * 0.1, rng_ns: 50.0 }
    }

    /// Virtual nanoseconds for an operation-count record.
    pub fn cost_ns(&self, ops: &OpCounts) -> f64 {
        (ops.comparisons + ops.swaps) as f64 * self.op_ns
            + ops.scan_ops as f64 * self.scan_op_ns
            + ops.rng_calls as f64 * self.rng_ns
    }
}

/// Work estimate for the manager: expected `1.39·n·log₂n` comparisons.
pub fn estimate(n: usize, model: &SortCostModel) -> WorkEstimate {
    let nf = n as f64;
    let ops = 1.39 * nf * nf.max(2.0).log2();
    WorkEstimate::fully_parallel(ops * model.op_ns, (n * 8) as u64)
}

/// `true` iff ascending.
pub fn is_sorted(xs: &[i64]) -> bool {
    xs.windows(2).all(|w| w[0] <= w[1])
}

/// `true` iff `a` is a permutation of `b` (multiset equality).
pub fn is_permutation(a: &[i64], b: &[i64]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut sa = a.to_vec();
    let mut sb = b.to_vec();
    sa.sort_unstable();
    sb.sort_unstable();
    sa == sb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_charges_all_classes() {
        let m = SortCostModel::paper_2022();
        let ops = OpCounts { comparisons: 10, swaps: 5, scan_ops: 100, rng_calls: 2 };
        let c = m.cost_ns(&ops);
        assert!((c - (15.0 * m.op_ns + 100.0 * m.scan_op_ns + 2.0 * m.rng_ns)).abs() < 1e-9);
    }

    #[test]
    fn paper_model_reproduces_serial_column_scale() {
        // Table 3: serial n=1000 ≈ 2.246 ms. 1.39·n·log2(n)·op_ns ≈ 3.1ms,
        // same order of magnitude (exact value depends on the input).
        let e = estimate(1000, &SortCostModel::paper_2022());
        assert!(e.total_work_ns > 1e6 && e.total_work_ns < 1e7, "{e:?}");
    }

    #[test]
    fn validators() {
        assert!(is_sorted(&[1, 2, 2, 3]));
        assert!(!is_sorted(&[2, 1]));
        assert!(is_permutation(&[3, 1, 2], &[1, 2, 3]));
        assert!(!is_permutation(&[1, 1], &[1, 2]));
        assert!(!is_permutation(&[1], &[1, 1]));
        assert!(is_sorted(&[]) && is_permutation(&[], &[]));
    }
}
