//! Parallel quicksort — the paper's Fig 4 workflow on every engine.
//!
//! Scheme (paper Table 2): the master selects and places the pivot, then
//! the two sub-arrays recurse in parallel (fork-join), each core repeating
//! the same split until segments fall below the **overhead-managed cutoff**
//! — the grain at which the [`Manager`](crate::overhead::Manager) predicts
//! further forking would cost more (α/β/γ) than it saves.

use super::pivot::PivotStrategy;
use super::quicksort::{partition, quicksort_rec, OpCounts};
use super::SortCostModel;
use crate::exec::{Engine, ExecCtx, RunReport};
use crate::overhead::{Ledger, Manager};
use crate::pool::ThreadPool;
use crate::sim::SimCtx;
use crate::util::{Pcg32, Stopwatch};

/// Smallest segment the manager still wants to fork, given the cost model.
/// Monotone bisection over the work estimate (see `Manager::decide`).
pub fn managed_cutoff(manager: &Manager, model: &SortCostModel) -> usize {
    let parallel_at = |n: usize| manager.decide(&super::estimate(n, model)).is_parallel();
    if !parallel_at(1 << 24) {
        return usize::MAX; // never fork (e.g. 1 core)
    }
    let mut lo = super::quicksort::INSERTION_CUTOFF;
    if parallel_at(lo) {
        return lo;
    }
    let mut hi = 1usize << 24;
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if parallel_at(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    hi
}

/// Overhead-managed parallel quicksort with paper-calibrated simulation
/// costs ([`SortCostModel::paper_2022`]); see [`run_with_model`] for
/// custom cost models and seeds.
pub fn parallel_quicksort(xs: &mut [i64], strategy: PivotStrategy, ctx: &ExecCtx) -> RunReport {
    run_with_model(xs, strategy, ctx, &SortCostModel::paper_2022(), 0)
}

/// Full-control entry point: sort `xs` under `ctx` with cost model `model`
/// and pivot-rng `seed`. Deterministic given (input, strategy, seed).
pub fn run_with_model(
    xs: &mut [i64],
    strategy: PivotStrategy,
    ctx: &ExecCtx,
    model: &SortCostModel,
    seed: u64,
) -> RunReport {
    let cutoff = managed_cutoff(&ctx.manager, model);
    let sw = Stopwatch::start();
    match &ctx.engine {
        Engine::Serial => {
            let ops = super::serial_quicksort(xs, strategy, seed);
            let cost = model.cost_ns(&ops);
            let mut rep = RunReport::wall_only(sw.elapsed_ns());
            // Serial runs still report virtual time so Table 3's serial
            // column is commensurable with the simulated parallel columns.
            rep.virtual_ns = Some(cost);
            rep.serial_equiv_ns = Some(cost);
            rep.ledger.compute_ns = cost as u64;
            (rep.ledger.bytes, rep.ledger.spawns) = (0, 0);
            rep
        }
        Engine::Threaded(pool) => {
            let before = pool.metrics();
            let ops = threaded_rec(pool, xs, strategy, cutoff, seed);
            let delta = pool.metrics().delta_since(&before);
            let mut rep = RunReport::wall_only(sw.elapsed_ns());
            rep.ledger = Ledger::from_metrics(&delta, (xs.len() * 8) as u64);
            rep.ledger.compute_ns = model.cost_ns(&ops) as u64;
            rep
        }
        Engine::Simulated(machine) => {
            let mut sc = SimCtx::new();
            let _ops = sim_rec(&mut sc, xs, strategy, cutoff, seed, model);
            let sim = machine.run(&sc.into_node(), ctx.trace);
            RunReport {
                wall_ns: sw.elapsed_ns(),
                virtual_ns: Some(sim.makespan_ns),
                serial_equiv_ns: Some(sim.serial_ns),
                ledger: sim.ledger,
                timeline: sim.timeline,
            }
        }
    }
}

/// Simulate with an explicit fork cutoff (grain-ablation entry point):
/// bypasses the manager and reports the raw schedule.
pub fn simulate_with_cutoff(
    xs: &mut [i64],
    strategy: PivotStrategy,
    cutoff: usize,
    seed: u64,
    model: &SortCostModel,
    machine: &crate::sim::Machine,
) -> crate::sim::SimReport {
    let mut sc = SimCtx::new();
    let _ops = sim_rec(&mut sc, xs, strategy, cutoff, seed, model);
    machine.run(&sc.into_node(), false)
}

/// Real-threads recursion: master partitions, halves fork on the pool.
fn threaded_rec(
    pool: &ThreadPool,
    xs: &mut [i64],
    strategy: PivotStrategy,
    cutoff: usize,
    seed: u64,
) -> OpCounts {
    if xs.len() <= cutoff.max(super::quicksort::INSERTION_CUTOFF) {
        let mut ops = OpCounts::default();
        let mut rng = Pcg32::new(seed);
        quicksort_rec(xs, strategy, &mut rng, &mut ops);
        return ops;
    }
    let mut ops = OpCounts::default();
    let mut rng = Pcg32::new(seed);
    let p = strategy.choose(xs, &mut rng, &mut ops);
    let p = partition(xs, p, &mut ops);
    let (lo, rest) = xs.split_at_mut(p);
    let hi = &mut rest[1..];
    let (o1, o2) = pool.join(
        || threaded_rec(pool, lo, strategy, cutoff, seed.wrapping_mul(2).wrapping_add(1)),
        || threaded_rec(pool, hi, strategy, cutoff, seed.wrapping_mul(2).wrapping_add(2)),
    );
    ops.merged(&o1).merged(&o2)
}

/// Virtual-time twin: identical partition sequence (same seeds ⇒ same
/// pivots ⇒ same op counts), fork-join structure recorded on the SimCtx.
fn sim_rec(
    ctx: &mut SimCtx,
    xs: &mut [i64],
    strategy: PivotStrategy,
    cutoff: usize,
    seed: u64,
    model: &SortCostModel,
) -> OpCounts {
    if xs.len() <= cutoff.max(super::quicksort::INSERTION_CUTOFF) {
        let mut ops = OpCounts::default();
        let mut rng = Pcg32::new(seed);
        quicksort_rec(xs, strategy, &mut rng, &mut ops);
        ctx.work(model.cost_ns(&ops), "sort-leaf");
        return ops;
    }
    let mut ops = OpCounts::default();
    let mut rng = Pcg32::new(seed);
    let p = strategy.choose(xs, &mut rng, &mut ops);
    let p = partition(xs, p, &mut ops);
    ctx.work(model.cost_ns(&ops), "partition");
    let (lo, rest) = xs.split_at_mut(p);
    let hi = &mut rest[1..];
    let bytes = (lo.len() as u64 * 8, hi.len() as u64 * 8);
    let (o1, o2) = ctx.join(
        bytes,
        |ca| sim_rec(ca, lo, strategy, cutoff, seed.wrapping_mul(2).wrapping_add(1), model),
        |cb| sim_rec(cb, hi, strategy, cutoff, seed.wrapping_mul(2).wrapping_add(2), model),
    );
    ops.merged(&o1).merged(&o2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overhead::OverheadParams;
    use crate::sort::{is_permutation, is_sorted};
    use crate::workload::arrays;

    fn sorted_ok(xs: &[i64], orig: &[i64]) {
        assert!(is_sorted(xs));
        assert!(is_permutation(xs, orig));
    }

    #[test]
    fn threaded_sorts_all_strategies() {
        let ctx = ExecCtx::threaded(3);
        for s in PivotStrategy::PAPER_SET {
            let orig = arrays::uniform_i64(5000, 11);
            let mut xs = orig.clone();
            let rep = parallel_quicksort(&mut xs, s, &ctx);
            sorted_ok(&xs, &orig);
            assert!(rep.wall_ns > 0);
        }
    }

    #[test]
    fn simulated_sorts_and_reports_virtual_time() {
        let ctx = ExecCtx::simulated(4, OverheadParams::paper_2022());
        let orig = arrays::uniform_i64(2000, 13);
        let mut xs = orig.clone();
        let rep = parallel_quicksort(&mut xs, PivotStrategy::Mean, &ctx);
        sorted_ok(&xs, &orig);
        assert!(rep.virtual_ns.unwrap() > 0.0);
        assert!(rep.ledger.spawns > 0, "must have forked: {:?}", rep.ledger);
    }

    #[test]
    fn table3_shape_parallel_beats_serial_at_1000_plus() {
        let model = SortCostModel::paper_2022();
        for n in [1000usize, 2000] {
            let orig = arrays::uniform_i64(n, 42);
            let mut a = orig.clone();
            let ser = run_with_model(
                &mut a,
                PivotStrategy::Left,
                &ExecCtx::serial(),
                &model,
                1,
            );
            let mut b = orig.clone();
            let par = run_with_model(
                &mut b,
                PivotStrategy::Left,
                &ExecCtx::simulated(4, OverheadParams::paper_2022()),
                &model,
                1,
            );
            assert!(
                par.virtual_ns.unwrap() < ser.virtual_ns.unwrap(),
                "n={n}: parallel {} !< serial {}",
                par.virtual_ns.unwrap(),
                ser.virtual_ns.unwrap()
            );
        }
    }

    #[test]
    fn table3_shape_random_is_slowest_parallel() {
        let n = 1000;
        let orig = arrays::uniform_i64(n, 42);
        let model = SortCostModel::paper_2022();
        let time = |s: PivotStrategy| {
            let mut xs = orig.clone();
            let ctx = ExecCtx::simulated(4, OverheadParams::paper_2022());
            run_with_model(&mut xs, s, &ctx, &model, 1).virtual_ns.unwrap()
        };
        let (l, m, r, rnd) = (
            time(PivotStrategy::Left),
            time(PivotStrategy::Mean),
            time(PivotStrategy::Right),
            time(PivotStrategy::Random),
        );
        assert!(rnd > l && rnd > m && rnd > r, "random {rnd} vs l={l} m={m} r={r}");
    }

    #[test]
    fn managed_cutoff_monotone_in_overhead() {
        let model = SortCostModel::paper_2022();
        let cheap = Manager::new(
            OverheadParams { alpha_spawn_ns: 100.0, ..OverheadParams::paper_2022() },
            4,
        );
        let costly = Manager::new(OverheadParams::paper_2022(), 4);
        let c_cheap = managed_cutoff(&cheap, &model);
        let c_costly = managed_cutoff(&costly, &model);
        assert!(c_cheap <= c_costly, "{c_cheap} vs {c_costly}");
        assert!(c_costly < usize::MAX);
    }

    #[test]
    fn single_core_manager_never_forks() {
        let ctx = ExecCtx::simulated(1, OverheadParams::paper_2022());
        let orig = arrays::uniform_i64(3000, 5);
        let mut xs = orig.clone();
        let rep = parallel_quicksort(&mut xs, PivotStrategy::Mean, &ctx);
        sorted_ok(&xs, &orig);
        assert_eq!(rep.ledger.spawns, 0);
    }

    #[test]
    fn sim_and_threaded_same_op_counts() {
        // Same seeds ⇒ identical pivot sequence ⇒ identical sorted output;
        // the sim twin is faithful to the threaded execution.
        let orig = arrays::uniform_i64(4000, 21);
        let cutoff = 256;
        let pool = ThreadPool::new(2);
        let mut a = orig.clone();
        let ot = threaded_rec(&pool, &mut a, PivotStrategy::Random, cutoff, 99);
        let mut b = orig.clone();
        let mut sc = SimCtx::new();
        let os = sim_rec(
            &mut sc,
            &mut b,
            PivotStrategy::Random,
            cutoff,
            99,
            &SortCostModel::paper_2022(),
        );
        assert_eq!(a, b);
        assert_eq!(ot, os, "instrumentation must agree across engines");
    }
}
