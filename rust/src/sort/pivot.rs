//! Pivot-selection strategies (paper Table 2: "Random, mean, leftmost
//! element, rightmost element"), plus median-of-three as an extension.
//!
//! Every strategy returns a pivot *index* so the partition kernel can
//! guarantee progress (the pivot element lands at its final position and
//! is excluded from recursion). The instrumented cost of selection —
//! scan operations for `Mean`, rng calls for `Random` — is charged to the
//! caller's [`OpCounts`](super::OpCounts); that cost asymmetry is exactly
//! what Table 3 measures.

use super::quicksort::OpCounts;
use crate::util::Pcg32;

/// Pivot-selection strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PivotStrategy {
    /// Leftmost element (Fig 3's `x := A[q]`).
    Left,
    /// Element closest to the arithmetic mean (O(n) scan per partition).
    Mean,
    /// Rightmost element.
    Right,
    /// Uniform random element (pays the locked-`rand()` cost, see
    /// [`SortCostModel`](super::SortCostModel)).
    Random,
    /// Median of first/middle/last (extension; classic engineering fix).
    MedianOf3,
}

impl PivotStrategy {
    pub const PAPER_SET: [PivotStrategy; 4] =
        [PivotStrategy::Left, PivotStrategy::Mean, PivotStrategy::Right, PivotStrategy::Random];

    pub fn name(&self) -> &'static str {
        match self {
            PivotStrategy::Left => "left",
            PivotStrategy::Mean => "mean",
            PivotStrategy::Right => "right",
            PivotStrategy::Random => "random",
            PivotStrategy::MedianOf3 => "median3",
        }
    }

    pub fn from_name(s: &str) -> Option<PivotStrategy> {
        Some(match s {
            "left" => PivotStrategy::Left,
            "mean" => PivotStrategy::Mean,
            "right" => PivotStrategy::Right,
            "random" => PivotStrategy::Random,
            "median3" => PivotStrategy::MedianOf3,
            _ => return None,
        })
    }

    /// Choose the pivot index in `xs` (non-empty), charging selection costs.
    pub fn choose(&self, xs: &[i64], rng: &mut Pcg32, ops: &mut OpCounts) -> usize {
        debug_assert!(!xs.is_empty());
        match self {
            PivotStrategy::Left => 0,
            PivotStrategy::Right => xs.len() - 1,
            PivotStrategy::Random => {
                ops.rng_calls += 1;
                rng.below(xs.len() as u64) as usize
            }
            PivotStrategy::Mean => {
                // Pass 1: mean; pass 2: closest element. 2n scan ops.
                ops.scan_ops += 2 * xs.len() as u64;
                let sum: i128 = xs.iter().map(|&v| v as i128).sum();
                let mean = sum / xs.len() as i128;
                let mut best = 0usize;
                let mut best_d = i128::MAX;
                for (i, &v) in xs.iter().enumerate() {
                    let d = (v as i128 - mean).abs();
                    if d < best_d {
                        best_d = d;
                        best = i;
                    }
                }
                best
            }
            PivotStrategy::MedianOf3 => {
                ops.comparisons += 3;
                let (a, b, c) = (0, xs.len() / 2, xs.len() - 1);
                let (va, vb, vc) = (xs[a], xs[b], xs[c]);
                if (va <= vb) == (vb <= vc) {
                    b
                } else if (vb <= va) == (va <= vc) {
                    a
                } else {
                    c
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ops() -> OpCounts {
        OpCounts::default()
    }

    #[test]
    fn left_right_endpoints() {
        let xs = [5i64, 1, 9, 3];
        let mut rng = Pcg32::new(0);
        let mut o = ops();
        assert_eq!(PivotStrategy::Left.choose(&xs, &mut rng, &mut o), 0);
        assert_eq!(PivotStrategy::Right.choose(&xs, &mut rng, &mut o), 3);
        assert_eq!(o.rng_calls + o.scan_ops, 0, "no selection cost for endpoints");
    }

    #[test]
    fn mean_picks_closest_and_charges_scan() {
        let xs = [0i64, 10, 100, 6]; // mean = 29 → closest is 10 (idx 1)
        let mut rng = Pcg32::new(0);
        let mut o = ops();
        let i = PivotStrategy::Mean.choose(&xs, &mut rng, &mut o);
        assert_eq!(i, 1);
        assert_eq!(o.scan_ops, 8);
    }

    #[test]
    fn random_in_bounds_and_charged() {
        let xs: Vec<i64> = (0..50).collect();
        let mut rng = Pcg32::new(7);
        let mut o = ops();
        for _ in 0..100 {
            let i = PivotStrategy::Random.choose(&xs, &mut rng, &mut o);
            assert!(i < xs.len());
        }
        assert_eq!(o.rng_calls, 100);
    }

    #[test]
    fn median3_is_the_median() {
        let mut rng = Pcg32::new(1);
        let mut o = ops();
        // first=9, mid=4, last=6 → median is 6 (last).
        let xs = [9i64, 0, 4, 0, 6];
        let i = PivotStrategy::MedianOf3.choose(&xs, &mut rng, &mut o);
        assert_eq!(xs[i], 6);
        // first=1, mid=5, last=9 → median is 5 (mid).
        let xs = [1i64, 0, 5, 0, 9];
        assert_eq!(xs[PivotStrategy::MedianOf3.choose(&xs, &mut rng, &mut o)], 5);
    }

    #[test]
    fn names_roundtrip() {
        for s in [
            PivotStrategy::Left,
            PivotStrategy::Mean,
            PivotStrategy::Right,
            PivotStrategy::Random,
            PivotStrategy::MedianOf3,
        ] {
            assert_eq!(PivotStrategy::from_name(s.name()), Some(s));
        }
        assert_eq!(PivotStrategy::from_name("bogus"), None);
    }
}
