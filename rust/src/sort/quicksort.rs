//! Instrumented serial quicksort (paper Fig 3, generalized over pivot
//! strategies), with a small-segment insertion-sort cutoff.
//!
//! Every comparison, swap, pivot-scan element and rng call is counted in
//! [`OpCounts`]; the counts are deterministic for a given (input, strategy,
//! seed), which is what lets the simulator's virtual clock and the paper's
//! Table 3 share one source of truth.

use super::pivot::PivotStrategy;
use crate::util::Pcg32;

/// Operation counters (the sort domain's "root level" accounting).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub comparisons: u64,
    pub swaps: u64,
    /// Elements visited by mean-pivot scans.
    pub scan_ops: u64,
    /// Random-pivot selections.
    pub rng_calls: u64,
}

impl OpCounts {
    pub fn merged(&self, o: &OpCounts) -> OpCounts {
        OpCounts {
            comparisons: self.comparisons + o.comparisons,
            swaps: self.swaps + o.swaps,
            scan_ops: self.scan_ops + o.scan_ops,
            rng_calls: self.rng_calls + o.rng_calls,
        }
    }

    pub fn total(&self) -> u64 {
        self.comparisons + self.swaps + self.scan_ops + self.rng_calls
    }
}

/// Below this length, insertion sort (standard engineering cutoff; also
/// the floor for parallel grain decisions).
pub const INSERTION_CUTOFF: usize = 16;

/// Lomuto partition around the pivot *element* at `pivot_idx`; returns the
/// pivot's final index. Both sides exclude the pivot ⇒ guaranteed progress
/// for every strategy (including adversarial inputs).
pub fn partition(xs: &mut [i64], pivot_idx: usize, ops: &mut OpCounts) -> usize {
    let n = xs.len();
    debug_assert!(pivot_idx < n);
    xs.swap(pivot_idx, n - 1);
    ops.swaps += 1;
    let pivot = xs[n - 1];
    let mut store = 0usize;
    for i in 0..n - 1 {
        ops.comparisons += 1;
        if xs[i] <= pivot {
            if i != store {
                xs.swap(i, store);
                ops.swaps += 1;
            }
            store += 1;
        }
    }
    xs.swap(store, n - 1);
    ops.swaps += 1;
    store
}

fn insertion_sort(xs: &mut [i64], ops: &mut OpCounts) {
    for i in 1..xs.len() {
        let mut j = i;
        while j > 0 {
            ops.comparisons += 1;
            if xs[j - 1] <= xs[j] {
                break;
            }
            xs.swap(j - 1, j);
            ops.swaps += 1;
            j -= 1;
        }
    }
}

/// Serial quicksort with the given pivot strategy (Fig 3 when `Left`).
/// Returns the operation counts.
pub fn serial_quicksort(xs: &mut [i64], strategy: PivotStrategy, seed: u64) -> OpCounts {
    let mut ops = OpCounts::default();
    let mut rng = Pcg32::new(seed);
    quicksort_rec(xs, strategy, &mut rng, &mut ops);
    ops
}

pub(crate) fn quicksort_rec(
    xs: &mut [i64],
    strategy: PivotStrategy,
    rng: &mut Pcg32,
    ops: &mut OpCounts,
) {
    // Iterative on the larger side to bound stack depth on adversarial
    // inputs (left pivot on sorted data is O(n) deep otherwise).
    let mut xs = xs;
    loop {
        if xs.len() <= INSERTION_CUTOFF {
            insertion_sort(xs, ops);
            return;
        }
        let p = strategy.choose(xs, rng, ops);
        let p = partition(xs, p, ops);
        let (lo, rest) = xs.split_at_mut(p);
        let hi = &mut rest[1..];
        if lo.len() < hi.len() {
            quicksort_rec(lo, strategy, rng, ops);
            xs = hi;
        } else {
            quicksort_rec(hi, strategy, rng, ops);
            xs = lo;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::{is_permutation, is_sorted};
    use crate::workload::arrays::{self, Distribution};

    fn check_sorts(dist: Distribution, n: usize) {
        for strategy in [
            PivotStrategy::Left,
            PivotStrategy::Mean,
            PivotStrategy::Right,
            PivotStrategy::Random,
            PivotStrategy::MedianOf3,
        ] {
            let orig = arrays::generate(n, dist, 42);
            let mut xs = orig.clone();
            let ops = serial_quicksort(&mut xs, strategy, 7);
            assert!(is_sorted(&xs), "{strategy:?} on {}", dist.name());
            assert!(is_permutation(&xs, &orig), "{strategy:?} permutes");
            if n > 1 {
                assert!(ops.comparisons > 0);
            }
        }
    }

    #[test]
    fn sorts_uniform() {
        check_sorts(Distribution::UniformRandom, 500);
    }

    #[test]
    fn sorts_adversarial() {
        check_sorts(Distribution::Sorted, 300);
        check_sorts(Distribution::Reverse, 300);
        check_sorts(Distribution::FewUnique { k: 3 }, 300);
    }

    #[test]
    fn sorts_tiny_and_empty() {
        for n in [0usize, 1, 2, 15, 16, 17] {
            check_sorts(Distribution::UniformRandom, n);
        }
    }

    #[test]
    fn partition_places_pivot_correctly() {
        let mut xs = vec![5i64, 9, 1, 7, 3];
        let mut ops = OpCounts::default();
        let p = partition(&mut xs, 0, &mut ops); // pivot value 5
        assert_eq!(xs[p], 5);
        assert!(xs[..p].iter().all(|&v| v <= 5));
        assert!(xs[p + 1..].iter().all(|&v| v >= 5));
    }

    #[test]
    fn left_pivot_on_sorted_is_quadratic_median3_is_not() {
        let n = 2000;
        let sorted = arrays::generate(n, Distribution::Sorted, 0);
        let mut a = sorted.clone();
        let left = serial_quicksort(&mut a, PivotStrategy::Left, 0);
        let mut b = sorted.clone();
        let med = serial_quicksort(&mut b, PivotStrategy::MedianOf3, 0);
        // Left degenerates to ~n²/2; median-of-3 stays ~n·log n.
        assert!(
            left.comparisons > 10 * med.comparisons,
            "left {} vs median3 {}",
            left.comparisons,
            med.comparisons
        );
    }

    #[test]
    fn op_counts_deterministic_per_seed() {
        let orig = arrays::uniform_i64(1000, 3);
        let mut a = orig.clone();
        let mut b = orig.clone();
        let oa = serial_quicksort(&mut a, PivotStrategy::Random, 9);
        let ob = serial_quicksort(&mut b, PivotStrategy::Random, 9);
        assert_eq!(oa, ob);
        let mut c = orig.clone();
        let oc = serial_quicksort(&mut c, PivotStrategy::Random, 10);
        assert_ne!(oa, oc, "different seed, different pivots");
    }

    #[test]
    fn uniform_comparisons_near_n_log_n() {
        let n = 4096usize;
        let mut xs = arrays::uniform_i64(n, 5);
        let ops = serial_quicksort(&mut xs, PivotStrategy::Random, 5);
        let nlogn = n as f64 * (n as f64).log2();
        let ratio = ops.comparisons as f64 / nlogn;
        assert!(ratio > 0.8 && ratio < 2.5, "ratio {ratio}");
    }

    #[test]
    fn merged_counts_add() {
        let a = OpCounts { comparisons: 1, swaps: 2, scan_ops: 3, rng_calls: 4 };
        let b = a.merged(&a);
        assert_eq!(b.total(), 20);
    }
}
