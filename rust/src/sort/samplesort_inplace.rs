//! Multi-pivot samplesort with **in-place** partitioning.
//!
//! [`baselines::samplesort`](super::baselines::samplesort) scatters into
//! per-bucket `Vec`s and gathers back — 2n element moves and ~n·8 bytes
//! of transient allocation per call. This variant keeps the same
//! splitter-selection scheme (oversampled random sample, one splitter per
//! bucket boundary) but partitions with the American-flag cycle-following
//! permutation: one counting pass, then each misplaced element is walked
//! around its permutation cycle directly into its destination bucket, so
//! the only allocations are the `O(buckets)` cursor arrays.
//!
//! The buckets are then disjoint sub-slices of the input, so the
//! per-bucket sorts run on the pool via `split_at_mut` chunks with no
//! copy-out/copy-in — the p-way generalization of the paper's in-place
//! master-slave quicksort split, without the scatter/gather overhead the
//! Ledger would book as `bytes_moved`.

use super::quicksort::OpCounts;
use super::PivotStrategy;
use crate::pool::ThreadPool;
use crate::util::Pcg32;

const OVERSAMPLE: usize = 8;
const SMALL_CUTOFF: usize = 64;

/// Sort `xs` ascending with `buckets`-way in-place samplesort; buckets
/// sort on `pool` when one is supplied. Deterministic for a given
/// `(xs, buckets, seed)` regardless of pool size.
pub fn samplesort_inplace(
    xs: &mut [i64],
    buckets: usize,
    pool: Option<&ThreadPool>,
    seed: u64,
) -> OpCounts {
    let n = xs.len();
    let buckets = buckets.clamp(1, n.max(1));
    if n <= SMALL_CUTOFF || buckets == 1 {
        let mut ops = OpCounts::default();
        let mut rng = Pcg32::new(seed);
        super::quicksort::quicksort_rec(xs, PivotStrategy::MedianOf3, &mut rng, &mut ops);
        return ops;
    }
    let mut ops = OpCounts::default();
    let mut rng = Pcg32::new(seed);

    // Oversampled splitters — same selection scheme as the scatter
    // baseline so the two variants see comparable bucket balance.
    let mut sample: Vec<i64> =
        (0..buckets * OVERSAMPLE).map(|_| xs[rng.below(n as u64) as usize]).collect();
    sample.sort_unstable();
    ops.scan_ops += sample.len() as u64;
    let splitters: Vec<i64> = (1..buckets).map(|i| sample[i * OVERSAMPLE]).collect();
    let classify_cost = (splitters.len().max(1)).ilog2() as u64 + 1;

    // Counting pass: bucket sizes → [start, end) ranges.
    let mut counts = vec![0usize; buckets];
    for &v in xs.iter() {
        counts[splitters.partition_point(|&s| s < v)] += 1;
        ops.comparisons += classify_cost;
    }
    let mut starts = vec![0usize; buckets];
    for b in 1..buckets {
        starts[b] = starts[b - 1] + counts[b - 1];
    }
    let ends: Vec<usize> = starts.iter().zip(&counts).map(|(&s, &c)| s + c).collect();

    // American-flag permutation: `next[b]` is the first not-yet-settled
    // slot of bucket `b`. Every element left of `next[b]` within bucket
    // `b` is already home, so each element moves at most once.
    let mut next = starts;
    for b in 0..buckets {
        while next[b] < ends[b] {
            let slot = next[b];
            let mut v = xs[slot];
            let mut dest = splitters.partition_point(|&s| s < v);
            ops.comparisons += classify_cost;
            while dest != b {
                // Follow the cycle: swap `v` into its destination's
                // cursor slot and continue with the evicted element.
                let d = next[dest];
                next[dest] += 1;
                core::mem::swap(&mut v, &mut xs[d]);
                ops.swaps += 1;
                dest = splitters.partition_point(|&s| s < v);
                ops.comparisons += classify_cost;
            }
            xs[slot] = v;
            next[b] += 1;
        }
    }

    // Buckets are now disjoint slices — carve them out and sort each,
    // on the pool when supplied. Per-bucket RNG seeds match the scatter
    // baseline so pivot sequences are comparable.
    let mut slices: Vec<&mut [i64]> = Vec::with_capacity(buckets);
    let mut rest = xs;
    for &c in &counts {
        let (head, tail) = rest.split_at_mut(c);
        slices.push(head);
        rest = tail;
    }
    let bucket_ops: Vec<OpCounts> = match pool {
        Some(pool) => {
            let mut slots: Vec<OpCounts> = vec![OpCounts::default(); buckets];
            {
                let jobs: Vec<(&mut OpCounts, &mut [i64])> =
                    slots.iter_mut().zip(slices).collect();
                pool.scope(|s| {
                    for (bi, (slot, part)) in jobs.into_iter().enumerate() {
                        s.spawn(move |_| {
                            let mut o = OpCounts::default();
                            let mut r = Pcg32::new(seed ^ (bi as u64) << 20);
                            super::quicksort::quicksort_rec(
                                part,
                                PivotStrategy::MedianOf3,
                                &mut r,
                                &mut o,
                            );
                            *slot = o;
                        });
                    }
                });
            }
            slots
        }
        None => slices
            .into_iter()
            .enumerate()
            .map(|(bi, part)| {
                let mut o = OpCounts::default();
                let mut r = Pcg32::new(seed ^ (bi as u64) << 20);
                super::quicksort::quicksort_rec(part, PivotStrategy::MedianOf3, &mut r, &mut o);
                o
            })
            .collect(),
    };
    for o in bucket_ops {
        ops = ops.merged(&o);
    }
    ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::{is_permutation, is_sorted, serial_quicksort};
    use crate::workload::arrays::{self, Distribution};

    fn check(n: usize, buckets: usize, dist: Distribution, pool: Option<&ThreadPool>) {
        let orig = arrays::generate(n, dist, 123);
        let mut xs = orig.clone();
        samplesort_inplace(&mut xs, buckets, pool, 5);
        assert!(is_sorted(&xs), "n={n} buckets={buckets} {}", dist.name());
        assert!(is_permutation(&xs, &orig));
    }

    #[test]
    fn sorts_across_sizes_and_bucket_counts() {
        for n in [0usize, 1, 2, 17, 64, 65, 100, 1000, 5000] {
            for buckets in [1usize, 2, 8, 16] {
                check(n, buckets, Distribution::UniformRandom, None);
            }
        }
    }

    #[test]
    fn adversarial_distributions() {
        for dist in [
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::FewUnique { k: 3 },
        ] {
            check(3000, 8, dist, None);
        }
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        let pool = ThreadPool::new(3);
        for n in [65usize, 1000, 5000] {
            let orig = arrays::uniform_i64(n, 9);
            let (mut a, mut b) = (orig.clone(), orig.clone());
            let oa = samplesort_inplace(&mut a, 8, None, 5);
            let ob = samplesort_inplace(&mut b, 8, Some(&pool), 5);
            assert_eq!(a, b, "n={n}");
            // Same splitters + same per-bucket seeds ⇒ same op counts.
            assert_eq!(oa, ob, "n={n}");
        }
        check(5000, 8, Distribution::FewUnique { k: 4 }, Some(&pool));
    }

    #[test]
    fn output_matches_serial_quicksort_reference() {
        for n in [0usize, 1, 100, 2500] {
            let orig = arrays::uniform_i64(n, 31);
            let mut a = orig.clone();
            let mut b = orig.clone();
            samplesort_inplace(&mut a, 8, None, 7);
            serial_quicksort(&mut b, PivotStrategy::Random, 7);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn counts_include_partition_work() {
        let mut xs = arrays::uniform_i64(2000, 2);
        let ops = samplesort_inplace(&mut xs, 8, None, 1);
        assert!(ops.comparisons > 2000, "classification counted: {ops:?}");
        assert!(ops.swaps > 0, "cycle moves counted");
    }
}
