//! Fixed-memory streaming quantile digest for serving telemetry.
//!
//! The serving layer needs queue-wait percentiles that are (a) cheap to
//! record on the dispatch hot path, (b) cheap to snapshot under the
//! telemetry lock (`STATS` must not clone `O(samples)` buffers), and
//! (c) mergeable, so rolling windows and cross-lane rollups are bucket
//! additions rather than sample concatenations. [`Digest`] provides all
//! three with a log-bucketed histogram (HDR-histogram style, the same
//! family as t-digest/P² estimators but with a *provable* per-query
//! error bound instead of a heuristic one):
//!
//! * values are counted into buckets spaced `2^(1/SUBS_PER_OCTAVE)`
//!   apart geometrically, so memory is a fixed [`NBUCKETS`]-slot array
//!   (≈2 KiB) no matter how many samples are recorded;
//! * [`Digest::quantile`] returns the geometric midpoint of the bucket
//!   containing the exact rank-`q` sample, which bounds the relative
//!   value error by [`Digest::MAX_RATIO`] (≈4.6%, the half-bucket
//!   `2^(1/16) ≈ 4.4%` plus float slack) for any value inside the
//!   tracked range — see `rust/tests/prop_digest.rs` for the property
//!   checked against exact sorted-sample quantiles;
//! * [`Digest::merge`] is an element-wise bucket addition: exact,
//!   commutative, and associative on counts, so merged quantiles equal
//!   the quantiles of the union of the inputs' samples.
//!
//! The tracked range is `[2^-4, 2^30]` (in the caller's unit; for queue
//! waits in µs that is 62.5 ns … ~18 min). Finite values outside it —
//! including zero and negatives — clamp into the edge buckets, where the
//! relative bound no longer applies; non-finite values are dropped;
//! `min`/`max`/`mean` stay exact regardless because they are tracked
//! directly.

/// Geometric sub-buckets per factor-of-two. 8 gives a bucket width of
/// `2^(1/8) ≈ 1.09`, i.e. ≤ ~4.4% error from the geometric midpoint.
pub const SUBS_PER_OCTAVE: usize = 8;

/// Smallest tracked value is `2^LOG2_MIN` (see module docs for units).
pub const LOG2_MIN: f64 = -4.0;

/// Largest tracked value is `2^LOG2_MAX`.
pub const LOG2_MAX: f64 = 30.0;

/// Bucket count: `(LOG2_MAX - LOG2_MIN) * SUBS_PER_OCTAVE` octant steps.
/// Spelled as a literal so it can size an array type; the unit test
/// `bucket_count_matches_range` pins it to the formula.
pub const NBUCKETS: usize = 272;

/// A fixed-memory streaming quantile digest (log-bucketed histogram).
///
/// `Clone` is a flat memcpy of ~2 KiB and `merge` a bucket-wise add, so
/// snapshotting and windowing never touch per-sample storage.
#[derive(Debug, Clone, PartialEq)]
pub struct Digest {
    counts: [u64; NBUCKETS],
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Digest {
    fn default() -> Self {
        Digest::new()
    }
}

/// Percentile snapshot rendered from a [`Digest`] (the digest analogue
/// of [`super::Summary`], restricted to what buckets can answer).
#[derive(Debug, Clone, PartialEq)]
pub struct DigestSummary {
    pub n: u64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Digest {
    /// Guaranteed bound on `estimate / exact` (and its inverse) for
    /// quantiles of samples inside the tracked range: half a bucket in
    /// each direction, `2^(1 / (2 · SUBS_PER_OCTAVE)) ≈ 1.0443`, padded
    /// slightly for floating-point slack in the bucket index math.
    pub const MAX_RATIO: f64 = 1.046;

    pub fn new() -> Digest {
        Digest {
            counts: [0u64; NBUCKETS],
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// The bucket a (finite) value counts into. Zero and negative values
    /// clamp to the lowest bucket; values past the tracked range clamp
    /// to the edge buckets.
    fn bucket_of(v: f64) -> usize {
        if v <= 0.0 {
            return 0;
        }
        let pos = (v.log2() - LOG2_MIN) * SUBS_PER_OCTAVE as f64;
        if pos < 0.0 {
            0
        } else if pos >= NBUCKETS as f64 {
            NBUCKETS - 1
        } else {
            pos as usize
        }
    }

    /// Geometric midpoint of a bucket — the value a quantile query
    /// reports for samples that landed in it.
    fn representative(bucket: usize) -> f64 {
        2f64.powf(LOG2_MIN + (bucket as f64 + 0.5) / SUBS_PER_OCTAVE as f64)
    }

    /// Record one observation. O(1), no allocation. Non-finite values
    /// (NaN, ±∞) are dropped entirely: they have no meaningful bucket
    /// and a single ∞ would poison the running mean forever.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.counts[Self::bucket_of(v)] += 1;
        self.n += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Exact running mean (`None` when empty).
    pub fn mean(&self) -> Option<f64> {
        if self.n == 0 { None } else { Some(self.sum / self.n as f64) }
    }

    /// Exact minimum recorded value (`None` when empty).
    pub fn min(&self) -> Option<f64> {
        if self.n == 0 { None } else { Some(self.min) }
    }

    /// Exact maximum recorded value (`None` when empty).
    pub fn max(&self) -> Option<f64> {
        if self.n == 0 { None } else { Some(self.max) }
    }

    /// Estimate the `q`-quantile (`q` in `[0, 1]`), `None` when empty.
    ///
    /// Rank convention: the estimate targets the sample at ascending
    /// index `ceil(q·n) - 1` (clamped into range). The reported value is
    /// the geometric midpoint of that sample's bucket, clamped into the
    /// exact observed `[min, max]`, so for in-range samples it is within
    /// a factor [`Digest::MAX_RATIO`] of the true sorted-sample quantile.
    ///
    /// Delegates to [`Digest::quantile_union`] with an empty second
    /// digest, so the rank/scan logic exists exactly once.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        Self::quantile_union(self, &Digest::new(), q)
    }

    /// Quantile of the union of two digests without materializing the
    /// merge: one zipped cumulative walk over both bucket arrays, no
    /// clone, no allocation. Equal to `a.clone().merge(b).quantile(q)`;
    /// used on the admission hot path where that copy would be per-request
    /// work under the governor's lane lock.
    pub fn quantile_union(a: &Digest, b: &Digest, q: f64) -> Option<f64> {
        let n = a.n + b.n;
        if n == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        // An empty side contributes (+∞, -∞) sentinels, which min/max
        // ignore by construction.
        let (lo, hi) = (a.min.min(b.min), a.max.max(b.max));
        let mut cum = 0u64;
        for (bucket, (ca, cb)) in a.counts.iter().zip(b.counts.iter()).enumerate() {
            cum += ca + cb;
            if cum >= target {
                return Some(Self::representative(bucket).clamp(lo, hi));
            }
        }
        Some(hi)
    }

    /// Fold another digest in: bucket-wise addition (exact on counts and
    /// therefore on every quantile of the union; commutative and
    /// associative), exact on `min`/`max`, and summing on `mean`.
    pub fn merge(&mut self, other: &Digest) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        if other.n > 0 {
            if other.min < self.min {
                self.min = other.min;
            }
            if other.max > self.max {
                self.max = other.max;
            }
        }
    }

    /// Render the standard percentile snapshot (`None` when empty).
    pub fn summary(&self) -> Option<DigestSummary> {
        if self.n == 0 {
            return None;
        }
        Some(DigestSummary {
            n: self.n,
            mean: self.mean().expect("nonempty"),
            p50: self.quantile(0.50).expect("nonempty"),
            p90: self.quantile(0.90).expect("nonempty"),
            p99: self.quantile(0.99).expect("nonempty"),
            max: self.max,
        })
    }

    /// The fixed memory footprint of one digest, independent of how many
    /// samples were recorded (asserted by `prop_digest.rs`).
    pub fn memory_bytes() -> usize {
        std::mem::size_of::<Digest>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_count_matches_range() {
        assert_eq!(NBUCKETS, ((LOG2_MAX - LOG2_MIN) as usize) * SUBS_PER_OCTAVE);
    }

    #[test]
    fn empty_digest_answers_none() {
        let d = Digest::new();
        assert_eq!(d.count(), 0);
        assert!(d.is_empty());
        assert!(d.quantile(0.5).is_none());
        assert!(d.mean().is_none());
        assert!(d.min().is_none());
        assert!(d.max().is_none());
        assert!(d.summary().is_none());
    }

    #[test]
    fn single_value_is_every_quantile() {
        let mut d = Digest::new();
        d.record(42.0);
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            let est = d.quantile(q).unwrap();
            assert!(
                est / 42.0 <= Digest::MAX_RATIO && 42.0 / est <= Digest::MAX_RATIO,
                "q={q}: {est}"
            );
        }
        assert_eq!(d.min(), Some(42.0));
        assert_eq!(d.max(), Some(42.0));
        assert_eq!(d.mean(), Some(42.0));
    }

    #[test]
    fn quantiles_track_exact_on_a_known_sample() {
        let mut d = Digest::new();
        // 1..=100: exact p90 (ceil convention) is the 90th value = 90.
        for v in 1..=100 {
            d.record(v as f64);
        }
        assert_eq!(d.count(), 100);
        let p90 = d.quantile(0.9).unwrap();
        assert!(p90 / 90.0 <= Digest::MAX_RATIO && 90.0 / p90 <= Digest::MAX_RATIO, "{p90}");
        let p50 = d.quantile(0.5).unwrap();
        assert!(p50 / 50.0 <= Digest::MAX_RATIO && 50.0 / p50 <= Digest::MAX_RATIO, "{p50}");
        assert!((d.mean().unwrap() - 50.5).abs() < 1e-9, "mean is exact");
        let s = d.summary().unwrap();
        assert_eq!(s.n, 100);
        assert_eq!(s.max, 100.0);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn out_of_range_values_clamp_into_edge_buckets() {
        let mut d = Digest::new();
        d.record(0.0); // below range: lowest bucket
        d.record(-5.0); // negative: lowest bucket
        d.record(1e30); // above range: highest bucket
        d.record(f64::NAN); // dropped entirely
        d.record(f64::INFINITY); // dropped: would poison the mean
        d.record(f64::NEG_INFINITY); // dropped
        assert_eq!(d.count(), 3);
        assert!(d.mean().unwrap().is_finite(), "mean must survive ∞ inputs");
        assert_eq!(d.min(), Some(-5.0), "min stays exact despite clamping");
        assert_eq!(d.max(), Some(1e30), "max stays exact despite clamping");
        // Quantiles stay inside the observed range via the min/max clamp.
        let p99 = d.quantile(0.99).unwrap();
        assert!(p99 <= 1e30 && p99 >= -5.0);
    }

    #[test]
    fn merge_equals_recording_the_union() {
        let (mut a, mut b, mut whole) = (Digest::new(), Digest::new(), Digest::new());
        for v in [0.5, 3.0, 7.5, 100.0] {
            a.record(v);
            whole.record(v);
        }
        for v in [2.0, 9.0, 4096.0] {
            b.record(v);
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        for q in [0.0, 0.25, 0.5, 0.9, 1.0] {
            assert_eq!(a.quantile(q), whole.quantile(q), "q={q}");
        }
    }

    #[test]
    fn quantile_union_equals_materialized_merge() {
        let (mut a, mut b) = (Digest::new(), Digest::new());
        for v in [0.5, 3.0, 7.5, 100.0, 250.0] {
            a.record(v);
        }
        for v in [2.0, 9.0, 4096.0] {
            b.record(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(Digest::quantile_union(&a, &b, q), merged.quantile(q), "q={q}");
            assert_eq!(Digest::quantile_union(&b, &a, q), merged.quantile(q), "commutes, q={q}");
        }
        // One empty side degenerates to the other's quantile.
        let empty = Digest::new();
        assert_eq!(Digest::quantile_union(&a, &empty, 0.9), a.quantile(0.9));
        assert_eq!(Digest::quantile_union(&empty, &empty, 0.9), None);
    }

    #[test]
    fn memory_is_fixed_and_small() {
        let bytes = Digest::memory_bytes();
        assert!(bytes < 4096, "digest must stay ~2KiB, got {bytes}");
        assert_eq!(bytes, std::mem::size_of::<Digest>());
    }
}
