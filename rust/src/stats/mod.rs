//! Robust summary statistics, a small least-squares fitter, and a
//! fixed-memory streaming quantile digest.
//!
//! Used by the bench harness (sample summaries), the overhead calibrator
//! (fitting α/β/γ/δ from micro-benchmarks), the report layer, and the
//! serving telemetry ([`digest`] backs queue-wait percentiles and the
//! adaptive admission governor without retaining per-sample buffers).

pub mod digest;

pub use digest::{Digest, DigestSummary};

/// Summary of a sample of observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p10: percentile_sorted(&sorted, 10.0),
            p90: percentile_sorted(&sorted, 90.0),
        })
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean.abs()
        }
    }

    /// Half-width of an approximate 95% confidence interval on the mean.
    pub fn ci95_half(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        1.96 * self.std / (self.n as f64).sqrt()
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, `p` in [0,100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least squares `y = slope·x + intercept`; returns
/// `(slope, intercept, r²)`. Panics if fewer than 2 points or zero x-variance.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need >= 2 points to fit a line");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    assert!(sxx > 0.0, "x has zero variance");
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let syy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (slope, intercept, r2)
}

/// Multi-variate OLS without intercept: solve `min ||A·x - b||²` for small
/// column counts via normal equations + Gaussian elimination.
///
/// Used by the calibrator: each micro-benchmark run contributes a row
/// `(spawns, syncs, messages, bytes) → observed overhead ns`, and the
/// solution is the per-event costs `(α, β, γ, δ)`.
pub fn least_squares(rows: &[Vec<f64>], b: &[f64]) -> Vec<f64> {
    assert_eq!(rows.len(), b.len());
    assert!(!rows.is_empty());
    let k = rows[0].len();
    assert!(rows.iter().all(|r| r.len() == k));
    // Normal equations: (AᵀA) x = Aᵀb
    let mut ata = vec![vec![0.0f64; k]; k];
    let mut atb = vec![0.0f64; k];
    for (row, &bv) in rows.iter().zip(b) {
        for i in 0..k {
            atb[i] += row[i] * bv;
            for j in 0..k {
                ata[i][j] += row[i] * row[j];
            }
        }
    }
    // Ridge epsilon for numerical safety on near-collinear designs.
    for (i, row) in ata.iter_mut().enumerate() {
        row[i] += 1e-9;
        let _ = i;
    }
    gaussian_solve(ata, atb)
}

fn gaussian_solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // Partial pivot.
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        assert!(d.abs() > 1e-30, "singular system");
        for row in col + 1..n {
            let f = a[row][col] / d;
            for c in col..n {
                a[row][c] -= f * a[col][c];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for c in row + 1..n {
            acc -= a[row][c] * x[c];
        }
        x[row] = acc / a[row][row];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_singleton() {
        assert!(Summary::of(&[]).is_none());
        let s = Summary::of(&[7.0]).unwrap();
        assert_eq!(s.median, 7.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.ci95_half(), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0]; // y = 2x + 1
        let (m, c, r2) = linear_fit(&xs, &ys);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((c - 1.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn least_squares_recovers_coeffs() {
        // b = 3*x0 + 5*x1 exactly.
        let rows = vec![
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
            vec![2.0, 3.0],
        ];
        let b: Vec<f64> = rows.iter().map(|r| 3.0 * r[0] + 5.0 * r[1]).collect();
        let x = least_squares(&rows, &b);
        assert!((x[0] - 3.0).abs() < 1e-6, "{x:?}");
        assert!((x[1] - 5.0).abs() < 1e-6, "{x:?}");
    }

    #[test]
    fn least_squares_overdetermined_noisy() {
        let rows: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![i as f64, (i * i % 17) as f64])
            .collect();
        let b: Vec<f64> = rows
            .iter()
            .enumerate()
            .map(|(i, r)| 2.0 * r[0] + 7.0 * r[1] + if i % 2 == 0 { 0.01 } else { -0.01 })
            .collect();
        let x = least_squares(&rows, &b);
        assert!((x[0] - 2.0).abs() < 1e-2, "{x:?}");
        assert!((x[1] - 7.0).abs() < 1e-2, "{x:?}");
    }
}
