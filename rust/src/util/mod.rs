//! Small shared utilities: deterministic PRNG, wall-clock timing, env knobs.

pub mod rng;
pub mod timer;

pub use rng::Pcg32;
pub use timer::Stopwatch;

/// Read an environment override (`OHM_*` knobs), falling back to `default`.
///
/// Used by the CLI and benches so experiments can be re-parameterized
/// without recompiling (e.g. `OHM_CORES=8 cargo bench`).
pub fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Round `v` up to the next multiple of `m` (m > 0).
pub fn round_up(v: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    v.div_ceil(m) * m
}

/// Integer ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 4), 0);
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(4, 4), 4);
        assert_eq!(round_up(1000, 128), 1024);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(6, 3), 2);
        assert_eq!(ceil_div(7, 3), 3);
    }

    #[test]
    fn env_or_falls_back() {
        assert_eq!(env_or::<usize>("OHM_DEFINITELY_UNSET_KNOB", 7), 7);
    }
}
