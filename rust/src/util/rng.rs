//! Deterministic PRNG (PCG32 seeded via SplitMix64).
//!
//! The offline build has no `rand` crate, and determinism is a hard
//! requirement anyway: every experiment, simulator run, and property test
//! must replay bit-identically from a seed. PCG32 (O'Neill 2014) is small,
//! fast, and statistically solid for workload generation.

/// SplitMix64 step — used to expand a single `u64` seed into PCG state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Deterministic generator from a seed; distinct seeds give
    /// independent-looking streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1; // increment must be odd
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-worker / per-task RNGs).
    pub fn split(&mut self) -> Pcg32 {
        Pcg32::new(self.next_u64())
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for workload generation; exact rejection not needed here).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        (((self.next_u64() >> 11) as u128 * bound as u128) >> 53) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)` as f32.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Standard normal via Box–Muller (used for Gaussian workloads).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times for the
    /// coordinator's job traces).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..1000).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5, "streams should not collide: {same}");
    }

    #[test]
    fn below_in_bounds_and_covers() {
        let mut rng = Pcg32::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg32::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut rng = Pcg32::new(19);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "mean={mean}");
    }
}
