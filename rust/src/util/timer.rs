//! Wall-clock measurement helpers.

use std::time::{Duration, Instant};

/// A simple re-startable stopwatch around [`Instant`].
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ns(&self) -> u64 {
        self.elapsed().as_nanos() as u64
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Render nanoseconds human-readably (`412ns`, `3.21µs`, `4.5ms`, `2.13s`).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time a closure once, returning (result, elapsed).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_ns();
        let b = sw.elapsed_ns();
        assert!(b >= a);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(412.0), "412ns");
        assert_eq!(fmt_ns(3210.0), "3.21µs");
        assert_eq!(fmt_ns(4_500_000.0), "4.50ms");
        assert_eq!(fmt_ns(2.13e9), "2.130s");
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
