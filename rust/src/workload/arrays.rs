//! Integer-array workloads for the sorting domain (Table 3, Fig 5).

use crate::util::Pcg32;

/// Input distribution for sorting workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Distribution {
    /// i.i.d. uniform over a wide range — the paper's (implicit) default.
    UniformRandom,
    /// Already ascending — adversarial for left-pivot quicksort.
    Sorted,
    /// Strictly descending — adversarial for right-pivot quicksort.
    Reverse,
    /// Only `k` distinct values — stresses partition balance.
    FewUnique { k: usize },
    /// Rounded Gaussian — clustered values.
    Gaussian,
    /// Piecewise ascending runs (nearly-sorted real-world shape).
    Sawtooth { run: usize },
}

impl Distribution {
    pub fn name(&self) -> String {
        match self {
            Distribution::UniformRandom => "uniform".into(),
            Distribution::Sorted => "sorted".into(),
            Distribution::Reverse => "reverse".into(),
            Distribution::FewUnique { k } => format!("few-unique-{k}"),
            Distribution::Gaussian => "gaussian".into(),
            Distribution::Sawtooth { run } => format!("sawtooth-{run}"),
        }
    }
}

/// Generate `n` i64 values with the given distribution and seed.
pub fn generate(n: usize, dist: Distribution, seed: u64) -> Vec<i64> {
    let mut rng = Pcg32::new(seed);
    match dist {
        Distribution::UniformRandom => (0..n).map(|_| rng.range_i64(-1_000_000, 1_000_000)).collect(),
        Distribution::Sorted => (0..n as i64).collect(),
        Distribution::Reverse => (0..n as i64).rev().collect(),
        Distribution::FewUnique { k } => {
            let k = k.max(1);
            (0..n).map(|_| rng.below(k as u64) as i64).collect()
        }
        Distribution::Gaussian => (0..n).map(|_| (rng.normal() * 1e5) as i64).collect(),
        Distribution::Sawtooth { run } => {
            let run = run.max(1);
            (0..n).map(|i| (i % run) as i64).collect()
        }
    }
}

/// Shorthand for the paper's default workload.
pub fn uniform_i64(n: usize, seed: u64) -> Vec<i64> {
    generate(n, Distribution::UniformRandom, seed)
}

/// f32 variant for XLA-backed sorting (bitonic artifacts take f32).
pub fn uniform_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.f32_range(-1000.0, 1000.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(uniform_i64(100, 5), uniform_i64(100, 5));
        assert_ne!(uniform_i64(100, 5), uniform_i64(100, 6));
    }

    #[test]
    fn sorted_reverse_shapes() {
        let s = generate(10, Distribution::Sorted, 0);
        assert!(s.windows(2).all(|w| w[0] <= w[1]));
        let r = generate(10, Distribution::Reverse, 0);
        assert!(r.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn few_unique_cardinality() {
        let v = generate(1000, Distribution::FewUnique { k: 4 }, 1);
        let mut u = v.clone();
        u.sort_unstable();
        u.dedup();
        assert!(u.len() <= 4);
    }

    #[test]
    fn sizes_respected() {
        for n in [0, 1, 2, 1000] {
            assert_eq!(generate(n, Distribution::Gaussian, 2).len(), n);
            assert_eq!(uniform_f32(n, 2).len(), n);
        }
    }

    #[test]
    fn sawtooth_runs_ascend() {
        let v = generate(20, Distribution::Sawtooth { run: 5 }, 0);
        assert_eq!(&v[0..5], &[0, 1, 2, 3, 4]);
        assert_eq!(v[5], 0);
    }
}
