//! Matrix workloads for the DLA domain (Table 1, Fig 1, Fig 2).

use crate::dla::Matrix;
use crate::util::Pcg32;

/// Uniform random matrix in [-1, 1) — the Fig 2 workload.
pub fn uniform(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed);
    Matrix::from_fn(rows, cols, |_, _| rng.f32_range(-1.0, 1.0))
}

/// Identity matrix (exactness checks: A·I = A).
pub fn identity(n: usize) -> Matrix {
    Matrix::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
}

/// Diagonally dominant well-conditioned matrix (stability tests).
pub fn diag_dominant(n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed);
    Matrix::from_fn(n, n, |r, c| {
        if r == c {
            n as f32 + rng.f32_range(0.0, 1.0)
        } else {
            rng.f32_range(-0.5, 0.5)
        }
    })
}

/// Low-precision-friendly integer-valued matrix: products are exactly
/// representable in f32, so serial/parallel/XLA results must be
/// *bit-identical* (used by cross-backend equivalence tests).
pub fn small_int(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed);
    Matrix::from_fn(rows, cols, |_, _| rng.range_i64(-8, 9) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_deterministic_and_in_range() {
        let a = uniform(20, 30, 3);
        let b = uniform(20, 30, 3);
        assert_eq!(a.data(), b.data());
        assert!(a.data().iter().all(|v| (-1.0..1.0).contains(v)));
        assert_eq!((a.rows(), a.cols()), (20, 30));
    }

    #[test]
    fn identity_multiplies_exactly() {
        let a = small_int(16, 16, 4);
        let i = identity(16);
        let prod = crate::dla::matmul::serial(&a, &i);
        assert_eq!(prod.data(), a.data());
    }

    #[test]
    fn diag_dominant_dominates() {
        let m = diag_dominant(8, 5);
        for r in 0..8 {
            let diag = m.get(r, r).abs();
            let off: f32 = (0..8).filter(|&c| c != r).map(|c| m.get(r, c).abs()).sum();
            assert!(diag > off);
        }
    }
}
