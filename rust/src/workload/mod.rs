//! Workload generators for the paper's experiments.
//!
//! The paper only specifies "order of matrix" and "number of elements";
//! distributions here fill in the standard assumptions (uniform random)
//! plus the adversarial shapes used by the pivot ablation
//! (sorted / reverse / few-unique — the inputs that make left/right pivots
//! quadratic and motivate random pivots in the first place).

pub mod arrays;
pub mod matrices;
pub mod traces;
