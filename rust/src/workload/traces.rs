//! Job traces for the coordinator: Poisson arrivals over a mixed op set.
//!
//! The paper's applications section motivates "scientific and mathematical
//! domains where parallelization of mathematical concepts is demanded";
//! a trace models such a client: a stream of matmul and sort requests of
//! varying sizes arriving over time.

use crate::util::Pcg32;

/// One request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceJob {
    /// Arrival offset from trace start, in microseconds.
    pub arrival_us: u64,
    pub kind: TraceKind,
    /// Workload seed (distinct per job).
    pub seed: u64,
}

// `Hash`: a `TraceKind` (with the seed) is the warm result cache's key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TraceKind {
    /// Square matmul of the given order.
    Matmul { n: usize },
    /// Quicksort of `n` elements.
    Sort { n: usize },
}

impl TraceKind {
    /// Approximate serial work, in "element operations" — used by the
    /// coordinator's policy to pick a backend before running.
    pub fn work_estimate(&self) -> f64 {
        match self {
            TraceKind::Matmul { n } => (*n as f64).powi(3),
            TraceKind::Sort { n } => {
                let n = *n as f64;
                n * n.log2().max(1.0)
            }
        }
    }
}

/// Trace generator parameters.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Number of jobs.
    pub jobs: usize,
    /// Mean arrival rate (jobs per second).
    pub rate_per_s: f64,
    /// Candidate matmul orders.
    pub matmul_orders: Vec<usize>,
    /// Candidate sort sizes.
    pub sort_sizes: Vec<usize>,
    /// Fraction of jobs that are matmuls (rest are sorts), in [0, 1].
    pub matmul_fraction: f64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            jobs: 100,
            rate_per_s: 200.0,
            // Paper sweep sizes (Fig 2 / Table 3).
            matmul_orders: vec![64, 128, 256, 512],
            sort_sizes: vec![1000, 1100, 1500, 2000],
            matmul_fraction: 0.5,
        }
    }
}

/// Generate a deterministic Poisson trace.
pub fn generate(spec: &TraceSpec, seed: u64) -> Vec<TraceJob> {
    assert!(!spec.matmul_orders.is_empty() && !spec.sort_sizes.is_empty());
    let mut rng = Pcg32::new(seed);
    let mut t_us = 0.0f64;
    (0..spec.jobs)
        .map(|i| {
            t_us += rng.exp(spec.rate_per_s) * 1e6;
            let kind = if rng.f64() < spec.matmul_fraction {
                let n = spec.matmul_orders[rng.below(spec.matmul_orders.len() as u64) as usize];
                TraceKind::Matmul { n }
            } else {
                let n = spec.sort_sizes[rng.below(spec.sort_sizes.len() as u64) as usize];
                TraceKind::Sort { n }
            };
            TraceJob { arrival_us: t_us as u64, kind, seed: seed ^ (i as u64) << 17 }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let spec = TraceSpec::default();
        let a = generate(&spec, 9);
        let b = generate(&spec, 9);
        assert_eq!(a, b);
        assert_eq!(a.len(), spec.jobs);
        assert!(a.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
    }

    #[test]
    fn mix_fraction_respected() {
        let spec = TraceSpec { jobs: 2000, matmul_fraction: 0.25, ..Default::default() };
        let t = generate(&spec, 1);
        let mm = t.iter().filter(|j| matches!(j.kind, TraceKind::Matmul { .. })).count();
        let frac = mm as f64 / t.len() as f64;
        assert!((frac - 0.25).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn arrival_rate_approximates_spec() {
        let spec = TraceSpec { jobs: 5000, rate_per_s: 1000.0, ..Default::default() };
        let t = generate(&spec, 2);
        let span_s = t.last().unwrap().arrival_us as f64 / 1e6;
        let rate = t.len() as f64 / span_s;
        assert!((rate - 1000.0).abs() < 100.0, "rate={rate}");
    }

    #[test]
    fn work_estimates_ordered() {
        assert!(
            TraceKind::Matmul { n: 512 }.work_estimate()
                > TraceKind::Matmul { n: 64 }.work_estimate()
        );
        assert!(TraceKind::Sort { n: 2000 }.work_estimate() > TraceKind::Sort { n: 1000 }.work_estimate());
    }
}
