//! Chaos conformance: the full fault × feature scenario matrix must be
//! green with the pinned CI seed, and the nastiest known interleaving —
//! DRAIN arriving while an adaptive rebalance window and a single-flight
//! cache fill are both mid-flight — must settle exactly-once with
//! bit-identical results.
//!
//! The matrix itself lives behind `ohm chaos --matrix` (see docs/CHAOS.md
//! for the cell layout); this suite drives it end to end exactly as the
//! CI `chaos-matrix` job does, then exercises the triple race the matrix
//! cells can't line up on purpose.

mod common;

use common::stat_u64;
use ohm::coordinator::server::Server;
use ohm::coordinator::{AdmissionMode, Coordinator, CoordinatorCfg, RebalanceMode};
use ohm::workload::traces::TraceKind;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// Parse `key=<u64>` out of one report line's whitespace-separated
/// fields (`injected=3`, `drop=1`, ...).
fn field_u64(line: &str, key: &str) -> u64 {
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(key))
        .unwrap_or_else(|| panic!("{key:?} missing in report line {line:?}"))
        .parse()
        .unwrap_or_else(|_| panic!("non-numeric {key:?} in report line {line:?}"))
}

#[test]
fn chaos_matrix_is_green_with_the_pinned_ci_seed() {
    let report_path = std::env::temp_dir().join("ohm-chaos-matrix-report.txt");
    let argv: Vec<String> =
        ["chaos", "--matrix", "--seed", "42", "--out", report_path.to_str().unwrap()]
            .iter()
            .map(|s| s.to_string())
            .collect();
    let out = ohm::cli::run(&argv).unwrap();

    // Every cell green, none failed, and the saved report is the same
    // evidence CI uploads as an artifact.
    assert!(out.contains("chaos matrix: 14/14 cells green (seed 42)"), "{out}");
    assert!(!out.contains("verdict=FAIL"), "{out}");
    assert_eq!(out.matches("verdict=PASS").count(), 14, "{out}");
    let saved = std::fs::read_to_string(&report_path).unwrap();
    assert_eq!(saved, out, "--out report must match the console report");
    std::fs::remove_file(&report_path).ok();

    // The pinned @N triggers have guaranteed opportunities in a 12-request
    // sequential trace, so these kinds must have actually injected in
    // BOTH feature cells — a matrix that passes by never firing its
    // faults proves nothing.
    for kind in ["kill-lane", "wedge-client", "stall-dispatcher", "drop-reply"] {
        let lines: Vec<&str> =
            out.lines().filter(|l| l.contains(&format!("fault={kind} "))).collect();
        assert_eq!(lines.len(), 2, "{kind}: expected a base and a full cell\n{out}");
        for line in lines {
            assert!(field_u64(line, "injected=") >= 1, "{kind} never fired: {line}");
        }
    }
    // abort-flight needs a live cache to have any opportunity: the full
    // cell must fire, the base (cache-off) cell must count zero.
    for line in out.lines().filter(|l| l.contains("fault=abort-flight ")) {
        let want_fired = line.contains("features=full");
        let injected = field_u64(line, "injected=");
        assert_eq!(injected >= 1, want_fired, "abort-flight opportunity gating: {line}");
    }
    // The reply-path faults are visible client-side as lost replies.
    for kind in ["wedge-client", "drop-reply"] {
        for line in out.lines().filter(|l| l.contains(&format!("fault={kind} "))) {
            assert!(field_u64(line, "drop=") >= 1, "{kind} cell lost no replies: {line}");
        }
    }
}

/// ROADMAP 5(c): the triple race. A slow matmul holds a single-flight
/// cache fill open, the 50ms adaptive-rebalance window is live, and
/// DRAIN lands on top of both. Exactly-once still has to hold: every
/// client sees either a bit-identical `OK` or `ERR DRAINING` (nothing
/// hangs, nothing is double-executed), the drained trailer balances, the
/// lane telemetry is regime-pure, and the server exits promptly.
#[test]
fn drain_during_rebalance_during_cache_fill_settles_exactly_once() {
    let cfg = CoordinatorCfg {
        threads: 1,
        serve_threads: 4,
        lanes: 4,
        steal: false,
        cache: true,
        cache_entries: 64,
        cache_bytes: 1 << 20,
        admission: AdmissionMode::Adaptive,
        slo_p90_us: 1e9, // adaptive governor live but never shedding
        admission_window_ms: 50,
        rebalance: RebalanceMode::Adaptive,
        rebalance_window_ms: 50,
        ..Default::default()
    };

    let mut reference =
        Coordinator::new(CoordinatorCfg { threads: 1, ..Default::default() }, None);
    let want = format!("checksum={:.4}", reference.submit(TraceKind::Matmul { n: 256 }, 7).checksum);

    let server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let (done_tx, done_rx) = mpsc::channel();
    let serve = thread::spawn(move || {
        let result = server.serve(cfg, None);
        let _ = done_tx.send(result);
    });

    // Client 0 leads the cache fill (n=256 on one worker thread is slow
    // enough to stay in flight); the others send the identical request
    // staggered a few ms apart, so they land as single-flight followers
    // — some before the drain, likely some after.
    let clients: Vec<_> = (0..5)
        .map(|i| {
            let want = want.clone();
            thread::spawn(move || {
                thread::sleep(Duration::from_millis(2 * i as u64));
                let stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
                let mut out = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                writeln!(out, "MATMUL 256 7").unwrap();
                out.flush().unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let reply = line.trim().to_string();
                assert!(
                    (reply.starts_with("OK ") && reply.contains(&want))
                        || reply.starts_with("ERR DRAINING"),
                    "client {i}: neither a bit-identical OK nor ERR DRAINING: {reply:?}"
                );
                reply
            })
        })
        .collect();

    // Land the DRAIN while the fill (and the first rebalance window) is
    // still in flight.
    thread::sleep(Duration::from_millis(10));
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut out = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(out, "DRAIN").unwrap();
    out.flush().unwrap();
    let mut block = String::new();
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "server closed mid-DRAIN:\n{block}");
        if line.trim() == "." {
            break;
        }
        block.push_str(&line);
    }
    assert!(block.starts_with("DRAINED"), "{block}");

    let replies: Vec<String> = clients.into_iter().map(|h| h.join().unwrap()).collect();
    // The leader was admitted well before the drain, so at least one
    // client must have been served for real.
    assert!(replies.iter().any(|r| r.starts_with("OK ")), "{replies:?}");

    // Nothing admitted was lost, and nothing ran twice: the trailer
    // balances and agrees with the count of OK replies that required an
    // execution (followers ride the leader's single flight, so cache-fed
    // OKs don't add admissions).
    assert_eq!(
        stat_u64(&block, "admitted="),
        stat_u64(&block, "finished="),
        "drained trailer out of balance:\n{block}"
    );

    // Regime-pure telemetry even with the rebalancer mid-window.
    let lane_titles: Vec<&str> = block.lines().filter(|l| l.contains("dispatch lanes")).collect();
    let epoch_titled = lane_titles.iter().filter(|l| l.contains("dispatch lanes (epoch")).count();
    assert!(
        epoch_titled == 0 || epoch_titled == lane_titles.len(),
        "regime-mixed lane tables:\n{block}"
    );

    // Bounded exit: the serve thread ends promptly after the drain.
    let serve_result = done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("server did not exit within 30s of DRAIN");
    serve.join().unwrap();
    serve_result.unwrap();
}
