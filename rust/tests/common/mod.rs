//! Shared helpers for the integration test suites.

// Each test binary compiles this module independently and uses a
// different subset of the helpers.
#![allow(dead_code)]

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

/// Fetch the STATS block over a fresh connection: returns the block's
/// lines (without the `.` terminator), then QUITs cleanly.
pub fn fetch_stats(addr: SocketAddr) -> String {
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;
    writeln!(out, "STATS").unwrap();
    out.flush().unwrap();
    let mut block = String::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "server closed mid-STATS:\n{block}");
        if line.trim() == "." {
            break;
        }
        block.push_str(&line);
    }
    writeln!(out, "QUIT").unwrap();
    out.flush().unwrap();
    let mut bye = String::new();
    reader.read_line(&mut bye).unwrap();
    assert_eq!(bye.trim(), "BYE");
    block
}

/// Extract the unsigned integer immediately following `key` in rendered
/// STATS/telemetry text — e.g. `stat_u64(stats, "completed=")` or
/// `stat_u64(stats, "max width ")`. Panics with the full text on a
/// missing key or non-numeric suffix so failures stay diagnosable.
pub fn stat_u64(stats: &str, key: &str) -> u64 {
    let at = stats.find(key).unwrap_or_else(|| panic!("{key:?} missing in:\n{stats}"));
    stats[at + key.len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap_or_else(|_| panic!("no number after {key:?} in:\n{stats}"))
}
