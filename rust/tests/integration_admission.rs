//! Integration tests for SLO-driven adaptive admission: a lane driven
//! past its queue-wait SLO must shed with `ERR OVERLOADED` (while the
//! hard `ERR BUSY` path stays untouched), recover once the load drops,
//! and report per-lane percentiles and shed counts in the STATS
//! admission table. Fixed mode must never shed under the identical
//! sequence.

mod common;

use common::{fetch_stats, stat_u64};
use ohm::coordinator::server::Server;
use ohm::coordinator::{AdmissionMode, CoordinatorCfg};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn request(out: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> String {
    writeln!(out, "{line}").unwrap();
    out.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply.trim().to_string()
}

fn quit(mut out: TcpStream, mut reader: BufReader<TcpStream>) {
    assert_eq!(request(&mut out, &mut reader, "QUIT"), "BYE");
}

/// Deterministic overload: with `slo_p90_us = 0` every measured queue
/// wait (always strictly positive) violates the SLO, so the very first
/// served job flips its lane to shedding — no timing races involved.
/// The governor observes the wait *before* the reply is written, so once
/// the client has read its own `OK`, the next request must shed.
fn overload_cfg(window_ms: u64) -> CoordinatorCfg {
    CoordinatorCfg {
        threads: 1,
        serve_threads: 2,
        queue_depth: 64,
        // Stealing off so the sort lane's jobs execute on the sort lane;
        // admission feedback is keyed by routed lane either way, but the
        // test stays simplest with one moving part fewer.
        steal: false,
        admission: AdmissionMode::Adaptive,
        slo_p90_us: 0.0,
        admission_window_ms: window_ms,
        ..Default::default()
    }
}

#[test]
fn adaptive_sheds_past_slo_with_evidence_and_stats_table() {
    let server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    // Window far longer than the test: the rolling estimate cannot age
    // out mid-sequence, so every assertion is deterministic.
    let h = std::thread::spawn(move || server.serve(overload_cfg(600_000), Some(2)).unwrap());

    let (mut out, mut reader) = connect(addr);
    let first = request(&mut out, &mut reader, "SORT 300 1");
    assert!(first.starts_with("OK SORT n=300"), "no waits observed yet: {first}");

    // The first job's queue wait is now in the rolling window and any
    // positive p90 exceeds slo=0: the lane must shed, with evidence.
    let second = request(&mut out, &mut reader, "SORT 300 2");
    assert!(second.starts_with("ERR OVERLOADED"), "expected a shed: {second}");
    assert!(second.contains("p90="), "shed must report the observed p90: {second}");
    assert!(second.contains("slo=0"), "shed must report the SLO: {second}");

    // Hysteresis: still shedding on the next request.
    let third = request(&mut out, &mut reader, "SORT 300 3");
    assert!(third.starts_with("ERR OVERLOADED"), "hysteresis must hold: {third}");

    // The matmul lane is independent: its window is empty, so it admits.
    let matmul = request(&mut out, &mut reader, "MATMUL 24 4");
    assert!(matmul.starts_with("OK MATMUL n=24"), "sibling lane must admit: {matmul}");
    quit(out, reader);

    let stats = fetch_stats(addr);
    h.join().unwrap();
    assert_eq!(stat_u64(&stats, "shed="), 2, "stats:\n{stats}");
    assert_eq!(stat_u64(&stats, "rejected="), 0, "sheds are not ERR BUSY:\n{stats}");
    assert_eq!(stat_u64(&stats, "completed="), 2, "stats:\n{stats}");
    assert!(stats.contains("admission (mode=adaptive, slo p90=0µs)"), "stats:\n{stats}");
    assert!(stats.contains("sheds=2"), "ledger carries the sheds:\n{stats}");
    // The admission table renders per-lane percentiles from the digests.
    for col in ["p50 (µs)", "p90 (µs)", "p99 (µs)"] {
        assert!(stats.contains(col), "admission percentile column {col} missing:\n{stats}");
    }
}

#[test]
fn adaptive_recovers_after_the_window_drains() {
    let server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    // Short rolling window: after ~2 windows of silence the estimate is
    // empty and the lane must re-admit (idle recovery).
    let h = std::thread::spawn(move || server.serve(overload_cfg(400), Some(1)).unwrap());

    let (mut out, mut reader) = connect(addr);
    let first = request(&mut out, &mut reader, "SORT 300 1");
    assert!(first.starts_with("OK SORT"), "{first}");
    let second = request(&mut out, &mut reader, "SORT 300 2");
    assert!(second.starts_with("ERR OVERLOADED"), "{second}");

    // Let both half-windows age out, then the lane must admit again.
    std::thread::sleep(Duration::from_millis(1_000));
    let third = request(&mut out, &mut reader, "SORT 300 3");
    assert!(third.starts_with("OK SORT"), "lane must recover after idle windows: {third}");
    quit(out, reader);
    h.join().unwrap();
}

#[test]
fn fixed_admission_never_sheds_on_the_identical_sequence() {
    let server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let cfg = CoordinatorCfg {
        admission: AdmissionMode::Fixed,
        // Same impossible SLO: fixed mode must ignore it entirely.
        slo_p90_us: 0.0,
        ..overload_cfg(600_000)
    };
    let h = std::thread::spawn(move || server.serve(cfg, Some(2)).unwrap());

    let (mut out, mut reader) = connect(addr);
    for seed in 1..=4 {
        let reply = request(&mut out, &mut reader, &format!("SORT 300 {seed}"));
        assert!(reply.starts_with("OK SORT"), "fixed mode must not shed: {reply}");
    }
    quit(out, reader);

    let stats = fetch_stats(addr);
    h.join().unwrap();
    assert_eq!(stat_u64(&stats, "shed="), 0, "stats:\n{stats}");
    assert_eq!(stat_u64(&stats, "completed="), 4, "stats:\n{stats}");
    assert!(stats.contains("admission (mode=fixed"), "table still renders:\n{stats}");
}
