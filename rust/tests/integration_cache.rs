//! Integration tests for the warm result cache behind the TCP serving
//! layer: hits must bypass a shedding lane (no admission budget, no
//! queue), DRAIN must complete cleanly with single-flight followers
//! in flight, and `--cache off` (the default) must leave the STATS
//! shape exactly as it was before the cache existed.

mod common;

use common::{fetch_stats, stat_u64};
use ohm::coordinator::server::Server;
use ohm::coordinator::{AdmissionMode, CoordinatorCfg};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn request(out: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> String {
    writeln!(out, "{line}").unwrap();
    out.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply.trim().to_string()
}

fn quit(mut out: TcpStream, mut reader: BufReader<TcpStream>) {
    assert_eq!(request(&mut out, &mut reader, "QUIT"), "BYE");
}

fn checksum_of(reply: &str) -> &str {
    reply
        .split_whitespace()
        .find(|t| t.starts_with("checksum="))
        .unwrap_or_else(|| panic!("no checksum in {reply:?}"))
}

#[test]
fn cache_hits_bypass_a_shedding_lane() {
    let server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    // slo=0 makes the overload deterministic: the first completed job's
    // (strictly positive) queue wait flips the sort lane to shedding.
    let cfg = CoordinatorCfg {
        threads: 1,
        serve_threads: 2,
        steal: false,
        admission: AdmissionMode::Adaptive,
        slo_p90_us: 0.0,
        admission_window_ms: 600_000,
        cache: true,
        ..Default::default()
    };
    let h = std::thread::spawn(move || server.serve(cfg, Some(2)).unwrap());

    let (mut out, mut reader) = connect(addr);
    let cold = request(&mut out, &mut reader, "SORT 300 1");
    assert!(cold.starts_with("OK SORT n=300"), "{cold}");
    assert!(!cold.contains("engine=cache"), "first run executes cold: {cold}");

    // The lane now sheds fresh work (different seed = cache miss)...
    let fresh = request(&mut out, &mut reader, "SORT 300 2");
    assert!(fresh.starts_with("ERR OVERLOADED"), "expected a shed: {fresh}");

    // ...but the identical repeat is served warm, bypassing admission
    // entirely: bit-identical checksum, engine=cache, no queueing.
    let warm = request(&mut out, &mut reader, "SORT 300 1");
    assert!(
        warm.starts_with("OK SORT n=300"),
        "hit must be admitted even while the lane sheds: {warm}"
    );
    assert!(warm.contains("engine=cache"), "{warm}");
    assert!(warm.contains("queue_us=0.0"), "hits never queue: {warm}");
    assert_eq!(checksum_of(&cold), checksum_of(&warm), "bit-identical checksum");
    quit(out, reader);

    let stats = fetch_stats(addr);
    h.join().unwrap();
    assert_eq!(stat_u64(&stats, "completed="), 2, "cold run + warm hit:\n{stats}");
    assert_eq!(stat_u64(&stats, "shed="), 1, "only the fresh seed shed:\n{stats}");
    assert!(stats.contains("result cache"), "cache table renders:\n{stats}");
    assert_eq!(stat_u64(&stats, "cache: hits="), 1, "stats:\n{stats}");
    assert!(stats.contains("engine:cache"), "hit-path service series renders:\n{stats}");
    assert!(stats.contains("cache_hits=1"), "ledger attributes the managed-away work:\n{stats}");
}

#[test]
fn drain_completes_cleanly_with_single_flight_followers_in_flight() {
    let server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let cfg = CoordinatorCfg {
        // Small CPU pool + a large matmul: the leader's execution takes
        // long enough for a follower to coalesce and a DRAIN to arrive
        // while it is still in flight.
        threads: 2,
        serve_threads: 4,
        cache: true,
        ..Default::default()
    };
    let h = std::thread::spawn(move || server.serve(cfg, None).unwrap());

    let leader = std::thread::spawn(move || {
        let (mut out, mut reader) = connect(addr);
        let r = request(&mut out, &mut reader, "MATMUL 512 9");
        quit(out, reader);
        r
    });
    std::thread::sleep(Duration::from_millis(30));
    let follower = std::thread::spawn(move || {
        let (mut out, mut reader) = connect(addr);
        let r = request(&mut out, &mut reader, "MATMUL 512 9");
        quit(out, reader);
        r
    });
    std::thread::sleep(Duration::from_millis(30));

    // DRAIN while (in the common timing) the leader is still executing
    // and the follower is blocked on its flight. Whatever the timing
    // resolved to, the invariants below hold: the drain completes with
    // admitted == finished, and both clients get the same OK checksum —
    // an admitted leader always runs to completion, and its followers
    // are served from its result rather than stranded.
    let (mut out, mut reader) = connect(addr);
    writeln!(out, "DRAIN").unwrap();
    out.flush().unwrap();
    let mut block = String::new();
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "server closed mid-DRAIN:\n{block}");
        if line.trim() == "." {
            break;
        }
        block.push_str(&line);
    }
    assert!(block.starts_with("DRAINED"), "{block}");
    let admitted = stat_u64(&block, "drained: admitted=");
    let finished = stat_u64(&block, "finished=");
    assert_eq!(admitted, finished, "drain completeness:\n{block}");
    quit(out, reader);

    let leader_reply = leader.join().unwrap();
    let follower_reply = follower.join().unwrap();
    h.join().unwrap();
    assert!(leader_reply.starts_with("OK MATMUL n=512"), "{leader_reply}");
    assert!(follower_reply.starts_with("OK MATMUL n=512"), "{follower_reply}");
    assert_eq!(
        checksum_of(&leader_reply),
        checksum_of(&follower_reply),
        "follower served the leader's result"
    );
    assert!(admitted <= 2, "a coalesced follower consumes no admission:\n{block}");
}

#[test]
fn cache_off_keeps_the_stats_shape_and_reexecutes_repeats() {
    let server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    // Default cfg: cache off. Repeated seeds must re-execute (still
    // deterministic, so checksums agree), and nothing cache-related may
    // appear anywhere in replies or STATS.
    let cfg = CoordinatorCfg { threads: 1, ..Default::default() };
    assert!(!cfg.cache, "the cache defaults to off");
    let h = std::thread::spawn(move || server.serve(cfg, Some(1)).unwrap());

    let (mut out, mut reader) = connect(addr);
    let first = request(&mut out, &mut reader, "SORT 300 1");
    let second = request(&mut out, &mut reader, "SORT 300 1");
    assert!(first.starts_with("OK SORT"), "{first}");
    assert!(second.starts_with("OK SORT"), "{second}");
    assert!(!second.contains("engine=cache"), "no cache ⇒ repeat re-executes: {second}");
    assert_eq!(checksum_of(&first), checksum_of(&second), "determinism without caching");

    writeln!(out, "STATS").unwrap();
    out.flush().unwrap();
    let mut stats = String::new();
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "server closed mid-STATS:\n{stats}");
        if line.trim() == "." {
            break;
        }
        stats.push_str(&line);
    }
    quit(out, reader);
    h.join().unwrap();

    assert_eq!(stat_u64(&stats, "completed="), 2, "both executions served:\n{stats}");
    for forbidden in ["result cache", "cache: hits=", "engine:cache", "cache_hits="] {
        assert!(
            !stats.contains(forbidden),
            "--cache off must leave STATS in its pre-cache shape; found {forbidden:?} in:\n{stats}"
        );
    }
    // The pre-cache tables are all still present.
    assert!(stats.contains("coordinator telemetry"), "{stats}");
    assert!(stats.contains("dispatch lanes"), "{stats}");
    assert!(stats.contains("queue: len="), "{stats}");
}
