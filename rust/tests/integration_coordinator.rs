//! Integration: coordinator end-to-end, with and without the XLA runtime,
//! plus the serving layer's cross-connection shape batching.

mod common;

use ohm::coordinator::server::Server;
use ohm::coordinator::{Coordinator, CoordinatorCfg, RoutedEngine};
use ohm::runtime::Runtime;
use ohm::workload::traces::{self, TraceKind, TraceSpec};
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn xla_runtime() -> Option<Runtime> {
    let dir = artifacts_dir();
    if dir.join("manifest.tsv").exists() {
        Some(Runtime::load(&dir).expect("artifacts present but unloadable"))
    } else {
        eprintln!("skipping xla-coordinator integration: run `make artifacts`");
        None
    }
}

#[test]
fn cpu_only_trace_all_jobs_ok() {
    let mut c = Coordinator::new(CoordinatorCfg { threads: 2, ..Default::default() }, None);
    let spec = TraceSpec {
        jobs: 30,
        matmul_orders: vec![16, 32, 64],
        sort_sizes: vec![200, 500, 1000],
        ..Default::default()
    };
    let results = c.run_trace(&traces::generate(&spec, 3));
    assert_eq!(results.len(), 30);
    assert!(results.iter().all(|r| r.ok));
    assert_eq!(c.telemetry.completed, 30);
    assert_eq!(c.telemetry.engine_count(RoutedEngine::Xla), 0, "no runtime ⇒ no xla routing");
}

#[test]
fn xla_routing_used_for_known_shapes() {
    let Some(rt) = xla_runtime() else { return };
    let mut c = Coordinator::new(CoordinatorCfg { threads: 2, ..Default::default() }, Some(rt));
    assert_eq!(c.route(&TraceKind::Matmul { n: 64 }), RoutedEngine::Xla);
    assert_eq!(c.route(&TraceKind::Sort { n: 1000 }), RoutedEngine::Xla);
    // Shapes without artifacts fall back to CPU.
    assert_ne!(c.route(&TraceKind::Matmul { n: 48 }), RoutedEngine::Xla);
    assert_ne!(c.route(&TraceKind::Sort { n: 999 }), RoutedEngine::Xla);
    let r = c.submit(TraceKind::Matmul { n: 64 }, 5);
    assert!(r.ok);
    assert_eq!(r.engine, RoutedEngine::Xla);
    assert!(r.checksum > 0.0);
}

#[test]
fn xla_and_cpu_checksums_agree() {
    let Some(rt) = xla_runtime() else { return };
    // Same seed → same workload; frobenius checksum must agree between
    // XLA (L1 pallas kernel) and the CPU engines to ~f32 rounding.
    let mut with_xla = Coordinator::new(CoordinatorCfg::default(), Some(rt));
    let mut cpu_only = Coordinator::new(CoordinatorCfg::default(), None);
    let a = with_xla.submit(TraceKind::Matmul { n: 128 }, 77);
    let b = cpu_only.submit(TraceKind::Matmul { n: 128 }, 77);
    assert_eq!(a.engine, RoutedEngine::Xla);
    assert_ne!(b.engine, RoutedEngine::Xla);
    let rel = (a.checksum - b.checksum).abs() / b.checksum.abs().max(1.0);
    assert!(rel < 1e-5, "checksum divergence {rel}: {a:?} vs {b:?}");
}

#[test]
fn mixed_trace_with_runtime_routes_both_ways() {
    let Some(rt) = xla_runtime() else { return };
    let mut c = Coordinator::new(CoordinatorCfg { threads: 2, ..Default::default() }, Some(rt));
    let spec = TraceSpec {
        jobs: 40,
        matmul_orders: vec![48, 64],     // 48 has no artifact, 64 does
        sort_sizes: vec![999, 1000],     // likewise
        ..Default::default()
    };
    let results = c.run_trace(&traces::generate(&spec, 11));
    assert!(results.iter().all(|r| r.ok));
    let xla = results.iter().filter(|r| r.engine == RoutedEngine::Xla).count();
    assert!(xla > 0, "some jobs must hit XLA");
    assert!(xla < results.len(), "some jobs must stay on CPU");
    let telemetry = c.telemetry.render();
    assert!(telemetry.contains("engine:xla"), "{telemetry}");
}

/// Shape batching must extend *across connections*: three clients send
/// the same shape concurrently, the dispatcher lingers long enough for
/// the batch to form, and telemetry reports a batch width > 1.
#[test]
fn server_batches_same_shape_across_connections() {
    let server = Server::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let cfg = CoordinatorCfg {
        threads: 1,
        serve_threads: 4,
        queue_depth: 16,
        batch_linger_us: 500_000, // generous batch-formation window
        // Stealing off: an idle sibling lane would poach queued sorts out
        // of the forming batch and the width assertion would be flaky.
        steal: false,
        ..Default::default()
    };
    let h = std::thread::spawn(move || server.serve(cfg, Some(4)).unwrap());

    // Connect all clients before any sends (barrier), so connect jitter
    // cannot push a request outside the batch-formation window.
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(3));
    let clients: Vec<_> = (0..3)
        .map(|c| {
            let barrier = std::sync::Arc::clone(&barrier);
            std::thread::spawn(move || {
                let stream = std::net::TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut out = stream;
                barrier.wait();
                writeln!(out, "SORT 400 {c}").unwrap();
                out.flush().unwrap();
                let mut reply = String::new();
                reader.read_line(&mut reply).unwrap();
                assert!(reply.starts_with("OK SORT n=400"), "{reply}");
                writeln!(out, "QUIT").unwrap();
                out.flush().unwrap();
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }

    // Read STATS over a fourth connection and parse the max batch width.
    let stats = common::fetch_stats(addr);
    h.join().unwrap();

    assert!(stats.contains("batch-width"), "batch-width stats missing:\n{stats}");
    let width = common::stat_u64(&stats, "max width ");
    assert!(
        width >= 2,
        "expected a cross-connection batch of width ≥ 2, stats:\n{stats}"
    );
}

#[test]
fn telemetry_batches_count_shape_groups() {
    let mut c = Coordinator::new(CoordinatorCfg { threads: 1, ..Default::default() }, None);
    let jobs: Vec<_> = [100usize, 100, 300, 300, 300, 100]
        .iter()
        .map(|&n| ohm::workload::traces::TraceJob { arrival_us: 0, kind: TraceKind::Sort { n }, seed: 1 })
        .collect();
    c.run_trace(&jobs);
    assert_eq!(c.telemetry.batches, 3);
    assert_eq!(c.telemetry.batched_jobs, 6);
}
