//! Integration: the full experiment suite runs, writes well-formed
//! outputs, and the headline paper shapes hold end to end.

use ohm::config::ExperimentConfig;
use ohm::experiments;

fn small_cfg() -> ExperimentConfig {
    ExperimentConfig {
        matmul_orders: vec![32, 64, 128, 512, 1000],
        sort_sizes: vec![1000, 2000],
        reps: 1,
        ..Default::default()
    }
}

#[test]
fn all_experiments_run_and_save() {
    let cfg = small_cfg();
    let dir = std::env::temp_dir().join("ohm-int-exp");
    let _ = std::fs::remove_dir_all(&dir);
    let outs = experiments::run_all(&cfg).unwrap();
    assert_eq!(outs.len(), experiments::ALL.len());
    for out in &outs {
        let paths = experiments::save(out, &dir).unwrap();
        assert!(!out.text.is_empty(), "{} empty", out.id);
        for p in &paths {
            assert!(p.exists());
            let meta = std::fs::metadata(p).unwrap();
            assert!(meta.len() > 0, "{} empty file", p.display());
        }
    }
    // CSVs parse as rectangular tables.
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().is_some_and(|e| e == "csv") {
            let text = std::fs::read_to_string(&p).unwrap();
            let mut lines = text.lines();
            let header_cols = lines.next().unwrap().split(',').count();
            for l in lines {
                assert!(
                    l.split(',').count() >= header_cols,
                    "ragged csv {} line {l:?}",
                    p.display()
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fig2_shape_crossovers_ordered() {
    let out = experiments::run("fig2", &small_cfg()).unwrap();
    // Naive crossover exists at order ≈1000 (paper), managed well before.
    assert!(out.text.contains("naive at order 1000"), "{}", out.text);
    assert!(!out.text.contains("managed at order none"), "{}", out.text);
}

#[test]
fn table3_reproduces_paper_ordering_at_2000() {
    let cfg = ExperimentConfig { sort_sizes: vec![2000], reps: 2, ..Default::default() };
    let g = experiments::table3::grid(&cfg);
    let (_, c) = &g[0];
    // Paper row n=2000: serial 3.838 > random 3.136 > left/right > mean.
    assert!(c[0] > c[4], "serial must be slowest overall at n=2000: {c:?}");
    assert!(c[4] > c[2], "random slower than mean: {c:?}");
}

#[test]
fn ablation_grain_minimum_not_at_extremes() {
    // The interesting claim: the best grain is interior (not 1 task, and
    // not the absurd maximum) for a 512 matmul on 4 cores.
    let cfg = small_cfg();
    let out = experiments::run("abl-grain", &cfg).unwrap();
    let rows: Vec<(usize, f64)> = out.csv[0]
        .2
        .iter()
        .filter(|r| r[0] == "matmul")
        .map(|r| (r[1].parse().unwrap(), r[2].parse().unwrap()))
        .collect();
    let best = rows.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    assert!(best.1 < first.1, "1 task must not be optimal");
    assert!(best.1 <= last.1, "max tasks must not beat the optimum");
}

#[test]
fn cli_experiment_all_smoke() {
    let dir = std::env::temp_dir().join("ohm-cli-all");
    let _ = std::fs::remove_dir_all(&dir);
    let argv: Vec<String> = [
        "experiment",
        "all",
        "--out-dir",
        dir.to_str().unwrap(),
        "--reps",
        "1",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let out = ohm::cli::run(&argv).unwrap();
    assert!(out.contains("table3"));
    assert!(dir.join("fig2.txt").exists());
    assert!(dir.join("table3_quicksort.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}
